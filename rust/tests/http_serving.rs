//! HTTP-level chaos scenarios for the `cskv serve --listen` front-end,
//! plus the cross-process binary smoke (`CARGO_BIN_EXE_cskv`).
//!
//! In-process scenarios drive a real [`TcpListener`] + `serve()` loop
//! over loopback sockets and assert the robustness contract end to end:
//!
//! * **SSE correctness** — the streamed tokens and the terminal `done`
//!   event are bit-identical to the direct-engine oracle; `/healthz`,
//!   `/readyz` and `/stats` report truthfully alongside.
//! * **Mid-stream disconnect** — dropping the client socket cancels the
//!   request at the next round boundary (terminal outcome `cancelled`,
//!   KV bytes freed), while a concurrent bystander stays bit-identical.
//! * **Injected short write** (`http.write`, `FaultMode::Nth`) — a
//!   truncated SSE frame surfaces as a write error and cancels exactly
//!   that request; the server keeps serving afterwards.
//! * **Overload shedding** — with `max_queued = 1`, a burst during an
//!   active stream gets `429` + `Retry-After` (counted in
//!   `requests_shed`); the admitted stream is unaffected.
//! * **Drain with restore** — `POST /drain` mid-stream ends the SSE
//!   stream with a `migrated` terminal event, writes the bundle to
//!   disk, and a fresh coordinator resumes it bit-identically.
//! * **Accept fault** (`http.accept`) — a dropped connection at accept
//!   hits only that client; the listener keeps serving.
//!
//! Every scenario asserts exactly one terminal outcome per request
//! (completed / cancelled / shed / drained sum to the submit count) and
//! zero KV + cold bytes after drain.
//!
//! The binary tests spawn the real `cskv serve --listen` process (seeded
//! weights, throttled decode), exercise one streaming request, one
//! mid-stream disconnect, and a drain-to-file, then prove a second
//! process resumes the migrated sequence bit-identically
//! (`--resume-from`). Flag validation is covered the same way PR 7's
//! suite covers the offline flags: bad values exit non-zero with a
//! pointed message before any model work.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use cskv::coordinator::server::{BackendFactory, Setup};
use cskv::coordinator::{
    Coordinator, CoordinatorConfig, DrainBundle, HttpConfig, MetricsSnapshot,
    RustSequenceBackend, ThrottledBackend,
};
use cskv::kvcache::FullCache;
use cskv::model::{engine::Engine, ModelConfig, ModelWeights};
use cskv::util::faults::{FaultInjector, FaultMode};
use cskv::util::json::Json;

const LONG_PROMPT: [usize; 6] = [1, 7, 9, 2, 30, 41];
const SHORT_PROMPT: [usize; 3] = [3, 5, 8];
const WEIGHT_SEED: u64 = 5;

fn make_engine(seed: u64) -> Engine {
    Engine::new(Arc::new(ModelWeights::init(&ModelConfig::test_small(), seed)))
}

fn oracle(seed: u64, prompt: &[usize], n_new: usize) -> Vec<usize> {
    let engine = make_engine(seed);
    let cfg = engine.w.cfg.clone();
    let mut cache = FullCache::new(cfg.n_layers, cfg.d_model);
    engine.generate(prompt, n_new, &mut cache).0
}

/// Full-cache backends, optionally throttled so decode spans a wide,
/// schedulable window.
fn throttled_setup(seed: u64, throttle: Option<Duration>) -> Setup {
    Box::new(move || {
        let engine = make_engine(seed);
        let factory: BackendFactory = Box::new(move || {
            let c = engine.w.cfg.clone();
            let inner: Box<dyn cskv::coordinator::SequenceBackend> =
                Box::new(RustSequenceBackend::new(
                    engine.clone(),
                    Box::new(FullCache::new(c.n_layers, c.d_model)),
                ));
            Ok(match throttle {
                Some(d) => Box::new(ThrottledBackend::new(inner, d)),
                None => inner,
            })
        });
        Ok(factory)
    })
}

struct TestServer {
    addr: SocketAddr,
    join: std::thread::JoinHandle<anyhow::Result<MetricsSnapshot>>,
}

impl TestServer {
    /// Bind loopback, start `serve()` on a thread, return the resolved
    /// address + the handle that yields the final metrics snapshot.
    fn start(seed: u64, throttle_ms: u64, tweak: impl FnOnce(&mut HttpConfig)) -> TestServer {
        let throttle = (throttle_ms > 0).then(|| Duration::from_millis(throttle_ms));
        let coord = Coordinator::start(
            throttled_setup(seed, throttle),
            CoordinatorConfig::default(),
        );
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let addr = listener.local_addr().unwrap();
        let mut cfg = HttpConfig {
            drain_grace: Duration::ZERO,
            ..HttpConfig::default()
        };
        tweak(&mut cfg);
        let join = std::thread::spawn(move || cskv::coordinator::serve(coord, listener, cfg));
        TestServer { addr, join }
    }

    /// `POST /drain`, then join the serve loop for its final snapshot.
    fn drain_and_join(self) -> (usize, MetricsSnapshot) {
        let (status, _, body) = http_request(self.addr, "POST", "/drain", "");
        assert_eq!(status, 200, "drain must succeed: {}", String::from_utf8_lossy(&body));
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let migrated = j.at("migrated").and_then(Json::as_usize).unwrap();
        let snap = self.join.join().unwrap().expect("serve loop exits cleanly");
        (migrated, snap)
    }
}

/// One complete request/response exchange (`Connection: close`), raw.
fn http_request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String, Vec<u8>) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(
        format!(
            "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )
    .expect("send request");
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).expect("read response");
    split_response(&buf)
}

fn split_response(raw: &[u8]) -> (u16, String, Vec<u8>) {
    let pos = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("complete response head");
    let head = String::from_utf8_lossy(&raw[..pos]).to_string();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .expect("status code");
    (status, head, raw[pos + 4..].to_vec())
}

fn generate_body(prompt: &[usize], n_new: usize) -> String {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!("{{\"prompt\":[{}],\"n_new\":{n_new}}}", toks.join(","))
}

/// Parse complete SSE frames, skipping `: ping` comments and any
/// truncated trailing frame (short-write scenarios cut mid-frame).
fn parse_sse(body: &str) -> Vec<(String, Json)> {
    let mut out = Vec::new();
    for frame in body.split("\n\n") {
        let (mut event, mut data) = (None, None);
        for line in frame.lines() {
            if let Some(e) = line.strip_prefix("event: ") {
                event = Some(e.to_string());
            } else if let Some(d) = line.strip_prefix("data: ") {
                data = Some(d.to_string());
            }
        }
        if let (Some(e), Some(d)) = (event, data) {
            if let Ok(j) = Json::parse(&d) {
                out.push((e, j));
            }
        }
    }
    out
}

fn sse_tokens(events: &[(String, Json)]) -> Vec<usize> {
    events
        .iter()
        .filter(|(e, _)| e == "token")
        .map(|(_, j)| j.at("token").and_then(Json::as_usize).unwrap())
        .collect()
}

/// Run one `/generate` to completion and return its parsed SSE events.
fn sse_collect(addr: SocketAddr, prompt: &[usize], n_new: usize) -> Vec<(String, Json)> {
    let (status, head, body) = http_request(addr, "POST", "/generate", &generate_body(prompt, n_new));
    assert_eq!(status, 200, "{head}");
    assert!(head.contains("text/event-stream"), "{head}");
    parse_sse(std::str::from_utf8(&body).unwrap())
}

fn stats(addr: SocketAddr) -> Json {
    let (status, _, body) = http_request(addr, "GET", "/stats", "");
    assert_eq!(status, 200);
    Json::parse(std::str::from_utf8(&body).unwrap()).expect("stats is valid JSON")
}

fn stat_usize(j: &Json, path: &str) -> usize {
    j.at(path).and_then(Json::as_usize).unwrap_or(usize::MAX)
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed().as_secs() < 30, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn assert_no_leak(snap: &MetricsSnapshot) {
    assert_eq!(snap.kv_bytes_current, 0, "KV bytes must refund to zero after drain");
    assert_eq!(snap.cold_bytes_current, 0, "cold tier must be empty after drain");
}

fn tmp(label: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cskv-http-{label}-{}", std::process::id()))
}

/// Scenario 0 (baseline): a streamed generation is bit-identical to the
/// oracle, token frames and terminal `done` alike, and the probe
/// endpoints tell the truth before/after.
#[test]
fn sse_stream_is_bit_identical_and_probes_report_truthfully() {
    let want = oracle(WEIGHT_SEED, &SHORT_PROMPT, 6);
    let srv = TestServer::start(WEIGHT_SEED, 0, |_| {});

    let (st, _, body) = http_request(srv.addr, "GET", "/healthz", "");
    assert_eq!((st, &body[..]), (200, &b"ok\n"[..]));
    let (st, _, body) = http_request(srv.addr, "GET", "/readyz", "");
    assert_eq!((st, &body[..]), (200, &b"ready\n"[..]));
    let (st, _, _) = http_request(srv.addr, "GET", "/nope", "");
    assert_eq!(st, 404);
    let (st, _, body) = http_request(srv.addr, "POST", "/generate", "{\"n_new\":1}");
    assert_eq!(st, 400, "missing prompt must 400");
    assert!(String::from_utf8_lossy(&body).contains("prompt"));

    let events = sse_collect(srv.addr, &SHORT_PROMPT, 6);
    assert_eq!(sse_tokens(&events), want, "streamed tokens match the oracle");
    let (ev, data) = events.last().expect("terminal event");
    assert_eq!(ev, "done");
    let done_tokens: Vec<usize> = match data.at("tokens") {
        Some(Json::Arr(a)) => a.iter().map(|v| v.as_usize().unwrap()).collect(),
        other => panic!("done.tokens missing: {other:?}"),
    };
    assert_eq!(done_tokens, want, "terminal event carries the complete stream");

    let j = stats(srv.addr);
    assert_eq!(stat_usize(&j, "requests.completed"), 1);
    assert_eq!(stat_usize(&j, "requests.failed"), 0, "a 400 never reaches the coordinator");
    assert_eq!(stat_usize(&j, "kv.bytes_current"), 0, "retired stream holds no KV");
    assert_eq!(stat_usize(&j, "inflight"), 0);
    assert_eq!(j.at("draining").and_then(Json::as_bool), Some(false));

    let (migrated, snap) = srv.drain_and_join();
    assert_eq!(migrated, 0);
    assert_eq!(snap.requests_completed, 1);
    assert_no_leak(&snap);
}

/// Scenario 1: a client that vanishes mid-stream cancels its request at
/// the next round boundary; the concurrent bystander is bit-identical
/// and every submit gets exactly one terminal outcome.
#[test]
fn mid_stream_disconnect_cancels_and_frees_kv() {
    let bystander_want = oracle(WEIGHT_SEED, &SHORT_PROMPT, 4);
    let srv = TestServer::start(WEIGHT_SEED, 3, |_| {});

    // Doomed client: submit a long generation, read the first token
    // frame, then drop the socket without reading further.
    let mut doomed = TcpStream::connect(srv.addr).unwrap();
    let body = generate_body(&LONG_PROMPT, 2000);
    doomed
        .write_all(
            format!(
                "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
    let mut got = Vec::new();
    let mut chunk = [0u8; 256];
    wait_until("first token frame", || {
        let n = doomed.read(&mut chunk).unwrap_or(0);
        got.extend_from_slice(&chunk[..n]);
        String::from_utf8_lossy(&got).contains("event: token")
    });
    drop(doomed);

    // Bystander runs while the cancel percolates.
    let events = sse_collect(srv.addr, &SHORT_PROMPT, 4);
    assert_eq!(sse_tokens(&events), bystander_want, "bystander must be bit-identical");
    assert_eq!(events.last().unwrap().0, "done");

    wait_until("disconnect maps to cancel", || {
        stat_usize(&stats(srv.addr), "requests.cancelled") == 1
    });
    wait_until("cancelled KV is freed", || {
        stat_usize(&stats(srv.addr), "kv.bytes_current") == 0
    });

    let (migrated, snap) = srv.drain_and_join();
    assert_eq!(migrated, 0, "the cancelled sequence must not reach the drain bundle");
    assert_eq!(snap.requests_cancelled, 1);
    assert_eq!(snap.requests_completed, 1);
    assert_eq!(snap.requests_failed, 0, "a vanished client is not a failure");
    assert_no_leak(&snap);
}

/// Scenario 2: an injected `http.write` short write (Nth data frame)
/// cancels exactly that request; the server keeps serving bit-identical
/// streams afterwards.
#[test]
fn injected_short_write_cancels_only_that_request() {
    let want = oracle(WEIGHT_SEED, &LONG_PROMPT, 50);
    let faults = FaultInjector::seeded(0x5EED);
    faults.arm("http.write", FaultMode::Nth(3));
    let f = faults.clone();
    let srv = TestServer::start(WEIGHT_SEED, 3, move |c| c.faults = f);

    // The faulted request runs alone so the Nth counting is per-request
    // deterministic: frames 1 and 2 arrive whole, frame 3 is truncated,
    // then the connection dies.
    let (status, head, body) =
        http_request(srv.addr, "POST", "/generate", &generate_body(&LONG_PROMPT, 50));
    assert_eq!(status, 200, "{head}");
    let events = parse_sse(std::str::from_utf8(&body).unwrap_or(""));
    let toks = sse_tokens(&events);
    assert_eq!(toks, want[..2], "exactly the two pre-fault frames arrive intact");
    assert!(
        !events.iter().any(|(e, _)| e != "token"),
        "no terminal event reaches the client after a short write"
    );
    assert_eq!(faults.trips("http.write"), 1, "the fault fired exactly once");

    wait_until("short write maps to cancel", || {
        stat_usize(&stats(srv.addr), "requests.cancelled") == 1
    });

    // The plane is healthy: a follow-up stream is bit-identical.
    let after = sse_collect(srv.addr, &SHORT_PROMPT, 4);
    assert_eq!(sse_tokens(&after), oracle(WEIGHT_SEED, &SHORT_PROMPT, 4));

    let (migrated, snap) = srv.drain_and_join();
    assert_eq!(migrated, 0);
    assert_eq!(snap.requests_cancelled, 1);
    assert_eq!(snap.requests_completed, 1);
    assert_no_leak(&snap);
}

/// Scenario 3: overload shedding — while one stream occupies the only
/// admission slot, burst traffic gets `429` + `Retry-After` and is
/// counted shed; the admitted stream completes bit-identically.
#[test]
fn burst_beyond_max_queued_sheds_with_429_and_retry_after() {
    let want = oracle(WEIGHT_SEED, &LONG_PROMPT, 200);
    let srv = TestServer::start(WEIGHT_SEED, 5, |c| c.max_queued = 1);
    let addr = srv.addr;

    let streamer = std::thread::spawn(move || sse_collect(addr, &LONG_PROMPT, 200));
    wait_until("streamer occupies the admission slot", || {
        stat_usize(&stats(addr), "inflight") == 1
    });

    for i in 0..5 {
        let (status, head, _) =
            http_request(addr, "POST", "/generate", &generate_body(&SHORT_PROMPT, 2));
        assert_eq!(status, 429, "burst request {i} must shed");
        assert!(
            head.to_ascii_lowercase().contains("retry-after"),
            "shed response advertises Retry-After: {head}"
        );
    }

    let events = streamer.join().unwrap();
    assert_eq!(sse_tokens(&events), want, "the admitted stream is unaffected by the burst");
    assert_eq!(events.last().unwrap().0, "done");

    let (migrated, snap) = srv.drain_and_join();
    assert_eq!(migrated, 0);
    assert_eq!(snap.requests_shed, 5, "every burst request counted shed");
    assert_eq!(snap.requests_completed, 1);
    assert_no_leak(&snap);
}

/// Scenario 4: graceful drain mid-stream — the SSE stream ends with a
/// `migrated` terminal, the bundle lands on disk, and a fresh
/// coordinator resumes it bit-identically.
#[test]
fn drain_mid_stream_migrates_and_restores_bit_identically() {
    let want = oracle(WEIGHT_SEED, &LONG_PROMPT, 60);
    let path = tmp("drain-restore");
    let _ = std::fs::remove_file(&path);
    let p = path.clone();
    let srv = TestServer::start(WEIGHT_SEED, 3, move |c| c.drain_file = Some(p));
    let addr = srv.addr;

    let streamer = std::thread::spawn(move || {
        let (status, _, body) =
            http_request(addr, "POST", "/generate", &generate_body(&LONG_PROMPT, 60));
        assert_eq!(status, 200);
        parse_sse(std::str::from_utf8(&body).unwrap())
    });
    wait_until("stream is hot", || stat_usize(&stats(addr), "kv.bytes_current") > 0);

    let (migrated, snap) = srv.drain_and_join();
    assert_eq!(migrated, 1, "the in-flight stream must migrate");
    assert_eq!(snap.requests_drained, 1);
    assert_eq!(snap.requests_completed, 0);
    assert_no_leak(&snap);

    let events = streamer.join().unwrap();
    let streamed = sse_tokens(&events);
    assert!(!streamed.is_empty() && streamed.len() < 60, "cut mid-stream");
    assert_eq!(streamed[..], want[..streamed.len()], "streamed prefix matches the oracle");
    let (ev, data) = events.last().unwrap();
    assert_eq!(ev, "migrated", "drain maps onto the migrated terminal event");
    assert_eq!(data.at("streamed").and_then(Json::as_usize), Some(streamed.len()));

    // Readiness flipped during the drain; the listener is gone after.
    let bundle = DrainBundle::load(&path).expect("bundle on disk");
    let _ = std::fs::remove_file(&path);
    assert_eq!(bundle.seqs.len(), 1);
    assert_eq!(bundle.seqs[0].generated, streamed, "bundle carries exactly the delivered prefix");

    let coord2 = Coordinator::start(throttled_setup(WEIGHT_SEED, None), CoordinatorConfig::default());
    let results = cskv::coordinator::resume_bundle(&coord2, bundle);
    assert_eq!(results.len(), 1);
    let (_, tokens, error) = &results[0];
    assert!(error.is_none(), "{error:?}");
    assert_eq!(*tokens, want, "cross-coordinator resume is bit-identical");
    let snap2 = coord2.shutdown();
    assert_eq!(snap2.requests_completed, 1);
    assert_no_leak(&snap2);
}

/// Scenario 5: an injected `http.accept` fault drops exactly one
/// connection at the door; the next connection is served normally.
#[test]
fn injected_accept_fault_drops_one_connection_only() {
    let faults = FaultInjector::seeded(0xACC);
    faults.arm("http.accept", FaultMode::Nth(1));
    let f = faults.clone();
    let srv = TestServer::start(WEIGHT_SEED, 0, move |c| c.faults = f);

    // First connection: accepted then dropped before any response.
    let mut s = TcpStream::connect(srv.addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let _ = s.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf); // EOF or reset — never a response
    assert!(buf.is_empty(), "faulted accept must not answer: {:?}", String::from_utf8_lossy(&buf));
    assert_eq!(faults.trips("http.accept"), 1);

    // Second connection: business as usual.
    let (st, _, body) = http_request(srv.addr, "GET", "/healthz", "");
    assert_eq!((st, &body[..]), (200, &b"ok\n"[..]));

    let (_, snap) = srv.drain_and_join();
    assert_no_leak(&snap);
}

// ---------------------------------------------------------------------------
// Binary end-to-end: the real `cskv serve` process over real sockets.
// ---------------------------------------------------------------------------

struct ServeProc {
    child: std::process::Child,
    stdout: BufReader<std::process::ChildStdout>,
    addr: SocketAddr,
}

impl ServeProc {
    fn spawn(extra: &[&str]) -> ServeProc {
        let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_cskv"));
        cmd.args([
            "serve",
            "--listen",
            "127.0.0.1:0",
            "--seed-weights",
            "5",
            "--decode-throttle-ms",
            "2",
            "--drain-grace",
            "0",
        ])
        .args(extra)
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::inherit());
        let mut child = cmd.spawn().expect("spawn cskv serve");
        let mut stdout = BufReader::new(child.stdout.take().unwrap());
        let mut line = String::new();
        let addr = loop {
            line.clear();
            assert!(
                stdout.read_line(&mut line).expect("read child stdout") > 0,
                "child exited before printing its address"
            );
            if let Some(rest) = line.trim().strip_prefix("listening on ") {
                break rest.parse().expect("child printed a valid address");
            }
        };
        ServeProc { child, stdout, addr }
    }

    fn wait_exit_ok(mut self) {
        let t0 = Instant::now();
        loop {
            if let Some(status) = self.child.try_wait().expect("wait child") {
                assert!(status.success(), "serve process must exit cleanly: {status}");
                return;
            }
            assert!(t0.elapsed().as_secs() < 30, "serve process did not exit after drain");
            std::thread::sleep(Duration::from_millis(20));
        }
    }
}

/// The CI smoke: a real server process serves a bit-identical stream,
/// survives a mid-stream disconnect, drains to a bundle file, exits 0 —
/// and a second process resumes the migrated sequence bit-identically.
#[test]
fn serve_binary_streams_survives_disconnect_and_migrates_across_processes() {
    let stream_want = oracle(5, &SHORT_PROMPT, 4);
    let migrate_prompt = [2usize, 4, 6];
    let migrate_want = oracle(5, &migrate_prompt, 100);
    let bundle = tmp("bin-bundle");
    let _ = std::fs::remove_file(&bundle);

    let a = ServeProc::spawn(&["--drain-file", bundle.to_str().unwrap(), "--max-queued", "8"]);

    // 1. One complete streaming request, bit-identical to the oracle.
    let events = sse_collect(a.addr, &SHORT_PROMPT, 4);
    assert_eq!(sse_tokens(&events), stream_want);
    assert_eq!(events.last().unwrap().0, "done");

    // 2. Mid-stream disconnect: read one token frame, drop the socket,
    //    and wait for the cancel to register server-side.
    let body = generate_body(&LONG_PROMPT, 100);
    let mut doomed = TcpStream::connect(a.addr).unwrap();
    doomed
        .write_all(
            format!(
                "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
    let mut got = Vec::new();
    let mut chunk = [0u8; 256];
    wait_until("binary: first token frame", || {
        let n = doomed.read(&mut chunk).unwrap_or(0);
        got.extend_from_slice(&chunk[..n]);
        String::from_utf8_lossy(&got).contains("event: token")
    });
    drop(doomed);
    wait_until("binary: disconnect cancels", || {
        stat_usize(&stats(a.addr), "requests.cancelled") == 1
    });

    // 3. Drain mid-stream: a third request is cut loose into the bundle.
    let addr = a.addr;
    let streamer = std::thread::spawn(move || {
        let (status, _, body) =
            http_request(addr, "POST", "/generate", &generate_body(&migrate_prompt, 100));
        assert_eq!(status, 200);
        parse_sse(std::str::from_utf8(&body).unwrap())
    });
    wait_until("binary: migration stream hot", || {
        stat_usize(&stats(addr), "kv.bytes_current") > 0
    });
    let (st, _, dbody) = http_request(addr, "POST", "/drain", "");
    assert_eq!(st, 200, "{}", String::from_utf8_lossy(&dbody));
    let dj = Json::parse(std::str::from_utf8(&dbody).unwrap()).unwrap();
    assert_eq!(dj.at("migrated").and_then(Json::as_usize), Some(1));
    let events = streamer.join().unwrap();
    let streamed = sse_tokens(&events);
    assert_eq!(events.last().unwrap().0, "migrated");
    assert!(!streamed.is_empty() && streamed.len() < 100);
    assert_eq!(streamed[..], migrate_want[..streamed.len()]);
    a.wait_exit_ok();

    // 4. Process B resumes the bundle and reports the full stream —
    //    bit-identical across processes.
    let mut b = ServeProc::spawn(&["--resume-from", bundle.to_str().unwrap()]);
    let mut resumed = String::new();
    wait_until("binary: resumed line", || {
        resumed.clear();
        b.stdout.read_line(&mut resumed).expect("read B stdout") > 0
            && resumed.trim().starts_with("resumed id=")
    });
    let toks_json = resumed.trim().split_once("tokens=").expect("tokens field").1;
    let resumed_tokens: Vec<usize> = match Json::parse(toks_json).expect("tokens JSON") {
        Json::Arr(a) => a.iter().map(|v| v.as_usize().unwrap()).collect(),
        other => panic!("unexpected tokens payload: {other:?}"),
    };
    assert_eq!(
        resumed_tokens, migrate_want,
        "the resumed process must reproduce the oracle stream bit-identically"
    );
    let (st, _, _) = http_request(b.addr, "POST", "/drain", "");
    assert_eq!(st, 200);
    b.wait_exit_ok();
    let _ = std::fs::remove_file(&bundle);
}

/// Flag validation: bad serve/HTTP flags exit non-zero with a pointed
/// message before any model work starts.
#[test]
fn serve_flag_validation_rejects_bad_http_flags() {
    let cases: &[(&[&str], &str)] = &[
        (&["serve", "--listen", "not-an-addr"], "invalid --listen"),
        (&["serve", "--listen", "127.0.0.1"], "invalid --listen"),
        (&["serve", "--listen", "127.0.0.1:0", "--max-queued", "0"], "--max-queued"),
        (
            &["serve", "--listen", "127.0.0.1:0", "--client-stall-timeout", "0"],
            "--client-stall-timeout",
        ),
        (
            &["serve", "--listen", "127.0.0.1:0", "--client-stall-timeout", "nan"],
            "--client-stall-timeout",
        ),
        (&["serve", "--listen", "127.0.0.1:0", "--drain-grace", "-1"], "--drain-grace"),
        (&["serve", "--listen", "127.0.0.1:0", "--seed-weights", "x"], "--seed-weights"),
        (
            &["serve", "--listen", "127.0.0.1:0", "--decode-throttle-ms", "fast"],
            "--decode-throttle-ms",
        ),
    ];
    for (args, want) in cases {
        let out = std::process::Command::new(env!("CARGO_BIN_EXE_cskv"))
            .args(*args)
            .output()
            .expect("run cskv");
        assert!(!out.status.success(), "{args:?} must be rejected");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains(want), "{args:?}: missing {want:?} in {err}");
    }
}
