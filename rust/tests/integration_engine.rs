//! Integration: the reference engine against every cache policy — the
//! mechanisms behind Table 1's qualitative ordering, checked on random
//! weights (trained-model accuracy lives in the benches).

use std::sync::Arc;

use cskv::baselines::{AsvdCache, H2oCache, StreamingLlmCache};
use cskv::compress::svd_init::{init_factors, InitMethod};
use cskv::compress::{LayerFactors, ModelFactors};
use cskv::data::tasks;
use cskv::eval::harness::replay_generate;
use cskv::eval::{EvalSet, Suite};
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, QuantMode};
use cskv::model::{engine::Engine, ModelConfig, ModelWeights};
use cskv::util::prng::Pcg64;

fn engine() -> Engine {
    Engine::new(Arc::new(ModelWeights::init(&ModelConfig::test_small(), 11)))
}

fn full_rank_factors(w: &ModelWeights) -> Arc<ModelFactors> {
    // Full-rank SVD factors: mathematically exact compression.
    let layers = w
        .layers
        .iter()
        .map(|lw| LayerFactors {
            k: init_factors(&lw.wk, lw.wk.cols, InitMethod::Svd, None, 0),
            v: init_factors(&lw.wv, lw.wv.cols, InitMethod::Svd, None, 0),
        })
        .collect();
    Arc::new(ModelFactors {
        layers,
        provenance: "fullrank".into(),
    })
}

/// With full-rank factors the bi-branch cache is exact ⇒ generation must
/// match the full cache token-for-token, for any window size.
#[test]
fn cskv_fullrank_equals_full_cache() {
    let e = engine();
    let cfg = e.w.cfg.clone();
    let f = full_rank_factors(&e.w);
    let mut rng = Pcg64::new(1);
    for window in [0usize, 2, 8, 64] {
        let s = tasks::line_retrieval(6, &mut rng);
        let mut full = FullCache::new(cfg.n_layers, cfg.d_model);
        let (want, _) = e.generate(&s.prompt, 5, &mut full);
        let mut cskv = CskvCache::new(
            Arc::clone(&f),
            cfg.d_model,
            CskvConfig {
                window,
                quant: QuantMode::None,
            },
        );
        let (got, _) = e.generate(&s.prompt, 5, &mut cskv);
        assert_eq!(got, want, "window={window}");
    }
}

/// StreamingLLM with a budget >= sequence length is exact too (nothing is
/// ever evicted, cache-relative == absolute positions).
#[test]
fn streamingllm_unevicted_equals_full_cache() {
    let e = engine();
    let cfg = e.w.cfg.clone();
    let mut rng = Pcg64::new(2);
    let s = tasks::line_retrieval(5, &mut rng);
    let mut full = FullCache::new(cfg.n_layers, cfg.d_model);
    let (want, _) = e.generate(&s.prompt, 4, &mut full);
    let mut sl = StreamingLlmCache::new(cfg.n_layers, cfg.d_model, 4, s.prompt.len() + 10);
    let (got, _) = e.generate(&s.prompt, 4, &mut sl);
    assert_eq!(got, want);
}

/// H2O with budget >= sequence length is exact as well.
#[test]
fn h2o_unevicted_equals_full_cache() {
    let e = engine();
    let cfg = e.w.cfg.clone();
    let mut rng = Pcg64::new(3);
    let s = tasks::line_retrieval(5, &mut rng);
    let mut full = FullCache::new(cfg.n_layers, cfg.d_model);
    let (want, _) = e.generate(&s.prompt, 4, &mut full);
    let mut h2o = H2oCache::new(cfg.n_layers, cfg.d_model, s.prompt.len() + 10);
    let (got, _) = e.generate(&s.prompt, 4, &mut h2o);
    assert_eq!(got, want);
}

/// Memory ordering at the same nominal ratio: cskv-int4 < pruned(20%) ≈
/// cskv(20%) < full. (The exact Table-style bytes are in bench_memory.)
#[test]
fn memory_footprints_are_ordered() {
    let e = engine();
    let cfg = e.w.cfg.clone();
    let mut rng = Pcg64::new(4);
    let prompt: Vec<usize> = (0..100).map(|_| rng.range(10, 250)).collect();
    let f = {
        let layers = e
            .w
            .layers
            .iter()
            .map(|lw| LayerFactors {
                k: init_factors(&lw.wk, 6, InitMethod::Svd, None, 0), // ~80%
                v: init_factors(&lw.wv, 6, InitMethod::Svd, None, 0),
            })
            .collect();
        Arc::new(ModelFactors {
            layers,
            provenance: "r6".into(),
        })
    };
    let run = |mut p: Box<dyn KvCachePolicy>| {
        let _ = e.generate(&prompt, 3, p.as_mut());
        p.kv_bytes()
    };
    let full = run(Box::new(FullCache::new(cfg.n_layers, cfg.d_model)));
    let budget = (prompt.len() + 3) / 5; // ~80% pruned
    let pruned = run(Box::new(StreamingLlmCache::new(
        cfg.n_layers,
        cfg.d_model,
        2,
        budget.max(3),
    )));
    let cskv = run(Box::new(CskvCache::new(
        Arc::clone(&f),
        cfg.d_model,
        CskvConfig {
            window: 4,
            quant: QuantMode::None,
        },
    )));
    let cskv_q = run(Box::new(CskvCache::new(
        f,
        cfg.d_model,
        CskvConfig {
            window: 4,
            quant: QuantMode::Int4,
        },
    )));
    assert!(cskv < full / 3, "cskv {cskv} vs full {full}");
    assert!(pruned < full / 3, "pruned {pruned} vs full {full}");
    assert!(cskv_q < cskv, "int4 {cskv_q} vs fp32 {cskv}");
}

/// The eviction baselines *lose* the queried line when it falls outside
/// their kept set, while CSKV (which keeps every token, compressed)
/// retains at least the positional coverage — structural check on the
/// materialized views.
#[test]
fn eviction_drops_query_line_coverage() {
    let e = engine();
    let cfg = e.w.cfg.clone();
    let mut rng = Pcg64::new(5);
    let s = tasks::line_retrieval(8, &mut rng); // 8 lines × 8 tokens ≈ 68 ctx
    let budget = s.prompt.len() / 5;

    let mut sl = StreamingLlmCache::new(cfg.n_layers, cfg.d_model, 2, budget);
    let _ = e.generate(&s.prompt, 2, &mut sl);
    let view = sl.materialize(0);
    // Early-middle positions are gone.
    assert!(!view.abs_pos.contains(&(s.prompt.len() / 2)));

    let f = full_rank_factors(&e.w);
    let mut ck = CskvCache::new(f, cfg.d_model, CskvConfig::default());
    let _ = e.generate(&s.prompt, 2, &mut ck);
    let view = ck.materialize(0);
    // CSKV covers every absolute position.
    assert_eq!(view.abs_pos.len(), s.prompt.len() + 1);
}

/// Replay-based evaluation must agree with direct generation for every
/// replay-safe policy (the harness optimization is not allowed to change
/// results).
#[test]
fn harness_replay_consistency_across_policies() {
    let e = engine();
    let cfg = e.w.cfg.clone();
    let suite = Suite::LongBench { ctx: 80, n_facts: 4 };
    let samples = suite.sample_set(3, 9);
    let set = EvalSet::build(&e, samples.clone());
    let f = full_rank_factors(&e.w);

    type Factory = Box<dyn Fn() -> Box<dyn KvCachePolicy>>;
    let factories: Vec<Factory> = vec![
        Box::new({
            let c = cfg.clone();
            move || Box::new(FullCache::new(c.n_layers, c.d_model))
        }),
        Box::new({
            let c = cfg.clone();
            move || Box::new(StreamingLlmCache::new(c.n_layers, c.d_model, 4, 30))
        }),
        Box::new({
            let c = cfg.clone();
            move || Box::new(H2oCache::new(c.n_layers, c.d_model, 30))
        }),
        Box::new({
            let c = cfg.clone();
            let f = Arc::clone(&f);
            move || Box::new(CskvCache::new(Arc::clone(&f), c.d_model, CskvConfig::default()))
        }),
    ];
    for factory in factories {
        for s in &samples {
            let mut p_direct = factory();
            let (direct, _) = e.generate(&s.prompt, 3, p_direct.as_mut());
            let rec = e.prefill(&s.prompt, None);
            let mut p_replay = factory();
            let replay = replay_generate(&e, &rec, s.prompt.len(), 3, p_replay.as_mut());
            assert_eq!(direct, replay, "policy {}", p_direct.name());
        }
    }
    // And the EvalSet wrapper runs end-to-end.
    let mut factory = {
        let c = cfg.clone();
        move || -> Box<dyn KvCachePolicy> { Box::new(FullCache::new(c.n_layers, c.d_model)) }
    };
    let r = set.eval(&e, &mut factory);
    assert_eq!(r.n_samples, 3);
}

/// ASVD goes through the lossy-prefill path and still produces sane output.
#[test]
fn asvd_lossy_prefill_path() {
    let e = engine();
    let mut rng = Pcg64::new(10);
    let s = tasks::line_retrieval(5, &mut rng);
    let f = full_rank_factors(&e.w);
    let mut asvd = AsvdCache::new(Arc::clone(&f));
    assert!(asvd.lossy_prefill());
    let (toks, _) = e.generate(&s.prompt, 4, &mut asvd);
    assert_eq!(toks.len(), 4);
    // Full-rank ASVD == exact, so it must match the full cache.
    let cfg = e.w.cfg.clone();
    let mut full = FullCache::new(cfg.n_layers, cfg.d_model);
    let (want, _) = e.generate(&s.prompt, 4, &mut full);
    assert_eq!(toks, want);
}
