//! Integration: the full compression pipeline (calibrate → init →
//! fine-tune → bi-branch inference) on a randomly-initialized model, plus
//! end-to-end behaviour checks that mirror the paper's mechanisms without
//! needing the trained checkpoint.

use std::sync::Arc;

use cskv::compress::quant::QuantAxis;
use cskv::compress::svd_init::{init_factors, InitMethod};
use cskv::compress::{KvCompressionPlan, LayerFactors, ModelFactors};
use cskv::data::corpus::{calibration_docs, CorpusConfig};
use cskv::finetune::recon::{recon_loss, QatMode};
use cskv::finetune::{build_factors, FinetuneConfig};
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, QuantMode};
use cskv::model::{engine::Engine, ModelConfig, ModelWeights};
use cskv::util::prng::Pcg64;

fn small_engine(seed: u64) -> Engine {
    Engine::new(Arc::new(ModelWeights::init(&ModelConfig::test_small(), seed)))
}

fn calib_for(engine: &Engine) -> Vec<cskv::tensor::Mat> {
    let corpus = CorpusConfig {
        seq_len: 96,
        ..Default::default()
    };
    let docs = calibration_docs(&corpus, 6, 11);
    engine.collect_calibration(&docs, 512, 3)
}

#[test]
fn pipeline_produces_usable_factors() {
    let engine = small_engine(1);
    let calib = calib_for(&engine);
    let plan = KvCompressionPlan::uniform(0.5);
    let rep = build_factors(
        &engine.w,
        &calib,
        plan,
        &FinetuneConfig {
            steps: 80,
            ..Default::default()
        },
    );
    // Factors reconstruct K reasonably at 50% on real activations.
    for (li, lw) in engine.w.layers.iter().enumerate() {
        let rel = rep.factors.layers[li].k.relative_error(&calib[li], &lw.wk);
        assert!(rel < 0.35, "layer {li} rel err {rel}");
    }
    // And plug into generation without changing output shape/length.
    let mut policy = CskvCache::new(
        Arc::new(rep.factors),
        engine.w.cfg.d_model,
        CskvConfig::default(),
    );
    let prompt: Vec<usize> = (1..40).map(|i| (i * 7) % 250).collect();
    let (toks, stats) = engine.generate(&prompt, 5, &mut policy);
    assert_eq!(toks.len(), 5);
    assert!(stats.kv_bytes_final > 0);
}

#[test]
fn finetuning_beats_pure_init_on_real_activations() {
    // §2.2's claim: reconstruction training improves on the (A)SVD init.
    let engine = small_engine(2);
    let calib = calib_for(&engine);
    let plan = KvCompressionPlan::uniform(0.8);
    let no_ft = build_factors(
        &engine.w,
        &calib,
        plan,
        &FinetuneConfig {
            steps: 0,
            ..Default::default()
        },
    );
    let ft = build_factors(
        &engine.w,
        &calib,
        plan,
        &FinetuneConfig {
            steps: 200,
            ..Default::default()
        },
    );
    assert!(
        ft.final_total_loss < no_ft.final_total_loss,
        "ft {} !< init {}",
        ft.final_total_loss,
        no_ft.final_total_loss
    );
}

#[test]
fn bibranch_preserves_generation_better_than_asvd_at_high_ratio() {
    // Mechanism check (Table 1's shape): at a high compression ratio, the
    // bi-branch cache (exact prefill + window) must disturb generation
    // less than whole-projection ASVD replacement, measured by agreement
    // with the uncompressed generation.
    let engine = small_engine(3);
    let cfg = engine.w.cfg.clone();
    let calib = calib_for(&engine);
    let plan = KvCompressionPlan::uniform(0.8);
    let rep = build_factors(
        &engine.w,
        &calib,
        plan,
        &FinetuneConfig {
            steps: 150,
            ..Default::default()
        },
    );
    let factors = Arc::new(rep.factors);

    let mut rng = Pcg64::new(4);
    let mut agree_cskv = 0usize;
    let mut agree_asvd = 0usize;
    let mut total = 0usize;
    for _ in 0..8 {
        let prompt: Vec<usize> = (0..64).map(|_| rng.range(10, 250)).collect();
        let mut full = FullCache::new(cfg.n_layers, cfg.d_model);
        let (want, _) = engine.generate(&prompt, 6, &mut full);
        let mut cskv = CskvCache::new(Arc::clone(&factors), cfg.d_model, CskvConfig::default());
        let (got_cskv, _) = engine.generate(&prompt, 6, &mut cskv);
        let mut asvd = cskv::baselines::AsvdCache::new(Arc::clone(&factors));
        let (got_asvd, _) = engine.generate(&prompt, 6, &mut asvd);
        for i in 0..want.len() {
            total += 1;
            if got_cskv[i] == want[i] {
                agree_cskv += 1;
            }
            if got_asvd[i] == want[i] {
                agree_asvd += 1;
            }
        }
    }
    assert!(
        agree_cskv >= agree_asvd,
        "cskv agreement {agree_cskv}/{total} should be ≥ asvd {agree_asvd}/{total}"
    );
    assert!(agree_cskv as f64 / total as f64 > 0.5, "{agree_cskv}/{total}");
}

#[test]
fn qat_factors_survive_quantized_inference_better_than_ptq() {
    // Table 5's mechanism: evaluate both factor sets under *quantized*
    // reconstruction loss.
    // High compression ratio: the compressed features are dense and the
    // int4 error matters (at 50% the effect is within noise — the paper's
    // Table 5 shows the same trend strengthening with ratio).
    let engine = small_engine(5);
    let calib = calib_for(&engine);
    let plan = KvCompressionPlan::uniform(0.8);
    let mk = |qat| {
        build_factors(
            &engine.w,
            &calib,
            plan,
            &FinetuneConfig {
                steps: 200,
                qat,
                ..Default::default()
            },
        )
    };
    let ptq = mk(QatMode::Off);
    let qat = mk(QatMode::Int4);
    let qloss = |rep: &cskv::finetune::FinetuneReport| -> f32 {
        engine
            .w
            .layers
            .iter()
            .enumerate()
            .map(|(li, lw)| {
                recon_loss(
                    &calib[li],
                    &lw.wk,
                    &rep.factors.layers[li].k,
                    Some(QuantAxis::PerChannel),
                ) + recon_loss(
                    &calib[li],
                    &lw.wv,
                    &rep.factors.layers[li].v,
                    Some(QuantAxis::PerToken),
                )
            })
            .sum()
    };
    let (lp, lq) = (qloss(&ptq), qloss(&qat));
    assert!(lq <= lp * 1.10, "qat {lq} should not lose to ptq {lp}");
}

#[test]
fn factor_files_roundtrip_through_policies() {
    let engine = small_engine(6);
    let d = engine.w.cfg.d_model;
    let layers: Vec<LayerFactors> = engine
        .w
        .layers
        .iter()
        .map(|lw| LayerFactors {
            k: init_factors(&lw.wk, 8, InitMethod::Svd, None, 0),
            v: init_factors(&lw.wv, 8, InitMethod::Svd, None, 0),
        })
        .collect();
    let f = ModelFactors {
        layers,
        provenance: "roundtrip".into(),
    };
    let path = std::env::temp_dir().join("cskv_it_factors.bin");
    f.save(&path).unwrap();
    let loaded = Arc::new(ModelFactors::load(&path).unwrap());
    let mut a = CskvCache::new(loaded.clone(), d, CskvConfig::default());
    let mut b = CskvCache::new(Arc::new(f), d, CskvConfig::default());
    let prompt: Vec<usize> = (1..30).collect();
    let (ta, _) = engine.generate(&prompt, 4, &mut a);
    let (tb, _) = engine.generate(&prompt, 4, &mut b);
    assert_eq!(ta, tb, "saved+loaded factors must behave identically");
}

#[test]
fn quantized_bibranch_reduces_memory_8x_on_history() {
    let engine = small_engine(7);
    let cfg = engine.w.cfg.clone();
    let calib = calib_for(&engine);
    let rep = build_factors(
        &engine.w,
        &calib,
        KvCompressionPlan::uniform(0.5),
        &FinetuneConfig {
            steps: 0,
            ..Default::default()
        },
    );
    let f = Arc::new(rep.factors);
    let prompt: Vec<usize> = (0..96).map(|i| (i * 3) % 200 + 10).collect();
    let run = |quant| {
        let mut p = CskvCache::new(
            Arc::clone(&f),
            cfg.d_model,
            CskvConfig { window: 4, quant },
        );
        let _ = engine.generate(&prompt, 3, &mut p);
        p.kv_bytes()
    };
    let fp32 = run(QuantMode::None);
    let int4 = run(QuantMode::Int4);
    let ratio = fp32 as f64 / int4 as f64;
    assert!(
        ratio > 3.0,
        "int4 history should be much smaller: fp32={fp32} int4={int4} (ratio {ratio:.2})"
    );
}
