//! The batched serving data plane's correctness oracles.
//!
//! 1. Engine level: fused multi-sequence prefill ([`Engine::prefill_batch`])
//!    and GEMM-batched decode rounds ([`Engine::decode_step_batch`]) must
//!    be **bit-identical** to independent per-sequence calls — logits,
//!    prefill records and policy state — across every cache policy, batch
//!    widths {1, 2, 8} and thread counts {1, 8}.
//! 2. Scheduler level: the fused coordinator, the sequential (A/B
//!    baseline) coordinator, and a direct `Engine::generate` must produce
//!    identical token streams for every request, at mixed prompt lengths
//!    and batch widths.
//! 3. Liveness: a short request admitted mid-flight finishes before a
//!    long earlier one drains (continuous batching), and no request can
//!    ever hang its caller — every submission is answered, success or
//!    error (failure injection).

use std::sync::Arc;

use cskv::baselines::{AsvdCache, H2oCache, StreamingLlmCache};
use cskv::compress::{LayerFactors, LowRankFactors, ModelFactors};
use cskv::coordinator::server::{BackendFactory, Setup};
use cskv::coordinator::{Coordinator, CoordinatorConfig, RustSequenceBackend, SchedulerKind};
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, QuantMode};
use cskv::model::engine::{
    BatchDecodeEntry, BatchDecodeScratch, BatchPrefillScratch, DecodeState, Engine,
};
use cskv::model::{ModelConfig, ModelWeights};
use cskv::tensor::ops;
use cskv::tensor::Mat;
use cskv::util::prng::Pcg64;

/// Low-rank factors matching the `test_small` engine geometry.
fn engine_factors(rank: usize) -> Arc<ModelFactors> {
    let cfg = ModelConfig::test_small();
    let d = cfg.d_model;
    let mut rng = Pcg64::new(rank as u64 * 77 + 5);
    let mut mk = move || {
        LowRankFactors::new(
            Mat::randn(d, rank, 0.2, &mut rng),
            Mat::randn(rank, d, 0.2, &mut rng),
        )
    };
    Arc::new(ModelFactors {
        layers: (0..cfg.n_layers).map(|_| LayerFactors { k: mk(), v: mk() }).collect(),
        provenance: "batched-serving".into(),
    })
}

/// One instance of every cache policy, freshly constructed.
fn mk_policies() -> Vec<Box<dyn KvCachePolicy>> {
    let cfg = ModelConfig::test_small();
    let (l, d) = (cfg.n_layers, cfg.d_model);
    vec![
        Box::new(FullCache::new(l, d)),
        Box::new(CskvCache::new(
            engine_factors(8),
            d,
            CskvConfig { window: 6, quant: QuantMode::None },
        )),
        Box::new(CskvCache::new(
            engine_factors(8),
            d,
            CskvConfig { window: 6, quant: QuantMode::Int4 },
        )),
        Box::new(StreamingLlmCache::new(l, d, 2, 12)),
        Box::new(H2oCache::new(l, d, 10)),
        Box::new(AsvdCache::new(engine_factors(8))),
    ]
}

/// Mixed prompt lengths exercising the attention row tiles (> 32) and the
/// parallel GEMM row blocks (> 64).
fn mk_prompts(width: usize, seed: u64) -> Vec<Vec<usize>> {
    let lens = [70usize, 1, 33, 12, 57, 5, 21, 44];
    let mut rng = Pcg64::new(seed);
    (0..width)
        .map(|i| (0..lens[i % lens.len()]).map(|_| rng.range(16, 250)).collect())
        .collect()
}

/// THE bit-identity oracle for the tentpole: batched prefill + batched
/// decode ≡ per-sequence prefill + decode, for batch widths {1, 2, 8} ×
/// threads {1, 8} × every cache policy.
#[test]
fn batched_rounds_bit_identical_to_per_sequence() {
    let base = ModelConfig::test_small();
    let n_policies = mk_policies().len();
    for threads in [1usize, 8] {
        let cfg = base.clone().with_threads(threads);
        let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 7)));
        for width in [1usize, 2, 8] {
            let prompts = mk_prompts(width, width as u64 * 31 + threads as u64);
            let prompt_refs: Vec<&[usize]> = prompts.iter().map(|p| p.as_slice()).collect();
            for pi in 0..n_policies {
                // Per-sequence oracle: one policy instance per sequence.
                let mut seq_pols: Vec<Box<dyn KvCachePolicy>> =
                    (0..width).map(|_| mk_policies().swap_remove(pi)).collect();
                let mut want_recs = Vec::with_capacity(width);
                for (p, pol) in prompt_refs.iter().zip(seq_pols.iter_mut()) {
                    want_recs.push(engine.prefill(p, Some(pol.as_mut())));
                }

                // Batched prefill.
                let mut bat_pols: Vec<Box<dyn KvCachePolicy>> =
                    (0..width).map(|_| mk_policies().swap_remove(pi)).collect();
                let mut scratch = BatchPrefillScratch::new();
                let recs = {
                    let mut policies: Vec<Option<&mut dyn KvCachePolicy>> =
                        bat_pols.iter_mut().map(|p| Some(p.as_mut())).collect();
                    engine.prefill_batch(&prompt_refs, &mut policies, &mut scratch)
                };
                let name = seq_pols[0].name();
                for si in 0..width {
                    assert_eq!(
                        recs[si].logits.data, want_recs[si].logits.data,
                        "{name}: prefill logits seq {si} width {width} threads {threads}"
                    );
                    for li in 0..cfg.n_layers {
                        assert_eq!(recs[si].attn_mass[li], want_recs[si].attn_mass[li]);
                        let (va, vb) =
                            (seq_pols[si].materialize(li), bat_pols[si].materialize(li));
                        assert_eq!(va.k.data, vb.k.data, "{name}: K state L{li} seq {si}");
                        assert_eq!(va.v.data, vb.v.data, "{name}: V state L{li} seq {si}");
                        assert_eq!(va.abs_pos, vb.abs_pos);
                    }
                }

                // Decode rounds: batched vs per-sequence, 6 steps.
                let mut seq_states: Vec<DecodeState> =
                    (0..width).map(|_| DecodeState::new(&cfg)).collect();
                let mut bat_states: Vec<DecodeState> =
                    (0..width).map(|_| DecodeState::new(&cfg)).collect();
                let mut toks: Vec<usize> = (0..width)
                    .map(|si| ops::argmax(recs[si].logits.row(prompts[si].len() - 1)))
                    .collect();
                let mut pos: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
                let mut dec_scratch = BatchDecodeScratch::new();
                for step in 0..6 {
                    let mut want_logits = Vec::with_capacity(width);
                    for si in 0..width {
                        let l = engine.decode_step_with(
                            seq_pols[si].as_mut(),
                            toks[si],
                            pos[si],
                            &mut seq_states[si],
                        );
                        want_logits.push(l.to_vec());
                    }
                    {
                        let mut entries: Vec<BatchDecodeEntry> = bat_pols
                            .iter_mut()
                            .zip(bat_states.iter_mut())
                            .enumerate()
                            .map(|(si, (pol, st))| BatchDecodeEntry {
                                policy: pol.as_mut(),
                                token: toks[si],
                                abs_pos: pos[si],
                                state: st,
                            })
                            .collect();
                        engine.decode_step_batch(&mut entries, &mut dec_scratch);
                    }
                    for si in 0..width {
                        assert_eq!(
                            dec_scratch.logits_row(si),
                            &want_logits[si][..],
                            "{name}: decode step {step} seq {si} width {width} threads {threads}"
                        );
                        toks[si] = ops::argmax(&want_logits[si]);
                        pos[si] += 1;
                    }
                }
            }
        }
    }
}

fn make_engine(seed: u64) -> Engine {
    Engine::new(Arc::new(ModelWeights::init(&ModelConfig::test_small(), seed)))
}

/// A coordinator Setup serving the `pi`-th cache policy.
fn policy_setup(seed: u64, pi: usize) -> Setup {
    Box::new(move || {
        let engine = make_engine(seed);
        let factory: BackendFactory = Box::new(move || {
            let policy = mk_policies().swap_remove(pi);
            Ok(Box::new(RustSequenceBackend::new(engine.clone(), policy)))
        });
        Ok(factory)
    })
}

/// Scheduler-level equivalence: fused rounds, sequential rounds and the
/// direct engine agree on every request's token stream, for every policy
/// at mixed prompt lengths and batch widths.
#[test]
fn fused_scheduler_matches_sequential_and_direct_engine() {
    let n_policies = mk_policies().len();
    let engine = make_engine(23);
    for pi in 0..n_policies {
        let prompts = mk_prompts(6, 97 + pi as u64);
        // Direct per-sequence oracle.
        let mut want: Vec<Vec<usize>> = Vec::new();
        for p in &prompts {
            let mut pol = mk_policies().swap_remove(pi);
            let (toks, _) = engine.generate(p, 5, pol.as_mut());
            want.push(toks);
        }
        for (max_batch, fused) in [(1usize, true), (2, true), (8, true), (8, false)] {
            let coord = Coordinator::start(
                policy_setup(23, pi),
                CoordinatorConfig { max_batch, fused, ..Default::default() },
            );
            let rxs: Vec<_> = prompts.iter().map(|p| coord.submit(p.clone(), 5)).collect();
            for (ri, rx) in rxs.into_iter().enumerate() {
                let resp = rx.recv().unwrap();
                assert!(resp.error.is_none(), "request {ri} errored: {:?}", resp.error);
                assert_eq!(
                    resp.tokens, want[ri],
                    "policy {pi} req {ri}: scheduler (max_batch={max_batch}, fused={fused}) \
                     must match the direct engine"
                );
            }
            coord.shutdown();
        }
    }
}

fn full_setup(seed: u64) -> Setup {
    Box::new(move || {
        let engine = make_engine(seed);
        let factory: BackendFactory = Box::new(move || {
            let c = engine.w.cfg.clone();
            Ok(Box::new(RustSequenceBackend::new(
                engine.clone(),
                Box::new(FullCache::new(c.n_layers, c.d_model)),
            )))
        });
        Ok(factory)
    })
}

/// Continuous batching: a short request submitted while a long one is
/// mid-flight must be admitted into the running batch and finish first.
#[test]
fn short_request_admitted_mid_flight_overtakes_long_one() {
    let coord = Coordinator::start(
        full_setup(9),
        CoordinatorConfig { max_batch: 4, ..Default::default() },
    );
    let long_rx = coord.submit(vec![1, 2, 3, 4], 1200);
    // Wait until the long request is actually in flight (its KV footprint
    // is visible), then submit the short one mid-generation.
    let t0 = std::time::Instant::now();
    while coord.metrics().kv_bytes_current() == 0 {
        assert!(t0.elapsed().as_secs() < 30, "long request never started");
        std::thread::yield_now();
    }
    let short = coord.submit_wait(vec![5, 6, 7], 2);
    assert!(short.error.is_none());
    assert_eq!(short.tokens.len(), 2);
    // ~1198 decode rounds remain for the long request: it must still be
    // in flight when the short one is answered.
    assert!(
        matches!(long_rx.try_recv(), Err(std::sync::mpsc::TryRecvError::Empty)),
        "short request must overtake the long one"
    );
    let long = long_rx.recv().unwrap();
    assert!(long.error.is_none());
    assert_eq!(long.tokens.len(), 1200);
    let snap = coord.shutdown();
    assert_eq!(snap.requests_completed, 2);
    assert!(snap.active_peak >= 2, "short request must join the running batch");
}

/// A full-cache Setup that blocks inside the worker thread until `gate`
/// fires — so a test can queue a whole workload before the scheduler
/// sees any of it (deterministic admission order, no submit races).
fn gated_setup(seed: u64, gate: std::sync::mpsc::Receiver<()>) -> Setup {
    Box::new(move || {
        let _ = gate.recv();
        let engine = make_engine(seed);
        let factory: BackendFactory = Box::new(move || {
            let c = engine.w.cfg.clone();
            Ok(Box::new(RustSequenceBackend::new(
                engine.clone(),
                Box::new(FullCache::new(c.n_layers, c.d_model)),
            )))
        });
        Ok(factory)
    })
}

/// The scheduler fairness oracle (head-of-line blocking): one 509-token
/// prompt queued ahead of eight 16-token prompts, with a KV budget that
/// hosts either the long prompt or all the shorts — not both.
///
/// * `Fifo` admits the long head first; every short waits behind it and
///   the long request retires **first** (the documented head-of-line
///   block, asserted via the retirement order and queue-wait metrics).
/// * `SizeAware` admits all eight shorts ahead of the long prompt; every
///   short retires before the long one finishes and short queue waits
///   drop below the long one's.
#[test]
fn size_aware_eliminates_head_of_line_blocking_where_fifo_must_not() {
    let cfg = ModelConfig::test_small();
    let mut rng = Pcg64::new(41);
    let long_prompt: Vec<usize> = (0..509).map(|_| rng.range(16, 250)).collect();
    let short_prompts: Vec<Vec<usize>> = (0..8)
        .map(|_| (0..16).map(|_| rng.range(16, 250)).collect())
        .collect();
    let n_new = 4;
    // Long projects to 513 tokens, the eight shorts to 8 × 20 = 160: a
    // 524-token budget fits the long alone (with < 1 short of headroom,
    // so fifo can't sneak a short in beside it) or all eight shorts
    // together — never both sides at once.
    let budget = cfg.kv_bytes_full(524);
    for (kind, long_first) in [(SchedulerKind::Fifo, true), (SchedulerKind::SizeAware, false)] {
        let (gate_tx, gate_rx) = std::sync::mpsc::channel();
        let coord = Coordinator::start(
            gated_setup(23, gate_rx),
            CoordinatorConfig {
                max_batch: 16,
                kv_budget_bytes: Some(budget),
                scheduler: kind,
                ..Default::default()
            },
        );
        let long_rx = coord.submit(long_prompt.clone(), n_new);
        let short_rxs: Vec<_> = short_prompts
            .iter()
            .map(|p| coord.submit(p.clone(), n_new))
            .collect();
        gate_tx.send(()).unwrap(); // release the worker: the whole queue is visible at once
        let long = long_rx.recv().unwrap();
        assert!(long.error.is_none());
        assert_eq!(long.tokens.len(), n_new);
        let shorts: Vec<_> = short_rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        for s in &shorts {
            assert!(s.error.is_none());
            assert_eq!(s.tokens.len(), n_new);
        }
        let snap = coord.shutdown();
        assert_eq!(snap.requests_completed, 9);
        assert_eq!(snap.preemptions, 0, "neither policy preempts here");
        let long_pos = snap
            .completion_order
            .iter()
            .position(|&id| id == long.id)
            .expect("long request retired");
        let max_short_wait = shorts.iter().map(|s| s.queue_wait_s).fold(0.0, f64::max);
        let min_short_wait = shorts.iter().map(|s| s.queue_wait_s).fold(f64::MAX, f64::min);
        if long_first {
            assert_eq!(
                long_pos, 0,
                "fifo: the long head must retire before any short (head-of-line block)"
            );
            assert!(
                min_short_wait > long.queue_wait_s,
                "fifo: every short queues behind the long prompt \
                 (short min {min_short_wait:.4}s vs long {:.4}s)",
                long.queue_wait_s
            );
        } else {
            assert_eq!(
                long_pos,
                snap.completion_order.len() - 1,
                "size-aware: every short must retire before the long request finishes \
                 (order {:?}, long id {})",
                snap.completion_order,
                long.id
            );
            assert!(
                max_short_wait < long.queue_wait_s,
                "size-aware: shorts stop queueing behind the long prompt \
                 (short max {max_short_wait:.4}s vs long {:.4}s)",
                long.queue_wait_s
            );
        }
    }
}

/// Preemption round-trip through the whole scheduler with the paper's
/// compressed cache: a long CSKV generation is swapped to the cold tier
/// (its snapshot carrying the low-rank features), a short request runs,
/// and the restored long stream is bit-identical to the direct engine.
#[test]
fn preemptive_scheduler_round_trips_cskv_sequences() {
    let engine = make_engine(29);
    let cfg = ModelConfig::test_small();
    let long_prompt: Vec<usize> = (0..40).map(|i| (i * 13 + 5) % 256).collect();
    let short_prompt = vec![7usize, 11, 13];
    let (long_n, short_n) = (90usize, 2usize);
    let want_long = {
        let mut pol = mk_policies().swap_remove(1); // cskv fp32
        engine.generate(&long_prompt, long_n, pol.as_mut()).0
    };
    let want_short = {
        let mut pol = mk_policies().swap_remove(1);
        engine.generate(&short_prompt, short_n, pol.as_mut()).0
    };
    // Budget: the cskv projection of the long sequence plus a hair — the
    // short request can only run by swapping the long one out.
    let long_cost = mk_policies()
        .swap_remove(1)
        .kv_bytes_projected(long_prompt.len() + long_n);
    let short_cost = mk_policies()
        .swap_remove(1)
        .kv_bytes_projected(short_prompt.len() + short_n);
    let budget = long_cost + short_cost / 2;
    let coord = Coordinator::start(
        policy_setup(29, 1),
        CoordinatorConfig {
            max_batch: 4,
            kv_budget_bytes: Some(budget),
            scheduler: SchedulerKind::Preemptive,
            ..Default::default()
        },
    );
    let long_rx = coord.submit(long_prompt.clone(), long_n);
    let t0 = std::time::Instant::now();
    while coord.metrics().kv_bytes_current() == 0 {
        assert!(t0.elapsed().as_secs() < 30, "long request never started");
        std::thread::yield_now();
    }
    let short = coord.submit_wait(short_prompt, short_n);
    assert!(short.error.is_none(), "{:?}", short.error);
    assert_eq!(short.tokens, want_short);
    let long = long_rx.recv().unwrap();
    assert!(long.error.is_none(), "{:?}", long.error);
    assert_eq!(long.tokens, want_long, "compressed swap-out must resume bit-identically");
    let snap = coord.shutdown();
    assert!(snap.preemptions >= 1, "budget pressure must trigger a swap-out");
    assert_eq!(snap.restores, snap.preemptions);
    assert!(
        snap.cold_bytes_peak > 0 && snap.cold_bytes_peak < cfg.kv_bytes_full(long_prompt.len() + long_n),
        "cold snapshot stores the compressed representation, not the materialized cache \
         (got {} vs full {})",
        snap.cold_bytes_peak,
        cfg.kv_bytes_full(long_prompt.len() + long_n)
    );
}

/// A backend factory that fails every second construction.
fn flaky_setup(seed: u64) -> Setup {
    Box::new(move || {
        let engine = make_engine(seed);
        let mut n = 0usize;
        let factory: BackendFactory = Box::new(move || {
            n += 1;
            anyhow::ensure!(n % 2 != 0, "injected backend failure #{n}");
            let c = engine.w.cfg.clone();
            Ok(Box::new(RustSequenceBackend::new(
                engine.clone(),
                Box::new(FullCache::new(c.n_layers, c.d_model)),
            )) as Box<dyn cskv::coordinator::SequenceBackend>)
        });
        Ok(factory)
    })
}

/// Failure injection: no request can hang its caller. Every submission —
/// including ones whose backend construction or prefill fails — receives
/// exactly one Response.
#[test]
fn every_request_is_answered_under_failures() {
    let coord = Coordinator::start(flaky_setup(13), CoordinatorConfig::default());
    // 6 normal requests: factory calls 1..=6, the even ones fail.
    let rxs: Vec<_> = (0..6).map(|i| coord.submit(vec![1, 2 + i, 3], 3)).collect();
    // Plus one empty prompt: its construction may succeed, prefill fails.
    let bad_rx = coord.submit(vec![], 3);
    let mut ok = 0;
    let mut failed = 0;
    for rx in rxs {
        let resp = rx.recv().expect("every request must be answered");
        if resp.error.is_none() {
            assert_eq!(resp.tokens.len(), 3);
            ok += 1;
        } else {
            assert!(resp.tokens.is_empty());
            failed += 1;
        }
    }
    let bad = bad_rx.recv().expect("failed prefill must still answer");
    assert!(bad.error.is_some());
    let snap = coord.shutdown();
    assert_eq!(ok, 3, "odd-numbered constructions succeed");
    assert_eq!(failed, 3, "even-numbered constructions fail");
    assert_eq!(snap.requests_completed as usize, ok);
    assert_eq!(snap.requests_failed as usize, failed + 1);
}
