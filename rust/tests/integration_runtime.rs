//! Cross-validation of the PJRT serving path against the Rust reference
//! engine — THE architecture-level correctness signal: the quality numbers
//! (measured on the Rust engine) are only meaningful for the served system
//! if both execute the same function.
//!
//! Requires `make artifacts`. Tests are skipped gracefully if artifacts
//! are missing so `cargo test` stays runnable pre-AOT.

use std::rc::Rc;
use std::sync::Arc;

use cskv::compress::svd_init::{init_factors, InitMethod};
use cskv::compress::{LayerFactors, ModelFactors};
use cskv::coordinator::pjrt_backend::{PjrtContext, PjrtCskvSession, PjrtFullSession};
use cskv::coordinator::SequenceBackend;
use cskv::data::tasks;
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, QuantMode};
use cskv::model::{engine::Engine, ModelWeights};
use cskv::runtime::trainer::Trainer;
use cskv::runtime::{Runtime, Value};
use cskv::util::prng::Pcg64;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = cskv::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(&dir).expect("artifacts present but unloadable"))
}

fn test_weights(rt: &Runtime) -> Arc<ModelWeights> {
    Arc::new(ModelWeights::init(&rt.manifest.model, 2024))
}

#[test]
fn manifest_matches_rust_config() {
    let Some(rt) = runtime_or_skip() else { return };
    rt.manifest.model.validate().unwrap();
    assert_eq!(rt.manifest.model.d_model, 128);
    let ranks: Vec<usize> = rt.manifest.cskv_ranks().into_iter().map(|(_, r)| r).collect();
    assert!(ranks.contains(&26) && ranks.contains(&64));
}

#[test]
fn pjrt_full_session_matches_rust_engine() {
    let Some(rt) = runtime_or_skip() else { return };
    let w = test_weights(&rt);
    let engine = Engine::new(Arc::clone(&w));
    let cfg = w.cfg.clone();

    let mut rng = Pcg64::new(5);
    let sample = tasks::line_retrieval(8, &mut rng);
    let n_new = 6;

    // Rust engine reference.
    let mut cache = FullCache::new(cfg.n_layers, cfg.d_model);
    let (want, _) = engine.generate(&sample.prompt, n_new, &mut cache);

    // PJRT path.
    let ctx = Rc::new(PjrtContext::new(rt, w).unwrap());
    let mut sess = PjrtFullSession::new(ctx);
    let mut got = vec![sess.prefill(&sample.prompt).unwrap()];
    for _ in 1..n_new {
        got.push(sess.decode_next().unwrap());
    }
    assert_eq!(got, want, "PJRT decode_full must reproduce the rust engine");
    assert_eq!(sess.kv_bytes(), cfg.kv_bytes_full(sample.prompt.len() + n_new - 1));
}

#[test]
fn pjrt_cskv_session_matches_rust_policy() {
    let Some(rt) = runtime_or_skip() else { return };
    let w = test_weights(&rt);
    let engine = Engine::new(Arc::clone(&w));
    let cfg = w.cfg.clone();

    // SVD-initialized rank-26 factors (matches the exported artifact).
    let layers: Vec<LayerFactors> = w
        .layers
        .iter()
        .map(|lw| LayerFactors {
            k: init_factors(&lw.wk, 26, InitMethod::Svd, None, 0),
            v: init_factors(&lw.wv, 26, InitMethod::Svd, None, 0),
        })
        .collect();
    let factors = Arc::new(ModelFactors {
        layers,
        provenance: "it-svd-r26".into(),
    });

    let mut rng = Pcg64::new(6);
    let sample = tasks::line_retrieval(10, &mut rng);
    let n_new = 5;

    // Rust bi-branch policy (window must equal the artifact's: 32).
    let mut policy = CskvCache::new(
        Arc::clone(&factors),
        cfg.d_model,
        CskvConfig {
            window: 32,
            quant: QuantMode::None,
        },
    );
    let (want, _) = engine.generate(&sample.prompt, n_new, &mut policy);

    let ctx = Rc::new(PjrtContext::new(rt, w).unwrap());
    let mut sess = PjrtCskvSession::new(ctx, factors).unwrap();
    let mut got = vec![sess.prefill(&sample.prompt).unwrap()];
    for _ in 1..n_new {
        got.push(sess.decode_next().unwrap());
    }
    assert_eq!(
        got, want,
        "PJRT decode_cskv (fused Pallas kernel) must reproduce the rust bi-branch cache"
    );
    // Compressed session must be much smaller than a full cache would be.
    let full = cfg.kv_bytes_full(sample.prompt.len() + n_new - 1);
    assert!(sess.kv_bytes() < full, "{} !< {full}", sess.kv_bytes());
}

#[test]
fn pjrt_prefill_logits_match_engine() {
    let Some(rt) = runtime_or_skip() else { return };
    let w = test_weights(&rt);
    let engine = Engine::new(Arc::clone(&w));
    let prompt: Vec<usize> = vec![1, 30, 77, 120, 9, 64, 200, 3];
    let rec = engine.prefill(&prompt, None);

    let mut tokens: Vec<i32> = prompt.iter().map(|&t| t as i32).collect();
    tokens.resize(w.cfg.max_seq, 0);
    let mut inputs: Vec<Value> = w.flat_order().iter().map(|(_, m)| Value::from_mat(m)).collect();
    inputs.push(Value::i32_vec(vec![w.cfg.max_seq], tokens));
    let out = rt.execute("prefill", &inputs).unwrap();
    let logits = out[0].to_mat().unwrap();
    let mut max_diff = 0.0f32;
    for t in 0..prompt.len() {
        for v in 0..w.cfg.vocab_size {
            max_diff = max_diff.max((logits.at(t, v) - rec.logits.at(t, v)).abs());
        }
    }
    assert!(
        max_diff < 5e-3,
        "XLA vs rust-engine logits diverge: max {max_diff}"
    );
}

#[test]
fn trainer_reduces_loss_through_pjrt() {
    let Some(rt) = runtime_or_skip() else { return };
    if rt.manifest.get("train_step").is_err() {
        eprintln!("SKIP: train_step not exported");
        return;
    }
    let mut trainer = Trainer::new(&rt, 7).unwrap();
    let losses = trainer
        .train(&cskv::runtime::trainer::TrainConfig {
            steps: 6,
            lr: 3e-3,
            seed: 7,
            log_every: 100,
        })
        .unwrap();
    assert_eq!(losses.len(), 6);
    assert!(
        losses[5] < losses[0],
        "loss should drop within 6 steps: {losses:?}"
    );
    // ~uniform initial loss: ln(256) ≈ 5.55.
    assert!((5.0..6.0).contains(&losses[0]), "init loss {}", losses[0]);
}

#[test]
fn runtime_rejects_bad_inputs() {
    let Some(rt) = runtime_or_skip() else { return };
    // Wrong arity.
    assert!(rt.execute("prefill", &[]).is_err());
    // Unknown executable.
    assert!(rt.execute("nope", &[]).is_err());
}
