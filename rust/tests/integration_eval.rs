//! Integration: evaluation suites + harness + Figure 3 analysis end-to-end
//! on a randomly-initialized model (trained-model numbers live in benches).

use std::sync::Arc;

use cskv::data::corpus::{calibration_docs, CorpusConfig};
use cskv::data::vocab;
use cskv::eval::svd_analysis;
use cskv::eval::{EvalSet, Suite};
use cskv::kvcache::{FullCache, KvCachePolicy};
use cskv::model::{engine::Engine, ModelConfig, ModelWeights};

fn engine() -> Engine {
    Engine::new(Arc::new(ModelWeights::init(&ModelConfig::test_small(), 21)))
}

#[test]
fn all_table1_suites_generate_and_evaluate() {
    let e = engine();
    let cfg = e.w.cfg.clone();
    // Scale the suites down to the test model's 128 max_seq.
    let suites = [
        Suite::LongEval { ctx: 64 },
        Suite::LongBench { ctx: 64, n_facts: 3 },
        Suite::LvEval { ctx: 100 },
    ];
    for suite in suites {
        let set = EvalSet::build(&e, suite.sample_set(4, 3));
        let c = cfg.clone();
        let mut factory = move || -> Box<dyn KvCachePolicy> {
            Box::new(FullCache::new(c.n_layers, c.d_model))
        };
        let r = set.eval(&e, &mut factory);
        assert_eq!(r.n_samples, 4);
        assert!(r.mean_kv_bytes > 0.0);
        assert!(!r.decode_tok_s.is_empty());
        // Untrained model: accuracy is whatever it is, but the scorer must
        // produce a valid fraction.
        assert!((0.0..=1.0).contains(&r.accuracy()));
    }
}

#[test]
fn answers_are_present_in_prompts() {
    // Every generated sample must be solvable: the queried digits appear
    // verbatim right after the queried key.
    for suite in [
        Suite::LongEval { ctx: 96 },
        Suite::LongBench { ctx: 96, n_facts: 4 },
        Suite::LvEval { ctx: 110 },
    ] {
        for s in suite.sample_set(10, 5) {
            let qkey = s.prompt[s.prompt.len() - 2];
            assert!(vocab::is_key(qkey));
            let kpos = s
                .prompt
                .iter()
                .position(|&t| t == qkey)
                .expect("query key in context");
            let window = &s.prompt[kpos..kpos + 3 + vocab::VALUE_LEN];
            let has_answer = window
                .windows(vocab::VALUE_LEN)
                .any(|w| w == &s.answer[..]);
            assert!(has_answer, "answer near key: {:?}", vocab::detokenize(window));
        }
    }
}

#[test]
fn figure3_analysis_on_model_key_cache() {
    let e = engine();
    let corpus = CorpusConfig {
        seq_len: 96,
        ..Default::default()
    };
    let docs = calibration_docs(&corpus, 4, 17);
    let rep = svd_analysis::analyze_key_cache(&e, &docs, e.w.cfg.n_layers / 2);
    assert_eq!(rep.singular_values.len(), e.w.cfg.d_model);
    // Spectrum sorted descending, cumulative energy valid.
    assert!(rep
        .singular_values
        .windows(2)
        .all(|w| w[0] >= w[1] - 1e-5));
    assert!((rep.cum_energy.last().unwrap() - 1.0).abs() < 1e-3);
    assert!(rep.half_rank_rel_error >= 0.0 && rep.half_rank_rel_error <= 1.0);
}

#[test]
fn suite_ctx_budgets_respected_at_scale() {
    for (name, suite) in Suite::table1_columns() {
        let s = suite.sample_set(2, 8);
        for t in s {
            assert!(
                t.ctx_len <= 512,
                "{name}: sample too long for max_seq ({})",
                t.ctx_len
            );
        }
    }
}
