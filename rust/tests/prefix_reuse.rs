//! Bit-identity oracle for shared-prefix KV reuse (the prefix-cache
//! tentpole).
//!
//! A donor prompt is prefilled cold with capture, its prefix published
//! into a [`PrefixCache`]; a target prompt sharing the first 64 tokens
//! (two aligned blocks) is then prefilled **seeded** from the cache and
//! compared field-by-field against its own cold run — logits, per-layer
//! activations, attention mass, policy KV state, and 8 subsequent
//! decode steps must match **bitwise**, for every cache policy ×
//! threads {1, 8}.
//!
//! CSKV coverage deliberately includes a *mid-window* prefix boundary:
//! with `window = 48` and a 96-token target, the 64-token seed boundary
//! falls inside the uncompressed recent window, and with `window = 6`
//! it falls deep in the compressed region. Replay ingestion makes both
//! trivially exact (the policy observes the identical full stream), but
//! the oracle pins that down against regressions.

use std::sync::Arc;

use cskv::baselines::{AsvdCache, H2oCache, StreamingLlmCache};
use cskv::compress::{LayerFactors, LowRankFactors, ModelFactors};
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, PrefixCache, QuantMode};
use cskv::model::engine::{BatchPrefillScratch, DecodeState, Engine};
use cskv::model::{ModelConfig, ModelWeights};
use cskv::tensor::ops;
use cskv::tensor::Mat;
use cskv::util::prng::Pcg64;

/// Low-rank factors matching the `test_small` engine geometry.
fn engine_factors(rank: usize) -> Arc<ModelFactors> {
    let cfg = ModelConfig::test_small();
    let d = cfg.d_model;
    let mut rng = Pcg64::new(rank as u64 * 77 + 5);
    let mut mk = move || {
        LowRankFactors::new(
            Mat::randn(d, rank, 0.2, &mut rng),
            Mat::randn(rank, d, 0.2, &mut rng),
        )
    };
    Arc::new(ModelFactors {
        layers: (0..cfg.n_layers).map(|_| LayerFactors { k: mk(), v: mk() }).collect(),
        provenance: "prefix-reuse".into(),
    })
}

/// One instance of every cache policy. CSKV appears three times: fp32
/// and int4 with the seed boundary mid-window (window 48 > suffix), and
/// fp32 with the boundary far past the window (window 6).
fn mk_policies() -> Vec<Box<dyn KvCachePolicy>> {
    let cfg = ModelConfig::test_small();
    let (l, d) = (cfg.n_layers, cfg.d_model);
    vec![
        Box::new(FullCache::new(l, d)),
        Box::new(CskvCache::new(
            engine_factors(8),
            d,
            CskvConfig { window: 48, quant: QuantMode::None },
        )),
        Box::new(CskvCache::new(
            engine_factors(8),
            d,
            CskvConfig { window: 48, quant: QuantMode::Int4 },
        )),
        Box::new(CskvCache::new(
            engine_factors(8),
            d,
            CskvConfig { window: 6, quant: QuantMode::None },
        )),
        Box::new(StreamingLlmCache::new(l, d, 2, 12)),
        Box::new(H2oCache::new(l, d, 10)),
        Box::new(AsvdCache::new(engine_factors(8))),
    ]
}

/// 96 deterministic donor tokens; targets share the first 64 and then
/// diverge.
fn donor_prompt() -> Vec<usize> {
    let mut rng = Pcg64::new(41);
    (0..96).map(|_| rng.range(16, 250)).collect()
}

fn target_prompt(donor: &[usize], tail_seed: u64, tail_len: usize) -> Vec<usize> {
    let mut p = donor[..64].to_vec();
    let mut rng = Pcg64::new(tail_seed);
    p.extend((0..tail_len).map(|_| rng.range(16, 250)));
    p
}

/// The oracle: seeded prefill + decode ≡ cold prefill + decode, bitwise,
/// for every policy × threads {1, 8} × two suffix lengths (one keeping
/// the target mid-window for CSKV's 48-token window, one shorter).
#[test]
fn prefix_seeded_runs_bit_identical_to_cold() {
    let base = ModelConfig::test_small();
    let n_policies = mk_policies().len();
    let donor = donor_prompt();
    for threads in [1usize, 8] {
        let cfg = base.clone().with_threads(threads);
        let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 7)));
        for pi in 0..n_policies {
            // Publish the donor's prefix from a captured cold run.
            let mut donor_pol = mk_policies().swap_remove(pi);
            let mut scratch = BatchPrefillScratch::new();
            let donor_sp =
                engine.prefill_seeded(&donor, None, Some(donor_pol.as_mut()), true, &mut scratch);
            let mut pc = PrefixCache::new(64 << 20);
            pc.publish(&donor, &donor_sp);

            for (tail_seed, tail_len) in [(97u64, 32usize), (131, 9)] {
                let target = target_prompt(&donor, tail_seed, tail_len);
                let t = target.len();

                // Cold oracle.
                let mut cold_pol = mk_policies().swap_remove(pi);
                let cold = engine.prefill(&target, Some(cold_pol.as_mut()));

                // Warm run, seeded from the published prefix.
                let (seed, pin) = pc.lookup(&target).expect("64-token prefix must hit");
                assert_eq!(seed.len, 64, "two aligned blocks of shared prefix");
                let mut warm_pol = mk_policies().swap_remove(pi);
                let warm = engine.prefill_seeded(
                    &target,
                    Some(&seed),
                    Some(warm_pol.as_mut()),
                    true,
                    &mut scratch,
                );
                pc.release(pin);

                let name = cold_pol.name();
                assert_eq!(warm.start, 64);
                assert_eq!(
                    warm.record.logits.data,
                    cold.logits.rows_slice(64, t).data,
                    "{name}: suffix logits, threads {threads} tail {tail_len}"
                );
                for li in 0..cfg.n_layers {
                    assert_eq!(warm.record.xnorms[li].data, cold.xnorms[li].data);
                    assert_eq!(warm.record.ks[li].data, cold.ks[li].data);
                    assert_eq!(warm.record.vs[li].data, cold.vs[li].data);
                    assert_eq!(
                        warm.record.attn_mass[li], cold.attn_mass[li],
                        "{name}: attention mass L{li}, threads {threads}"
                    );
                    let (cv, wv) = (cold_pol.materialize(li), warm_pol.materialize(li));
                    assert_eq!(cv.k.data, wv.k.data, "{name}: K state L{li}");
                    assert_eq!(cv.v.data, wv.v.data, "{name}: V state L{li}");
                    assert_eq!(cv.abs_pos, wv.abs_pos);
                }
                assert_eq!(cold_pol.kv_bytes(), warm_pol.kv_bytes(), "{name}: footprint");

                // 8 decode steps from the shared last-token argmax.
                let mut cold_st = DecodeState::new(&cfg);
                let mut warm_st = DecodeState::new(&cfg);
                let mut ct = ops::argmax(cold.logits.row(t - 1));
                let wt = ops::argmax(warm.record.logits.row(t - 64 - 1));
                assert_eq!(ct, wt, "{name}: first sampled token");
                for step in 0..8 {
                    let pos = t + step;
                    let cl =
                        engine.decode_step_with(cold_pol.as_mut(), ct, pos, &mut cold_st).to_vec();
                    let wl =
                        engine.decode_step_with(warm_pol.as_mut(), ct, pos, &mut warm_st).to_vec();
                    assert_eq!(cl, wl, "{name}: decode step {step}, threads {threads}");
                    ct = ops::argmax(&cl);
                }
                assert_eq!(cold_pol.kv_bytes(), warm_pol.kv_bytes());
            }
        }
    }
}

/// Unaligned sharing still hits on whole blocks only: a target sharing
/// 70 tokens with the donor seeds from the 64-token (2-block) node, and
/// a target sharing fewer tokens than one block misses outright.
#[test]
fn lookup_is_block_granular() {
    let cfg = ModelConfig::test_small().with_threads(1);
    let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 7)));
    let donor = donor_prompt();
    let mut pol = mk_policies().swap_remove(0);
    let mut scratch = BatchPrefillScratch::new();
    let sp = engine.prefill_seeded(&donor, None, Some(pol.as_mut()), true, &mut scratch);
    let mut pc = PrefixCache::new(64 << 20);
    pc.publish(&donor, &sp);

    // Shares 70 tokens ⇒ only the 64-token boundary is usable.
    let mut t70 = donor[..70].to_vec();
    t70.extend_from_slice(&[3, 4, 5]);
    let (seed, pin) = pc.lookup(&t70).expect("2-block prefix");
    assert_eq!(seed.len, 64);
    pc.release(pin);

    // Shares 20 tokens ⇒ below one block ⇒ miss.
    let mut t20 = donor[..20].to_vec();
    t20.extend_from_slice(&[7, 8, 9]);
    assert!(pc.lookup(&t20).is_none());
    let s = pc.stats();
    assert_eq!((s.hits, s.misses), (1, 1));
}
