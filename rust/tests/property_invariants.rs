//! Property-based tests (via the in-tree prop framework) on the
//! coordinator-facing invariants: cache routing, token accounting, window
//! management, memory monotonicity, and compression-plan arithmetic.

use std::sync::Arc;

use cskv::compress::quant::{quantize_block, QuantAxis};
use cskv::compress::ratio::{rank_for_keep, KvCompressionPlan};
use cskv::compress::{LayerFactors, LowRankFactors, ModelFactors};
use cskv::baselines::{AsvdCache, H2oCache, StreamingLlmCache};
use cskv::tensor::matmul;
use cskv::kvcache::{
    merge_blocks, split_blocks, CskvCache, CskvConfig, DecodeView, FullCache, KvCachePolicy,
    KvSnapshot, QuantMode,
};
use cskv::model::engine::DecodeState;
use cskv::model::{engine::Engine, ModelConfig, ModelWeights};
use cskv::tensor::ops;
use cskv::tensor::Mat;
use cskv::util::prng::Pcg64;
use cskv::util::prop::{forall, zip, Gen};

const D: usize = 16;

fn factors(rank: usize, layers: usize) -> Arc<ModelFactors> {
    let mut rng = Pcg64::new(rank as u64 * 31 + layers as u64);
    let mut mk = move || {
        LowRankFactors::new(
            Mat::randn(D, rank, 0.2, &mut rng),
            Mat::randn(rank, D, 0.2, &mut rng),
        )
    };
    Arc::new(ModelFactors {
        layers: (0..layers).map(|_| LayerFactors { k: mk(), v: mk() }).collect(),
        provenance: "prop".into(),
    })
}

/// Drive any policy through a synthetic prefill + N appends.
fn drive(policy: &mut dyn KvCachePolicy, prefill_len: usize, appends: usize, seed: u64) {
    let mut rng = Pcg64::new(seed);
    let t = prefill_len.max(1);
    let x = Mat::randn(t, D, 1.0, &mut rng);
    let k = Mat::randn(t, D, 1.0, &mut rng);
    let v = Mat::randn(t, D, 1.0, &mut rng);
    policy.ingest_prefill(0, &x, &k, &v);
    policy.observe_prefill_attn(0, &vec![0.1; t]);
    for _ in 0..appends {
        let row: Vec<f32> = (0..D).map(|_| rng.normal()).collect();
        policy.append(0, &row, &row, &row);
    }
}

#[test]
fn prop_cskv_total_tokens_and_window() {
    forall(
        "cskv: len == prefill+appends; view covers all; window ≤ m",
        60,
        zip(Gen::usize_in(1..60), zip(Gen::usize_in(0..40), Gen::usize_in(0..12))),
        |&(prefill, (appends, window))| {
            let f = factors(4, 1);
            let mut c = CskvCache::new(
                f,
                D,
                CskvConfig {
                    window,
                    quant: QuantMode::None,
                },
            );
            drive(&mut c, prefill, appends, 1);
            let total = prefill.max(1) + appends;
            let view = c.materialize(0);
            view.validate();
            c.len(0) == total
                && view.len() == total
                && view.abs_pos == (0..total).collect::<Vec<_>>()
        },
    );
}

#[test]
fn prop_cskv_memory_monotone_in_tokens() {
    forall(
        "cskv: kv_bytes non-decreasing as tokens append",
        40,
        zip(Gen::usize_in(1..40), Gen::usize_in(1..30)),
        |&(prefill, appends)| {
            let f = factors(4, 1);
            let mut c = CskvCache::new(f, D, CskvConfig::default());
            let mut rng = Pcg64::new(3);
            let t = prefill.max(1);
            let x = Mat::randn(t, D, 1.0, &mut rng);
            c.ingest_prefill(0, &x, &x, &x);
            let mut last = c.kv_bytes();
            for _ in 0..appends {
                let row: Vec<f32> = (0..D).map(|_| rng.normal()).collect();
                c.append(0, &row, &row, &row);
                let now = c.kv_bytes();
                if now < last {
                    return false;
                }
                last = now;
            }
            true
        },
    );
}

#[test]
fn prop_streaming_budget_and_sinks() {
    forall(
        "streamingllm: kept ≤ budget; sinks pinned; newest kept",
        60,
        zip(
            zip(Gen::usize_in(1..5), Gen::usize_in(6..40)),
            zip(Gen::usize_in(1..80), Gen::usize_in(0..40)),
        ),
        |&((sinks, budget), (prefill, appends))| {
            let mut c = StreamingLlmCache::new(1, D, sinks, budget);
            drive(&mut c, prefill, appends, 2);
            let total = prefill.max(1) + appends;
            let view = c.materialize(0);
            view.validate();
            let kept_ok = view.len() <= budget && view.len() == total.min(budget);
            let newest_ok = *view.abs_pos.last().unwrap() == total - 1;
            let sinks_ok = if total > budget {
                (0..sinks.min(view.len())).all(|i| view.abs_pos[i] == i)
            } else {
                true
            };
            // Cache-relative positions are contiguous.
            let rope_ok = view.rope_pos == (0..view.len()).collect::<Vec<_>>();
            kept_ok && newest_ok && sinks_ok && rope_ok
        },
    );
}

#[test]
fn prop_h2o_budget_and_recency() {
    forall(
        "h2o: kept ≤ budget; recent half protected; positions sorted",
        60,
        zip(Gen::usize_in(4..32), zip(Gen::usize_in(1..60), Gen::usize_in(0..30))),
        |&(budget, (prefill, appends))| {
            let mut c = H2oCache::new(1, D, budget);
            drive(&mut c, prefill, appends, 4);
            let total = prefill.max(1) + appends;
            let view = c.materialize(0);
            view.validate();
            if view.len() > budget || view.len() != total.min(budget) {
                return false;
            }
            // Absolute positions strictly increasing (order preserved).
            if !view.abs_pos.windows(2).all(|w| w[0] < w[1]) {
                return false;
            }
            // The most recent budget/2 tokens are always kept.
            let recent = budget / 2;
            (total.saturating_sub(recent)..total).all(|p| view.abs_pos.contains(&p))
        },
    );
}

#[test]
fn prop_full_cache_is_identity() {
    forall(
        "full cache: exact storage, bytes = 2·n·D·4·layers",
        40,
        zip(Gen::usize_in(1..50), Gen::usize_in(0..30)),
        |&(prefill, appends)| {
            let mut c = FullCache::new(2, D);
            drive(&mut c, prefill, appends, 5);
            // layer 1 untouched by drive()
            let total = prefill.max(1) + appends;
            c.len(0) == total
                && c.len(1) == 0
                && c.kv_bytes() == 2 * total * D * 4
        },
    );
}

#[test]
fn prop_ratio_plan_arithmetic() {
    forall(
        "compression plan: allocation preserves total; ranks within bounds",
        100,
        zip(Gen::f64_in(0.05, 0.95), Gen::usize_in(1..8)),
        |&(total, octave)| {
            let budget = 2.0 * (1.0 - total);
            let keep_k = budget * octave as f64 / 8.0;
            if keep_k <= 0.0 || keep_k >= 1.0 || budget - keep_k <= 0.0 || budget - keep_k > 1.0 {
                return true; // infeasible allocation — constructor would panic by design
            }
            let plan = KvCompressionPlan::with_allocation(total, keep_k);
            let rt = (plan.total_ratio() - total).abs() < 1e-9;
            let rk = plan.rank_k(128);
            let rv = plan.rank_v(128);
            rt && (1..=128).contains(&rk) && (1..=128).contains(&rv)
        },
    );
}

#[test]
fn prop_rank_for_keep_monotone() {
    forall(
        "rank_for_keep monotone in keep fraction",
        100,
        zip(Gen::f64_in(0.0, 1.0), Gen::f64_in(0.0, 1.0)),
        |&(a, b)| {
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            rank_for_keep(128, lo) <= rank_for_keep(128, hi)
        },
    );
}

/// THE correctness oracle for the incremental decode views: for every
/// policy, a persistently-synced [`DecodeView`] after an arbitrary random
/// schedule of prefill + decode appends (with evictions, window rolls and
/// int4 group seals happening along the way) must be **bit-identical** to
/// a from-scratch materialization into a fresh view.
#[test]
fn prop_incremental_decode_views_match_full_rebuild() {
    const NH: usize = 2; // D = 16 ⇒ d_head = 8, even as RoPE requires
    forall(
        "all policies: incremental DecodeView ≡ from-scratch rebuild",
        25,
        zip(
            zip(Gen::usize_in(1..70), Gen::usize_in(0..45)),
            zip(Gen::usize_in(0..8), Gen::usize_in(4..12)),
        ),
        |&((prefill, appends), (window, budget))| {
            let mk_policies = || -> Vec<Box<dyn KvCachePolicy>> {
                vec![
                    Box::new(FullCache::new(1, D)),
                    Box::new(CskvCache::new(
                        factors(4, 1),
                        D,
                        CskvConfig { window, quant: QuantMode::None },
                    )),
                    Box::new(CskvCache::new(
                        factors(4, 1),
                        D,
                        CskvConfig { window, quant: QuantMode::Int4 },
                    )),
                    Box::new(StreamingLlmCache::new(1, D, 2, budget.max(3))),
                    Box::new(H2oCache::new(1, D, budget)),
                    Box::new(AsvdCache::new(factors(4, 1))),
                ]
            };
            for mut policy in mk_policies() {
                let mut rng = Pcg64::new(prefill as u64 * 1000 + appends as u64);
                let t = prefill.max(1);
                let x = Mat::randn(t, D, 1.0, &mut rng);
                let k = Mat::randn(t, D, 1.0, &mut rng);
                let v = Mat::randn(t, D, 1.0, &mut rng);
                policy.ingest_prefill(0, &x, &k, &v);
                policy.observe_prefill_attn(0, &vec![0.1; t]);

                // The live view is synced every step, like the engine's
                // persistent DecodeState.
                let mut live = DecodeView::new(D, NH, 10000.0);
                policy.sync_view(0, &mut live);
                for _ in 0..appends {
                    let row: Vec<f32> = (0..D).map(|_| rng.normal()).collect();
                    policy.append(0, &row, &row, &row);
                    policy.sync_view(0, &mut live);
                    live.validate();
                    // Random attention feedback so H2O evicts mid-list.
                    let probs: Vec<f32> =
                        (0..live.len()).map(|_| rng.normal().abs()).collect();
                    let abs: Vec<usize> = live.abs_positions().to_vec();
                    policy.observe_decode_attn(0, &abs, &probs);
                }

                // From-scratch oracle into a fresh view.
                let mut fresh = DecodeView::new(D, NH, 10000.0);
                policy.sync_view(0, &mut fresh);
                fresh.validate();
                if !live.same_contents(&fresh) || live.len() != policy.len(0) {
                    eprintln!(
                        "view mismatch: policy={} live_len={} fresh_len={}",
                        policy.name(),
                        live.len(),
                        fresh.len()
                    );
                    return false;
                }
            }
            true
        },
    );
}

/// Low-rank factors matching the `test_small` engine geometry
/// (d_model = 32, 2 layers) for the prefill bit-identity sweep.
fn engine_factors(rank: usize) -> Arc<ModelFactors> {
    let d = ModelConfig::test_small().d_model;
    let mut rng = Pcg64::new(rank as u64 * 77 + 5);
    let mut mk = move || {
        LowRankFactors::new(
            Mat::randn(d, rank, 0.2, &mut rng),
            Mat::randn(rank, d, 0.2, &mut rng),
        )
    };
    Arc::new(ModelFactors {
        layers: (0..2).map(|_| LayerFactors { k: mk(), v: mk() }).collect(),
        provenance: "prop-prefill".into(),
    })
}

/// THE correctness oracle for the streaming tiled prefill: for every
/// cache policy — including ASVD's lossy K/V substitution — and every
/// thread count, [`Engine::prefill`] must be **bit-identical** to the
/// pre-refactor serial reference ([`Engine::prefill_reference`]) in all
/// five record fields (logits, xnorms, pre-RoPE K, V, H2O mass), and
/// must leave the policy in an identical state.
#[test]
fn prop_streaming_prefill_bit_identical_to_serial_reference() {
    let base = ModelConfig::test_small();
    let d = base.d_model;
    let n_layers = base.n_layers;
    forall(
        // t up to 80 so the row-chunked parallel GEMM path (m > MC = 64)
        // is exercised *inside* prefill, not only at the kernel level.
        "prefill: streaming/tiled ≡ serial reference, all policies × widths",
        10,
        zip(Gen::usize_in(1..80), Gen::usize_in(0..10_000)),
        |&(t, seed)| {
            let mk_policies = || -> Vec<Box<dyn KvCachePolicy>> {
                vec![
                    Box::new(FullCache::new(n_layers, d)),
                    Box::new(CskvCache::new(
                        engine_factors(8),
                        d,
                        CskvConfig { window: 6, quant: QuantMode::None },
                    )),
                    Box::new(CskvCache::new(
                        engine_factors(8),
                        d,
                        CskvConfig { window: 6, quant: QuantMode::Int4 },
                    )),
                    Box::new(StreamingLlmCache::new(n_layers, d, 2, 12)),
                    Box::new(H2oCache::new(n_layers, d, 10)),
                    Box::new(AsvdCache::new(engine_factors(8))),
                ]
            };
            let mut rng = Pcg64::new(seed as u64 + 1);
            let vocab = base.vocab_size;
            let tokens: Vec<usize> = (0..t).map(|_| rng.range(0, vocab)).collect();
            for threads in [1usize, 2, 8] {
                let cfg = base.clone().with_threads(threads);
                // Same init seed ⇒ identical weights at every width.
                let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 7)));
                for (mut pa, mut pb) in mk_policies().into_iter().zip(mk_policies()) {
                    let want = engine.prefill_reference(&tokens, Some(pa.as_mut()));
                    let got = engine.prefill(&tokens, Some(pb.as_mut()));
                    if got.logits.data != want.logits.data {
                        eprintln!("logits mismatch: {} t={t} threads={threads}", pa.name());
                        return false;
                    }
                    for li in 0..n_layers {
                        if got.xnorms[li].data != want.xnorms[li].data
                            || got.ks[li].data != want.ks[li].data
                            || got.vs[li].data != want.vs[li].data
                            || got.attn_mass[li] != want.attn_mass[li]
                        {
                            eprintln!("record mismatch: {} L{li} t={t} threads={threads}", pa.name());
                            return false;
                        }
                        // Both policies must have ingested identical
                        // streams and observed identical mass.
                        let (va, vb) = (pa.materialize(li), pb.materialize(li));
                        if pa.len(li) != pb.len(li)
                            || va.k.data != vb.k.data
                            || va.v.data != vb.v.data
                            || va.rope_pos != vb.rope_pos
                            || va.abs_pos != vb.abs_pos
                        {
                            eprintln!("policy state mismatch: {} L{li} t={t} threads={threads}", pa.name());
                            return false;
                        }
                    }
                }
            }
            true
        },
    );
}

/// Engine-geometry policy set for the preemption round-trip sweep: the
/// paper policy in both quant modes plus every baseline.
fn preemptable_policies() -> Vec<Box<dyn KvCachePolicy>> {
    let cfg = ModelConfig::test_small();
    let (l, d) = (cfg.n_layers, cfg.d_model);
    vec![
        Box::new(FullCache::new(l, d)),
        Box::new(CskvCache::new(
            engine_factors(8),
            d,
            CskvConfig { window: 6, quant: QuantMode::None },
        )),
        Box::new(CskvCache::new(
            engine_factors(8),
            d,
            CskvConfig { window: 6, quant: QuantMode::Int4 },
        )),
        Box::new(StreamingLlmCache::new(l, d, 2, 12)),
        Box::new(H2oCache::new(l, d, 10)),
        Box::new(AsvdCache::new(engine_factors(8))),
    ]
}

/// THE correctness oracle for the preemptive scheduler's state
/// migration: for every policy × ctx {64, 256, 509} × threads {1, 8},
/// a generation that is snapshotted mid-decode, round-tripped through
/// the cold tier's encoded byte form, and restored into a **fresh**
/// policy + fresh engine `DecodeState` (views rebuilt through the
/// normal `sync_view` path) must produce the exact token stream — and
/// the exact final cache state — of an unpreempted run.
///
/// The snapshot point (after 2 decode steps, every ctx > window) is
/// deliberately mid-window-migration for the bi-branch cache: each
/// append is rolling one token from the exact window into the
/// compressed branch, and at ctx 509 the int4 store also holds a
/// partially-filled residual group.
#[test]
fn snapshot_restore_decode_bit_identical_to_unpreempted() {
    let base = ModelConfig::test_small();
    let n_policies = preemptable_policies().len();
    const SPLIT: usize = 2; // decode steps before the snapshot
    const TAIL: usize = 4; // decode steps after the restore
    for threads in [1usize, 8] {
        let cfg = base.clone().with_threads(threads);
        let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 7)));
        for ctx in [64usize, 256, 509] {
            let mut rng = Pcg64::new(ctx as u64 * 31 + threads as u64);
            let tokens: Vec<usize> = (0..ctx).map(|_| rng.range(16, 250)).collect();
            for pi in 0..n_policies {
                // Unpreempted oracle.
                let mut oracle = preemptable_policies().swap_remove(pi);
                let name = oracle.name();
                let rec = engine.prefill(&tokens, Some(oracle.as_mut()));
                let mut ostate = DecodeState::new(&engine.w.cfg);
                let mut tok = ops::argmax(rec.logits.row(ctx - 1));
                let mut want = vec![tok];
                for i in 0..(SPLIT + TAIL) {
                    let logits =
                        engine.decode_step_with(oracle.as_mut(), tok, ctx + i, &mut ostate);
                    tok = ops::argmax(logits);
                    want.push(tok);
                }

                // Preempted run: decode SPLIT steps, snapshot, drop the
                // hot state entirely, restore into a fresh policy.
                let mut pre = preemptable_policies().swap_remove(pi);
                let rec2 = engine.prefill(&tokens, Some(pre.as_mut()));
                let mut pstate = DecodeState::new(&engine.w.cfg);
                let mut tok2 = ops::argmax(rec2.logits.row(ctx - 1));
                let mut got = vec![tok2];
                for i in 0..SPLIT {
                    let logits =
                        engine.decode_step_with(pre.as_mut(), tok2, ctx + i, &mut pstate);
                    tok2 = ops::argmax(logits);
                    got.push(tok2);
                }
                // Round-trip through the encoded byte form — exactly
                // what the cold tier stores and reads back.
                let snap = KvSnapshot::decode(&pre.snapshot().encode())
                    .expect("snapshot encoding round-trips");
                drop(pre);
                drop(pstate);
                let mut restored = preemptable_policies().swap_remove(pi);
                restored
                    .restore(&snap)
                    .unwrap_or_else(|e| panic!("{name}: restore failed: {e:#}"));
                let mut rstate = DecodeState::new(&engine.w.cfg);
                for i in SPLIT..(SPLIT + TAIL) {
                    let logits =
                        engine.decode_step_with(restored.as_mut(), tok2, ctx + i, &mut rstate);
                    tok2 = ops::argmax(logits);
                    got.push(tok2);
                }
                assert_eq!(
                    got, want,
                    "{name}: ctx={ctx} threads={threads}: preempted stream must equal unpreempted"
                );
                // Final cache state is bit-identical too.
                for li in 0..engine.w.cfg.n_layers {
                    let (a, b) = (oracle.materialize(li), restored.materialize(li));
                    assert_eq!(a.k.data, b.k.data, "{name}: K state L{li} ctx={ctx}");
                    assert_eq!(a.v.data, b.v.data, "{name}: V state L{li} ctx={ctx}");
                    assert_eq!(a.rope_pos, b.rope_pos, "{name}: rope L{li}");
                    assert_eq!(a.abs_pos, b.abs_pos, "{name}: abs L{li}");
                }
            }
        }
    }
}

/// The pager's block codec contract, over *real* policy snapshots: for
/// every policy variant, splitting the encoded snapshot into block runs
/// at arbitrary boundaries, round-tripping each block through its own
/// framed byte form (what the warm/disk tiers store), and re-merging —
/// in any assembly order — must reproduce the original encoded bytes
/// exactly, and the re-merged form must decode + restore bit-identically.
/// This is what makes block-granular spill/promote safe for all six
/// policies: blocks are byte ranges of the canonical encoding, so no
/// policy-specific structure can straddle a boundary incorrectly.
#[test]
fn snapshot_block_split_merge_bit_identical_for_all_policies() {
    let cfg = ModelConfig::test_small();
    let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 11)));
    let ctx = 64usize;
    let mut rng = Pcg64::new(0xB10C);
    let tokens: Vec<usize> = (0..ctx).map(|_| rng.range(16, 250)).collect();
    let n_policies = preemptable_policies().len();
    for pi in 0..n_policies {
        let mut policy = preemptable_policies().swap_remove(pi);
        let name = policy.name();
        let rec = engine.prefill(&tokens, Some(policy.as_mut()));
        let mut state = DecodeState::new(&engine.w.cfg);
        let mut tok = ops::argmax(rec.logits.row(ctx - 1));
        for i in 0..3 {
            tok = ops::argmax(engine.decode_step_with(policy.as_mut(), tok, ctx + i, &mut state));
        }
        let encoded = policy.snapshot().encode();
        // Arbitrary boundaries: degenerate 1-byte blocks, primes that
        // leave ragged tails, the exact length, and oversized.
        for block_bytes in [1usize, 7, 64, 1024, encoded.len().max(2) - 1, encoded.len(), encoded.len() + 9] {
            let blocks = split_blocks(&encoded, block_bytes);
            assert_eq!(
                blocks.iter().map(|b| b.payload.len()).sum::<usize>(),
                encoded.len(),
                "{name}: blocks partition the encoding (block_bytes={block_bytes})"
            );
            // Frame round-trip per block, reassembled in reverse order —
            // merge must sort by index, not arrival.
            let mut framed: Vec<_> = blocks
                .iter()
                .map(|b| {
                    cskv::kvcache::SnapshotBlock::decode(&b.encode())
                        .unwrap_or_else(|e| panic!("{name}: block frame round-trip: {e:#}"))
                })
                .collect();
            framed.reverse();
            let merged = merge_blocks(&framed)
                .unwrap_or_else(|e| panic!("{name}: merge failed (block_bytes={block_bytes}): {e:#}"));
            assert_eq!(
                merged, encoded,
                "{name}: split/merge must be bit-identical (block_bytes={block_bytes})"
            );
            let snap = KvSnapshot::decode(&merged)
                .unwrap_or_else(|e| panic!("{name}: re-merged decode: {e:#}"));
            let mut restored = preemptable_policies().swap_remove(pi);
            restored
                .restore(&snap)
                .unwrap_or_else(|e| panic!("{name}: restore from re-merged blocks: {e:#}"));
            for li in 0..engine.w.cfg.n_layers {
                let (a, b) = (policy.materialize(li), restored.materialize(li));
                assert_eq!(a.k.data, b.k.data, "{name}: K state L{li} block_bytes={block_bytes}");
                assert_eq!(a.v.data, b.v.data, "{name}: V state L{li} block_bytes={block_bytes}");
            }
        }
    }
}

/// The admission pre-charge's accuracy: for fp32 policies,
/// `kv_bytes_projected(n)` computed on an *empty* cache equals the real
/// `kv_bytes()` after the cache actually holds `n` tokens (for int4 the
/// projection is a documented upper bound instead).
#[test]
fn prop_kv_bytes_projected_matches_actual_for_fp32_policies() {
    forall(
        "kv_bytes_projected(n) == kv_bytes() after n tokens (fp32 policies)",
        40,
        zip(Gen::usize_in(1..60), Gen::usize_in(0..40)),
        |&(prefill, appends)| {
            let total = prefill.max(1) + appends;
            let mk: Vec<Box<dyn KvCachePolicy>> = vec![
                Box::new(FullCache::new(1, D)),
                Box::new(CskvCache::new(
                    factors(4, 1),
                    D,
                    CskvConfig { window: 5, quant: QuantMode::None },
                )),
                Box::new(StreamingLlmCache::new(1, D, 2, 9)),
                Box::new(H2oCache::new(1, D, 8)),
                Box::new(AsvdCache::new(factors(4, 1))),
            ];
            for mut policy in mk {
                let projected = policy.kv_bytes_projected(total);
                drive(&mut policy, prefill, appends, 9);
                if projected != policy.kv_bytes() {
                    eprintln!(
                        "projection mismatch: {} projected={} actual={} total={total}",
                        policy.name(),
                        projected,
                        policy.kv_bytes()
                    );
                    return false;
                }
            }
            // Int4 projection is an upper bound (fp32 accounting).
            let mut q = CskvCache::new(
                factors(4, 1),
                D,
                CskvConfig { window: 5, quant: QuantMode::Int4 },
            );
            let projected = q.kv_bytes_projected(total);
            drive(&mut q, prefill, appends, 9);
            projected >= q.kv_bytes()
        },
    );
}

#[test]
fn prop_quantized_store_tracks_token_count() {
    forall(
        "cskv+int4: token accounting identical to fp32 under any schedule",
        40,
        zip(Gen::usize_in(1..80), Gen::usize_in(0..50)),
        |&(prefill, appends)| {
            let f = factors(4, 1);
            let mut q = CskvCache::new(
                Arc::clone(&f),
                D,
                CskvConfig {
                    window: 3,
                    quant: QuantMode::Int4,
                },
            );
            let mut p = CskvCache::new(
                f,
                D,
                CskvConfig {
                    window: 3,
                    quant: QuantMode::None,
                },
            );
            drive(&mut q, prefill, appends, 6);
            drive(&mut p, prefill, appends, 6);
            q.len(0) == p.len(0)
                && q.materialize(0).len() == p.materialize(0).len()
                && q.kv_bytes() <= p.kv_bytes()
        },
    );
}

// ---------------------------------------------------------------------------
// SIMD kernel layer: dispatching kernels vs their scalar oracles.
// ---------------------------------------------------------------------------

/// Exact bit comparison — `==` on f32 would paper over `-0.0` and NaN.
fn same_bits(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// One unit-in-the-last-place at the given magnitude (clamped away from
/// zero so a fully-cancelling reduction still gets a finite budget).
fn ulp_at(scale: f32) -> f32 {
    let s = scale.abs().max(f32::MIN_POSITIVE);
    f32::from_bits(s.to_bits() + 1) - s
}

/// THE contract the `simd` feature rests on, AXPY half: every AXPY-shaped
/// kernel — raw [`matmul::axpy_row`], the GEMV [`matmul::matvec_t_into`],
/// the blocked GEMM [`matmul::matmul_into`] and the batched decode
/// projection [`matmul::matvec_t_batch_into`] — is **bit-identical** to
/// its scalar oracle on arbitrary shapes (odd lengths, SIMD-width tails),
/// and the row/column-parallel entry points preserve those bits at
/// threads 1 and 8. With the feature off (or on a CPU without the ISA)
/// the dispatchers *are* the oracles and this degenerates to a identity
/// check — CI runs both feature legs.
#[test]
fn prop_simd_axpy_family_bit_identical_to_scalar() {
    forall(
        "axpy-family kernels: simd dispatch ≡ scalar oracle, bit-exact",
        40,
        zip(Gen::usize_in(1..512), Gen::usize_in(0..10_000)),
        |&(n, seed)| {
            let mut rng = Pcg64::new(seed as u64 + 1);
            // Raw AXPY on a shared dirty base.
            let brow: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let s = rng.normal();
            let base: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut c_dispatch = base.clone();
            let mut c_scalar = base;
            matmul::axpy_row(&mut c_dispatch, s, &brow);
            matmul::axpy_row_scalar(&mut c_scalar, s, &brow);
            if !same_bits(&c_dispatch, &c_scalar) {
                return false;
            }
            // GEMV `y = Aᵀ·x` into dirty buffers of different filth.
            let m = n % 37 + 1;
            let a = Mat::randn(m, n, 1.0, &mut rng);
            let x: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
            let mut y1 = vec![1.0f32; n];
            let mut y2 = vec![-2.0f32; n];
            matmul::matvec_t_into(&a, &x, &mut y1);
            matmul::matvec_t_into_scalar(&a, &x, &mut y2);
            if !same_bits(&y1, &y2) {
                return false;
            }
            // Blocked GEMM + its row-parallel split.
            let (mm, kk, nn) = (n % 67 + 1, n % 129 + 1, n % 33 + 1);
            let a2 = Mat::randn(mm, kk, 1.0, &mut rng);
            let b2 = Mat::randn(kk, nn, 1.0, &mut rng);
            let mut c1 = Mat::zeros(mm, nn);
            let mut c2 = Mat::zeros(mm, nn);
            matmul::matmul_into(&a2, &b2, &mut c1);
            matmul::matmul_into_scalar(&a2, &b2, &mut c2);
            if !same_bits(&c1.data, &c2.data) {
                return false;
            }
            for threads in [1usize, 8] {
                let mut cp = Mat::from_vec(mm, nn, vec![3.0; mm * nn]); // dirty
                matmul::par_matmul_into(&a2, &b2, &mut cp, threads);
                if !same_bits(&cp.data, &c1.data) {
                    return false;
                }
            }
            // Batched decode GEMV + its column-parallel split.
            let bsz = n % 5 + 1;
            let xs = Mat::randn(bsz, m, 1.0, &mut rng);
            let mut ys1 = Mat::from_vec(bsz, n, vec![9.0; bsz * n]); // dirty
            let mut ys2 = Mat::zeros(bsz, n);
            matmul::matvec_t_batch_into(&a, &xs, &mut ys1);
            matmul::matvec_t_batch_into_scalar(&a, &xs, &mut ys2);
            if !same_bits(&ys1.data, &ys2.data) {
                return false;
            }
            for threads in [1usize, 8] {
                let mut ysp = Mat::from_vec(bsz, n, vec![-1.0; bsz * n]); // dirty
                matmul::par_matvec_t_batch_into(&a, &xs, &mut ysp, threads);
                if !same_bits(&ysp.data, &ys1.data) {
                    return false;
                }
            }
            true
        },
    );
}

/// THE contract the `simd` feature rests on, dot half: the 8-lane dot
/// reassociates the reduction, so [`matmul::dot`] agrees with
/// [`matmul::dot_scalar`] only to a documented tolerance — 4 ULP at the
/// magnitude of `Σ|xᵢyᵢ|` — and [`matmul::matmul_nt_into`] inherits one
/// such budget per `KC` depth block per element. The row-parallel nt
/// split must still be bit-identical to the serial *dispatched* kernel
/// (parallelism never reorders a row's reduction).
#[test]
fn prop_simd_dot_family_within_ulp_of_scalar() {
    forall(
        "dot-family kernels: simd dispatch within 4 ULP/depth-block of scalar",
        40,
        zip(Gen::usize_in(1..600), Gen::usize_in(0..10_000)),
        |&(n, seed)| {
            let mut rng = Pcg64::new(seed as u64 * 3 + 7);
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let d = matmul::dot(&x, &y);
            let ds = matmul::dot_scalar(&x, &y);
            let mag: f32 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
            if (d - ds).abs() > 4.0 * ulp_at(mag) {
                eprintln!("dot: n={n} |Δ|={} tol={}", (d - ds).abs(), 4.0 * ulp_at(mag));
                return false;
            }
            // A·Bᵀ: 4 ULP per depth block, at the full-row product scale.
            let (mm, nn) = (n % 7 + 1, n % 11 + 1);
            let a = Mat::randn(mm, n, 0.5, &mut rng);
            let b = Mat::randn(nn, n, 0.5, &mut rng);
            let mut c1 = Mat::zeros(mm, nn);
            let mut c2 = Mat::zeros(mm, nn);
            matmul::matmul_nt_into(&a, &b, &mut c1);
            matmul::matmul_nt_into_scalar(&a, &b, &mut c2);
            let blocks = n.div_ceil(matmul::KC) as f32;
            for i in 0..mm {
                for j in 0..nn {
                    let mag: f32 =
                        a.row(i).iter().zip(b.row(j)).map(|(p, q)| (p * q).abs()).sum();
                    if (c1.at(i, j) - c2.at(i, j)).abs() > 4.0 * blocks * ulp_at(mag) {
                        eprintln!("nt: n={n} ({i},{j})");
                        return false;
                    }
                }
            }
            for threads in [1usize, 8] {
                let mut cp = Mat::from_vec(mm, nn, vec![2.0; mm * nn]); // dirty
                matmul::par_matmul_nt_into(&a, &b, &mut cp, threads);
                if !same_bits(&cp.data, &c1.data) {
                    return false;
                }
            }
            true
        },
    );
}

/// THE contract the fused int4 decode rests on: for any block shape —
/// including partial final groups (`rows < GROUP`) — and any head column
/// slice, [`quantize_block`]'s fused dequantize-dot and dequantize-AXPY
/// are **bit-identical** to dequantizing the block to f32 and running the
/// scalar GEMV kernels, on both quantization axes. This is what lets
/// `decode_attention` score packed segments without a correctness gap to
/// the materialized path.
#[test]
fn prop_fused_int4_gemv_bit_identical_to_dequantize() {
    forall(
        "fused int4 dot/axpy ≡ dequantize-then-scalar-GEMV, bit-exact",
        40,
        zip(zip(Gen::usize_in(1..40), Gen::usize_in(1..5)), Gen::usize_in(0..10_000)),
        |&((rows, heads), seed)| {
            let mut rng = Pcg64::new(seed as u64 + 11);
            let dh = 2 * (seed % 4 + 1); // head widths 2/4/6/8
            let cols = heads * dh;
            let m = Mat::randn(rows, cols, 1.0, &mut rng);
            for axis in [QuantAxis::PerChannel, QuantAxis::PerToken] {
                let blk = quantize_block(&m, axis);
                let deq = blk.dequantize();
                for h in 0..heads {
                    let (lo, hi) = (h * dh, (h + 1) * dh);
                    let x: Vec<f32> = (0..dh).map(|_| rng.normal()).collect();
                    let scale = rng.normal();
                    let mut got = vec![0.0f32; rows];
                    blk.fused_dot_rows(&x, lo, hi, scale, &mut got);
                    for (r, g) in got.iter().enumerate() {
                        let want = matmul::dot_scalar(&x, &deq.row(r)[lo..hi]) * scale;
                        if g.to_bits() != want.to_bits() {
                            eprintln!("fused dot: rows={rows} r={r} axis={axis:?}");
                            return false;
                        }
                    }
                    let w: Vec<f32> = (0..rows).map(|_| rng.normal().abs()).collect();
                    let mut acc_fused: Vec<f32> = (0..dh).map(|_| rng.normal()).collect();
                    let mut acc_oracle = acc_fused.clone();
                    blk.fused_axpy_rows(&w, lo, hi, &mut acc_fused);
                    for (r, &wr) in w.iter().enumerate() {
                        matmul::axpy_row_scalar(&mut acc_oracle, wr, &deq.row(r)[lo..hi]);
                    }
                    if !same_bits(&acc_fused, &acc_oracle) {
                        eprintln!("fused axpy: rows={rows} axis={axis:?}");
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// THE integrity oracle for the snapshot codec's CRC-32 footer: for
/// every policy's encoded mid-decode snapshot, flipping any single byte
/// — header, version, tag, payload body, or the checksum itself — must
/// make [`KvSnapshot::decode`] return a clean `Err`, never a
/// silently-truncated or bit-rotted cache. Truncations at every
/// boundary (empty, header-only, mid-payload, missing footer) must
/// error too. This is what lets a corrupt cold-tier blob fail exactly
/// one sequence instead of poisoning a restore.
#[test]
fn snapshot_corruption_is_always_rejected() {
    let base = ModelConfig::test_small();
    let engine = Engine::new(Arc::new(ModelWeights::init(&base, 7)));
    let ctx = 64usize;
    let mut rng = Pcg64::new(2026);
    let tokens: Vec<usize> = (0..ctx).map(|_| rng.range(16, 250)).collect();
    let n_policies = preemptable_policies().len();
    for pi in 0..n_policies {
        // Mid-decode snapshot, same split point as the round-trip sweep.
        let mut policy = preemptable_policies().swap_remove(pi);
        let name = policy.name();
        let rec = engine.prefill(&tokens, Some(policy.as_mut()));
        let mut state = DecodeState::new(&engine.w.cfg);
        let mut tok = ops::argmax(rec.logits.row(ctx - 1));
        for i in 0..2 {
            let logits = engine.decode_step_with(policy.as_mut(), tok, ctx + i, &mut state);
            tok = ops::argmax(logits);
        }
        let clean = policy.snapshot().encode();
        assert!(
            KvSnapshot::decode(&clean).is_ok(),
            "{name}: pristine snapshot must decode"
        );

        // Single-byte flips across every region of the layout.
        let n = clean.len();
        let offsets = [
            0,         // magic
            5,         // version / header field
            9,         // header length field
            12,        // first payload byte
            n / 2,     // payload body
            n - 5,     // last payload byte
            n - 4,     // first checksum byte
            n - 1,     // last checksum byte
        ];
        for &off in &offsets {
            for flip in [0x01u8, 0x80] {
                let mut bad = clean.clone();
                bad[off] ^= flip;
                assert!(
                    KvSnapshot::decode(&bad).is_err(),
                    "{name}: flip 0x{flip:02x} at byte {off}/{n} must be rejected"
                );
            }
        }

        // Truncations at every structural boundary.
        for keep in [0usize, 4, 11, 12, n / 2, n - 4, n - 1] {
            assert!(
                KvSnapshot::decode(&clean[..keep]).is_err(),
                "{name}: truncation to {keep}/{n} bytes must be rejected"
            );
        }

        // Trailing garbage is not silently ignored either.
        let mut padded = clean.clone();
        padded.push(0);
        assert!(
            KvSnapshot::decode(&padded).is_err(),
            "{name}: trailing byte must be rejected"
        );
    }
}
