//! Drain → migrate → restore: the graceful-drain counterpart of the
//! preemption round-trip sweep.
//!
//! For **every policy variant** (full, CSKV fp32, CSKV int4,
//! StreamingLLM, H2O, ASVD) a sequence is caught mid-decode by
//! `Coordinator::drain(ZERO)`:
//!
//! * the drained request is answered exactly once, with reason
//!   [`DRAINED`] and the partial tokens generated so far (an exact
//!   prefix of the undisturbed oracle);
//! * the [`DrainBundle`] carries one sequence with a live backend
//!   snapshot, and survives the file round-trip (`save`/`load` — the
//!   cross-process handoff path `cskv serve --drain-file`/
//!   `--resume-from` uses);
//! * the drained coordinator leaks nothing (zero KV / cold bytes) and
//!   counts the migration in `requests_drained`;
//! * a **fresh** coordinator resumes the sequence via `resume_drained`
//!   and completes it **bit-identically** to the oracle, streaming only
//!   the post-migration tokens.
//!
//! Decode is slowed by [`ThrottledBackend`] so "mid-decode" is a wide,
//! deterministic window rather than a race.

use std::sync::Arc;
use std::time::Duration;

use cskv::baselines::{AsvdCache, H2oCache, StreamingLlmCache};
use cskv::compress::{LayerFactors, LowRankFactors, ModelFactors};
use cskv::coordinator::server::{BackendFactory, Setup};
use cskv::coordinator::{
    Coordinator, CoordinatorConfig, DrainBundle, MetricsSnapshot, RustSequenceBackend,
    ThrottledBackend, DRAINED,
};
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, QuantMode};
use cskv::model::{engine::Engine, ModelConfig, ModelWeights};
use cskv::tensor::Mat;
use cskv::util::prng::Pcg64;

const PROMPT: [usize; 6] = [1, 7, 9, 2, 30, 41];
const N_NEW: usize = 60;
const WEIGHT_SEED: u64 = 5;
/// Per-token decode delay in the first coordinator: wide enough that
/// the drain lands mid-decode with hundreds of milliseconds to spare.
const THROTTLE: Duration = Duration::from_millis(4);

fn make_engine() -> Engine {
    Engine::new(Arc::new(ModelWeights::init(&ModelConfig::test_small(), WEIGHT_SEED)))
}

/// Low-rank factors matching the `test_small` engine geometry — same
/// construction as the property-invariants sweep, so CSKV/ASVD states
/// here correspond to proven snapshot round-trip geometry.
fn engine_factors(rank: usize) -> Arc<ModelFactors> {
    let d = ModelConfig::test_small().d_model;
    let mut rng = Pcg64::new(rank as u64 * 77 + 5);
    let mut mk = move || {
        LowRankFactors::new(
            Mat::randn(d, rank, 0.2, &mut rng),
            Mat::randn(rank, d, 0.2, &mut rng),
        )
    };
    Arc::new(ModelFactors {
        layers: (0..2).map(|_| LayerFactors { k: mk(), v: mk() }).collect(),
        provenance: "drain-migrate".into(),
    })
}

/// The six policy variants, as capture-free constructors so both
/// coordinators (and the oracle) build identical fresh instances.
fn policies() -> Vec<(&'static str, fn() -> Box<dyn KvCachePolicy>)> {
    fn full() -> Box<dyn KvCachePolicy> {
        let c = ModelConfig::test_small();
        Box::new(FullCache::new(c.n_layers, c.d_model))
    }
    fn cskv_fp32() -> Box<dyn KvCachePolicy> {
        let c = ModelConfig::test_small();
        Box::new(CskvCache::new(
            engine_factors(8),
            c.d_model,
            CskvConfig { window: 6, quant: QuantMode::None },
        ))
    }
    fn cskv_int4() -> Box<dyn KvCachePolicy> {
        let c = ModelConfig::test_small();
        Box::new(CskvCache::new(
            engine_factors(8),
            c.d_model,
            CskvConfig { window: 6, quant: QuantMode::Int4 },
        ))
    }
    fn streaming() -> Box<dyn KvCachePolicy> {
        let c = ModelConfig::test_small();
        Box::new(StreamingLlmCache::new(c.n_layers, c.d_model, 2, 12))
    }
    fn h2o() -> Box<dyn KvCachePolicy> {
        let c = ModelConfig::test_small();
        Box::new(H2oCache::new(c.n_layers, c.d_model, 10))
    }
    fn asvd() -> Box<dyn KvCachePolicy> {
        Box::new(AsvdCache::new(engine_factors(8)))
    }
    vec![
        ("full", full as fn() -> Box<dyn KvCachePolicy>),
        ("cskv-fp32", cskv_fp32),
        ("cskv-int4", cskv_int4),
        ("streaming-llm", streaming),
        ("h2o", h2o),
        ("asvd", asvd),
    ]
}

/// Coordinator setup running `mk`-policy backends, optionally throttled.
fn setup(mk: fn() -> Box<dyn KvCachePolicy>, throttle: Option<Duration>) -> Setup {
    Box::new(move || {
        let engine = make_engine();
        let factory: BackendFactory = Box::new(move || {
            let inner: Box<dyn cskv::coordinator::SequenceBackend> =
                Box::new(RustSequenceBackend::new(engine.clone(), mk()));
            Ok(match throttle {
                Some(d) => Box::new(ThrottledBackend::new(inner, d)),
                None => inner,
            })
        });
        Ok(factory)
    })
}

fn assert_drained_clean(name: &str, snap: &MetricsSnapshot) {
    assert_eq!(snap.kv_bytes_current, 0, "{name}: KV bytes must refund to zero");
    assert_eq!(snap.cold_bytes_current, 0, "{name}: cold tier must be empty");
}

fn tmp(label: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cskv-drainmig-{label}-{}", std::process::id()))
}

/// The full migration round-trip for every policy variant.
#[test]
fn drain_mid_decode_restores_bit_identically_for_every_policy() {
    for (name, mk) in policies() {
        // Oracle: the undisturbed generation under this exact policy.
        let engine = make_engine();
        let mut cache = mk();
        let want = engine.generate(&PROMPT, N_NEW, cache.as_mut()).0;
        assert_eq!(want.len(), N_NEW);

        // Coordinator 1: throttled decode, drained mid-stream.
        let coord = Coordinator::start(setup(mk, Some(THROTTLE)), CoordinatorConfig::default());
        let (handle, stream) = coord.submit_streaming(PROMPT.to_vec(), N_NEW, None);
        for i in 0..2 {
            let tok = stream
                .recv_timeout(Duration::from_secs(20))
                .unwrap_or_else(|e| panic!("{name}: no streamed token {i}: {e}"));
            assert_eq!(tok, want[i], "{name}: streamed token {i} must match the oracle");
        }
        let bundle = coord.drain(Duration::ZERO).expect("drain");
        assert_eq!(bundle.seqs.len(), 1, "{name}: one in-flight sequence to migrate");
        let resp = handle.rx.recv().expect("drained request must still answer");
        assert_eq!(resp.error.as_deref(), Some(DRAINED), "{name}");
        assert!(
            !resp.tokens.is_empty() && resp.tokens.len() < N_NEW,
            "{name}: drain must land mid-decode (got {} tokens)",
            resp.tokens.len()
        );
        assert_eq!(
            resp.tokens[..],
            want[..resp.tokens.len()],
            "{name}: partial stream is an oracle prefix"
        );
        assert!(handle.rx.recv().is_err(), "{name}: exactly one Response");
        let seq = &bundle.seqs[0];
        assert!(seq.snapshot.is_some(), "{name}: mid-decode sequences carry a snapshot");
        assert_eq!(seq.generated, resp.tokens, "{name}: bundle carries the delivered prefix");
        assert_eq!(seq.prompt, PROMPT.to_vec());
        assert_eq!(seq.n_new, N_NEW);

        // File round-trip: the cross-process handoff path.
        let path = tmp(name);
        bundle.save(&path).expect("save bundle");
        let loaded = DrainBundle::load(&path).expect("load bundle");
        let _ = std::fs::remove_file(&path);
        assert_eq!(loaded.seqs.len(), 1);

        let snap = coord.shutdown();
        assert_eq!(snap.requests_drained, 1, "{name}");
        assert_eq!(snap.requests_completed, 0, "{name}");
        assert_eq!(snap.requests_failed, 0, "{name}");
        assert_drained_clean(name, &snap);

        // Coordinator 2: fresh process stand-in, unthrottled; the resumed
        // stream must be bit-identical to the oracle.
        let carried = resp.tokens.len();
        let coord2 = Coordinator::start(setup(mk, None), CoordinatorConfig::default());
        let (h2, s2) = coord2.resume_drained(loaded.seqs.into_iter().next().unwrap(), None);
        let resp2 = h2.rx.recv().expect("resumed request must answer");
        assert!(resp2.error.is_none(), "{name}: resume failed: {:?}", resp2.error);
        assert_eq!(resp2.tokens, want, "{name}: resumed stream must be bit-identical");
        let streamed2: Vec<usize> = s2.try_iter().collect();
        assert_eq!(
            streamed2[..],
            want[carried..],
            "{name}: only post-migration tokens are re-streamed"
        );
        let snap2 = coord2.shutdown();
        assert_eq!(snap2.requests_completed, 1, "{name}");
        assert_drained_clean(name, &snap2);
    }
}

/// Still-queued sequences migrate without a snapshot and re-run from
/// the prompt — bit-identical for a lossless-deterministic policy.
#[test]
fn drain_migrates_queued_sequences_without_snapshot() {
    let (name, mk) = policies().remove(0); // full cache
    let engine = make_engine();
    let mut cache = mk();
    let want = engine.generate(&PROMPT, 8, cache.as_mut()).0;

    // max_batch 0: nothing is ever admitted, the request stays queued.
    let coord = Coordinator::start(
        setup(mk, None),
        CoordinatorConfig { max_batch: 0, ..Default::default() },
    );
    let (handle, _stream) = coord.submit_streaming(PROMPT.to_vec(), 8, None);
    let bundle = coord.drain(Duration::ZERO).expect("drain");
    assert_eq!(bundle.seqs.len(), 1);
    assert!(bundle.seqs[0].snapshot.is_none(), "queued sequences carry no snapshot");
    assert!(bundle.seqs[0].generated.is_empty());
    let resp = handle.rx.recv().unwrap();
    assert_eq!(resp.error.as_deref(), Some(DRAINED));
    assert!(resp.tokens.is_empty());
    let snap = coord.shutdown();
    assert_eq!(snap.requests_drained, 1);
    assert_drained_clean(name, &snap);

    let coord2 = Coordinator::start(setup(mk, None), CoordinatorConfig::default());
    let (h2, s2) = coord2.resume_drained(bundle.seqs.into_iter().next().unwrap(), None);
    let resp2 = h2.rx.recv().unwrap();
    assert!(resp2.error.is_none(), "{:?}", resp2.error);
    assert_eq!(resp2.tokens, want, "queued migration re-runs from the prompt");
    let streamed: Vec<usize> = s2.try_iter().collect();
    assert_eq!(streamed, want, "a re-run streams every token");
    let snap2 = coord2.shutdown();
    assert_drained_clean(name, &snap2);
}
