//! Integration: the serving coordinator under load, mixed policies,
//! KV-budget admission, and failure handling.

use std::sync::Arc;

use cskv::compress::svd_init::{init_factors, InitMethod};
use cskv::compress::{LayerFactors, ModelFactors};
use cskv::coordinator::server::{BackendFactory, Setup};
use cskv::coordinator::{Coordinator, CoordinatorConfig, RustSequenceBackend};
use cskv::data::tasks;
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, QuantMode};
use cskv::model::{engine::Engine, ModelConfig, ModelWeights};
use cskv::util::prng::Pcg64;

fn make_engine(seed: u64) -> Engine {
    Engine::new(Arc::new(ModelWeights::init(&ModelConfig::test_small(), seed)))
}

fn full_setup(seed: u64) -> Setup {
    Box::new(move || {
        let engine = make_engine(seed);
        let factory: BackendFactory = Box::new(move || {
            let c = engine.w.cfg.clone();
            Ok(Box::new(RustSequenceBackend::new(
                engine.clone(),
                Box::new(FullCache::new(c.n_layers, c.d_model)),
            )))
        });
        Ok(factory)
    })
}

fn cskv_setup(seed: u64, rank: usize) -> Setup {
    Box::new(move || {
        let engine = make_engine(seed);
        let layers = engine
            .w
            .layers
            .iter()
            .map(|lw| LayerFactors {
                k: init_factors(&lw.wk, rank, InitMethod::Svd, None, 0),
                v: init_factors(&lw.wv, rank, InitMethod::Svd, None, 0),
            })
            .collect();
        let f = Arc::new(ModelFactors {
            layers,
            provenance: format!("coord-r{rank}"),
        });
        let factory: BackendFactory = Box::new(move || {
            let c = engine.w.cfg.clone();
            Ok(Box::new(RustSequenceBackend::new(
                engine.clone(),
                Box::new(CskvCache::new(
                    Arc::clone(&f),
                    c.d_model,
                    CskvConfig {
                        window: 8,
                        quant: QuantMode::None,
                    },
                )),
            )))
        });
        Ok(factory)
    })
}

#[test]
fn many_requests_complete_in_order_of_ids() {
    let coord = Coordinator::start(full_setup(1), CoordinatorConfig::default());
    let mut rng = Pcg64::new(1);
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for _ in 0..12 {
        let s = tasks::line_retrieval(4, &mut rng);
        expected.push(s.prompt.clone());
        rxs.push(coord.submit(s.prompt, 3));
    }
    let mut ids = Vec::new();
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert_eq!(r.tokens.len(), 3);
        ids.push(r.id);
    }
    // IDs are assigned monotonically at submission.
    let mut sorted = ids.clone();
    sorted.sort_unstable();
    assert_eq!(ids, sorted);
    let snap = coord.shutdown();
    assert_eq!(snap.requests_completed, 12);
    assert!(snap.queue_wait_s.len() == 12);
}

/// The operational payoff of CSKV: under the same KV budget, the
/// compressed backend sustains strictly higher concurrency than the full
/// cache.
#[test]
fn cskv_admits_more_concurrency_under_same_budget() {
    let cfg = ModelConfig::test_small();
    // Budget: about 2.5 full-cache sequences of ~44 tokens.
    let budget = cfg.kv_bytes_full(44) * 5 / 2;
    let run = |setup: Setup| {
        let coord = Coordinator::start(
            setup,
            CoordinatorConfig {
                max_batch: 16,
                kv_budget_bytes: Some(budget),
                ..Default::default()
            },
        );
        let mut rng = Pcg64::new(2);
        let rxs: Vec<_> = (0..10)
            .map(|_| {
                let s = tasks::line_retrieval(5, &mut rng); // ctx ≈ 44
                coord.submit(s.prompt, 6)
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        coord.shutdown()
    };
    let full = run(full_setup(3));
    let cskv = run(cskv_setup(3, 4)); // rank 4 of 32 ⇒ ~8× smaller history
    assert_eq!(full.requests_completed, 10);
    assert_eq!(cskv.requests_completed, 10);
    assert!(
        cskv.active_peak > full.active_peak,
        "cskv concurrency {} should beat full {} under budget {budget}",
        cskv.active_peak,
        full.active_peak
    );
}

#[test]
fn coordinator_survives_empty_prompt() {
    // Empty prompts fail prefill; the coordinator must answer with an
    // error Response (never a dropped reply, which would hang
    // submit_wait) and keep serving subsequent requests.
    let coord = Coordinator::start(full_setup(4), CoordinatorConfig::default());
    let bad_rx = coord.submit(vec![], 3);
    let good = coord.submit_wait(vec![1, 2, 3], 3);
    assert_eq!(good.tokens.len(), 3);
    assert!(good.error.is_none());
    let bad = bad_rx.recv().expect("failed request must still be answered");
    assert!(bad.tokens.is_empty());
    assert!(bad.error.as_deref().unwrap_or("").contains("prefill failed"));
    let snap = coord.shutdown();
    assert_eq!(snap.requests_completed, 1);
    assert_eq!(snap.requests_failed, 1);
}

#[test]
fn metrics_track_latency_components() {
    let coord = Coordinator::start(full_setup(5), CoordinatorConfig { max_batch: 2, ..Default::default() });
    let mut rng = Pcg64::new(6);
    let rxs: Vec<_> = (0..6)
        .map(|_| coord.submit(tasks::line_retrieval(4, &mut rng).prompt, 4))
        .collect();
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert!(r.ttft_s >= r.queue_wait_s);
        assert!(r.total_s >= r.ttft_s * 0.5);
        assert!(r.backend.contains("rust-engine"));
    }
    let snap = coord.shutdown();
    assert!(snap.tok_latency_s.len() >= 6 * 3);
    assert!(snap.throughput_tok_s() > 0.0);
    assert!(snap.report().contains("tok/s"));
}

#[test]
fn shutdown_drains_pending_work() {
    let coord = Coordinator::start(full_setup(7), CoordinatorConfig { max_batch: 1, ..Default::default() });
    let rxs: Vec<_> = (0..4).map(|i| coord.submit(vec![1, 2 + i], 5)).collect();
    // Immediately shut down — all four must still be answered.
    let snap = coord.shutdown();
    assert_eq!(snap.requests_completed, 4);
    for rx in rxs {
        assert_eq!(rx.recv().unwrap().tokens.len(), 5);
    }
}
