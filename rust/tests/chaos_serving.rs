//! Deterministic chaos harness for the fault-hardened serving plane.
//!
//! Every test arms named fault points on a seeded
//! [`FaultInjector`] (`CSKV_CHAOS_SEED` overrides the default seed, as
//! CI does) and then proves the coordinator's failure-semantics
//! contract (see the `cskv::coordinator` module docs) under that exact
//! fault schedule:
//!
//! * **Exactly one `Response` per submit** — faulted requests answer
//!   with an error (plus any partial tokens), never a dropped channel.
//! * **No hang** — every `recv` below returns; `shutdown` drains.
//! * **No budget leak** — after drain, committed KV bytes and pager
//!   residency (warm + disk) both read zero.
//! * **Blast-radius containment** — co-scheduled sequences untouched by
//!   the fault produce token streams bit-identical to a fault-free run
//!   (the direct-engine oracle).
//!
//! Fault points exercised: `pager.write` (transient → retry; persistent
//! → degrade-to-warm), `pager.read` (transient → prefetch falls back to
//! a successful synchronous restore; persistent → one failed restore),
//! `snapshot.corrupt` (CRC-32 rejection), and `backend.build` (one
//! failed admission). Deadline expiry, mid-decode cancellation,
//! submit-time validation, and warm-tier pressure (budget exceeded with
//! no disk to spill to — admission must keep making progress) round out
//! the lifecycle.

use std::sync::Arc;
use std::time::{Duration, Instant};

use cskv::coordinator::server::{BackendFactory, Setup};
use cskv::coordinator::{Coordinator, CoordinatorConfig, MetricsSnapshot, RustSequenceBackend, SchedulerKind};
use cskv::kvcache::FullCache;
use cskv::model::{engine::Engine, ModelConfig, ModelWeights};
use cskv::util::faults::{FaultInjector, FaultMode};

/// Fault schedule seed — fixed default, overridable so CI can pin (or
/// sweep) the schedule explicitly.
fn chaos_seed() -> u64 {
    std::env::var("CSKV_CHAOS_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC5CA05)
}

fn make_engine(seed: u64) -> Engine {
    Engine::new(Arc::new(ModelWeights::init(&ModelConfig::test_small(), seed)))
}

fn full_setup(seed: u64) -> Setup {
    Box::new(move || {
        let engine = make_engine(seed);
        let factory: BackendFactory = Box::new(move || {
            let c = engine.w.cfg.clone();
            Ok(Box::new(RustSequenceBackend::new(
                engine.clone(),
                Box::new(FullCache::new(c.n_layers, c.d_model)),
            )))
        });
        Ok(factory)
    })
}

/// A setup that blocks inside the worker until `gate` fires, so a whole
/// workload can be queued before the first scheduling round.
fn gated_setup(seed: u64, gate: std::sync::mpsc::Receiver<()>) -> Setup {
    Box::new(move || {
        let _ = gate.recv();
        let engine = make_engine(seed);
        let factory: BackendFactory = Box::new(move || {
            let c = engine.w.cfg.clone();
            Ok(Box::new(RustSequenceBackend::new(
                engine.clone(),
                Box::new(FullCache::new(c.n_layers, c.d_model)),
            )))
        });
        Ok(factory)
    })
}

/// Direct-engine oracle for a full-cache generation.
fn oracle(seed: u64, prompt: &[usize], n_new: usize) -> Vec<usize> {
    let engine = make_engine(seed);
    let cfg = engine.w.cfg.clone();
    let mut cache = FullCache::new(cfg.n_layers, cfg.d_model);
    engine.generate(prompt, n_new, &mut cache).0
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed().as_secs() < 30, "timed out waiting for {what}");
        std::thread::yield_now();
    }
}

/// The no-leak invariant: a drained plane holds zero committed KV bytes
/// and an empty pager (both tiers).
fn assert_drained(snap: &MetricsSnapshot) {
    assert_eq!(snap.kv_bytes_current, 0, "committed KV must refund to zero after drain");
    assert_eq!(snap.cold_bytes_current, 0, "pager must be empty after drain");
}

/// The proven preemption geometry (same as the scheduler tests): a long
/// generation whose projection fills the whole budget, so admitting the
/// short request requires swapping the long one out.
const LONG_PROMPT: [usize; 6] = [1, 7, 9, 2, 30, 41];
const SHORT_PROMPT: [usize; 3] = [3, 5, 8];

fn preemptive_cfg(budget_tokens: usize, faults: FaultInjector, dir: Option<std::path::PathBuf>) -> CoordinatorConfig {
    CoordinatorConfig {
        max_batch: 4,
        kv_budget_bytes: Some(ModelConfig::test_small().kv_bytes_full(budget_tokens)),
        scheduler: SchedulerKind::Preemptive,
        disk_dir: dir,
        faults,
        ..Default::default()
    }
}

fn tmp(label: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cskv-chaos-{label}-{}", std::process::id()))
}

/// A transient spill-write fault (fails the 1st attempt only) is
/// absorbed by the retry: both streams bit-identical, nothing degraded,
/// the retry visible in the health counters.
#[test]
fn transient_spill_write_fault_is_retried_and_invisible() {
    let (long_n, short_n) = (120usize, 2usize);
    let want_long = oracle(5, &LONG_PROMPT, long_n);
    let want_short = oracle(5, &SHORT_PROMPT, short_n);
    let dir = tmp("wretry");
    let _ = std::fs::remove_dir_all(&dir);

    let faults = FaultInjector::seeded(chaos_seed());
    faults.arm("pager.write", FaultMode::Nth(1));
    let coord = Coordinator::start(full_setup(5), preemptive_cfg(128, faults, Some(dir.clone())));
    let long_rx = coord.submit(LONG_PROMPT.to_vec(), long_n);
    wait_until("long request hot", || coord.metrics().kv_bytes_current() > 0);
    let short = coord.submit_wait(SHORT_PROMPT.to_vec(), short_n);
    assert!(short.error.is_none(), "{:?}", short.error);
    assert_eq!(short.tokens, want_short);
    let long = long_rx.recv().unwrap();
    assert!(long.error.is_none(), "{:?}", long.error);
    assert_eq!(long.tokens, want_long, "retried spill must restore bit-identically");

    let snap = coord.shutdown();
    assert_eq!(snap.requests_completed, 2);
    assert_eq!(snap.requests_failed, 0);
    assert!(snap.preemptions >= 1);
    assert!(snap.pager.spill_retries >= 1, "the injected write fault was retried");
    assert!(!snap.pager.degraded, "one transient fault must not degrade the tier");
    assert_drained(&snap);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A persistently failing spill disk degrades the tier to memory —
/// every preemption still succeeds, every stream stays bit-identical,
/// and the degradation is observable in the metrics.
#[test]
fn persistent_spill_faults_degrade_tier_without_losing_requests() {
    // Long enough that the long sequence is still mid-decode across two
    // preemption windows.
    let (long_n, short_n) = (1200usize, 2usize);
    let want_long = oracle(6, &LONG_PROMPT, long_n);
    let want_short = oracle(6, &SHORT_PROMPT, short_n);
    let dir = tmp("wdegrade");
    let _ = std::fs::remove_dir_all(&dir);

    let faults = FaultInjector::seeded(chaos_seed() ^ 1);
    faults.arm("pager.write", FaultMode::FromNth(1));
    // Budget fits the long projection (1206 tokens) but not long + short.
    let coord = Coordinator::start(full_setup(6), preemptive_cfg(1206, faults, Some(dir.clone())));
    let long_rx = coord.submit(LONG_PROMPT.to_vec(), long_n);
    wait_until("long request hot", || coord.metrics().kv_bytes_current() > 0);
    // First preemption: the spill write exhausts its retries, the blob
    // stays in memory, the preemption succeeds anyway.
    let s1 = coord.submit_wait(SHORT_PROMPT.to_vec(), short_n);
    assert!(s1.error.is_none(), "{:?}", s1.error);
    assert_eq!(s1.tokens, want_short);
    // Wait for the long sequence to be restored and hot again, then
    // trigger the second preemption — the failure streak degrades the
    // tier to memory for all subsequent blobs.
    wait_until("long request restored", || {
        let m = coord.metrics();
        m.cold_bytes_current() == 0 && m.kv_bytes_current() > 0
    });
    let s2 = coord.submit_wait(SHORT_PROMPT.to_vec(), short_n);
    assert!(s2.error.is_none(), "{:?}", s2.error);
    assert_eq!(s2.tokens, want_short);
    let long = long_rx.recv().unwrap();
    assert!(long.error.is_none(), "{:?}", long.error);
    assert_eq!(long.tokens, want_long, "memory-fallback blobs must restore bit-identically");

    let snap = coord.shutdown();
    assert_eq!(snap.requests_completed, 3);
    assert_eq!(snap.requests_failed, 0, "a failing disk must not fail any request");
    assert!(snap.preemptions >= 2, "got {} preemptions", snap.preemptions);
    assert_eq!(snap.restores, snap.preemptions);
    assert!(snap.pager.spill_retries >= 4);
    assert!(snap.pager.degraded, "persistent write faults must degrade the tier");
    assert_drained(&snap);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A persistently unreadable spill blob fails exactly the sequence that
/// owned it — partial tokens + error, one Response — while the
/// co-scheduled short request stays bit-identical and the plane drains.
#[test]
fn unreadable_cold_blob_fails_only_its_own_sequence() {
    let (long_n, short_n) = (120usize, 2usize);
    let want_long = oracle(7, &LONG_PROMPT, long_n);
    let want_short = oracle(7, &SHORT_PROMPT, short_n);
    let dir = tmp("rfail");
    let _ = std::fs::remove_dir_all(&dir);

    let faults = FaultInjector::seeded(chaos_seed() ^ 2);
    faults.arm("pager.read", FaultMode::FromNth(1));
    let coord = Coordinator::start(full_setup(7), preemptive_cfg(128, faults.clone(), Some(dir.clone())));
    let long_rx = coord.submit(LONG_PROMPT.to_vec(), long_n);
    wait_until("long request hot", || coord.metrics().kv_bytes_current() > 0);
    let short = coord.submit_wait(SHORT_PROMPT.to_vec(), short_n);
    assert!(short.error.is_none(), "{:?}", short.error);
    assert_eq!(short.tokens, want_short, "unaffected sequence must be bit-identical");

    let long = long_rx.recv().expect("failed restore must still answer");
    let err = long.error.as_deref().expect("unreadable blob must surface as an error");
    assert!(err.contains("injected fault"), "error must carry the root cause: {err}");
    assert!(!long.tokens.is_empty(), "partial pre-preemption tokens are returned");
    assert!(long.tokens.len() < long_n);
    assert_eq!(long.tokens[..], want_long[..long.tokens.len()], "partial stream is a prefix");
    assert!(long_rx.recv().is_err(), "exactly one Response per request");

    let snap = coord.shutdown();
    assert_eq!(snap.requests_completed, 1);
    assert_eq!(snap.requests_failed, 1);
    assert!(snap.pager.read_retries >= 3, "all read attempts were retried");
    assert_drained(&snap);
    assert!(faults.trips("pager.read") >= 3);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A corrupted snapshot blob is rejected by the CRC-32 footer at
/// restore: that sequence fails cleanly (never a truncated cache), the
/// corruption is counted, and the rest of the round is untouched.
#[test]
fn corrupt_snapshot_is_rejected_by_checksum_not_decoded() {
    let (long_n, short_n) = (120usize, 2usize);
    let want_long = oracle(8, &LONG_PROMPT, long_n);
    let want_short = oracle(8, &SHORT_PROMPT, short_n);

    let faults = FaultInjector::seeded(chaos_seed() ^ 3);
    faults.arm("snapshot.corrupt", FaultMode::Nth(1));
    // In-memory tier: corruption is injected between the store and the
    // decoder, so the CRC must catch it with no disk involved at all.
    let coord = Coordinator::start(full_setup(8), preemptive_cfg(128, faults, None));
    let long_rx = coord.submit(LONG_PROMPT.to_vec(), long_n);
    wait_until("long request hot", || coord.metrics().kv_bytes_current() > 0);
    let short = coord.submit_wait(SHORT_PROMPT.to_vec(), short_n);
    assert!(short.error.is_none(), "{:?}", short.error);
    assert_eq!(short.tokens, want_short, "unaffected sequence must be bit-identical");

    let long = long_rx.recv().expect("corrupt restore must still answer");
    let err = long.error.as_deref().expect("corruption must surface as an error");
    assert!(err.contains("corrupt"), "error names the corruption: {err}");
    assert_eq!(long.tokens[..], want_long[..long.tokens.len()], "partial stream is a prefix");
    assert!(long_rx.recv().is_err(), "exactly one Response per request");

    let snap = coord.shutdown();
    assert_eq!(snap.requests_completed, 1);
    assert_eq!(snap.requests_failed, 1);
    assert_eq!(snap.pager.corrupt_restores, 1);
    assert_drained(&snap);
}

/// A backend-construction fault fails exactly one admission; the other
/// queued requests are served bit-identically to the fault-free oracle.
#[test]
fn backend_build_fault_fails_one_admission_only() {
    let n_new = 4usize;
    let prompts: Vec<Vec<usize>> = (0..3).map(|i| vec![1, 2 + i, 3, 4]).collect();
    let oracles: Vec<Vec<usize>> = prompts.iter().map(|p| oracle(9, p, n_new)).collect();

    let faults = FaultInjector::seeded(chaos_seed() ^ 4);
    faults.arm("backend.build", FaultMode::Nth(1));
    let (gate_tx, gate_rx) = std::sync::mpsc::channel();
    let coord = Coordinator::start(
        gated_setup(9, gate_rx),
        CoordinatorConfig { faults: faults.clone(), ..Default::default() },
    );
    let rxs: Vec<_> = prompts.iter().map(|p| coord.submit(p.clone(), n_new)).collect();
    gate_tx.send(()).unwrap(); // whole queue visible at the first round
    let mut failed = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("every request must be answered");
        match resp.error {
            Some(e) => {
                assert!(e.contains("injected fault"), "{e}");
                assert!(resp.tokens.is_empty());
                failed += 1;
            }
            None => assert_eq!(resp.tokens, oracles[i], "survivor {i} must be bit-identical"),
        }
    }
    assert_eq!(failed, 1, "Nth(1) fails exactly one construction");
    assert_eq!(faults.trips("backend.build"), 1);
    let snap = coord.shutdown();
    assert_eq!(snap.requests_completed, 2);
    assert_eq!(snap.requests_failed, 1);
    assert_drained(&snap);
}

/// Mid-decode cancellation: the client flips the token, the worker cuts
/// the sequence at the next round boundary and returns the partial
/// stream — a strict prefix of the uncancelled oracle — with reason
/// `"cancelled"`, and the KV budget is refunded.
#[test]
fn mid_decode_cancellation_returns_partial_prefix() {
    let prompt = vec![1usize, 2, 3, 4];
    let n_new = 1200usize;
    let want = oracle(10, &prompt, n_new);

    let coord = Coordinator::start(full_setup(10), CoordinatorConfig::default());
    let handle = coord.submit_with(prompt, n_new, None);
    wait_until("request hot", || coord.metrics().kv_bytes_current() > 0);
    handle.cancel.cancel();
    let resp = handle.rx.recv().expect("cancelled request must still answer");
    assert_eq!(resp.error.as_deref(), Some("cancelled"));
    assert!(!resp.tokens.is_empty(), "prefill token precedes the cancellation");
    assert!(resp.tokens.len() < n_new, "cancellation must cut the stream short");
    assert_eq!(resp.tokens[..], want[..resp.tokens.len()], "partial stream is a prefix");
    assert!(handle.rx.recv().is_err(), "exactly one Response per request");

    let snap = coord.shutdown();
    assert_eq!(snap.requests_cancelled, 1);
    assert_eq!(snap.requests_completed, 0);
    assert_eq!(snap.requests_failed, 0, "cancellation is not a failure");
    assert_eq!(snap.cancelled_s.len(), 1);
    assert_drained(&snap);
}

/// A queued request whose deadline passes before the scheduler ever
/// runs is rejected without admission — empty tokens, zero TTFT,
/// `"deadline exceeded"` — while a co-queued request without a deadline
/// is served bit-identically.
#[test]
fn expired_queued_request_is_rejected_without_admission() {
    let live_prompt = vec![5usize, 6, 7, 8];
    let n_new = 4usize;
    let want_live = oracle(11, &live_prompt, n_new);

    let (gate_tx, gate_rx) = std::sync::mpsc::channel();
    let coord = Coordinator::start(gated_setup(11, gate_rx), CoordinatorConfig::default());
    let doomed = coord.submit_with(vec![1, 2, 3], n_new, Some(Duration::from_millis(1)));
    let live_rx = coord.submit(live_prompt, n_new);
    // Let the deadline lapse while the worker is still gated, then open
    // the gate: the first round must reap before it admits.
    std::thread::sleep(Duration::from_millis(20));
    gate_tx.send(()).unwrap();

    let resp = doomed.rx.recv().expect("expired request must still answer");
    assert_eq!(resp.error.as_deref(), Some("deadline exceeded"));
    assert!(resp.tokens.is_empty(), "never admitted, no tokens");
    assert_eq!(resp.ttft_s, 0.0, "no prefill ever ran");
    assert!(doomed.rx.recv().is_err(), "exactly one Response per request");
    let live = live_rx.recv().unwrap();
    assert!(live.error.is_none(), "{:?}", live.error);
    assert_eq!(live.tokens, want_live, "undeadlined request must be untouched");

    let snap = coord.shutdown();
    assert_eq!(snap.requests_expired, 1);
    assert_eq!(snap.requests_completed, 1);
    assert_eq!(snap.requests_failed, 0, "expiry is not a failure");
    assert_drained(&snap);
}

/// The config-wide `request_timeout` gives every request a default
/// deadline: an in-flight sequence past it retires early with its
/// partial stream and releases its KV state.
#[test]
fn config_request_timeout_retires_in_flight_sequence_early() {
    let prompt = vec![9usize, 8, 7, 6];
    let n_new = 5000usize; // far more decode rounds than the timeout allows
    let want = oracle(12, &prompt, n_new);

    let coord = Coordinator::start(
        full_setup(12),
        CoordinatorConfig {
            request_timeout: Some(Duration::from_millis(40)),
            ..Default::default()
        },
    );
    let resp = coord.submit_wait(prompt, n_new);
    assert_eq!(resp.error.as_deref(), Some("deadline exceeded"));
    assert!(!resp.tokens.is_empty(), "admitted and decoding before the deadline");
    assert!(resp.tokens.len() < n_new, "the deadline must cut the stream short");
    assert_eq!(resp.tokens[..], want[..resp.tokens.len()], "partial stream is a prefix");

    let snap = coord.shutdown();
    assert_eq!(snap.requests_expired, 1);
    assert_eq!(snap.expired_s.len(), 1);
    assert_eq!(snap.requests_completed, 0);
    assert_drained(&snap);
}

/// Submit-time validation: an empty prompt or a zero token budget is
/// answered immediately (the worker never sees it), and the coordinator
/// keeps serving valid requests afterwards.
#[test]
fn invalid_submits_get_immediate_error_responses() {
    let valid_prompt = vec![1usize, 2, 3];
    let n_new = 3usize;
    let want = oracle(13, &valid_prompt, n_new);

    let coord = Coordinator::start(full_setup(13), CoordinatorConfig::default());
    let empty = coord.submit(vec![], n_new).recv().expect("validation must answer");
    assert_eq!(empty.error.as_deref(), Some("empty prompt"));
    assert!(empty.tokens.is_empty());
    let zero = coord.submit(valid_prompt.clone(), 0).recv().expect("validation must answer");
    assert_eq!(zero.error.as_deref(), Some("n_new must be at least 1"));
    assert!(zero.tokens.is_empty());

    let ok = coord.submit_wait(valid_prompt, n_new);
    assert!(ok.error.is_none(), "{:?}", ok.error);
    assert_eq!(ok.tokens, want, "valid traffic unaffected by rejected submits");

    let snap = coord.shutdown();
    assert_eq!(snap.requests_failed, 2);
    assert_eq!(snap.requests_completed, 1);
    assert_drained(&snap);
}

/// A transient `pager.read` fault hits the overlapped prefetch (or the
/// first synchronous attempt — whichever the schedule reaches first):
/// the restore degrades to a successful synchronous re-read, both
/// streams stay bit-identical to the fault-free oracle, and no request
/// fails.
#[test]
fn prefetch_read_fault_degrades_to_synchronous_restore() {
    let (long_n, short_n) = (120usize, 2usize);
    let want_long = oracle(14, &LONG_PROMPT, long_n);
    let want_short = oracle(14, &SHORT_PROMPT, short_n);
    let dir = tmp("pfdegrade");
    let _ = std::fs::remove_dir_all(&dir);

    let faults = FaultInjector::seeded(chaos_seed() ^ 5);
    faults.arm("pager.read", FaultMode::Nth(1));
    let coord = Coordinator::start(full_setup(14), preemptive_cfg(128, faults.clone(), Some(dir.clone())));
    let long_rx = coord.submit(LONG_PROMPT.to_vec(), long_n);
    wait_until("long request hot", || coord.metrics().kv_bytes_current() > 0);
    let short = coord.submit_wait(SHORT_PROMPT.to_vec(), short_n);
    assert!(short.error.is_none(), "{:?}", short.error);
    assert_eq!(short.tokens, want_short, "co-scheduled stream must be untouched");
    let long = long_rx.recv().unwrap();
    assert!(
        long.error.is_none(),
        "a transient read fault must degrade to a sync restore, not fail: {:?}",
        long.error
    );
    assert_eq!(long.tokens, want_long, "degraded restore must stay bit-identical");

    let snap = coord.shutdown();
    assert_eq!(snap.requests_completed, 2);
    assert_eq!(snap.requests_failed, 0);
    assert!(snap.preemptions >= 1);
    assert_eq!(snap.restores, snap.preemptions, "every swap still resumes");
    assert_eq!(faults.trips("pager.read"), 1, "exactly the armed attempt fired");
    assert!(snap.pager.read_retries >= 1, "the failed attempt is visible in health");
    assert_drained(&snap);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Persistent warm-tier pressure — a warm budget far too small for even
/// one parked sequence and *no* disk tier to spill to — must never
/// deadlock admission: the pager holds blocks warm over budget rather
/// than dropping them, every preempted sequence restores bit-identically,
/// and the plane still drains to zero.
#[test]
fn warm_tier_pressure_never_deadlocks_admission() {
    let (long_n, short_n) = (120usize, 2usize);
    let want_long = oracle(15, &LONG_PROMPT, long_n);
    let want_short = oracle(15, &SHORT_PROMPT, short_n);

    let mut cfg = preemptive_cfg(128, FaultInjector::none(), None);
    // A handful of bytes: every parked block run exceeds this.
    cfg.warm_budget_bytes = Some(64);
    let coord = Coordinator::start(full_setup(15), cfg);
    let long_rx = coord.submit(LONG_PROMPT.to_vec(), long_n);
    wait_until("long request hot", || coord.metrics().kv_bytes_current() > 0);
    // Repeated short requests keep re-triggering preemption while the
    // warm tier is permanently over budget.
    for _ in 0..3 {
        let short = coord.submit_wait(SHORT_PROMPT.to_vec(), short_n);
        assert!(short.error.is_none(), "{:?}", short.error);
        assert_eq!(short.tokens, want_short);
    }
    let long = long_rx.recv().unwrap();
    assert!(long.error.is_none(), "{:?}", long.error);
    assert_eq!(
        long.tokens, want_long,
        "over-budget warm blocks must still restore bit-identically"
    );

    let snap = coord.shutdown();
    assert_eq!(snap.requests_completed, 4);
    assert_eq!(snap.requests_failed, 0, "warm pressure must not fail requests");
    assert!(snap.preemptions >= 1);
    assert_eq!(snap.restores, snap.preemptions);
    assert!(
        snap.pager.warm_bytes_peak > 64,
        "blocks were held warm past the budget rather than dropped"
    );
    assert!(!snap.pager.degraded, "over-budget warm is pressure, not degradation");
    assert_drained(&snap);
}
