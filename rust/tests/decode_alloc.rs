//! Steady-state decode must not touch the heap.
//!
//! A counting global allocator (thread-local, so the libtest runner's own
//! threads can't pollute the count) wraps `System`. After reserving view,
//! scratch and cache capacity, `Engine::decode_step_with` is driven for a
//! run of steps and must perform **zero** allocations. Two policies are
//! held to this bar: the full cache (the original incremental-view
//! acceptance criterion) and CSKV int4, whose decode path additionally
//! exercises the zero-alloc compressed append, the scratch-buffered
//! sync migration, and the fused dequantize-GEMV attention over sealed
//! quantized view segments.
//!
//! This binary must hold only alloc-counting tests: the allocator hook is
//! process-global (counting itself is per-thread, and each test resets
//! its own counter, so the two tests cannot pollute each other).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use std::sync::Arc;

use cskv::compress::{LayerFactors, LowRankFactors, ModelFactors};
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, QuantMode};
use cskv::model::engine::DecodeState;
use cskv::model::{Engine, ModelConfig, ModelWeights};
use cskv::tensor::ops;
use cskv::tensor::Mat;
use cskv::util::prng::Pcg64;

thread_local! {
    static TRACKING: Cell<bool> = const { Cell::new(false) };
    static ALLOC_COUNT: Cell<usize> = const { Cell::new(0) };
}

struct CountingAlloc;

impl CountingAlloc {
    fn record() {
        // try_with: never panic inside the allocator (TLS teardown).
        let _ = TRACKING.try_with(|t| {
            if t.get() {
                let _ = ALLOC_COUNT.try_with(|c| c.set(c.get() + 1));
            }
        });
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        Self::record();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        Self::record();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        Self::record();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn full_cache_decode_steady_state_allocates_nothing() {
    let cfg = ModelConfig::test_small();
    let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 7)));
    let mut rng = Pcg64::new(11);
    let prompt: Vec<usize> = (0..48).map(|_| rng.range(5, 200)).collect();
    let n_steps = 24usize;

    let mut policy = FullCache::new(cfg.n_layers, cfg.d_model);
    let _ = engine.prefill(&prompt, Some(&mut policy));

    let mut state = DecodeState::new(&cfg);
    let total = prompt.len() + n_steps + 4;
    state.reserve(total);
    policy.reserve(n_steps + 4);

    // Warm-up steps: build the view (within reserved capacity) and settle
    // any lazy one-time work.
    let mut tok = 42usize;
    for i in 0..4 {
        let logits = engine.decode_step_with(&mut policy, tok, prompt.len() + i, &mut state);
        tok = ops::argmax(logits);
    }

    // Measured steady state: every decode step must be alloc-free.
    ALLOC_COUNT.with(|c| c.set(0));
    TRACKING.with(|t| t.set(true));
    for i in 4..n_steps {
        let logits = engine.decode_step_with(&mut policy, tok, prompt.len() + i, &mut state);
        tok = ops::argmax(logits);
    }
    TRACKING.with(|t| t.set(false));
    let allocs = ALLOC_COUNT.with(|c| c.get());

    assert_eq!(
        allocs, 0,
        "decode_step_with allocated {allocs} times over {} steady-state steps",
        n_steps - 4
    );
    // Sanity: the run actually decoded into the persistent view.
    assert_eq!(state.view(0).len(), prompt.len() + n_steps);
    assert_eq!(policy.len(0), prompt.len() + n_steps);
}

/// Low-rank factors matching the `test_small` engine geometry.
fn engine_factors(rank: usize) -> Arc<ModelFactors> {
    let d = ModelConfig::test_small().d_model;
    let mut rng = Pcg64::new(rank as u64 * 31 + 9);
    let mut mk = move || {
        LowRankFactors::new(
            Mat::randn(d, rank, 0.2, &mut rng),
            Mat::randn(rank, d, 0.2, &mut rng),
        )
    };
    Arc::new(ModelFactors {
        layers: (0..2).map(|_| LayerFactors { k: mk(), v: mk() }).collect(),
        provenance: "alloc-test".into(),
    })
}

/// The CSKV int4 fused decode path must be just as alloc-free as the full
/// cache: compressed append into the policy's scratch row, sync-time
/// history migration through the grow-only `SyncScratch`, and attention
/// scored straight off the view's packed int4 segments. The geometry is
/// chosen so the measured window crosses no policy seal (prompt 100,
/// residual stays below a group) while the view already carries two
/// sealed quantized groups — the fused kernels run every step.
#[test]
fn cskv_int4_fused_decode_steady_state_allocates_nothing() {
    let cfg = ModelConfig::test_small();
    let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 7)));
    let mut rng = Pcg64::new(13);
    let prompt: Vec<usize> = (0..100).map(|_| rng.range(5, 200)).collect();
    let n_steps = 24usize;

    let mut policy = CskvCache::new(
        engine_factors(8),
        cfg.d_model,
        CskvConfig { window: 32, quant: QuantMode::Int4 },
    );
    let _ = engine.prefill(&prompt, Some(&mut policy));

    let mut state = DecodeState::new(&cfg);
    let total = prompt.len() + n_steps + 4;
    state.reserve(total);
    policy.reserve(n_steps + 4);

    // Warm-up: first post-prefill sync seals the view's quantized groups
    // and sets the sync scratch high-water marks.
    let mut tok = 42usize;
    for i in 0..4 {
        let logits = engine.decode_step_with(&mut policy, tok, prompt.len() + i, &mut state);
        tok = ops::argmax(logits);
    }
    assert!(
        state.view(0).quant_rows() > 0,
        "geometry bug: the measured steps would never touch the fused int4 path"
    );

    ALLOC_COUNT.with(|c| c.set(0));
    TRACKING.with(|t| t.set(true));
    for i in 4..n_steps {
        let logits = engine.decode_step_with(&mut policy, tok, prompt.len() + i, &mut state);
        tok = ops::argmax(logits);
    }
    TRACKING.with(|t| t.set(false));
    let allocs = ALLOC_COUNT.with(|c| c.get());

    assert_eq!(
        allocs, 0,
        "int4 decode_step_with allocated {allocs} times over {} steady-state steps",
        n_steps - 4
    );
    assert_eq!(state.view(0).len(), prompt.len() + n_steps);
    assert_eq!(policy.len(0), prompt.len() + n_steps);
}
