//! Steady-state decode must not touch the heap (full-cache policy).
//!
//! A counting global allocator (thread-local, so the libtest runner's own
//! threads can't pollute the count) wraps `System`. After reserving view,
//! scratch and cache capacity, `Engine::decode_step_with` is driven for a
//! run of steps and must perform **zero** allocations — the acceptance
//! criterion for the incremental-view refactor's alloc-free hot path.
//!
//! This file must stay a single-test binary: the allocator hooks are
//! process-global even though counting is per-thread.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use std::sync::Arc;

use cskv::kvcache::{FullCache, KvCachePolicy};
use cskv::model::engine::DecodeState;
use cskv::model::{Engine, ModelConfig, ModelWeights};
use cskv::tensor::ops;
use cskv::util::prng::Pcg64;

thread_local! {
    static TRACKING: Cell<bool> = const { Cell::new(false) };
    static ALLOC_COUNT: Cell<usize> = const { Cell::new(0) };
}

struct CountingAlloc;

impl CountingAlloc {
    fn record() {
        // try_with: never panic inside the allocator (TLS teardown).
        let _ = TRACKING.try_with(|t| {
            if t.get() {
                let _ = ALLOC_COUNT.try_with(|c| c.set(c.get() + 1));
            }
        });
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        Self::record();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        Self::record();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        Self::record();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn full_cache_decode_steady_state_allocates_nothing() {
    let cfg = ModelConfig::test_small();
    let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 7)));
    let mut rng = Pcg64::new(11);
    let prompt: Vec<usize> = (0..48).map(|_| rng.range(5, 200)).collect();
    let n_steps = 24usize;

    let mut policy = FullCache::new(cfg.n_layers, cfg.d_model);
    let _ = engine.prefill(&prompt, Some(&mut policy));

    let mut state = DecodeState::new(&cfg);
    let total = prompt.len() + n_steps + 4;
    state.reserve(total);
    policy.reserve(n_steps + 4);

    // Warm-up steps: build the view (within reserved capacity) and settle
    // any lazy one-time work.
    let mut tok = 42usize;
    for i in 0..4 {
        let logits = engine.decode_step_with(&mut policy, tok, prompt.len() + i, &mut state);
        tok = ops::argmax(logits);
    }

    // Measured steady state: every decode step must be alloc-free.
    ALLOC_COUNT.with(|c| c.set(0));
    TRACKING.with(|t| t.set(true));
    for i in 4..n_steps {
        let logits = engine.decode_step_with(&mut policy, tok, prompt.len() + i, &mut state);
        tok = ops::argmax(logits);
    }
    TRACKING.with(|t| t.set(false));
    let allocs = ALLOC_COUNT.with(|c| c.get());

    assert_eq!(
        allocs, 0,
        "decode_step_with allocated {allocs} times over {} steady-state steps",
        n_steps - 4
    );
    // Sanity: the run actually decoded into the persistent view.
    assert_eq!(state.view(0).len(), prompt.len() + n_steps);
    assert_eq!(policy.len(0), prompt.len() + n_steps);
}
