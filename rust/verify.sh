#!/usr/bin/env bash
# Tier-1 verification + perf smoke for the Rust crate.
#
#   ./rust/verify.sh          # build, test, lint, bench smoke
#   ./rust/verify.sh --quick  # build + test only
#
# Run from anywhere; resolves the workspace root (where Cargo.toml lives).
set -euo pipefail

cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo test -q --no-default-features   (scalar fallback)"
cargo test -q --no-default-features

if [[ "${1:-}" == "--quick" ]]; then
    echo "==> quick mode: skipping clippy + bench smoke"
    exit 0
fi

echo "==> cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo clippy --all-targets --no-default-features -- -D warnings"
cargo clippy --all-targets --no-default-features -- -D warnings

echo "==> cargo bench --bench bench_perf_decode -- --fast   (smoke)"
cargo bench --bench bench_perf_decode -- --fast

echo "verify: OK"
