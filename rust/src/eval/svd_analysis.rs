//! Figure 3: singular-value distribution of the key cache.
//!
//! The paper visualizes the singular values of the key cache of a middle
//! layer on Pile samples, showing a long-tailed distribution (most
//! singular values ≈ 0) that motivates channel shrinking. We reproduce
//! the analysis on TinyLM's key cache over calibration documents, plus
//! the abstract's MMLU-style check: zeroing the smallest 50% of singular
//! values barely changes the cache.

use crate::model::engine::Engine;
use crate::tensor::svd;
use crate::tensor::Mat;

/// Singular-value analysis of one layer's key cache.
#[derive(Clone, Debug)]
pub struct SvdReport {
    pub layer: usize,
    /// Sorted (descending) singular values of the stacked key cache.
    pub singular_values: Vec<f32>,
    /// Fraction of Frobenius energy captured by the top k values, for
    /// k = 1..n (cumulative, in [0,1]).
    pub cum_energy: Vec<f32>,
    /// Relative reconstruction error when keeping the top half.
    pub half_rank_rel_error: f32,
}

/// Stack the key cache of `layer` over `docs` and analyze its spectrum.
pub fn analyze_key_cache(engine: &Engine, docs: &[Vec<usize>], layer: usize) -> SvdReport {
    let mut k_all = Mat::zeros(0, engine.w.cfg.d_model);
    for doc in docs {
        let rec = engine.prefill(doc, None);
        k_all = k_all.vcat(&rec.ks[layer]);
    }
    analyze_matrix(&k_all, layer)
}

/// Spectrum analysis of an arbitrary stacked cache matrix.
pub fn analyze_matrix(k_all: &Mat, layer: usize) -> SvdReport {
    let s = svd::singular_values(k_all);
    let total: f32 = s.iter().map(|x| x * x).sum();
    let mut cum = Vec::with_capacity(s.len());
    let mut acc = 0.0f32;
    for &x in &s {
        acc += x * x;
        cum.push(if total > 0.0 { acc / total } else { 0.0 });
    }
    let half = s.len() / 2;
    let half_rank_rel_error = if total > 0.0 {
        (svd::lowrank_error(&s, half).powi(2) / total).sqrt()
    } else {
        0.0
    };
    SvdReport {
        layer,
        singular_values: s,
        cum_energy: cum,
        half_rank_rel_error,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn lowrank_matrix_has_longtailed_spectrum() {
        // Planted rank-3 + noise: analysis must find ≥95% energy in top 3.
        let mut rng = Pcg64::new(1);
        let u = Mat::randn(200, 3, 1.0, &mut rng);
        let v = Mat::randn(3, 32, 1.0, &mut rng);
        let noise = Mat::randn(200, 32, 0.02, &mut rng);
        let k = u.matmul(&v).add(&noise);
        let rep = analyze_matrix(&k, 0);
        assert_eq!(rep.singular_values.len(), 32);
        assert!(rep.cum_energy[2] > 0.95, "top-3 energy {}", rep.cum_energy[2]);
        assert!(rep.half_rank_rel_error < 0.05);
        // cumulative energy is monotone and ends at 1
        for w in rep.cum_energy.windows(2) {
            assert!(w[1] >= w[0] - 1e-6);
        }
        assert!((rep.cum_energy.last().unwrap() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn fullrank_matrix_not_longtailed() {
        let mut rng = Pcg64::new(2);
        let k = Mat::randn(200, 32, 1.0, &mut rng);
        let rep = analyze_matrix(&k, 0);
        // isotropic Gaussian: top-3 energy far below 95%
        assert!(rep.cum_energy[2] < 0.5);
        assert!(rep.half_rank_rel_error > 0.3);
    }
}
