//! Shared experiment plumbing for the paper-table benches.
//!
//! Provides the trained-model environment, disk-cached factor
//! construction (so the table benches don't re-fine-tune identical
//! configurations), and uniform policy factories for every method
//! compared in the paper.

use std::sync::Arc;

use crate::baselines::{AsvdCache, H2oCache, StreamingLlmCache};
use crate::compress::{InitMethod, KvCompressionPlan, ModelFactors};
use crate::data::corpus::{calibration_docs, CorpusConfig};
use crate::eval::harness::{EvalSet, SuiteResult};
use crate::eval::suites::Suite;
use crate::finetune::recon::QatMode;
use crate::finetune::{build_factors, FinetuneConfig};
use crate::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, QuantMode};
use crate::model::{engine::Engine, ModelWeights};
use crate::tensor::Mat;

/// Trained-model experiment environment.
pub struct Env {
    pub engine: Engine,
    /// Per-layer calibration activations (attention inputs).
    pub calib: Vec<Mat>,
    pub label: String,
}

impl Env {
    /// Load trained weights + collect calibration activations.
    pub fn load(weights_path: &std::path::Path, label: &str) -> anyhow::Result<Env> {
        let w = ModelWeights::load(weights_path).map_err(|e| {
            anyhow::anyhow!(
                "{e:#}\nhint: run `make pretrain` (or `cskv pretrain`) to produce {}",
                weights_path.display()
            )
        })?;
        let engine = Engine::new(Arc::new(w));
        let docs = calibration_docs(&CorpusConfig::default(), 24, 99);
        let calib = engine.collect_calibration(&docs, 4096, 1);
        Ok(Env {
            engine,
            calib,
            label: label.to_string(),
        })
    }

    /// The default environment (runs/tinylm.bin).
    pub fn load_default() -> anyhow::Result<Env> {
        Env::load(&crate::runs_dir().join("tinylm.bin"), "TinyLM")
    }

    /// Secondary-model environment if present (Table 1's second block).
    pub fn load_secondary() -> Option<Env> {
        let p = crate::runs_dir().join("tinylm_b.bin");
        if p.exists() {
            Env::load(&p, "TinyLM-B").ok()
        } else {
            None
        }
    }

    pub fn d_model(&self) -> usize {
        self.engine.w.cfg.d_model
    }

    pub fn n_layers(&self) -> usize {
        self.engine.w.cfg.n_layers
    }
}

/// Build (or load from `runs/`) fine-tuned factors for a configuration.
///
/// Cache key includes the env label, plan ranks, init, steps and QAT mode —
/// benches across tables share identical configurations for free.
pub fn factors_for(
    env: &Env,
    plan: KvCompressionPlan,
    init: InitMethod,
    steps: usize,
    qat: QatMode,
) -> Arc<ModelFactors> {
    let d = env.d_model();
    let tag = format!(
        "{}_rk{}_rv{}_{}_s{}_{:?}",
        env.label,
        plan.rank_k(d),
        plan.rank_v(d),
        init.name().replace(['(', ')', '=', '.'], ""),
        steps,
        qat
    );
    let path = crate::runs_dir().join(format!("factors_{tag}.bin"));
    if let Ok(f) = ModelFactors::load(&path) {
        return Arc::new(f);
    }
    let rep = build_factors(
        &env.engine.w,
        &env.calib,
        plan,
        &FinetuneConfig {
            init,
            steps,
            qat,
            ..Default::default()
        },
    );
    let _ = rep.factors.save(&path);
    Arc::new(rep.factors)
}

/// A method under comparison (one row group of Table 1).
#[derive(Clone)]
pub enum Method {
    Full,
    StreamingLlm { ratio: f64 },
    H2o { ratio: f64 },
    Asvd { factors: Arc<ModelFactors> },
    Cskv {
        factors: Arc<ModelFactors>,
        window: usize,
        quant: QuantMode,
    },
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Full => "-",
            Method::StreamingLlm { .. } => "StreamingLLM",
            Method::H2o { .. } => "H2O",
            Method::Asvd { .. } => "ASVD",
            Method::Cskv { .. } => "CSKV (Ours)",
        }
    }

    /// Per-sample policy factory for a suite with nominal context `ctx`.
    /// Token-pruning budgets follow the paper: keep `(1−ratio)·ctx` tokens.
    pub fn factory<'a>(
        &'a self,
        n_layers: usize,
        d_model: usize,
        ctx: usize,
    ) -> Box<dyn FnMut() -> Box<dyn KvCachePolicy> + 'a> {
        match self {
            Method::Full => Box::new(move || Box::new(FullCache::new(n_layers, d_model))),
            Method::StreamingLlm { ratio } => {
                let budget = (((1.0 - ratio) * ctx as f64).round() as usize).max(6);
                Box::new(move || {
                    Box::new(StreamingLlmCache::new(n_layers, d_model, 4, budget))
                })
            }
            Method::H2o { ratio } => {
                let budget = (((1.0 - ratio) * ctx as f64).round() as usize).max(6);
                Box::new(move || Box::new(H2oCache::new(n_layers, d_model, budget)))
            }
            Method::Asvd { factors } => {
                Box::new(move || Box::new(AsvdCache::new(Arc::clone(factors))))
            }
            Method::Cskv {
                factors,
                window,
                quant,
            } => Box::new(move || {
                Box::new(CskvCache::new(
                    Arc::clone(factors),
                    d_model,
                    CskvConfig {
                        window: *window,
                        quant: *quant,
                    },
                ))
            }),
        }
    }
}

/// Evaluate one (suite, method) grid cell on a shared sample set.
pub fn eval_cell(env: &Env, set: &EvalSet, suite: &Suite, method: &Method) -> SuiteResult {
    let mut factory = method.factory(env.n_layers(), env.d_model(), suite.ctx());
    set.eval(&env.engine, &mut factory)
}

/// Standard fine-tune budget used by the table benches.
pub const FT_STEPS: usize = 250;

/// Build the shared per-suite sample sets once.
pub fn build_sets(env: &Env, columns: &[(String, Suite)], n: usize, seed: u64) -> Vec<EvalSet> {
    columns
        .iter()
        .map(|(_, s)| EvalSet::build(&env.engine, s.sample_set(n, seed)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn fake_env() -> Env {
        let w = ModelWeights::init(&ModelConfig::test_small(), 33);
        let engine = Engine::new(Arc::new(w));
        let docs = calibration_docs(
            &CorpusConfig {
                seq_len: 64,
                ..Default::default()
            },
            3,
            1,
        );
        let calib = engine.collect_calibration(&docs, 256, 1);
        Env {
            engine,
            calib,
            label: "test".into(),
        }
    }

    #[test]
    fn factors_cache_roundtrip() {
        let env = fake_env();
        let plan = KvCompressionPlan::uniform(0.5);
        let a = factors_for(&env, plan, InitMethod::Svd, 5, QatMode::Off);
        let b = factors_for(&env, plan, InitMethod::Svd, 5, QatMode::Off);
        assert_eq!(a.layers.len(), b.layers.len());
        assert_eq!(a.rank_k(), b.rank_k());
        // Second call must come from disk (identical values).
        assert_eq!(a.layers[0].k.a, b.layers[0].k.a);
    }

    #[test]
    fn all_methods_produce_policies() {
        let env = fake_env();
        let plan = KvCompressionPlan::uniform(0.5);
        let f = factors_for(&env, plan, InitMethod::Svd, 0, QatMode::Off);
        let methods = [
            Method::Full,
            Method::StreamingLlm { ratio: 0.5 },
            Method::H2o { ratio: 0.5 },
            Method::Asvd {
                factors: Arc::clone(&f),
            },
            Method::Cskv {
                factors: f,
                window: 8,
                quant: QuantMode::None,
            },
        ];
        let suite = Suite::LongEval { ctx: 64 };
        let set = EvalSet::build(&env.engine, suite.sample_set(2, 4));
        for m in &methods {
            let r = eval_cell(&env, &set, &suite, m);
            assert_eq!(r.n_samples, 2);
        }
    }
}
