//! Shared-prefill evaluation harness.
//!
//! Every method answers the same questions: a suite's sample set is fixed
//! by seed, each sample's **exact prefill is computed once** and replayed
//! into every replay-safe policy (CSKV, StreamingLLM, H2O, full — their
//! prefill attention is exact, §2.1). Lossy-prefill policies (ASVD) rerun
//! the forward pass per sample. Decode always runs per policy.

use crate::data::tasks::{score_exact, TaskSample};
use crate::data::vocab;
use crate::kvcache::KvCachePolicy;
use crate::model::engine::{DecodeState, Engine, PrefillRecord, PrefillScratch};
use crate::tensor::ops;
use crate::util::stats::Samples;

/// Builds a fresh policy instance per sample.
pub type PolicyFactory<'a> = dyn FnMut() -> Box<dyn KvCachePolicy> + 'a;

/// Result of evaluating one policy on one suite.
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub policy: String,
    pub n_samples: usize,
    pub n_correct: usize,
    /// Samples whose full generation matches the uncompressed cache's
    /// (robust secondary metric, independent of base-model quality).
    pub n_agree_full: usize,
    /// Mean KV bytes at the end of generation.
    pub mean_kv_bytes: f64,
    /// Decode latency samples (seconds per generated token).
    pub decode_tok_s: Samples,
}

impl SuiteResult {
    pub fn accuracy(&self) -> f64 {
        if self.n_samples == 0 {
            0.0
        } else {
            self.n_correct as f64 / self.n_samples as f64
        }
    }

    /// Agreement with the uncompressed cache's generations.
    pub fn agreement(&self) -> f64 {
        if self.n_samples == 0 {
            0.0
        } else {
            self.n_agree_full as f64 / self.n_samples as f64
        }
    }
}

/// A fixed sample set with cached exact prefills and the reference
/// (full-cache) generations.
pub struct EvalSet {
    pub samples: Vec<TaskSample>,
    records: Vec<PrefillRecord>,
    /// Full-cache generations (the agreement reference).
    reference: Vec<Vec<usize>>,
}

impl EvalSet {
    /// Generate `samples` and run the exact prefill once per sample.
    ///
    /// One [`PrefillScratch`] is shared across the whole set (a suite's
    /// prompts share a context length, so after the first sample every
    /// prefill runs against warm buffers), and each prefill itself
    /// parallelizes per the engine's thread knob.
    pub fn build(engine: &Engine, samples: Vec<TaskSample>) -> Self {
        let mut scratch = PrefillScratch::new();
        let records: Vec<PrefillRecord> = samples
            .iter()
            .map(|s| engine.prefill_with(&s.prompt, None, &mut scratch))
            .collect();
        let cfg = &engine.w.cfg;
        let reference = samples
            .iter()
            .zip(&records)
            .map(|(s, rec)| {
                let mut full =
                    crate::kvcache::FullCache::new(cfg.n_layers, cfg.d_model);
                replay_generate(engine, rec, s.prompt.len(), vocab::VALUE_LEN, &mut full)
            })
            .collect();
        EvalSet {
            samples,
            records,
            reference,
        }
    }

    /// Evaluate one policy across the set.
    pub fn eval(&self, engine: &Engine, factory: &mut PolicyFactory) -> SuiteResult {
        let mut n_correct = 0;
        let mut n_agree_full = 0;
        let mut kv_bytes = 0.0f64;
        let mut decode_tok_s = Samples::new();
        let mut name = String::new();
        for ((sample, rec), reference) in
            self.samples.iter().zip(&self.records).zip(&self.reference)
        {
            let mut policy = factory();
            name = policy.name();
            let n_new = vocab::VALUE_LEN;
            let generated = if policy.lossy_prefill() {
                let (generated, stats) = engine.generate(&sample.prompt, n_new, policy.as_mut());
                if stats.decode_steps > 0 {
                    decode_tok_s.push(stats.decode_s / stats.decode_steps as f64);
                }
                generated
            } else {
                let t0 = std::time::Instant::now();
                let generated = replay_generate(engine, rec, sample.prompt.len(), n_new, policy.as_mut());
                let dt = t0.elapsed().as_secs_f64();
                if n_new > 1 {
                    decode_tok_s.push(dt / (n_new - 1) as f64);
                }
                generated
            };
            kv_bytes += policy.kv_bytes() as f64;
            if score_exact(&generated, &sample.answer) {
                n_correct += 1;
            }
            if generated == *reference {
                n_agree_full += 1;
            }
        }
        SuiteResult {
            policy: name,
            n_samples: self.samples.len(),
            n_correct,
            n_agree_full,
            mean_kv_bytes: kv_bytes / self.samples.len().max(1) as f64,
            decode_tok_s,
        }
    }
}

/// Replay a cached exact prefill into a replay-safe policy, then decode.
///
/// Panics (debug) if the policy tries to substitute prefill K/V — callers
/// must route lossy-prefill policies through [`Engine::generate`].
pub fn replay_generate(
    engine: &Engine,
    rec: &PrefillRecord,
    prompt_len: usize,
    n_new: usize,
    policy: &mut dyn KvCachePolicy,
) -> Vec<usize> {
    debug_assert!(!policy.lossy_prefill());
    for li in 0..engine.w.cfg.n_layers {
        let rep = policy.ingest_prefill(li, &rec.xnorms[li], &rec.ks[li], &rec.vs[li]);
        debug_assert!(rep.is_none(), "replay requires exact-prefill policies");
        policy.observe_prefill_attn(li, &rec.attn_mass[li]);
    }
    let mut out = Vec::with_capacity(n_new);
    let mut next = ops::argmax(rec.logits.row(prompt_len - 1));
    let mut state = DecodeState::new(&engine.w.cfg);
    state.reserve(prompt_len + n_new);
    policy.reserve(n_new);
    for i in 0..n_new {
        out.push(next);
        if i + 1 == n_new {
            break;
        }
        let logits = engine.decode_step_with(policy, next, prompt_len + i, &mut state);
        next = ops::argmax(logits);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::suites::Suite;
    use crate::kvcache::FullCache;
    use crate::model::{ModelConfig, ModelWeights};
    use std::sync::Arc;

    fn tiny_engine() -> Engine {
        Engine::new(Arc::new(ModelWeights::init(&ModelConfig::test_small(), 1)))
    }

    #[test]
    fn replay_matches_direct_generation() {
        let e = tiny_engine();
        let suite = Suite::LongEval { ctx: 64 };
        let samples = suite.sample_set(3, 7);
        let set = EvalSet::build(&e, samples.clone());
        for (s, rec) in samples.iter().zip(&set.records) {
            let cfg = &e.w.cfg;
            let mut direct = FullCache::new(cfg.n_layers, cfg.d_model);
            let (g_direct, _) = e.generate(&s.prompt, 3, &mut direct);
            let mut replayed = FullCache::new(cfg.n_layers, cfg.d_model);
            let g_replay = replay_generate(&e, rec, s.prompt.len(), 3, &mut replayed);
            assert_eq!(g_direct, g_replay, "replay must be bit-identical");
        }
    }

    #[test]
    fn eval_reports_consistent_counts() {
        let e = tiny_engine();
        let suite = Suite::LongBench { ctx: 60, n_facts: 3 };
        let set = EvalSet::build(&e, suite.sample_set(4, 9));
        let cfg = e.w.cfg.clone();
        let mut factory = move || -> Box<dyn KvCachePolicy> {
            Box::new(FullCache::new(cfg.n_layers, cfg.d_model))
        };
        let r = set.eval(&e, &mut factory);
        assert_eq!(r.n_samples, 4);
        assert!(r.n_correct <= 4);
        assert!(r.mean_kv_bytes > 0.0);
        assert!((0.0..=1.0).contains(&r.accuracy()));
        assert_eq!(r.policy, "full");
    }
}
