//! Evaluation harnesses for the paper's three benchmark suites.
//!
//! * [`suites`] — the scaled task suites (LongEval lengths, LongBench
//!   buckets, LVEval) and their sample generators.
//! * [`harness`] — shared-prefill evaluation: one exact prefill per sample
//!   is replayed into every replay-safe policy (CSKV, StreamingLLM, H2O,
//!   full), while lossy-prefill policies (ASVD) rerun the forward pass.
//! * [`svd_analysis`] — Figure 3: singular-value distribution of the key
//!   cache on calibration data.

pub mod experiments;
pub mod harness;
pub mod suites;
pub mod svd_analysis;

pub use harness::{EvalSet, PolicyFactory, SuiteResult};
pub use suites::Suite;
