//! The scaled benchmark suites (DESIGN.md §2 maps them to the paper's).
//!
//! | paper | here |
//! |-------|------|
//! | LongEval 200/300/400/500 lines (≈4k/6k/8k/10k tokens) | line retrieval at ctx ≈ 128/256/384/500 |
//! | LongBench-E buckets 0-4k / 4-8k / 8k+ | multi-fact QA at ctx ≈ 150 / 300 / 470 |
//! | LVEval 16k | confusing retrieval at ctx ≈ 500 (max distance + near-miss values) |

use crate::data::tasks::{self, TaskSample};
use crate::util::prng::Pcg64;

/// One evaluation suite cell (a column of Table 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Suite {
    /// LongEval-style line retrieval at a target context length.
    LongEval { ctx: usize },
    /// LongBench-style multi-fact QA bucket.
    LongBench { ctx: usize, n_facts: usize },
    /// LVEval-style hardest bucket.
    LvEval { ctx: usize },
}

impl Suite {
    /// The Table 1 column set, scaled to TinyLM's 512 context.
    pub fn table1_columns() -> Vec<(String, Suite)> {
        vec![
            ("LongEval-4k".into(), Suite::LongEval { ctx: 128 }),
            ("LongEval-6k".into(), Suite::LongEval { ctx: 256 }),
            ("LongEval-8k".into(), Suite::LongEval { ctx: 384 }),
            ("LongEval-10k".into(), Suite::LongEval { ctx: 500 }),
            (
                "LongBench-0-4k".into(),
                Suite::LongBench {
                    ctx: 150,
                    n_facts: 5,
                },
            ),
            (
                "LongBench-4-8k".into(),
                Suite::LongBench {
                    ctx: 300,
                    n_facts: 8,
                },
            ),
            (
                "LongBench-8k+".into(),
                Suite::LongBench {
                    ctx: 470,
                    n_facts: 10,
                },
            ),
            ("LVEval-16k".into(), Suite::LvEval { ctx: 500 }),
        ]
    }

    /// The ablation suite (the paper's §C uses LongEval averages).
    pub fn ablation_columns() -> Vec<(String, Suite)> {
        vec![
            ("LongEval-4k".into(), Suite::LongEval { ctx: 128 }),
            ("LongEval-6k".into(), Suite::LongEval { ctx: 256 }),
            ("LongEval-8k".into(), Suite::LongEval { ctx: 384 }),
            ("LongEval-10k".into(), Suite::LongEval { ctx: 500 }),
        ]
    }

    pub fn ctx(&self) -> usize {
        match self {
            Suite::LongEval { ctx } | Suite::LongBench { ctx, .. } | Suite::LvEval { ctx } => *ctx,
        }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> TaskSample {
        match *self {
            Suite::LongEval { ctx } => tasks::line_retrieval_ctx(ctx, rng),
            Suite::LongBench { ctx, n_facts } => tasks::multifact_qa(ctx, n_facts, rng),
            Suite::LvEval { ctx } => tasks::confusing_retrieval(ctx, 3, rng),
        }
    }

    /// Generate a fixed sample set (shared across all policies so every method
    /// answers exactly the same questions).
    pub fn sample_set(&self, n: usize, seed: u64) -> Vec<TaskSample> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| self.sample(&mut rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_columns_cover_all_suites() {
        let cols = Suite::table1_columns();
        assert_eq!(cols.len(), 8);
        assert!(matches!(cols[0].1, Suite::LongEval { .. }));
        assert!(matches!(cols[4].1, Suite::LongBench { .. }));
        assert!(matches!(cols[7].1, Suite::LvEval { .. }));
    }

    #[test]
    fn samples_respect_ctx() {
        let mut rng = Pcg64::new(1);
        for (_, s) in Suite::table1_columns() {
            let t = s.sample(&mut rng);
            assert!(t.ctx_len <= s.ctx() + 8, "{:?}: {} vs {}", s, t.ctx_len, s.ctx());
            assert!(t.ctx_len >= s.ctx() / 2, "{:?}: {}", s, t.ctx_len);
        }
    }

    #[test]
    fn sample_set_is_deterministic() {
        let s = Suite::LongEval { ctx: 128 };
        let a = s.sample_set(5, 42);
        let b = s.sample_set(5, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
        }
    }
}
