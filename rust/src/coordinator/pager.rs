//! Attention-aware multi-tier pager for preempted sequence state.
//!
//! The memory hierarchy has three tiers:
//!
//! * **hot** — the policy's own cache (f32 / int4), budgeted by the
//!   coordinator's KV admission pre-charge (`--hot-kb`, alias of the
//!   original `--kv-budget-kb`);
//! * **warm** — this pager's RAM store of **encoded** block runs
//!   (`--warm-kb`): a preempted sequence's snapshot is split into
//!   [`SnapshotBlock`] runs (byte ranges of the canonical encoding,
//!   each framed with its own CRC-32) that park here at the snapshot's
//!   compressed size;
//! * **disk** — one file per block (`<dir>/seq-<id>.blk<index>`,
//!   `--disk-dir`), holding whatever the warm budget cannot.
//!
//! **Eviction-scoring contract.** When the warm tier is over budget the
//! pager spills the *globally lowest-scored* warm block (ties broken by
//! sequence id, then block index — deterministic across runs). A
//! block's score comes from the policy's accumulated attention mass
//! ([`crate::kvcache::KvCachePolicy::attention_profile`], H2O's
//! heavy-hitter scores) mapped onto the block's byte span: every
//! policy's payload stores each layer's rows in token order, so a
//! block's byte-offset fraction tracks its token-position fraction, and
//! the block scores the **mean mass over that token span**. Sequences
//! without a profile — and every sequence under
//! [`EvictionScoring::Age`], the A/B baseline — score by relative
//! position instead (later history hotter, StreamingLLM-style recency).
//! Scores order eviction only; they never affect restored bytes — a
//! take reassembles all runs and re-verifies the snapshot's end-to-end
//! CRC, so token streams stay bit-identical to a never-preempted run
//! regardless of where the blocks sat.
//!
//! **Prefetch/overlap.** A background restore thread reads the disk
//! blocks the scheduler expects to resume next round
//! ([`Pager::prefetch`]) into a landing zone, so the decode round hides
//! the I/O; [`Pager::take`] consumes landed blocks for free
//! (`prefetch_hits`) and falls back to a synchronous retried read for
//! anything missing or failed (`prefetch_misses`, stall time in
//! [`PagerStats::restore_stall_s`]). Prefetch performs I/O only — a
//! missed, failed, or never-issued prefetch changes latency, never
//! bytes.
//!
//! **Fault hardening** (carried over from the PR 4 cold tier, points
//! renamed `pager.write` / `pager.read`): spill writes and synchronous
//! reads retry with bounded backoff; an exhausted write keeps the block
//! warm (over budget if need be — parked state is never dropped, so
//! admission cannot deadlock on a dead disk) and a persistent streak
//! degrades the disk tier entirely; the prefetch thread does a single
//! attempt and leaves retrying to the synchronous fallback; a blob that
//! reassembles corrupt fails only that sequence's take. The
//! [`FaultInjector`] points `pager.write` / `pager.read` /
//! `snapshot.corrupt` are how `rust/tests/chaos_serving.rs` schedules
//! deterministic faults into all of this.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::kvcache::snapshot::{merge_blocks, split_blocks, SnapshotBlock};
use crate::kvcache::KvSnapshot;
use crate::util::faults::FaultInjector;

/// Attempts per spill write / synchronous read (1 initial + retries).
const IO_ATTEMPTS: u32 = 3;
/// Backoff before retry k (1-based) is `BACKOFF_BASE_MS << (k - 1)` ms.
const BACKOFF_BASE_MS: u64 = 1;
/// Consecutive exhausted-retry writes before the disk tier degrades.
const DEGRADE_STREAK: u32 = 2;
/// Default split granularity: small enough that a long sequence yields
/// tens of independently evictable runs, large enough that per-block
/// framing (20 bytes) and per-file syscalls stay noise.
pub const DEFAULT_BLOCK_BYTES: usize = 16 * 1024;
/// Upper bound on waiting for an in-flight prefetch before giving up
/// and re-reading synchronously (guards against a dead worker thread).
const PREFETCH_WAIT_CAP: Duration = Duration::from_secs(10);

/// How spill priority is computed. `Attention` is the default;
/// `Age` is the A/B baseline `bench_perf_paging` compares against.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EvictionScoring {
    /// Attention mass where the policy tracks it, position otherwise.
    #[default]
    Attention,
    /// Relative token position only (later history hotter).
    Age,
}

impl EvictionScoring {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "attention" => Ok(EvictionScoring::Attention),
            "age" => Ok(EvictionScoring::Age),
            other => anyhow::bail!("unknown eviction scoring '{other}' (attention | age)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EvictionScoring::Attention => "attention",
            EvictionScoring::Age => "age",
        }
    }
}

/// Tier shape and knobs. [`PagerConfig::default`] reproduces the PR 4
/// cold-tier behavior: no disk dir and no warm budget parks everything
/// in RAM; a disk dir with no warm budget spills everything to disk.
#[derive(Clone, Debug)]
pub struct PagerConfig {
    /// Disk tier directory (`--disk-dir`). `None` disables the disk
    /// tier; the warm budget then cannot be enforced (blocks park warm
    /// over budget rather than being dropped).
    pub disk_dir: Option<PathBuf>,
    /// Warm (RAM) tier budget in bytes (`--warm-kb`). `None` means
    /// unbounded when there is no disk tier, and **zero** when there is
    /// one — i.e. a bare `--disk-dir` spills whole sequences, exactly
    /// like the old `--cold-tier`.
    pub warm_budget_bytes: Option<usize>,
    /// Split granularity for block runs.
    pub block_bytes: usize,
    /// Spill-priority mode.
    pub scoring: EvictionScoring,
    /// Run the background prefetch thread. Off = every disk restore is
    /// synchronous (the bench's baseline).
    pub prefetch: bool,
}

impl Default for PagerConfig {
    fn default() -> Self {
        PagerConfig {
            disk_dir: None,
            warm_budget_bytes: None,
            block_bytes: DEFAULT_BLOCK_BYTES,
            scoring: EvictionScoring::Attention,
            prefetch: true,
        }
    }
}

/// Pager health counters, mirrored into [`crate::coordinator::Metrics`]
/// once per scheduling round. All values are cumulative absolutes.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PagerStats {
    /// Spill-write attempts that failed (retried, or — budget exhausted
    /// — the block stays warm).
    pub spill_retries: u64,
    /// Synchronous read attempts that failed, plus prefetch reads whose
    /// single attempt failed (observed at take time).
    pub read_retries: u64,
    /// Sequences whose reassembled snapshot failed checksum/decode —
    /// each fails exactly one sequence, never the round.
    pub corrupt_restores: u64,
    /// True once the disk tier is out of play (unusable dir at
    /// construction, or a persistent write-fault streak).
    pub degraded: bool,
    /// Block runs spilled warm → disk / promoted disk → hot, and the
    /// bytes they moved.
    pub block_spills: u64,
    pub block_promotes: u64,
    pub spill_bytes: u64,
    pub promote_bytes: u64,
    /// Disk blocks consumed from the prefetch landing zone vs. restored
    /// synchronously.
    pub prefetch_hits: u64,
    pub prefetch_misses: u64,
    /// Wall-clock the pager spent blocking takes on disk I/O (sync
    /// reads + waits for in-flight prefetches) — what prefetch exists
    /// to hide.
    pub restore_stall_s: f64,
    /// Tier occupancy high-water marks.
    pub warm_bytes_peak: usize,
    pub disk_bytes_peak: usize,
}

enum BlockLoc {
    /// At-rest encoded form ([`SnapshotBlock::encode`]) held in RAM.
    Warm(Vec<u8>),
    /// Spilled to this file.
    Disk(PathBuf),
}

struct BlockSlot {
    score: f32,
    /// At-rest encoded size (what both tiers account).
    bytes: usize,
    loc: BlockLoc,
}

struct SeqEntry {
    blocks: Vec<BlockSlot>,
}

enum Fetch {
    Pending,
    Done(Vec<u8>),
    Failed,
}

/// Shared landing zone between the prefetch thread and `take`.
struct Landing {
    slots: Mutex<HashMap<(u64, usize), Fetch>>,
    cv: Condvar,
}

enum Claim {
    Absent,
    Done(Vec<u8>),
    Failed,
}

impl Landing {
    /// Consume the landing slot for one block, waiting out an in-flight
    /// read (bounded by [`PREFETCH_WAIT_CAP`]).
    fn claim(&self, key: (u64, usize)) -> Claim {
        let mut m = self.slots.lock().unwrap();
        let deadline = Instant::now() + PREFETCH_WAIT_CAP;
        loop {
            match m.get(&key) {
                None => return Claim::Absent,
                Some(Fetch::Pending) => {
                    let left = deadline.saturating_duration_since(Instant::now());
                    if left.is_zero() {
                        m.remove(&key);
                        return Claim::Absent;
                    }
                    m = self.cv.wait_timeout(m, left).unwrap().0;
                }
                Some(Fetch::Done(_)) => match m.remove(&key) {
                    Some(Fetch::Done(data)) => return Claim::Done(data),
                    _ => unreachable!("checked above under the same lock"),
                },
                Some(Fetch::Failed) => {
                    m.remove(&key);
                    return Claim::Failed;
                }
            }
        }
    }

    /// Prefetch-thread side: deliver a result, unless the slot was
    /// already abandoned (taken or discarded meanwhile).
    fn complete(&self, key: (u64, usize), result: Result<Vec<u8>, ()>) {
        let mut m = self.slots.lock().unwrap();
        if let Some(slot) = m.get_mut(&key) {
            *slot = match result {
                Ok(data) => Fetch::Done(data),
                Err(()) => Fetch::Failed,
            };
            self.cv.notify_all();
        }
    }

    fn forget(&self, key: (u64, usize)) {
        self.slots.lock().unwrap().remove(&key);
    }
}

/// Background restore thread + its job queue and landing zone.
struct Prefetcher {
    jobs: mpsc::Sender<(u64, usize, PathBuf)>,
    landing: Arc<Landing>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Prefetcher {
    fn start(faults: FaultInjector) -> Self {
        let landing = Arc::new(Landing {
            slots: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
        });
        let (jobs, rx) = mpsc::channel::<(u64, usize, PathBuf)>();
        let zone = Arc::clone(&landing);
        let handle = std::thread::Builder::new()
            .name("cskv-pager-prefetch".into())
            .spawn(move || {
                // One attempt per block: a fault here degrades to the
                // synchronous (retried) path in `take`, never corrupts.
                for (id, index, path) in rx {
                    let read = faults
                        .trip("pager.read")
                        .and_then(|()| std::fs::read(&path).map_err(anyhow::Error::from));
                    zone.complete((id, index), read.map_err(|_| ()));
                }
            })
            .expect("spawn pager prefetch thread");
        Prefetcher {
            jobs,
            landing,
            handle: Some(handle),
        }
    }

    /// Queue one block read unless it is already in flight or landed.
    fn request(&self, key: (u64, usize), path: PathBuf) {
        let mut m = self.landing.slots.lock().unwrap();
        if m.contains_key(&key) {
            return;
        }
        m.insert(key, Fetch::Pending);
        drop(m);
        if self.jobs.send((key.0, key.1, path)).is_err() {
            self.landing.forget(key);
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Closing the channel ends the thread after the queued jobs.
        let (dead, _) = mpsc::channel();
        self.jobs = dead;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Block store for swapped-out sequence state, keyed by request id.
/// (The cross-tier high-water mark lives in
/// [`crate::coordinator::Metrics`], fed by [`Pager::bytes_resident`].)
pub struct Pager {
    dir: Option<PathBuf>,
    warm_budget: usize,
    block_bytes: usize,
    scoring: EvictionScoring,
    /// BTreeMap so eviction tie-breaks (and tests) are deterministic.
    seqs: BTreeMap<u64, SeqEntry>,
    warm_bytes: usize,
    disk_bytes: usize,
    faults: FaultInjector,
    stats: PagerStats,
    /// Consecutive block spills whose disk write exhausted its retries.
    write_fail_streak: u32,
    prefetcher: Option<Prefetcher>,
}

impl Pager {
    pub fn new(cfg: PagerConfig) -> Self {
        Pager::with_faults(cfg, FaultInjector::none())
    }

    /// [`Pager::new`] with a fault-injection registry threaded into
    /// every disk write/read (sync and prefetch) and the pre-decode
    /// corruption site.
    pub fn with_faults(cfg: PagerConfig, faults: FaultInjector) -> Self {
        let mut stats = PagerStats::default();
        let dir = cfg.disk_dir.and_then(|d| match std::fs::create_dir_all(&d) {
            Ok(()) => Some(d),
            Err(e) => {
                crate::log_error!("pager disk dir {} unusable ({e}); warm tier only", d.display());
                stats.degraded = true;
                None
            }
        });
        let warm_budget = cfg
            .warm_budget_bytes
            .unwrap_or(if dir.is_some() { 0 } else { usize::MAX });
        let prefetcher = (cfg.prefetch && dir.is_some())
            .then(|| Prefetcher::start(faults.clone()));
        Pager {
            dir,
            warm_budget,
            block_bytes: cfg.block_bytes.max(1),
            scoring: cfg.scoring,
            seqs: BTreeMap::new(),
            warm_bytes: 0,
            disk_bytes: 0,
            faults,
            stats,
            write_fail_streak: 0,
            prefetcher,
        }
    }

    fn block_path(dir: &Path, id: u64, index: usize) -> PathBuf {
        dir.join(format!("seq-{id}.blk{index}"))
    }

    /// Check up front that `dir` can hold spill files: create it and
    /// round-trip a probe file. Lets the `serve` CLI reject a bad
    /// `--disk-dir` with a clear error instead of silently degrading.
    pub fn probe_dir(dir: &Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("cannot create {}: {e}", dir.display()))?;
        let probe = dir.join(".cskv-probe");
        std::fs::write(&probe, b"probe")
            .map_err(|e| anyhow::anyhow!("cannot write to {}: {e}", dir.display()))?;
        std::fs::remove_file(&probe)
            .map_err(|e| anyhow::anyhow!("cannot clean up probe in {}: {e}", dir.display()))?;
        Ok(())
    }

    /// Map per-token attention mass onto `total` byte blocks (mean mass
    /// over each block's token span); position fallback otherwise.
    fn score_blocks(&self, total: usize, profile: Option<&[f32]>) -> Vec<f32> {
        if let (EvictionScoring::Attention, Some(mass)) = (self.scoring, profile) {
            if !mass.is_empty() {
                let t = mass.len();
                return (0..total)
                    .map(|i| {
                        let lo = i * t / total;
                        let hi = ((i + 1) * t / total).clamp(lo + 1, t);
                        mass[lo..hi].iter().sum::<f32>() / (hi - lo) as f32
                    })
                    .collect();
            }
        }
        // Later history hotter: recency, the only signal available.
        (0..total).map(|i| (i + 1) as f32 / total as f32).collect()
    }

    fn note_peaks(&mut self) {
        self.stats.warm_bytes_peak = self.stats.warm_bytes_peak.max(self.warm_bytes);
        self.stats.disk_bytes_peak = self.stats.disk_bytes_peak.max(self.disk_bytes);
    }

    /// One spill write with bounded retry/backoff. Each attempt
    /// consults the `pager.write` fault point before the filesystem.
    fn write_with_retry(&mut self, path: &Path, data: &[u8]) -> anyhow::Result<()> {
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..IO_ATTEMPTS {
            if attempt > 0 {
                std::thread::sleep(Duration::from_millis(BACKOFF_BASE_MS << (attempt - 1)));
            }
            let res = self.faults.trip("pager.write").and_then(|()| {
                std::fs::write(path, data)
                    .map_err(|e| anyhow::anyhow!("pager spill to {}: {e}", path.display()))
            });
            match res {
                Ok(()) => return Ok(()),
                Err(e) => {
                    self.stats.spill_retries += 1;
                    crate::log_warn!(
                        "pager write attempt {}/{IO_ATTEMPTS} failed: {e:#}",
                        attempt + 1
                    );
                    last = Some(e);
                }
            }
        }
        Err(last.expect("IO_ATTEMPTS > 0"))
    }

    /// One synchronous block read with bounded retry/backoff
    /// (`pager.read` fault point per attempt).
    fn read_with_retry(&mut self, path: &Path) -> anyhow::Result<Vec<u8>> {
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..IO_ATTEMPTS {
            if attempt > 0 {
                std::thread::sleep(Duration::from_millis(BACKOFF_BASE_MS << (attempt - 1)));
            }
            let res = self.faults.trip("pager.read").and_then(|()| {
                std::fs::read(path)
                    .map_err(|e| anyhow::anyhow!("pager read {}: {e}", path.display()))
            });
            match res {
                Ok(data) => return Ok(data),
                Err(e) => {
                    self.stats.read_retries += 1;
                    crate::log_warn!(
                        "pager read attempt {}/{IO_ATTEMPTS} failed: {e:#}",
                        attempt + 1
                    );
                    last = Some(e);
                }
            }
        }
        Err(last.expect("IO_ATTEMPTS > 0"))
    }

    /// Spill the globally lowest-scored warm blocks until the warm tier
    /// fits its budget. A write that exhausts its retries leaves the
    /// block warm (over budget — parked state is never dropped) and
    /// stops this pass; a persistent streak degrades the disk tier.
    fn enforce_warm_budget(&mut self) {
        while self.warm_bytes > self.warm_budget {
            let Some(dir) = self.dir.clone() else { return };
            // Globally lowest (score, id, index) among warm blocks.
            let victim = self
                .seqs
                .iter()
                .flat_map(|(&id, e)| {
                    e.blocks
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| matches!(s.loc, BlockLoc::Warm(_)))
                        .map(move |(i, s)| (s.score, id, i))
                })
                .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
            let Some((_, id, index)) = victim else { return };
            let path = Self::block_path(&dir, id, index);
            let slot = &self.seqs[&id].blocks[index];
            let (bytes, data) = match &slot.loc {
                BlockLoc::Warm(data) => (slot.bytes, data.clone()),
                BlockLoc::Disk(_) => unreachable!("victim filter keeps warm blocks only"),
            };
            match self.write_with_retry(&path, &data) {
                Ok(()) => {
                    self.write_fail_streak = 0;
                    let slot = self
                        .seqs
                        .get_mut(&id)
                        .expect("victim entry")
                        .blocks
                        .get_mut(index)
                        .expect("victim block");
                    slot.loc = BlockLoc::Disk(path);
                    self.warm_bytes -= bytes;
                    self.disk_bytes += bytes;
                    self.stats.block_spills += 1;
                    self.stats.spill_bytes += bytes as u64;
                    self.note_peaks();
                }
                Err(e) => {
                    self.write_fail_streak += 1;
                    crate::log_error!(
                        "pager spill of seq {id} block {index} failed after {IO_ATTEMPTS} \
                         attempts ({e:#}); block stays warm"
                    );
                    if self.write_fail_streak >= DEGRADE_STREAK {
                        crate::log_error!(
                            "pager disk tier degraded after {} consecutive write failures; \
                             blocks stay warm",
                            self.write_fail_streak
                        );
                        self.dir = None;
                        self.prefetcher = None;
                        self.stats.degraded = true;
                    }
                    return;
                }
            }
        }
    }

    /// Park `snap` under `id`: split into block runs, score them with
    /// `profile` (the sequence's attention mass, if its policy tracks
    /// any), land them warm, then spill down to the warm budget.
    /// Returns the parked byte size. The only error left is the
    /// double-park programming bug — I/O trouble degrades, never fails
    /// a preemption.
    pub fn put(&mut self, id: u64, snap: &KvSnapshot, profile: Option<&[f32]>) -> anyhow::Result<usize> {
        anyhow::ensure!(
            !self.seqs.contains_key(&id),
            "pager already holds sequence {id}"
        );
        let encoded = snap.encode();
        let runs = split_blocks(&encoded, self.block_bytes);
        let scores = self.score_blocks(runs.len(), profile);
        let mut total = 0usize;
        let blocks: Vec<BlockSlot> = runs
            .iter()
            .zip(&scores)
            .map(|(run, &score)| {
                let at_rest = run.encode();
                total += at_rest.len();
                BlockSlot {
                    score,
                    bytes: at_rest.len(),
                    loc: BlockLoc::Warm(at_rest),
                }
            })
            .collect();
        self.seqs.insert(id, SeqEntry { blocks });
        self.warm_bytes += total;
        self.note_peaks();
        self.enforce_warm_budget();
        Ok(total)
    }

    /// Queue background reads for these sequences' disk blocks, so a
    /// following [`Pager::take`] finds them landed. I/O only — calling
    /// this for a sequence that never resumes wastes a read, nothing
    /// more.
    pub fn prefetch(&mut self, ids: &[u64]) {
        let Some(p) = &self.prefetcher else { return };
        for &id in ids {
            let Some(entry) = self.seqs.get(&id) else { continue };
            for (index, slot) in entry.blocks.iter().enumerate() {
                if let BlockLoc::Disk(path) = &slot.loc {
                    p.request((id, index), path.clone());
                }
            }
        }
    }

    /// Fetch one disk block: landed prefetch if available, synchronous
    /// retried read otherwise. Accumulates stall time for every path
    /// that blocks the caller.
    fn fetch_disk_block(&mut self, id: u64, index: usize, path: &Path) -> anyhow::Result<Vec<u8>> {
        if let Some(p) = &self.prefetcher {
            let started = Instant::now();
            let claim = p.landing.claim((id, index));
            self.stats.restore_stall_s += started.elapsed().as_secs_f64();
            match claim {
                Claim::Done(data) => {
                    self.stats.prefetch_hits += 1;
                    return Ok(data);
                }
                Claim::Failed => {
                    // The single prefetch attempt failed; the retried
                    // synchronous path below is the degrade.
                    self.stats.read_retries += 1;
                }
                Claim::Absent => {}
            }
        }
        self.stats.prefetch_misses += 1;
        let started = Instant::now();
        let read = self.read_with_retry(path);
        self.stats.restore_stall_s += started.elapsed().as_secs_f64();
        read
    }

    /// Remove and decode the snapshot parked under `id`, promoting its
    /// disk blocks back. A read or checksum/decode failure errors for
    /// **this sequence only**: the entry, its landing slots, and every
    /// spill file are always released, so the caller can fail the one
    /// sequence and keep serving.
    pub fn take(&mut self, id: u64) -> anyhow::Result<KvSnapshot> {
        let entry = self
            .seqs
            .remove(&id)
            .ok_or_else(|| anyhow::anyhow!("pager has no sequence {id}"))?;
        let mut first_err: Option<anyhow::Error> = None;
        let mut runs: Vec<SnapshotBlock> = Vec::with_capacity(entry.blocks.len());
        for (index, slot) in entry.blocks.into_iter().enumerate() {
            let at_rest = match slot.loc {
                BlockLoc::Warm(data) => {
                    self.warm_bytes -= slot.bytes;
                    Ok(data)
                }
                BlockLoc::Disk(path) => {
                    self.disk_bytes -= slot.bytes;
                    let read = self.fetch_disk_block(id, index, &path);
                    // The entry is already gone from the index, so the
                    // spill file is deleted on *every* outcome — a
                    // failed read must not leak an orphan block file.
                    let _ = std::fs::remove_file(&path);
                    if read.is_ok() {
                        self.stats.block_promotes += 1;
                        self.stats.promote_bytes += slot.bytes as u64;
                    }
                    read
                }
            };
            match at_rest.and_then(|b| SnapshotBlock::decode(&b)) {
                Ok(run) => runs.push(run),
                Err(e) if first_err.is_none() => first_err = Some(e),
                Err(_) => {}
            }
        }
        if let Some(e) = first_err {
            return Err(e.context(format!("pager blocks for sequence {id} unreadable")));
        }
        let mut encoded = merge_blocks(&runs)
            .map_err(|e| e.context(format!("pager block set for sequence {id}")))?;
        // Chaos hook: flip a seeded byte right where real bit rot would
        // land, between the medium and the decoder.
        self.faults.corrupt("snapshot.corrupt", &mut encoded);
        match KvSnapshot::decode(&encoded) {
            Ok(snap) => Ok(snap),
            Err(e) => {
                self.stats.corrupt_restores += 1;
                Err(e.context(format!("pager blob for sequence {id} corrupt")))
            }
        }
    }

    /// Drop everything parked under `id` without decoding — how
    /// cancelled or deadline-expired sequences release their parked
    /// state immediately. Returns whether anything was held.
    pub fn discard(&mut self, id: u64) -> bool {
        match self.seqs.remove(&id) {
            Some(entry) => {
                for (index, slot) in entry.blocks.into_iter().enumerate() {
                    match slot.loc {
                        BlockLoc::Warm(_) => self.warm_bytes -= slot.bytes,
                        BlockLoc::Disk(path) => {
                            self.disk_bytes -= slot.bytes;
                            if let Some(p) = &self.prefetcher {
                                p.landing.forget((id, index));
                            }
                            let _ = std::fs::remove_file(&path);
                        }
                    }
                }
                true
            }
            None => false,
        }
    }

    /// Number of parked sequences.
    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    /// Bytes currently parked across both tiers.
    pub fn bytes_resident(&self) -> usize {
        self.warm_bytes + self.disk_bytes
    }

    /// Warm (RAM) tier occupancy.
    pub fn warm_bytes_resident(&self) -> usize {
        self.warm_bytes
    }

    /// Disk tier occupancy.
    pub fn disk_bytes_resident(&self) -> usize {
        self.disk_bytes
    }

    /// Cumulative health counters.
    pub fn stats(&self) -> PagerStats {
        self.stats
    }
}

impl Drop for Pager {
    fn drop(&mut self) {
        // Stop the prefetch thread before sweeping, so it cannot race
        // the file removals below.
        self.prefetcher = None;
        for entry in self.seqs.values() {
            for slot in &entry.blocks {
                if let BlockLoc::Disk(path) = &slot.loc {
                    let _ = std::fs::remove_file(path);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::snapshot::tags;
    use crate::util::faults::FaultMode;

    fn snap(fill: u8, n: usize) -> KvSnapshot {
        KvSnapshot::new(tags::FULL, vec![fill; n])
    }

    fn tmp(label: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cskv-pager-{label}-{}", std::process::id()))
    }

    fn cfg(dir: Option<PathBuf>) -> PagerConfig {
        PagerConfig {
            disk_dir: dir,
            block_bytes: 64,
            ..PagerConfig::default()
        }
    }

    fn counters_clean(s: &PagerStats) {
        assert_eq!(s.spill_retries, 0);
        assert_eq!(s.read_retries, 0);
        assert_eq!(s.corrupt_restores, 0);
        assert!(!s.degraded);
    }

    #[test]
    fn memory_put_take_roundtrip_and_accounting() {
        let mut pager = Pager::new(cfg(None));
        assert!(pager.is_empty());
        let b1 = pager.put(1, &snap(7, 300), None).unwrap();
        let b2 = pager.put(2, &snap(9, 40), None).unwrap();
        assert_eq!(pager.len(), 2);
        assert_eq!(pager.bytes_resident(), b1 + b2);
        assert_eq!(pager.warm_bytes_resident(), b1 + b2, "no disk tier: all warm");
        assert_eq!(pager.disk_bytes_resident(), 0);
        // Double-park is a bug, not an overwrite.
        assert!(pager.put(1, &snap(0, 1), None).is_err());
        let s = pager.take(1).unwrap();
        assert_eq!(s.payload(), [7u8; 300]);
        assert_eq!(pager.bytes_resident(), b2);
        assert!(pager.take(1).is_err(), "take removes");
        pager.take(2).unwrap();
        assert!(pager.is_empty());
        assert_eq!(pager.bytes_resident(), 0);
        counters_clean(&pager.stats());
        assert_eq!(pager.stats().block_spills, 0, "nothing hit disk");
    }

    #[test]
    fn disk_spill_roundtrip_and_cleanup() {
        let dir = tmp("roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        {
            // Bare disk dir = warm budget 0: whole sequences spill,
            // the old cold-tier behavior.
            let mut pager = Pager::new(cfg(Some(dir.clone())));
            pager.put(5, &snap(3, 300), None).unwrap();
            assert_eq!(pager.warm_bytes_resident(), 0);
            assert!(pager.disk_bytes_resident() > 0);
            let files = [dir.join("seq-5.blk0"), dir.join("seq-5.blk4")];
            assert!(files.iter().all(|f| f.exists()), "blocks spilled to disk");
            let s = pager.take(5).unwrap();
            assert_eq!(s.tag(), tags::FULL);
            assert_eq!(s.payload(), [3u8; 300]);
            assert!(files.iter().all(|f| !f.exists()), "take deletes spill files");
            let st = pager.stats();
            assert_eq!(st.block_spills, st.block_promotes);
            assert_eq!(st.spill_bytes, st.promote_bytes);
            assert!(st.disk_bytes_peak > 0);
            // Blocks left parked are swept on drop.
            pager.put(6, &snap(1, 8), None).unwrap();
            assert!(dir.join("seq-6.blk0").exists());
        }
        assert!(!dir.join("seq-6.blk0").exists(), "drop sweeps leftovers");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn warm_budget_keeps_high_scored_blocks_warm() {
        let dir = tmp("budget");
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = cfg(Some(dir.clone()));
        // Fits roughly one of the two sequences.
        c.warm_budget_bytes = Some(450);
        let mut pager = Pager::new(c);
        // Seq 1 carries high attention mass everywhere, seq 2 low.
        pager.put(1, &snap(1, 300), Some(&[9.0; 32])).unwrap();
        pager.put(2, &snap(2, 300), Some(&[0.1; 32])).unwrap();
        assert!(pager.warm_bytes_resident() <= 450, "budget enforced");
        assert!(!dir.join("seq-1.blk0").exists(), "high-mass blocks stay warm");
        assert!(dir.join("seq-2.blk0").exists(), "low-mass blocks spilled");
        assert_eq!(pager.take(1).unwrap().payload(), [1u8; 300]);
        assert_eq!(pager.take(2).unwrap().payload(), [2u8; 300]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn attention_scoring_spills_low_mass_spans_age_spills_early_history() {
        let dir = tmp("scoring");
        let _ = std::fs::remove_dir_all(&dir);
        // Mass concentrated at the START of the sequence.
        let mut mass = vec![0.0f32; 32];
        for m in mass.iter_mut().take(16) {
            *m = 5.0;
        }
        let run = |scoring: EvictionScoring, sub: &str| {
            let d = dir.join(sub);
            let mut c = cfg(Some(d.clone()));
            c.warm_budget_bytes = Some(200);
            c.scoring = scoring;
            let mut pager = Pager::new(c);
            pager.put(1, &snap(4, 300), Some(&mass)).unwrap();
            let spilled: Vec<bool> = (0..5)
                .map(|i| Pager::block_path(&d, 1, i).exists())
                .collect();
            assert_eq!(pager.take(1).unwrap().payload(), [4u8; 300]);
            spilled
        };
        let attention = run(EvictionScoring::Attention, "attn");
        let age = run(EvictionScoring::Age, "age");
        // Attention parks the low-mass TAIL cold; age parks the HEAD.
        assert!(attention[4] && !attention[0], "attention spills tail: {attention:?}");
        assert!(age[0] && !age[4], "age spills head: {age:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prefetch_lands_blocks_and_take_consumes_them() {
        let dir = tmp("prefetch");
        let _ = std::fs::remove_dir_all(&dir);
        let mut pager = Pager::new(cfg(Some(dir.clone())));
        pager.put(1, &snap(6, 500), None).unwrap();
        let n_blocks = 500usize.div_ceil(64) + 1; // payload + header/footer
        pager.prefetch(&[1]);
        // Claim waits out in-flight reads, so no sleep is needed: every
        // disk block must resolve as a hit, not a sync fallback.
        assert_eq!(pager.take(1).unwrap().payload(), [6u8; 500]);
        let st = pager.stats();
        assert_eq!(st.prefetch_misses, 0, "all blocks landed or were awaited");
        assert!(st.prefetch_hits >= n_blocks as u64 - 1, "{st:?}");
        assert_eq!(st.read_retries, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prefetch_read_fault_degrades_to_synchronous_restore() {
        let dir = tmp("prefetch-fault");
        let _ = std::fs::remove_dir_all(&dir);
        let faults = FaultInjector::seeded(11);
        let mut pager = Pager::with_faults(cfg(Some(dir.clone())), faults.clone());
        pager.put(1, &snap(2, 40), None).unwrap(); // single block
        faults.arm("pager.read", FaultMode::Nth(1));
        pager.prefetch(&[1]);
        // The one prefetch attempt faults; take falls back to the
        // synchronous retried read and still round-trips bit-exactly.
        assert_eq!(pager.take(1).unwrap().payload(), [2u8; 40]);
        let st = pager.stats();
        assert!(st.read_retries >= 1, "failed prefetch observed: {st:?}");
        assert_eq!(st.prefetch_hits, 0);
        assert_eq!(st.prefetch_misses, 1);
        assert_eq!(st.corrupt_restores, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unusable_dir_degrades_and_is_counted() {
        // A file where the directory should be makes create_dir_all fail.
        let bogus = tmp("unusable");
        let _ = std::fs::remove_dir_all(&bogus);
        std::fs::write(&bogus, b"not a dir").unwrap();
        let mut pager = Pager::new(cfg(Some(bogus.clone())));
        assert!(pager.stats().degraded, "construction fallback is observable");
        pager.put(1, &snap(2, 16), None).unwrap();
        assert_eq!(pager.take(1).unwrap().payload(), [2u8; 16]);
        let _ = std::fs::remove_file(&bogus);
    }

    #[test]
    fn transient_write_fault_is_retried() {
        let dir = tmp("wretry");
        let _ = std::fs::remove_dir_all(&dir);
        let faults = FaultInjector::seeded(1);
        faults.arm("pager.write", FaultMode::Nth(1));
        let mut pager = Pager::with_faults(cfg(Some(dir.clone())), faults);
        pager.put(1, &snap(4, 32), None).unwrap();
        assert!(dir.join("seq-1.blk0").exists(), "retry landed on disk");
        assert_eq!(pager.stats().spill_retries, 1);
        assert!(!pager.stats().degraded);
        assert_eq!(pager.take(1).unwrap().payload(), [4u8; 32]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persistent_write_faults_degrade_to_warm_without_failing_puts() {
        let dir = tmp("wdegrade");
        let _ = std::fs::remove_dir_all(&dir);
        let faults = FaultInjector::seeded(2);
        faults.arm("pager.write", FaultMode::FromNth(1));
        let mut pager = Pager::with_faults(cfg(Some(dir.clone())), faults.clone());
        // First exhausted write: the block stays warm, not yet degraded.
        pager.put(1, &snap(5, 16), None).unwrap();
        assert!(!dir.join("seq-1.blk0").exists());
        assert!(!pager.stats().degraded);
        assert!(pager.warm_bytes_resident() > 0, "block parked warm over budget");
        // Second in a row: the disk tier degrades entirely.
        pager.put(2, &snap(6, 16), None).unwrap();
        assert!(pager.stats().degraded);
        let attempts_after_degrade = faults.hits("pager.write");
        // Degraded pager stops attempting doomed disk I/O entirely.
        pager.put(3, &snap(7, 16), None).unwrap();
        assert_eq!(faults.hits("pager.write"), attempts_after_degrade);
        // Every sequence still round-trips from the warm tier.
        for id in 1..=3 {
            assert!(pager.take(id).is_ok(), "seq {id} survived the faulty disk");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persistent_read_fault_fails_only_that_take_and_releases_the_file() {
        let dir = tmp("rfail");
        let _ = std::fs::remove_dir_all(&dir);
        let faults = FaultInjector::seeded(3);
        let mut c = cfg(Some(dir.clone()));
        c.prefetch = false; // exercise the pure synchronous path
        let mut pager = Pager::with_faults(c, faults.clone());
        pager.put(1, &snap(8, 16), None).unwrap();
        pager.put(2, &snap(9, 16), None).unwrap();
        faults.arm("pager.read", FaultMode::FromNth(1));
        let err = pager.take(1).expect_err("all read attempts fault");
        assert!(err.to_string().contains("unreadable"), "{err:#}");
        assert_eq!(pager.stats().read_retries, IO_ATTEMPTS as u64);
        assert!(!dir.join("seq-1.blk0").exists(), "failed take still cleans up");
        // The sibling sequence is unaffected once the fault clears.
        faults.arm("pager.read", FaultMode::Nth(1));
        assert_eq!(pager.take(2).unwrap().payload(), [9u8; 16], "one retry away");
        assert!(pager.is_empty());
        assert_eq!(pager.bytes_resident(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_blob_fails_cleanly_and_is_counted() {
        let faults = FaultInjector::seeded(4);
        faults.arm("snapshot.corrupt", FaultMode::Nth(1));
        let mut pager = Pager::with_faults(cfg(None), faults);
        pager.put(1, &snap(1, 128), None).unwrap();
        pager.put(2, &snap(2, 128), None).unwrap();
        let err = pager.take(1).expect_err("corrupted blob must not decode");
        assert!(err.to_string().contains("corrupt"), "{err:#}");
        assert_eq!(pager.stats().corrupt_restores, 1);
        // Only that sequence: the next take round-trips untouched.
        assert_eq!(pager.take(2).unwrap().payload(), [2u8; 128]);
        assert_eq!(pager.bytes_resident(), 0, "failed take refunds accounting");
    }

    #[test]
    fn discard_releases_blocks_and_spill_files_without_decoding() {
        let dir = tmp("discard");
        let _ = std::fs::remove_dir_all(&dir);
        let mut pager = Pager::new(cfg(Some(dir.clone())));
        pager.put(7, &snap(3, 200), None).unwrap();
        assert!(dir.join("seq-7.blk0").exists());
        assert!(pager.discard(7));
        assert!(!dir.join("seq-7.blk0").exists());
        assert!(!dir.join("seq-7.blk1").exists());
        assert_eq!(pager.bytes_resident(), 0);
        assert!(!pager.discard(7), "second discard is a no-op");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
