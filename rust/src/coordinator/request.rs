//! Request/response types for the serving path.

use std::sync::mpsc;
use std::time::Instant;

/// A generation request.
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub n_new: usize,
    pub submitted_at: Instant,
    /// Channel the coordinator answers on.
    pub reply: mpsc::Sender<Response>,
}

/// A completed (or failed) generation. Every submitted request receives
/// exactly one `Response` — failures carry [`Response::error`] instead of
/// silently dropping the reply channel, so `submit_wait` can never hang.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<usize>,
    /// Queue wait before prefill started.
    pub queue_wait_s: f64,
    /// Time to first token (queue + prefill).
    pub ttft_s: f64,
    /// Total time in the system.
    pub total_s: f64,
    /// KV bytes held by this sequence at completion.
    pub kv_bytes: usize,
    pub backend: String,
    /// `Some(reason)` when the request failed (backend construction or
    /// prefill error: `tokens` is empty; decode error: `tokens` holds the
    /// prefix generated before the failure).
    pub error: Option<String>,
}

impl Response {
    /// A failure response for a request that produced no tokens.
    pub fn failure(req: &Request, error: impl Into<String>) -> Response {
        Response {
            id: req.id,
            tokens: Vec::new(),
            queue_wait_s: req.submitted_at.elapsed().as_secs_f64(),
            ttft_s: 0.0,
            total_s: req.submitted_at.elapsed().as_secs_f64(),
            kv_bytes: 0,
            backend: String::new(),
            error: Some(error.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_over_channel() {
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: 7,
            prompt: vec![1, 2, 3],
            n_new: 4,
            submitted_at: Instant::now(),
            reply: tx,
        };
        req.reply
            .send(Response {
                id: req.id,
                tokens: vec![9],
                queue_wait_s: 0.0,
                ttft_s: 0.1,
                total_s: 0.2,
                kv_bytes: 64,
                backend: "test".into(),
                error: None,
            })
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.tokens, vec![9]);
        assert!(resp.error.is_none());
    }

    #[test]
    fn failure_response_carries_reason() {
        let (tx, _rx) = mpsc::channel();
        let req = Request {
            id: 3,
            prompt: vec![],
            n_new: 1,
            submitted_at: Instant::now(),
            reply: tx,
        };
        let resp = Response::failure(&req, "boom");
        assert_eq!(resp.id, 3);
        assert!(resp.tokens.is_empty());
        assert_eq!(resp.error.as_deref(), Some("boom"));
    }
}
