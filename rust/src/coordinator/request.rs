//! Request/response types for the serving path.

use std::sync::mpsc;
use std::time::Instant;

/// A generation request.
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub n_new: usize,
    pub submitted_at: Instant,
    /// Channel the coordinator answers on.
    pub reply: mpsc::Sender<Response>,
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<usize>,
    /// Queue wait before prefill started.
    pub queue_wait_s: f64,
    /// Time to first token (queue + prefill).
    pub ttft_s: f64,
    /// Total time in the system.
    pub total_s: f64,
    /// KV bytes held by this sequence at completion.
    pub kv_bytes: usize,
    pub backend: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_over_channel() {
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: 7,
            prompt: vec![1, 2, 3],
            n_new: 4,
            submitted_at: Instant::now(),
            reply: tx,
        };
        req.reply
            .send(Response {
                id: req.id,
                tokens: vec![9],
                queue_wait_s: 0.0,
                ttft_s: 0.1,
                total_s: 0.2,
                kv_bytes: 64,
                backend: "test".into(),
            })
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.tokens, vec![9]);
    }
}
