//! Request/response types for the serving path.
//!
//! A [`Request`] carries two lifecycle controls alongside the prompt:
//!
//! * `deadline` — an absolute [`Instant`] after which the request is
//!   worthless to the client. The worker checks it at every round
//!   boundary: a still-queued request past its deadline is answered
//!   `"deadline exceeded"` without admission; an in-flight one retires
//!   early with whatever tokens it has.
//! * `cancel` — a [`CancelToken`] the client can flip from any thread.
//!   Same enforcement points, reason `"cancelled"`, and the partial
//!   token stream is returned rather than discarded.
//!
//! Both resolve to a single [`Response`] whose `error` field carries the
//! reason — the exactly-one-`Response` contract (see the
//! [`crate::coordinator`] module docs) holds for every exit path.
//!
//! Two optional channels ride along for the HTTP serving plane:
//!
//! * `stream` — a per-token sink the worker feeds as tokens are
//!   generated (prefill's first token, then every decode step). The
//!   final [`Response`] still carries the complete stream; `stream` is
//!   pure fan-out for SSE forwarding and never blocks the worker (the
//!   channel is unbounded; a gone receiver is ignored).
//! * `resume` — a [`ResumeSeed`] marking this request as a migrated
//!   sequence from a drained coordinator: instead of prefilling, the
//!   worker restores the nested backend snapshot and continues decoding
//!   from `generated`, bit-identically to the undisturbed run. Only
//!   tokens generated *after* the migration are streamed.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::kvcache::KvSnapshot;

/// `Response::error` reason for sequences cut loose by a graceful drain:
/// their state was snapshotted into the drain bundle rather than run to
/// completion. The HTTP layer maps this onto the `migrated` SSE terminal
/// event; everything else is a plain `error` terminal.
pub const DRAINED: &str = "drained: state migrated to snapshot bundle";

/// Client-side cancellation flag. Cloning shares the flag: the client
/// keeps one clone (via `RequestHandle`), the worker polls the other at
/// round boundaries. Cancellation is level-triggered and sticky.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        CancelToken::default()
    }

    /// Request cancellation. Idempotent; takes effect at the worker's
    /// next round boundary.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// A generation request.
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub n_new: usize,
    pub submitted_at: Instant,
    /// Absolute point past which the request should be abandoned
    /// (`None` = no deadline). Checked at round boundaries, so
    /// enforcement granularity is one decode round.
    pub deadline: Option<Instant>,
    /// Client-held cancellation flag (see [`CancelToken`]).
    pub cancel: CancelToken,
    /// Channel the coordinator answers on.
    pub reply: mpsc::Sender<Response>,
    /// Optional per-token sink for SSE streaming (`None` for plain
    /// request/response submits). Send-only from the worker; a
    /// disconnected receiver is silently ignored.
    pub stream: Option<mpsc::Sender<usize>>,
    /// Set when this request resumes a drained sequence: the worker
    /// restores the snapshot instead of prefilling the prompt.
    pub resume: Option<ResumeSeed>,
}

/// Mid-generation state carried by a migrated request: the backend
/// snapshot from the drained process plus the tokens already generated
/// (and already delivered to the original client) before the cut.
pub struct ResumeSeed {
    pub snapshot: KvSnapshot,
    pub generated: Vec<usize>,
}

impl Request {
    /// True once the deadline (if any) has passed.
    pub fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// True once the client has flipped the cancel token.
    pub fn cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// Fan a freshly generated token out to the streaming sink, if any.
    /// Never blocks and never fails: the channel is unbounded and a
    /// dropped receiver (client gone) is the cancel path's business, not
    /// the data plane's.
    pub fn stream_token(&self, tok: usize) {
        if let Some(s) = &self.stream {
            let _ = s.send(tok);
        }
    }
}

/// A completed (or failed) generation. Every submitted request receives
/// exactly one `Response` — failures carry an `error` reason instead of
/// silently dropping the reply channel, so `submit_wait` can never hang.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<usize>,
    /// Queue wait before prefill started.
    pub queue_wait_s: f64,
    /// Time to first token (queue + prefill).
    pub ttft_s: f64,
    /// Total time in the system.
    pub total_s: f64,
    /// KV bytes held by this sequence at completion.
    pub kv_bytes: usize,
    pub backend: String,
    /// `Some(reason)` when the request did not run to completion.
    /// Backend-construction / prefill errors and pre-admission rejections
    /// (`"deadline exceeded"` while queued, invalid submit) leave `tokens`
    /// empty; decode-time failures and mid-stream `"cancelled"` /
    /// `"deadline exceeded"` exits carry the partial prefix generated
    /// before the cut.
    pub error: Option<String>,
}

impl Response {
    /// A failure response for a request that produced no tokens.
    pub fn failure(req: &Request, error: impl Into<String>) -> Response {
        Response::error(req, error)
    }

    /// An error response for a request that produced no tokens — used
    /// for submit-time validation failures and queued requests reaped at
    /// a round boundary (expired/cancelled before admission).
    pub fn error(req: &Request, reason: impl Into<String>) -> Response {
        Response {
            id: req.id,
            tokens: Vec::new(),
            queue_wait_s: req.submitted_at.elapsed().as_secs_f64(),
            ttft_s: 0.0,
            total_s: req.submitted_at.elapsed().as_secs_f64(),
            kv_bytes: 0,
            backend: String::new(),
            error: Some(reason.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn request(id: u64, reply: mpsc::Sender<Response>) -> Request {
        Request {
            id,
            prompt: vec![1, 2, 3],
            n_new: 4,
            submitted_at: Instant::now(),
            deadline: None,
            cancel: CancelToken::new(),
            reply,
            stream: None,
            resume: None,
        }
    }

    #[test]
    fn stream_sink_receives_tokens_and_tolerates_gone_receiver() {
        let (tx, _rx) = mpsc::channel();
        let (stx, srx) = mpsc::channel();
        let mut req = request(4, tx);
        req.stream_token(9); // no sink: no-op
        req.stream = Some(stx);
        req.stream_token(1);
        req.stream_token(2);
        assert_eq!(srx.try_recv(), Ok(1));
        assert_eq!(srx.try_recv(), Ok(2));
        drop(srx);
        req.stream_token(3); // receiver gone: still no panic
    }

    #[test]
    fn request_roundtrip_over_channel() {
        let (tx, rx) = mpsc::channel();
        let req = request(7, tx);
        req.reply
            .send(Response {
                id: req.id,
                tokens: vec![9],
                queue_wait_s: 0.0,
                ttft_s: 0.1,
                total_s: 0.2,
                kv_bytes: 64,
                backend: "test".into(),
                error: None,
            })
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.tokens, vec![9]);
        assert!(resp.error.is_none());
    }

    #[test]
    fn failure_response_carries_reason() {
        let (tx, _rx) = mpsc::channel();
        let req = request(3, tx);
        let resp = Response::failure(&req, "boom");
        assert_eq!(resp.id, 3);
        assert!(resp.tokens.is_empty());
        assert_eq!(resp.error.as_deref(), Some("boom"));
    }

    #[test]
    fn cancel_token_is_shared_and_sticky() {
        let (tx, _rx) = mpsc::channel();
        let req = request(1, tx);
        assert!(!req.cancelled());
        let client_side = req.cancel.clone();
        client_side.cancel();
        assert!(req.cancelled(), "clones share one flag");
        client_side.cancel();
        assert!(req.cancelled(), "idempotent");
    }

    #[test]
    fn deadline_expiry_is_observable() {
        let (tx, _rx) = mpsc::channel();
        let mut req = request(2, tx);
        assert!(!req.expired(), "no deadline, never expired");
        req.deadline = Some(Instant::now() + Duration::from_secs(3600));
        assert!(!req.expired());
        req.deadline = Some(Instant::now() - Duration::from_millis(1));
        assert!(req.expired());
    }
}
