//! PJRT serving sessions — the AOT hot path.
//!
//! These backends execute the lowered artifacts (`prefill`, `decode_full`,
//! `decode_cskv_r*`) via the PJRT CPU client. Rust owns all cache buffers
//! (full K/V buffers, or the CSKV compressed history + rolling window) and
//! feeds them to the fixed-shape executables each step; Python is never
//! involved.
//!
//! Buffer-ownership contract per artifact (see `python/compile/model.py`):
//! * `decode_full`  — Rust writes returned `k_new/v_new` (post-RoPE) into
//!   row `pos` of its `[L, max_seq, d]` buffers.
//! * `decode_cskv`  — Rust appends `ck_new/cv_new` to the compressed
//!   history and rolls the pre-RoPE window (`win_k/win_v/win_pos`),
//!   mirroring `kvcache::bibranch` exactly.

use std::rc::Rc;
use std::sync::Arc;

use crate::compress::ModelFactors;
use crate::data::vocab;
use crate::kvcache::snapshot::{tags, SnapReader, SnapWriter};
use crate::kvcache::KvSnapshot;
use crate::model::ModelWeights;
use crate::runtime::{Runtime, Value};
use crate::tensor::ops;
use crate::tensor::Mat;

use super::backend::SequenceBackend;

/// Shared per-process serving state: runtime + marshalled weights.
pub struct PjrtContext {
    pub rt: Runtime,
    pub weights: Arc<ModelWeights>,
    params: Vec<Value>,
}

impl PjrtContext {
    pub fn new(rt: Runtime, weights: Arc<ModelWeights>) -> anyhow::Result<Self> {
        rt.manifest.model.validate_against_json(&weights.cfg.to_json())?;
        let params: Vec<Value> = weights
            .flat_order()
            .iter()
            .map(|(_, m)| Value::from_mat(m))
            .collect();
        Ok(PjrtContext { rt, weights, params })
    }

    fn cfg(&self) -> &crate::model::ModelConfig {
        &self.weights.cfg
    }

    /// Run the prefill artifact on a (padded) prompt.
    fn run_prefill(&self, prompt: &[usize]) -> anyhow::Result<(usize, Vec<Mat>, Vec<Mat>, Vec<Mat>)> {
        let cfg = self.cfg();
        anyhow::ensure!(
            !prompt.is_empty() && prompt.len() <= cfg.max_seq,
            "prompt length {} out of range (max {})",
            prompt.len(),
            cfg.max_seq
        );
        let mut tokens: Vec<i32> = prompt.iter().map(|&t| t as i32).collect();
        tokens.resize(cfg.max_seq, vocab::PAD as i32);
        let mut inputs = self.params.clone();
        inputs.push(Value::i32_vec(vec![cfg.max_seq], tokens));
        let out = self.rt.execute("prefill", &inputs)?;
        // outputs: logits [T,V], xnorms [L,T,d], ks [L,T,d], vs [L,T,d]
        let logits = out[0].to_mat()?;
        let first = ops::argmax(logits.row(prompt.len() - 1));
        let take = |v: &Value| -> anyhow::Result<Vec<Mat>> {
            (0..cfg.n_layers)
                .map(|li| Ok(v.mat_at(li)?.rows_slice(0, prompt.len())))
                .collect()
        };
        Ok((first, take(&out[1])?, take(&out[2])?, take(&out[3])?))
    }
}

// ---------------------------------------------------------------------------
// Full-precision session
// ---------------------------------------------------------------------------

/// Serving session with an uncompressed KV cache (baseline).
pub struct PjrtFullSession {
    ctx: Rc<PjrtContext>,
    k_buf: Vec<f32>, // [L, max_seq, d]
    v_buf: Vec<f32>,
    pos: usize,
    last_token: usize,
}

impl PjrtFullSession {
    pub fn new(ctx: Rc<PjrtContext>) -> Self {
        let cfg = ctx.cfg();
        let n = cfg.n_layers * cfg.max_seq * cfg.d_model;
        PjrtFullSession {
            ctx,
            k_buf: vec![0.0; n],
            v_buf: vec![0.0; n],
            pos: 0,
            last_token: 0,
        }
    }

    fn write_row(buf: &mut [f32], li: usize, row: usize, max_seq: usize, d: usize, data: &[f32]) {
        let off = (li * max_seq + row) * d;
        buf[off..off + d].copy_from_slice(data);
    }
}

impl SequenceBackend for PjrtFullSession {
    fn name(&self) -> String {
        "pjrt/decode_full".into()
    }

    fn prefill(&mut self, prompt: &[usize]) -> anyhow::Result<usize> {
        let cfg = self.ctx.cfg().clone();
        let (first, _xn, ks, vs) = self.ctx.run_prefill(prompt)?;
        for li in 0..cfg.n_layers {
            // Buffer stores post-RoPE keys.
            let mut k = ks[li].clone();
            ops::rope_rows(&mut k, cfg.n_heads, 0, cfg.rope_base);
            for t in 0..prompt.len() {
                Self::write_row(&mut self.k_buf, li, t, cfg.max_seq, cfg.d_model, k.row(t));
                Self::write_row(&mut self.v_buf, li, t, cfg.max_seq, cfg.d_model, vs[li].row(t));
            }
        }
        self.pos = prompt.len();
        self.last_token = first;
        Ok(first)
    }

    fn decode_next(&mut self) -> anyhow::Result<usize> {
        let cfg = self.ctx.cfg().clone();
        anyhow::ensure!(self.pos < cfg.max_seq, "sequence exceeded max_seq");
        let shape = vec![cfg.n_layers, cfg.max_seq, cfg.d_model];
        let mut inputs = self.ctx.params.clone();
        inputs.push(Value::scalar_i32(self.last_token as i32));
        inputs.push(Value::scalar_i32(self.pos as i32));
        inputs.push(Value::f32_vec(shape.clone(), self.k_buf.clone()));
        inputs.push(Value::f32_vec(shape, self.v_buf.clone()));
        let out = self.ctx.rt.execute("decode_full", &inputs)?;
        let logits = out[0].as_f32()?;
        let k_new = out[1].as_f32()?;
        let v_new = out[2].as_f32()?;
        for li in 0..cfg.n_layers {
            Self::write_row(
                &mut self.k_buf,
                li,
                self.pos,
                cfg.max_seq,
                cfg.d_model,
                &k_new[li * cfg.d_model..(li + 1) * cfg.d_model],
            );
            Self::write_row(
                &mut self.v_buf,
                li,
                self.pos,
                cfg.max_seq,
                cfg.d_model,
                &v_new[li * cfg.d_model..(li + 1) * cfg.d_model],
            );
        }
        self.pos += 1;
        self.last_token = ops::argmax(logits);
        Ok(self.last_token)
    }

    fn kv_bytes(&self) -> usize {
        // Semantic footprint: valid rows only (buffers are preallocated).
        self.ctx.cfg().kv_bytes_full(self.pos)
    }

    fn kv_bytes_projected(&self, tokens: usize) -> usize {
        self.ctx.cfg().kv_bytes_full(tokens)
    }

    fn snapshot(&self) -> anyhow::Result<KvSnapshot> {
        // Only the valid rows travel: the preallocated [L, max_seq, d]
        // buffers shrink to [L, pos, d] in the serialized form.
        let cfg = self.ctx.cfg();
        let (l, d, t) = (cfg.n_layers, cfg.d_model, cfg.max_seq);
        let mut w = SnapWriter::new();
        w.write_usize(l);
        w.write_usize(d);
        w.write_usize(self.pos);
        w.write_usize(self.last_token);
        for li in 0..l {
            let off = li * t * d;
            w.f32s(&self.k_buf[off..off + self.pos * d]);
            w.f32s(&self.v_buf[off..off + self.pos * d]);
        }
        Ok(KvSnapshot::new(tags::PJRT_FULL, w.finish()))
    }

    fn restore(&mut self, snap: &KvSnapshot) -> anyhow::Result<()> {
        snap.expect_tag(tags::PJRT_FULL, "pjrt full session")?;
        let cfg = self.ctx.cfg().clone();
        let (l, d, t) = (cfg.n_layers, cfg.d_model, cfg.max_seq);
        let mut r = SnapReader::new(snap.payload());
        let (sl, sd) = (r.read_usize()?, r.read_usize()?);
        let pos = r.read_usize()?;
        let last_token = r.read_usize()?;
        anyhow::ensure!(
            sl == l && sd == d && pos <= t,
            "pjrt full session: snapshot geometry {sl}x{sd} pos {pos} != target {l}x{d} (max_seq {t})"
        );
        self.k_buf.fill(0.0);
        self.v_buf.fill(0.0);
        for li in 0..l {
            let k = r.f32s()?;
            let v = r.f32s()?;
            anyhow::ensure!(
                k.len() == pos * d && v.len() == pos * d,
                "pjrt full session: layer {li} rows {} != pos {pos}",
                k.len() / d.max(1)
            );
            let off = li * t * d;
            self.k_buf[off..off + pos * d].copy_from_slice(&k);
            self.v_buf[off..off + pos * d].copy_from_slice(&v);
        }
        r.expect_end()?;
        self.pos = pos;
        self.last_token = last_token;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// CSKV bi-branch session
// ---------------------------------------------------------------------------

/// Serving session with the CSKV bi-branch cache, executing the fused
/// Pallas decode artifact `decode_cskv_r{rank}`.
pub struct PjrtCskvSession {
    ctx: Rc<PjrtContext>,
    exe: String,
    factors: Arc<ModelFactors>,
    fac_vals: [Value; 4], // ak, bk, av, bv
    rank: usize,
    window: usize,
    ck: Vec<f32>,  // [L, max_seq, r]
    cv: Vec<f32>,
    win_k: Vec<f32>, // [L, W, d] pre-RoPE
    win_v: Vec<f32>,
    win_pos: Vec<i32>, // [L, W]
    n: usize,
    win_len: usize,
    last_token: usize,
}

impl PjrtCskvSession {
    /// `factors` rank must match one of the exported artifacts.
    pub fn new(ctx: Rc<PjrtContext>, factors: Arc<ModelFactors>) -> anyhow::Result<Self> {
        let rank = factors.rank_k();
        anyhow::ensure!(
            factors.rank_v() == rank,
            "PJRT cskv artifacts are exported with rank_k == rank_v (got {} vs {})",
            rank,
            factors.rank_v()
        );
        let exe = format!("decode_cskv_r{rank}");
        let spec = ctx.rt.manifest.get(&exe)?;
        let window = spec
            .static_usize("window")
            .ok_or_else(|| anyhow::anyhow!("{exe}: missing window"))?;
        let cfg = ctx.cfg();
        let (l, d, t) = (cfg.n_layers, cfg.d_model, cfg.max_seq);
        anyhow::ensure!(factors.layers.len() == l, "factor layer count mismatch");
        // Marshal factors once: ak/av [L,d,r]; bk/bv [L,r,d].
        let stack = |f: &dyn Fn(usize) -> Mat| -> Value {
            let mats: Vec<Mat> = (0..l).map(f).collect();
            Value::from_mats(&mats.iter().collect::<Vec<_>>())
        };
        let fac_vals = [
            stack(&|i| factors.layers[i].k.a.clone()),
            stack(&|i| factors.layers[i].k.b.clone()),
            stack(&|i| factors.layers[i].v.a.clone()),
            stack(&|i| factors.layers[i].v.b.clone()),
        ];
        Ok(PjrtCskvSession {
            ctx,
            exe,
            factors,
            fac_vals,
            rank,
            window,
            ck: vec![0.0; l * t * rank],
            cv: vec![0.0; l * t * rank],
            win_k: vec![0.0; l * window * d],
            win_v: vec![0.0; l * window * d],
            win_pos: vec![0; l * window],
            n: 0,
            win_len: 0,
            last_token: 0,
        })
    }

    fn push_window(&mut self, li: usize, k: &[f32], v: &[f32], pos: usize, d: usize) {
        let w = self.window;
        if self.win_len < w {
            let off = (li * w + self.win_len) * d;
            self.win_k[off..off + d].copy_from_slice(k);
            self.win_v[off..off + d].copy_from_slice(v);
            self.win_pos[li * w + self.win_len] = pos as i32;
        } else {
            // Shift left one slot (ring semantics, oldest evicted).
            let base = li * w * d;
            self.win_k.copy_within(base + d..base + w * d, base);
            self.win_v.copy_within(base + d..base + w * d, base);
            let pbase = li * w;
            self.win_pos.copy_within(pbase + 1..pbase + w, pbase);
            let off = base + (w - 1) * d;
            self.win_k[off..off + d].copy_from_slice(k);
            self.win_v[off..off + d].copy_from_slice(v);
            self.win_pos[pbase + w - 1] = pos as i32;
        }
    }
}

impl SequenceBackend for PjrtCskvSession {
    fn name(&self) -> String {
        format!("pjrt/{} (w={})", self.exe, self.window)
    }

    fn prefill(&mut self, prompt: &[usize]) -> anyhow::Result<usize> {
        let cfg = self.ctx.cfg().clone();
        let (first, xns, ks, vs) = self.ctx.run_prefill(prompt)?;
        let t = prompt.len();
        let (d, r, maxt) = (cfg.d_model, self.rank, cfg.max_seq);
        for li in 0..cfg.n_layers {
            // Compressed history for every prompt token: C = xnorm · A.
            let ckm = self.factors.layers[li].k.compress(&xns[li]);
            let cvm = self.factors.layers[li].v.compress(&xns[li]);
            for row in 0..t {
                let off = (li * maxt + row) * r;
                self.ck[off..off + r].copy_from_slice(ckm.row(row));
                self.cv[off..off + r].copy_from_slice(cvm.row(row));
            }
        }
        // Window: the last min(W, t) tokens at full precision (pre-RoPE).
        self.win_len = 0;
        let w0 = t.saturating_sub(self.window);
        for pos in w0..t {
            for li in 0..cfg.n_layers {
                let k = ks[li].row(pos).to_vec();
                let v = vs[li].row(pos).to_vec();
                self.push_window(li, &k, &v, pos, d);
            }
            self.win_len = (self.win_len + 1).min(self.window);
        }
        self.n = t;
        self.last_token = first;
        Ok(first)
    }

    fn decode_next(&mut self) -> anyhow::Result<usize> {
        let cfg = self.ctx.cfg().clone();
        anyhow::ensure!(self.n < cfg.max_seq, "sequence exceeded max_seq");
        let (l, d, r, t, w) = (cfg.n_layers, cfg.d_model, self.rank, cfg.max_seq, self.window);
        let mut inputs = self.ctx.params.clone();
        inputs.extend(self.fac_vals.iter().cloned());
        inputs.push(Value::scalar_i32(self.last_token as i32));
        inputs.push(Value::scalar_i32(self.n as i32));
        inputs.push(Value::scalar_i32(self.win_len as i32));
        inputs.push(Value::f32_vec(vec![l, t, r], self.ck.clone()));
        inputs.push(Value::f32_vec(vec![l, t, r], self.cv.clone()));
        inputs.push(Value::f32_vec(vec![l, w, d], self.win_k.clone()));
        inputs.push(Value::f32_vec(vec![l, w, d], self.win_v.clone()));
        inputs.push(Value::i32_vec(vec![l, w], self.win_pos.clone()));
        let out = self.ctx.rt.execute(&self.exe, &inputs)?;
        // outputs: logits, ck_new [L,r], cv_new [L,r], k_new [L,d], v_new [L,d]
        let logits = out[0].as_f32()?;
        let ck_new = out[1].as_f32()?.to_vec();
        let cv_new = out[2].as_f32()?.to_vec();
        let k_new = out[3].as_f32()?.to_vec();
        let v_new = out[4].as_f32()?.to_vec();
        let pos = self.n;
        for li in 0..l {
            let off = (li * t + pos) * r;
            self.ck[off..off + r].copy_from_slice(&ck_new[li * r..(li + 1) * r]);
            self.cv[off..off + r].copy_from_slice(&cv_new[li * r..(li + 1) * r]);
            let kd = &k_new[li * d..(li + 1) * d].to_vec();
            let vd = &v_new[li * d..(li + 1) * d].to_vec();
            self.push_window(li, kd, vd, pos, d);
        }
        self.win_len = (self.win_len + 1).min(w);
        self.n += 1;
        self.last_token = ops::argmax(logits);
        Ok(self.last_token)
    }

    fn kv_bytes(&self) -> usize {
        let cfg = self.ctx.cfg();
        let l = cfg.n_layers;
        // compressed history (all n tokens) + full-precision window
        l * self.n * 2 * self.rank * 4 + l * self.win_len * 2 * cfg.d_model * 4
    }

    fn kv_bytes_projected(&self, tokens: usize) -> usize {
        let cfg = self.ctx.cfg();
        let l = cfg.n_layers;
        let win = tokens.min(self.window);
        l * tokens * 2 * self.rank * 4 + l * win * 2 * cfg.d_model * 4
    }

    fn snapshot(&self) -> anyhow::Result<KvSnapshot> {
        // The compressed representation travels: [L, n, r] feature rows
        // plus the ≤ window full-precision rows — the same ~20%-of-hot
        // footprint the Rust CSKV policy snapshots.
        let cfg = self.ctx.cfg();
        let (l, d, t, wlen) = (cfg.n_layers, cfg.d_model, cfg.max_seq, self.window);
        let mut w = SnapWriter::new();
        w.write_usize(l);
        w.write_usize(d);
        w.write_usize(self.rank);
        w.write_usize(wlen);
        w.write_usize(self.n);
        w.write_usize(self.win_len);
        w.write_usize(self.last_token);
        for li in 0..l {
            let coff = li * t * self.rank;
            w.f32s(&self.ck[coff..coff + self.n * self.rank]);
            w.f32s(&self.cv[coff..coff + self.n * self.rank]);
            let woff = li * wlen * d;
            w.f32s(&self.win_k[woff..woff + self.win_len * d]);
            w.f32s(&self.win_v[woff..woff + self.win_len * d]);
            let poff = li * wlen;
            let pos: Vec<usize> =
                self.win_pos[poff..poff + self.win_len].iter().map(|&p| p as usize).collect();
            w.usizes(&pos);
        }
        Ok(KvSnapshot::new(tags::PJRT_CSKV, w.finish()))
    }

    fn restore(&mut self, snap: &KvSnapshot) -> anyhow::Result<()> {
        snap.expect_tag(tags::PJRT_CSKV, "pjrt cskv session")?;
        let cfg = self.ctx.cfg().clone();
        let (l, d, t, wlen) = (cfg.n_layers, cfg.d_model, cfg.max_seq, self.window);
        let mut r = SnapReader::new(snap.payload());
        let (sl, sd) = (r.read_usize()?, r.read_usize()?);
        let (srank, swin) = (r.read_usize()?, r.read_usize()?);
        let n = r.read_usize()?;
        let win_len = r.read_usize()?;
        let last_token = r.read_usize()?;
        anyhow::ensure!(
            sl == l && sd == d && srank == self.rank && swin == wlen && n <= t && win_len <= wlen,
            "pjrt cskv session: snapshot geometry (L{sl}, d{sd}, r{srank}, w{swin}, n={n}) \
             incompatible with target (L{l}, d{d}, r{}, w{wlen})",
            self.rank
        );
        self.ck.fill(0.0);
        self.cv.fill(0.0);
        self.win_k.fill(0.0);
        self.win_v.fill(0.0);
        self.win_pos.fill(0);
        for li in 0..l {
            let ck = r.f32s()?;
            let cv = r.f32s()?;
            let wk = r.f32s()?;
            let wv = r.f32s()?;
            let pos = r.usizes()?;
            anyhow::ensure!(
                ck.len() == n * self.rank
                    && cv.len() == n * self.rank
                    && wk.len() == win_len * d
                    && wv.len() == win_len * d
                    && pos.len() == win_len,
                "pjrt cskv session: layer {li} slice lengths inconsistent with header"
            );
            let coff = li * t * self.rank;
            self.ck[coff..coff + n * self.rank].copy_from_slice(&ck);
            self.cv[coff..coff + n * self.rank].copy_from_slice(&cv);
            let woff = li * wlen * d;
            self.win_k[woff..woff + win_len * d].copy_from_slice(&wk);
            self.win_v[woff..woff + win_len * d].copy_from_slice(&wv);
            let poff = li * wlen;
            for (slot, &p) in pos.iter().enumerate() {
                self.win_pos[poff + slot] = p as i32;
            }
        }
        r.expect_end()?;
        self.n = n;
        self.win_len = win_len;
        self.last_token = last_token;
        Ok(())
    }
}

// Integration coverage (needs compiled artifacts) lives in
// rust/tests/integration_runtime.rs.
