//! The coordinator thread: queueing, KV-budget admission, continuous
//! batching, preemptive tiered scheduling, completion.
//!
//! Control plane / data plane split: the **data plane** (fused
//! multi-sequence prefill, GEMM-batched decode rounds — PR 3) moves
//! tokens; the **control plane** decides *which* sequences occupy the
//! hot tier each round, and is pluggable through the
//! [`super::scheduler::Scheduler`] trait (`fifo` | `size-aware` |
//! `preemptive`, selected by [`CoordinatorConfig::scheduler`]).
//!
//! Each scheduling round:
//!
//! 1. Requests land in an mpsc queue; the worker drains it.
//! 1a. **Reap**: every cancelled or deadline-expired request — queued,
//!    active, or swapped — is answered and released before the
//!    scheduler runs (`"cancelled"` / `"deadline exceeded"`, with the
//!    partial token stream for in-flight sequences). Deadlines come
//!    from the request itself or the config-wide
//!    [`CoordinatorConfig::request_timeout`]; enforcement granularity
//!    is one round. See the [`crate::coordinator`] module docs for the
//!    full lifecycle state machine.
//! 2. **Admission**: the scheduler repeatedly picks the next queued
//!    request that fits the headroom, every sequence charged at its
//!    *projected completion* footprint
//!    ([`SequenceBackend::kv_bytes_projected`]). With the prefix cache
//!    enabled ([`CoordinatorConfig::prefix_cache_bytes`]) a request
//!    whose prompt opens with a cached prefix is charged only its
//!    **unshared suffix** (`projected(prompt + n_new) −
//!    projected(prefix)`): the shared bytes already sit in the trie and
//!    are counted once, not once per admission. This is an
//!    admission-time discount — after prefill the sequence's real
//!    footprint (the policy re-ingests the full context) re-enters the
//!    budget through the `cost.max(kv_bytes())` term in
//!    `committed_bytes`, so the hot tier is never under-accounted for
//!    long. When the preferred candidate does not fit, a preemptive
//!    scheduler may swap the lowest-priority active sequence (most
//!    remaining work) out to the [`super::pager::Pager`] to fund
//!    it. If nothing at all is running, the preferred candidate is
//!    admitted over budget — the can't-deadlock escape hatch. Admission
//!    also **prices resume cost**: a swapped sequence parked for
//!    [`STARVATION_ROUNDS`] rounds has its footprint reserved out of
//!    the headroom the scheduler sees, so a preemption storm cannot
//!    starve restores behind an endless stream of small admits (the
//!    escape hatch is untouched — reservation narrows admission, never
//!    blocks the only runnable work).
//!    Each admission also performs its [`PrefixCache::lookup`]: the
//!    longest-prefix match is pinned (refcounted) and carried to the
//!    prefill round as a [`PrefixSeed`].
//! 3. **Resume**: swapped-out sequences return from the pager
//!    (smallest remaining work first) with whatever budget and batch
//!    headroom is left *after* admission — so queued work the scheduler
//!    prefers is never displaced by an eager restore, and a parked long
//!    sequence stays parked (no snapshot/restore churn) while strictly
//!    shorter requests keep arriving. Restores are **bit-identical**,
//!    from the policy's own compressed [`crate::kvcache::KvSnapshot`]
//!    representation (`DecodeView`s rebuild through the normal
//!    `sync_view` path), and the resumed sequence joins the same
//!    round's decode. After resuming, the worker predicts the *next*
//!    round's resume picks and queues [`super::pager::Pager::prefetch`]
//!    for their disk blocks, so those reads overlap the decode round
//!    about to run instead of stalling the following one.
//! 4. The whole admission round prefills in **one fused pass**
//!    ([`super::backend::prefill_batch`], or
//!    [`super::backend::prefill_batch_seeded`] when the prefix cache is
//!    on — seeded sequences compute only their unshared suffix, the
//!    warm-TTFT win `bench_perf_prefix` measures, yet stay bitwise
//!    identical to a cold run); each decode round advances every active
//!    sequence in **one GEMM-batched call**
//!    ([`super::backend::decode_batch`]). `fused: false` keeps the
//!    per-sequence A/B baseline; token streams are bit-identical either
//!    way (`rust/tests/batched_serving.rs`). After the round, every
//!    prefilled prompt (cold or warm) is **published** back into the
//!    trie and its pinned seed chain released; the trie then LRU-evicts
//!    down to its byte budget.
//! 5. Every submitted request receives exactly one [`Response`]:
//!    construction, prefill, and cold-tier/restore failures answer with
//!    an error `Response` (counted in [`Metrics`]) instead of dropping
//!    the reply channel, so `submit_wait` can never hang.
//!
//! [`Metrics`] additionally records queue waits, preemption/restore
//! counts, cold-tier bytes, per-outcome TTFT and the retirement order —
//! the observables `bench_perf_scheduling` and the fairness tests build
//! on.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use super::backend::{
    decode_batch, prefill_batch, prefill_batch_seeded, BatchScratch, SequenceBackend,
};
use super::metrics::{Completion, Metrics};
use super::pager::{EvictionScoring, Pager, PagerConfig};
use super::request::{CancelToken, Request, Response, ResumeSeed, DRAINED};
use super::scheduler::{ActiveSeq, QueuedSeq, Scheduler, SchedulerKind};
use crate::kvcache::snapshot::{tags, SnapReader, SnapWriter};
use crate::kvcache::{KvSnapshot, PrefixCache, PrefixRef};
use crate::model::engine::{PrefixSeed, SeededPrefill};
use crate::util::faults::FaultInjector;

/// Factory producing a fresh backend per admitted sequence. Created inside
/// the worker thread (PJRT clients are not Send), hence the two-level
/// `Setup -> Factory` indirection.
pub type BackendFactory = Box<dyn FnMut() -> anyhow::Result<Box<dyn SequenceBackend>>>;
pub type Setup = Box<dyn FnOnce() -> anyhow::Result<BackendFactory> + Send>;

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Max concurrently-decoding sequences.
    pub max_batch: usize,
    /// Aggregate KV budget across active sequences (None = unlimited).
    pub kv_budget_bytes: Option<usize>,
    /// Worker threads for the engines' parallel kernels (prefill GEMMs
    /// and the batched decode projections). Applied as the **process
    /// default** ([`crate::util::threadpool::set_global_threads`]) when
    /// the coordinator starts, so every sequence backend (and the eval
    /// harness, if colocated) shares one pool width instead of each
    /// engine implicitly serializing. `0` = leave the process default
    /// untouched. Results are bit-identical at any width.
    pub threads: usize,
    /// Run admission prefills and decode rounds through the fused
    /// multi-sequence data plane (default). `false` restores the
    /// per-sequence rounds of the pre-batching scheduler — the A/B
    /// baseline for `bench_perf_serving`; token streams are identical
    /// either way.
    pub fused: bool,
    /// Admission/preemption policy (`cskv serve --scheduler …`):
    /// [`SchedulerKind::Fifo`] (default, the A/B baseline),
    /// [`SchedulerKind::SizeAware`], or [`SchedulerKind::Preemptive`].
    pub scheduler: SchedulerKind,
    /// Disk tier directory for the pager (`cskv serve --disk-dir <dir>`,
    /// `--cold-tier` kept as an alias). `None` parks preempted
    /// sequences in RAM only.
    pub disk_dir: Option<std::path::PathBuf>,
    /// Warm (RAM) tier byte budget for parked block runs (`cskv serve
    /// --warm-kb <n>`). `None` = unbounded without a disk tier, zero
    /// with one (whole sequences spill — the old cold-tier shape).
    pub warm_budget_bytes: Option<usize>,
    /// Spill-priority mode for the pager: attention-mass scoring
    /// (default) or the age-only A/B baseline.
    pub pager_scoring: EvictionScoring,
    /// Run the pager's background prefetch thread (default). `false`
    /// makes every disk restore synchronous — the overlap A/B baseline
    /// for `bench_perf_paging`.
    pub pager_prefetch: bool,
    /// Byte budget for the shared-prefix radix cache (`cskv serve
    /// --prefix-cache-kb <n>`). `None` disables prefix reuse; `Some(0)`
    /// is rejected by the CLI up front (a zero-budget trie could never
    /// retain a node).
    pub prefix_cache_bytes: Option<usize>,
    /// Default per-request deadline (`cskv serve --request-timeout
    /// <secs>`), applied at submit time to every request that doesn't
    /// carry its own. `None` = requests wait and run indefinitely.
    pub request_timeout: Option<Duration>,
    /// Fault-injection registry for chaos testing
    /// ([`crate::util::faults`]). The default is inert (one branch per
    /// consulted error path); `rust/tests/chaos_serving.rs` passes a
    /// seeded injector and arms points on its own clone.
    pub faults: FaultInjector,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_batch: 8,
            kv_budget_bytes: None,
            threads: 0,
            fused: true,
            scheduler: SchedulerKind::Fifo,
            disk_dir: None,
            warm_budget_bytes: None,
            pager_scoring: EvictionScoring::Attention,
            pager_prefetch: true,
            prefix_cache_bytes: None,
            request_timeout: None,
            faults: FaultInjector::none(),
        }
    }
}

/// One hot (actively decoding) sequence.
struct Active {
    req: Request,
    backend: Box<dyn SequenceBackend>,
    generated: Vec<usize>,
    queue_wait_s: f64,
    ttft_s: f64,
    started: Instant,
    tok_latencies: Vec<f64>,
    /// Admission pre-charge: projected completion footprint, bytes.
    cost_bytes: usize,
    /// Times this sequence has been swapped out to the cold tier.
    preemptions: usize,
    /// True from restore until the next decoded token: a just-restored
    /// sequence is not preemptable again, so every swap cycle makes at
    /// least one decode round of progress (no snapshot/restore thrash,
    /// no starvation under a sustained short-request stream).
    just_restored: bool,
    /// Set when a decode step errored; the sequence retires with the
    /// tokens generated so far and the error attached.
    failed: Option<String>,
}

/// One preempted sequence: its KV state is parked in the pager; only
/// the request bookkeeping stays resident.
struct Swapped {
    req: Request,
    generated: Vec<usize>,
    queue_wait_s: f64,
    ttft_s: f64,
    started: Instant,
    tok_latencies: Vec<f64>,
    cost_bytes: usize,
    preemptions: usize,
    /// Rounds spent parked since the last swap-out. Past
    /// [`STARVATION_ROUNDS`], admission reserves this sequence's
    /// footprint out of the headroom it offers the scheduler — the
    /// resume-cost pricing that keeps preemption storms from starving
    /// restores.
    parked_rounds: usize,
}

/// One admitted-this-round sequence, waiting for the fused prefill.
struct Admit {
    req: Request,
    backend: Box<dyn SequenceBackend>,
    cost_bytes: usize,
    queue_wait_s: f64,
    started: Instant,
    /// The prefix cache's longest match for this prompt, acquired at
    /// pick time: the owned seed for the prefill plus the trie
    /// reference pinning the matched chain until the round completes.
    seed: Option<(PrefixSeed, PrefixRef)>,
}

/// Client-side handle to one in-flight request: the reply channel plus
/// the [`CancelToken`] that cuts the request loose at the worker's next
/// round boundary.
pub struct RequestHandle {
    pub id: u64,
    pub cancel: CancelToken,
    pub rx: mpsc::Receiver<Response>,
}

/// What the coordinator's control channel carries: requests, or the
/// graceful-drain order.
enum Msg {
    Submit(Request),
    Drain {
        grace: Duration,
        reply: mpsc::Sender<DrainBundle>,
    },
}

/// An in-progress drain inside the worker: stop admitting, let actives
/// run until the deadline, then snapshot whatever is left.
struct DrainGoal {
    deadline: Instant,
    reply: mpsc::Sender<DrainBundle>,
}

/// One sequence migrated out by a graceful drain. `snapshot` is the
/// backend's complete execution state for sequences that were mid-decode
/// (hot or parked in the cold tier); `None` means the request was still
/// queued — a restore re-runs it from the prompt. `generated` holds the
/// tokens already produced (and already streamed to the original
/// client); a resumed stream emits only the tokens after them.
pub struct DrainedSeq {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub n_new: usize,
    pub generated: Vec<usize>,
    pub snapshot: Option<KvSnapshot>,
}

/// Everything a drained coordinator hands to its successor, serialized
/// through the v2 snapshot codec (tag [`tags::DRAIN`], CRC-checked) so a
/// *different process* can load it and resume every sequence
/// bit-identically ([`Coordinator::resume_drained`]). File handoff:
/// [`DrainBundle::save`] / [`DrainBundle::load`].
pub struct DrainBundle {
    pub seqs: Vec<DrainedSeq>,
}

impl DrainBundle {
    pub fn encode(&self) -> KvSnapshot {
        let mut w = SnapWriter::new();
        w.write_usize(self.seqs.len());
        for s in &self.seqs {
            w.u64(s.id);
            w.usizes(&s.prompt);
            w.write_usize(s.n_new);
            w.usizes(&s.generated);
            match &s.snapshot {
                Some(snap) => {
                    w.u8(1);
                    w.nested(snap);
                }
                None => w.u8(0),
            }
        }
        KvSnapshot::new(tags::DRAIN, w.finish())
    }

    pub fn decode(snap: &KvSnapshot) -> anyhow::Result<DrainBundle> {
        snap.expect_tag(tags::DRAIN, "drain bundle")?;
        let mut r = SnapReader::new(snap.payload());
        let n = r.read_usize()?;
        let mut seqs = Vec::new();
        for _ in 0..n {
            let id = r.u64()?;
            let prompt = r.usizes()?;
            let n_new = r.read_usize()?;
            let generated = r.usizes()?;
            let snapshot = match r.u8()? {
                0 => None,
                1 => Some(r.nested()?),
                x => anyhow::bail!("drain bundle: bad snapshot marker {x}"),
            };
            seqs.push(DrainedSeq {
                id,
                prompt,
                n_new,
                generated,
                snapshot,
            });
        }
        r.expect_end()?;
        Ok(DrainBundle { seqs })
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.encode().encode())
            .map_err(|e| anyhow::anyhow!("writing drain bundle {}: {e}", path.display()))
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<DrainBundle> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading drain bundle {}: {e}", path.display()))?;
        DrainBundle::decode(&KvSnapshot::decode(&bytes)?)
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Option<mpsc::Sender<Msg>>,
    worker: Option<thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: std::sync::atomic::AtomicU64,
    request_timeout: Option<Duration>,
}

impl Coordinator {
    /// Start the worker. `setup` runs once inside the worker thread and
    /// returns the per-sequence backend factory.
    pub fn start(setup: Setup, cfg: CoordinatorConfig) -> Self {
        if cfg.threads > 0 {
            crate::util::threadpool::set_global_threads(cfg.threads);
        }
        let request_timeout = cfg.request_timeout;
        let metrics = Arc::new(Metrics::new());
        let m = Arc::clone(&metrics);
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = thread::spawn(move || {
            let mut factory = match setup() {
                Ok(f) => f,
                Err(e) => {
                    crate::log_error!("coordinator setup failed: {e:#}");
                    return;
                }
            };
            worker_loop(rx, &mut factory, &cfg, &m);
        });
        Coordinator {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
            request_timeout,
        }
    }

    /// Submit a request with full lifecycle control: an optional
    /// per-request deadline (overriding the config-level
    /// [`CoordinatorConfig::request_timeout`]) and a [`CancelToken`] the
    /// caller keeps. Invalid requests (empty prompt, `n_new == 0`) are
    /// answered with an immediate error `Response` without reaching the
    /// worker — the library-level mirror of the CLI's flag validation.
    pub fn submit_with(
        &self,
        prompt: Vec<usize>,
        n_new: usize,
        deadline: Option<Duration>,
    ) -> RequestHandle {
        self.submit_inner(prompt, n_new, deadline, None, None)
    }

    /// [`Self::submit_with`] plus a per-token stream: the second return
    /// is fed each token as the worker generates it (prefill's first
    /// token, then every decode step). The final [`Response`] still
    /// arrives on the handle with the complete stream — the HTTP layer's
    /// SSE path consumes both.
    pub fn submit_streaming(
        &self,
        prompt: Vec<usize>,
        n_new: usize,
        deadline: Option<Duration>,
    ) -> (RequestHandle, mpsc::Receiver<usize>) {
        let (stx, srx) = mpsc::channel();
        let h = self.submit_inner(prompt, n_new, deadline, Some(stx), None);
        (h, srx)
    }

    /// Resume one sequence from another coordinator's [`DrainBundle`].
    /// Mid-decode sequences restore their backend snapshot and continue
    /// bit-identically (the stream emits only post-migration tokens);
    /// still-queued sequences re-run from the prompt.
    pub fn resume_drained(
        &self,
        seq: DrainedSeq,
        deadline: Option<Duration>,
    ) -> (RequestHandle, mpsc::Receiver<usize>) {
        let (stx, srx) = mpsc::channel();
        let resume = seq.snapshot.map(|snapshot| ResumeSeed {
            snapshot,
            generated: seq.generated,
        });
        let h = self.submit_inner(seq.prompt, seq.n_new, deadline, Some(stx), resume);
        (h, srx)
    }

    fn submit_inner(
        &self,
        prompt: Vec<usize>,
        n_new: usize,
        deadline: Option<Duration>,
        stream: Option<mpsc::Sender<usize>>,
        resume: Option<ResumeSeed>,
    ) -> RequestHandle {
        let (reply, rx) = mpsc::channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics.mark_start();
        let cancel = CancelToken::new();
        let req = Request {
            id,
            prompt,
            n_new,
            submitted_at: Instant::now(),
            deadline: deadline.or(self.request_timeout).map(|d| Instant::now() + d),
            cancel: cancel.clone(),
            reply,
            stream,
            resume,
        };
        let invalid = if req.prompt.is_empty() {
            Some("empty prompt")
        } else if req.n_new == 0 {
            Some("n_new must be at least 1")
        } else {
            None
        };
        if let Some(reason) = invalid {
            self.metrics.record_failure();
            let _ = req.reply.send(Response::error(&req, reason));
            return RequestHandle { id, cancel, rx };
        }
        let tx = self.tx.as_ref().expect("coordinator already shut down");
        if let Err(mpsc::SendError(Msg::Submit(req))) = tx.send(Msg::Submit(req)) {
            // Worker already exited (a completed drain): shed instead of
            // panicking — the exactly-one-Response contract holds.
            self.metrics.record_shed();
            let _ = req
                .reply
                .send(Response::error(&req, "coordinator stopped: not admitting requests"));
        }
        RequestHandle { id, cancel, rx }
    }

    /// Gracefully drain the worker: stop admitting, give in-flight
    /// sequences `grace` to finish, then snapshot whatever is left (hot,
    /// cold-parked, or still queued) into a [`DrainBundle`]. Every
    /// migrated request is answered with its partial tokens and the
    /// [`DRAINED`] error reason. Errors if a drain is already running.
    pub fn drain(&self, grace: Duration) -> anyhow::Result<DrainBundle> {
        let (reply, rx) = mpsc::channel();
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("coordinator already shut down"))?;
        tx.send(Msg::Drain { grace, reply })
            .map_err(|_| anyhow::anyhow!("coordinator worker already stopped"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("drain already in progress"))
    }

    /// Submit a request; returns the response channel.
    pub fn submit(&self, prompt: Vec<usize>, n_new: usize) -> mpsc::Receiver<Response> {
        self.submit_with(prompt, n_new, None).rx
    }

    /// Submit and block for the response.
    pub fn submit_wait(&self, prompt: Vec<usize>, n_new: usize) -> Response {
        self.submit(prompt, n_new).recv().expect("worker dropped reply")
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Drain the queue (including swapped-out sequences) and stop the
    /// worker.
    pub fn shutdown(mut self) -> super::metrics::MetricsSnapshot {
        self.tx.take(); // close channel
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Answer `req` with an error `Response` and count the failure — the
/// no-hang guarantee: a dropped reply channel would strand `submit_wait`.
fn fail_request(req: Request, err: &str, metrics: &Metrics) {
    crate::log_error!("request {} failed: {err}", req.id);
    metrics.record_failure();
    let _ = req.reply.send(Response::failure(&req, err));
}

/// Answer a swapped-out sequence whose resume failed (cold-tier read,
/// backend construction, or restore error): the tokens generated before
/// preemption are returned alongside the error.
fn fail_swapped(s: Swapped, err: &str, metrics: &Metrics) {
    crate::log_error!("request {} failed after preemption: {err}", s.req.id);
    metrics.record_failure();
    let resp = Response {
        id: s.req.id,
        tokens: s.generated,
        queue_wait_s: s.queue_wait_s,
        ttft_s: s.ttft_s,
        total_s: s.started.elapsed().as_secs_f64() + s.queue_wait_s,
        kv_bytes: 0,
        backend: String::new(),
        error: Some(err.to_string()),
    };
    let _ = s.req.reply.send(resp);
}

/// Why the round-boundary reaper cut a request loose. Cancellation
/// outranks expiry: a request both cancelled and past its deadline
/// reports `"cancelled"` (the client's explicit signal wins).
#[derive(Clone, Copy)]
enum Verdict {
    Cancelled,
    Expired,
}

impl Verdict {
    fn of(req: &Request) -> Option<Verdict> {
        if req.cancelled() {
            Some(Verdict::Cancelled)
        } else if req.expired() {
            Some(Verdict::Expired)
        } else {
            None
        }
    }

    fn reason(self) -> &'static str {
        match self {
            Verdict::Cancelled => "cancelled",
            Verdict::Expired => "deadline exceeded",
        }
    }

    /// Reaped outcomes land in their own counters/distributions, not in
    /// `requests_failed` — nothing broke, the client moved on.
    fn record(self, total_s: f64, metrics: &Metrics) {
        match self {
            Verdict::Cancelled => metrics.record_cancelled(total_s),
            Verdict::Expired => metrics.record_expired(total_s),
        }
    }
}

/// Retire one sequence: record metrics and answer its request. A
/// decode-failed sequence counts as a failure (its partial tokens are
/// returned but stay out of the success distributions).
fn retire(a: Active, metrics: &Metrics) {
    if a.failed.is_some() {
        metrics.record_failure();
    } else {
        metrics.record_completion(Completion {
            id: a.req.id,
            queue_wait_s: a.queue_wait_s,
            ttft_s: a.ttft_s,
            tokens: a.generated.len(),
            tok_latency_s: &a.tok_latencies,
            preemptions: a.preemptions,
        });
    }
    let resp = Response {
        id: a.req.id,
        tokens: a.generated,
        queue_wait_s: a.queue_wait_s,
        ttft_s: a.ttft_s,
        total_s: a.started.elapsed().as_secs_f64() + a.queue_wait_s,
        kv_bytes: a.backend.kv_bytes(),
        backend: a.backend.name(),
        error: a.failed,
    };
    let _ = a.req.reply.send(resp);
}

/// The worker's round state. One instance lives for the worker's whole
/// life; [`worker_loop`] drives one scheduling round per iteration.
struct Worker<'a> {
    cfg: &'a CoordinatorConfig,
    metrics: &'a Metrics,
    scheduler: Box<dyn Scheduler>,
    pager: Pager,
    pending: VecDeque<Request>,
    active: Vec<Active>,
    swapped: Vec<Swapped>,
    batch: BatchScratch,
    /// Shared-prefix radix cache ([`CoordinatorConfig::prefix_cache_bytes`]);
    /// worker-owned, no locking.
    prefix: Option<PrefixCache>,
    /// A constructed-but-unused backend from a blocked admission.
    /// Backends carry no request-specific state before prefill, so the
    /// spare serves whichever request is picked next — `factory()` stays
    /// ~1:1 with admissions instead of re-constructing every blocked
    /// round.
    spare: Option<Box<dyn SequenceBackend>>,
    /// `Some` while a graceful drain is in progress: no admissions, no
    /// pager resumes; actives run until the deadline, then
    /// [`Worker::complete_drain`] migrates everything left.
    drain: Option<DrainGoal>,
}

/// Rounds a swapped sequence may sit parked before admission starts
/// reserving its resume footprint out of the scheduler's headroom.
const STARVATION_ROUNDS: usize = 4;

impl Worker<'_> {
    /// KV bytes the budget must reserve for the hot tier: every active
    /// plus every this-round-admitted sequence at its projected
    /// completion footprint (or its current footprint, if a generation
    /// somehow outgrew the projection).
    fn committed_bytes(&self, admitted: &[Admit]) -> usize {
        self.active
            .iter()
            .map(|a| a.cost_bytes.max(a.backend.kv_bytes()))
            .sum::<usize>()
            + admitted.iter().map(|ad| ad.cost_bytes).sum::<usize>()
    }

    fn take_or_build_backend(
        &mut self,
        factory: &mut BackendFactory,
    ) -> anyhow::Result<Box<dyn SequenceBackend>> {
        match self.spare.take() {
            Some(b) => Ok(b),
            None => {
                // Chaos hook: a fired `backend.build` fault stands in for
                // a real construction failure (allocation, device init).
                self.cfg.faults.trip("backend.build")?;
                factory()
            }
        }
    }

    /// Round-boundary lifecycle enforcement: answer and drop every
    /// cancelled or deadline-expired request, wherever it lives. Queued
    /// requests are rejected without admission; active sequences retire
    /// early with their partial token stream (dropping the backend frees
    /// the hot KV bytes now); swapped sequences discard their cold-tier
    /// blob without decoding it. Runs before admission each round, so an
    /// expired request can never consume a prefill. Returns how many
    /// requests were reaped (the idle wait's progress signal).
    fn reap_lifecycle(&mut self) -> usize {
        let mut reaped = 0;
        let mut i = 0;
        while i < self.pending.len() {
            match Verdict::of(&self.pending[i]) {
                Some(v) => {
                    let req = self.pending.remove(i).expect("index in range");
                    v.record(req.submitted_at.elapsed().as_secs_f64(), self.metrics);
                    let _ = req.reply.send(Response::error(&req, v.reason()));
                    reaped += 1;
                }
                None => i += 1,
            }
        }
        let mut i = 0;
        while i < self.active.len() {
            match Verdict::of(&self.active[i].req) {
                Some(v) => {
                    let a = self.active.swap_remove(i);
                    let total_s = a.started.elapsed().as_secs_f64() + a.queue_wait_s;
                    v.record(total_s, self.metrics);
                    let resp = Response {
                        id: a.req.id,
                        tokens: a.generated,
                        queue_wait_s: a.queue_wait_s,
                        ttft_s: a.ttft_s,
                        total_s,
                        kv_bytes: 0,
                        backend: a.backend.name(),
                        error: Some(v.reason().to_string()),
                    };
                    let _ = a.req.reply.send(resp);
                    reaped += 1;
                }
                None => i += 1,
            }
        }
        let mut i = 0;
        while i < self.swapped.len() {
            match Verdict::of(&self.swapped[i].req) {
                Some(v) => {
                    let s = self.swapped.swap_remove(i);
                    self.pager.discard(s.req.id);
                    let total_s = s.started.elapsed().as_secs_f64() + s.queue_wait_s;
                    v.record(total_s, self.metrics);
                    let resp = Response {
                        id: s.req.id,
                        tokens: s.generated,
                        queue_wait_s: s.queue_wait_s,
                        ttft_s: s.ttft_s,
                        total_s,
                        kv_bytes: 0,
                        backend: String::new(),
                        error: Some(v.reason().to_string()),
                    };
                    let _ = s.req.reply.send(resp);
                    reaped += 1;
                }
                None => i += 1,
            }
        }
        reaped
    }

    /// Swap the `idx`-th active sequence out to the pager. Returns
    /// false (and leaves the sequence hot) if the snapshot or the pager
    /// write fails — preemption is an optimization, never a correctness
    /// risk.
    fn preempt(&mut self, idx: usize) -> bool {
        let id = self.active[idx].req.id;
        let snap = match self.active[idx].backend.snapshot() {
            Ok(s) => s,
            Err(e) => {
                crate::log_error!("snapshot failed for request {id}: {e:#}; not preempting");
                return false;
            }
        };
        // The policy's accumulated attention mass (H2O) ranks this
        // sequence's history blocks for eviction; scoring only — bytes
        // round-trip bit-identically regardless.
        let profile = self.active[idx].backend.attention_profile();
        if let Err(e) = self.pager.put(id, &snap, profile.as_deref()) {
            crate::log_error!("pager write failed for request {id}: {e:#}; not preempting");
            return false;
        }
        let a = self.active.swap_remove(idx);
        // Dropping the backend releases the hot KV memory; only the
        // compressed snapshot (pager tiers) and the bookkeeping survive.
        self.swapped.push(Swapped {
            req: a.req,
            generated: a.generated,
            queue_wait_s: a.queue_wait_s,
            ttft_s: a.ttft_s,
            started: a.started,
            tok_latencies: a.tok_latencies,
            cost_bytes: a.cost_bytes,
            preemptions: a.preemptions + 1,
            parked_rounds: 0,
        });
        self.metrics.record_preemption(self.pager.bytes_resident());
        true
    }

    /// Bring swapped-out sequences back while the batch and KV budget
    /// have headroom, smallest remaining work first. Runs *after* the
    /// round's admissions, so queued work the scheduler prefers always
    /// outranks a restore — a parked sequence can't ping-pong through
    /// the pager while shorter requests keep arriving. When nothing
    /// else is runnable (no actives, no pending), one sequence is
    /// resumed unconditionally so the pager can always drain.
    fn resume_round(&mut self, factory: &mut BackendFactory) -> usize {
        let mut resumed = 0;
        while !self.swapped.is_empty() && self.active.len() < self.cfg.max_batch {
            let idx = self
                .swapped
                .iter()
                .enumerate()
                .min_by_key(|(_, s)| (s.req.n_new.saturating_sub(s.generated.len()), s.req.id))
                .map(|(i, _)| i)
                .expect("non-empty");
            let committed = self.committed_bytes(&[]);
            let fits = self
                .cfg
                .kv_budget_bytes
                .is_none_or(|b| committed + self.swapped[idx].cost_bytes <= b);
            let force = self.active.is_empty() && self.pending.is_empty();
            if !(fits || force) {
                return resumed;
            }
            let s = self.swapped.swap_remove(idx);
            // Wall-clock this take blocks the round — near zero when the
            // prefetch thread already landed the disk blocks.
            let take_started = Instant::now();
            let snap = match self.pager.take(s.req.id) {
                Ok(x) => x,
                Err(e) => {
                    fail_swapped(s, &format!("pager read failed: {e:#}"), self.metrics);
                    continue;
                }
            };
            let restore_stall_s = take_started.elapsed().as_secs_f64();
            let mut backend = match self.take_or_build_backend(factory) {
                Ok(b) => b,
                Err(e) => {
                    fail_swapped(
                        s,
                        &format!("backend construction failed during resume: {e:#}"),
                        self.metrics,
                    );
                    continue;
                }
            };
            if let Err(e) = backend.restore(&snap) {
                // The backend may be half-written — discard it rather
                // than keeping it as a spare.
                fail_swapped(s, &format!("restore failed: {e:#}"), self.metrics);
                continue;
            }
            self.metrics
                .record_restore(self.pager.bytes_resident(), restore_stall_s);
            self.active.push(Active {
                req: s.req,
                backend,
                generated: s.generated,
                queue_wait_s: s.queue_wait_s,
                ttft_s: s.ttft_s,
                started: s.started,
                tok_latencies: s.tok_latencies,
                cost_bytes: s.cost_bytes,
                preemptions: s.preemptions,
                just_restored: true,
                failed: None,
            });
            resumed += 1;
        }
        resumed
    }

    /// Queue pager prefetches for the sequences the *next* resume round
    /// is likely to pick — same smallest-remaining-work order as
    /// [`Worker::resume_round`], bounded by the batch headroom those
    /// resumes could actually use. Runs between resume and decode, so
    /// the background reads overlap the decode round about to execute
    /// instead of stalling the following one. Pure I/O: a wrong guess
    /// wastes a read, never changes bytes.
    fn prefetch_expected_resumes(&mut self) {
        if self.swapped.is_empty() || self.drain.is_some() {
            return;
        }
        let mut order: Vec<(usize, u64)> = self
            .swapped
            .iter()
            .map(|s| (s.req.n_new.saturating_sub(s.generated.len()), s.req.id))
            .collect();
        order.sort_unstable();
        // At least one candidate even with a full batch: retirement can
        // open a slot before the next resume round runs.
        let slots = (self.cfg.max_batch - self.active.len().min(self.cfg.max_batch)).max(1);
        let ids: Vec<u64> = order.into_iter().take(slots).map(|(_, id)| id).collect();
        self.pager.prefetch(&ids);
    }

    /// Collect this round's admission set under the batch-size and
    /// KV-budget constraints, consulting the scheduler for ordering and
    /// (under pressure) preemption. See the module docs for the round
    /// structure and the escape hatch.
    fn collect_admissions(&mut self, factory: &mut BackendFactory) -> Vec<Admit> {
        let mut admitted: Vec<Admit> = Vec::new();
        // Resume-cost pricing: sequences parked past the starvation
        // threshold get their footprint reserved out of the headroom
        // the scheduler is offered, so this round's admissions leave
        // room for next round's restores. The escape hatch below is
        // deliberately exempt — when nothing is running, admitting over
        // budget is still better than idling.
        for s in &mut self.swapped {
            s.parked_rounds += 1;
        }
        let resume_reserved: usize = self
            .swapped
            .iter()
            .filter(|s| s.parked_rounds >= STARVATION_ROUNDS)
            .map(|s| s.cost_bytes)
            .sum();
        // Queue descriptors, priced once per round (every fresh backend
        // carries the same policy configuration, so one backend prices
        // every candidate's pre-charge) and kept in lockstep with
        // `pending` as requests are admitted or failed — admission is
        // O(1) re-pricing per iteration instead of O(pending).
        let mut queued: Vec<QueuedSeq> = Vec::new();
        while self.active.len() + admitted.len() < self.cfg.max_batch && !self.pending.is_empty() {
            let backend = match self.take_or_build_backend(factory) {
                Ok(b) => b,
                Err(e) => {
                    let req = self.pending.pop_front().expect("non-empty");
                    if !queued.is_empty() {
                        queued.remove(0);
                    }
                    fail_request(req, &format!("backend construction failed: {e:#}"), self.metrics);
                    continue;
                }
            };
            if queued.len() != self.pending.len() {
                let prefix = self.prefix.as_ref();
                queued = self
                    .pending
                    .iter()
                    .map(|r| {
                        let total = backend.kv_bytes_projected(r.prompt.len() + r.n_new);
                        // Suffix-only charging: bytes the trie already
                        // holds for this prompt's prefix are counted
                        // once (in the trie), not per admission. `peek`
                        // is read-only — no reference is acquired until
                        // the request is actually picked. Migrated
                        // sequences restore a snapshot instead of
                        // prefilling, so no prefix discount applies.
                        let peeked = if r.resume.is_some() {
                            None
                        } else {
                            prefix.map(|pc| pc.peek(&r.prompt))
                        };
                        let cost_bytes = match peeked {
                            Some(p) if p > 0 => {
                                total.saturating_sub(backend.kv_bytes_projected(p))
                            }
                            _ => total,
                        };
                        QueuedSeq {
                            id: r.id,
                            cost_bytes,
                            work_tokens: r.prompt.len() + r.n_new,
                        }
                    })
                    .collect();
            }
            let committed = self.committed_bytes(&admitted);
            let headroom = self
                .cfg
                .kv_budget_bytes
                .map(|b| b.saturating_sub(committed + resume_reserved));
            let pick = match self.scheduler.pick_admission(&queued, headroom) {
                Some(i) => i,
                None => {
                    if self.active.is_empty() && admitted.is_empty() {
                        // Deadlock escape: nothing is running, so the
                        // preferred candidate is admitted over budget.
                        match self.scheduler.preferred(&queued) {
                            Some(i) => i,
                            None => {
                                self.spare = Some(backend);
                                break;
                            }
                        }
                    } else if self.cfg.kv_budget_bytes.is_some() {
                        // Budget pressure: a preemptive scheduler may
                        // swap out a low-priority active sequence to
                        // fund the preferred candidate; the freed budget
                        // is re-evaluated on the next loop iteration.
                        let pref = self.scheduler.preferred(&queued);
                        let victim = pref.and_then(|p| {
                            // Just-restored sequences are off the table:
                            // each swap cycle must decode at least once.
                            let (idxs, actives): (Vec<usize>, Vec<ActiveSeq>) = self
                                .active
                                .iter()
                                .enumerate()
                                .filter(|(_, a)| !a.just_restored)
                                .map(|(i, a)| {
                                    (
                                        i,
                                        ActiveSeq {
                                            id: a.req.id,
                                            cost_bytes: a.cost_bytes.max(a.backend.kv_bytes()),
                                            remaining_tokens: a
                                                .req
                                                .n_new
                                                .saturating_sub(a.generated.len()),
                                            preemptions: a.preemptions,
                                        },
                                    )
                                })
                                .unzip();
                            self.scheduler.pick_victim(&queued[p], &actives).map(|v| idxs[v])
                        });
                        self.spare = Some(backend);
                        match victim {
                            Some(v) if self.preempt(v) => continue,
                            _ => break,
                        }
                    } else {
                        self.spare = Some(backend);
                        break;
                    }
                }
            };
            let req = self.pending.remove(pick).expect("pick in range");
            let cost_bytes = queued.remove(pick).cost_bytes;
            let queue_wait_s = req.submitted_at.elapsed().as_secs_f64();
            // Acquire the prefix seed now that the pick is final: the
            // lookup pins the matched chain against eviction until the
            // prefill round releases it. Migrated sequences skip the
            // cache entirely — they never prefill.
            let seed = match self.prefix.as_mut().filter(|_| req.resume.is_none()) {
                Some(pc) => {
                    let before = pc.stats().shared_bytes;
                    match pc.lookup(&req.prompt) {
                        Some(hit) => {
                            let served = (pc.stats().shared_bytes - before) as usize;
                            self.metrics.record_prefix_hit(served);
                            Some(hit)
                        }
                        None => {
                            self.metrics.record_prefix_miss();
                            None
                        }
                    }
                }
                None => None,
            };
            admitted.push(Admit {
                req,
                backend,
                cost_bytes,
                queue_wait_s,
                started: Instant::now(),
                seed,
            });
        }
        admitted
    }

    /// Prefill the admission round — fused (weights streamed once across
    /// the round) or per-sequence (A/B baseline). TTFT is taken when a
    /// sequence's first token actually exists: after the whole pass for
    /// the fused round, after each sequence's own prefill for the
    /// sequential baseline.
    fn prefill_round(&mut self, admitted: Vec<Admit>) {
        // Migrated sequences restore their snapshot instead of
        // prefilling; the rest go through the (possibly fused) prefill.
        let (resumes, mut admitted): (Vec<Admit>, Vec<Admit>) = admitted
            .into_iter()
            .partition(|ad| ad.req.resume.is_some());
        for ad in resumes {
            self.restore_admit(ad);
        }
        if admitted.is_empty() {
            return;
        }
        type SeededResult = (anyhow::Result<(usize, Option<SeededPrefill>)>, Option<f64>);
        let results: Vec<SeededResult> = if self.prefix.is_some() {
            // Prefix-cache rounds go through the seeded engine path even
            // at width 1: warm sequences prefill only their unshared
            // suffix, and every prompt's activations are captured for
            // publication into the trie.
            if self.cfg.fused {
                let mut bs: Vec<&mut dyn SequenceBackend> = Vec::with_capacity(admitted.len());
                let mut prompts: Vec<&[usize]> = Vec::with_capacity(admitted.len());
                let mut seeds: Vec<Option<&PrefixSeed>> = Vec::with_capacity(admitted.len());
                for ad in admitted.iter_mut() {
                    prompts.push(&ad.req.prompt);
                    seeds.push(ad.seed.as_ref().map(|(s, _)| s));
                    bs.push(ad.backend.as_mut());
                }
                prefill_batch_seeded(&mut bs, &prompts, &seeds, true, &mut self.batch)
                    .into_iter()
                    .map(|r| (r, None))
                    .collect()
            } else {
                admitted
                    .iter_mut()
                    .map(|ad| {
                        let seed = ad.seed.as_ref().map(|(s, _)| s);
                        let r = prefill_batch_seeded(
                            &mut [ad.backend.as_mut()],
                            &[&ad.req.prompt],
                            &[seed],
                            true,
                            &mut self.batch,
                        )
                        .pop()
                        .expect("one sequence in, one result out");
                        let ttft = ad.req.submitted_at.elapsed().as_secs_f64();
                        (r, Some(ttft))
                    })
                    .collect()
            }
        } else if self.cfg.fused {
            let mut bs: Vec<&mut dyn SequenceBackend> = Vec::with_capacity(admitted.len());
            let mut prompts: Vec<&[usize]> = Vec::with_capacity(admitted.len());
            for ad in admitted.iter_mut() {
                prompts.push(&ad.req.prompt);
                bs.push(ad.backend.as_mut());
            }
            prefill_batch(&mut bs, &prompts, &mut self.batch)
                .into_iter()
                .map(|r| (r.map(|tok| (tok, None)), None))
                .collect()
        } else {
            admitted
                .iter_mut()
                .map(|ad| {
                    let r = ad.backend.prefill(&ad.req.prompt);
                    let ttft = ad.req.submitted_at.elapsed().as_secs_f64();
                    (r.map(|tok| (tok, None)), Some(ttft))
                })
                .collect()
        };
        for (mut ad, (res, ttft)) in admitted.into_iter().zip(results) {
            if let Some(pc) = self.prefix.as_mut() {
                // Release the pinned chain first so publication's LRU
                // pass sees true refcounts, then publish this prompt's
                // prefix (deduplicated against existing nodes; the
                // sequence's own seed rows are owned copies, so eviction
                // can't touch in-flight state).
                if let Some((_, pin)) = ad.seed.take() {
                    pc.release(pin);
                }
                if let Ok((_, Some(sp))) = &res {
                    pc.publish(&ad.req.prompt, sp);
                }
                self.metrics
                    .record_prefix_cache(pc.resident_bytes(), pc.stats().evictions);
            }
            match res {
                Ok((first, _)) => {
                    let ttft_s =
                        ttft.unwrap_or_else(|| ad.req.submitted_at.elapsed().as_secs_f64());
                    ad.req.stream_token(first);
                    self.active.push(Active {
                        req: ad.req,
                        backend: ad.backend,
                        generated: vec![first],
                        queue_wait_s: ad.queue_wait_s,
                        ttft_s,
                        started: ad.started,
                        tok_latencies: Vec::new(),
                        cost_bytes: ad.cost_bytes,
                        preemptions: 0,
                        just_restored: false,
                        failed: None,
                    });
                }
                Err(e) => {
                    fail_request(ad.req, &format!("prefill failed: {e:#}"), self.metrics);
                }
            }
        }
    }

    /// Admit one migrated sequence: restore the drained process's
    /// backend snapshot and rejoin the decode rounds mid-generation.
    /// Tokens in `generated` were already streamed by the original
    /// process, so they are not re-emitted; the next decode step
    /// continues the stream bit-identically.
    fn restore_admit(&mut self, mut ad: Admit) {
        let seed = ad.req.resume.take().expect("partitioned on resume");
        if let Err(e) = ad.backend.restore(&seed.snapshot) {
            fail_request(
                ad.req,
                &format!("restore of migrated sequence failed: {e:#}"),
                self.metrics,
            );
            return;
        }
        self.active.push(Active {
            req: ad.req,
            backend: ad.backend,
            generated: seed.generated,
            queue_wait_s: ad.queue_wait_s,
            // First token belonged to the drained process; this side's
            // TTFT is not meaningful.
            ttft_s: 0.0,
            started: ad.started,
            tok_latencies: Vec::new(),
            cost_bytes: ad.cost_bytes,
            preemptions: 0,
            just_restored: false,
            failed: None,
        });
    }

    /// One decode round across every unfinished sequence — a single
    /// fused call (or per-sequence steps in the A/B baseline). Returns
    /// how many sequences stepped.
    fn decode_round(&mut self) -> usize {
        let mut round: Vec<usize> = Vec::with_capacity(self.active.len());
        let mut bs: Vec<&mut dyn SequenceBackend> = Vec::with_capacity(self.active.len());
        for (i, a) in self.active.iter_mut().enumerate() {
            if a.generated.len() < a.req.n_new {
                round.push(i);
                bs.push(a.backend.as_mut());
            }
        }
        if bs.is_empty() {
            return 0;
        }
        let (results, lats): (Vec<anyhow::Result<usize>>, Vec<f64>) = if self.cfg.fused {
            let t0 = Instant::now();
            let r = decode_batch(&mut bs, &mut self.batch);
            // Fused rounds are timed as a whole; each sequence is
            // attributed its per-token share.
            let share = t0.elapsed().as_secs_f64() / r.len() as f64;
            let n = r.len();
            (r, vec![share; n])
        } else {
            let mut lats = Vec::with_capacity(bs.len());
            let r = bs
                .iter_mut()
                .map(|b| {
                    let t0 = Instant::now();
                    let res = b.decode_next();
                    lats.push(t0.elapsed().as_secs_f64());
                    res
                })
                .collect();
            (r, lats)
        };
        drop(bs);
        let stepped = round.len();
        for ((&i, res), lat) in round.iter().zip(results).zip(lats) {
            match res {
                Ok(tok) => {
                    self.active[i].tok_latencies.push(lat);
                    self.active[i].generated.push(tok);
                    self.active[i].req.stream_token(tok);
                    // Progress made: the sequence is preemptable again.
                    self.active[i].just_restored = false;
                }
                Err(e) => {
                    crate::log_error!("decode failed for request {}: {e:#}", self.active[i].req.id);
                    self.active[i].failed = Some(format!("decode failed: {e:#}"));
                }
            }
        }
        stepped
    }

    /// Retire finished (or failed) sequences. Returns how many retired.
    fn retire_finished(&mut self) -> usize {
        let mut retired = 0;
        let mut i = 0;
        while i < self.active.len() {
            let done = self.active[i].failed.is_some()
                || self.active[i].generated.len() >= self.active[i].req.n_new;
            if done {
                retire(self.active.swap_remove(i), self.metrics);
                retired += 1;
            } else {
                i += 1;
            }
        }
        retired
    }

    /// Nothing queued, running, or parked.
    fn drained(&self) -> bool {
        self.active.is_empty() && self.pending.is_empty() && self.swapped.is_empty()
    }

    /// Route one control message. During a drain, new submissions are
    /// shed immediately (answered, counted) instead of queued; a second
    /// drain order is rejected by dropping its reply channel.
    fn accept(&mut self, m: Msg) {
        match m {
            Msg::Submit(req) => {
                if self.drain.is_some() {
                    self.metrics.record_shed();
                    let _ = req
                        .reply
                        .send(Response::error(&req, "draining: not admitting new requests"));
                } else {
                    self.pending.push_back(req);
                }
            }
            Msg::Drain { grace, reply } => {
                if self.drain.is_none() {
                    self.drain = Some(DrainGoal {
                        deadline: Instant::now() + grace,
                        reply,
                    });
                }
                // else: drop `reply` — the second drain() call errors.
            }
        }
    }

    /// How long the idle wait may sleep: until the earliest deadline
    /// anywhere in the system (queued, active, swapped, or the drain
    /// grace), capped at a short poll tick so client-side cancellation
    /// is also noticed promptly. This is the satellite fix for deadline
    /// skew — a request can no longer sit past its deadline just
    /// because the submission queue is quiet.
    fn next_wakeup(&self) -> Duration {
        const POLL: Duration = Duration::from_millis(25);
        let now = Instant::now();
        let mut wait = POLL;
        let mut consider = |deadline: Option<Instant>| {
            if let Some(d) = deadline {
                wait = wait.min(d.saturating_duration_since(now));
            }
        };
        for r in &self.pending {
            consider(r.deadline);
        }
        for a in &self.active {
            consider(a.req.deadline);
        }
        for s in &self.swapped {
            consider(s.req.deadline);
        }
        if let Some(g) = &self.drain {
            consider(Some(g.deadline));
        }
        wait
    }

    /// Finish a drain: everything still in the system is migrated into a
    /// [`DrainBundle`] — hot actives snapshot their backend state,
    /// cold-parked sequences contribute the blob already in the tier,
    /// queued requests travel as prompt + `n_new` (no state yet). Each
    /// migrated request is answered with its partial tokens and the
    /// [`DRAINED`] reason; snapshot failures degrade to plain failures
    /// (the request is answered either way). Gauges are re-zeroed so the
    /// no-leak invariant (`kv_bytes_current == 0`, `cold_bytes_current
    /// == 0`) holds after the worker exits.
    fn complete_drain(&mut self, goal: DrainGoal) {
        let mut seqs = Vec::new();
        for a in self.active.drain(..) {
            match a.backend.snapshot() {
                Ok(snap) => {
                    self.metrics.record_drained();
                    let resp = Response {
                        id: a.req.id,
                        tokens: a.generated.clone(),
                        queue_wait_s: a.queue_wait_s,
                        ttft_s: a.ttft_s,
                        total_s: a.started.elapsed().as_secs_f64() + a.queue_wait_s,
                        kv_bytes: 0,
                        backend: a.backend.name(),
                        error: Some(DRAINED.to_string()),
                    };
                    let _ = a.req.reply.send(resp);
                    seqs.push(DrainedSeq {
                        id: a.req.id,
                        prompt: a.req.prompt.clone(),
                        n_new: a.req.n_new,
                        generated: a.generated,
                        snapshot: Some(snap),
                    });
                }
                Err(e) => {
                    self.metrics.record_failure();
                    crate::log_error!("drain snapshot failed for request {}: {e:#}", a.req.id);
                    let resp = Response {
                        id: a.req.id,
                        tokens: a.generated,
                        queue_wait_s: a.queue_wait_s,
                        ttft_s: a.ttft_s,
                        total_s: a.started.elapsed().as_secs_f64() + a.queue_wait_s,
                        kv_bytes: 0,
                        backend: a.backend.name(),
                        error: Some(format!("drain snapshot failed: {e:#}")),
                    };
                    let _ = a.req.reply.send(resp);
                }
            }
        }
        for s in std::mem::take(&mut self.swapped) {
            match self.pager.take(s.req.id) {
                Ok(snap) => {
                    self.metrics.record_drained();
                    let resp = Response {
                        id: s.req.id,
                        tokens: s.generated.clone(),
                        queue_wait_s: s.queue_wait_s,
                        ttft_s: s.ttft_s,
                        total_s: s.started.elapsed().as_secs_f64() + s.queue_wait_s,
                        kv_bytes: 0,
                        backend: String::new(),
                        error: Some(DRAINED.to_string()),
                    };
                    let _ = s.req.reply.send(resp);
                    seqs.push(DrainedSeq {
                        id: s.req.id,
                        prompt: s.req.prompt.clone(),
                        n_new: s.req.n_new,
                        generated: s.generated,
                        snapshot: Some(snap),
                    });
                }
                Err(e) => {
                    fail_swapped(s, &format!("pager read failed during drain: {e:#}"), self.metrics);
                }
            }
        }
        for req in self.pending.drain(..) {
            self.metrics.record_drained();
            let resp = Response::error(&req, DRAINED);
            seqs.push(DrainedSeq {
                id: req.id,
                prompt: req.prompt.clone(),
                n_new: req.n_new,
                generated: Vec::new(),
                snapshot: None,
            });
            let _ = req.reply.send(resp);
        }
        self.metrics.record_kv(0, 0);
        self.metrics.record_pager(
            self.pager.warm_bytes_resident(),
            self.pager.disk_bytes_resident(),
            self.pager.stats(),
        );
        let _ = goal.reply.send(DrainBundle { seqs });
    }
}

fn worker_loop(
    rx: mpsc::Receiver<Msg>,
    factory: &mut BackendFactory,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
) {
    let mut w = Worker {
        cfg,
        metrics,
        scheduler: cfg.scheduler.build(),
        pager: Pager::with_faults(
            PagerConfig {
                disk_dir: cfg.disk_dir.clone(),
                warm_budget_bytes: cfg.warm_budget_bytes,
                block_bytes: super::pager::DEFAULT_BLOCK_BYTES,
                scoring: cfg.pager_scoring,
                prefetch: cfg.pager_prefetch,
            },
            cfg.faults.clone(),
        ),
        pending: VecDeque::new(),
        active: Vec::new(),
        swapped: Vec::new(),
        batch: BatchScratch::default(),
        prefix: cfg.prefix_cache_bytes.map(PrefixCache::new),
        spare: None,
        drain: None,
    };
    // Did the previous round change any state? While true the loop spins
    // hot (real work is flowing); once false it sleeps deadline-aware
    // (`next_wakeup`) so a quiet queue never delays expiry enforcement
    // and a stuck plane never busy-waits.
    let mut progress = true;
    let mut closed = false;
    loop {
        if w.drained() && w.drain.is_none() {
            // Fully idle: block until the next message (a parked or
            // draining plane never reaches this branch).
            if closed {
                break;
            }
            match rx.recv() {
                Ok(m) => w.accept(m),
                Err(_) => break, // channel closed and nothing to do
            }
        } else if !progress {
            if closed {
                if w.active.is_empty() && w.swapped.is_empty() && w.drain.is_none() {
                    // Nothing can ever run these (e.g. `max_batch` 0 with
                    // no deadline) and no submitter remains — answer
                    // rather than sleep forever.
                    for req in w.pending.drain(..) {
                        fail_request(req, "coordinator stopped before this request could run", metrics);
                    }
                    break;
                }
                thread::sleep(w.next_wakeup());
            } else {
                match rx.recv_timeout(w.next_wakeup()) {
                    Ok(m) => w.accept(m),
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => closed = true,
                }
            }
        }
        while let Ok(m) = rx.try_recv() {
            w.accept(m);
        }

        // Lifecycle first: expired/cancelled requests must never reach
        // the scheduler, consume a prefill, or hold KV another round.
        let reaped = w.reap_lifecycle();

        // A draining plane admits and resumes nothing: pending requests
        // are held for migration, cold-parked blobs are bundled as-is.
        let admitted = if w.drain.is_some() {
            Vec::new()
        } else {
            w.collect_admissions(factory)
        };
        let n_admitted = admitted.len();
        w.prefill_round(admitted);
        let resumed = if w.drain.is_some() {
            0
        } else {
            w.resume_round(factory)
        };
        // Overlap: kick background restores for the sequences the *next*
        // round is expected to resume, so their disk blocks land while
        // this round's decode GEMMs run.
        w.prefetch_expected_resumes();

        let kv_now: usize = w.active.iter().map(|a| a.backend.kv_bytes()).sum();
        metrics.record_kv(kv_now, w.active.len());

        let stepped = w.decode_round();
        let retired = w.retire_finished();

        // Refresh the drain-state gauges *after* retirement so a fully
        // drained plane reads zero committed KV and empty pager tiers —
        // the no-leak observable the chaos suite asserts on.
        let kv_after: usize = w.active.iter().map(|a| a.backend.kv_bytes()).sum();
        metrics.record_kv(kv_after, w.active.len());
        metrics.record_pager(
            w.pager.warm_bytes_resident(),
            w.pager.disk_bytes_resident(),
            w.pager.stats(),
        );

        // A drain completes when the hot tier empties or the grace
        // deadline passes — whichever comes first. Afterwards the worker
        // only sheds: every late submission is still answered (the
        // exactly-one-Response contract), and the thread exits when the
        // coordinator handle closes the channel.
        if w
            .drain
            .as_ref()
            .is_some_and(|g| w.active.is_empty() || Instant::now() >= g.deadline)
        {
            let goal = w.drain.take().expect("checked above");
            w.complete_drain(goal);
            while let Ok(m) = rx.recv() {
                match m {
                    Msg::Submit(req) => {
                        metrics.record_shed();
                        let _ = req
                            .reply
                            .send(Response::error(&req, "draining: coordinator already drained"));
                    }
                    Msg::Drain { reply, .. } => {
                        // Idempotent: a second drain finds nothing left.
                        let _ = reply.send(DrainBundle { seqs: Vec::new() });
                    }
                }
            }
            break;
        }

        progress = reaped + n_admitted + resumed + stepped + retired > 0;

        // Exit when the channel is closed and all work is drained.
        if w.drained() && w.drain.is_none() {
            match rx.try_recv() {
                Ok(m) => w.accept(m),
                Err(mpsc::TryRecvError::Disconnected) => break,
                Err(mpsc::TryRecvError::Empty) => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::RustSequenceBackend;
    use crate::kvcache::FullCache;
    use crate::model::{engine::Engine, ModelConfig, ModelWeights};
    use std::sync::Arc as StdArc;

    fn test_setup() -> Setup {
        Box::new(|| {
            let cfg = ModelConfig::test_small();
            let engine = Engine::new(StdArc::new(ModelWeights::init(&cfg, 5)));
            let factory: BackendFactory = Box::new(move || {
                let c = engine.w.cfg.clone();
                Ok(Box::new(RustSequenceBackend::new(
                    engine.clone(),
                    Box::new(FullCache::new(c.n_layers, c.d_model)),
                )))
            });
            Ok(factory)
        })
    }

    #[test]
    fn serves_batched_requests() {
        let coord = Coordinator::start(test_setup(), CoordinatorConfig::default());
        let rxs: Vec<_> = (0..5)
            .map(|i| coord.submit(vec![1, 2 + i, 3, 4], 4))
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.tokens.len(), 4);
            assert!(resp.error.is_none());
            assert!(resp.ttft_s >= resp.queue_wait_s);
            assert!(resp.kv_bytes > 0);
        }
        let snap = coord.shutdown();
        assert_eq!(snap.requests_completed, 5);
        assert_eq!(snap.tokens_generated, 20);
        assert!(snap.active_peak >= 2, "batching should overlap requests");
        assert_eq!(snap.preemptions, 0, "fifo never preempts");
    }

    #[test]
    fn kv_budget_limits_concurrency() {
        // Budget fits ~1 sequence ⇒ active_peak must stay small even with
        // many queued requests.
        let cfg = ModelConfig::test_small();
        let one_seq_bytes = cfg.kv_bytes_full(12);
        let coord = Coordinator::start(
            test_setup(),
            CoordinatorConfig {
                max_batch: 8,
                kv_budget_bytes: Some(one_seq_bytes),
                ..Default::default()
            },
        );
        let rxs: Vec<_> = (0..4).map(|_| coord.submit(vec![1, 2, 3, 4, 5, 6], 6)).collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().tokens.len(), 6);
        }
        let snap = coord.shutdown();
        assert_eq!(snap.requests_completed, 4);
        assert!(
            snap.active_peak <= 2,
            "budget should throttle concurrency, got {}",
            snap.active_peak
        );
    }

    /// Admission pre-charges each request's projected completion
    /// footprint (prompt + n_new): with a budget that fits one request
    /// but not two, a second must *not* be co-admitted just because the
    /// current footprint still looks small. The old current-footprint-
    /// only check admitted it (kv_now = one prompt < budget) and blew
    /// past the budget at the second prefill.
    #[test]
    fn admission_precharge_prevents_budget_overshoot() {
        let cfg = ModelConfig::test_small();
        // Budget: 10 tokens. Each request projects to 12 tokens at
        // completion (8 prompt + 4 generated), so requests must run
        // strictly one at a time (the first admits via the
        // can't-deadlock escape hatch).
        let budget = cfg.kv_bytes_full(10);
        let coord = Coordinator::start(
            test_setup(),
            CoordinatorConfig {
                max_batch: 8,
                kv_budget_bytes: Some(budget),
                ..Default::default()
            },
        );
        let rxs: Vec<_> = (0..3)
            .map(|i| coord.submit(vec![1, 2, 3, 4, 5, 6, 7, 8 + i], 4))
            .collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().tokens.len(), 4);
        }
        let snap = coord.shutdown();
        assert_eq!(snap.requests_completed, 3);
        assert_eq!(
            snap.active_peak, 1,
            "pre-charge must serialize prompts that can't share the budget"
        );
    }

    #[test]
    fn deterministic_vs_direct_engine() {
        let cfg = ModelConfig::test_small();
        let engine = Engine::new(StdArc::new(ModelWeights::init(&cfg, 5)));
        let prompt = vec![1usize, 7, 9, 2];
        let mut cache = FullCache::new(cfg.n_layers, cfg.d_model);
        let (want, _) = engine.generate(&prompt, 5, &mut cache);
        let coord = Coordinator::start(test_setup(), CoordinatorConfig::default());
        let resp = coord.submit_wait(prompt, 5);
        assert_eq!(resp.tokens, want);
    }

    /// The preemptive tentpole, end to end: a long generation hogging
    /// the whole budget is swapped out to the pager when a short
    /// request arrives, the short request runs to completion first, and
    /// the long one resumes **bit-identically** — same token stream as
    /// an unpreempted direct-engine run. Exercised against both pager
    /// shapes (warm-only and disk spill).
    #[test]
    fn preemptive_swaps_out_long_sequence_and_resumes_bit_identically() {
        let cfg = ModelConfig::test_small();
        let engine = Engine::new(StdArc::new(ModelWeights::init(&cfg, 5)));
        let long_prompt = vec![1usize, 7, 9, 2, 30, 41];
        let short_prompt = vec![3usize, 5, 8];
        let (long_n, short_n) = (120usize, 2usize);
        let mut c1 = FullCache::new(cfg.n_layers, cfg.d_model);
        let (want_long, _) = engine.generate(&long_prompt, long_n, &mut c1);
        let mut c2 = FullCache::new(cfg.n_layers, cfg.d_model);
        let (want_short, _) = engine.generate(&short_prompt, short_n, &mut c2);

        let disk_dir = std::env::temp_dir()
            .join(format!("cskv-preempt-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&disk_dir);
        for disk_dir in [None, Some(disk_dir.clone())] {
            // Budget fits the long projection (126 tokens) but not long
            // + short (131): admitting the short request requires
            // swapping the long one out.
            let budget = cfg.kv_bytes_full(128);
            let coord = Coordinator::start(
                test_setup(),
                CoordinatorConfig {
                    max_batch: 4,
                    kv_budget_bytes: Some(budget),
                    scheduler: SchedulerKind::Preemptive,
                    disk_dir,
                    ..Default::default()
                },
            );
            let long_rx = coord.submit(long_prompt.clone(), long_n);
            // Wait until the long request is hot, then submit the short.
            let t0 = Instant::now();
            while coord.metrics().kv_bytes_current() == 0 {
                assert!(t0.elapsed().as_secs() < 30, "long request never started");
                std::thread::yield_now();
            }
            let short = coord.submit_wait(short_prompt.clone(), short_n);
            assert!(short.error.is_none(), "{:?}", short.error);
            assert_eq!(short.tokens, want_short);
            let long = long_rx.recv().unwrap();
            assert!(long.error.is_none(), "{:?}", long.error);
            assert_eq!(
                long.tokens, want_long,
                "preempted + restored stream must equal the unpreempted run"
            );
            let snap = coord.shutdown();
            assert_eq!(snap.requests_completed, 2);
            assert!(snap.preemptions >= 1, "long sequence must be swapped out");
            assert_eq!(snap.restores, snap.preemptions, "every swap resumes");
            assert!(snap.cold_bytes_peak > 0);
            assert_eq!(
                *snap.completion_order.first().unwrap(),
                short.id,
                "short request retires before the preempted long one"
            );
            assert_eq!(snap.ttft_preempted_s.len(), 1, "long TTFT lands in the preempted split");
        }
        let _ = std::fs::remove_dir_all(&disk_dir);
    }

    /// SizeAware never preempts; under pressure it simply orders
    /// admissions shortest-first. Sanity-check the config plumbing.
    #[test]
    fn size_aware_orders_without_preempting() {
        let coord = Coordinator::start(
            test_setup(),
            CoordinatorConfig {
                max_batch: 2,
                scheduler: SchedulerKind::SizeAware,
                ..Default::default()
            },
        );
        let rxs: Vec<_> = (0..4).map(|i| coord.submit(vec![1, 2 + i, 3], 3)).collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none());
            assert_eq!(r.tokens.len(), 3);
        }
        let snap = coord.shutdown();
        assert_eq!(snap.requests_completed, 4);
        assert_eq!(snap.preemptions, 0);
    }

    /// Prefix-cache reuse must be invisible in the token stream: prompts
    /// sharing a 64-token (block-aligned) prefix generate bit-identical
    /// completions with the cache on vs. off, while the metrics show the
    /// later prompts actually hit the trie and reused shared bytes.
    #[test]
    fn prefix_cache_seeds_shared_prompts_bit_identically() {
        let shared: Vec<usize> = (0..64).map(|i| (i * 7 + 3) % 50).collect();
        let mk = |tail: usize| {
            let mut p = shared.clone();
            p.extend_from_slice(&[tail, tail + 1, tail + 2]);
            p
        };
        let run = |prefix_cache_bytes: Option<usize>| {
            let coord = Coordinator::start(
                test_setup(),
                CoordinatorConfig { prefix_cache_bytes, ..Default::default() },
            );
            // Sequential submit/wait so each prompt's prefix is published
            // before the next is admitted.
            let outs: Vec<Vec<usize>> = (0..3)
                .map(|i| {
                    let r = coord.submit(mk(60 + 10 * i), 8).recv().unwrap();
                    assert!(r.error.is_none(), "{:?}", r.error);
                    r.tokens
                })
                .collect();
            (outs, coord.shutdown())
        };
        let (cold, cold_snap) = run(None);
        let (warm, warm_snap) = run(Some(64 << 20));
        assert_eq!(warm, cold, "seeded prefill must not change any token");
        assert_eq!(cold_snap.prefix_hits, 0);
        assert!(
            warm_snap.prefix_hits >= 2,
            "second and third prompts should hit, got {}",
            warm_snap.prefix_hits
        );
        assert!(warm_snap.prefix_shared_bytes > 0);
        assert!(warm_snap.prefix_bytes_peak > 0);
    }

    #[test]
    fn streaming_submit_emits_every_token_in_order() {
        let cfg = ModelConfig::test_small();
        let engine = Engine::new(StdArc::new(ModelWeights::init(&cfg, 5)));
        let prompt = vec![1usize, 7, 9, 2];
        let mut cache = FullCache::new(cfg.n_layers, cfg.d_model);
        let (want, _) = engine.generate(&prompt, 6, &mut cache);
        let coord = Coordinator::start(test_setup(), CoordinatorConfig::default());
        let (h, tokens) = coord.submit_streaming(prompt, 6, None);
        let resp = h.rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.tokens, want);
        let streamed: Vec<usize> = tokens.try_iter().collect();
        assert_eq!(streamed, want, "stream must mirror the final response");
        coord.shutdown();
    }

    #[test]
    fn drain_bundle_codec_roundtrips_via_file() {
        let bundle = DrainBundle {
            seqs: vec![
                DrainedSeq {
                    id: 7,
                    prompt: vec![1, 2, 3],
                    n_new: 9,
                    generated: vec![4, 5],
                    snapshot: Some(KvSnapshot::new(tags::FULL, vec![1, 2, 3, 4])),
                },
                DrainedSeq {
                    id: 9,
                    prompt: vec![8],
                    n_new: 2,
                    generated: Vec::new(),
                    snapshot: None,
                },
            ],
        };
        let path = std::env::temp_dir()
            .join(format!("cskv-drain-bundle-test-{}.bin", std::process::id()));
        bundle.save(&path).unwrap();
        let back = DrainBundle::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back.seqs.len(), 2);
        assert_eq!(back.seqs[0].id, 7);
        assert_eq!(back.seqs[0].prompt, vec![1, 2, 3]);
        assert_eq!(back.seqs[0].n_new, 9);
        assert_eq!(back.seqs[0].generated, vec![4, 5]);
        let s = back.seqs[0].snapshot.as_ref().unwrap();
        assert_eq!(s.tag(), tags::FULL);
        assert_eq!(s.payload(), &[1, 2, 3, 4]);
        assert!(back.seqs[1].snapshot.is_none());
    }

    #[test]
    fn drain_idle_coordinator_returns_empty_bundle_and_sheds_afterwards() {
        let coord = Coordinator::start(test_setup(), CoordinatorConfig::default());
        let bundle = coord.drain(Duration::from_millis(10)).unwrap();
        assert!(bundle.seqs.is_empty());
        // Submissions after the drain are shed (answered), never dropped.
        let resp = coord.submit_wait(vec![1, 2, 3], 2);
        let err = resp.error.expect("post-drain submit must be answered with an error");
        assert!(err.contains("drain") || err.contains("stopped"), "{err}");
        let snap = coord.shutdown();
        assert_eq!(snap.requests_shed, 1);
    }

    /// A still-queued request migrates as prompt + `n_new` (no backend
    /// state), is answered `DRAINED`, and a *fresh* coordinator resumes
    /// it from the bundle producing the undisturbed token stream.
    #[test]
    fn drain_migrates_queued_request_and_fresh_coordinator_runs_it() {
        let cfg = ModelConfig::test_small();
        let engine = Engine::new(StdArc::new(ModelWeights::init(&cfg, 5)));
        let prompt = vec![1usize, 7, 9, 2];
        let mut cache = FullCache::new(cfg.n_layers, cfg.d_model);
        let (want, _) = engine.generate(&prompt, 5, &mut cache);

        // `max_batch: 0` keeps the request queued until the drain order
        // lands (both messages ride the same FIFO channel).
        let coord = Coordinator::start(
            test_setup(),
            CoordinatorConfig { max_batch: 0, ..Default::default() },
        );
        let h = coord.submit_with(prompt.clone(), 5, None);
        let bundle = coord.drain(Duration::ZERO).unwrap();
        let resp = h.rx.recv().unwrap();
        assert_eq!(resp.error.as_deref(), Some(DRAINED));
        assert!(resp.tokens.is_empty());
        assert_eq!(bundle.seqs.len(), 1);
        assert!(bundle.seqs[0].snapshot.is_none(), "still queued: no state yet");
        assert_eq!(bundle.seqs[0].prompt, prompt);
        let snap = coord.shutdown();
        assert_eq!(snap.requests_drained, 1);
        assert_eq!(snap.kv_bytes_current, 0, "drained plane leaks no KV");
        assert_eq!(snap.cold_bytes_current, 0);

        let coord2 = Coordinator::start(test_setup(), CoordinatorConfig::default());
        let (h2, tokens) =
            coord2.resume_drained(bundle.seqs.into_iter().next().unwrap(), None);
        let resp2 = h2.rx.recv().unwrap();
        assert!(resp2.error.is_none(), "{:?}", resp2.error);
        assert_eq!(resp2.tokens, want, "resumed run must match the undisturbed one");
        let streamed: Vec<usize> = tokens.try_iter().collect();
        assert_eq!(streamed, want);
        coord2.shutdown();
    }

    /// The satellite fix: with a quiet submission queue and nothing
    /// runnable (`max_batch: 0`), deadline expiry and cancellation are
    /// answered by the timeout-aware idle wait — not deferred until the
    /// next submission arrives.
    #[test]
    fn queued_deadline_and_cancel_answer_promptly_on_a_quiet_queue() {
        let coord = Coordinator::start(
            test_setup(),
            CoordinatorConfig { max_batch: 0, ..Default::default() },
        );
        let t0 = Instant::now();
        let h = coord.submit_with(vec![1, 2, 3], 4, Some(Duration::from_millis(80)));
        let resp = h.rx.recv().unwrap();
        assert_eq!(resp.error.as_deref(), Some("deadline exceeded"));
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "expiry must answer without a follow-up submission, took {:?}",
            t0.elapsed()
        );

        let h2 = coord.submit_with(vec![1, 2, 3], 4, None);
        h2.cancel.cancel();
        let resp2 = h2.rx.recv().unwrap();
        assert_eq!(resp2.error.as_deref(), Some("cancelled"));
        drop(coord); // worker exits cleanly once drained
    }
}
