//! The coordinator thread: queueing, KV-budget admission, continuous
//! batching, completion.
//!
//! Scheduling model (single-worker continuous batching):
//!
//! 1. Requests land in an mpsc queue.
//! 2. The worker admits queued requests into the active set while
//!    `active < max_batch` **and** the aggregate KV footprint stays under
//!    `kv_budget_bytes` — the admission test uses each backend's real
//!    [`SequenceBackend::kv_bytes`], so compressed-cache policies admit
//!    proportionally more concurrent sequences (the serving-side win of
//!    the paper, measured by `bench_perf_decode`).
//! 3. Each scheduling round decodes one token for every active sequence
//!    (round-robin), then re-admits — i.e. new requests don't wait for the
//!    whole batch to drain (continuous batching à la Orca/vLLM).

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use super::backend::SequenceBackend;
use super::metrics::Metrics;
use super::request::{Request, Response};

/// Factory producing a fresh backend per admitted sequence. Created inside
/// the worker thread (PJRT clients are not Send), hence the two-level
/// `Setup -> Factory` indirection.
pub type BackendFactory = Box<dyn FnMut() -> anyhow::Result<Box<dyn SequenceBackend>>>;
pub type Setup = Box<dyn FnOnce() -> anyhow::Result<BackendFactory> + Send>;

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Max concurrently-decoding sequences.
    pub max_batch: usize,
    /// Aggregate KV budget across active sequences (None = unlimited).
    pub kv_budget_bytes: Option<usize>,
    /// Worker threads for the engines' parallel prefill kernels. Applied
    /// as the **process default**
    /// ([`crate::util::threadpool::set_global_threads`]) when the
    /// coordinator starts, so every sequence backend (and the eval
    /// harness, if colocated) shares one pool width instead of each
    /// engine implicitly serializing. `0` = leave the process default
    /// untouched. Results are bit-identical at any width.
    pub threads: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_batch: 8,
            kv_budget_bytes: None,
            threads: 0,
        }
    }
}

struct Active {
    req: Request,
    backend: Box<dyn SequenceBackend>,
    generated: Vec<usize>,
    queue_wait_s: f64,
    ttft_s: f64,
    started: Instant,
    tok_latencies: Vec<f64>,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Option<mpsc::Sender<Request>>,
    worker: Option<thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Coordinator {
    /// Start the worker. `setup` runs once inside the worker thread and
    /// returns the per-sequence backend factory.
    pub fn start(setup: Setup, cfg: CoordinatorConfig) -> Self {
        if cfg.threads > 0 {
            crate::util::threadpool::set_global_threads(cfg.threads);
        }
        let metrics = Arc::new(Metrics::new());
        let m = Arc::clone(&metrics);
        let (tx, rx) = mpsc::channel::<Request>();
        let worker = thread::spawn(move || {
            let mut factory = match setup() {
                Ok(f) => f,
                Err(e) => {
                    crate::log_error!("coordinator setup failed: {e:#}");
                    return;
                }
            };
            worker_loop(rx, &mut factory, &cfg, &m);
        });
        Coordinator {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    /// Submit a request; returns the response channel.
    pub fn submit(&self, prompt: Vec<usize>, n_new: usize) -> mpsc::Receiver<Response> {
        let (reply, rx) = mpsc::channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics.mark_start();
        let req = Request {
            id,
            prompt,
            n_new,
            submitted_at: Instant::now(),
            reply,
        };
        self.tx
            .as_ref()
            .expect("coordinator already shut down")
            .send(req)
            .expect("coordinator worker gone");
        rx
    }

    /// Submit and block for the response.
    pub fn submit_wait(&self, prompt: Vec<usize>, n_new: usize) -> Response {
        self.submit(prompt, n_new).recv().expect("worker dropped reply")
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Drain the queue and stop the worker.
    pub fn shutdown(mut self) -> super::metrics::MetricsSnapshot {
        self.tx.take(); // close channel
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    rx: mpsc::Receiver<Request>,
    factory: &mut BackendFactory,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
) {
    let mut pending: VecDeque<Request> = VecDeque::new();
    let mut active: Vec<Active> = Vec::new();
    loop {
        // Pull everything currently queued (non-blocking), or block if idle.
        if active.is_empty() && pending.is_empty() {
            match rx.recv() {
                Ok(r) => pending.push_back(r),
                Err(_) => break, // channel closed and nothing to do
            }
        }
        while let Ok(r) = rx.try_recv() {
            pending.push_back(r);
        }

        // Admission under batch-size and KV-budget constraints.
        while active.len() < cfg.max_batch && !pending.is_empty() {
            let kv_now: usize = active.iter().map(|a| a.backend.kv_bytes()).sum();
            if let Some(budget) = cfg.kv_budget_bytes {
                // Require headroom ≥ the smallest active sequence (or admit
                // the first unconditionally so we can't deadlock).
                if !active.is_empty() && kv_now >= budget {
                    break;
                }
            }
            let req = pending.pop_front().unwrap();
            let queue_wait_s = req.submitted_at.elapsed().as_secs_f64();
            let started = Instant::now();
            let mut backend = match factory() {
                Ok(b) => b,
                Err(e) => {
                    crate::log_error!("backend construction failed: {e:#}");
                    continue;
                }
            };
            match backend.prefill(&req.prompt) {
                Ok(first) => {
                    let ttft_s = req.submitted_at.elapsed().as_secs_f64();
                    active.push(Active {
                        req,
                        backend,
                        generated: vec![first],
                        queue_wait_s,
                        ttft_s,
                        started,
                        tok_latencies: Vec::new(),
                    });
                }
                Err(e) => {
                    crate::log_error!("prefill failed for request {}: {e:#}", req.id);
                }
            }
        }
        let kv_now: usize = active.iter().map(|a| a.backend.kv_bytes()).sum();
        metrics.record_kv(kv_now, active.len());

        // One decode round, retiring finished sequences.
        let mut i = 0;
        while i < active.len() {
            let a = &mut active[i];
            let done = if a.generated.len() >= a.req.n_new {
                true
            } else {
                let t0 = Instant::now();
                match a.backend.decode_next() {
                    Ok(tok) => {
                        a.tok_latencies.push(t0.elapsed().as_secs_f64());
                        a.generated.push(tok);
                        a.generated.len() >= a.req.n_new
                    }
                    Err(e) => {
                        crate::log_error!("decode failed for request {}: {e:#}", a.req.id);
                        true
                    }
                }
            };
            if done {
                let a = active.swap_remove(i);
                metrics.record_completion(
                    a.queue_wait_s,
                    a.ttft_s,
                    a.generated.len(),
                    &a.tok_latencies,
                );
                let resp = Response {
                    id: a.req.id,
                    tokens: a.generated,
                    queue_wait_s: a.queue_wait_s,
                    ttft_s: a.ttft_s,
                    total_s: a.started.elapsed().as_secs_f64() + a.queue_wait_s,
                    kv_bytes: a.backend.kv_bytes(),
                    backend: a.backend.name(),
                };
                let _ = a.req.reply.send(resp);
            } else {
                i += 1;
            }
        }

        // Exit when the channel is closed and all work is drained.
        if active.is_empty() && pending.is_empty() {
            match rx.try_recv() {
                Ok(r) => pending.push_back(r),
                Err(mpsc::TryRecvError::Disconnected) => break,
                Err(mpsc::TryRecvError::Empty) => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::RustSequenceBackend;
    use crate::kvcache::FullCache;
    use crate::model::{engine::Engine, ModelConfig, ModelWeights};
    use std::sync::Arc as StdArc;

    fn test_setup() -> Setup {
        Box::new(|| {
            let cfg = ModelConfig::test_small();
            let engine = Engine::new(StdArc::new(ModelWeights::init(&cfg, 5)));
            let factory: BackendFactory = Box::new(move || {
                let c = engine.w.cfg.clone();
                Ok(Box::new(RustSequenceBackend::new(
                    engine.clone(),
                    Box::new(FullCache::new(c.n_layers, c.d_model)),
                )))
            });
            Ok(factory)
        })
    }

    #[test]
    fn serves_batched_requests() {
        let coord = Coordinator::start(test_setup(), CoordinatorConfig::default());
        let rxs: Vec<_> = (0..5)
            .map(|i| coord.submit(vec![1, 2 + i, 3, 4], 4))
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.tokens.len(), 4);
            assert!(resp.ttft_s >= resp.queue_wait_s);
            assert!(resp.kv_bytes > 0);
        }
        let snap = coord.shutdown();
        assert_eq!(snap.requests_completed, 5);
        assert_eq!(snap.tokens_generated, 20);
        assert!(snap.active_peak >= 2, "batching should overlap requests");
    }

    #[test]
    fn kv_budget_limits_concurrency() {
        // Budget fits ~1 sequence ⇒ active_peak must stay small even with
        // many queued requests.
        let cfg = ModelConfig::test_small();
        let one_seq_bytes = cfg.kv_bytes_full(12);
        let coord = Coordinator::start(
            test_setup(),
            CoordinatorConfig {
                max_batch: 8,
                kv_budget_bytes: Some(one_seq_bytes),
                ..Default::default()
            },
        );
        let rxs: Vec<_> = (0..4).map(|_| coord.submit(vec![1, 2, 3, 4, 5, 6], 6)).collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().tokens.len(), 6);
        }
        let snap = coord.shutdown();
        assert_eq!(snap.requests_completed, 4);
        assert!(
            snap.active_peak <= 2,
            "budget should throttle concurrency, got {}",
            snap.active_peak
        );
    }

    #[test]
    fn deterministic_vs_direct_engine() {
        let cfg = ModelConfig::test_small();
        let engine = Engine::new(StdArc::new(ModelWeights::init(&cfg, 5)));
        let prompt = vec![1usize, 7, 9, 2];
        let mut cache = FullCache::new(cfg.n_layers, cfg.d_model);
        let (want, _) = engine.generate(&prompt, 5, &mut cache);
        let coord = Coordinator::start(test_setup(), CoordinatorConfig::default());
        let resp = coord.submit_wait(prompt, 5);
        assert_eq!(resp.tokens, want);
    }
}
