//! The coordinator thread: queueing, KV-budget admission, continuous
//! batching, completion.
//!
//! Scheduling model (single-worker continuous batching, **fused rounds**):
//!
//! 1. Requests land in an mpsc queue.
//! 2. The worker collects an *admission round*: queued requests are
//!    admitted while `active + admitted < max_batch` **and** the
//!    aggregate KV footprint stays under `kv_budget_bytes`. The admission
//!    test charges every sequence at its *projected completion*
//!    footprint — prompt plus `n_new` tokens through
//!    [`SequenceBackend::kv_bytes_projected`] — so neither a long prompt
//!    at prefill nor decode growth afterwards can blow past the budget,
//!    and compressed-cache policies still admit proportionally more
//!    concurrent sequences (the serving-side win of the paper, measured
//!    by `bench_perf_serving`).
//! 3. The whole admission round is prefilled in **one fused pass**
//!    ([`super::backend::prefill_batch`]): each layer's weights stream
//!    once across the stacked prompts, so TTFT under load stops scaling
//!    with queue depth. With `fused: false` (A/B baseline) prefills run
//!    per sequence, as the pre-batching scheduler did.
//! 4. Each scheduling round decodes one token for every active sequence
//!    in **one fused GEMM-batched call** ([`super::backend::decode_batch`]:
//!    QKV / output / MLP / LM-head weights stream once per round instead
//!    of once per sequence), then re-admits — i.e. new requests don't
//!    wait for the whole batch to drain (continuous batching à la
//!    Orca/vLLM). Fused and sequential rounds produce **bit-identical**
//!    token streams at every batch size and thread count
//!    (`rust/tests/batched_serving.rs`).
//! 5. Every submitted request receives exactly one [`Response`]:
//!    backend-construction and prefill failures answer with
//!    [`Response::failure`] (counted in [`Metrics`]) instead of silently
//!    dropping the reply channel, so `submit_wait` can never hang.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use super::backend::{decode_batch, prefill_batch, BatchScratch, SequenceBackend};
use super::metrics::Metrics;
use super::request::{Request, Response};

/// Factory producing a fresh backend per admitted sequence. Created inside
/// the worker thread (PJRT clients are not Send), hence the two-level
/// `Setup -> Factory` indirection.
pub type BackendFactory = Box<dyn FnMut() -> anyhow::Result<Box<dyn SequenceBackend>>>;
pub type Setup = Box<dyn FnOnce() -> anyhow::Result<BackendFactory> + Send>;

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Max concurrently-decoding sequences.
    pub max_batch: usize,
    /// Aggregate KV budget across active sequences (None = unlimited).
    pub kv_budget_bytes: Option<usize>,
    /// Worker threads for the engines' parallel prefill kernels. Applied
    /// as the **process default**
    /// ([`crate::util::threadpool::set_global_threads`]) when the
    /// coordinator starts, so every sequence backend (and the eval
    /// harness, if colocated) shares one pool width instead of each
    /// engine implicitly serializing. `0` = leave the process default
    /// untouched. Results are bit-identical at any width.
    pub threads: usize,
    /// Run admission prefills and decode rounds through the fused
    /// multi-sequence data plane (default). `false` restores the
    /// per-sequence rounds of the pre-batching scheduler — the A/B
    /// baseline for `bench_perf_serving`; token streams are identical
    /// either way.
    pub fused: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_batch: 8,
            kv_budget_bytes: None,
            threads: 0,
            fused: true,
        }
    }
}

struct Active {
    req: Request,
    backend: Box<dyn SequenceBackend>,
    generated: Vec<usize>,
    queue_wait_s: f64,
    ttft_s: f64,
    started: Instant,
    tok_latencies: Vec<f64>,
    /// Set when a decode step errored; the sequence retires with the
    /// tokens generated so far and the error attached.
    failed: Option<String>,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Option<mpsc::Sender<Request>>,
    worker: Option<thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Coordinator {
    /// Start the worker. `setup` runs once inside the worker thread and
    /// returns the per-sequence backend factory.
    pub fn start(setup: Setup, cfg: CoordinatorConfig) -> Self {
        if cfg.threads > 0 {
            crate::util::threadpool::set_global_threads(cfg.threads);
        }
        let metrics = Arc::new(Metrics::new());
        let m = Arc::clone(&metrics);
        let (tx, rx) = mpsc::channel::<Request>();
        let worker = thread::spawn(move || {
            let mut factory = match setup() {
                Ok(f) => f,
                Err(e) => {
                    crate::log_error!("coordinator setup failed: {e:#}");
                    return;
                }
            };
            worker_loop(rx, &mut factory, &cfg, &m);
        });
        Coordinator {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    /// Submit a request; returns the response channel.
    pub fn submit(&self, prompt: Vec<usize>, n_new: usize) -> mpsc::Receiver<Response> {
        let (reply, rx) = mpsc::channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics.mark_start();
        let req = Request {
            id,
            prompt,
            n_new,
            submitted_at: Instant::now(),
            reply,
        };
        self.tx
            .as_ref()
            .expect("coordinator already shut down")
            .send(req)
            .expect("coordinator worker gone");
        rx
    }

    /// Submit and block for the response.
    pub fn submit_wait(&self, prompt: Vec<usize>, n_new: usize) -> Response {
        self.submit(prompt, n_new).recv().expect("worker dropped reply")
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Drain the queue and stop the worker.
    pub fn shutdown(mut self) -> super::metrics::MetricsSnapshot {
        self.tx.take(); // close channel
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Answer `req` with an error `Response` and count the failure — the
/// no-hang guarantee: a dropped reply channel would strand `submit_wait`.
fn fail_request(req: Request, err: &str, metrics: &Metrics) {
    crate::log_error!("request {} failed: {err}", req.id);
    metrics.record_failure();
    let _ = req.reply.send(Response::failure(&req, err));
}

/// Retire one sequence: record metrics and answer its request. A
/// decode-failed sequence counts as a failure (its partial tokens are
/// returned but stay out of the success distributions).
fn retire(a: Active, metrics: &Metrics) {
    if a.failed.is_some() {
        metrics.record_failure();
    } else {
        metrics.record_completion(a.queue_wait_s, a.ttft_s, a.generated.len(), &a.tok_latencies);
    }
    let resp = Response {
        id: a.req.id,
        tokens: a.generated,
        queue_wait_s: a.queue_wait_s,
        ttft_s: a.ttft_s,
        total_s: a.started.elapsed().as_secs_f64() + a.queue_wait_s,
        kv_bytes: a.backend.kv_bytes(),
        backend: a.backend.name(),
        error: a.failed,
    };
    let _ = a.req.reply.send(resp);
}

fn worker_loop(
    rx: mpsc::Receiver<Request>,
    factory: &mut BackendFactory,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
) {
    let mut pending: VecDeque<Request> = VecDeque::new();
    let mut active: Vec<Active> = Vec::new();
    let mut batch = BatchScratch::default();
    // Backend built for the queue head on a round where the budget
    // blocked admission — kept so `factory()` stays 1:1 with requests
    // instead of re-constructing (and dropping) a backend every round
    // the head stays blocked.
    let mut staged: Option<Box<dyn SequenceBackend>> = None;
    loop {
        // Pull everything currently queued (non-blocking), or block if idle.
        if active.is_empty() && pending.is_empty() {
            match rx.recv() {
                Ok(r) => pending.push_back(r),
                Err(_) => break, // channel closed and nothing to do
            }
        }
        while let Ok(r) = rx.try_recv() {
            pending.push_back(r);
        }

        // Collect this round's admission set under the batch-size and
        // KV-budget constraints. The budget test charges every sequence
        // — active, admitted this round, and the incoming candidate — at
        // its *projected completion* footprint (prompt + n_new tokens,
        // via kv_bytes_projected), so neither a long prompt at prefill
        // nor decode growth afterwards can push the aggregate past the
        // budget. The first sequence is admitted unconditionally so an
        // over-budget request can't deadlock the queue.
        let mut admitted: Vec<(Request, Box<dyn SequenceBackend>, f64, Instant)> = Vec::new();
        while active.len() + admitted.len() < cfg.max_batch && !pending.is_empty() {
            let backend = match staged.take() {
                Some(b) => b, // built for this same queue head on a blocked round
                None => match factory() {
                    Ok(b) => b,
                    Err(e) => {
                        let req = pending.pop_front().unwrap();
                        fail_request(req, &format!("backend construction failed: {e:#}"), metrics);
                        continue;
                    }
                },
            };
            if let Some(budget) = cfg.kv_budget_bytes {
                let committed: usize = active
                    .iter()
                    .map(|a| {
                        a.backend
                            .kv_bytes_projected(a.req.prompt.len() + a.req.n_new)
                            .max(a.backend.kv_bytes())
                    })
                    .sum::<usize>()
                    + admitted
                        .iter()
                        .map(|(r, b, ..)| b.kv_bytes_projected(r.prompt.len() + r.n_new))
                        .sum::<usize>();
                let head = pending.front().unwrap();
                let incoming = backend.kv_bytes_projected(head.prompt.len() + head.n_new);
                if (!active.is_empty() || !admitted.is_empty()) && committed + incoming > budget {
                    staged = Some(backend);
                    break;
                }
            }
            let req = pending.pop_front().unwrap();
            let queue_wait_s = req.submitted_at.elapsed().as_secs_f64();
            admitted.push((req, backend, queue_wait_s, Instant::now()));
        }

        // Prefill the admission round — fused (weights streamed once
        // across the round) or per-sequence (A/B baseline). TTFT is
        // taken when a sequence's first token actually exists: after the
        // whole pass for the fused round, after each sequence's own
        // prefill for the sequential baseline.
        if !admitted.is_empty() {
            let results: Vec<(anyhow::Result<usize>, Option<f64>)> = if cfg.fused {
                let mut bs: Vec<&mut dyn SequenceBackend> = Vec::with_capacity(admitted.len());
                let mut prompts: Vec<&[usize]> = Vec::with_capacity(admitted.len());
                for (req, backend, ..) in admitted.iter_mut() {
                    prompts.push(&req.prompt);
                    bs.push(backend.as_mut());
                }
                prefill_batch(&mut bs, &prompts, &mut batch)
                    .into_iter()
                    .map(|r| (r, None))
                    .collect()
            } else {
                admitted
                    .iter_mut()
                    .map(|(req, backend, ..)| {
                        let r = backend.prefill(&req.prompt);
                        let ttft = req.submitted_at.elapsed().as_secs_f64();
                        (r, Some(ttft))
                    })
                    .collect()
            };
            for ((req, backend, queue_wait_s, started), (res, ttft)) in
                admitted.into_iter().zip(results)
            {
                match res {
                    Ok(first) => {
                        let ttft_s =
                            ttft.unwrap_or_else(|| req.submitted_at.elapsed().as_secs_f64());
                        active.push(Active {
                            req,
                            backend,
                            generated: vec![first],
                            queue_wait_s,
                            ttft_s,
                            started,
                            tok_latencies: Vec::new(),
                            failed: None,
                        });
                    }
                    Err(e) => {
                        fail_request(req, &format!("prefill failed: {e:#}"), metrics);
                    }
                }
            }
        }
        let kv_now: usize = active.iter().map(|a| a.backend.kv_bytes()).sum();
        metrics.record_kv(kv_now, active.len());

        // One decode round across every unfinished sequence — a single
        // fused call (or per-sequence steps in the A/B baseline).
        let mut round: Vec<usize> = Vec::with_capacity(active.len());
        {
            let mut bs: Vec<&mut dyn SequenceBackend> = Vec::with_capacity(active.len());
            for (i, a) in active.iter_mut().enumerate() {
                if a.generated.len() < a.req.n_new {
                    round.push(i);
                    bs.push(a.backend.as_mut());
                }
            }
            if !bs.is_empty() {
                let (results, lats): (Vec<anyhow::Result<usize>>, Vec<f64>) = if cfg.fused {
                    let t0 = Instant::now();
                    let r = decode_batch(&mut bs, &mut batch);
                    // Fused rounds are timed as a whole; each sequence is
                    // attributed its per-token share.
                    let share = t0.elapsed().as_secs_f64() / r.len() as f64;
                    let n = r.len();
                    (r, vec![share; n])
                } else {
                    let mut lats = Vec::with_capacity(bs.len());
                    let r = bs
                        .iter_mut()
                        .map(|b| {
                            let t0 = Instant::now();
                            let res = b.decode_next();
                            lats.push(t0.elapsed().as_secs_f64());
                            res
                        })
                        .collect();
                    (r, lats)
                };
                drop(bs);
                for ((&i, res), lat) in round.iter().zip(results).zip(lats) {
                    match res {
                        Ok(tok) => {
                            active[i].tok_latencies.push(lat);
                            active[i].generated.push(tok);
                        }
                        Err(e) => {
                            crate::log_error!(
                                "decode failed for request {}: {e:#}",
                                active[i].req.id
                            );
                            active[i].failed = Some(format!("decode failed: {e:#}"));
                        }
                    }
                }
            }
        }

        // Retire finished (or failed) sequences.
        let mut i = 0;
        while i < active.len() {
            let done =
                active[i].failed.is_some() || active[i].generated.len() >= active[i].req.n_new;
            if done {
                retire(active.swap_remove(i), metrics);
            } else {
                i += 1;
            }
        }

        // Exit when the channel is closed and all work is drained.
        if active.is_empty() && pending.is_empty() {
            match rx.try_recv() {
                Ok(r) => pending.push_back(r),
                Err(mpsc::TryRecvError::Disconnected) => break,
                Err(mpsc::TryRecvError::Empty) => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::RustSequenceBackend;
    use crate::kvcache::FullCache;
    use crate::model::{engine::Engine, ModelConfig, ModelWeights};
    use std::sync::Arc as StdArc;

    fn test_setup() -> Setup {
        Box::new(|| {
            let cfg = ModelConfig::test_small();
            let engine = Engine::new(StdArc::new(ModelWeights::init(&cfg, 5)));
            let factory: BackendFactory = Box::new(move || {
                let c = engine.w.cfg.clone();
                Ok(Box::new(RustSequenceBackend::new(
                    engine.clone(),
                    Box::new(FullCache::new(c.n_layers, c.d_model)),
                )))
            });
            Ok(factory)
        })
    }

    #[test]
    fn serves_batched_requests() {
        let coord = Coordinator::start(test_setup(), CoordinatorConfig::default());
        let rxs: Vec<_> = (0..5)
            .map(|i| coord.submit(vec![1, 2 + i, 3, 4], 4))
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.tokens.len(), 4);
            assert!(resp.error.is_none());
            assert!(resp.ttft_s >= resp.queue_wait_s);
            assert!(resp.kv_bytes > 0);
        }
        let snap = coord.shutdown();
        assert_eq!(snap.requests_completed, 5);
        assert_eq!(snap.tokens_generated, 20);
        assert!(snap.active_peak >= 2, "batching should overlap requests");
    }

    #[test]
    fn kv_budget_limits_concurrency() {
        // Budget fits ~1 sequence ⇒ active_peak must stay small even with
        // many queued requests.
        let cfg = ModelConfig::test_small();
        let one_seq_bytes = cfg.kv_bytes_full(12);
        let coord = Coordinator::start(
            test_setup(),
            CoordinatorConfig {
                max_batch: 8,
                kv_budget_bytes: Some(one_seq_bytes),
                ..Default::default()
            },
        );
        let rxs: Vec<_> = (0..4).map(|_| coord.submit(vec![1, 2, 3, 4, 5, 6], 6)).collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().tokens.len(), 6);
        }
        let snap = coord.shutdown();
        assert_eq!(snap.requests_completed, 4);
        assert!(
            snap.active_peak <= 2,
            "budget should throttle concurrency, got {}",
            snap.active_peak
        );
    }

    /// Admission pre-charges each request's projected completion
    /// footprint (prompt + n_new): with a budget that fits one request
    /// but not two, a second must *not* be co-admitted just because the
    /// current footprint still looks small. The old current-footprint-
    /// only check admitted it (kv_now = one prompt < budget) and blew
    /// past the budget at the second prefill.
    #[test]
    fn admission_precharge_prevents_budget_overshoot() {
        let cfg = ModelConfig::test_small();
        // Budget: 10 tokens. Each request projects to 12 tokens at
        // completion (8 prompt + 4 generated), so requests must run
        // strictly one at a time (the first admits via the
        // can't-deadlock escape hatch).
        let budget = cfg.kv_bytes_full(10);
        let coord = Coordinator::start(
            test_setup(),
            CoordinatorConfig {
                max_batch: 8,
                kv_budget_bytes: Some(budget),
                ..Default::default()
            },
        );
        let rxs: Vec<_> = (0..3)
            .map(|i| coord.submit(vec![1, 2, 3, 4, 5, 6, 7, 8 + i], 4))
            .collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().tokens.len(), 4);
        }
        let snap = coord.shutdown();
        assert_eq!(snap.requests_completed, 3);
        assert_eq!(
            snap.active_peak, 1,
            "pre-charge must serialize prompts that can't share the budget"
        );
    }

    #[test]
    fn deterministic_vs_direct_engine() {
        let cfg = ModelConfig::test_small();
        let engine = Engine::new(StdArc::new(ModelWeights::init(&cfg, 5)));
        let prompt = vec![1usize, 7, 9, 2];
        let mut cache = FullCache::new(cfg.n_layers, cfg.d_model);
        let (want, _) = engine.generate(&prompt, 5, &mut cache);
        let coord = Coordinator::start(test_setup(), CoordinatorConfig::default());
        let resp = coord.submit_wait(prompt, 5);
        assert_eq!(resp.tokens, want);
    }
}
