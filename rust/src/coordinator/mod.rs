//! L3 serving coordinator — vLLM-router-shaped.
//!
//! The coordinator owns the event loop: requests enter a queue, a
//! continuous batcher admits them into the active set under a **KV-memory
//! budget** (this is where CSKV pays off operationally: the compressed
//! cache admits ~5× more concurrent sequences at 80% compression — and
//! admission pre-charges each prompt's projected footprint so the budget
//! holds *before* prefill commits it), whole admission rounds prefill in
//! one fused multi-sequence pass, decode proceeds as one GEMM-batched
//! round across active sequences with new admissions between rounds, and
//! metrics record queue wait, TTFT, per-token latency, failures and KV
//! footprint. Fused rounds stream each weight set once per round instead
//! of once per sequence; token streams are bit-identical to the
//! per-sequence scheduler (`rust/tests/batched_serving.rs`).
//!
//! * [`backend`] — per-sequence execution backends: the Rust reference
//!   engine (any [`crate::kvcache::KvCachePolicy`]) and helpers, plus
//!   the fused round entry points ([`backend::prefill_batch`] /
//!   [`backend::decode_batch`]).
//! * [`pjrt_backend`] — the AOT serving path: sessions that execute
//!   `decode_full` / `decode_cskv_r*` artifacts via PJRT.
//! * [`server`] — the coordinator thread, admission control, scheduling.
//! * [`request`] / [`metrics`] — request/response types and counters.

pub mod backend;
pub mod metrics;
pub mod pjrt_backend;
pub mod request;
pub mod server;

pub use backend::{RustSequenceBackend, SequenceBackend};
pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{Request, Response};
pub use server::{Coordinator, CoordinatorConfig};
