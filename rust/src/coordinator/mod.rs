//! L3 serving coordinator — vLLM-router-shaped, with a preemptive
//! tiered control plane.
//!
//! Two planes:
//!
//! * **Data plane** — whole admission rounds prefill in one fused
//!   multi-sequence pass and decode proceeds as one GEMM-batched round
//!   across active sequences ([`backend::prefill_batch`] /
//!   [`backend::decode_batch`]); weights stream once per round instead
//!   of once per sequence, and token streams are bit-identical to the
//!   per-sequence scheduler (`rust/tests/batched_serving.rs`).
//! * **Control plane** — a pluggable [`scheduler::Scheduler`] decides
//!   which sequences occupy the hot tier under the **KV-memory budget**
//!   (admission pre-charges each prompt's projected completion
//!   footprint, so the budget holds *before* prefill commits it —
//!   compressed CSKV caches admit ~5× more concurrent sequences at 80%
//!   compression). `fifo` keeps strict arrival order (the A/B
//!   baseline), `size-aware` admits shortest-remaining-work-first
//!   within the budget (no head-of-line blocking), and `preemptive`
//!   additionally swaps the lowest-priority active sequence out to a
//!   cold tier under pressure. With the prefix cache enabled
//!   ([`CoordinatorConfig::prefix_cache_bytes`]), admission charges
//!   only each request's **unshared suffix**: the cached prefix rows
//!   already resident in [`crate::kvcache::PrefixCache`] are priced
//!   once for the whole fleet, so prompts sharing a long system
//!   preamble admit at a fraction of their nominal footprint.
//!
//! Preemption is built on sequence state migration:
//! [`crate::kvcache::KvCachePolicy::snapshot`] serializes the cache in
//! its **compressed** representation (≈ 20% of the hot footprint for
//! CSKV), the [`coldtier::ColdTier`] parks it in memory or spills it to
//! disk, and restore resumes the generation **bit-identically** — the
//! engine rebuilds its decode views through the existing `sync_view`
//! path. [`Metrics`] records queue waits, preemption/restore counts,
//! cold-tier bytes, per-outcome TTFT and retirement order;
//! `bench_perf_scheduling` measures the fleet-level effect.
//!
//! * [`backend`] — per-sequence execution backends: the Rust reference
//!   engine (any [`crate::kvcache::KvCachePolicy`]) and helpers, plus
//!   the fused round entry points and sequence snapshot/restore.
//! * [`pjrt_backend`] — the AOT serving path: sessions that execute
//!   `decode_full` / `decode_cskv_r*` artifacts via PJRT, including
//!   their serialized snapshot forms.
//! * [`scheduler`] — the control-plane trait and the three policies.
//! * [`coldtier`] — the blob store for preempted sequence state.
//! * [`server`] — the coordinator thread and the scheduling rounds.
//! * [`request`] / [`metrics`] — request/response types and counters.

pub mod backend;
pub mod coldtier;
pub mod metrics;
pub mod pjrt_backend;
pub mod request;
pub mod scheduler;
pub mod server;

pub use backend::{RustSequenceBackend, SequenceBackend};
pub use coldtier::ColdTier;
pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{Request, Response};
pub use scheduler::{Scheduler, SchedulerKind};
pub use server::{Coordinator, CoordinatorConfig};
