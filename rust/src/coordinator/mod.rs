//! L3 serving coordinator — vLLM-router-shaped, with a preemptive
//! tiered control plane hardened to fail *partially*, never totally.
//!
//! Two planes:
//!
//! * **Data plane** — whole admission rounds prefill in one fused
//!   multi-sequence pass and decode proceeds as one GEMM-batched round
//!   across active sequences ([`backend::prefill_batch`] /
//!   [`backend::decode_batch`]); weights stream once per round instead
//!   of once per sequence, and token streams are bit-identical to the
//!   per-sequence scheduler (`rust/tests/batched_serving.rs`).
//! * **Control plane** — a pluggable [`scheduler::Scheduler`] decides
//!   which sequences occupy the hot tier under the **KV-memory budget**
//!   (admission pre-charges each prompt's projected completion
//!   footprint, so the budget holds *before* prefill commits it —
//!   compressed CSKV caches admit ~5× more concurrent sequences at 80%
//!   compression). `fifo` keeps strict arrival order (the A/B
//!   baseline), `size-aware` admits shortest-remaining-work-first
//!   within the budget (no head-of-line blocking), and `preemptive`
//!   additionally swaps the lowest-priority active sequence out to a
//!   cold tier under pressure. With the prefix cache enabled
//!   ([`CoordinatorConfig::prefix_cache_bytes`]), admission charges
//!   only each request's **unshared suffix**: the cached prefix rows
//!   already resident in [`crate::kvcache::PrefixCache`] are priced
//!   once for the whole fleet, so prompts sharing a long system
//!   preamble admit at a fraction of their nominal footprint.
//!
//! # Request lifecycle
//!
//! Every request moves through this state machine, driven once per
//! scheduling round by the worker:
//!
//! ```text
//!                    submit
//!                      │  (invalid: empty prompt / n_new == 0
//!                      │   → immediate error Response, no admission)
//!                      ▼
//!                   queued ──────────────────────┐
//!                      │ scheduler picks,        │ deadline passed /
//!                      │ budget pre-charged      │ cancel token set
//!                      ▼                         │ (reaped *before*
//!                  admitted ── prefill err ──┐   │  the scheduler
//!                      │ fused prefill       │   │  ever sees it)
//!                      ▼                     │   │
//!        ┌────────── active ── decode err ──┤   │
//!        │ preempted   │ ▲                   │   │
//!        ▼             │ │ restored          │   │
//!     swapped ─────────┼─┘ (bit-identical)   │   │
//!        │             │                     │   │
//!        │ restore/    │ all n_new           │   │
//!        │ read err    │ tokens done         │   │
//!        ▼             ▼                     ▼   ▼
//!     failed        retired              failed  expired / cancelled
//! ```
//!
//! **Failure-semantics contract** (what `rust/tests/chaos_serving.rs`
//! enforces under injected faults):
//!
//! * **Exactly one [`Response`] per submit**, on every path. Successful
//!   retirement carries the full token stream; `failed` carries an error
//!   plus whatever prefix was generated before the fault; `expired` /
//!   `cancelled` carry the partial stream and the reason (`"deadline
//!   exceeded"` / `"cancelled"`, client cancellation winning when both
//!   hold). The reply channel is never silently dropped, so
//!   `submit_wait` can never hang.
//! * **Budget refund on every exit.** Retiring, failing, or reaping a
//!   sequence drops its backend (hot KV bytes) and/or discards its
//!   parked pager blocks in the same round; once the plane drains,
//!   committed KV bytes and pager residency (warm + disk) both read
//!   zero.
//! * **Faults are contained to the sequence they hit.** A corrupt or
//!   unreadable parked snapshot fails that one restore (the worker
//!   `fail_swapped`s it and keeps the round); a failing spill *disk*
//!   degrades the tier to memory rather than failing preemptions; a
//!   backend-construction error fails one admission. Co-scheduled
//!   sequences produce token streams bit-identical to a fault-free run.
//! * **Reaped ≠ failed.** Deadline expiry and cancellation land in
//!   their own [`Metrics`] counters (`expired` / `cancelled`), not in
//!   `requests_failed` — nothing broke, the client moved on.
//!
//! # HTTP serving lifecycle
//!
//! The [`http`] module lifts the same contract onto the wire
//! (`cskv serve --listen <addr>`). A connection moves through:
//!
//! ```text
//!   connect ──► admit ──────► stream (SSE) ──► terminal event ──► close
//!      │          │ queue full    │ client gone /      done | migrated
//!      │          │ or draining   │ stall timeout /    | error
//!      │          ▼               │ short write
//!      │     429 / 503            ▼
//!      ▼     (+Retry-After,   CancelToken::cancel()
//!   dropped   requests_shed)  → worker frees KV at the
//!  (http.accept                 next round boundary;
//!   fault)                      terminal = "cancelled"
//! ```
//!
//! * **Admit/shed** — an atomic in-flight gate bounds concurrent
//!   `/generate` requests; excess load is shed with `429` and a
//!   `Retry-After` header, never queued unboundedly. Once a drain
//!   starts, `/readyz` flips to `503` and `/generate` sheds with `503`.
//! * **Stream** — tokens flow worker → unbounded channel → SSE frames
//!   (`event: token`, `data: {"i":..,"token":..}`); the worker never
//!   blocks on a slow socket. Idle gaps carry `: ping` comment frames.
//! * **Disconnect maps to cancel** — any socket write failure (closed
//!   connection, stall past `--client-stall-timeout`, injected
//!   `http.write` short write) flips the request's [`CancelToken`]; the
//!   sequence retires as `cancelled` at the next round boundary and its
//!   KV / cold bytes are freed. Exactly-one-terminal still holds.
//! * **Drain/migrate** — `SIGTERM` or `POST /drain` stops admissions,
//!   waits out `--drain-grace`, then snapshots every in-flight sequence
//!   into a [`DrainBundle`] (v2 snapshot codec, `tags::DRAIN`) written
//!   to `--drain-file`. Each migrated request's stream ends with an
//!   `event: migrated` terminal; `cskv serve --resume-from <bundle>`
//!   restores every sequence in a fresh process and re-generates
//!   bit-identically (mid-decode sequences resume from their restored
//!   KV state; still-queued ones re-run from the prompt).
//! * **Stats** — `GET /stats` returns the full [`MetricsSnapshot`] as
//!   JSON (`requests{completed,failed,expired,cancelled,shed,drained}`,
//!   latency quantiles, `kv`, `pager` with per-tier occupancy, and
//!   `prefix_cache`), plus the live `draining` flag and `inflight`
//!   gauge.
//!
//! Preemption is built on sequence state migration across a
//! **multi-tier memory hierarchy**:
//! [`crate::kvcache::KvCachePolicy::snapshot`] serializes the cache in
//! its **compressed** representation (≈ 20% of the hot footprint for
//! CSKV) with a CRC-32 integrity footer (snapshot codec v2); the
//! [`pager::Pager`] splits it into independently stored block runs that
//! park in a budgeted warm RAM tier and spill — lowest attention-mass
//! first — to disk (`--hot-kb` / `--warm-kb` / `--disk-dir`). A
//! background thread prefetches the blocks the next round's resumes
//! will need so restores hide behind the current decode round, and
//! restore resumes the generation **bit-identically** — the engine
//! rebuilds its decode views through the existing `sync_view` path.
//! [`Metrics`] records queue waits, preemption/restore counts,
//! per-tier occupancy and pager health, restore-stall time,
//! per-outcome TTFT and retirement order; `bench_perf_scheduling` and
//! `bench_perf_paging` measure the fleet-level effect.
//!
//! * [`backend`] — per-sequence execution backends: the Rust reference
//!   engine (any [`crate::kvcache::KvCachePolicy`]) and helpers, plus
//!   the fused round entry points and sequence snapshot/restore.
//! * [`pjrt_backend`] — the AOT serving path: sessions that execute
//!   `decode_full` / `decode_cskv_r*` artifacts via PJRT, including
//!   their serialized snapshot forms.
//! * [`scheduler`] — the control-plane trait and the three policies.
//! * [`pager`] — the multi-tier block store for preempted sequence
//!   state: warm/disk budgets, attention-aware eviction scoring,
//!   prefetch-overlapped restores, retry/degrade semantics
//!   ([`pager::PagerStats`]).
//! * [`server`] — the coordinator thread and the scheduling rounds,
//!   plus graceful drain and the [`DrainBundle`] migration codec.
//! * [`http`] — the std-only HTTP/1.1 + SSE front-end (`cskv serve`).
//! * [`request`] / [`metrics`] — request/response types (deadlines,
//!   [`request::CancelToken`], streaming/resume hooks) and counters.

pub mod backend;
pub mod http;
pub mod metrics;
pub mod pager;
pub mod pjrt_backend;
pub mod request;
pub mod scheduler;
pub mod server;

pub use backend::{RustSequenceBackend, SequenceBackend, ThrottledBackend};
pub use pager::{EvictionScoring, Pager, PagerConfig, PagerStats};
pub use http::{parse_listen, resume_bundle, serve, HttpConfig};
pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{CancelToken, Request, Response, DRAINED};
pub use scheduler::{Scheduler, SchedulerKind};
pub use server::{Coordinator, CoordinatorConfig, DrainBundle, DrainedSeq, RequestHandle};
