//! Cold tier for preempted sequences: a blob store holding encoded
//! [`KvSnapshot`]s, in memory by default, spilled to a directory when
//! `--cold-tier <dir>` is configured (the tiered-storage shape of
//! disk-backed KV offload engines: hot KV in RAM, evicted state as
//! self-describing blobs on disk).
//!
//! The tier stores the snapshot's **encoded** byte form — for CSKV
//! sequences that is the compressed representation (low-rank features +
//! int4 groups), so a preempted compressed sequence costs roughly 20% of
//! its hot footprint while parked. `take` removes the blob (and any
//! spill file); a worker that dies mid-serve leaves at most already-
//! consumed files behind, and `Drop` sweeps whatever is left.
//!
//! **Fault hardening:** disk I/O is the part of the control plane most
//! exposed to transient faults, so the tier fails *partially*, never
//! totally:
//!
//! * spill writes and reads retry up to [`IO_ATTEMPTS`] times with
//!   bounded exponential backoff ([`BACKOFF_BASE_MS`] · 2^attempt);
//! * a write that exhausts its retries keeps the blob in the in-memory
//!   store instead of failing the preemption, and
//!   [`DEGRADE_STREAK`] consecutive exhausted writes **degrade** the
//!   whole tier to memory for subsequent blobs (no more doomed I/O);
//! * a blob that reads back corrupt (the encoded form carries a CRC-32,
//!   snapshot codec v2) fails only that `take` — the caller answers that
//!   one sequence and keeps the round alive.
//!
//! Health counters ([`ColdTierStats`]) are surfaced through
//! [`crate::coordinator::Metrics`]; the I/O paths consult the
//! [`FaultInjector`] points `coldtier.write` / `coldtier.read` /
//! `snapshot.corrupt`, which is how `rust/tests/chaos_serving.rs`
//! schedules deterministic disk faults.

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Duration;

use crate::kvcache::KvSnapshot;
use crate::util::faults::FaultInjector;

/// Attempts per spill write/read (1 initial + retries).
const IO_ATTEMPTS: u32 = 3;
/// Backoff before retry k (1-based) is `BACKOFF_BASE_MS << (k - 1)` ms —
/// bounded at a few ms so a faulting disk slows a round, never stalls it.
const BACKOFF_BASE_MS: u64 = 1;
/// Consecutive exhausted-retry writes before the disk tier degrades to
/// the in-memory store for all subsequent blobs.
const DEGRADE_STREAK: u32 = 2;

enum Blob {
    Mem(Vec<u8>),
    Disk { path: PathBuf, bytes: usize },
}

impl Blob {
    fn bytes(&self) -> usize {
        match self {
            Blob::Mem(b) => b.len(),
            Blob::Disk { bytes, .. } => *bytes,
        }
    }
}

/// Cold-tier health counters, mirrored into
/// [`crate::coordinator::Metrics`] once per scheduling round. All values
/// are cumulative absolutes, not deltas.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ColdTierStats {
    /// Spill-write attempts that failed (each is either retried or, when
    /// the budget is exhausted, degrades that blob to memory).
    pub spill_retries: u64,
    /// Spill-read attempts that failed.
    pub read_retries: u64,
    /// Blobs whose encoded form failed checksum/decode on the way back —
    /// each one fails exactly one sequence, never the round.
    pub corrupt_restores: u64,
    /// True once the disk tier has fallen back to the in-memory store
    /// (unusable dir at construction, or a persistent write-fault streak).
    pub degraded: bool,
}

/// Blob store for swapped-out sequence state, keyed by request id.
/// (The high-water mark lives in [`crate::coordinator::Metrics`], fed by
/// [`ColdTier::bytes_resident`] — one owner for the peak.)
pub struct ColdTier {
    dir: Option<PathBuf>,
    blobs: HashMap<u64, Blob>,
    bytes_current: usize,
    faults: FaultInjector,
    stats: ColdTierStats,
    /// Consecutive puts whose disk write exhausted its retries.
    write_fail_streak: u32,
}

impl ColdTier {
    /// `dir = None` keeps snapshots in memory; `Some(dir)` spills each
    /// blob to `<dir>/seq-<id>.kvsnap`. An unusable directory degrades
    /// to the in-memory store (recorded in [`ColdTierStats::degraded`])
    /// rather than disabling preemption.
    pub fn new(dir: Option<PathBuf>) -> Self {
        ColdTier::with_faults(dir, FaultInjector::none())
    }

    /// [`ColdTier::new`] with a fault-injection registry threaded into
    /// every spill write/read and the pre-decode corruption site.
    pub fn with_faults(dir: Option<PathBuf>, faults: FaultInjector) -> Self {
        let mut stats = ColdTierStats::default();
        let dir = dir.and_then(|d| match std::fs::create_dir_all(&d) {
            Ok(()) => Some(d),
            Err(e) => {
                crate::log_error!("cold tier dir {} unusable ({e}); using memory", d.display());
                stats.degraded = true;
                None
            }
        });
        ColdTier {
            dir,
            blobs: HashMap::new(),
            bytes_current: 0,
            faults,
            stats,
            write_fail_streak: 0,
        }
    }

    fn spill_path(&self, id: u64) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(format!("seq-{id}.kvsnap")))
    }

    /// Check up front that `dir` can hold spill files: create it and
    /// round-trip a probe file. Lets callers (the `serve` CLI) reject a
    /// bad `--cold-tier` with a clear error instead of silently
    /// degrading to memory mid-run.
    pub fn probe_dir(dir: &std::path::Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("cannot create {}: {e}", dir.display()))?;
        let probe = dir.join(".cskv-probe");
        std::fs::write(&probe, b"probe")
            .map_err(|e| anyhow::anyhow!("cannot write to {}: {e}", dir.display()))?;
        std::fs::remove_file(&probe)
            .map_err(|e| anyhow::anyhow!("cannot clean up probe in {}: {e}", dir.display()))?;
        Ok(())
    }

    /// One spill write with bounded retry/backoff. Each attempt consults
    /// the `coldtier.write` fault point before touching the filesystem.
    fn write_with_retry(&mut self, path: &std::path::Path, data: &[u8]) -> anyhow::Result<()> {
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..IO_ATTEMPTS {
            if attempt > 0 {
                std::thread::sleep(Duration::from_millis(BACKOFF_BASE_MS << (attempt - 1)));
            }
            let res = self.faults.trip("coldtier.write").and_then(|()| {
                std::fs::write(path, data)
                    .map_err(|e| anyhow::anyhow!("cold tier spill to {}: {e}", path.display()))
            });
            match res {
                Ok(()) => return Ok(()),
                Err(e) => {
                    self.stats.spill_retries += 1;
                    crate::log_warn!(
                        "cold tier write attempt {}/{IO_ATTEMPTS} failed: {e:#}",
                        attempt + 1
                    );
                    last = Some(e);
                }
            }
        }
        Err(last.expect("IO_ATTEMPTS > 0"))
    }

    /// One spill read with bounded retry/backoff (`coldtier.read` fault
    /// point per attempt).
    fn read_with_retry(&mut self, path: &std::path::Path) -> anyhow::Result<Vec<u8>> {
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..IO_ATTEMPTS {
            if attempt > 0 {
                std::thread::sleep(Duration::from_millis(BACKOFF_BASE_MS << (attempt - 1)));
            }
            let res = self.faults.trip("coldtier.read").and_then(|()| {
                std::fs::read(path)
                    .map_err(|e| anyhow::anyhow!("cold tier read {}: {e}", path.display()))
            });
            match res {
                Ok(data) => return Ok(data),
                Err(e) => {
                    self.stats.read_retries += 1;
                    crate::log_warn!(
                        "cold tier read attempt {}/{IO_ATTEMPTS} failed: {e:#}",
                        attempt + 1
                    );
                    last = Some(e);
                }
            }
        }
        Err(last.expect("IO_ATTEMPTS > 0"))
    }

    /// Park `snap` under `id`. Returns the parked byte size. A disk
    /// write that exhausts its retries keeps the blob in memory — the
    /// preemption still succeeds — and a persistent failure streak
    /// degrades the tier to memory for subsequent blobs; the only error
    /// left is the double-park programming bug.
    pub fn put(&mut self, id: u64, snap: &KvSnapshot) -> anyhow::Result<usize> {
        anyhow::ensure!(
            !self.blobs.contains_key(&id),
            "cold tier already holds sequence {id}"
        );
        let encoded = snap.encode();
        let bytes = encoded.len();
        let blob = match self.spill_path(id) {
            Some(path) => match self.write_with_retry(&path, &encoded) {
                Ok(()) => {
                    self.write_fail_streak = 0;
                    Blob::Disk { path, bytes }
                }
                Err(e) => {
                    self.write_fail_streak += 1;
                    crate::log_error!(
                        "cold tier spill for sequence {id} failed after {IO_ATTEMPTS} attempts \
                         ({e:#}); keeping blob in memory"
                    );
                    if self.write_fail_streak >= DEGRADE_STREAK {
                        crate::log_error!(
                            "cold tier disk degraded after {} consecutive write failures; \
                             subsequent blobs stay in memory",
                            self.write_fail_streak
                        );
                        self.dir = None;
                        self.stats.degraded = true;
                    }
                    Blob::Mem(encoded)
                }
            },
            None => Blob::Mem(encoded),
        };
        self.blobs.insert(id, blob);
        self.bytes_current += bytes;
        Ok(bytes)
    }

    /// Remove and decode the snapshot parked under `id`. A read or
    /// checksum/decode failure errors for **this blob only**: the entry
    /// (and any spill file) is always released, so the caller can fail
    /// the one sequence and keep serving.
    pub fn take(&mut self, id: u64) -> anyhow::Result<KvSnapshot> {
        let blob = self
            .blobs
            .remove(&id)
            .ok_or_else(|| anyhow::anyhow!("cold tier has no sequence {id}"))?;
        self.bytes_current -= blob.bytes();
        let mut encoded = match blob {
            Blob::Mem(b) => b,
            Blob::Disk { path, .. } => {
                let data = self.read_with_retry(&path);
                // The entry is already gone from the index, so the spill
                // file is deleted on *every* outcome — a failed read must
                // not leak an orphan .kvsnap the Drop sweep can't see.
                let _ = std::fs::remove_file(&path);
                data?
            }
        };
        // Chaos hook: flip a seeded byte right where real bit rot would
        // land, between the medium and the decoder.
        self.faults.corrupt("snapshot.corrupt", &mut encoded);
        match KvSnapshot::decode(&encoded) {
            Ok(snap) => Ok(snap),
            Err(e) => {
                self.stats.corrupt_restores += 1;
                Err(e.context(format!("cold tier blob for sequence {id} corrupt")))
            }
        }
    }

    /// Drop the blob parked under `id` without decoding it — how
    /// cancelled or deadline-expired sequences release their cold-tier
    /// state immediately. Returns whether a blob was held.
    pub fn discard(&mut self, id: u64) -> bool {
        match self.blobs.remove(&id) {
            Some(blob) => {
                self.bytes_current -= blob.bytes();
                if let Blob::Disk { path, .. } = blob {
                    let _ = std::fs::remove_file(&path);
                }
                true
            }
            None => false,
        }
    }

    /// Number of parked sequences.
    pub fn len(&self) -> usize {
        self.blobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blobs.is_empty()
    }

    /// Bytes currently parked (memory + disk).
    pub fn bytes_resident(&self) -> usize {
        self.bytes_current
    }

    /// Cumulative health counters (retries, corrupt restores, degraded).
    pub fn stats(&self) -> ColdTierStats {
        self.stats
    }
}

impl Drop for ColdTier {
    fn drop(&mut self) {
        // Best-effort sweep of any spill files never taken back.
        for blob in self.blobs.values() {
            if let Blob::Disk { path, .. } = blob {
                let _ = std::fs::remove_file(path);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::snapshot::tags;
    use crate::util::faults::FaultMode;

    fn snap(fill: u8, n: usize) -> KvSnapshot {
        KvSnapshot::new(tags::FULL, vec![fill; n])
    }

    fn tmp(label: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cskv-coldtier-{label}-{}", std::process::id()))
    }

    #[test]
    fn memory_put_take_roundtrip_and_accounting() {
        let mut tier = ColdTier::new(None);
        assert!(tier.is_empty());
        let b1 = tier.put(1, &snap(7, 100)).unwrap();
        let b2 = tier.put(2, &snap(9, 40)).unwrap();
        assert_eq!(tier.len(), 2);
        assert_eq!(tier.bytes_resident(), b1 + b2);
        // Double-park is a bug, not an overwrite.
        assert!(tier.put(1, &snap(0, 1)).is_err());
        let s = tier.take(1).unwrap();
        assert_eq!(s.payload(), [7u8; 100]);
        assert_eq!(tier.bytes_resident(), b2);
        assert!(tier.take(1).is_err(), "take removes");
        tier.take(2).unwrap();
        assert!(tier.is_empty());
        assert_eq!(tier.stats(), ColdTierStats::default(), "clean run, clean stats");
    }

    #[test]
    fn disk_spill_roundtrip_and_cleanup() {
        let dir = tmp("roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        {
            let mut tier = ColdTier::new(Some(dir.clone()));
            tier.put(5, &snap(3, 64)).unwrap();
            let file = dir.join("seq-5.kvsnap");
            assert!(file.exists(), "blob spilled to disk");
            let s = tier.take(5).unwrap();
            assert_eq!(s.tag(), tags::FULL);
            assert_eq!(s.payload(), [3u8; 64]);
            assert!(!file.exists(), "take deletes the spill file");
            // A blob left parked is swept on drop.
            tier.put(6, &snap(1, 8)).unwrap();
            assert!(dir.join("seq-6.kvsnap").exists());
        }
        assert!(!dir.join("seq-6.kvsnap").exists(), "drop sweeps leftovers");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unusable_dir_degrades_and_is_counted() {
        // A file where the directory should be makes create_dir_all fail.
        let bogus = tmp("unusable");
        let _ = std::fs::remove_dir_all(&bogus);
        std::fs::write(&bogus, b"not a dir").unwrap();
        let mut tier = ColdTier::new(Some(bogus.clone()));
        assert!(tier.stats().degraded, "construction fallback is observable");
        tier.put(1, &snap(2, 16)).unwrap();
        assert_eq!(tier.take(1).unwrap().payload(), [2u8; 16]);
        let _ = std::fs::remove_file(&bogus);
    }

    #[test]
    fn transient_write_fault_is_retried() {
        let dir = tmp("wretry");
        let _ = std::fs::remove_dir_all(&dir);
        let faults = FaultInjector::seeded(1);
        faults.arm("coldtier.write", FaultMode::Nth(1));
        let mut tier = ColdTier::with_faults(Some(dir.clone()), faults);
        tier.put(1, &snap(4, 32)).unwrap();
        assert!(dir.join("seq-1.kvsnap").exists(), "retry landed on disk");
        assert_eq!(tier.stats().spill_retries, 1);
        assert!(!tier.stats().degraded);
        assert_eq!(tier.take(1).unwrap().payload(), [4u8; 32]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persistent_write_faults_degrade_to_memory_without_failing_puts() {
        let dir = tmp("wdegrade");
        let _ = std::fs::remove_dir_all(&dir);
        let faults = FaultInjector::seeded(2);
        faults.arm("coldtier.write", FaultMode::FromNth(1));
        let mut tier = ColdTier::with_faults(Some(dir.clone()), faults.clone());
        // First exhausted write: blob lands in memory, not yet degraded.
        tier.put(1, &snap(5, 16)).unwrap();
        assert!(!dir.join("seq-1.kvsnap").exists());
        assert!(!tier.stats().degraded);
        // Second in a row: the tier degrades for subsequent blobs.
        tier.put(2, &snap(6, 16)).unwrap();
        assert!(tier.stats().degraded);
        let attempts_after_degrade = faults.hits("coldtier.write");
        // Degraded tier stops attempting doomed disk I/O entirely.
        tier.put(3, &snap(7, 16)).unwrap();
        assert_eq!(faults.hits("coldtier.write"), attempts_after_degrade);
        // Every blob still round-trips from memory.
        for id in 1..=3 {
            assert!(tier.take(id).is_ok(), "blob {id} survived the faulty disk");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persistent_read_fault_fails_only_that_take_and_releases_the_file() {
        let dir = tmp("rfail");
        let _ = std::fs::remove_dir_all(&dir);
        let faults = FaultInjector::seeded(3);
        let mut tier = ColdTier::with_faults(Some(dir.clone()), faults.clone());
        tier.put(1, &snap(8, 16)).unwrap();
        tier.put(2, &snap(9, 16)).unwrap();
        faults.arm("coldtier.read", FaultMode::FromNth(1));
        let err = tier.take(1).expect_err("all read attempts fault");
        assert!(err.to_string().contains("injected fault"), "{err:#}");
        assert_eq!(tier.stats().read_retries, IO_ATTEMPTS as u64);
        assert!(!dir.join("seq-1.kvsnap").exists(), "failed take still cleans up");
        // The sibling blob is unaffected once the fault clears.
        faults.arm("coldtier.read", FaultMode::Nth(1));
        assert_eq!(tier.take(2).unwrap().payload(), [9u8; 16], "one retry away");
        assert!(tier.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_blob_fails_cleanly_and_is_counted() {
        let faults = FaultInjector::seeded(4);
        faults.arm("snapshot.corrupt", FaultMode::Nth(1));
        let mut tier = ColdTier::with_faults(None, faults);
        tier.put(1, &snap(1, 128)).unwrap();
        tier.put(2, &snap(2, 128)).unwrap();
        let err = tier.take(1).expect_err("corrupted blob must not decode");
        assert!(err.to_string().contains("corrupt"), "{err:#}");
        assert_eq!(tier.stats().corrupt_restores, 1);
        // Only that blob: the next take round-trips untouched.
        assert_eq!(tier.take(2).unwrap().payload(), [2u8; 128]);
        assert_eq!(tier.bytes_resident(), 0, "failed take refunds accounting");
    }

    #[test]
    fn discard_releases_blob_and_spill_file_without_decoding() {
        let dir = tmp("discard");
        let _ = std::fs::remove_dir_all(&dir);
        let mut tier = ColdTier::new(Some(dir.clone()));
        tier.put(7, &snap(3, 24)).unwrap();
        assert!(dir.join("seq-7.kvsnap").exists());
        assert!(tier.discard(7));
        assert!(!dir.join("seq-7.kvsnap").exists());
        assert_eq!(tier.bytes_resident(), 0);
        assert!(!tier.discard(7), "second discard is a no-op");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
