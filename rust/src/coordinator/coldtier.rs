//! Cold tier for preempted sequences: a blob store holding encoded
//! [`KvSnapshot`]s, in memory by default, spilled to a directory when
//! `--cold-tier <dir>` is configured (the tiered-storage shape of
//! disk-backed KV offload engines: hot KV in RAM, evicted state as
//! self-describing blobs on disk).
//!
//! The tier stores the snapshot's **encoded** byte form — for CSKV
//! sequences that is the compressed representation (low-rank features +
//! int4 groups), so a preempted compressed sequence costs roughly 20% of
//! its hot footprint while parked. `take` removes the blob (and any
//! spill file); a worker that dies mid-serve leaves at most already-
//! consumed files behind, and `Drop` sweeps whatever is left.

use std::collections::HashMap;
use std::path::PathBuf;

use crate::kvcache::KvSnapshot;

enum Blob {
    Mem(Vec<u8>),
    Disk { path: PathBuf, bytes: usize },
}

impl Blob {
    fn bytes(&self) -> usize {
        match self {
            Blob::Mem(b) => b.len(),
            Blob::Disk { bytes, .. } => *bytes,
        }
    }
}

/// Blob store for swapped-out sequence state, keyed by request id.
/// (The high-water mark lives in [`crate::coordinator::Metrics`], fed by
/// [`ColdTier::bytes_resident`] — one owner for the peak.)
pub struct ColdTier {
    dir: Option<PathBuf>,
    blobs: HashMap<u64, Blob>,
    bytes_current: usize,
}

impl ColdTier {
    /// `dir = None` keeps snapshots in memory; `Some(dir)` spills each
    /// blob to `<dir>/seq-<id>.kvsnap`. An unusable directory degrades
    /// to the in-memory store with a logged error rather than disabling
    /// preemption.
    pub fn new(dir: Option<PathBuf>) -> Self {
        let dir = dir.and_then(|d| match std::fs::create_dir_all(&d) {
            Ok(()) => Some(d),
            Err(e) => {
                crate::log_error!("cold tier dir {} unusable ({e}); using memory", d.display());
                None
            }
        });
        ColdTier {
            dir,
            blobs: HashMap::new(),
            bytes_current: 0,
        }
    }

    fn spill_path(&self, id: u64) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(format!("seq-{id}.kvsnap")))
    }

    /// Check up front that `dir` can hold spill files: create it and
    /// round-trip a probe file. Lets callers (the `serve` CLI) reject a
    /// bad `--cold-tier` with a clear error instead of silently
    /// degrading to memory mid-run.
    pub fn probe_dir(dir: &std::path::Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("cannot create {}: {e}", dir.display()))?;
        let probe = dir.join(".cskv-probe");
        std::fs::write(&probe, b"probe")
            .map_err(|e| anyhow::anyhow!("cannot write to {}: {e}", dir.display()))?;
        std::fs::remove_file(&probe)
            .map_err(|e| anyhow::anyhow!("cannot clean up probe in {}: {e}", dir.display()))?;
        Ok(())
    }

    /// Park `snap` under `id`. Returns the parked byte size.
    pub fn put(&mut self, id: u64, snap: &KvSnapshot) -> anyhow::Result<usize> {
        anyhow::ensure!(
            !self.blobs.contains_key(&id),
            "cold tier already holds sequence {id}"
        );
        let encoded = snap.encode();
        let bytes = encoded.len();
        let blob = match self.spill_path(id) {
            Some(path) => {
                std::fs::write(&path, &encoded)
                    .map_err(|e| anyhow::anyhow!("cold tier spill to {}: {e}", path.display()))?;
                Blob::Disk { path, bytes }
            }
            None => Blob::Mem(encoded),
        };
        self.blobs.insert(id, blob);
        self.bytes_current += bytes;
        Ok(bytes)
    }

    /// Remove and decode the snapshot parked under `id`.
    pub fn take(&mut self, id: u64) -> anyhow::Result<KvSnapshot> {
        let blob = self
            .blobs
            .remove(&id)
            .ok_or_else(|| anyhow::anyhow!("cold tier has no sequence {id}"))?;
        self.bytes_current -= blob.bytes();
        let encoded = match blob {
            Blob::Mem(b) => b,
            Blob::Disk { path, .. } => {
                let data = std::fs::read(&path);
                // The entry is already gone from the index, so the spill
                // file is deleted on *every* outcome — a failed read must
                // not leak an orphan .kvsnap the Drop sweep can't see.
                let _ = std::fs::remove_file(&path);
                data.map_err(|e| anyhow::anyhow!("cold tier read {}: {e}", path.display()))?
            }
        };
        KvSnapshot::decode(&encoded)
    }

    /// Number of parked sequences.
    pub fn len(&self) -> usize {
        self.blobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blobs.is_empty()
    }

    /// Bytes currently parked (memory + disk).
    pub fn bytes_resident(&self) -> usize {
        self.bytes_current
    }
}

impl Drop for ColdTier {
    fn drop(&mut self) {
        // Best-effort sweep of any spill files never taken back.
        for blob in self.blobs.values() {
            if let Blob::Disk { path, .. } = blob {
                let _ = std::fs::remove_file(path);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::snapshot::tags;

    fn snap(fill: u8, n: usize) -> KvSnapshot {
        KvSnapshot::new(tags::FULL, vec![fill; n])
    }

    #[test]
    fn memory_put_take_roundtrip_and_accounting() {
        let mut tier = ColdTier::new(None);
        assert!(tier.is_empty());
        let b1 = tier.put(1, &snap(7, 100)).unwrap();
        let b2 = tier.put(2, &snap(9, 40)).unwrap();
        assert_eq!(tier.len(), 2);
        assert_eq!(tier.bytes_resident(), b1 + b2);
        // Double-park is a bug, not an overwrite.
        assert!(tier.put(1, &snap(0, 1)).is_err());
        let s = tier.take(1).unwrap();
        assert_eq!(s.payload(), [7u8; 100]);
        assert_eq!(tier.bytes_resident(), b2);
        assert!(tier.take(1).is_err(), "take removes");
        tier.take(2).unwrap();
        assert!(tier.is_empty());
    }

    #[test]
    fn disk_spill_roundtrip_and_cleanup() {
        let dir = std::env::temp_dir().join(format!("cskv-coldtier-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let mut tier = ColdTier::new(Some(dir.clone()));
            tier.put(5, &snap(3, 64)).unwrap();
            let file = dir.join("seq-5.kvsnap");
            assert!(file.exists(), "blob spilled to disk");
            let s = tier.take(5).unwrap();
            assert_eq!(s.tag(), tags::FULL);
            assert_eq!(s.payload(), [3u8; 64]);
            assert!(!file.exists(), "take deletes the spill file");
            // A blob left parked is swept on drop.
            tier.put(6, &snap(1, 8)).unwrap();
            assert!(dir.join("seq-6.kvsnap").exists());
        }
        assert!(!dir.join("seq-6.kvsnap").exists(), "drop sweeps leftovers");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
