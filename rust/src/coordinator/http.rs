//! Hand-rolled HTTP/1.1 serving front-end over the [`Coordinator`].
//!
//! `cskv serve --listen <addr>` binds a [`std::net::TcpListener`] and
//! exposes the serving plane over plain sockets — no framework, no
//! dependencies, one thread per connection. The worker thread is never
//! blocked by a client: the connection thread owns the socket and the
//! per-token stream channel; all it shares with the worker are
//! unbounded `mpsc` channels and the request's [`CancelToken`].
//!
//! # Endpoints
//!
//! | Method | Path        | Behaviour                                        |
//! |--------|-------------|--------------------------------------------------|
//! | POST   | `/generate` | Submit `{"prompt":[..],"n_new":N}`; SSE stream   |
//! | GET    | `/healthz`  | Liveness: `200 ok` while the process runs        |
//! | GET    | `/readyz`   | Readiness: `503 draining` once drain starts      |
//! | GET    | `/stats`    | Metrics + cold-tier + prefix-cache JSON snapshot |
//! | POST   | `/drain`    | Graceful drain; `409` if already draining        |
//!
//! # Robustness contract
//!
//! * **Disconnect maps to cancel.** Any write failure on the SSE stream
//!   (client closed the socket, injected `http.write` short write, or a
//!   slow client exceeding the stall timeout) flips the request's
//!   [`CancelToken`]; the worker retires the sequence at its next round
//!   boundary and frees its KV / cold-tier bytes. Exactly one terminal
//!   outcome per request (`cancelled` here) still holds.
//! * **Slow clients never block the worker.** The socket carries a
//!   write timeout of [`HttpConfig::client_stall_timeout`]; a stalled
//!   `write_all` surfaces as an error on the connection thread only,
//!   which then cancels as above. Tokens queue in the unbounded stream
//!   channel meanwhile — the worker's sends never block.
//! * **Overload sheds, never queues unboundedly.** An atomic in-flight
//!   gate admits at most [`HttpConfig::max_queued`] concurrent
//!   `/generate` requests; excess connections get `429` with a
//!   `Retry-After` header (counted via `requests_shed`).
//! * **Graceful drain.** `SIGTERM` or `POST /drain` stops admissions
//!   (`/readyz` flips to 503, `/generate` answers 503), gives in-flight
//!   sequences [`HttpConfig::drain_grace`] to finish, then snapshots the
//!   rest into a [`DrainBundle`] written to [`HttpConfig::drain_file`].
//!   Migrated requests see a terminal `migrated` SSE event; a second
//!   process started with `--resume-from` restores them bit-identically.
//!
//! # SSE wire format
//!
//! Data frames are `event: <name>\ndata: <json>\n\n`:
//!
//! * `token` — `{"i":<index>,"token":<id>}` per generated token;
//! * `done` — `{"id":..,"tokens":[..],"backend":".."}` terminal success
//!   (`tokens` is the *complete* stream, prompt excluded);
//! * `migrated` — `{"id":..,"streamed":N,"error":".."}` when a drain cut
//!   the sequence loose mid-generation;
//! * `error` — `{"id":..,"streamed":N,"error":".."}` for every other
//!   failure (deadline, cancel, backend error).
//!
//! Idle gaps carry `: ping` comment frames (~4/s) so dead clients are
//! detected even between tokens. Pings bypass the `http.write` fault
//! point so `FaultMode::Nth` arming counts data frames deterministically.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context};

use super::metrics::MetricsSnapshot;
use super::request::{Response, DRAINED};
use super::server::{Coordinator, DrainBundle, RequestHandle};
use crate::util::faults::FaultInjector;
use crate::util::json::Json;

/// Serving-plane knobs. `Default` matches the CLI defaults of
/// `cskv serve`.
pub struct HttpConfig {
    /// Maximum concurrent `/generate` requests before shedding with 429.
    pub max_queued: usize,
    /// Socket write timeout: a client that cannot absorb a frame for
    /// this long is treated as gone (write error → cancel).
    pub client_stall_timeout: Duration,
    /// Seconds advertised in `Retry-After` on 429/503 responses.
    pub retry_after_s: u64,
    /// Grace period handed to [`Coordinator::drain`] before in-flight
    /// sequences are snapshotted.
    pub drain_grace: Duration,
    /// Where the [`DrainBundle`] is written on drain (`None`: the bundle
    /// is dropped after answering the migrated requests).
    pub drain_file: Option<PathBuf>,
    /// Reject prompt tokens `>= vocab_size` at the door (0 = unchecked).
    pub vocab_size: usize,
    /// Reject `prompt.len() + n_new > max_seq` at the door (0 = unchecked).
    pub max_seq: usize,
    /// Fault registry consulted at `http.accept` / `http.write`.
    pub faults: FaultInjector,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            max_queued: 64,
            client_stall_timeout: Duration::from_secs(10),
            retry_after_s: 1,
            drain_grace: Duration::from_secs(5),
            drain_file: None,
            vocab_size: 0,
            max_seq: 0,
            faults: FaultInjector::none(),
        }
    }
}

/// Parse a `--listen` address, with a CLI-grade error message.
pub fn parse_listen(s: &str) -> anyhow::Result<SocketAddr> {
    s.parse::<SocketAddr>().map_err(|e| {
        anyhow!("invalid --listen address {s:?}: {e} (expected ip:port, e.g. 127.0.0.1:8080)")
    })
}

/// State shared between the accept loop and connection threads.
struct Shared {
    coord: Coordinator,
    cfg: HttpConfig,
    /// Concurrent `/generate` requests currently admitted.
    inflight: AtomicUsize,
    /// Set once a drain starts; admissions stop immediately.
    draining: AtomicBool,
    /// Set once the drain completes; the accept loop exits.
    done: AtomicBool,
}

/// Decrements the in-flight gauge on every exit path of a `/generate`
/// handler (shed, parse error, stream end, panic unwind).
struct InflightGuard<'a>(&'a AtomicUsize);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

#[cfg(unix)]
mod sigterm {
    //! Minimal `SIGTERM` hook: an async-signal-safe flag flip, polled by
    //! the accept loop. `signal(2)` is reached through a direct libc
    //! declaration — the crate stays dependency-free.
    use std::sync::atomic::{AtomicBool, Ordering};

    static FIRED: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_sigterm(_signum: i32) {
        FIRED.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGTERM, on_sigterm as usize);
        }
    }

    pub fn fired() -> bool {
        FIRED.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sigterm {
    pub fn install() {}
    pub fn fired() -> bool {
        false
    }
}

/// Run the serving loop until a drain completes (via `SIGTERM` or
/// `POST /drain`), then shut the coordinator down and return its final
/// metrics snapshot. Consumes the coordinator: once drained, nothing
/// can be admitted anyway.
pub fn serve(
    coord: Coordinator,
    listener: TcpListener,
    cfg: HttpConfig,
) -> anyhow::Result<MetricsSnapshot> {
    sigterm::install();
    listener
        .set_nonblocking(true)
        .context("set_nonblocking on listener")?;
    let shared = Arc::new(Shared {
        coord,
        cfg,
        inflight: AtomicUsize::new(0),
        draining: AtomicBool::new(false),
        done: AtomicBool::new(false),
    });
    let mut handles: Vec<thread::JoinHandle<()>> = Vec::new();
    while !shared.done.load(Ordering::SeqCst) {
        if sigterm::fired() && !shared.draining.load(Ordering::SeqCst) {
            match do_drain(&shared) {
                Ok((n, _)) => crate::log_info!("sigterm: drained, {n} sequence(s) migrated"),
                Err(e) => crate::log_warn!("sigterm drain: {e:#}"),
            }
            continue;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                if shared.cfg.faults.trip("http.accept").is_err() {
                    // Injected accept fault: the connection is dropped
                    // before a single byte is read — the client sees a
                    // reset, the serving plane sees nothing.
                    drop(stream);
                    continue;
                }
                let s = Arc::clone(&shared);
                handles.push(thread::spawn(move || {
                    if let Err(e) = handle_connection(stream, &s) {
                        crate::log_debug!("connection {peer}: {e:#}");
                    }
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(anyhow!("accept failed: {e}")),
        }
        // Reap finished connection threads so long-lived servers don't
        // accumulate handles.
        let mut live = Vec::with_capacity(handles.len());
        for h in handles.drain(..) {
            if h.is_finished() {
                let _ = h.join();
            } else {
                live.push(h);
            }
        }
        handles = live;
    }
    for h in handles {
        let _ = h.join();
    }
    let shared = Arc::try_unwrap(shared)
        .map_err(|_| anyhow!("a connection thread still holds server state after join"))?;
    Ok(shared.coord.shutdown())
}

/// Start the drain exactly once; concurrent callers get an error (the
/// HTTP handler maps it to `409`). On success the accept loop exits at
/// its next iteration via the `done` flag.
fn do_drain(s: &Shared) -> anyhow::Result<(usize, Option<PathBuf>)> {
    if s.draining.swap(true, Ordering::SeqCst) {
        bail!("drain already in progress");
    }
    let res = (|| {
        let bundle = s.coord.drain(s.cfg.drain_grace)?;
        let mut saved = None;
        if let Some(path) = &s.cfg.drain_file {
            bundle.save(path)?;
            saved = Some(path.clone());
        }
        Ok((bundle.seqs.len(), saved))
    })();
    // Even a failed drain stops the server: the worker is no longer in a
    // state where admitting more work makes sense.
    s.done.store(true, Ordering::SeqCst);
    res
}

fn handle_connection(mut stream: TcpStream, s: &Shared) -> anyhow::Result<()> {
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .context("set_read_timeout")?;
    stream
        .set_write_timeout(Some(s.cfg.client_stall_timeout))
        .context("set_write_timeout")?;
    let (method, path, body) = match read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let _ = write_simple(
                &mut stream,
                400,
                "text/plain",
                format!("bad request: {e:#}\n").as_bytes(),
                &[],
            );
            return Ok(());
        }
    };
    match (method.as_str(), path.as_str()) {
        ("GET", "/healthz") => write_simple(&mut stream, 200, "text/plain", b"ok\n", &[])?,
        ("GET", "/readyz") => {
            if s.draining.load(Ordering::SeqCst) || s.done.load(Ordering::SeqCst) {
                write_simple(&mut stream, 503, "text/plain", b"draining\n", &[])?;
            } else {
                write_simple(&mut stream, 200, "text/plain", b"ready\n", &[])?;
            }
        }
        ("GET", "/stats") => {
            let mut j = s.coord.metrics().snapshot().to_json();
            j.set(
                "draining",
                Json::from(s.draining.load(Ordering::SeqCst) || s.done.load(Ordering::SeqCst)),
            );
            j.set("inflight", Json::from(s.inflight.load(Ordering::SeqCst)));
            write_simple(
                &mut stream,
                200,
                "application/json",
                j.to_string_compact().as_bytes(),
                &[],
            )?;
        }
        ("POST", "/drain") => match do_drain(s) {
            Ok((n, file)) => {
                let mut j = Json::from_pairs(vec![("migrated", Json::from(n))]);
                if let Some(p) = file {
                    j.set("bundle", Json::from(p.display().to_string()));
                }
                write_simple(
                    &mut stream,
                    200,
                    "application/json",
                    j.to_string_compact().as_bytes(),
                    &[],
                )?;
            }
            Err(e) => {
                write_simple(&mut stream, 409, "text/plain", format!("{e:#}\n").as_bytes(), &[])?;
            }
        },
        ("POST", "/generate") => handle_generate(&mut stream, s, &body)?,
        ("GET" | "POST", _) => write_simple(&mut stream, 404, "text/plain", b"not found\n", &[])?,
        _ => write_simple(&mut stream, 405, "text/plain", b"method not allowed\n", &[])?,
    }
    Ok(())
}

/// Read one HTTP/1.1 request: head (≤16 KiB) up to the blank line, then
/// `Content-Length` bytes of body (≤4 MiB). Returns
/// `(method, path-without-query, body)`.
fn read_request(stream: &mut TcpStream) -> anyhow::Result<(String, String, Vec<u8>)> {
    const MAX_HEAD: usize = 16 * 1024;
    const MAX_BODY: usize = 4 * 1024 * 1024;
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    let head_end = loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        ensure!(buf.len() <= MAX_HEAD, "request head exceeds {MAX_HEAD} bytes");
        let n = stream.read(&mut chunk).context("read request head")?;
        ensure!(n > 0, "connection closed before full request head");
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end]).context("request head is not UTF-8")?;
    let mut lines = head.split("\r\n");
    let reqline = lines.next().unwrap_or("");
    let mut parts = reqline.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let raw_path = parts.next().unwrap_or("");
    ensure!(
        !method.is_empty() && !raw_path.is_empty(),
        "malformed request line {reqline:?}"
    );
    let path = raw_path.split('?').next().unwrap_or(raw_path).to_string();
    let mut content_len = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_len = v
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("invalid Content-Length {:?}", v.trim()))?;
            }
        }
    }
    ensure!(content_len <= MAX_BODY, "body exceeds {MAX_BODY} bytes");
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_len {
        let n = stream.read(&mut chunk).context("read request body")?;
        ensure!(n > 0, "connection closed before full request body");
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_len);
    Ok((method, path, body))
}

fn find_subslice(hay: &[u8], needle: &[u8]) -> Option<usize> {
    hay.windows(needle.len()).position(|w| w == needle)
}

fn handle_generate(stream: &mut TcpStream, s: &Shared, body: &[u8]) -> anyhow::Result<()> {
    let retry = [("Retry-After", s.cfg.retry_after_s.to_string())];
    if s.draining.load(Ordering::SeqCst) || s.done.load(Ordering::SeqCst) {
        s.coord.metrics().record_shed();
        write_simple(
            stream,
            503,
            "text/plain",
            b"draining: not admitting requests\n",
            &retry,
        )?;
        return Ok(());
    }
    // Admission gate: increment first, check after — two racing
    // borderline requests may then both shed, but the gate can never
    // admit more than `max_queued`.
    let held = s.inflight.fetch_add(1, Ordering::SeqCst);
    let _guard = InflightGuard(&s.inflight);
    if held >= s.cfg.max_queued {
        s.coord.metrics().record_shed();
        write_simple(
            stream,
            429,
            "text/plain",
            b"overloaded: queue full, retry later\n",
            &retry,
        )?;
        return Ok(());
    }
    let (prompt, n_new, deadline) = match parse_generate_body(body, &s.cfg) {
        Ok(p) => p,
        Err(e) => {
            write_simple(
                stream,
                400,
                "text/plain",
                format!("bad request: {e:#}\n").as_bytes(),
                &[],
            )?;
            return Ok(());
        }
    };
    let (handle, tokens) = s.coord.submit_streaming(prompt, n_new, deadline);
    stream_sse(stream, s, handle, tokens)
}

/// Strict token parse: non-negative integer, rejecting floats and
/// negatives that `f64 as usize` would silently clamp.
fn as_token(v: &Json) -> Option<usize> {
    let n = v.as_f64()?;
    if !n.is_finite() || n < 0.0 || n.fract() != 0.0 || n >= usize::MAX as f64 {
        return None;
    }
    Some(n as usize)
}

type GenerateParams = (Vec<usize>, usize, Option<Duration>);

fn parse_generate_body(body: &[u8], cfg: &HttpConfig) -> anyhow::Result<GenerateParams> {
    let text = std::str::from_utf8(body).context("body is not UTF-8")?;
    let j = Json::parse(text).map_err(|e| anyhow!("invalid JSON at byte {}: {}", e.pos, e.msg))?;
    let prompt_json = j
        .at("prompt")
        .ok_or_else(|| anyhow!("missing \"prompt\" (array of token ids)"))?;
    let Json::Arr(items) = prompt_json else {
        bail!("\"prompt\" must be an array of token ids");
    };
    let mut prompt = Vec::with_capacity(items.len());
    for it in items {
        let tok =
            as_token(it).ok_or_else(|| anyhow!("prompt entries must be non-negative integers"))?;
        if cfg.vocab_size > 0 && tok >= cfg.vocab_size {
            bail!("prompt token {tok} out of range (vocab size {})", cfg.vocab_size);
        }
        prompt.push(tok);
    }
    ensure!(!prompt.is_empty(), "\"prompt\" must be non-empty");
    let n_new = j
        .at("n_new")
        .and_then(as_token)
        .ok_or_else(|| anyhow!("missing or invalid \"n_new\" (positive integer)"))?;
    ensure!(n_new >= 1, "\"n_new\" must be at least 1");
    if cfg.max_seq > 0 {
        ensure!(
            prompt.len() + n_new <= cfg.max_seq,
            "prompt ({}) + n_new ({n_new}) exceeds max sequence length {}",
            prompt.len(),
            cfg.max_seq
        );
    }
    let deadline = match j.at("deadline_ms") {
        Some(v) => {
            let ms = as_token(v)
                .filter(|&ms| ms > 0)
                .ok_or_else(|| anyhow!("\"deadline_ms\" must be a positive integer"))?;
            Some(Duration::from_millis(ms as u64))
        }
        None => None,
    };
    Ok((prompt, n_new, deadline))
}

/// Forward the token stream as SSE until the terminal [`Response`]
/// arrives. Every write failure cancels the request — the worker frees
/// its KV at the next round boundary — and ends the connection.
fn stream_sse(
    stream: &mut TcpStream,
    s: &Shared,
    handle: RequestHandle,
    tokens: mpsc::Receiver<usize>,
) -> anyhow::Result<()> {
    const HEAD: &str = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n";
    const PING_EVERY: Duration = Duration::from_millis(250);
    let faults = &s.cfg.faults;
    if let Err(e) = stream.write_all(HEAD.as_bytes()) {
        handle.cancel.cancel();
        bail!("client gone before stream start (request {} cancelled): {e}", handle.id);
    }
    let mut streamed = 0usize;
    let mut last_ping = Instant::now();
    loop {
        match tokens.recv_timeout(Duration::from_millis(20)) {
            Ok(tok) => {
                if let Err(e) = emit_token(stream, streamed, tok, faults) {
                    handle.cancel.cancel();
                    bail!("client write failed (request {} cancelled): {e}", handle.id);
                }
                streamed += 1;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if let Ok(resp) = handle.rx.try_recv() {
                    return drain_and_finish(stream, &handle, &tokens, resp, streamed, faults);
                }
                if last_ping.elapsed() >= PING_EVERY {
                    // Keep-alive comment frame, written raw: pings bypass
                    // the `http.write` fault point so Nth-frame arming
                    // counts data frames only.
                    if let Err(e) = stream.write_all(b": ping\n\n") {
                        handle.cancel.cancel();
                        bail!("client gone at ping (request {} cancelled): {e}", handle.id);
                    }
                    last_ping = Instant::now();
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // The worker dropped the stream sender: the terminal
                // Response is already sent (exactly-one-Response).
                let resp = handle
                    .rx
                    .recv()
                    .map_err(|_| anyhow!("worker dropped reply for request {}", handle.id))?;
                return drain_and_finish(stream, &handle, &tokens, resp, streamed, faults);
            }
        }
    }
}

/// Flush any tokens still buffered in the stream channel, then emit the
/// terminal SSE event for `resp`.
fn drain_and_finish(
    stream: &mut TcpStream,
    handle: &RequestHandle,
    tokens: &mpsc::Receiver<usize>,
    resp: Response,
    mut streamed: usize,
    faults: &FaultInjector,
) -> anyhow::Result<()> {
    for tok in tokens.try_iter() {
        if let Err(e) = emit_token(stream, streamed, tok, faults) {
            handle.cancel.cancel(); // no-op post-terminal; kept for symmetry
            bail!("client write failed at tail of request {}: {e}", handle.id);
        }
        streamed += 1;
    }
    finish_sse(stream, &resp, streamed, faults)
        .map_err(|e| anyhow!("client write failed at terminal event of request {}: {e}", resp.id))
}

fn emit_token(
    stream: &mut TcpStream,
    i: usize,
    tok: usize,
    faults: &FaultInjector,
) -> std::io::Result<()> {
    let data = Json::from_pairs(vec![("i", Json::from(i)), ("token", Json::from(tok))]);
    write_sse(stream, "token", &data.to_string_compact(), faults)
}

/// Map the terminal [`Response`] onto its SSE event: `done` on success,
/// `migrated` when a graceful drain snapshotted the sequence
/// ([`DRAINED`]), `error` otherwise.
fn finish_sse(
    stream: &mut TcpStream,
    resp: &Response,
    streamed: usize,
    faults: &FaultInjector,
) -> std::io::Result<()> {
    let id = Json::from(resp.id as usize);
    let (event, data) = match resp.error.as_deref() {
        None => (
            "done",
            Json::from_pairs(vec![
                ("id", id),
                (
                    "tokens",
                    Json::Arr(resp.tokens.iter().map(|&t| Json::from(t)).collect()),
                ),
                ("backend", Json::from(resp.backend.as_str())),
            ]),
        ),
        Some(e) if e == DRAINED => (
            "migrated",
            Json::from_pairs(vec![
                ("id", id),
                ("streamed", Json::from(streamed)),
                ("error", Json::from(e)),
            ]),
        ),
        Some(e) => (
            "error",
            Json::from_pairs(vec![
                ("id", id),
                ("streamed", Json::from(streamed)),
                ("error", Json::from(e)),
            ]),
        ),
    };
    write_sse(stream, event, &data.to_string_compact(), faults)
}

/// Write one SSE frame through the `http.write` fault point: an armed
/// fault truncates the frame mid-write (a deterministic "short write")
/// and surfaces `BrokenPipe`, exactly like a client vanishing between
/// two TCP segments.
fn write_sse(
    stream: &mut TcpStream,
    event: &str,
    data: &str,
    faults: &FaultInjector,
) -> std::io::Result<()> {
    let frame = format!("event: {event}\ndata: {data}\n\n");
    if faults.trip("http.write").is_err() {
        let half = frame.len() / 2;
        let _ = stream.write_all(&frame.as_bytes()[..half]);
        let _ = stream.flush();
        return Err(std::io::Error::new(
            std::io::ErrorKind::BrokenPipe,
            "injected short write at http.write",
        ));
    }
    stream.write_all(frame.as_bytes())
}

/// Write a complete non-streaming response with `Connection: close`.
fn write_simple(
    stream: &mut TcpStream,
    status: u16,
    ctype: &str,
    body: &[u8],
    extra: &[(&str, String)],
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        _ => "Unknown",
    };
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    for (k, v) in extra {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// Resume every sequence of a [`DrainBundle`] on `coord`, blocking until
/// all have completed. Returns `(id, tokens, error)` per sequence in
/// bundle order — `tokens` is the *full* stream (carried + regenerated),
/// so a successful resume is bit-identical to an undisturbed run. Used
/// by `cskv serve --resume-from` and the cross-process migration tests.
pub fn resume_bundle(
    coord: &Coordinator,
    bundle: DrainBundle,
) -> Vec<(u64, Vec<usize>, Option<String>)> {
    let mut pending = Vec::new();
    for seq in bundle.seqs {
        let id = seq.id;
        let carried = seq.generated.clone();
        let (handle, tokens) = coord.resume_drained(seq, None);
        pending.push((id, carried, handle, tokens));
    }
    let mut out = Vec::new();
    for (id, carried, handle, _tokens) in pending {
        match handle.rx.recv() {
            Ok(resp) => {
                let toks = if resp.error.is_none() {
                    // `resp.tokens` already includes the carried prefix
                    // for restored sequences; re-run queued sequences
                    // start from scratch and also return the full stream.
                    resp.tokens
                } else {
                    carried
                };
                out.push((id, toks, resp.error));
            }
            Err(_) => out.push((id, carried, Some("worker dropped reply".to_string()))),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_listen_accepts_ip_port_and_rejects_garbage() {
        let a = parse_listen("127.0.0.1:8080").unwrap();
        assert_eq!(a.port(), 8080);
        assert!(a.ip().is_loopback());
        assert!(parse_listen("0.0.0.0:0").is_ok());
        for bad in ["", "8080", "localhost:8080", "127.0.0.1", "127.0.0.1:banana"] {
            let err = parse_listen(bad).unwrap_err().to_string();
            assert!(err.contains("invalid --listen"), "{bad}: {err}");
        }
    }

    #[test]
    fn token_parse_rejects_floats_negatives_and_non_numbers() {
        assert_eq!(as_token(&Json::from(7usize)), Some(7));
        assert_eq!(as_token(&Json::from(0usize)), Some(0));
        assert_eq!(as_token(&Json::Num(3.5)), None);
        assert_eq!(as_token(&Json::Num(-1.0)), None);
        assert_eq!(as_token(&Json::Num(f64::NAN)), None);
        assert_eq!(as_token(&Json::from("9")), None);
    }

    #[test]
    fn generate_body_validation_covers_every_field() {
        let cfg = HttpConfig {
            vocab_size: 50,
            max_seq: 16,
            ..HttpConfig::default()
        };
        let ok = parse_generate_body(br#"{"prompt":[1,2,3],"n_new":4}"#, &cfg).unwrap();
        assert_eq!(ok, (vec![1, 2, 3], 4, None));
        let with_deadline =
            parse_generate_body(br#"{"prompt":[1],"n_new":1,"deadline_ms":250}"#, &cfg).unwrap();
        assert_eq!(with_deadline.2, Some(Duration::from_millis(250)));
        let cases: &[(&[u8], &str)] = &[
            (b"not json", "invalid JSON"),
            (br#"{"n_new":4}"#, "missing \"prompt\""),
            (br#"{"prompt":"hi","n_new":4}"#, "must be an array"),
            (br#"{"prompt":[],"n_new":4}"#, "non-empty"),
            (br#"{"prompt":[1.5],"n_new":4}"#, "non-negative integers"),
            (br#"{"prompt":[99],"n_new":4}"#, "out of range"),
            (br#"{"prompt":[1]}"#, "n_new"),
            (br#"{"prompt":[1],"n_new":0}"#, "n_new"),
            (br#"{"prompt":[1,2],"n_new":15}"#, "exceeds max sequence"),
            (br#"{"prompt":[1],"n_new":1,"deadline_ms":0}"#, "deadline_ms"),
        ];
        for (body, want) in cases {
            let err = format!("{:#}", parse_generate_body(body, &cfg).unwrap_err());
            assert!(err.contains(want), "body {:?}: {err}", String::from_utf8_lossy(body));
        }
        // Unchecked limits admit anything structurally valid.
        let open = HttpConfig::default();
        assert!(parse_generate_body(br#"{"prompt":[99999],"n_new":500}"#, &open).is_ok());
    }

    #[test]
    fn subslice_finder_locates_header_terminator() {
        assert_eq!(find_subslice(b"ab\r\n\r\ncd", b"\r\n\r\n"), Some(2));
        assert_eq!(find_subslice(b"ab", b"\r\n\r\n"), None);
        assert_eq!(find_subslice(b"", b"\r\n\r\n"), None);
    }
}
