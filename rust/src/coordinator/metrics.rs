//! Serving metrics: counters, latency distributions, KV footprint, and
//! the scheduler's preemption/cold-tier accounting.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Samples;

#[derive(Default)]
struct Inner {
    requests_completed: u64,
    requests_failed: u64,
    tokens_generated: u64,
    queue_wait_s: Samples,
    ttft_s: Samples,
    /// TTFT split by outcome: sequences that ran hot end-to-end vs
    /// sequences that were swapped to the cold tier at least once.
    ttft_clean_s: Samples,
    ttft_preempted_s: Samples,
    tok_latency_s: Samples,
    kv_bytes_peak: usize,
    kv_bytes_current: usize,
    active_peak: usize,
    /// Swap-outs to the cold tier / restores back into the hot tier.
    preemptions: u64,
    restores: u64,
    cold_bytes_current: usize,
    cold_bytes_peak: usize,
    /// Request ids in retirement order — the fairness oracle
    /// (`rust/tests/batched_serving.rs` asserts head-of-line behavior
    /// directly on this).
    completion_order: Vec<u64>,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// Thread-safe metrics sink shared between the coordinator and callers.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// One successful retirement, as recorded by the worker.
pub struct Completion<'a> {
    pub id: u64,
    pub queue_wait_s: f64,
    pub ttft_s: f64,
    pub tokens: usize,
    pub tok_latency_s: &'a [f64],
    /// Times this sequence was swapped out before finishing.
    pub preemptions: usize,
}

/// A point-in-time copy for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests_completed: u64,
    /// Requests answered with an error `Response` (backend construction,
    /// prefill, or cold-tier restore failure) instead of tokens.
    pub requests_failed: u64,
    pub tokens_generated: u64,
    pub queue_wait_s: Samples,
    pub ttft_s: Samples,
    /// TTFT of sequences never swapped out.
    pub ttft_clean_s: Samples,
    /// TTFT of sequences preempted at least once (TTFT itself is set at
    /// first prefill; this isolates whether preemption-prone sequences
    /// also queued longer).
    pub ttft_preempted_s: Samples,
    pub tok_latency_s: Samples,
    pub kv_bytes_peak: usize,
    pub active_peak: usize,
    /// Cold-tier traffic: swap-outs and bit-identical restores.
    pub preemptions: u64,
    pub restores: u64,
    /// High-water mark of snapshot bytes parked in the cold tier.
    pub cold_bytes_peak: usize,
    /// Request ids in retirement order.
    pub completion_order: Vec<u64>,
    pub wall_s: f64,
}

impl MetricsSnapshot {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens_generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} failed={} tokens={} throughput={:.1} tok/s | queue-wait {} | ttft {} | tok-latency {} | kv-peak {} | max-concurrency {} | preempt/restore {}/{} (cold-peak {})",
            self.requests_completed,
            self.requests_failed,
            self.tokens_generated,
            self.throughput_tok_s(),
            self.queue_wait_s.summary("s"),
            self.ttft_s.summary("s"),
            self.tok_latency_s.summary("s"),
            crate::util::table::bytes(self.kv_bytes_peak),
            self.active_peak,
            self.preemptions,
            self.restores,
            crate::util::table::bytes(self.cold_bytes_peak),
        )
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mark_start(&self) {
        let mut g = self.inner.lock().unwrap();
        if g.started.is_none() {
            g.started = Some(Instant::now());
        }
    }

    pub fn record_completion(&self, c: Completion<'_>) {
        let mut g = self.inner.lock().unwrap();
        g.requests_completed += 1;
        g.tokens_generated += c.tokens as u64;
        g.queue_wait_s.push(c.queue_wait_s);
        g.ttft_s.push(c.ttft_s);
        if c.preemptions > 0 {
            g.ttft_preempted_s.push(c.ttft_s);
        } else {
            g.ttft_clean_s.push(c.ttft_s);
        }
        for &t in c.tok_latency_s {
            g.tok_latency_s.push(t);
        }
        g.completion_order.push(c.id);
        g.finished = Some(Instant::now());
    }

    /// A request was answered with an error `Response`.
    pub fn record_failure(&self) {
        let mut g = self.inner.lock().unwrap();
        g.requests_failed += 1;
        g.finished = Some(Instant::now());
    }

    pub fn record_kv(&self, current_bytes: usize, active: usize) {
        let mut g = self.inner.lock().unwrap();
        g.kv_bytes_current = current_bytes;
        g.kv_bytes_peak = g.kv_bytes_peak.max(current_bytes);
        g.active_peak = g.active_peak.max(active);
    }

    /// A sequence was swapped out; `cold_bytes_now` is the tier's new
    /// resident size.
    pub fn record_preemption(&self, cold_bytes_now: usize) {
        let mut g = self.inner.lock().unwrap();
        g.preemptions += 1;
        g.cold_bytes_current = cold_bytes_now;
        g.cold_bytes_peak = g.cold_bytes_peak.max(cold_bytes_now);
    }

    /// A swapped sequence was restored into the hot tier.
    pub fn record_restore(&self, cold_bytes_now: usize) {
        let mut g = self.inner.lock().unwrap();
        g.restores += 1;
        g.cold_bytes_current = cold_bytes_now;
    }

    pub fn kv_bytes_current(&self) -> usize {
        self.inner.lock().unwrap().kv_bytes_current
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let wall_s = match (g.started, g.finished) {
            (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
            (Some(a), None) => a.elapsed().as_secs_f64(),
            _ => 0.0,
        };
        MetricsSnapshot {
            requests_completed: g.requests_completed,
            requests_failed: g.requests_failed,
            tokens_generated: g.tokens_generated,
            queue_wait_s: g.queue_wait_s.clone(),
            ttft_s: g.ttft_s.clone(),
            ttft_clean_s: g.ttft_clean_s.clone(),
            ttft_preempted_s: g.ttft_preempted_s.clone(),
            tok_latency_s: g.tok_latency_s.clone(),
            kv_bytes_peak: g.kv_bytes_peak,
            active_peak: g.active_peak,
            preemptions: g.preemptions,
            restores: g.restores,
            cold_bytes_peak: g.cold_bytes_peak,
            completion_order: g.completion_order.clone(),
            wall_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn complete(m: &Metrics, id: u64, ttft: f64, preemptions: usize) {
        m.record_completion(Completion {
            id,
            queue_wait_s: 0.01,
            ttft_s: ttft,
            tokens: 3,
            tok_latency_s: &[0.01, 0.02],
            preemptions,
        });
    }

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.mark_start();
        m.record_kv(1000, 2);
        m.record_kv(500, 1);
        complete(&m, 7, 0.05, 0);
        complete(&m, 9, 0.06, 2);
        m.record_failure();
        let s = m.snapshot();
        assert_eq!(s.requests_completed, 2);
        assert_eq!(s.requests_failed, 1);
        assert!(s.report().contains("failed=1"));
        assert_eq!(s.tokens_generated, 6);
        assert_eq!(s.kv_bytes_peak, 1000);
        assert_eq!(s.active_peak, 2);
        assert_eq!(s.tok_latency_s.len(), 4);
        assert!(s.throughput_tok_s() >= 0.0);
        assert!(s.report().contains("requests=2"));
        // Per-outcome TTFT split + completion order.
        assert_eq!(s.ttft_clean_s.len(), 1);
        assert_eq!(s.ttft_preempted_s.len(), 1);
        assert_eq!(s.completion_order, vec![7, 9]);
    }

    #[test]
    fn cold_tier_counters_track_peak() {
        let m = Metrics::new();
        m.record_preemption(4096);
        m.record_preemption(10240);
        m.record_restore(6144);
        m.record_restore(0);
        let s = m.snapshot();
        assert_eq!(s.preemptions, 2);
        assert_eq!(s.restores, 2);
        assert_eq!(s.cold_bytes_peak, 10240);
        assert!(s.report().contains("preempt/restore 2/2"));
    }
}
