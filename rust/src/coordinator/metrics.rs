//! Serving metrics: counters, latency distributions, KV footprint.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Samples;

#[derive(Default)]
struct Inner {
    requests_completed: u64,
    requests_failed: u64,
    tokens_generated: u64,
    queue_wait_s: Samples,
    ttft_s: Samples,
    tok_latency_s: Samples,
    kv_bytes_peak: usize,
    kv_bytes_current: usize,
    active_peak: usize,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// Thread-safe metrics sink shared between the coordinator and callers.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time copy for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests_completed: u64,
    /// Requests answered with an error `Response` (backend construction
    /// or prefill failure) instead of tokens.
    pub requests_failed: u64,
    pub tokens_generated: u64,
    pub queue_wait_s: Samples,
    pub ttft_s: Samples,
    pub tok_latency_s: Samples,
    pub kv_bytes_peak: usize,
    pub active_peak: usize,
    pub wall_s: f64,
}

impl MetricsSnapshot {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens_generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} failed={} tokens={} throughput={:.1} tok/s | ttft {} | tok-latency {} | kv-peak {} | max-concurrency {}",
            self.requests_completed,
            self.requests_failed,
            self.tokens_generated,
            self.throughput_tok_s(),
            self.ttft_s.summary("s"),
            self.tok_latency_s.summary("s"),
            crate::util::table::bytes(self.kv_bytes_peak),
            self.active_peak,
        )
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mark_start(&self) {
        let mut g = self.inner.lock().unwrap();
        if g.started.is_none() {
            g.started = Some(Instant::now());
        }
    }

    pub fn record_completion(&self, queue_wait_s: f64, ttft_s: f64, tokens: usize, tok_latency_s: &[f64]) {
        let mut g = self.inner.lock().unwrap();
        g.requests_completed += 1;
        g.tokens_generated += tokens as u64;
        g.queue_wait_s.push(queue_wait_s);
        g.ttft_s.push(ttft_s);
        for &t in tok_latency_s {
            g.tok_latency_s.push(t);
        }
        g.finished = Some(Instant::now());
    }

    /// A request was answered with an error `Response`.
    pub fn record_failure(&self) {
        let mut g = self.inner.lock().unwrap();
        g.requests_failed += 1;
        g.finished = Some(Instant::now());
    }

    pub fn record_kv(&self, current_bytes: usize, active: usize) {
        let mut g = self.inner.lock().unwrap();
        g.kv_bytes_current = current_bytes;
        g.kv_bytes_peak = g.kv_bytes_peak.max(current_bytes);
        g.active_peak = g.active_peak.max(active);
    }

    pub fn kv_bytes_current(&self) -> usize {
        self.inner.lock().unwrap().kv_bytes_current
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let wall_s = match (g.started, g.finished) {
            (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
            (Some(a), None) => a.elapsed().as_secs_f64(),
            _ => 0.0,
        };
        MetricsSnapshot {
            requests_completed: g.requests_completed,
            requests_failed: g.requests_failed,
            tokens_generated: g.tokens_generated,
            queue_wait_s: g.queue_wait_s.clone(),
            ttft_s: g.ttft_s.clone(),
            tok_latency_s: g.tok_latency_s.clone(),
            kv_bytes_peak: g.kv_bytes_peak,
            active_peak: g.active_peak,
            wall_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.mark_start();
        m.record_kv(1000, 2);
        m.record_kv(500, 1);
        m.record_completion(0.01, 0.05, 3, &[0.01, 0.02]);
        m.record_completion(0.02, 0.06, 2, &[0.015]);
        m.record_failure();
        let s = m.snapshot();
        assert_eq!(s.requests_completed, 2);
        assert_eq!(s.requests_failed, 1);
        assert!(s.report().contains("failed=1"));
        assert_eq!(s.tokens_generated, 5);
        assert_eq!(s.kv_bytes_peak, 1000);
        assert_eq!(s.active_peak, 2);
        assert_eq!(s.tok_latency_s.len(), 3);
        assert!(s.throughput_tok_s() >= 0.0);
        assert!(s.report().contains("requests=2"));
    }
}
