//! Serving metrics: counters, latency distributions, KV footprint, and
//! the scheduler's preemption/pager accounting.

use std::sync::Mutex;
use std::time::Instant;

use super::pager::PagerStats;
use crate::util::stats::Samples;

#[derive(Default)]
struct Inner {
    requests_completed: u64,
    requests_failed: u64,
    /// Requests reaped past their deadline / by client cancellation.
    /// Tracked apart from `requests_failed`: nothing broke, the client
    /// changed its mind (or ran out of patience).
    requests_expired: u64,
    requests_cancelled: u64,
    /// Total time-in-system of expired / cancelled requests — how long
    /// abandoned work occupied the plane before the reaper cut it.
    expired_s: Samples,
    cancelled_s: Samples,
    /// Requests rejected by the overload admission gate (HTTP 429) or
    /// refused because the coordinator had already stopped admitting.
    requests_shed: u64,
    /// Sequences migrated out through a graceful drain: answered with
    /// [`crate::coordinator::request::DRAINED`] and written to the
    /// snapshot bundle instead of running to completion here.
    requests_drained: u64,
    tokens_generated: u64,
    queue_wait_s: Samples,
    ttft_s: Samples,
    /// TTFT split by outcome: sequences that ran hot end-to-end vs
    /// sequences that were swapped to the cold tier at least once.
    ttft_clean_s: Samples,
    ttft_preempted_s: Samples,
    tok_latency_s: Samples,
    kv_bytes_peak: usize,
    kv_bytes_current: usize,
    active_peak: usize,
    /// Swap-outs to the pager / restores back into the hot tier.
    preemptions: u64,
    restores: u64,
    /// Total pager-resident bytes (warm + disk) — the old cold-tier
    /// gauge, kept so no-leak assertions read one number.
    cold_bytes_current: usize,
    cold_bytes_peak: usize,
    /// Per-tier split of the same residency.
    warm_bytes_current: usize,
    disk_bytes_current: usize,
    /// Pager health, mirrored from [`PagerStats`] once per round.
    pager: PagerStats,
    /// Wall time `resume_round` spent blocked on pager reads — the
    /// stall the prefetcher exists to hide (one sample per restore).
    restore_stall_s: Samples,
    /// Request ids in retirement order — the fairness oracle
    /// (`rust/tests/batched_serving.rs` asserts head-of-line behavior
    /// directly on this).
    completion_order: Vec<u64>,
    /// Prefix-cache traffic: admission lookups that matched / missed,
    /// total bytes served from shared trie nodes, LRU evictions, and the
    /// trie's resident-bytes high-water mark.
    prefix_hits: u64,
    prefix_misses: u64,
    prefix_shared_bytes: u64,
    prefix_evictions: u64,
    prefix_bytes_peak: usize,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// Thread-safe metrics sink shared between the coordinator and callers.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// One successful retirement, as recorded by the worker.
pub struct Completion<'a> {
    pub id: u64,
    pub queue_wait_s: f64,
    pub ttft_s: f64,
    pub tokens: usize,
    pub tok_latency_s: &'a [f64],
    /// Times this sequence was swapped out before finishing.
    pub preemptions: usize,
}

/// A point-in-time copy for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests_completed: u64,
    /// Requests answered with an error `Response` (backend construction,
    /// prefill, or cold-tier restore failure) instead of tokens.
    pub requests_failed: u64,
    /// Requests reaped past their deadline (queued or in-flight).
    pub requests_expired: u64,
    /// Requests cut short by client cancellation.
    pub requests_cancelled: u64,
    /// Time-in-system distributions of the two reaped outcomes.
    pub expired_s: Samples,
    pub cancelled_s: Samples,
    /// Requests bounced by the overload gate (or a stopped coordinator)
    /// without ever being queued.
    pub requests_shed: u64,
    /// Sequences answered `DRAINED` and migrated into a snapshot bundle.
    pub requests_drained: u64,
    pub tokens_generated: u64,
    pub queue_wait_s: Samples,
    pub ttft_s: Samples,
    /// TTFT of sequences never swapped out.
    pub ttft_clean_s: Samples,
    /// TTFT of sequences preempted at least once (TTFT itself is set at
    /// first prefill; this isolates whether preemption-prone sequences
    /// also queued longer).
    pub ttft_preempted_s: Samples,
    pub tok_latency_s: Samples,
    pub kv_bytes_peak: usize,
    /// Committed KV bytes at snapshot time — 0 once the plane is drained
    /// (the no-leak assertion chaos tests pivot on).
    pub kv_bytes_current: usize,
    pub active_peak: usize,
    /// Pager traffic: swap-outs and bit-identical restores.
    pub preemptions: u64,
    pub restores: u64,
    /// High-water mark of snapshot bytes parked in the pager (all tiers).
    pub cold_bytes_peak: usize,
    /// Snapshot bytes parked right now (warm + disk) — 0 once drained.
    pub cold_bytes_current: usize,
    /// Per-tier split of the same residency: encoded blocks held in the
    /// warm RAM tier vs spilled to the disk tier.
    pub warm_bytes_current: usize,
    pub disk_bytes_current: usize,
    /// Pager health: per-tier peaks, block spill/promote traffic,
    /// prefetch hit/miss counts, retry counts, degraded flag.
    pub pager: PagerStats,
    /// Per-restore wall time the worker spent blocked on pager reads.
    pub restore_stall_s: Samples,
    /// Request ids in retirement order.
    pub completion_order: Vec<u64>,
    /// Prefix-cache admission hits / misses (0/0 when the cache is off).
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    /// Total trie-node bytes served to warm admissions.
    pub prefix_shared_bytes: u64,
    /// Trie LRU evictions.
    pub prefix_evictions: u64,
    /// High-water mark of the trie's resident payload bytes.
    pub prefix_bytes_peak: usize,
    pub wall_s: f64,
}

impl MetricsSnapshot {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens_generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Prefix-cache hit rate over admission lookups, or `None` when the
    /// cache never saw one (disabled).
    pub fn prefix_hit_rate(&self) -> Option<f64> {
        let total = self.prefix_hits + self.prefix_misses;
        (total > 0).then(|| self.prefix_hits as f64 / total as f64)
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} failed={} expired={} cancelled={} shed={} drained={} tokens={} throughput={:.1} tok/s | queue-wait {} | ttft {} | tok-latency {} | kv-peak {} | max-concurrency {} | preempt/restore {}/{} (cold-peak {})",
            self.requests_completed,
            self.requests_failed,
            self.requests_expired,
            self.requests_cancelled,
            self.requests_shed,
            self.requests_drained,
            self.tokens_generated,
            self.throughput_tok_s(),
            self.queue_wait_s.summary("s"),
            self.ttft_s.summary("s"),
            self.tok_latency_s.summary("s"),
            crate::util::table::bytes(self.kv_bytes_peak),
            self.active_peak,
            self.preemptions,
            self.restores,
            crate::util::table::bytes(self.cold_bytes_peak),
        );
        if let Some(rate) = self.prefix_hit_rate() {
            s.push_str(&format!(
                " | prefix-cache {}/{} hits ({:.0}%) shared {} evictions {} (resident-peak {})",
                self.prefix_hits,
                self.prefix_hits + self.prefix_misses,
                rate * 100.0,
                crate::util::table::bytes(self.prefix_shared_bytes as usize),
                self.prefix_evictions,
                crate::util::table::bytes(self.prefix_bytes_peak),
            ));
        }
        if let Some(t) = self.pager_tiers() {
            s.push_str(&format!(" | pager {t}"));
        }
        if let Some(h) = self.pager_health() {
            s.push_str(&format!(" | pager-health {h}"));
        }
        s
    }

    /// Per-tier pager traffic summary, or `None` when the pager never
    /// held a block (no preemptions) — quiet planes stay off the line.
    pub fn pager_tiers(&self) -> Option<String> {
        let p = &self.pager;
        if p.warm_bytes_peak == 0 && p.disk_bytes_peak == 0 {
            return None;
        }
        let b = crate::util::table::bytes;
        let mut s = format!(
            "warm {}/{} disk {}/{} spill {}blk/{} promote {}blk/{}",
            b(self.warm_bytes_current),
            b(p.warm_bytes_peak),
            b(self.disk_bytes_current),
            b(p.disk_bytes_peak),
            p.block_spills,
            b(p.spill_bytes as usize),
            p.block_promotes,
            b(p.promote_bytes as usize),
        );
        if p.prefetch_hits + p.prefetch_misses > 0 {
            s.push_str(&format!(
                " prefetch {}h/{}m",
                p.prefetch_hits, p.prefetch_misses
            ));
        }
        if self.restore_stall_s.len() > 0 {
            s.push_str(&format!(
                " stall {:.4}s/restore",
                self.restore_stall_s.mean()
            ));
        }
        Some(s)
    }

    /// Pager fault summary, or `None` when every tier ran clean (no
    /// retries, no corrupt restores, never degraded) — the common case
    /// stays out of the report line.
    pub fn pager_health(&self) -> Option<String> {
        let c = &self.pager;
        if c.spill_retries == 0
            && c.read_retries == 0
            && c.corrupt_restores == 0
            && !c.degraded
        {
            return None;
        }
        let mut parts = Vec::new();
        if c.spill_retries > 0 {
            parts.push(format!("spill-retries={}", c.spill_retries));
        }
        if c.read_retries > 0 {
            parts.push(format!("read-retries={}", c.read_retries));
        }
        if c.corrupt_restores > 0 {
            parts.push(format!("corrupt-restores={}", c.corrupt_restores));
        }
        if c.degraded {
            parts.push("DEGRADED(warm-only)".to_string());
        }
        Some(parts.join(" "))
    }

    /// The wire form of the HTTP stats endpoint: every counter, the
    /// latency distributions (mean/p50/p95/n), the KV / pager /
    /// prefix-cache gauges, and the pager health block, as one JSON
    /// object built on [`crate::util::json::Json`]. Shape documented in
    /// the [`crate::coordinator`] module docs.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let dist = |s: &Samples| {
            Json::from_pairs(vec![
                ("mean_s", Json::Num(s.mean())),
                ("p50_s", Json::Num(s.percentile(50.0))),
                ("p95_s", Json::Num(s.percentile(95.0))),
                ("n", Json::from(s.len())),
            ])
        };
        let requests = Json::from_pairs(vec![
            ("completed", Json::from(self.requests_completed as usize)),
            ("failed", Json::from(self.requests_failed as usize)),
            ("expired", Json::from(self.requests_expired as usize)),
            ("cancelled", Json::from(self.requests_cancelled as usize)),
            ("shed", Json::from(self.requests_shed as usize)),
            ("drained", Json::from(self.requests_drained as usize)),
        ]);
        let latency = Json::from_pairs(vec![
            ("queue_wait", dist(&self.queue_wait_s)),
            ("ttft", dist(&self.ttft_s)),
            ("ttft_clean", dist(&self.ttft_clean_s)),
            ("ttft_preempted", dist(&self.ttft_preempted_s)),
            ("tok_latency", dist(&self.tok_latency_s)),
            ("expired", dist(&self.expired_s)),
            ("cancelled", dist(&self.cancelled_s)),
        ]);
        let kv = Json::from_pairs(vec![
            ("bytes_current", Json::from(self.kv_bytes_current)),
            ("bytes_peak", Json::from(self.kv_bytes_peak)),
            ("active_peak", Json::from(self.active_peak)),
        ]);
        let pager = Json::from_pairs(vec![
            ("bytes_current", Json::from(self.cold_bytes_current)),
            ("bytes_peak", Json::from(self.cold_bytes_peak)),
            ("warm_bytes_current", Json::from(self.warm_bytes_current)),
            ("warm_bytes_peak", Json::from(self.pager.warm_bytes_peak)),
            ("disk_bytes_current", Json::from(self.disk_bytes_current)),
            ("disk_bytes_peak", Json::from(self.pager.disk_bytes_peak)),
            ("preemptions", Json::from(self.preemptions as usize)),
            ("restores", Json::from(self.restores as usize)),
            ("block_spills", Json::from(self.pager.block_spills as usize)),
            ("block_promotes", Json::from(self.pager.block_promotes as usize)),
            ("spill_bytes", Json::from(self.pager.spill_bytes as usize)),
            ("promote_bytes", Json::from(self.pager.promote_bytes as usize)),
            ("prefetch_hits", Json::from(self.pager.prefetch_hits as usize)),
            ("prefetch_misses", Json::from(self.pager.prefetch_misses as usize)),
            ("restore_stall_s", Json::Num(self.restore_stall_s.mean() * self.restore_stall_s.len() as f64)),
            ("spill_retries", Json::from(self.pager.spill_retries as usize)),
            ("read_retries", Json::from(self.pager.read_retries as usize)),
            ("corrupt_restores", Json::from(self.pager.corrupt_restores as usize)),
            ("degraded", Json::from(self.pager.degraded)),
        ]);
        let prefix = Json::from_pairs(vec![
            ("hits", Json::from(self.prefix_hits as usize)),
            ("misses", Json::from(self.prefix_misses as usize)),
            ("shared_bytes", Json::from(self.prefix_shared_bytes as usize)),
            ("evictions", Json::from(self.prefix_evictions as usize)),
            ("bytes_peak", Json::from(self.prefix_bytes_peak)),
        ]);
        Json::from_pairs(vec![
            ("requests", requests),
            ("tokens_generated", Json::from(self.tokens_generated as usize)),
            ("throughput_tok_s", Json::Num(self.throughput_tok_s())),
            ("latency", latency),
            ("kv", kv),
            ("pager", pager),
            ("prefix_cache", prefix),
            ("wall_s", Json::Num(self.wall_s)),
        ])
    }

    /// The latency distributions as one aligned table (mean / p50 / p95 /
    /// n), queue-wait alongside TTFT so scheduler effects (how long a
    /// request sat in `pending`) and prefill effects (how long its first
    /// token took once admitted — where the prefix cache bites) are
    /// separable at a glance. Rendered by `cskv serve` under the one-line
    /// [`MetricsSnapshot::report`].
    pub fn summary_table(&self) -> crate::util::table::Table {
        let mut t = crate::util::table::Table::new(
            "latency summary",
            &["metric", "mean", "p50", "p95", "n"],
        );
        let rows: [(&str, &Samples); 8] = [
            ("queue-wait", &self.queue_wait_s),
            ("ttft", &self.ttft_s),
            ("ttft-clean", &self.ttft_clean_s),
            ("ttft-preempted", &self.ttft_preempted_s),
            ("tok-latency", &self.tok_latency_s),
            // Per-restore wall time blocked on pager reads — near zero
            // when the prefetcher lands blocks ahead of the resume.
            ("restore-stall", &self.restore_stall_s),
            // Time-in-system of reaped requests: how long abandoned work
            // sat on the plane before the deadline/cancel cut it loose.
            ("expired", &self.expired_s),
            ("cancelled", &self.cancelled_s),
        ];
        for (name, s) in rows {
            t.row(&[
                name.to_string(),
                format!("{:.4}s", s.mean()),
                format!("{:.4}s", s.percentile(50.0)),
                format!("{:.4}s", s.percentile(95.0)),
                format!("{}", s.len()),
            ]);
        }
        t
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mark_start(&self) {
        let mut g = self.inner.lock().unwrap();
        if g.started.is_none() {
            g.started = Some(Instant::now());
        }
    }

    pub fn record_completion(&self, c: Completion<'_>) {
        let mut g = self.inner.lock().unwrap();
        g.requests_completed += 1;
        g.tokens_generated += c.tokens as u64;
        g.queue_wait_s.push(c.queue_wait_s);
        g.ttft_s.push(c.ttft_s);
        if c.preemptions > 0 {
            g.ttft_preempted_s.push(c.ttft_s);
        } else {
            g.ttft_clean_s.push(c.ttft_s);
        }
        for &t in c.tok_latency_s {
            g.tok_latency_s.push(t);
        }
        g.completion_order.push(c.id);
        g.finished = Some(Instant::now());
    }

    /// A request was answered with an error `Response`.
    pub fn record_failure(&self) {
        let mut g = self.inner.lock().unwrap();
        g.requests_failed += 1;
        g.finished = Some(Instant::now());
    }

    /// A request was reaped past its deadline after `total_s` in the
    /// system (queued or in-flight).
    pub fn record_expired(&self, total_s: f64) {
        let mut g = self.inner.lock().unwrap();
        g.requests_expired += 1;
        g.expired_s.push(total_s);
        g.finished = Some(Instant::now());
    }

    /// A request was reaped by client cancellation after `total_s`.
    pub fn record_cancelled(&self, total_s: f64) {
        let mut g = self.inner.lock().unwrap();
        g.requests_cancelled += 1;
        g.cancelled_s.push(total_s);
        g.finished = Some(Instant::now());
    }

    /// A request was refused admission — overload gate said 429, the
    /// coordinator was draining, or the worker had already stopped.
    pub fn record_shed(&self) {
        self.inner.lock().unwrap().requests_shed += 1;
    }

    /// An in-flight or queued sequence was migrated into a drain bundle
    /// instead of running to completion.
    pub fn record_drained(&self) {
        self.inner.lock().unwrap().requests_drained += 1;
    }

    /// Refresh pager gauges: current per-tier resident bytes and the
    /// pager's cumulative health counters (absolutes, not deltas).
    pub fn record_pager(&self, warm_bytes: usize, disk_bytes: usize, stats: PagerStats) {
        let mut g = self.inner.lock().unwrap();
        g.warm_bytes_current = warm_bytes;
        g.disk_bytes_current = disk_bytes;
        g.cold_bytes_current = warm_bytes + disk_bytes;
        g.cold_bytes_peak = g.cold_bytes_peak.max(g.cold_bytes_current);
        g.pager = stats;
    }

    pub fn record_kv(&self, current_bytes: usize, active: usize) {
        let mut g = self.inner.lock().unwrap();
        g.kv_bytes_current = current_bytes;
        g.kv_bytes_peak = g.kv_bytes_peak.max(current_bytes);
        g.active_peak = g.active_peak.max(active);
    }

    /// A sequence was swapped out; `cold_bytes_now` is the pager's new
    /// resident size (all tiers).
    pub fn record_preemption(&self, cold_bytes_now: usize) {
        let mut g = self.inner.lock().unwrap();
        g.preemptions += 1;
        g.cold_bytes_current = cold_bytes_now;
        g.cold_bytes_peak = g.cold_bytes_peak.max(cold_bytes_now);
    }

    /// A swapped sequence was restored into the hot tier after the
    /// worker spent `stall_s` blocked on the pager read (≈0 when the
    /// prefetcher already landed the blocks).
    pub fn record_restore(&self, cold_bytes_now: usize, stall_s: f64) {
        let mut g = self.inner.lock().unwrap();
        g.restores += 1;
        g.cold_bytes_current = cold_bytes_now;
        g.restore_stall_s.push(stall_s);
    }

    /// An admission lookup matched `shared_bytes` of cached prefix.
    pub fn record_prefix_hit(&self, shared_bytes: usize) {
        let mut g = self.inner.lock().unwrap();
        g.prefix_hits += 1;
        g.prefix_shared_bytes += shared_bytes as u64;
    }

    /// An admission lookup found no cached prefix.
    pub fn record_prefix_miss(&self) {
        let mut g = self.inner.lock().unwrap();
        g.prefix_misses += 1;
    }

    /// Refresh the trie's occupancy gauges (`evictions` is the trie's
    /// cumulative count, not a delta).
    pub fn record_prefix_cache(&self, resident_bytes: usize, evictions: u64) {
        let mut g = self.inner.lock().unwrap();
        g.prefix_bytes_peak = g.prefix_bytes_peak.max(resident_bytes);
        g.prefix_evictions = evictions;
    }

    pub fn kv_bytes_current(&self) -> usize {
        self.inner.lock().unwrap().kv_bytes_current
    }

    pub fn cold_bytes_current(&self) -> usize {
        self.inner.lock().unwrap().cold_bytes_current
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let wall_s = match (g.started, g.finished) {
            (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
            (Some(a), None) => a.elapsed().as_secs_f64(),
            _ => 0.0,
        };
        MetricsSnapshot {
            requests_completed: g.requests_completed,
            requests_failed: g.requests_failed,
            requests_expired: g.requests_expired,
            requests_cancelled: g.requests_cancelled,
            expired_s: g.expired_s.clone(),
            cancelled_s: g.cancelled_s.clone(),
            requests_shed: g.requests_shed,
            requests_drained: g.requests_drained,
            tokens_generated: g.tokens_generated,
            queue_wait_s: g.queue_wait_s.clone(),
            ttft_s: g.ttft_s.clone(),
            ttft_clean_s: g.ttft_clean_s.clone(),
            ttft_preempted_s: g.ttft_preempted_s.clone(),
            tok_latency_s: g.tok_latency_s.clone(),
            kv_bytes_peak: g.kv_bytes_peak,
            kv_bytes_current: g.kv_bytes_current,
            active_peak: g.active_peak,
            preemptions: g.preemptions,
            restores: g.restores,
            cold_bytes_peak: g.cold_bytes_peak,
            cold_bytes_current: g.cold_bytes_current,
            warm_bytes_current: g.warm_bytes_current,
            disk_bytes_current: g.disk_bytes_current,
            pager: g.pager,
            restore_stall_s: g.restore_stall_s.clone(),
            completion_order: g.completion_order.clone(),
            prefix_hits: g.prefix_hits,
            prefix_misses: g.prefix_misses,
            prefix_shared_bytes: g.prefix_shared_bytes,
            prefix_evictions: g.prefix_evictions,
            prefix_bytes_peak: g.prefix_bytes_peak,
            wall_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn complete(m: &Metrics, id: u64, ttft: f64, preemptions: usize) {
        m.record_completion(Completion {
            id,
            queue_wait_s: 0.01,
            ttft_s: ttft,
            tokens: 3,
            tok_latency_s: &[0.01, 0.02],
            preemptions,
        });
    }

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.mark_start();
        m.record_kv(1000, 2);
        m.record_kv(500, 1);
        complete(&m, 7, 0.05, 0);
        complete(&m, 9, 0.06, 2);
        m.record_failure();
        let s = m.snapshot();
        assert_eq!(s.requests_completed, 2);
        assert_eq!(s.requests_failed, 1);
        assert!(s.report().contains("failed=1"));
        assert_eq!(s.tokens_generated, 6);
        assert_eq!(s.kv_bytes_peak, 1000);
        assert_eq!(s.active_peak, 2);
        assert_eq!(s.tok_latency_s.len(), 4);
        assert!(s.throughput_tok_s() >= 0.0);
        assert!(s.report().contains("requests=2"));
        // Per-outcome TTFT split + completion order.
        assert_eq!(s.ttft_clean_s.len(), 1);
        assert_eq!(s.ttft_preempted_s.len(), 1);
        assert_eq!(s.completion_order, vec![7, 9]);
    }

    #[test]
    fn prefix_counters_and_summary_table() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert!(s.prefix_hit_rate().is_none(), "cache off → no rate");
        assert!(!s.report().contains("prefix-cache"));

        m.record_prefix_hit(4096);
        m.record_prefix_miss();
        m.record_prefix_miss();
        m.record_prefix_hit(4096);
        m.record_prefix_cache(8192, 3);
        m.record_prefix_cache(2048, 5);
        complete(&m, 1, 0.05, 0);
        let s = m.snapshot();
        assert_eq!((s.prefix_hits, s.prefix_misses), (2, 2));
        assert_eq!(s.prefix_shared_bytes, 8192);
        assert_eq!(s.prefix_evictions, 5);
        assert_eq!(s.prefix_bytes_peak, 8192);
        assert!((s.prefix_hit_rate().unwrap() - 0.5).abs() < 1e-12);
        assert!(s.report().contains("prefix-cache 2/4 hits (50%)"));

        // Queue-wait sits alongside TTFT in the summary table.
        let rendered = s.summary_table().render();
        assert!(rendered.contains("queue-wait"));
        assert!(rendered.contains("ttft"));
        assert!(rendered.contains("p95"));
    }

    #[test]
    fn expired_and_cancelled_are_tracked_apart_from_failures() {
        let m = Metrics::new();
        m.record_expired(0.5);
        m.record_cancelled(0.25);
        m.record_cancelled(0.75);
        let s = m.snapshot();
        assert_eq!(s.requests_expired, 1);
        assert_eq!(s.requests_cancelled, 2);
        assert_eq!(s.requests_failed, 0, "reaped ≠ failed");
        assert_eq!(s.expired_s.len(), 1);
        assert_eq!(s.cancelled_s.len(), 2);
        assert!((s.cancelled_s.mean() - 0.5).abs() < 1e-12);
        assert!(s.report().contains("expired=1 cancelled=2"));
        let rendered = s.summary_table().render();
        assert!(rendered.contains("expired"));
        assert!(rendered.contains("cancelled"));
    }

    #[test]
    fn pager_health_surfaces_only_when_dirty() {
        let m = Metrics::new();
        m.record_pager(1024, 0, PagerStats::default());
        let s = m.snapshot();
        assert!(s.pager_health().is_none(), "clean pager stays quiet");
        assert!(!s.report().contains("pager-health"));
        assert_eq!(s.cold_bytes_current, 1024);
        assert_eq!(s.warm_bytes_current, 1024);

        m.record_pager(
            0,
            0,
            PagerStats {
                spill_retries: 3,
                read_retries: 1,
                corrupt_restores: 2,
                degraded: true,
                ..Default::default()
            },
        );
        let s = m.snapshot();
        let h = s.pager_health().unwrap();
        assert!(h.contains("spill-retries=3"), "{h}");
        assert!(h.contains("read-retries=1"), "{h}");
        assert!(h.contains("corrupt-restores=2"), "{h}");
        assert!(h.contains("DEGRADED"), "{h}");
        assert!(s.report().contains("pager-health"));
        assert_eq!(s.cold_bytes_current, 0);
        assert_eq!(s.cold_bytes_peak, 1024, "peak survives the drain");
    }

    #[test]
    fn pager_tier_traffic_flows_through_report_and_table() {
        let m = Metrics::new();
        assert!(
            m.snapshot().pager_tiers().is_none(),
            "a pager that never held a block stays off the report line"
        );
        m.record_pager(
            2048,
            4096,
            PagerStats {
                warm_bytes_peak: 8192,
                disk_bytes_peak: 4096,
                block_spills: 5,
                block_promotes: 3,
                spill_bytes: 4096,
                promote_bytes: 2048,
                prefetch_hits: 3,
                prefetch_misses: 1,
                ..Default::default()
            },
        );
        m.record_restore(0, 0.002);
        m.record_restore(0, 0.004);
        let s = m.snapshot();
        let t = s.pager_tiers().unwrap();
        assert!(t.contains("spill 5blk"), "{t}");
        assert!(t.contains("promote 3blk"), "{t}");
        assert!(t.contains("prefetch 3h/1m"), "{t}");
        assert!(t.contains("stall 0.0030s/restore"), "{t}");
        assert!(s.report().contains("pager warm"));
        assert_eq!(s.restore_stall_s.len(), 2);

        // restore-stall sits alongside the latency rows.
        let rendered = s.summary_table().render();
        assert!(rendered.contains("restore-stall"));

        let j = s.to_json();
        assert_eq!(
            j.at("pager.prefetch_hits").and_then(|v| v.as_usize()),
            Some(3)
        );
        assert_eq!(
            j.at("pager.warm_bytes_peak").and_then(|v| v.as_usize()),
            Some(8192)
        );
        assert_eq!(
            j.at("pager.block_spills").and_then(|v| v.as_usize()),
            Some(5)
        );
    }

    #[test]
    fn shed_and_drained_counters_flow_through_report_and_json() {
        let m = Metrics::new();
        m.record_shed();
        m.record_shed();
        m.record_drained();
        m.record_completion(Completion {
            id: 1,
            queue_wait_s: 0.1,
            ttft_s: 0.2,
            tokens: 3,
            tok_latency_s: &[0.01, 0.02],
            preemptions: 0,
        });
        let s = m.snapshot();
        assert_eq!(s.requests_shed, 2);
        assert_eq!(s.requests_drained, 1);
        assert!(s.report().contains("shed=2 drained=1"));

        let j = s.to_json();
        assert_eq!(j.at("requests.shed").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(
            j.at("requests.drained").and_then(|v| v.as_usize()),
            Some(1)
        );
        assert_eq!(
            j.at("requests.completed").and_then(|v| v.as_usize()),
            Some(1)
        );
        assert_eq!(
            j.at("tokens_generated").and_then(|v| v.as_usize()),
            Some(3)
        );
        assert_eq!(j.at("latency.ttft.n").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(
            j.at("kv.bytes_peak").and_then(|v| v.as_usize()),
            Some(0),
            "record_completion does not move the kv gauge"
        );
        assert_eq!(
            j.at("pager.degraded").and_then(|v| v.as_bool()),
            Some(false)
        );
        // The whole thing round-trips through the hand-rolled parser —
        // this is exactly what the stats endpoint serves.
        let text = j.to_string_compact();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(
            back.at("requests.shed").and_then(|v| v.as_usize()),
            Some(2)
        );
    }

    #[test]
    fn pager_counters_track_peak() {
        let m = Metrics::new();
        m.record_preemption(4096);
        m.record_preemption(10240);
        m.record_restore(6144, 0.001);
        m.record_restore(0, 0.0);
        let s = m.snapshot();
        assert_eq!(s.preemptions, 2);
        assert_eq!(s.restores, 2);
        assert_eq!(s.cold_bytes_peak, 10240);
        assert!(s.report().contains("preempt/restore 2/2"));
    }
}
