//! Per-sequence execution backends.
//!
//! A [`SequenceBackend`] owns everything one in-flight generation needs
//! (cache state, position, last token) and exposes prefill/decode steps to
//! the scheduler. Two families exist: [`RustSequenceBackend`] (the
//! reference engine + any cache policy) and the PJRT sessions in
//! [`super::pjrt_backend`] that execute the AOT artifacts.

use crate::kvcache::KvCachePolicy;
use crate::model::engine::{DecodeState, Engine};
use crate::tensor::ops;

/// One in-flight sequence's execution state.
pub trait SequenceBackend {
    fn name(&self) -> String;

    /// Run prefill over the prompt and return the first generated token.
    fn prefill(&mut self, prompt: &[usize]) -> anyhow::Result<usize>;

    /// Decode one more token (after `prefill`).
    fn decode_next(&mut self) -> anyhow::Result<usize>;

    /// Current KV footprint in bytes.
    fn kv_bytes(&self) -> usize;
}

/// Rust reference engine + pluggable cache policy. Holds a persistent
/// [`DecodeState`] across decode steps, so the policy updates its cache
/// views incrementally instead of rematerializing per token.
pub struct RustSequenceBackend {
    engine: Engine,
    policy: Box<dyn KvCachePolicy>,
    state: DecodeState,
    pos: usize,
    last_token: usize,
    /// Tokens of view/cache capacity reserved so far. The backend does
    /// not know the generation length up front, so capacity is grown in
    /// [`RESERVE_CHUNK`] batches ahead of `pos` — decode steps between
    /// top-ups stay allocation-free.
    reserved_tokens: usize,
}

/// Capacity top-up granularity for open-ended generations.
const RESERVE_CHUNK: usize = 256;

impl RustSequenceBackend {
    pub fn new(engine: Engine, policy: Box<dyn KvCachePolicy>) -> Self {
        let state = DecodeState::new(&engine.w.cfg);
        RustSequenceBackend {
            engine,
            policy,
            state,
            pos: 0,
            last_token: 0,
            reserved_tokens: 0,
        }
    }

    /// Ensure at least one more token of headroom, topping up in chunks.
    fn reserve_ahead(&mut self) {
        if self.pos + 1 > self.reserved_tokens {
            self.reserved_tokens = self.pos + RESERVE_CHUNK;
            self.state.reserve(self.reserved_tokens);
            self.policy.reserve(RESERVE_CHUNK);
        }
    }
}

impl SequenceBackend for RustSequenceBackend {
    fn name(&self) -> String {
        format!("rust-engine/{}", self.policy.name())
    }

    fn prefill(&mut self, prompt: &[usize]) -> anyhow::Result<usize> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let rec = self.engine.prefill(prompt, Some(self.policy.as_mut()));
        self.pos = prompt.len();
        self.reserve_ahead();
        self.last_token = ops::argmax(rec.logits.row(prompt.len() - 1));
        Ok(self.last_token)
    }

    fn decode_next(&mut self) -> anyhow::Result<usize> {
        self.reserve_ahead();
        let logits = self.engine.decode_step_with(
            self.policy.as_mut(),
            self.last_token,
            self.pos,
            &mut self.state,
        );
        self.pos += 1;
        self.last_token = ops::argmax(logits);
        Ok(self.last_token)
    }

    fn kv_bytes(&self) -> usize {
        self.policy.kv_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::FullCache;
    use crate::model::{ModelConfig, ModelWeights};
    use std::sync::Arc;

    #[test]
    fn backend_matches_engine_generate() {
        let cfg = ModelConfig::test_small();
        let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 3)));
        let prompt = [1usize, 9, 17, 33];
        let mut direct_cache = FullCache::new(cfg.n_layers, cfg.d_model);
        let (want, _) = engine.generate(&prompt, 5, &mut direct_cache);

        let mut be = RustSequenceBackend::new(
            engine.clone(),
            Box::new(FullCache::new(cfg.n_layers, cfg.d_model)),
        );
        let mut got = vec![be.prefill(&prompt).unwrap()];
        for _ in 1..5 {
            got.push(be.decode_next().unwrap());
        }
        assert_eq!(got, want);
        assert!(be.kv_bytes() > 0);
        assert!(be.name().contains("full"));
    }
}
