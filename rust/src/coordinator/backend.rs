//! Per-sequence execution backends + fused multi-sequence entry points.
//!
//! A [`SequenceBackend`] owns everything one in-flight generation needs
//! (cache state, position, last token) and exposes prefill/decode steps to
//! the scheduler. Two families exist: [`RustSequenceBackend`] (the
//! reference engine + any cache policy) and the PJRT sessions in
//! [`super::pjrt_backend`] that execute the AOT artifacts.
//!
//! The scheduler drives whole *rounds* through [`prefill_batch`] /
//! [`decode_batch`]: when every backend in the round is a
//! [`RustSequenceBackend`] over the same engine weights, the round runs
//! through the engine's fused paths ([`Engine::prefill_batch`] /
//! [`Engine::decode_step_batch`]) so each layer's weights are streamed
//! once across all sequences; any other mix (PJRT sessions, heterogeneous
//! engines, single-sequence rounds) falls back to per-sequence calls.
//! Either way the per-sequence token streams are bit-identical — the
//! fused engine paths reuse the single-sequence kernels' reduction
//! orders (`rust/tests/batched_serving.rs`).

use std::sync::Arc;

use crate::kvcache::snapshot::{tags, SnapReader, SnapWriter};
use crate::kvcache::{KvCachePolicy, KvSnapshot};
use crate::model::engine::{
    BatchDecodeEntry, BatchDecodeScratch, BatchPrefillScratch, DecodeState, Engine, PrefixSeed,
    SeededPrefill,
};
use crate::tensor::ops;

/// One in-flight sequence's execution state.
pub trait SequenceBackend {
    fn name(&self) -> String;

    /// Run prefill over the prompt and return the first generated token.
    fn prefill(&mut self, prompt: &[usize]) -> anyhow::Result<usize>;

    /// Decode one more token (after `prefill`).
    fn decode_next(&mut self) -> anyhow::Result<usize>;

    /// Current KV footprint in bytes.
    fn kv_bytes(&self) -> usize;

    /// Estimated KV footprint in bytes once this backend holds `tokens`
    /// total tokens — the scheduler's admission pre-charge, evaluated
    /// *before* prefill commits the memory.
    fn kv_bytes_projected(&self, tokens: usize) -> usize;

    /// Serialize this sequence's complete execution state (cache in its
    /// policy's own — usually compressed — representation, plus decode
    /// bookkeeping) for the preemptive scheduler's cold tier.
    fn snapshot(&self) -> anyhow::Result<KvSnapshot>;

    /// Replace this (freshly constructed) backend's state with `snap`'s.
    /// Decoding then continues **bit-identically** to the unpreempted
    /// run: derived state like the engine's `DecodeView`s is rebuilt
    /// lazily through the normal sync paths.
    fn restore(&mut self, snap: &KvSnapshot) -> anyhow::Result<()>;

    /// Accumulated attention mass per absolute token position, where the
    /// underlying policy tracks it (H2O). The pager ranks this
    /// sequence's history blocks with it at preemption time; `None`
    /// falls back to age/position scoring. Eviction-ordering hint only —
    /// never affects restored state.
    fn attention_profile(&self) -> Option<Vec<f32>> {
        None
    }

    /// Downcast hook for fused rounds: backends able to share the Rust
    /// engine's batched data plane return themselves. Default: `None`
    /// (the scheduler falls back to per-sequence calls).
    fn as_rust_backend(&mut self) -> Option<&mut RustSequenceBackend> {
        None
    }
}

/// Rust reference engine + pluggable cache policy. Holds a persistent
/// [`DecodeState`] across decode steps, so the policy updates its cache
/// views incrementally instead of rematerializing per token.
pub struct RustSequenceBackend {
    engine: Engine,
    policy: Box<dyn KvCachePolicy>,
    state: DecodeState,
    pos: usize,
    last_token: usize,
    /// Tokens of view/cache capacity reserved so far. The backend does
    /// not know the generation length up front, so capacity is grown in
    /// [`RESERVE_CHUNK`] batches ahead of `pos` — decode steps between
    /// top-ups stay allocation-free.
    reserved_tokens: usize,
}

/// Capacity top-up granularity for open-ended generations.
const RESERVE_CHUNK: usize = 256;

impl RustSequenceBackend {
    pub fn new(engine: Engine, policy: Box<dyn KvCachePolicy>) -> Self {
        let state = DecodeState::new(&engine.w.cfg);
        RustSequenceBackend {
            engine,
            policy,
            state,
            pos: 0,
            last_token: 0,
            reserved_tokens: 0,
        }
    }

    /// Ensure at least one more token of headroom, topping up in chunks.
    fn reserve_ahead(&mut self) {
        if self.pos + 1 > self.reserved_tokens {
            self.reserved_tokens = self.pos + RESERVE_CHUNK;
            self.state.reserve(self.reserved_tokens);
            self.policy.reserve(RESERVE_CHUNK);
        }
    }
}

impl SequenceBackend for RustSequenceBackend {
    fn name(&self) -> String {
        format!("rust-engine/{}", self.policy.name())
    }

    fn prefill(&mut self, prompt: &[usize]) -> anyhow::Result<usize> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let rec = self.engine.prefill(prompt, Some(self.policy.as_mut()));
        self.pos = prompt.len();
        self.reserve_ahead();
        self.last_token = ops::argmax(rec.logits.row(prompt.len() - 1));
        Ok(self.last_token)
    }

    fn decode_next(&mut self) -> anyhow::Result<usize> {
        self.reserve_ahead();
        let logits = self.engine.decode_step_with(
            self.policy.as_mut(),
            self.last_token,
            self.pos,
            &mut self.state,
        );
        self.pos += 1;
        self.last_token = ops::argmax(logits);
        Ok(self.last_token)
    }

    fn kv_bytes(&self) -> usize {
        self.policy.kv_bytes()
    }

    fn kv_bytes_projected(&self, tokens: usize) -> usize {
        self.policy.kv_bytes_projected(tokens)
    }

    fn snapshot(&self) -> anyhow::Result<KvSnapshot> {
        // Decode bookkeeping + the policy's own snapshot, nested verbatim.
        let mut w = SnapWriter::new();
        w.write_usize(self.pos);
        w.write_usize(self.last_token);
        w.nested(&self.policy.snapshot());
        Ok(KvSnapshot::new(tags::RUST_BACKEND, w.finish()))
    }

    fn restore(&mut self, snap: &KvSnapshot) -> anyhow::Result<()> {
        snap.expect_tag(tags::RUST_BACKEND, "rust backend")?;
        let mut r = SnapReader::new(snap.payload());
        let pos = r.read_usize()?;
        let last_token = r.read_usize()?;
        let nested = r.nested()?;
        r.expect_end()?;
        self.policy.restore(&nested)?;
        // Fresh views: the next decode step rebuilds them from the
        // restored policy through `sync_view`'s full-rebuild path —
        // bit-identical to the views an unpreempted run would hold.
        self.state = DecodeState::new(&self.engine.w.cfg);
        self.pos = pos;
        self.last_token = last_token;
        self.reserved_tokens = 0;
        Ok(())
    }

    fn attention_profile(&self) -> Option<Vec<f32>> {
        self.policy.attention_profile()
    }

    fn as_rust_backend(&mut self) -> Option<&mut RustSequenceBackend> {
        Some(self)
    }
}

/// A delegating backend that sleeps before every decode step — a test
/// hook (`cskv serve --decode-throttle-ms`, the drain/migrate and HTTP
/// chaos tests) that stretches generations into a window long enough to
/// deterministically catch a sequence *mid-decode* with a drain or
/// disconnect. Token streams are unchanged. `as_rust_backend` stays
/// `None`, so fused rounds fall back to per-sequence calls and the delay
/// is actually applied each step.
pub struct ThrottledBackend {
    inner: Box<dyn SequenceBackend>,
    delay: std::time::Duration,
}

impl ThrottledBackend {
    pub fn new(inner: Box<dyn SequenceBackend>, delay: std::time::Duration) -> Self {
        ThrottledBackend { inner, delay }
    }
}

impl SequenceBackend for ThrottledBackend {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn prefill(&mut self, prompt: &[usize]) -> anyhow::Result<usize> {
        self.inner.prefill(prompt)
    }

    fn decode_next(&mut self) -> anyhow::Result<usize> {
        std::thread::sleep(self.delay);
        self.inner.decode_next()
    }

    fn kv_bytes(&self) -> usize {
        self.inner.kv_bytes()
    }

    fn kv_bytes_projected(&self, tokens: usize) -> usize {
        self.inner.kv_bytes_projected(tokens)
    }

    fn snapshot(&self) -> anyhow::Result<KvSnapshot> {
        self.inner.snapshot()
    }

    fn restore(&mut self, snap: &KvSnapshot) -> anyhow::Result<()> {
        self.inner.restore(snap)
    }

    fn attention_profile(&self) -> Option<Vec<f32>> {
        self.inner.attention_profile()
    }
}

/// Reusable stacked work buffers for fused rounds, owned by the
/// scheduler and threaded through [`prefill_batch`] / [`decode_batch`].
#[derive(Default)]
pub struct BatchScratch {
    prefill: BatchPrefillScratch,
    decode: BatchDecodeScratch,
}

/// True when every backend is a [`RustSequenceBackend`] over the same
/// engine weights — the precondition for the fused data plane.
fn same_rust_engine(backends: &mut [&mut dyn SequenceBackend]) -> bool {
    let mut w0: Option<Arc<crate::model::ModelWeights>> = None;
    for b in backends.iter_mut() {
        match b.as_rust_backend() {
            Some(rb) => match &w0 {
                Some(prev) => {
                    if !Arc::ptr_eq(prev, &rb.engine.w) {
                        return false;
                    }
                }
                None => w0 = Some(Arc::clone(&rb.engine.w)),
            },
            None => return false,
        }
    }
    !backends.is_empty()
}

/// Prefill one admission round. With ≥ 2 fusable backends and all-valid
/// prompts, runs the fused [`Engine::prefill_batch`] (each layer's
/// weights streamed once across the round); otherwise falls back to
/// per-sequence [`SequenceBackend::prefill`]. Returns each sequence's
/// first generated token, positionally.
pub fn prefill_batch(
    backends: &mut [&mut dyn SequenceBackend],
    prompts: &[&[usize]],
    scratch: &mut BatchScratch,
) -> Vec<anyhow::Result<usize>> {
    assert_eq!(backends.len(), prompts.len());
    let fusable = backends.len() > 1
        && prompts.iter().all(|p| !p.is_empty())
        && same_rust_engine(backends);
    if !fusable {
        return backends
            .iter_mut()
            .zip(prompts)
            .map(|(b, p)| b.prefill(p))
            .collect();
    }
    let mut rbs: Vec<&mut RustSequenceBackend> = backends
        .iter_mut()
        .map(|b| b.as_rust_backend().expect("checked by same_rust_engine"))
        .collect();
    let engine = rbs[0].engine.clone();
    let records = {
        let mut policies: Vec<Option<&mut dyn KvCachePolicy>> = rbs
            .iter_mut()
            .map(|rb| Some(rb.policy.as_mut()))
            .collect();
        engine.prefill_batch(prompts, &mut policies, &mut scratch.prefill)
    };
    rbs.iter_mut()
        .zip(prompts)
        .zip(&records)
        .map(|((rb, prompt), rec)| {
            rb.pos = prompt.len();
            rb.reserve_ahead();
            rb.last_token = ops::argmax(rec.logits.row(prompt.len() - 1));
            Ok(rb.last_token)
        })
        .collect()
}

/// Prefill one admission round with shared-prefix seeding: the variant
/// of [`prefill_batch`] the worker uses when its
/// [`crate::kvcache::PrefixCache`] is enabled. Each sequence may carry a
/// [`PrefixSeed`] (the trie's longest match for its prompt); seeded
/// sequences compute only their unshared suffix yet end bitwise
/// identical to a cold prefill, and with `capture` on every sequence
/// returns its [`SeededPrefill`] so the worker can publish the prompt's
/// prefix back into the trie.
///
/// Requires every backend to be a [`RustSequenceBackend`] over the same
/// engine weights — unlike [`prefill_batch`], width-1 rounds still take
/// the engine path (seeding/capture matter even without GEMM fusion).
/// Mixed/PJRT rounds fall back to per-sequence [`SequenceBackend::prefill`]
/// with seeds ignored and nothing captured (`None` per sequence).
pub fn prefill_batch_seeded(
    backends: &mut [&mut dyn SequenceBackend],
    prompts: &[&[usize]],
    seeds: &[Option<&PrefixSeed>],
    capture: bool,
    scratch: &mut BatchScratch,
) -> Vec<anyhow::Result<(usize, Option<SeededPrefill>)>> {
    assert_eq!(backends.len(), prompts.len());
    assert_eq!(backends.len(), seeds.len());
    let reusable = prompts.iter().all(|p| !p.is_empty()) && same_rust_engine(backends);
    if !reusable {
        // Also covers empty prompts: per-sequence prefill rejects those
        // with a clean error instead of panicking mid-round.
        return backends
            .iter_mut()
            .zip(prompts)
            .map(|(b, p)| b.prefill(p).map(|tok| (tok, None)))
            .collect();
    }
    let mut rbs: Vec<&mut RustSequenceBackend> = backends
        .iter_mut()
        .map(|b| b.as_rust_backend().expect("checked by same_rust_engine"))
        .collect();
    let engine = rbs[0].engine.clone();
    let results = {
        let mut policies: Vec<Option<&mut dyn KvCachePolicy>> = rbs
            .iter_mut()
            .map(|rb| Some(rb.policy.as_mut()))
            .collect();
        engine.prefill_batch_seeded(prompts, seeds, &mut policies, capture, &mut scratch.prefill)
    };
    rbs.iter_mut()
        .zip(prompts)
        .zip(results)
        .map(|((rb, prompt), sp)| {
            rb.pos = prompt.len();
            rb.reserve_ahead();
            // `logits` covers only the computed suffix rows.
            rb.last_token = ops::argmax(sp.record.logits.row(prompt.len() - sp.start - 1));
            Ok((rb.last_token, Some(sp)))
        })
        .collect()
}

/// Decode one token for every backend in the round. With ≥ 2 fusable
/// backends, runs the GEMM-batched [`Engine::decode_step_batch`] (QKV /
/// output / MLP / LM-head weights streamed once per round); otherwise
/// falls back to per-sequence [`SequenceBackend::decode_next`]. Returns
/// each sequence's next token, positionally.
pub fn decode_batch(
    backends: &mut [&mut dyn SequenceBackend],
    scratch: &mut BatchScratch,
) -> Vec<anyhow::Result<usize>> {
    if backends.len() <= 1 || !same_rust_engine(backends) {
        return backends.iter_mut().map(|b| b.decode_next()).collect();
    }
    let mut rbs: Vec<&mut RustSequenceBackend> = backends
        .iter_mut()
        .map(|b| b.as_rust_backend().expect("checked by same_rust_engine"))
        .collect();
    for rb in rbs.iter_mut() {
        rb.reserve_ahead();
    }
    let engine = rbs[0].engine.clone();
    {
        let mut entries: Vec<BatchDecodeEntry> = rbs
            .iter_mut()
            .map(|rb| {
                let RustSequenceBackend {
                    policy,
                    state,
                    pos,
                    last_token,
                    ..
                } = &mut **rb;
                BatchDecodeEntry {
                    policy: policy.as_mut(),
                    token: *last_token,
                    abs_pos: *pos,
                    state,
                }
            })
            .collect();
        engine.decode_step_batch(&mut entries, &mut scratch.decode);
    }
    rbs.iter_mut()
        .enumerate()
        .map(|(bi, rb)| {
            rb.pos += 1;
            rb.last_token = ops::argmax(scratch.decode.logits_row(bi));
            Ok(rb.last_token)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::FullCache;
    use crate::model::{ModelConfig, ModelWeights};
    use std::sync::Arc;

    #[test]
    fn backend_matches_engine_generate() {
        let cfg = ModelConfig::test_small();
        let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 3)));
        let prompt = [1usize, 9, 17, 33];
        let mut direct_cache = FullCache::new(cfg.n_layers, cfg.d_model);
        let (want, _) = engine.generate(&prompt, 5, &mut direct_cache);

        let mut be = RustSequenceBackend::new(
            engine.clone(),
            Box::new(FullCache::new(cfg.n_layers, cfg.d_model)),
        );
        let mut got = vec![be.prefill(&prompt).unwrap()];
        for _ in 1..5 {
            got.push(be.decode_next().unwrap());
        }
        assert_eq!(got, want);
        assert!(be.kv_bytes() > 0);
        assert!(be.name().contains("full"));
        // Projection is exact for the full cache: 4 prompt + 4 decoded.
        assert_eq!(be.kv_bytes_projected(8), be.kv_bytes());
    }

    /// Preemption round-trip at the backend level: snapshot mid-decode,
    /// restore into a fresh backend, and the continued token stream is
    /// bit-identical to the uninterrupted one.
    #[test]
    fn snapshot_restore_resumes_bit_identically() {
        let cfg = ModelConfig::test_small();
        let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 5)));
        let prompt: Vec<usize> = (0..20).map(|i| (i * 11 + 3) % 256).collect();
        let mk = || {
            RustSequenceBackend::new(
                engine.clone(),
                Box::new(FullCache::new(cfg.n_layers, cfg.d_model)),
            )
        };
        // Uninterrupted oracle.
        let mut oracle = mk();
        let mut want = vec![oracle.prefill(&prompt).unwrap()];
        for _ in 1..9 {
            want.push(oracle.decode_next().unwrap());
        }
        // Preempted run: snapshot after 4 tokens, restore into a fresh
        // backend, finish there.
        let mut first = mk();
        let mut got = vec![first.prefill(&prompt).unwrap()];
        for _ in 1..4 {
            got.push(first.decode_next().unwrap());
        }
        let snap = first.snapshot().unwrap();
        drop(first); // the hot state is gone — only the snapshot survives
        let mut resumed = mk();
        resumed.restore(&snap).unwrap();
        for _ in 4..9 {
            got.push(resumed.decode_next().unwrap());
        }
        assert_eq!(got, want, "restored stream must match the unpreempted run");
        assert_eq!(resumed.kv_bytes(), oracle.kv_bytes());
        // Wrong snapshot kind is rejected.
        let bogus = KvSnapshot::new(tags::PJRT_FULL, vec![]);
        assert!(mk().restore(&bogus).is_err());
    }

    /// Fused rounds through the backend layer must reproduce the
    /// per-sequence token streams exactly, and fall back cleanly for
    /// single-sequence rounds.
    #[test]
    fn batch_entry_points_match_sequential_backends() {
        let cfg = ModelConfig::test_small();
        let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 11)));
        let prompts: Vec<Vec<usize>> = vec![
            vec![1, 9, 17, 33],
            (0..20).map(|i| (i * 7 + 2) % 256).collect(),
            vec![5, 6],
        ];
        let mk = |engine: &Engine| -> Vec<Box<dyn SequenceBackend>> {
            prompts
                .iter()
                .map(|_| {
                    Box::new(RustSequenceBackend::new(
                        engine.clone(),
                        Box::new(FullCache::new(cfg.n_layers, cfg.d_model)),
                    )) as Box<dyn SequenceBackend>
                })
                .collect()
        };

        // Sequential oracle.
        let mut seq = mk(&engine);
        let mut want: Vec<Vec<usize>> = Vec::new();
        for (b, p) in seq.iter_mut().zip(&prompts) {
            let mut toks = vec![b.prefill(p).unwrap()];
            for _ in 1..6 {
                toks.push(b.decode_next().unwrap());
            }
            want.push(toks);
        }

        // Fused rounds.
        let mut fused = mk(&engine);
        let mut scratch = BatchScratch::default();
        let prompt_refs: Vec<&[usize]> = prompts.iter().map(|p| p.as_slice()).collect();
        let mut got: Vec<Vec<usize>> = {
            let mut bs: Vec<&mut dyn SequenceBackend> =
                fused.iter_mut().map(|b| b.as_mut()).collect();
            let firsts = prefill_batch(&mut bs, &prompt_refs, &mut scratch);
            firsts.into_iter().map(|r| vec![r.unwrap()]).collect()
        };
        for _ in 1..6 {
            let mut bs: Vec<&mut dyn SequenceBackend> =
                fused.iter_mut().map(|b| b.as_mut()).collect();
            let toks = decode_batch(&mut bs, &mut scratch);
            drop(bs);
            for (g, t) in got.iter_mut().zip(toks) {
                g.push(t.unwrap());
            }
        }
        assert_eq!(got, want, "fused rounds must match sequential streams");

        // Single-sequence round: the fallback path still answers.
        let mut one = mk(&engine);
        let mut bs: Vec<&mut dyn SequenceBackend> = vec![one[0].as_mut()];
        let first = prefill_batch(&mut bs, &prompt_refs[..1], &mut scratch);
        assert_eq!(first[0].as_ref().unwrap(), &want[0][0]);

        // An empty prompt in the round errors without poisoning others.
        let mut mixed = mk(&engine);
        let empty: &[usize] = &[];
        let ps = vec![prompt_refs[0], empty];
        let mut bs: Vec<&mut dyn SequenceBackend> =
            mixed.iter_mut().take(2).map(|b| b.as_mut()).collect();
        let res = prefill_batch(&mut bs, &ps, &mut scratch);
        assert!(res[0].is_ok());
        assert!(res[1].is_err());
    }
}
