//! Pluggable admission/preemption policies — the coordinator's control
//! plane.
//!
//! The worker loop owns the mechanism (queues, budget accounting, the
//! cold tier, fused prefill/decode rounds); a [`Scheduler`] owns the
//! *decisions*: which queued request to admit next, and which active
//! sequence to swap out when the KV budget blocks a candidate. Three
//! policies ship:
//!
//! * [`Fifo`] — strict arrival order, the pre-scheduler behavior and the
//!   A/B baseline. A long prompt at the queue head blocks every request
//!   behind it even when budget headroom exists (head-of-line blocking).
//! * [`SizeAware`] — shortest-remaining-work-first within the KV budget:
//!   each admission picks the queued request with the least total work
//!   (prompt + generation) whose projected footprint fits the remaining
//!   headroom, so short requests flow past a blocked long one.
//! * [`Preemptive`] — [`SizeAware`] ordering plus swap-out: when the
//!   preferred candidate cannot fit, the active sequence with the most
//!   remaining work (the lowest priority) is snapshotted to the cold
//!   tier — in its policy's *compressed* representation — and resumed
//!   bit-identically once headroom returns. A victim is only taken when
//!   its remaining work strictly exceeds the candidate's total work, so
//!   every preemption funds a strictly shorter request and the system
//!   always makes progress.
//!
//! All three see the same request descriptors ([`QueuedSeq`] /
//! [`ActiveSeq`]); costs are the admission pre-charge
//! (`kv_bytes_projected` at completion length), identical to the budget
//! the worker enforces. Schedulers never see lifecycle noise: the worker
//! reaps cancelled and deadline-expired requests at the round boundary
//! *before* building these descriptors, so every candidate offered here
//! is live and worth admitting. `bench_perf_scheduling` records the
//! fleet-level A/B; `rust/tests/batched_serving.rs` holds the fairness
//! and round-trip oracles.

/// What the scheduler sees of one queued request.
#[derive(Clone, Debug)]
pub struct QueuedSeq {
    pub id: u64,
    /// Projected completion KV footprint (prompt + n_new tokens), bytes.
    /// When the coordinator's prefix cache holds a matching prompt
    /// prefix, the worker subtracts the shared rows before building
    /// this value, so schedulers price only the unshared suffix.
    pub cost_bytes: usize,
    /// Total work ahead: prompt tokens to prefill + tokens to generate.
    pub work_tokens: usize,
}

/// What the scheduler sees of one active (hot) sequence.
#[derive(Clone, Debug)]
pub struct ActiveSeq {
    pub id: u64,
    /// Projected completion KV footprint, bytes (what preempting frees
    /// from the admission ledger).
    pub cost_bytes: usize,
    /// Decode steps left before this sequence retires.
    pub remaining_tokens: usize,
    /// Times this sequence has already been swapped out.
    pub preemptions: usize,
}

/// `cost` fits in the remaining budget (`None` = unlimited).
fn fits(headroom: Option<usize>, cost: usize) -> bool {
    headroom.is_none_or(|h| cost <= h)
}

/// An admission/preemption policy. Implementations are consulted once
/// per admission step; they never touch backends or the cold tier —
/// the worker executes whatever they decide.
pub trait Scheduler: Send {
    /// Display name (metrics, benches, CLI echo).
    fn name(&self) -> &'static str;

    /// Choose the queued request to admit next, given the KV headroom
    /// left after charging every active and already-admitted sequence at
    /// its projected completion footprint. Returning `None` ends this
    /// round's admission (the worker may still consult
    /// [`Scheduler::pick_victim`] or fall back to
    /// [`Scheduler::preferred`] when nothing at all is running).
    fn pick_admission(&mut self, queued: &[QueuedSeq], headroom: Option<usize>) -> Option<usize>;

    /// The request this policy would admit if capacity were no object —
    /// the worker's deadlock escape hatch admits it unconditionally when
    /// nothing is running, and preemption is evaluated on its behalf.
    fn preferred(&self, queued: &[QueuedSeq]) -> Option<usize> {
        if queued.is_empty() {
            None
        } else {
            Some(0)
        }
    }

    /// Under budget pressure (`blocked` = the preferred candidate that
    /// does not fit), choose an active sequence to swap out to the cold
    /// tier. Default: never preempt.
    fn pick_victim(&mut self, _blocked: &QueuedSeq, _active: &[ActiveSeq]) -> Option<usize> {
        None
    }
}

/// Strict arrival order — today's behavior, kept as the A/B baseline.
#[derive(Default)]
pub struct Fifo;

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick_admission(&mut self, queued: &[QueuedSeq], headroom: Option<usize>) -> Option<usize> {
        // Head of the queue or nothing: FIFO deliberately keeps the
        // head-of-line block so the A/B against SizeAware is honest.
        match queued.first() {
            Some(head) if fits(headroom, head.cost_bytes) => Some(0),
            _ => None,
        }
    }
}

/// Index of the queued request with the least total work (ties: lower
/// id, i.e. earlier arrival).
fn smallest_work(queued: &[QueuedSeq]) -> Option<usize> {
    queued
        .iter()
        .enumerate()
        .min_by_key(|(_, q)| (q.work_tokens, q.id))
        .map(|(i, _)| i)
}

/// Shortest-remaining-work-first within the KV budget: fixes FIFO's
/// head-of-line blocking. Long requests are not starved forever — once
/// the queue holds only long requests, the shortest of them is admitted;
/// arrival order only yields to strictly smaller work.
#[derive(Default)]
pub struct SizeAware;

impl Scheduler for SizeAware {
    fn name(&self) -> &'static str {
        "size-aware"
    }

    fn pick_admission(&mut self, queued: &[QueuedSeq], headroom: Option<usize>) -> Option<usize> {
        queued
            .iter()
            .enumerate()
            .filter(|(_, q)| fits(headroom, q.cost_bytes))
            .min_by_key(|(_, q)| (q.work_tokens, q.id))
            .map(|(i, _)| i)
    }

    fn preferred(&self, queued: &[QueuedSeq]) -> Option<usize> {
        smallest_work(queued)
    }
}

/// [`SizeAware`] ordering plus cold-tier swap-out under budget pressure.
#[derive(Default)]
pub struct Preemptive;

impl Scheduler for Preemptive {
    fn name(&self) -> &'static str {
        "preemptive"
    }

    fn pick_admission(&mut self, queued: &[QueuedSeq], headroom: Option<usize>) -> Option<usize> {
        SizeAware.pick_admission(queued, headroom)
    }

    fn preferred(&self, queued: &[QueuedSeq]) -> Option<usize> {
        smallest_work(queued)
    }

    fn pick_victim(&mut self, blocked: &QueuedSeq, active: &[ActiveSeq]) -> Option<usize> {
        // Lowest priority = most remaining work. Only preempt when the
        // victim's remaining work strictly exceeds the candidate's total
        // work: each swap funds a strictly shorter request, so progress
        // is monotone and resume cannot ping-pong with admission.
        active
            .iter()
            .enumerate()
            .filter(|(_, a)| a.remaining_tokens > blocked.work_tokens)
            .max_by_key(|(_, a)| (a.remaining_tokens, a.id))
            .map(|(i, _)| i)
    }
}

/// Config-level scheduler selector (`cskv serve --scheduler …`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedulerKind {
    #[default]
    Fifo,
    SizeAware,
    Preemptive,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "fifo" => Ok(SchedulerKind::Fifo),
            "size-aware" => Ok(SchedulerKind::SizeAware),
            "preemptive" => Ok(SchedulerKind::Preemptive),
            other => anyhow::bail!("unknown scheduler {other:?} (fifo|size-aware|preemptive)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Fifo => "fifo",
            SchedulerKind::SizeAware => "size-aware",
            SchedulerKind::Preemptive => "preemptive",
        }
    }

    pub fn build(&self) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Fifo => Box::new(Fifo),
            SchedulerKind::SizeAware => Box::new(SizeAware),
            SchedulerKind::Preemptive => Box::new(Preemptive),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: u64, cost: usize, work: usize) -> QueuedSeq {
        QueuedSeq { id, cost_bytes: cost, work_tokens: work }
    }

    fn a(id: u64, cost: usize, remaining: usize) -> ActiveSeq {
        ActiveSeq { id, cost_bytes: cost, remaining_tokens: remaining, preemptions: 0 }
    }

    #[test]
    fn fifo_blocks_at_the_head() {
        let mut s = Fifo;
        let queued = vec![q(1, 100, 50), q(2, 10, 5)];
        // Head fits ⇒ head.
        assert_eq!(s.pick_admission(&queued, Some(200)), Some(0));
        // Head blocked ⇒ nothing, even though #2 fits (the documented
        // head-of-line behavior the A/B measures).
        assert_eq!(s.pick_admission(&queued, Some(50)), None);
        assert_eq!(s.preferred(&queued), Some(0));
        assert_eq!(s.pick_victim(&queued[0], &[a(9, 10, 100)]), None);
    }

    #[test]
    fn size_aware_picks_smallest_fitting_work() {
        let mut s = SizeAware;
        let queued = vec![q(1, 100, 50), q(2, 10, 5), q(3, 10, 5)];
        // Smallest work that fits; ties break to the earlier arrival.
        assert_eq!(s.pick_admission(&queued, Some(50)), Some(1));
        // Unlimited budget still orders by work.
        assert_eq!(s.pick_admission(&queued, None), Some(1));
        // Nothing fits.
        assert_eq!(s.pick_admission(&queued, Some(5)), None);
        assert_eq!(s.preferred(&queued), Some(1));
    }

    #[test]
    fn preemptive_victim_is_longest_remaining_and_strictly_longer() {
        let mut s = Preemptive;
        let blocked = q(7, 60, 20);
        // Longest remaining work wins; only strictly-longer qualify.
        let active = vec![a(1, 50, 19), a(2, 50, 400), a(3, 50, 90)];
        assert_eq!(s.pick_victim(&blocked, &active), Some(1));
        // No sequence with more remaining work than the candidate needs
        // ⇒ no preemption (prevents thrash on equal-size workloads).
        let short = vec![a(1, 50, 20), a(2, 50, 5)];
        assert_eq!(s.pick_victim(&blocked, &short), None);
    }

    #[test]
    fn kind_parses_and_builds() {
        for (txt, want) in [
            ("fifo", SchedulerKind::Fifo),
            ("size-aware", SchedulerKind::SizeAware),
            ("preemptive", SchedulerKind::Preemptive),
        ] {
            let k = SchedulerKind::parse(txt).unwrap();
            assert_eq!(k, want);
            assert_eq!(k.name(), txt);
            assert_eq!(k.build().name(), txt);
        }
        assert!(SchedulerKind::parse("lifo").is_err());
        assert_eq!(SchedulerKind::default(), SchedulerKind::Fifo);
    }
}
