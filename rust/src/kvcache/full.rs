//! The uncompressed baseline cache (the paper's "0%" rows).

use crate::tensor::Mat;

use super::snapshot::{self, tags, KvSnapshot, SnapReader, SnapWriter};
use super::{CacheView, DecodeView, GrowMat, KvCachePolicy};

/// Stores every token's exact K/V for every layer.
pub struct FullCache {
    layers: Vec<LayerState>,
}

struct LayerState {
    k: GrowMat,
    v: GrowMat,
}

impl FullCache {
    pub fn new(n_layers: usize, d_model: usize) -> Self {
        FullCache {
            layers: (0..n_layers)
                .map(|_| LayerState {
                    k: GrowMat::new(d_model),
                    v: GrowMat::new(d_model),
                })
                .collect(),
        }
    }
}

impl KvCachePolicy for FullCache {
    fn name(&self) -> String {
        "full".into()
    }

    fn ingest_prefill(&mut self, layer: usize, _xnorm: &Mat, k: &Mat, v: &Mat) -> Option<(Mat, Mat)> {
        self.layers[layer].k.push_mat(k);
        self.layers[layer].v.push_mat(v);
        None
    }

    fn append(&mut self, layer: usize, _xnorm: &[f32], k: &[f32], v: &[f32]) {
        self.layers[layer].k.push_row(k);
        self.layers[layer].v.push_row(v);
    }

    fn sync_view(&mut self, layer: usize, view: &mut DecodeView) {
        let l = &self.layers[layer];
        let n = l.k.rows();
        view.truncate(n);
        // Append-only: rows already in the view are final (exact K/V,
        // absolute RoPE positions never change).
        for i in view.len()..n {
            view.write_row(i, l.k.row(i), l.v.row(i), i, i);
        }
        view.stable_rows = n;
        view.hist_rows = n;
    }

    fn materialize(&self, layer: usize) -> CacheView {
        let l = &self.layers[layer];
        let n = l.k.rows();
        CacheView {
            k: l.k.to_mat(),
            v: l.v.to_mat(),
            rope_pos: (0..n).collect(),
            abs_pos: (0..n).collect(),
        }
    }

    fn reserve(&mut self, additional_tokens: usize) {
        for l in &mut self.layers {
            l.k.reserve_rows(additional_tokens);
            l.v.reserve_rows(additional_tokens);
        }
    }

    fn len(&self, layer: usize) -> usize {
        self.layers[layer].k.rows()
    }

    fn kv_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.bytes() + l.v.bytes()).sum()
    }

    fn kv_bytes_projected(&self, tokens: usize) -> usize {
        // Exact: every token stores full-precision K + V per layer.
        self.layers
            .iter()
            .map(|l| 4 * tokens * (l.k.cols + l.v.cols))
            .sum()
    }

    fn snapshot(&self) -> KvSnapshot {
        let mut w = SnapWriter::new();
        w.write_usize(self.layers.len());
        for l in &self.layers {
            snapshot::write_growmat(&mut w, &l.k);
            snapshot::write_growmat(&mut w, &l.v);
        }
        KvSnapshot::new(tags::FULL, w.finish())
    }

    fn restore(&mut self, snap: &KvSnapshot) -> anyhow::Result<()> {
        snap.expect_tag(tags::FULL, "full cache")?;
        let mut r = SnapReader::new(snap.payload());
        let n_layers = r.read_usize()?;
        anyhow::ensure!(
            n_layers == self.layers.len(),
            "full cache: snapshot has {n_layers} layers, target {}",
            self.layers.len()
        );
        let mut layers = Vec::with_capacity(n_layers);
        for l in &self.layers {
            let k = snapshot::read_growmat(&mut r)?;
            let v = snapshot::read_growmat(&mut r)?;
            anyhow::ensure!(
                k.cols == l.k.cols && v.cols == l.v.cols && k.rows() == v.rows(),
                "full cache: snapshot geometry mismatch ({}x?/{} vs d_model {})",
                k.cols,
                v.cols,
                l.k.cols
            );
            layers.push(LayerState { k, v });
        }
        r.expect_end()?;
        self.layers = layers;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn stores_everything_exactly() {
        let mut rng = Pcg64::new(1);
        let mut c = FullCache::new(2, 8);
        let k = Mat::randn(5, 8, 1.0, &mut rng);
        let v = Mat::randn(5, 8, 1.0, &mut rng);
        assert!(c.ingest_prefill(0, &k, &k, &v).is_none());
        let krow: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
        let vrow: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
        c.append(0, &krow, &krow, &vrow);
        let view = c.materialize(0);
        view.validate();
        assert_eq!(view.len(), 6);
        assert_eq!(view.k.row(2), k.row(2));
        assert_eq!(view.v.row(5), &vrow[..]);
        assert_eq!(view.rope_pos, (0..6).collect::<Vec<_>>());
        // Layer 1 untouched.
        assert_eq!(c.len(1), 0);
        // 6 tokens * 2 tensors * 8 dims * 4B in layer 0.
        assert_eq!(c.kv_bytes(), 6 * 2 * 8 * 4);
    }

    #[test]
    fn snapshot_roundtrip_is_bit_exact() {
        let mut rng = Pcg64::new(3);
        let mut c = FullCache::new(2, 8);
        let k = Mat::randn(5, 8, 1.0, &mut rng);
        let v = Mat::randn(5, 8, 1.0, &mut rng);
        c.ingest_prefill(0, &k, &k, &v);
        c.ingest_prefill(1, &v, &v, &k);
        let snap = c.snapshot();
        let mut fresh = FullCache::new(2, 8);
        fresh.restore(&snap).unwrap();
        for li in 0..2 {
            let (a, b) = (c.materialize(li), fresh.materialize(li));
            assert_eq!(a.k.data, b.k.data);
            assert_eq!(a.v.data, b.v.data);
        }
        assert_eq!(fresh.kv_bytes(), c.kv_bytes());
        // Geometry mismatches are errors, not corruption.
        assert!(FullCache::new(3, 8).restore(&snap).is_err());
        assert!(FullCache::new(2, 4).restore(&snap).is_err());
    }
}
