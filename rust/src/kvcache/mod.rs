//! KV-cache policies — the seam where the paper's contribution (the
//! bi-branch channel-shrunk cache) and every baseline plug into both the
//! reference engine and the serving coordinator.
//!
//! Contract (shared by [`crate::model::engine::Engine`] and the
//! coordinator):
//!
//! 1. After the exact prefill pass the engine hands each layer's
//!    attention inputs (`xnorm`), pre-RoPE keys and values to
//!    [`KvCachePolicy::ingest_prefill`]. A policy may return replacement
//!    K/V to make *prefill attention itself* lossy (ASVD does; CSKV does
//!    not — its prefill is exact by design, §2.1).
//! 2. Each decode step appends one token via [`KvCachePolicy::append`]
//!    and then brings the engine-owned per-layer [`DecodeView`] up to
//!    date via [`KvCachePolicy::sync_view`]. The view holds the
//!    *reconstructed and RoPE'd* keys plus values and position vectors;
//!    policies update it **in place**, rewriting only rows that actually
//!    changed since the last sync (see the cost model below).
//! 3. [`KvCachePolicy::materialize`] remains as the cold-path oracle: a
//!    from-scratch [`CacheView`] with **pre-RoPE** keys, used by tests,
//!    diagnostics and structural checks — never by the decode hot loop.
//! 4. [`KvCachePolicy::kv_bytes`] reports the true storage footprint, so
//!    every experiment compares methods at equal memory budgets.
//!
//! ## Decode cost model
//!
//! Before the incremental views, `Engine::decode_step` re-materialized the
//! whole cache every token: reconstruct `K̂ = C·B` for all `n` historical
//! tokens (dequantizing every sealed int4 group), clone the full `[n, d]`
//! key matrix, and re-apply RoPE to every row — `O(n·r·d)` work and
//! `O(n·d)` fresh allocations *per token*, i.e. `O(n²·r·d)` per generated
//! sequence. The incremental [`DecodeView`] exploits the immutability
//! that KIVI-style group quantization and H2O-style eviction already
//! assume: sealed history never changes, so it is reconstructed,
//! dequantized and RoPE'd **exactly once**. Per-token sync cost by
//! policy:
//!
//! | policy        | rows rewritten per token | cost/token (sync)        |
//! |---------------|--------------------------|--------------------------|
//! | full          | 0 (append 1)             | `O(d)`                   |
//! | CSKV fp32     | 1 migrated + 1 appended  | `O(r·d)`                 |
//! | CSKV int4     | ≤ residual (< GROUP)     | `O(GROUP·r·d)` amortized `O(r·d)` |
//! | H2O           | suffix from evict point  | `O(budget·d)` worst case |
//! | StreamingLLM  | non-sink rows on evict   | `O(budget·d)` worst case |
//! | ASVD          | 0 (append 1)             | `O(r·d)`                 |
//!
//! Attention itself still reads all live rows (`O(n_eff·d)` dot products)
//! — the point is that *rematerialization* no longer dominates, and the
//! steady-state decode step performs no heap allocation at all for
//! append-only policies (`rust/tests/decode_alloc.rs` enforces this for
//! the full cache and for CSKV int4's fused decode).
//!
//! ### Quantized view segments (fused int4 decode)
//!
//! For CSKV int4 the view no longer materializes sealed history into f32
//! rows at all. Once a full [`GROUP`]-row span of history is backed by
//! sealed compressed storage, the policy hands the reconstructed span to
//! [`DecodeView::seal_group`], which RoPE's the keys and re-quantizes the
//! span into packed int4 blocks — per-channel for keys, per-token for
//! values, mirroring the KIVI layout of the store itself. The blocks are
//! a deterministic function of the immutable sealed store, so live and
//! fresh views still agree bit-for-bit. Decode attention consumes them
//! directly through the fused dequantize-dot / dequantize-AXPY kernels
//! ([`QuantizedBlock::fused_dot_rows`] /
//! [`QuantizedBlock::fused_axpy_rows`]): no dequantize-to-f32 round trip,
//! and the view's resident footprint on sealed history drops ~8×.
//! [`DecodeView::key_row`] / [`DecodeView::value_row`] address only the
//! f32 tail `[quant_rows, len)`; the engine dispatches per segment.
//!
//! ### View-consistency contract
//!
//! A policy may maintain **one** persistently-updated [`DecodeView`] set
//! (the engine's [`crate::model::engine::DecodeState`]) plus any number
//! of *fresh* (empty) views, which always trigger a full rebuild.
//! Syncing a second, stale non-empty view set is unsupported: eviction
//! policies track their dirty ranges relative to the single live view.
//! `rust/tests/property_invariants.rs` holds the correctness oracle:
//! after any schedule of appends/evictions/seals, the incrementally
//! synced view is bit-identical to a from-scratch rebuild.

pub mod bibranch;
pub mod full;
pub mod memory;
pub mod prefix;
pub mod snapshot;

pub use bibranch::{CskvCache, CskvConfig, QuantMode};
pub use full::FullCache;
pub use prefix::{PrefixCache, PrefixRef, PrefixStats};
pub use snapshot::{merge_blocks, split_blocks, KvSnapshot, SnapReader, SnapWriter, SnapshotBlock};

use crate::compress::quant::{quantize_block, QuantAxis, QuantizedBlock, GROUP};
use crate::tensor::{ops, Mat};

/// Effective cache contents for one layer's decode attention, materialized
/// from scratch (cold path / oracle). Keys are **pre-RoPE**.
#[derive(Clone, Debug)]
pub struct CacheView {
    /// Pre-RoPE keys `[n_eff, d_model]`.
    pub k: Mat,
    /// Values `[n_eff, d_model]`.
    pub v: Mat,
    /// RoPE position to apply to each key row.
    pub rope_pos: Vec<usize>,
    /// Absolute token index of each row (for attention-score attribution).
    pub abs_pos: Vec<usize>,
}

impl CacheView {
    pub fn len(&self) -> usize {
        self.k.rows
    }

    pub fn is_empty(&self) -> bool {
        self.k.rows == 0
    }

    pub fn validate(&self) {
        assert_eq!(self.k.rows, self.v.rows);
        assert_eq!(self.k.rows, self.rope_pos.len());
        assert_eq!(self.k.rows, self.abs_pos.len());
    }
}

/// Engine-owned, incrementally-maintained cache view for one layer.
///
/// Holds the *post-RoPE* keys, the values, and per-row position vectors.
/// Policies update it in place through [`DecodeView::write_row`] /
/// [`DecodeView::truncate`]; the engine only reads. Rows are written at
/// most once per change — append-only rows (full cache, sealed CSKV
/// groups, ASVD features) are reconstructed/dequantized/RoPE'd exactly
/// once over a whole generation.
///
/// Row storage is split into two segments: a leading **quantized
/// segment** of `quant_rows` rows held as packed int4 blocks (only CSKV
/// int4 populates it, via [`DecodeView::seal_group`]) and the f32 tail
/// `[quant_rows, len)` held in the grow matrices. The position vectors
/// span both segments, so `len()` counts every row.
///
/// The three cursor fields (`stable_rows`, `hist_rows`, `epoch`) are
/// **policy-interpreted** sync bookkeeping carried by the view so that a
/// policy stays correct when handed a fresh view (full rebuild) as well
/// as its live one (incremental update). The engine never touches them.
#[derive(Clone, Debug)]
pub struct DecodeView {
    n_heads: usize,
    rope_base: f32,
    /// RoPE'd keys, row-major `[len - quant_rows, d_model]` (f32 tail;
    /// view row `i` lives at matrix row `i - quant_rows`).
    k: GrowMat,
    /// Values `[len - quant_rows, d_model]` (f32 tail).
    v: GrowMat,
    /// Sealed key blocks: RoPE'd, re-quantized per-channel int4,
    /// [`GROUP`] rows each, covering view rows `[0, quant_rows)`.
    qk: Vec<QuantizedBlock>,
    /// Sealed value blocks (per-token int4), aligned with `qk`.
    qv: Vec<QuantizedBlock>,
    /// Reusable RoPE staging buffer for [`DecodeView::seal_group`].
    seal_buf: Mat,
    rope_pos: Vec<usize>,
    abs_pos: Vec<usize>,
    /// Rows `[0, stable_rows)` are final: derived from immutable storage
    /// and never rewritten (e.g. sealed-group history for CSKV int4).
    pub stable_rows: usize,
    /// Number of leading rows holding the policy's "history"
    /// representation (CSKV: reconstructed `C·B` rows; 0 for policies
    /// without a history/window split).
    pub hist_rows: usize,
    /// Policy-defined generation counter: sealed-group count for CSKV
    /// int4, cumulative eviction count for H2O / StreamingLLM. A mismatch
    /// with the policy's live counter signals that rows beyond the
    /// policy's stable region must be rebuilt.
    pub epoch: usize,
}

impl DecodeView {
    pub fn new(d_model: usize, n_heads: usize, rope_base: f32) -> Self {
        assert!(n_heads > 0 && d_model % n_heads == 0, "bad head split");
        DecodeView {
            n_heads,
            rope_base,
            k: GrowMat::new(d_model),
            v: GrowMat::new(d_model),
            qk: Vec::new(),
            qv: Vec::new(),
            seal_buf: Mat::zeros(0, 0),
            rope_pos: Vec::new(),
            abs_pos: Vec::new(),
            stable_rows: 0,
            hist_rows: 0,
            epoch: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.rope_pos.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rope_pos.is_empty()
    }

    pub fn d_model(&self) -> usize {
        self.k.cols
    }

    /// RoPE'd key row `i` — f32 segment only (`i ≥ quant_rows()`); rows
    /// below that are read through [`DecodeView::quant_key_groups`].
    #[inline]
    pub fn key_row(&self, i: usize) -> &[f32] {
        let q = self.quant_rows();
        debug_assert!(i >= q, "key_row({i}) inside quantized segment [0, {q})");
        self.k.row(i - q)
    }

    /// Value row `i` — f32 segment only (`i ≥ quant_rows()`).
    #[inline]
    pub fn value_row(&self, i: usize) -> &[f32] {
        let q = self.quant_rows();
        debug_assert!(i >= q, "value_row({i}) inside quantized segment [0, {q})");
        self.v.row(i - q)
    }

    /// Number of leading rows held as packed int4 blocks — always a
    /// multiple of [`GROUP`]; 0 for every policy but CSKV int4.
    #[inline]
    pub fn quant_rows(&self) -> usize {
        self.qk.len() * GROUP
    }

    /// Sealed key blocks (RoPE'd, per-channel int4), covering view rows
    /// `[g·GROUP, (g+1)·GROUP)` for block `g`.
    #[inline]
    pub fn quant_key_groups(&self) -> &[QuantizedBlock] {
        &self.qk
    }

    /// Sealed value blocks (per-token int4), aligned with the key blocks.
    #[inline]
    pub fn quant_value_groups(&self) -> &[QuantizedBlock] {
        &self.qv
    }

    pub fn rope_positions(&self) -> &[usize] {
        &self.rope_pos
    }

    pub fn abs_positions(&self) -> &[usize] {
        &self.abs_pos
    }

    /// Reserve capacity for `total_tokens` rows so steady-state appends
    /// perform no allocation. (Block *contents* still allocate at seal
    /// events — those sit outside the per-token hot loop.)
    pub fn reserve(&mut self, total_tokens: usize) {
        let extra = total_tokens.saturating_sub(self.len());
        self.k.reserve_rows(extra);
        self.v.reserve_rows(extra);
        self.rope_pos.reserve(extra);
        self.abs_pos.reserve(extra);
        let want_groups = total_tokens / GROUP + 1;
        self.qk.reserve(want_groups.saturating_sub(self.qk.len()));
        self.qv.reserve(want_groups.saturating_sub(self.qv.len()));
    }

    /// Write row `i` (`i ≤ len`; `i == len` appends). The key is handed
    /// in **pre-RoPE** and rotated in place at `rope_pos`, per head —
    /// this is the single point where RoPE is applied to cached keys, so
    /// incremental and from-scratch syncs are bit-identical.
    pub fn write_row(&mut self, i: usize, k_pre_rope: &[f32], v: &[f32], rope_pos: usize, abs_pos: usize) {
        let d = self.k.cols;
        debug_assert_eq!(k_pre_rope.len(), d);
        debug_assert_eq!(v.len(), d);
        let q = self.quant_rows();
        assert!(i >= q, "write into sealed quantized segment: {i} < {q}");
        assert!(i <= self.len(), "non-contiguous view write: {i} > {}", self.len());
        let fi = i - q;
        if i == self.len() {
            self.k.push_row(k_pre_rope);
            self.v.push_row(v);
            self.rope_pos.push(rope_pos);
            self.abs_pos.push(abs_pos);
        } else {
            self.k.row_mut(fi).copy_from_slice(k_pre_rope);
            self.v.row_mut(fi).copy_from_slice(v);
            self.rope_pos[i] = rope_pos;
            self.abs_pos[i] = abs_pos;
        }
        let dh = d / self.n_heads;
        let row = self.k.row_mut(fi);
        for h in 0..self.n_heads {
            ops::rope_rotate(&mut row[h * dh..(h + 1) * dh], rope_pos, self.rope_base);
        }
    }

    /// Seal the next [`GROUP`] history rows into packed int4 blocks.
    ///
    /// `k_pre_rope` / `v` hold the reconstructed rows for view positions
    /// `[quant_rows(), quant_rows() + GROUP)` — keys pre-RoPE, exactly as
    /// for [`DecodeView::write_row`]. The keys are rotated at their token
    /// positions (the quantized mirror of `write_row`'s single RoPE
    /// application point), both spans are quantized (per-channel keys,
    /// per-token values), the superseded f32 rows are dropped, and
    /// position entries are appended for rows the view had not
    /// materialized yet (fresh-view rebuilds). Quantized segments always
    /// cover history rows, whose `rope`/`abs` positions equal the token
    /// index — the blocks carry no per-row position payload.
    pub fn seal_group(&mut self, k_pre_rope: &Mat, v: &Mat) {
        let d = self.k.cols;
        assert_eq!((k_pre_rope.rows, k_pre_rope.cols), (GROUP, d), "bad seal K shape");
        assert_eq!((v.rows, v.cols), (GROUP, d), "bad seal V shape");
        let q0 = self.quant_rows();
        debug_assert!(q0 <= self.len());
        let dh = d / self.n_heads;
        self.seal_buf.rows = GROUP;
        self.seal_buf.cols = d;
        self.seal_buf.data.resize(GROUP * d, 0.0);
        self.seal_buf.data.copy_from_slice(&k_pre_rope.data);
        for j in 0..GROUP {
            let row = self.seal_buf.row_mut(j);
            for h in 0..self.n_heads {
                ops::rope_rotate(&mut row[h * dh..(h + 1) * dh], q0 + j, self.rope_base);
            }
        }
        let kb = quantize_block(&self.seal_buf, QuantAxis::PerChannel);
        self.qk.push(kb);
        self.qv.push(quantize_block(v, QuantAxis::PerToken));
        // Drop the f32 rows this group supersedes; their position entries
        // stay (history rows already carry rope = abs = token index).
        let overlap = (self.len() - q0).min(GROUP);
        self.k.remove_rows(0, overlap);
        self.v.remove_rows(0, overlap);
        for j in 0..overlap {
            debug_assert_eq!(self.rope_pos[q0 + j], q0 + j, "sealing a non-history row");
            self.rope_pos[q0 + j] = q0 + j;
            self.abs_pos[q0 + j] = q0 + j;
        }
        for j in overlap..GROUP {
            self.rope_pos.push(q0 + j);
            self.abs_pos.push(q0 + j);
        }
    }

    /// Drop rows `[n, len)` and clamp the cursors. A cut below
    /// `quant_rows()` must land on a [`GROUP`] boundary — sealed blocks
    /// are indivisible.
    pub fn truncate(&mut self, n: usize) {
        if n >= self.len() {
            return;
        }
        let q = self.quant_rows();
        if n < q {
            assert!(n % GROUP == 0, "truncate splits a sealed group: {n}");
            self.qk.truncate(n / GROUP);
            self.qv.truncate(n / GROUP);
            self.k.truncate_rows(0);
            self.v.truncate_rows(0);
        } else {
            self.k.truncate_rows(n - q);
            self.v.truncate_rows(n - q);
        }
        self.rope_pos.truncate(n);
        self.abs_pos.truncate(n);
        self.stable_rows = self.stable_rows.min(n);
        self.hist_rows = self.hist_rows.min(n);
    }

    pub fn clear(&mut self) {
        self.truncate(0);
        self.epoch = 0;
    }

    pub fn validate(&self) {
        let q = self.quant_rows();
        assert_eq!(self.qk.len(), self.qv.len());
        assert_eq!(self.k.rows(), self.v.rows());
        assert_eq!(self.k.rows() + q, self.rope_pos.len());
        assert_eq!(self.rope_pos.len(), self.abs_pos.len());
        for (kb, vb) in self.qk.iter().zip(&self.qv) {
            assert_eq!((kb.rows, kb.cols), (GROUP, self.k.cols));
            assert_eq!((vb.rows, vb.cols), (GROUP, self.k.cols));
        }
        assert!(self.stable_rows <= self.len());
        assert!(self.hist_rows <= self.len());
    }

    /// Content equality (rows, blocks + positions), ignoring the sync
    /// cursors — the property-test oracle for incremental ≡ from-scratch.
    pub fn same_contents(&self, other: &DecodeView) -> bool {
        self.k == other.k
            && self.v == other.v
            && self.qk == other.qk
            && self.qv == other.qv
            && self.rope_pos == other.rope_pos
            && self.abs_pos == other.abs_pos
    }
}

/// A pluggable KV-cache management policy (one instance per generation).
pub trait KvCachePolicy: Send {
    /// Display name used in experiment tables.
    fn name(&self) -> String;

    /// Ingest the prefill results for one layer. `xnorm`, `k`, `v` are
    /// `[T, d_model]`; keys are pre-RoPE. Returning `Some((k', v'))`
    /// replaces the K/V used for the prefill attention itself.
    fn ingest_prefill(&mut self, layer: usize, xnorm: &Mat, k: &Mat, v: &Mat)
        -> Option<(Mat, Mat)>;

    /// Aggregated prefill attention mass per key position (summed over
    /// heads and queries) — H2O's seeding signal.
    fn observe_prefill_attn(&mut self, _layer: usize, _mass: &[f32]) {}

    /// Append one decoded token's activations for one layer.
    fn append(&mut self, layer: usize, xnorm: &[f32], k: &[f32], v: &[f32]);

    /// Bring `view` up to date with this layer's cache contents,
    /// rewriting only rows that changed since the view's last sync (an
    /// empty view triggers a full rebuild). After returning, `view` holds
    /// exactly [`KvCachePolicy::len`] rows of RoPE'd keys + values with
    /// correct `rope`/`abs` positions. See the module docs for the
    /// single-live-view contract.
    fn sync_view(&mut self, layer: usize, view: &mut DecodeView);

    /// Cold-path oracle: materialize the effective cache from scratch
    /// with **pre-RoPE** keys. Tests and diagnostics only.
    fn materialize(&self, layer: usize) -> CacheView;

    /// Hint: `additional` more tokens are coming — reserve storage so
    /// appends don't reallocate. Best-effort; default no-op.
    fn reserve(&mut self, _additional_tokens: usize) {}

    /// Decode-time attention feedback aligned with the synced view's
    /// `abs_positions` (H2O score accumulation).
    fn observe_decode_attn(&mut self, _layer: usize, _abs_pos: &[usize], _probs: &[f32]) {}

    /// RoPE position for the query at absolute position `abs_pos`
    /// (StreamingLLM remaps to cache-relative coordinates).
    fn query_rope_pos(&self, _layer: usize, abs_pos: usize) -> usize {
        abs_pos
    }

    /// True if `ingest_prefill` substitutes lossy K/V (changing the
    /// forward pass itself) — such policies cannot share a cached exact
    /// prefill with others in the evaluation harness.
    fn lossy_prefill(&self) -> bool {
        false
    }

    /// Number of tokens represented in this layer's cache (for invariants;
    /// eviction policies may *store* fewer).
    fn len(&self, layer: usize) -> usize;

    /// True storage footprint across all layers, in bytes.
    fn kv_bytes(&self) -> usize;

    /// Estimated [`KvCachePolicy::kv_bytes`] if this (empty) cache held
    /// `tokens` tokens — the serving coordinator's admission pre-charge,
    /// so a long prompt is budgeted *before* its prefill commits the
    /// memory. Estimates use full-precision accounting (an upper bound
    /// for quantized stores), which keeps admission conservative.
    fn kv_bytes_projected(&self, tokens: usize) -> usize;

    /// Accumulated attention mass per **absolute token position**, for
    /// policies that track it (H2O's eviction scores). The pager uses
    /// this to rank a preempted sequence's history blocks — low-mass
    /// spans spill to colder tiers first. `None` (the default) means the
    /// policy has no signal and the pager falls back to age/position
    /// scoring. Purely an eviction-ordering hint: it never affects
    /// restored state or token streams.
    fn attention_profile(&self) -> Option<Vec<f32>> {
        None
    }

    /// Serialize the complete cache state in the policy's **own**
    /// representation (CSKV: low-rank features / int4 groups + window;
    /// eviction policies: kept rows + bookkeeping) — the portable form
    /// the preemptive scheduler swaps to the cold tier. Every f32 and
    /// packed int4 code round-trips bit-exactly.
    fn snapshot(&self) -> KvSnapshot;

    /// Replace this policy's state with `snap`'s. The target must be
    /// configured compatibly (same geometry / window / quant mode /
    /// factor ranks as the snapshotted instance); mismatches error
    /// without touching state where practical. After a successful
    /// restore, decoding continues **bit-identically** to the
    /// unpreempted run — the engine rebuilds its [`DecodeView`]s through
    /// the normal [`KvCachePolicy::sync_view`] fresh-view path.
    fn restore(&mut self, snap: &KvSnapshot) -> anyhow::Result<()>;
}

/// Growable row-major matrix used by cache implementations.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GrowMat {
    pub cols: usize,
    pub data: Vec<f32>,
}

impl GrowMat {
    pub fn new(cols: usize) -> Self {
        GrowMat {
            cols,
            data: Vec::new(),
        }
    }

    pub fn rows(&self) -> usize {
        if self.cols == 0 {
            0
        } else {
            self.data.len() / self.cols
        }
    }

    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.cols);
        self.data.extend_from_slice(row);
    }

    pub fn push_mat(&mut self, m: &Mat) {
        assert_eq!(m.cols, self.cols);
        self.data.extend_from_slice(&m.data);
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Remove row `i`, shifting the tail (eviction policies).
    pub fn remove_row(&mut self, i: usize) {
        let c = self.cols;
        self.data.drain(i * c..(i + 1) * c);
    }

    /// Remove rows `[lo, hi)` in one drain — O(tail) instead of the
    /// O((hi−lo)·tail) of repeated `remove_row` calls.
    pub fn remove_rows(&mut self, lo: usize, hi: usize) {
        assert!(lo <= hi && hi <= self.rows());
        let c = self.cols;
        self.data.drain(lo * c..hi * c);
    }

    /// Drop rows `[n, rows)`.
    pub fn truncate_rows(&mut self, n: usize) {
        let c = self.cols;
        self.data.truncate(n * c);
    }

    /// Reserve capacity for `additional` more rows.
    pub fn reserve_rows(&mut self, additional: usize) {
        self.data.reserve(additional * self.cols);
    }

    /// Rows `[lo, hi)` as a `Mat` copy.
    pub fn slice(&self, lo: usize, hi: usize) -> Mat {
        Mat::from_vec(hi - lo, self.cols, self.data[lo * self.cols..hi * self.cols].to_vec())
    }

    pub fn to_mat(&self) -> Mat {
        Mat::from_vec(self.rows(), self.cols, self.data.clone())
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn growmat_push_and_slice() {
        let mut g = GrowMat::new(3);
        g.push_row(&[1.0, 2.0, 3.0]);
        g.push_row(&[4.0, 5.0, 6.0]);
        g.push_row(&[7.0, 8.0, 9.0]);
        assert_eq!(g.rows(), 3);
        assert_eq!(g.row(1), &[4.0, 5.0, 6.0]);
        let s = g.slice(1, 3);
        assert_eq!(s.rows, 2);
        assert_eq!(s.at(1, 0), 7.0);
        assert_eq!(g.bytes(), 9 * 4);
    }

    #[test]
    fn growmat_remove_row() {
        let mut g = GrowMat::new(2);
        for i in 0..4 {
            g.push_row(&[i as f32, 10.0 + i as f32]);
        }
        g.remove_row(1);
        assert_eq!(g.rows(), 3);
        assert_eq!(g.row(0), &[0.0, 10.0]);
        assert_eq!(g.row(1), &[2.0, 12.0]);
        assert_eq!(g.row(2), &[3.0, 13.0]);
    }

    #[test]
    fn growmat_remove_rows_range() {
        let mut g = GrowMat::new(2);
        for i in 0..6 {
            g.push_row(&[i as f32, 10.0 + i as f32]);
        }
        g.remove_rows(1, 4);
        assert_eq!(g.rows(), 3);
        assert_eq!(g.row(0), &[0.0, 10.0]);
        assert_eq!(g.row(1), &[4.0, 14.0]);
        assert_eq!(g.row(2), &[5.0, 15.0]);
        // Degenerate range is a no-op.
        g.remove_rows(2, 2);
        assert_eq!(g.rows(), 3);
    }

    #[test]
    fn growmat_truncate_and_reserve() {
        let mut g = GrowMat::new(2);
        for i in 0..5 {
            g.push_row(&[i as f32, 0.0]);
        }
        g.truncate_rows(2);
        assert_eq!(g.rows(), 2);
        g.reserve_rows(100);
        assert!(g.data.capacity() >= 2 * 2 + 100 * 2);
        let before = g.data.capacity();
        for i in 0..100 {
            g.push_row(&[i as f32, 1.0]);
        }
        assert_eq!(g.data.capacity(), before, "reserved pushes must not realloc");
    }

    #[test]
    fn cacheview_validation() {
        let v = CacheView {
            k: Mat::zeros(2, 4),
            v: Mat::zeros(2, 4),
            rope_pos: vec![0, 1],
            abs_pos: vec![0, 1],
        };
        v.validate();
        assert_eq!(v.len(), 2);
    }

    #[test]
    fn decode_view_write_applies_rope_once() {
        let d = 8;
        let mut view = DecodeView::new(d, 2, 10000.0);
        let k: Vec<f32> = (0..d).map(|i| i as f32 * 0.1).collect();
        let v: Vec<f32> = (0..d).map(|i| i as f32).collect();
        view.write_row(0, &k, &v, 5, 5);
        view.validate();
        assert_eq!(view.len(), 1);
        // Keys are stored RoPE'd at the given position.
        let mut expect = k.clone();
        for h in 0..2 {
            ops::rope_rotate(&mut expect[h * 4..(h + 1) * 4], 5, 10000.0);
        }
        assert_eq!(view.key_row(0), &expect[..]);
        assert_eq!(view.value_row(0), &v[..]);
        // Rewrite in place at a new position.
        view.write_row(0, &k, &v, 0, 7);
        assert_eq!(view.key_row(0), &k[..], "pos 0 RoPE is identity");
        assert_eq!(view.rope_positions(), &[0]);
        assert_eq!(view.abs_positions(), &[7]);
    }

    #[test]
    fn decode_view_truncate_clamps_cursors() {
        let d = 4;
        let mut view = DecodeView::new(d, 2, 10000.0);
        for i in 0..5 {
            view.write_row(i, &[0.0; 4], &[0.0; 4], i, i);
        }
        view.stable_rows = 4;
        view.hist_rows = 5;
        view.truncate(3);
        view.validate();
        assert_eq!(view.len(), 3);
        assert_eq!(view.stable_rows, 3);
        assert_eq!(view.hist_rows, 3);
    }

    #[test]
    #[should_panic]
    fn decode_view_rejects_gap_writes() {
        let mut view = DecodeView::new(4, 2, 10000.0);
        view.write_row(2, &[0.0; 4], &[0.0; 4], 0, 0);
    }

    /// Sealing a group drops the superseded f32 rows, shifts the tail,
    /// stores blocks that dequantize to the RoPE'd rows (within quant
    /// error), and matches a fresh view sealed before any f32 writes.
    #[test]
    fn decode_view_seal_group_replaces_f32_rows() {
        let d = 8;
        let nh = 2;
        let mut rng = crate::util::prng::Pcg64::new(7);
        let k = Mat::randn(GROUP + 3, d, 1.0, &mut rng);
        let v = Mat::randn(GROUP + 3, d, 1.0, &mut rng);
        let mut view = DecodeView::new(d, nh, 10000.0);
        for i in 0..GROUP + 3 {
            view.write_row(i, k.row(i), v.row(i), i, i);
        }
        let roped_keys: Vec<Vec<f32>> = (0..GROUP).map(|i| view.key_row(i).to_vec()).collect();
        let tail_key = view.key_row(GROUP).to_vec();

        view.seal_group(&k.rows_slice(0, GROUP), &v.rows_slice(0, GROUP));
        view.validate();
        assert_eq!(view.len(), GROUP + 3);
        assert_eq!(view.quant_rows(), GROUP);
        assert_eq!(view.key_row(GROUP), &tail_key[..], "f32 tail must shift in place");
        assert_eq!(view.rope_positions().len(), GROUP + 3);

        // Blocks hold the RoPE'd keys / raw values within half a step.
        let kd = view.quant_key_groups()[0].dequantize();
        let vd = view.quant_value_groups()[0].dequantize();
        for i in 0..GROUP {
            for j in 0..d {
                assert!((kd.at(i, j) - roped_keys[i][j]).abs() < 0.5, "key ({i},{j})");
                assert!((vd.at(i, j) - v.at(i, j)).abs() < 0.5, "value ({i},{j})");
            }
        }

        // Fresh-view rebuild: seal first, then write the tail — identical.
        let mut fresh = DecodeView::new(d, nh, 10000.0);
        fresh.seal_group(&k.rows_slice(0, GROUP), &v.rows_slice(0, GROUP));
        for i in GROUP..GROUP + 3 {
            fresh.write_row(i, k.row(i), v.row(i), i, i);
        }
        fresh.validate();
        assert!(view.same_contents(&fresh), "live seal must equal fresh rebuild");
    }

    #[test]
    fn decode_view_truncate_respects_group_boundaries() {
        let d = 4;
        let mut rng = crate::util::prng::Pcg64::new(8);
        let k = Mat::randn(GROUP + 2, d, 1.0, &mut rng);
        let v = Mat::randn(GROUP + 2, d, 1.0, &mut rng);
        let mut view = DecodeView::new(d, 2, 10000.0);
        view.seal_group(&k.rows_slice(0, GROUP), &v.rows_slice(0, GROUP));
        for i in GROUP..GROUP + 2 {
            view.write_row(i, k.row(i), v.row(i), i, i);
        }
        view.truncate(GROUP + 1); // drop one f32 row
        view.validate();
        assert_eq!(view.len(), GROUP + 1);
        assert_eq!(view.quant_rows(), GROUP);
        view.truncate(GROUP); // drop the whole f32 tail, keep the block
        view.validate();
        assert_eq!(view.len(), GROUP);
        assert_eq!(view.quant_rows(), GROUP);
        view.clear(); // group boundary 0: blocks go too
        view.validate();
        assert_eq!(view.len(), 0);
        assert_eq!(view.quant_rows(), 0);
    }

    #[test]
    #[should_panic]
    fn decode_view_rejects_writes_into_sealed_segment() {
        let d = 4;
        let mut view = DecodeView::new(d, 2, 10000.0);
        let k = Mat::zeros(GROUP, d);
        let v = Mat::zeros(GROUP, d);
        view.seal_group(&k, &v);
        view.write_row(0, &[0.0; 4], &[0.0; 4], 0, 0);
    }
}
