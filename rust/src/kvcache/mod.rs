//! KV-cache policies — the seam where the paper's contribution (the
//! bi-branch channel-shrunk cache) and every baseline plug into both the
//! reference engine and the serving coordinator.
//!
//! Contract (shared by [`crate::model::engine::Engine`] and the
//! coordinator):
//!
//! 1. After the exact prefill pass the engine hands each layer's
//!    attention inputs (`xnorm`), pre-RoPE keys and values to
//!    [`KvCachePolicy::ingest_prefill`]. A policy may return replacement
//!    K/V to make *prefill attention itself* lossy (ASVD does; CSKV does
//!    not — its prefill is exact by design, §2.1).
//! 2. Each decode step appends one token via [`KvCachePolicy::append`]
//!    and materializes the effective cache via
//!    [`KvCachePolicy::materialize`]. Keys come back **pre-RoPE** along
//!    with the RoPE position to use per row, so policies can use absolute
//!    positions (CSKV, H2O, full) or cache-relative positions
//!    (StreamingLLM) under one interface.
//! 3. [`KvCachePolicy::kv_bytes`] reports the true storage footprint, so
//!    every experiment compares methods at equal memory budgets.

pub mod bibranch;
pub mod full;
pub mod memory;

pub use bibranch::{CskvCache, CskvConfig, QuantMode};
pub use full::FullCache;

use crate::tensor::Mat;

/// Effective cache contents for one layer's decode attention.
#[derive(Clone, Debug)]
pub struct CacheView {
    /// Pre-RoPE keys `[n_eff, d_model]`.
    pub k: Mat,
    /// Values `[n_eff, d_model]`.
    pub v: Mat,
    /// RoPE position to apply to each key row.
    pub rope_pos: Vec<usize>,
    /// Absolute token index of each row (for attention-score attribution).
    pub abs_pos: Vec<usize>,
}

impl CacheView {
    pub fn len(&self) -> usize {
        self.k.rows
    }

    pub fn is_empty(&self) -> bool {
        self.k.rows == 0
    }

    pub fn validate(&self) {
        assert_eq!(self.k.rows, self.v.rows);
        assert_eq!(self.k.rows, self.rope_pos.len());
        assert_eq!(self.k.rows, self.abs_pos.len());
    }
}

/// A pluggable KV-cache management policy (one instance per generation).
pub trait KvCachePolicy: Send {
    /// Display name used in experiment tables.
    fn name(&self) -> String;

    /// Ingest the prefill results for one layer. `xnorm`, `k`, `v` are
    /// `[T, d_model]`; keys are pre-RoPE. Returning `Some((k', v'))`
    /// replaces the K/V used for the prefill attention itself.
    fn ingest_prefill(&mut self, layer: usize, xnorm: &Mat, k: &Mat, v: &Mat)
        -> Option<(Mat, Mat)>;

    /// Aggregated prefill attention mass per key position (summed over
    /// heads and queries) — H2O's seeding signal.
    fn observe_prefill_attn(&mut self, _layer: usize, _mass: &[f32]) {}

    /// Append one decoded token's activations for one layer.
    fn append(&mut self, layer: usize, xnorm: &[f32], k: &[f32], v: &[f32]);

    /// Materialize the effective cache for attention at this step.
    fn materialize(&self, layer: usize) -> CacheView;

    /// Decode-time attention feedback aligned with `materialize`'s
    /// `abs_pos` (H2O score accumulation).
    fn observe_decode_attn(&mut self, _layer: usize, _abs_pos: &[usize], _probs: &[f32]) {}

    /// RoPE position for the query at absolute position `abs_pos`
    /// (StreamingLLM remaps to cache-relative coordinates).
    fn query_rope_pos(&self, _layer: usize, abs_pos: usize) -> usize {
        abs_pos
    }

    /// True if `ingest_prefill` substitutes lossy K/V (changing the
    /// forward pass itself) — such policies cannot share a cached exact
    /// prefill with others in the evaluation harness.
    fn lossy_prefill(&self) -> bool {
        false
    }

    /// Number of tokens represented in this layer's cache (for invariants;
    /// eviction policies may *store* fewer).
    fn len(&self, layer: usize) -> usize;

    /// True storage footprint across all layers, in bytes.
    fn kv_bytes(&self) -> usize;
}

/// Growable row-major matrix used by cache implementations.
#[derive(Clone, Debug, Default)]
pub struct GrowMat {
    pub cols: usize,
    pub data: Vec<f32>,
}

impl GrowMat {
    pub fn new(cols: usize) -> Self {
        GrowMat {
            cols,
            data: Vec::new(),
        }
    }

    pub fn rows(&self) -> usize {
        if self.cols == 0 {
            0
        } else {
            self.data.len() / self.cols
        }
    }

    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.cols);
        self.data.extend_from_slice(row);
    }

    pub fn push_mat(&mut self, m: &Mat) {
        assert_eq!(m.cols, self.cols);
        self.data.extend_from_slice(&m.data);
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Remove row `i`, shifting the tail (eviction policies).
    pub fn remove_row(&mut self, i: usize) {
        let c = self.cols;
        self.data.drain(i * c..(i + 1) * c);
    }

    /// Rows `[lo, hi)` as a `Mat` copy.
    pub fn slice(&self, lo: usize, hi: usize) -> Mat {
        Mat::from_vec(hi - lo, self.cols, self.data[lo * self.cols..hi * self.cols].to_vec())
    }

    pub fn to_mat(&self) -> Mat {
        Mat::from_vec(self.rows(), self.cols, self.data.clone())
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn growmat_push_and_slice() {
        let mut g = GrowMat::new(3);
        g.push_row(&[1.0, 2.0, 3.0]);
        g.push_row(&[4.0, 5.0, 6.0]);
        g.push_row(&[7.0, 8.0, 9.0]);
        assert_eq!(g.rows(), 3);
        assert_eq!(g.row(1), &[4.0, 5.0, 6.0]);
        let s = g.slice(1, 3);
        assert_eq!(s.rows, 2);
        assert_eq!(s.at(1, 0), 7.0);
        assert_eq!(g.bytes(), 9 * 4);
    }

    #[test]
    fn growmat_remove_row() {
        let mut g = GrowMat::new(2);
        for i in 0..4 {
            g.push_row(&[i as f32, 10.0 + i as f32]);
        }
        g.remove_row(1);
        assert_eq!(g.rows(), 3);
        assert_eq!(g.row(0), &[0.0, 10.0]);
        assert_eq!(g.row(1), &[2.0, 12.0]);
        assert_eq!(g.row(2), &[3.0, 13.0]);
    }

    #[test]
    fn cacheview_validation() {
        let v = CacheView {
            k: Mat::zeros(2, 4),
            v: Mat::zeros(2, 4),
            rope_pos: vec![0, 1],
            abs_pos: vec![0, 1],
        };
        v.validate();
        assert_eq!(v.len(), 2);
    }
}
