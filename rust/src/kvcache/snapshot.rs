//! KV state snapshots — the serialized, portable form of a sequence's
//! cache that the preemptive scheduler swaps between the hot tier and
//! the cold tier (in-memory blob store or disk spill directory).
//!
//! A [`KvSnapshot`] stores the policy's **own** representation — CSKV's
//! low-rank features and int4 groups, H2O's kept rows + scores, the
//! StreamingLLM sink/window, ASVD's features — so a preempted compressed
//! sequence costs roughly its `kv_bytes()` (≈ 20% of the full-precision
//! footprint at 80% compression), not the materialized cache. Restoring
//! into a compatibly-configured policy reproduces the pre-snapshot state
//! **bit-identically**: every f32 round-trips through its exact LE byte
//! pattern and int4 groups round-trip their packed codes, so a preempted
//! generation resumes with the exact token stream of an unpreempted run
//! (`rust/tests/property_invariants.rs` holds the oracle; the engine
//! rebuilds `DecodeView`s from the restored policy through the existing
//! `sync_view` full-rebuild path).
//!
//! The same container carries coordinator-side backend snapshots (Rust
//! backend bookkeeping wrapping a policy snapshot; PJRT session buffers)
//! — see [`tags`] for the registry.
//!
//! **Integrity:** the encoded form ends with a CRC-32 of header +
//! payload (codec v2). Spilled blobs live on the most fault-exposed
//! path of the stack — disk I/O under preemption pressure — so
//! [`KvSnapshot::decode`] verifies the checksum before any payload
//! parsing: a blob corrupted at rest or in transit fails with a clean
//! `snapshot checksum mismatch` error, and the coordinator fails *only
//! that sequence* (`fail_swapped` + budget refund) instead of the round
//! (`rust/tests/chaos_serving.rs`).

use super::GrowMat;

/// Snapshot kind registry. Policy snapshots are nested verbatim inside
/// backend snapshots, so every kind shares one namespace.
pub mod tags {
    /// [`crate::kvcache::FullCache`]
    pub const FULL: u32 = 1;
    /// [`crate::kvcache::CskvCache`] (fp32 or int4 compressed branch)
    pub const CSKV: u32 = 2;
    /// [`crate::baselines::H2oCache`]
    pub const H2O: u32 = 3;
    /// [`crate::baselines::StreamingLlmCache`]
    pub const STREAMING: u32 = 4;
    /// [`crate::baselines::AsvdCache`]
    pub const ASVD: u32 = 5;
    /// [`crate::coordinator::RustSequenceBackend`] (wraps a policy snapshot)
    pub const RUST_BACKEND: u32 = 16;
    /// `PjrtFullSession` serialized buffers
    pub const PJRT_FULL: u32 = 17;
    /// `PjrtCskvSession` serialized buffers (compressed history + window)
    pub const PJRT_CSKV: u32 = 18;
    /// [`crate::kvcache::PrefixCache`] — the coordinator's shared-prefix
    /// radix trie (per-block activation payloads + LRU bookkeeping)
    pub const PREFIX: u32 = 19;
    /// [`crate::coordinator::DrainBundle`] — a drained coordinator's
    /// in-flight sequence manifest: per sequence, the request identity
    /// (prompt, `n_new`, tokens generated so far) plus a nested backend
    /// snapshot for sequences that were mid-decode. The self-describing
    /// + CRC-checked container is what makes cross-process live
    /// migration safe over a plain file handoff.
    pub const DRAIN: u32 = 20;
}

/// `"KVSN"` — guards against feeding arbitrary files to [`KvSnapshot::decode`].
const MAGIC: u32 = 0x4b56_534e;
/// Bump on any incompatible payload-layout change.
/// v2: a CRC-32 of header + payload is appended to the encoded form, so
/// a blob corrupted at rest (disk spill, bit rot, a buggy transport)
/// fails [`KvSnapshot::decode`] with a clean checksum error instead of
/// being fed to a policy `restore`.
const VERSION: u32 = 2;

/// Header (magic + version + tag) plus the trailing CRC-32.
const HEADER_BYTES: usize = 12;
const FOOTER_BYTES: usize = 4;

/// IEEE CRC-32 lookup table (reflected polynomial 0xEDB88320), built at
/// compile time — the checksum variant of zlib/PNG, chosen because it is
/// table-driven (4 ops/byte) and universally cross-checkable.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// Streaming CRC-32 register update. Start from `0xFFFF_FFFF`, feed
/// chunks in order, finalize with a bitwise NOT ([`crc32`] does all
/// three for the single-slice case).
fn crc32_update(mut state: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        state = CRC_TABLE[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

/// IEEE CRC-32 of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    !crc32_update(0xFFFF_FFFF, bytes)
}

/// A serialized KV state: a kind tag plus an opaque payload written with
/// [`SnapWriter`] and read back with [`SnapReader`].
#[derive(Clone, Debug)]
pub struct KvSnapshot {
    tag: u32,
    payload: Vec<u8>,
}

impl KvSnapshot {
    pub fn new(tag: u32, payload: Vec<u8>) -> Self {
        KvSnapshot { tag, payload }
    }

    pub fn tag(&self) -> u32 {
        self.tag
    }

    pub fn payload(&self) -> &[u8] {
        &self.payload
    }

    /// Cold-tier accounting: bytes this snapshot occupies when encoded.
    pub fn size_bytes(&self) -> usize {
        HEADER_BYTES + self.payload.len() + FOOTER_BYTES
    }

    /// Self-describing byte form (magic + version + tag + payload +
    /// CRC-32 of everything before it) — what the cold tier stores in
    /// memory or spills to disk.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.size_bytes());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.tag.to_le_bytes());
        out.extend_from_slice(&self.payload);
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    pub fn decode(bytes: &[u8]) -> anyhow::Result<KvSnapshot> {
        anyhow::ensure!(
            bytes.len() >= HEADER_BYTES + FOOTER_BYTES,
            "snapshot truncated: {} bytes",
            bytes.len()
        );
        let word = |i: usize| u32::from_le_bytes(bytes[i..i + 4].try_into().unwrap());
        anyhow::ensure!(word(0) == MAGIC, "bad snapshot magic {:#x}", word(0));
        anyhow::ensure!(word(4) == VERSION, "unsupported snapshot version {}", word(4));
        // Integrity before content: a blob corrupted anywhere (header,
        // tag, payload, or the checksum itself) is rejected here, never
        // handed to a policy restore.
        let body = bytes.len() - FOOTER_BYTES;
        let (stored, computed) = (word(body), crc32(&bytes[..body]));
        anyhow::ensure!(
            stored == computed,
            "snapshot checksum mismatch (stored {stored:#010x}, computed {computed:#010x}): \
             blob corrupted"
        );
        Ok(KvSnapshot {
            tag: word(8),
            payload: bytes[HEADER_BYTES..body].to_vec(),
        })
    }

    /// Tag check shared by every `restore` implementation.
    pub fn expect_tag(&self, tag: u32, who: &str) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.tag == tag,
            "{who}: snapshot kind mismatch (got tag {}, want {tag})",
            self.tag
        );
        Ok(())
    }
}

/// `"KVBK"` — a block run of a split snapshot, distinct from a whole
/// snapshot so a stray block file can never decode as one.
const BLOCK_MAGIC: u32 = 0x4b56_424b;

/// Block frame: magic + index + total + payload length, then the
/// payload, then a CRC-32 of everything before it.
const BLOCK_HEADER_BYTES: usize = 16;
const BLOCK_FOOTER_BYTES: usize = 4;

/// One independently storable run of a split snapshot.
///
/// The pager spills and promotes these instead of whole-sequence blobs:
/// a run is a contiguous byte range of the snapshot's **encoded** form
/// (`[KvSnapshot::encode]` output), framed with its own position
/// (`index` of `total`) and CRC-32 so corruption at rest is detected
/// per block, before reassembly. Because runs are byte ranges of the
/// canonical encoding, [`merge_blocks`] reproduces the original encoded
/// bytes exactly — bit-identical for every policy at every boundary —
/// and the merged form still carries the snapshot's own end-to-end CRC,
/// which [`KvSnapshot::decode`] re-verifies.
///
/// Since every policy payload stores each layer's rows in token order,
/// a run's byte offset fraction tracks the token-position fraction to
/// first order — which is what lets the pager map per-token attention
/// mass onto byte blocks for eviction scoring (see
/// `coordinator::pager`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SnapshotBlock {
    /// Position of this run within the split (0-based).
    pub index: usize,
    /// Number of runs the snapshot was split into.
    pub total: usize,
    /// Raw byte range of the encoded snapshot.
    pub payload: Vec<u8>,
}

impl SnapshotBlock {
    /// Bytes this block occupies in its at-rest encoded form.
    pub fn size_bytes(&self) -> usize {
        BLOCK_HEADER_BYTES + self.payload.len() + BLOCK_FOOTER_BYTES
    }

    /// Self-describing at-rest form (magic + index + total + length +
    /// payload + CRC-32) — what the warm tier holds and the disk tier
    /// stores one file per block.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.size_bytes());
        out.extend_from_slice(&BLOCK_MAGIC.to_le_bytes());
        out.extend_from_slice(&(self.index as u32).to_le_bytes());
        out.extend_from_slice(&(self.total as u32).to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.payload);
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    pub fn decode(bytes: &[u8]) -> anyhow::Result<SnapshotBlock> {
        anyhow::ensure!(
            bytes.len() >= BLOCK_HEADER_BYTES + BLOCK_FOOTER_BYTES,
            "snapshot block truncated: {} bytes",
            bytes.len()
        );
        let word = |i: usize| u32::from_le_bytes(bytes[i..i + 4].try_into().unwrap());
        anyhow::ensure!(word(0) == BLOCK_MAGIC, "bad snapshot block magic {:#x}", word(0));
        let body = bytes.len() - BLOCK_FOOTER_BYTES;
        let (stored, computed) = (word(body), crc32(&bytes[..body]));
        anyhow::ensure!(
            stored == computed,
            "snapshot block checksum mismatch (stored {stored:#010x}, computed {computed:#010x}): \
             block corrupted"
        );
        let (index, total, len) = (word(4) as usize, word(8) as usize, word(12) as usize);
        anyhow::ensure!(
            len == body - BLOCK_HEADER_BYTES,
            "snapshot block length prefix {len} != body {}",
            body - BLOCK_HEADER_BYTES
        );
        anyhow::ensure!(total > 0 && index < total, "snapshot block index {index} of {total}");
        Ok(SnapshotBlock {
            index,
            total,
            payload: bytes[BLOCK_HEADER_BYTES..body].to_vec(),
        })
    }
}

/// Split an encoded snapshot (the [`KvSnapshot::encode`] byte form) into
/// `ceil(len / block_bytes)` runs of at most `block_bytes` each. Every
/// byte lands in exactly one run, in order; `block_bytes` of 0 is
/// treated as 1.
pub fn split_blocks(encoded: &[u8], block_bytes: usize) -> Vec<SnapshotBlock> {
    let step = block_bytes.max(1);
    let total = encoded.len().div_ceil(step).max(1);
    (0..total)
        .map(|i| SnapshotBlock {
            index: i,
            total,
            payload: encoded[i * step..((i + 1) * step).min(encoded.len())].to_vec(),
        })
        .collect()
}

/// Reassemble the runs of one snapshot back into its encoded byte form.
/// Accepts the blocks in any order; verifies that exactly `total` runs
/// with contiguous indices 0..total are present (each exactly once) and
/// that they agree on `total`. The output is bit-identical to the
/// `encoded` slice that was split, so `KvSnapshot::decode` re-verifies
/// the snapshot's own CRC end to end.
pub fn merge_blocks(blocks: &[SnapshotBlock]) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(!blocks.is_empty(), "merge of zero snapshot blocks");
    let total = blocks[0].total;
    anyhow::ensure!(
        blocks.len() == total,
        "snapshot block set incomplete: {} of {total} runs",
        blocks.len()
    );
    let mut ordered: Vec<Option<&SnapshotBlock>> = vec![None; total];
    for b in blocks {
        anyhow::ensure!(
            b.total == total,
            "snapshot block run-count mismatch ({} vs {total})",
            b.total
        );
        anyhow::ensure!(b.index < total, "snapshot block index {} of {total}", b.index);
        anyhow::ensure!(
            ordered[b.index].replace(b).is_none(),
            "duplicate snapshot block index {}",
            b.index
        );
    }
    let mut out = Vec::with_capacity(blocks.iter().map(|b| b.payload.len()).sum());
    for slot in ordered {
        out.extend_from_slice(&slot.expect("all indices present").payload);
    }
    Ok(out)
}

/// Append-only payload writer. All integers are LE u64 (usize) / u32 /
/// u8; f32 slices are raw LE bits, so round-trips are bit-exact.
#[derive(Default)]
pub struct SnapWriter {
    buf: Vec<u8>,
}

impl SnapWriter {
    pub fn new() -> Self {
        SnapWriter::default()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn write_usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Length-prefixed raw bytes.
    pub fn u8s(&mut self, v: &[u8]) {
        self.write_usize(v.len());
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed f32 slice, exact LE bit patterns.
    pub fn f32s(&mut self, v: &[f32]) {
        self.write_usize(v.len());
        self.buf.reserve(v.len() * 4);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed usize slice.
    pub fn usizes(&mut self, v: &[usize]) {
        self.write_usize(v.len());
        self.buf.reserve(v.len() * 8);
        for &x in v {
            self.buf.extend_from_slice(&(x as u64).to_le_bytes());
        }
    }

    /// Embed another snapshot in its encoded form, written directly into
    /// this buffer (byte-identical to `u8s(&snap.encode())` without the
    /// intermediate allocation — snapshots nest on the preemption hot
    /// path, where the payload is the whole KV state).
    pub fn nested(&mut self, snap: &KvSnapshot) {
        self.write_usize(snap.size_bytes());
        self.buf.reserve(snap.size_bytes());
        let start = self.buf.len();
        self.buf.extend_from_slice(&MAGIC.to_le_bytes());
        self.buf.extend_from_slice(&VERSION.to_le_bytes());
        self.buf.extend_from_slice(&snap.tag().to_le_bytes());
        self.buf.extend_from_slice(snap.payload());
        // The CRC covers header + payload, computed in place over the
        // bytes just written — still no intermediate encode() allocation.
        let crc = crc32(&self.buf[start..]);
        self.buf.extend_from_slice(&crc.to_le_bytes());
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Sequential payload reader; every accessor validates bounds so corrupt
/// or truncated cold-tier data surfaces as an error, never a panic.
pub struct SnapReader<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> SnapReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        SnapReader { buf, off: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.off
                .checked_add(n)
                .is_some_and(|end| end <= self.buf.len()),
            "snapshot payload truncated at byte {} (need {n} more of {})",
            self.off,
            self.buf.len()
        );
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn read_usize(&mut self) -> anyhow::Result<usize> {
        Ok(self.u64()? as usize)
    }

    pub fn u8s(&mut self) -> anyhow::Result<Vec<u8>> {
        let n = self.read_usize()?;
        Ok(self.take(n)?.to_vec())
    }

    /// A length prefix is untrusted cold-tier data: reject values whose
    /// byte size overflows instead of panicking on the multiply.
    fn checked_len(n: usize, elem: usize) -> anyhow::Result<usize> {
        n.checked_mul(elem)
            .ok_or_else(|| anyhow::anyhow!("snapshot length prefix {n} overflows"))
    }

    pub fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.read_usize()?;
        let raw = self.take(Self::checked_len(n, 4)?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn usizes(&mut self) -> anyhow::Result<Vec<usize>> {
        let n = self.read_usize()?;
        let raw = self.take(Self::checked_len(n, 8)?)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect())
    }

    /// Read a snapshot embedded with [`SnapWriter::nested`], decoding
    /// straight from the underlying buffer (no intermediate copy).
    pub fn nested(&mut self) -> anyhow::Result<KvSnapshot> {
        let n = self.read_usize()?;
        let raw = self.take(n)?;
        KvSnapshot::decode(raw)
    }

    /// All bytes must be consumed — catches writer/reader drift.
    pub fn expect_end(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.off == self.buf.len(),
            "snapshot payload has {} trailing bytes",
            self.buf.len() - self.off
        );
        Ok(())
    }
}

/// Serialize a [`GrowMat`] (cols + data).
pub fn write_growmat(w: &mut SnapWriter, g: &GrowMat) {
    w.write_usize(g.cols);
    w.f32s(&g.data);
}

/// Deserialize a [`GrowMat`], validating the row shape.
pub fn read_growmat(r: &mut SnapReader<'_>) -> anyhow::Result<GrowMat> {
    let cols = r.read_usize()?;
    let data = r.f32s()?;
    anyhow::ensure!(
        cols == 0 || data.len() % cols == 0,
        "growmat data {} not divisible by cols {cols}",
        data.len()
    );
    anyhow::ensure!(cols > 0 || data.is_empty(), "growmat with 0 cols must be empty");
    Ok(GrowMat { cols, data })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_reader_roundtrip_all_kinds() {
        let mut w = SnapWriter::new();
        w.u8(7);
        w.u64(u64::MAX - 3);
        w.write_usize(42);
        w.u8s(&[1, 2, 3]);
        w.f32s(&[0.0, -0.0, f32::MIN_POSITIVE, 1.5e30, -7.25]);
        w.usizes(&[0, 9, usize::MAX]);
        let buf = w.finish();
        let mut r = SnapReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.read_usize().unwrap(), 42);
        assert_eq!(r.u8s().unwrap(), vec![1, 2, 3]);
        let f = r.f32s().unwrap();
        assert_eq!(f.len(), 5);
        // Bit-exact, including the sign of -0.0.
        assert_eq!(f[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(f[3], 1.5e30);
        assert_eq!(r.usizes().unwrap(), vec![0, 9, usize::MAX]);
        r.expect_end().unwrap();
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = SnapWriter::new();
        w.f32s(&[1.0, 2.0, 3.0]);
        let mut buf = w.finish();
        buf.truncate(buf.len() - 2);
        let mut r = SnapReader::new(&buf);
        assert!(r.f32s().is_err());
    }

    #[test]
    fn absurd_length_prefix_is_an_error_not_a_panic() {
        // A corrupt blob whose length prefix decodes near usize::MAX must
        // error through the checked paths, not overflow the multiply.
        let mut w = SnapWriter::new();
        w.u64(u64::MAX - 1);
        let buf = w.finish();
        assert!(SnapReader::new(&buf).f32s().is_err());
        assert!(SnapReader::new(&buf).usizes().is_err());
        assert!(SnapReader::new(&buf).u8s().is_err());
    }

    #[test]
    fn snapshot_encode_decode() {
        let snap = KvSnapshot::new(tags::CSKV, vec![9, 8, 7]);
        let bytes = snap.encode();
        assert_eq!(bytes.len(), snap.size_bytes());
        let back = KvSnapshot::decode(&bytes).unwrap();
        assert_eq!(back.tag(), tags::CSKV);
        assert_eq!(back.payload(), &[9, 8, 7]);
        back.expect_tag(tags::CSKV, "test").unwrap();
        assert!(back.expect_tag(tags::FULL, "test").is_err());
        assert!(KvSnapshot::decode(&bytes[..8]).is_err());
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(KvSnapshot::decode(&bad).is_err());
    }

    #[test]
    fn crc32_matches_reference_vectors() {
        // zlib/PNG reference values.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn checksum_rejects_any_single_byte_flip() {
        let snap = KvSnapshot::new(tags::H2O, (0..=255u8).collect());
        let bytes = snap.encode();
        assert!(KvSnapshot::decode(&bytes).is_ok());
        // Every offset — header, tag, payload, and the checksum itself.
        for off in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[off] ^= 0x10;
            let err = KvSnapshot::decode(&bad)
                .expect_err(&format!("flip at {off} must be rejected"));
            // Clean error, and flips past the header surface as checksum
            // mismatches specifically.
            if off >= 12 && off < bytes.len() - 4 {
                assert!(err.to_string().contains("checksum"), "offset {off}: {err:#}");
            }
        }
        // Truncation anywhere is still an error, not a silent short read.
        for cut in [0, 8, 15, bytes.len() - 1] {
            assert!(KvSnapshot::decode(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn nested_snapshot_roundtrip_matches_byte_form() {
        let inner = KvSnapshot::new(tags::ASVD, vec![5, 6, 7, 8]);
        // nested() is byte-identical to the u8s(encode()) form.
        let via_nested = {
            let mut w = SnapWriter::new();
            w.nested(&inner);
            w.finish()
        };
        let via_u8s = {
            let mut w = SnapWriter::new();
            w.u8s(&inner.encode());
            w.finish()
        };
        assert_eq!(via_nested, via_u8s);
        // And reads back through SnapReader::nested.
        let mut w = SnapWriter::new();
        w.write_usize(9);
        w.nested(&inner);
        let buf = w.finish();
        let mut r = SnapReader::new(&buf);
        assert_eq!(r.read_usize().unwrap(), 9);
        let back = r.nested().unwrap();
        r.expect_end().unwrap();
        assert_eq!(back.tag(), tags::ASVD);
        assert_eq!(back.payload(), inner.payload());
    }

    #[test]
    fn block_split_merge_bit_identical_at_every_boundary() {
        let snap = KvSnapshot::new(tags::CSKV, (0..=255u8).cycle().take(777).collect());
        let encoded = snap.encode();
        for block_bytes in [1, 2, 3, 7, 64, 100, encoded.len() - 1, encoded.len(), 10_000] {
            let blocks = split_blocks(&encoded, block_bytes);
            assert_eq!(blocks.len(), encoded.len().div_ceil(block_bytes.max(1)).max(1));
            // At-rest round-trip per block, then reassembly in shuffled order.
            let mut stored: Vec<SnapshotBlock> = blocks
                .iter()
                .map(|b| SnapshotBlock::decode(&b.encode()).unwrap())
                .collect();
            stored.reverse();
            let merged = merge_blocks(&stored).unwrap();
            assert_eq!(merged, encoded, "block_bytes={block_bytes}");
            let back = KvSnapshot::decode(&merged).unwrap();
            assert_eq!(back.tag(), snap.tag());
            assert_eq!(back.payload(), snap.payload());
        }
    }

    #[test]
    fn block_codec_rejects_corruption_and_bad_sets() {
        let snap = KvSnapshot::new(tags::H2O, (0..200u8).collect());
        let blocks = split_blocks(&snap.encode(), 64);
        assert!(blocks.len() >= 3);
        // Any single-byte flip in a block's at-rest form is rejected.
        let bytes = blocks[1].encode();
        for off in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[off] ^= 0x20;
            assert!(SnapshotBlock::decode(&bad).is_err(), "flip at {off}");
        }
        assert!(SnapshotBlock::decode(&bytes[..bytes.len() - 1]).is_err());
        // A block file is not a snapshot and vice versa.
        assert!(KvSnapshot::decode(&bytes).is_err());
        assert!(SnapshotBlock::decode(&snap.encode()).is_err());
        // Incomplete, duplicated, and cross-snapshot sets are rejected.
        assert!(merge_blocks(&blocks[..blocks.len() - 1]).is_err());
        let mut dup = blocks.clone();
        dup[0] = dup[1].clone();
        assert!(merge_blocks(&dup).is_err());
        let mut crossed = blocks.clone();
        crossed[2].total = 99;
        assert!(merge_blocks(&crossed).is_err());
        assert!(merge_blocks(&[]).is_err());
    }

    #[test]
    fn growmat_roundtrip() {
        let mut g = GrowMat::new(3);
        g.push_row(&[1.0, -2.5, 3.25]);
        g.push_row(&[0.0, 7.0, -0.0]);
        let mut w = SnapWriter::new();
        write_growmat(&mut w, &g);
        let buf = w.finish();
        let mut r = SnapReader::new(&buf);
        let back = read_growmat(&mut r).unwrap();
        assert_eq!(back.cols, 3);
        assert_eq!(back.data, g.data);
        r.expect_end().unwrap();
    }
}
