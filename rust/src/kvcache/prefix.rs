//! Shared-prefix KV reuse: a block-granular radix trie over token-ID
//! prefixes, owned by the serving coordinator.
//!
//! Serving traffic is dominated by shared system prompts and few-shot
//! headers: most requests open with the same tokens, yet every sequence
//! used to prefill its full prompt from scratch. The [`PrefixCache`]
//! prefills a shared prefix **once** and seeds every later sequence from
//! it, skipping the prefix's GEMM + attention work entirely (the TTFT
//! lever `bench_perf_prefix` measures).
//!
//! ## What a node stores — replay ingestion, not policy snapshots
//!
//! Each trie node covers one [`PREFILL_ROW_BLOCK`]-token block of a
//! prefix and stores, per layer, exactly that block's **prefill
//! activations**: the attention inputs `xnorm`, the pre-RoPE,
//! pre-replacement keys, the values (each `[BLOCK, d_model]`), plus the
//! block's row-tile H2O mass partial. A warm sequence re-ingests the
//! assembled full-prefix activations into its *own* policy
//! ([`crate::model::engine::Engine::prefill_batch_seeded`] calls
//! `ingest_prefill` / `observe_prefill_attn` with inputs bitwise equal to
//! a cold run's) while computing GEMMs and attention only for the
//! unshared suffix rows.
//!
//! The obvious-looking alternative — snapshotting each policy's *cache
//! state* at the prefix boundary via [`KvSnapshot`] and `restore`-ing it
//! copy-on-write into new sequences — is unsound for eviction policies:
//! `H2oCache::observe_prefill_attn` folds the mass and evicts
//! immediately, so its state after a `P`-token prefill has already
//! dropped rows that a longer prompt's prefill would have kept. No
//! stored state at `P` can reproduce the cold state at `T > P`. Storing
//! the raw activations and replaying ingestion is the only seeding that
//! is bitwise-cold for **every** policy — the property
//! `rust/tests/prefix_reuse.rs` pins across all policy variants and
//! thread counts. (The [`KvSnapshot`] codec still carries the trie
//! itself: [`PrefixCache::snapshot`] / [`PrefixCache::from_snapshot`]
//! round-trip the whole structure under [`tags::PREFIX`].)
//!
//! ## Why the mass partial makes the replay bitwise
//!
//! The streaming prefill folds per-tile H2O mass partials in ascending
//! tile order, and tile `t`'s partial is zero beyond row `32·(t+1)` and
//! a pure function of the token prefix `[0, 32·(t+1))`. Partials are
//! sums of probabilities, hence `≥ +0.0`, and `x + 0.0 == x` bitwise for
//! `x ≥ 0` — so refolding the stored per-block slabs in ascending order
//! (and skipping the zero tail each slab omits) reproduces the cold
//! fold's prefix exactly, and the warm kernel folds the suffix tiles on
//! top in the same order the cold kernel would have.
//!
//! ## Sharing, refcounts, eviction
//!
//! Nodes form a radix trie: two prompts sharing 256 tokens share the
//! first 8 nodes and their bytes are counted **once**. [`lookup`]
//! acquires a reference on the whole matched chain (released by
//! [`release`] after the seeded prefill has published back); eviction is
//! byte-budgeted LRU over unreferenced leaves only, so a node feeding an
//! in-flight admission can never be evicted mid-use — the refcount unit
//! tests pin this. The budget may be transiently exceeded when every
//! node is referenced; the next publish retries.
//!
//! [`lookup`]: PrefixCache::lookup
//! [`release`]: PrefixCache::release

use std::collections::HashMap;

use super::snapshot::{tags, SnapReader, SnapWriter};
use super::KvSnapshot;
use crate::model::engine::{PrefixSeed, SeededPrefill, PREFILL_ROW_BLOCK};
use crate::tensor::Mat;

/// One trie node: a [`PREFILL_ROW_BLOCK`]-token block of some prefix and
/// its per-layer activation payload.
struct Node {
    /// The block's tokens (`PREFILL_ROW_BLOCK` of them).
    block: Vec<usize>,
    /// 1-based: this node completes a prefix of `depth * BLOCK` tokens.
    depth: usize,
    parent: Option<usize>,
    children: HashMap<Vec<usize>, usize>,
    /// In-flight sequences holding this node (acquired chain-wide by
    /// `lookup`, dropped by `release`). A referenced node is unevictable.
    refs: usize,
    /// LRU clock stamp of the last lookup/publish touching this node.
    last_use: u64,
    /// Payload bytes (counted once, shared by every prefix through here).
    bytes: usize,
    /// Per layer: attention inputs `rmsnorm(x)` for this block, `[BLOCK, d]`.
    xnorm: Vec<Mat>,
    /// Per layer: pre-RoPE, pre-replacement keys `[BLOCK, d]`.
    k: Vec<Mat>,
    /// Per layer: values `[BLOCK, d]`.
    v: Vec<Mat>,
    /// Per layer: this block's row-tile H2O mass partial, entries
    /// `[0, depth * BLOCK)` (exactly zero beyond — omitted).
    mass: Vec<Vec<f32>>,
}

impl Node {
    fn payload_bytes(&self) -> usize {
        let mats: usize = self
            .xnorm
            .iter()
            .chain(&self.k)
            .chain(&self.v)
            .map(|m| m.data.len() * 4)
            .sum();
        let mass: usize = self.mass.iter().map(|m| m.len() * 4).sum();
        mats + mass + self.block.len() * 8
    }
}

/// Handle to an acquired prefix chain. Must be handed back via
/// [`PrefixCache::release`] once the seeded prefill has completed (or
/// failed) — the chain is pinned against eviction until then.
#[must_use = "release() the chain or its nodes stay pinned forever"]
pub struct PrefixRef {
    leaf: usize,
}

/// Cumulative counters, surfaced through the coordinator's `Metrics`.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefixStats {
    /// Lookups that matched at least one block.
    pub hits: u64,
    /// Lookups that matched nothing.
    pub misses: u64,
    /// Total payload bytes served from the trie across all hits (a
    /// 2-block hit on a node chain of 3 counts the 2 matched blocks).
    pub shared_bytes: u64,
    /// Nodes evicted by the LRU to stay under the byte budget.
    pub evictions: u64,
    /// Current resident payload bytes.
    pub resident_bytes: usize,
    /// Current node count.
    pub nodes: usize,
}

/// Coordinator-owned radix prefix cache. See the module docs for the
/// design; all methods are `&mut self` — the single worker thread owns
/// the cache, no interior locking.
pub struct PrefixCache {
    budget_bytes: usize,
    /// Arena: `None` slots are free (reused by the free list).
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    roots: HashMap<Vec<usize>, usize>,
    clock: u64,
    resident_bytes: usize,
    hits: u64,
    misses: u64,
    shared_bytes: u64,
    evictions: u64,
}

impl PrefixCache {
    /// An empty trie with an LRU byte budget for node payloads.
    pub fn new(budget_bytes: usize) -> Self {
        PrefixCache {
            budget_bytes,
            nodes: Vec::new(),
            free: Vec::new(),
            roots: HashMap::new(),
            clock: 0,
            resident_bytes: 0,
            hits: 0,
            misses: 0,
            shared_bytes: 0,
            evictions: 0,
        }
    }

    fn node(&self, id: usize) -> &Node {
        self.nodes[id].as_ref().expect("live node")
    }

    fn node_mut(&mut self, id: usize) -> &mut Node {
        self.nodes[id].as_mut().expect("live node")
    }

    fn alloc(&mut self, n: Node) -> usize {
        match self.free.pop() {
            Some(id) => {
                self.nodes[id] = Some(n);
                id
            }
            None => {
                self.nodes.push(Some(n));
                self.nodes.len() - 1
            }
        }
    }

    /// The matched node chain for `tokens`, longest first-to-last, capped
    /// at `(len - 1) / BLOCK` blocks so a seed always leaves at least one
    /// suffix row to prefill (logits need a computed row).
    fn walk(&self, tokens: &[usize]) -> Vec<usize> {
        let mut chain = Vec::new();
        if tokens.is_empty() {
            return chain;
        }
        let max_blocks = (tokens.len() - 1) / PREFILL_ROW_BLOCK;
        let mut map = &self.roots;
        for b in 0..max_blocks {
            let block = &tokens[b * PREFILL_ROW_BLOCK..(b + 1) * PREFILL_ROW_BLOCK];
            match map.get(block) {
                Some(&id) => {
                    chain.push(id);
                    map = &self.node(id).children;
                }
                None => break,
            }
        }
        chain
    }

    /// Longest cached prefix usable for `tokens`, in tokens (0 = none).
    /// Read-only: used by admission to price the unshared suffix without
    /// acquiring references or touching the LRU.
    pub fn peek(&self, tokens: &[usize]) -> usize {
        self.walk(tokens).len() * PREFILL_ROW_BLOCK
    }

    /// Longest-prefix match for `tokens`: assemble an owned [`PrefixSeed`]
    /// from the matched chain and pin the chain against eviction until
    /// [`release`](PrefixCache::release). Counts a hit or a miss.
    pub fn lookup(&mut self, tokens: &[usize]) -> Option<(PrefixSeed, PrefixRef)> {
        let chain = self.walk(tokens);
        if chain.is_empty() {
            self.misses += 1;
            return None;
        }
        self.clock += 1;
        let clock = self.clock;
        let p = chain.len() * PREFILL_ROW_BLOCK;
        let n_layers = self.node(chain[0]).xnorm.len();
        let d = self.node(chain[0]).xnorm[0].cols;
        let mut xnorm = Vec::with_capacity(n_layers);
        let mut k = Vec::with_capacity(n_layers);
        let mut v = Vec::with_capacity(n_layers);
        let mut mass = Vec::with_capacity(n_layers);
        for li in 0..n_layers {
            let mut xn = Mat::zeros(p, d);
            let mut km = Mat::zeros(p, d);
            let mut vm = Mat::zeros(p, d);
            // Ascending-tile refold of the stored slabs: bitwise equal to
            // the cold fold's prefix (module docs).
            let mut ms = vec![0.0f32; p];
            for (bi, &id) in chain.iter().enumerate() {
                let node = self.node(id);
                let lo = bi * PREFILL_ROW_BLOCK * d;
                let hi = lo + PREFILL_ROW_BLOCK * d;
                xn.data[lo..hi].copy_from_slice(&node.xnorm[li].data);
                km.data[lo..hi].copy_from_slice(&node.k[li].data);
                vm.data[lo..hi].copy_from_slice(&node.v[li].data);
                for (mj, &pj) in ms.iter_mut().zip(&node.mass[li]) {
                    *mj += pj;
                }
            }
            xnorm.push(xn);
            k.push(km);
            v.push(vm);
            mass.push(ms);
        }
        let mut served = 0usize;
        for &id in &chain {
            let n = self.node_mut(id);
            n.refs += 1;
            n.last_use = clock;
            served += n.bytes;
        }
        self.hits += 1;
        self.shared_bytes += served as u64;
        Some((
            PrefixSeed {
                len: p,
                xnorm,
                k,
                v,
                mass,
            },
            PrefixRef {
                leaf: *chain.last().expect("non-empty chain"),
            },
        ))
    }

    /// Drop the references acquired by the matching [`lookup`]. Call
    /// exactly once per returned [`PrefixRef`].
    ///
    /// [`lookup`]: PrefixCache::lookup
    pub fn release(&mut self, r: PrefixRef) {
        let mut cur = Some(r.leaf);
        while let Some(id) = cur {
            let n = self.node_mut(id);
            debug_assert!(n.refs > 0, "release without matching lookup");
            n.refs = n.refs.saturating_sub(1);
            cur = n.parent;
        }
    }

    /// Publish a completed prefill's prompt prefix back into the trie:
    /// walk the existing chain (touching its LRU stamps) and extend it
    /// with one node per newly-covered complete block, sliced from the
    /// seeded record's full-length per-layer mats and the captured
    /// per-tile mass slabs. Already-present blocks are deduplicated (node
    /// contents are a pure function of the token prefix). Evicts down to
    /// the byte budget afterwards.
    pub fn publish(&mut self, tokens: &[usize], sp: &SeededPrefill) {
        let blocks_total = tokens.len() / PREFILL_ROW_BLOCK;
        if blocks_total == 0 {
            return;
        }
        debug_assert_eq!(sp.start % PREFILL_ROW_BLOCK, 0);
        let first_captured = sp.start / PREFILL_ROW_BLOCK;
        self.clock += 1;
        let clock = self.clock;
        let mut parent: Option<usize> = None;
        for b in 0..blocks_total {
            let block = tokens[b * PREFILL_ROW_BLOCK..(b + 1) * PREFILL_ROW_BLOCK].to_vec();
            let map = match parent {
                Some(pid) => &self.node(pid).children,
                None => &self.roots,
            };
            if let Some(&id) = map.get(&block) {
                self.node_mut(id).last_use = clock;
                parent = Some(id);
                continue;
            }
            // New block: needs this run's captured tile. A gap can only
            // appear if the caller publishes against a trie that lost the
            // seed chain it prefilled from — stop extending, never guess.
            let Some(lt) = b.checked_sub(first_captured) else {
                return;
            };
            if lt >= sp.mass_tiles.len() {
                return;
            }
            let n_layers = sp.record.xnorms.len();
            let (lo, hi) = (b * PREFILL_ROW_BLOCK, (b + 1) * PREFILL_ROW_BLOCK);
            let mut node = Node {
                block: block.clone(),
                depth: b + 1,
                parent,
                children: HashMap::new(),
                refs: 0,
                last_use: clock,
                bytes: 0,
                xnorm: (0..n_layers).map(|li| sp.record.xnorms[li].rows_slice(lo, hi)).collect(),
                k: (0..n_layers).map(|li| sp.record.ks[li].rows_slice(lo, hi)).collect(),
                v: (0..n_layers).map(|li| sp.record.vs[li].rows_slice(lo, hi)).collect(),
                mass: sp.mass_tiles[lt].clone(),
            };
            debug_assert!(node.mass.iter().all(|m| m.len() == hi));
            node.bytes = node.payload_bytes();
            let bytes = node.bytes;
            let id = self.alloc(node);
            match parent {
                Some(pid) => {
                    self.node_mut(pid).children.insert(block, id);
                }
                None => {
                    self.roots.insert(block, id);
                }
            }
            self.resident_bytes += bytes;
            parent = Some(id);
        }
        self.evict_to_budget();
    }

    /// LRU eviction over unreferenced, childless nodes until the payload
    /// fits the budget (or nothing is evictable — transient overage).
    fn evict_to_budget(&mut self) {
        while self.resident_bytes > self.budget_bytes {
            let victim = self
                .nodes
                .iter()
                .enumerate()
                .filter_map(|(id, slot)| slot.as_ref().map(|n| (id, n)))
                .filter(|(_, n)| n.refs == 0 && n.children.is_empty())
                .min_by_key(|(_, n)| n.last_use)
                .map(|(id, _)| id);
            let Some(id) = victim else { return };
            self.evict(id);
        }
    }

    fn evict(&mut self, id: usize) {
        let n = self.nodes[id].take().expect("live node");
        debug_assert_eq!(n.refs, 0);
        debug_assert!(n.children.is_empty());
        match n.parent {
            Some(pid) => {
                self.node_mut(pid).children.remove(&n.block);
            }
            None => {
                self.roots.remove(&n.block);
            }
        }
        self.resident_bytes -= n.bytes;
        self.evictions += 1;
        self.free.push(id);
    }

    /// Live node count.
    pub fn node_count(&self) -> usize {
        self.nodes.iter().filter(|s| s.is_some()).count()
    }

    /// Current resident payload bytes.
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    pub fn stats(&self) -> PrefixStats {
        PrefixStats {
            hits: self.hits,
            misses: self.misses,
            shared_bytes: self.shared_bytes,
            evictions: self.evictions,
            resident_bytes: self.resident_bytes,
            nodes: self.node_count(),
        }
    }

    /// Serialize the whole trie (structure + payloads + LRU stamps; not
    /// the transient refcounts — snapshots are taken at rest) under
    /// [`tags::PREFIX`]. Bit-exact round-trip via
    /// [`PrefixCache::from_snapshot`].
    pub fn snapshot(&self) -> KvSnapshot {
        let mut w = SnapWriter::new();
        w.write_usize(self.budget_bytes);
        w.u64(self.clock);
        w.write_usize(self.roots.len());
        // Deterministic order: sort sibling keys (HashMap order is not).
        let mut roots: Vec<&Vec<usize>> = self.roots.keys().collect();
        roots.sort();
        for key in roots {
            self.write_subtree(&mut w, self.roots[key]);
        }
        KvSnapshot::new(tags::PREFIX, w.finish())
    }

    fn write_subtree(&self, w: &mut SnapWriter, id: usize) {
        let n = self.node(id);
        w.usizes(&n.block);
        w.u64(n.last_use);
        w.write_usize(n.xnorm.len());
        for li in 0..n.xnorm.len() {
            for m in [&n.xnorm[li], &n.k[li], &n.v[li]] {
                w.write_usize(m.rows);
                w.write_usize(m.cols);
                w.f32s(&m.data);
            }
            w.f32s(&n.mass[li]);
        }
        w.write_usize(n.children.len());
        let mut keys: Vec<&Vec<usize>> = n.children.keys().collect();
        keys.sort();
        for key in keys {
            self.write_subtree(w, n.children[key]);
        }
    }

    /// Rebuild a trie from a [`PrefixCache::snapshot`].
    pub fn from_snapshot(snap: &KvSnapshot) -> anyhow::Result<PrefixCache> {
        snap.expect_tag(tags::PREFIX, "prefix cache")?;
        let mut r = SnapReader::new(snap.payload());
        let budget_bytes = r.read_usize()?;
        let clock = r.u64()?;
        let n_roots = r.read_usize()?;
        let mut pc = PrefixCache::new(budget_bytes);
        pc.clock = clock;
        for _ in 0..n_roots {
            pc.read_subtree(&mut r, None, 1)?;
        }
        r.expect_end()?;
        Ok(pc)
    }

    fn read_subtree(
        &mut self,
        r: &mut SnapReader<'_>,
        parent: Option<usize>,
        depth: usize,
    ) -> anyhow::Result<()> {
        let block = r.usizes()?;
        anyhow::ensure!(
            block.len() == PREFILL_ROW_BLOCK,
            "prefix node block has {} tokens, want {PREFILL_ROW_BLOCK}",
            block.len()
        );
        let last_use = r.u64()?;
        let n_layers = r.read_usize()?;
        let mut read_mat = |r: &mut SnapReader<'_>| -> anyhow::Result<Mat> {
            let rows = r.read_usize()?;
            let cols = r.read_usize()?;
            let data = r.f32s()?;
            anyhow::ensure!(data.len() == rows * cols, "prefix node mat shape mismatch");
            Ok(Mat::from_vec(rows, cols, data))
        };
        let mut xnorm = Vec::with_capacity(n_layers);
        let mut k = Vec::with_capacity(n_layers);
        let mut v = Vec::with_capacity(n_layers);
        let mut mass = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            xnorm.push(read_mat(r)?);
            k.push(read_mat(r)?);
            v.push(read_mat(r)?);
            mass.push(r.f32s()?);
        }
        let n_children = r.read_usize()?;
        let mut node = Node {
            block: block.clone(),
            depth,
            parent,
            children: HashMap::new(),
            refs: 0,
            last_use,
            bytes: 0,
            xnorm,
            k,
            v,
            mass,
        };
        node.bytes = node.payload_bytes();
        let bytes = node.bytes;
        let id = self.alloc(node);
        match parent {
            Some(pid) => {
                self.node_mut(pid).children.insert(block, id);
            }
            None => {
                self.roots.insert(block, id);
            }
        }
        self.resident_bytes += bytes;
        for _ in 0..n_children {
            self.read_subtree(r, Some(id), depth + 1)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::engine::PrefillRecord;

    const B: usize = PREFILL_ROW_BLOCK;

    /// Fabricate a deterministic seeded-prefill capture for `tokens`
    /// (1 layer, d=4), distinct per token prefix so payload mismatches
    /// would be caught by the bitwise assertions.
    fn fake_capture(tokens: &[usize], start: usize) -> SeededPrefill {
        let t = tokens.len();
        let d = 4;
        let cell = |i: usize, j: usize| (tokens[i] as f32) + 0.25 * j as f32;
        let mut xn = Mat::zeros(t, d);
        let mut k = Mat::zeros(t, d);
        let mut v = Mat::zeros(t, d);
        for i in 0..t {
            for j in 0..d {
                xn.row_mut(i)[j] = cell(i, j);
                k.row_mut(i)[j] = cell(i, j) + 100.0;
                v.row_mut(i)[j] = cell(i, j) + 200.0;
            }
        }
        let mass: Vec<f32> = (0..t).map(|j| j as f32 * 0.5).collect();
        let n_suffix_complete = (t - start) / B;
        let mass_tiles: Vec<Vec<Vec<f32>>> = (0..n_suffix_complete)
            .map(|lt| {
                let at = start / B + lt;
                vec![(0..(at + 1) * B).map(|j| j as f32 * 0.125).collect()]
            })
            .collect();
        SeededPrefill {
            record: PrefillRecord {
                xnorms: vec![xn],
                ks: vec![k],
                vs: vec![v],
                attn_mass: vec![mass],
                logits: Mat::zeros(t - start, 3),
            },
            start,
            mass_tiles,
        }
    }

    fn toks(seed: usize, n: usize) -> Vec<usize> {
        (0..n).map(|i| (seed * 1000 + i * 7) % 97).collect()
    }

    #[test]
    fn publish_lookup_roundtrip_and_strict_prefix_cap() {
        let mut pc = PrefixCache::new(usize::MAX);
        let donor = toks(1, 3 * B);
        pc.publish(&donor, &fake_capture(&donor, 0));
        assert_eq!(pc.node_count(), 3);

        // Same prompt: the cap leaves ≥ 1 suffix row, so only 2 blocks.
        assert_eq!(pc.peek(&donor), 2 * B);
        // A longer prompt sharing the prefix gets all 3 blocks.
        let mut target = donor.clone();
        target.extend_from_slice(&toks(2, B));
        assert_eq!(pc.peek(&target), 3 * B);

        let (seed, r) = pc.lookup(&target).expect("hit");
        assert_eq!(seed.len, 3 * B);
        // Seed rows are the donor's rows, bitwise.
        let cap = fake_capture(&donor, 0);
        assert_eq!(seed.xnorm[0].data, cap.record.xnorms[0].data);
        assert_eq!(seed.k[0].data, cap.record.ks[0].data);
        assert_eq!(seed.v[0].data, cap.record.vs[0].data);
        // Refolded mass = ascending sum of the per-block slabs.
        let mut want = vec![0.0f32; 3 * B];
        for slab in &cap.mass_tiles {
            for (mj, &pj) in want.iter_mut().zip(&slab[0]) {
                *mj += pj;
            }
        }
        assert_eq!(seed.mass[0], want);
        pc.release(r);

        let s = pc.stats();
        assert_eq!((s.hits, s.misses), (1, 0));
        assert!(s.shared_bytes > 0);

        // Unrelated prompt: miss.
        assert!(pc.lookup(&toks(9, 2 * B)).is_none());
        assert_eq!(pc.stats().misses, 1);
    }

    #[test]
    fn radix_sharing_counts_shared_bytes_once() {
        let mut pc = PrefixCache::new(usize::MAX);
        let a = toks(1, 2 * B);
        pc.publish(&a, &fake_capture(&a, 0));
        let bytes_after_a = pc.resident_bytes();
        // b shares a's full 2-block prefix and adds one more block.
        let mut b = a.clone();
        b.extend_from_slice(&toks(3, B));
        pc.publish(&b, &fake_capture(&b, 0));
        assert_eq!(pc.node_count(), 3, "shared blocks deduplicated");
        assert!(pc.resident_bytes() > bytes_after_a);
        assert!(
            pc.resident_bytes() < 2 * bytes_after_a,
            "only the unshared block adds bytes"
        );
    }

    #[test]
    fn evicting_a_referenced_node_is_impossible() {
        let mut pc = PrefixCache::new(usize::MAX);
        let donor = toks(1, 2 * B);
        pc.publish(&donor, &fake_capture(&donor, 0));
        let mut target = donor.clone();
        target.extend_from_slice(&toks(2, B));
        let (_seed, r) = pc.lookup(&target).expect("hit");

        // Shrink the budget to zero: nothing may be evicted while the
        // chain is referenced.
        pc.budget_bytes = 0;
        pc.evict_to_budget();
        assert_eq!(pc.node_count(), 2, "referenced chain survives");
        assert_eq!(pc.stats().evictions, 0);

        // Released, the same pass clears the trie.
        pc.release(r);
        pc.evict_to_budget();
        assert_eq!(pc.node_count(), 0);
        assert_eq!(pc.stats().evictions, 2);
        assert_eq!(pc.resident_bytes(), 0);
    }

    #[test]
    fn lru_evicts_oldest_unreferenced_leaf_first() {
        let a = toks(1, B);
        let b = toks(2, B);
        let mut pc = PrefixCache::new(usize::MAX);
        pc.publish(&a, &fake_capture(&a, 0));
        pc.publish(&b, &fake_capture(&b, 0));
        // Touch `a` (needs > B tokens for a usable match).
        let mut a_long = a.clone();
        a_long.push(1);
        let (_s, r) = pc.lookup(&a_long).expect("hit");
        pc.release(r);
        // Budget forces one eviction: `b` is older by LRU.
        pc.budget_bytes = pc.resident_bytes() - 1;
        pc.evict_to_budget();
        assert_eq!(pc.node_count(), 1);
        assert_eq!(pc.peek(&a_long), B, "a survives");
        let mut b_long = b.clone();
        b_long.push(1);
        assert_eq!(pc.peek(&b_long), 0, "b evicted");
    }

    #[test]
    fn republish_is_idempotent() {
        let mut pc = PrefixCache::new(usize::MAX);
        let donor = toks(1, 2 * B);
        pc.publish(&donor, &fake_capture(&donor, 0));
        let bytes = pc.resident_bytes();
        pc.publish(&donor, &fake_capture(&donor, 0));
        assert_eq!(pc.node_count(), 2);
        assert_eq!(pc.resident_bytes(), bytes);
    }

    #[test]
    fn warm_publish_extends_existing_chain() {
        let mut pc = PrefixCache::new(usize::MAX);
        let donor = toks(1, 2 * B);
        pc.publish(&donor, &fake_capture(&donor, 0));
        // A warm run that matched the 2-block prefix publishes a 4-block
        // prompt with suffix-only capture (start = 2B).
        let mut target = donor.clone();
        target.extend_from_slice(&toks(4, 2 * B));
        pc.publish(&target, &fake_capture(&target, 2 * B));
        assert_eq!(pc.node_count(), 4);
        let mut longer = target.clone();
        longer.push(1);
        assert_eq!(pc.peek(&longer), 4 * B);
    }

    #[test]
    fn snapshot_roundtrip_preserves_seeds() {
        let mut pc = PrefixCache::new(1 << 20);
        let donor = toks(1, 3 * B);
        pc.publish(&donor, &fake_capture(&donor, 0));
        let mut other = toks(5, 2 * B);
        pc.publish(&other, &fake_capture(&other, 0));
        other.push(2);

        let snap = pc.snapshot();
        let mut back = PrefixCache::from_snapshot(&snap).expect("decode");
        assert_eq!(back.node_count(), pc.node_count());
        assert_eq!(back.resident_bytes(), pc.resident_bytes());

        let mut target = donor.clone();
        target.push(9);
        let (want, r1) = pc.lookup(&target).expect("hit");
        let (got, r2) = back.lookup(&target).expect("hit after restore");
        assert_eq!(got.len, want.len);
        for li in 0..want.xnorm.len() {
            assert_eq!(got.xnorm[li].data, want.xnorm[li].data);
            assert_eq!(got.k[li].data, want.k[li].data);
            assert_eq!(got.v[li].data, want.v[li].data);
            assert_eq!(got.mass[li], want.mass[li]);
        }
        let (got2, r3) = back.lookup(&other).expect("second tree survives");
        assert_eq!(got2.len, 2 * B);
        pc.release(r1);
        back.release(r2);
        back.release(r3);

        // Wrong tag is rejected.
        let bogus = KvSnapshot::new(tags::FULL, vec![]);
        assert!(PrefixCache::from_snapshot(&bogus).is_err());
    }
}
