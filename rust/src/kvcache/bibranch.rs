//! The paper's contribution: the **bi-branch channel-shrunk KV cache**
//! (§2.1, Figure 1).
//!
//! Two branches per layer:
//!
//! * **Compressed branch** — every token's `C_K = xnorm·A_K` and
//!   `C_V = xnorm·A_V` (`rank ≪ d_model` columns). Optionally int4
//!   group-quantized (KIVI-style) for the Table 5 integration.
//! * **Window branch** — the most recent `m` tokens' exact pre-RoPE K/V,
//!   preserving local information at full precision.
//!
//! Prefill attention is exact (the policy returns no replacement);
//! decode attention sees `[K̂ = C·B_K (historical) ∥ K_window]`, matching
//! Figure 1(b): the oldest `n − m` tokens come from the compressed cache,
//! the rest from the window.

use std::sync::Arc;

use crate::compress::quant::{quantize_block, QuantAxis, QuantizedBlock, GROUP};
use crate::compress::ModelFactors;
use crate::tensor::Mat;

use super::snapshot::{self, tags, KvSnapshot, SnapReader, SnapWriter};
use super::{CacheView, DecodeView, GrowMat, KvCachePolicy};

/// Quantization applied to the compressed branch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMode {
    /// fp32 compressed features (the paper's main configuration).
    None,
    /// KIVI-style int4: per-channel for keys, per-token for values,
    /// group size [`GROUP`], fp32 residual until a group fills.
    Int4,
}

/// Bi-branch cache configuration.
#[derive(Clone, Debug)]
pub struct CskvConfig {
    /// Full-precision window length `m` (the paper's default is 32).
    pub window: usize,
    pub quant: QuantMode,
}

impl Default for CskvConfig {
    fn default() -> Self {
        CskvConfig {
            window: 32,
            quant: QuantMode::None,
        }
    }
}

/// Compressed-feature storage: fp32 or int4 groups + fp32 residual.
struct CompressedStore {
    rank: usize,
    axis: QuantAxis,
    quant: QuantMode,
    groups: Vec<QuantizedBlock>,
    resid: GrowMat,
}

impl CompressedStore {
    fn new(rank: usize, axis: QuantAxis, quant: QuantMode) -> Self {
        CompressedStore {
            rank,
            axis,
            quant,
            groups: Vec::new(),
            resid: GrowMat::new(rank),
        }
    }

    fn len(&self) -> usize {
        self.groups.len() * GROUP + self.resid.rows()
    }

    fn push_row(&mut self, row: &[f32]) {
        self.resid.push_row(row);
        self.maybe_seal();
    }

    fn push_mat(&mut self, m: &Mat) {
        self.resid.push_mat(m);
        self.maybe_seal();
    }

    /// Seal filled groups into quantized blocks (int4 mode only).
    fn maybe_seal(&mut self) {
        if self.quant == QuantMode::None {
            return;
        }
        while self.resid.rows() >= GROUP {
            let block = self.resid.slice(0, GROUP);
            self.groups.push(quantize_block(&block, self.axis));
            // One drain of the whole group — the per-row `remove_row(0)`
            // loop this replaces drained the entire buffer GROUP times
            // (O(GROUP²·rank) per seal).
            self.resid.remove_rows(0, GROUP);
        }
    }

    /// Tokens stored in sealed (immutable) quantized groups.
    fn sealed_rows(&self) -> usize {
        self.groups.len() * GROUP
    }

    /// Materialize rows `[0, n)` as fp32 (dequantizing groups as needed).
    fn rows(&self, n: usize) -> Mat {
        self.rows_range(0, n)
    }

    /// Rows `[lo, hi)` as fp32 in a fresh matrix (cold paths; the decode
    /// hot loop goes through [`CompressedStore::rows_range_into`]).
    fn rows_range(&self, lo: usize, hi: usize) -> Mat {
        let mut out = Mat::zeros(0, 0);
        self.rows_range_into(lo, hi, &mut out);
        out
    }

    /// Rows `[lo, hi)` as fp32, dequantized/copied into a caller-owned
    /// grow-only matrix: `out` is reshaped in place and its backing `Vec`
    /// reallocates only past its high-water capacity — the zero-alloc
    /// decode-migration path. Every element of the range is written
    /// (groups cover `[0, sealed)`, the residual covers the rest), so no
    /// zero-fill is needed.
    fn rows_range_into(&self, lo: usize, hi: usize, out: &mut Mat) {
        assert!(lo <= hi && hi <= self.len());
        let c = self.rank;
        out.rows = hi - lo;
        out.cols = c;
        out.data.resize((hi - lo) * c, 0.0);
        for (gi, g) in self.groups.iter().enumerate() {
            let g0 = gi * GROUP;
            if g0 >= hi {
                break;
            }
            let g1 = g0 + GROUP;
            let s = lo.max(g0);
            let e = hi.min(g1);
            if s < e {
                g.dequantize_rows_into(s - g0, e - g0, &mut out.data[(s - lo) * c..(e - lo) * c]);
            }
        }
        let sealed = self.sealed_rows();
        if hi > sealed {
            let s = lo.max(sealed);
            out.data[(s - lo) * c..(hi - lo) * c]
                .copy_from_slice(&self.resid.data[(s - sealed) * c..(hi - sealed) * c]);
        }
    }

    /// Reserve storage for `additional` more tokens.
    fn reserve(&mut self, additional: usize) {
        match self.quant {
            QuantMode::None => self.resid.reserve_rows(additional),
            QuantMode::Int4 => {
                self.groups.reserve(additional / GROUP + 1);
                self.resid.reserve_rows(additional.min(2 * GROUP));
            }
        }
    }

    fn bytes(&self) -> usize {
        self.groups.iter().map(|g| g.bytes()).sum::<usize>() + self.resid.bytes()
    }

    /// Serialize in the compressed representation: sealed int4 groups as
    /// packed codes + affine params, the residual as raw fp32 features.
    fn write_snapshot(&self, w: &mut SnapWriter) {
        w.write_usize(self.groups.len());
        for g in &self.groups {
            w.write_usize(g.rows);
            w.write_usize(g.cols);
            w.u8s(g.packed());
            w.f32s(g.scale());
            w.f32s(g.zero());
        }
        snapshot::write_growmat(w, &self.resid);
    }

    /// Replace contents from a snapshot; `rank`, `axis` and `quant` stay
    /// as constructed (the reader validates against them).
    fn read_snapshot(&mut self, r: &mut SnapReader<'_>) -> anyhow::Result<()> {
        let n_groups = r.read_usize()?;
        anyhow::ensure!(
            n_groups == 0 || self.quant == QuantMode::Int4,
            "compressed store: sealed groups in a {:?} snapshot",
            self.quant
        );
        let mut groups = Vec::with_capacity(n_groups);
        for _ in 0..n_groups {
            let rows = r.read_usize()?;
            let cols = r.read_usize()?;
            anyhow::ensure!(
                rows == GROUP && cols == self.rank,
                "compressed store: group {rows}x{cols}, want {GROUP}x{}",
                self.rank
            );
            let packed = r.u8s()?;
            let scale = r.f32s()?;
            let zero = r.f32s()?;
            groups.push(QuantizedBlock::from_raw(rows, cols, self.axis, packed, scale, zero)?);
        }
        let resid = snapshot::read_growmat(r)?;
        anyhow::ensure!(
            resid.cols == self.rank,
            "compressed store: residual width {} != rank {}",
            resid.cols,
            self.rank
        );
        self.groups = groups;
        self.resid = resid;
        Ok(())
    }
}

struct LayerState {
    /// Total tokens represented.
    n: usize,
    ck: CompressedStore,
    cv: CompressedStore,
    win_k: GrowMat,
    win_v: GrowMat,
    win_pos: Vec<usize>,
}

/// Grow-only scratch for the decode hot path (`append` / `sync_view`):
/// compressed feature staging and K̂/V̂ reconstruction buffers, shared
/// across layers. Capacities hit their high-water mark on the first
/// post-prefill sync (the big history migration); steady-state decode
/// steps then allocate nothing (`rust/tests/decode_alloc.rs`).
struct SyncScratch {
    /// Compressed feature rows `[batch, rank]` (K and V in turn).
    c: Mat,
    /// Reconstructed `K̂ = C·B_K` rows `[batch, d_model]`.
    kh: Mat,
    /// Reconstructed `V̂ = C·B_V` rows `[batch, d_model]`.
    vh: Mat,
    /// Single-token compressed K feature (append path).
    ck_row: Vec<f32>,
    /// Single-token compressed V feature (append path).
    cv_row: Vec<f32>,
}

impl SyncScratch {
    fn new() -> Self {
        SyncScratch {
            c: Mat::zeros(0, 0),
            kh: Mat::zeros(0, 0),
            vh: Mat::zeros(0, 0),
            ck_row: Vec::new(),
            cv_row: Vec::new(),
        }
    }
}

/// Resize a scratch matrix in place: logical dimensions change, but the
/// backing `Vec` only reallocates past its high-water capacity.
fn resize_mat(m: &mut Mat, rows: usize, cols: usize) {
    m.rows = rows;
    m.cols = cols;
    m.data.resize(rows * cols, 0.0);
}

/// The CSKV bi-branch cache policy.
pub struct CskvCache {
    cfg: CskvConfig,
    factors: Arc<ModelFactors>,
    layers: Vec<LayerState>,
    scratch: SyncScratch,
    label: String,
}

impl CskvCache {
    pub fn new(factors: Arc<ModelFactors>, d_model: usize, cfg: CskvConfig) -> Self {
        let layers = factors
            .layers
            .iter()
            .map(|lf| LayerState {
                n: 0,
                ck: CompressedStore::new(lf.k.rank(), QuantAxis::PerChannel, cfg.quant),
                cv: CompressedStore::new(lf.v.rank(), QuantAxis::PerToken, cfg.quant),
                win_k: GrowMat::new(d_model),
                win_v: GrowMat::new(d_model),
                win_pos: Vec::new(),
            })
            .collect();
        let label = format!(
            "cskv(w={},r_k={},r_v={}{})",
            cfg.window,
            factors.rank_k(),
            factors.rank_v(),
            if cfg.quant == QuantMode::Int4 { ",int4" } else { "" }
        );
        CskvCache {
            cfg,
            factors,
            layers,
            scratch: SyncScratch::new(),
            label,
        }
    }

    fn push_window(&mut self, layer: usize, k: &[f32], v: &[f32], pos: usize) {
        let l = &mut self.layers[layer];
        l.win_k.push_row(k);
        l.win_v.push_row(v);
        l.win_pos.push(pos);
        // "we remove the oldest token from the full-precision cache to keep
        // the window size as m" — §2.1.
        while l.win_pos.len() > self.cfg.window {
            l.win_k.remove_row(0);
            l.win_v.remove_row(0);
            l.win_pos.remove(0);
        }
    }
}

impl KvCachePolicy for CskvCache {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn ingest_prefill(&mut self, layer: usize, xnorm: &Mat, k: &Mat, v: &Mat) -> Option<(Mat, Mat)> {
        let t = xnorm.rows;
        {
            let lf = &self.factors.layers[layer];
            let ck = lf.k.compress(xnorm);
            let cv = lf.v.compress(xnorm);
            let l = &mut self.layers[layer];
            l.ck.push_mat(&ck);
            l.cv.push_mat(&cv);
            l.n = t;
        }
        // Window branch: the last m tokens at full precision.
        let w0 = t.saturating_sub(self.cfg.window);
        for i in w0..t {
            let (krow, vrow) = (k.row(i).to_vec(), v.row(i).to_vec());
            self.push_window(layer, &krow, &vrow, i);
        }
        None // prefill attention stays exact
    }

    fn append(&mut self, layer: usize, xnorm: &[f32], k: &[f32], v: &[f32]) {
        // Compress into the reusable scratch rows — the steady-state
        // append performs no allocation (seal events excepted).
        {
            let lf = &self.factors.layers[layer];
            let s = &mut self.scratch;
            s.ck_row.resize(lf.k.rank(), 0.0);
            lf.k.compress_row_into(xnorm, &mut s.ck_row);
            s.cv_row.resize(lf.v.rank(), 0.0);
            lf.v.compress_row_into(xnorm, &mut s.cv_row);
        }
        let pos = {
            let l = &mut self.layers[layer];
            l.ck.push_row(&self.scratch.ck_row);
            l.cv.push_row(&self.scratch.cv_row);
            let pos = l.n;
            l.n += 1;
            pos
        };
        self.push_window(layer, k, v, pos);
    }

    fn sync_view(&mut self, layer: usize, view: &mut DecodeView) {
        let quant = self.cfg.quant;
        let scratch = &mut self.scratch;
        let l = &self.layers[layer];
        let lf = &self.factors.layers[layer];
        let n = l.n;
        let win_len = l.win_pos.len();
        let hist = n - win_len;
        let sealed = l.ck.sealed_rows();

        // Safety for views that are ahead of this policy (fresh views are
        // behind and need no truncation; CSKV itself never shrinks).
        view.truncate(n);

        // Rows [0, valid_hist) already hold the final reconstruction.
        let mut valid_hist = view.hist_rows.min(hist).min(view.len());
        if view.epoch != sealed {
            // Groups sealed since this view last synced: residual-derived
            // rows now dequantize differently — drop back to the
            // sealed-stable prefix recorded at the previous sync.
            valid_hist = valid_hist.min(view.stable_rows);
        }

        // 1. Int4: advance the view's quantized segment over every fully
        //    sealed GROUP of history rows. The blocks are derived only
        //    from immutable sealed storage (reconstruct → RoPE →
        //    re-quantize inside `seal_group`), so a live view and a fresh
        //    rebuild produce identical bits; decode attention then reads
        //    them through the fused int4 GEMV kernels instead of f32 rows.
        if quant == QuantMode::Int4 {
            let quant_target = (hist.min(sealed) / GROUP) * GROUP;
            while view.quant_rows() < quant_target {
                let g0 = view.quant_rows();
                l.ck.rows_range_into(g0, g0 + GROUP, &mut scratch.c);
                resize_mat(&mut scratch.kh, GROUP, lf.k.d_out());
                lf.k.reconstruct_into(&scratch.c, &mut scratch.kh);
                l.cv.rows_range_into(g0, g0 + GROUP, &mut scratch.c);
                resize_mat(&mut scratch.vh, GROUP, lf.v.d_out());
                lf.v.reconstruct_into(&scratch.c, &mut scratch.vh);
                view.seal_group(&scratch.kh, &scratch.vh);
            }
            valid_hist = valid_hist.max(view.quant_rows());
        }

        // 2. (Re)write f32 history rows [valid_hist, hist): K̂ = C·B,
        //    RoPE'd at their absolute positions. Batched so the first
        //    sync after prefill is a single GEMM; in steady state this is
        //    the one token migrating out of the window. All staging goes
        //    through the grow-only scratch — no steady-state allocation.
        if hist > valid_hist {
            let batch = hist - valid_hist;
            l.ck.rows_range_into(valid_hist, hist, &mut scratch.c);
            resize_mat(&mut scratch.kh, batch, lf.k.d_out());
            lf.k.reconstruct_into(&scratch.c, &mut scratch.kh);
            l.cv.rows_range_into(valid_hist, hist, &mut scratch.c);
            resize_mat(&mut scratch.vh, batch, lf.v.d_out());
            lf.v.reconstruct_into(&scratch.c, &mut scratch.vh);
            for (j, r) in (valid_hist..hist).enumerate() {
                view.write_row(r, scratch.kh.row(j), scratch.vh.row(j), r, r);
            }
        }

        // 3. Window rows [hist, n): row t ↔ token t, exact pre-RoPE K/V
        //    from the window branch. A row already present was written
        //    from the same token's immutable window entry — skip it; only
        //    genuinely new tokens are appended.
        for t in view.len().max(hist)..n {
            let wi = t - hist;
            view.write_row(t, l.win_k.row(wi), l.win_v.row(wi), t, t);
        }

        view.hist_rows = hist;
        view.stable_rows = match self.cfg.quant {
            QuantMode::None => hist,
            QuantMode::Int4 => hist.min(sealed),
        };
        view.epoch = sealed;
    }

    fn materialize(&self, layer: usize) -> CacheView {
        let l = &self.layers[layer];
        let lf = &self.factors.layers[layer];
        let win_len = l.win_pos.len();
        let hist = l.n - win_len;
        let (mut kk, mut vv) = (Mat::zeros(0, l.win_k.cols), Mat::zeros(0, l.win_v.cols));
        if hist > 0 {
            kk = lf.k.reconstruct(&l.ck.rows(hist));
            vv = lf.v.reconstruct(&l.cv.rows(hist));
        }
        let k = kk.vcat(&l.win_k.to_mat());
        let v = vv.vcat(&l.win_v.to_mat());
        let mut pos: Vec<usize> = (0..hist).collect();
        pos.extend_from_slice(&l.win_pos);
        CacheView {
            k,
            v,
            rope_pos: pos.clone(),
            abs_pos: pos,
        }
    }

    fn reserve(&mut self, additional_tokens: usize) {
        for l in &mut self.layers {
            l.ck.reserve(additional_tokens);
            l.cv.reserve(additional_tokens);
        }
    }

    fn len(&self, layer: usize) -> usize {
        self.layers[layer].n
    }

    fn kv_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.ck.bytes() + l.cv.bytes() + l.win_k.bytes() + l.win_v.bytes())
            .sum()
    }

    fn kv_bytes_projected(&self, tokens: usize) -> usize {
        // Every token stores compressed features; the last ≤ window also
        // keep exact K/V. fp32 feature accounting — an upper bound for
        // int4 mode, keeping admission conservative.
        let win = tokens.min(self.cfg.window);
        self.layers
            .iter()
            .map(|l| {
                4 * (tokens * (l.ck.rank + l.cv.rank) + win * (l.win_k.cols + l.win_v.cols))
            })
            .sum()
    }

    fn snapshot(&self) -> KvSnapshot {
        let mut w = SnapWriter::new();
        w.write_usize(self.cfg.window);
        w.u8(match self.cfg.quant {
            QuantMode::None => 0,
            QuantMode::Int4 => 1,
        });
        w.write_usize(self.layers.len());
        for l in &self.layers {
            w.write_usize(l.n);
            l.ck.write_snapshot(&mut w);
            l.cv.write_snapshot(&mut w);
            snapshot::write_growmat(&mut w, &l.win_k);
            snapshot::write_growmat(&mut w, &l.win_v);
            w.usizes(&l.win_pos);
        }
        KvSnapshot::new(tags::CSKV, w.finish())
    }

    fn restore(&mut self, snap: &KvSnapshot) -> anyhow::Result<()> {
        snap.expect_tag(tags::CSKV, "cskv cache")?;
        let mut r = SnapReader::new(snap.payload());
        let window = r.read_usize()?;
        let quant = r.u8()?;
        let want_quant = match self.cfg.quant {
            QuantMode::None => 0u8,
            QuantMode::Int4 => 1,
        };
        anyhow::ensure!(
            window == self.cfg.window && quant == want_quant,
            "cskv cache: snapshot config (w={window}, quant={quant}) != target (w={}, quant={want_quant})",
            self.cfg.window
        );
        let n_layers = r.read_usize()?;
        anyhow::ensure!(
            n_layers == self.layers.len(),
            "cskv cache: snapshot has {n_layers} layers, target {}",
            self.layers.len()
        );
        for l in &mut self.layers {
            let n = r.read_usize()?;
            l.ck.read_snapshot(&mut r)?;
            l.cv.read_snapshot(&mut r)?;
            let win_k = snapshot::read_growmat(&mut r)?;
            let win_v = snapshot::read_growmat(&mut r)?;
            let win_pos = r.usizes()?;
            anyhow::ensure!(
                win_k.cols == l.win_k.cols
                    && win_v.cols == l.win_v.cols
                    && win_k.rows() == win_pos.len()
                    && win_v.rows() == win_pos.len()
                    && win_pos.len() <= self.cfg.window
                    && l.ck.len() == n
                    && l.cv.len() == n,
                "cskv cache: inconsistent layer snapshot (n={n}, window rows={}, features={})",
                win_pos.len(),
                l.ck.len()
            );
            l.n = n;
            l.win_k = win_k;
            l.win_v = win_v;
            l.win_pos = win_pos;
        }
        r.expect_end()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{LayerFactors, LowRankFactors};
    use crate::util::prng::Pcg64;

    fn identity_factors(d: usize, layers: usize) -> Arc<ModelFactors> {
        // Full-rank factors A=I, B=I: compression is lossless, which lets
        // tests check the bi-branch bookkeeping independently of rank loss.
        let lf = || LayerFactors {
            k: LowRankFactors::new(Mat::eye(d), Mat::eye(d)),
            v: LowRankFactors::new(Mat::eye(d), Mat::eye(d)),
        };
        Arc::new(ModelFactors {
            layers: (0..layers).map(|_| lf()).collect(),
            provenance: "identity".into(),
        })
    }

    fn lowrank_factors(d: usize, r: usize, layers: usize, seed: u64) -> Arc<ModelFactors> {
        let mut rng = Pcg64::new(seed);
        let mut mk =
            move || LowRankFactors::new(Mat::randn(d, r, 0.3, &mut rng), Mat::randn(r, d, 0.3, &mut rng));
        Arc::new(ModelFactors {
            layers: (0..layers)
                .map(|_| LayerFactors { k: mk(), v: mk() })
                .collect(),
            provenance: "random-lowrank".into(),
        })
    }

    #[test]
    fn bibranch_split_matches_paper_figure1() {
        // n = 10 tokens prefilled, window m = 4 ⇒ 6 historical + 4 window.
        let d = 8;
        let f = identity_factors(d, 1);
        let mut c = CskvCache::new(f, d, CskvConfig { window: 4, quant: QuantMode::None });
        let mut rng = Pcg64::new(1);
        let x = Mat::randn(10, d, 1.0, &mut rng);
        let k = Mat::randn(10, d, 1.0, &mut rng);
        let v = Mat::randn(10, d, 1.0, &mut rng);
        assert!(c.ingest_prefill(0, &x, &k, &v).is_none());
        let view = c.materialize(0);
        view.validate();
        assert_eq!(view.len(), 10);
        assert_eq!(view.rope_pos, (0..10).collect::<Vec<_>>());
        // Window rows are the exact keys; historical rows are X·A·B = X
        // (identity factors) — i.e. the *pre-projection* activations here,
        // deliberately different from k so the branches are diagnosable.
        for i in 6..10 {
            assert_eq!(view.k.row(i), k.row(i), "window row {i} must be exact");
        }
        for i in 0..6 {
            assert!(view
                .k
                .row(i)
                .iter()
                .zip(x.row(i))
                .all(|(a, b)| (a - b).abs() < 1e-5));
        }
    }

    #[test]
    fn decode_keeps_window_size_constant() {
        let d = 8;
        let f = identity_factors(d, 2);
        let mut c = CskvCache::new(f, d, CskvConfig { window: 3, quant: QuantMode::None });
        let mut rng = Pcg64::new(2);
        let x = Mat::randn(5, d, 1.0, &mut rng);
        let k = Mat::randn(5, d, 1.0, &mut rng);
        let v = Mat::randn(5, d, 1.0, &mut rng);
        for layer in 0..2 {
            c.ingest_prefill(layer, &x, &k, &v);
        }
        for step in 0..7 {
            let row: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            for layer in 0..2 {
                c.append(layer, &row, &row, &row);
            }
            let view = c.materialize(0);
            assert_eq!(view.len(), 5 + step + 1, "total tokens grow");
            assert_eq!(c.layers[0].win_pos.len(), 3, "window stays m");
            // Window always holds the newest positions.
            let n = c.len(0);
            assert_eq!(c.layers[0].win_pos, vec![n - 3, n - 2, n - 1]);
        }
    }

    #[test]
    fn memory_shrinks_vs_full() {
        let d = 32;
        let r = 6; // ~80% compression
        let f = lowrank_factors(d, r, 2, 3);
        let mut c = CskvCache::new(f, d, CskvConfig { window: 4, quant: QuantMode::None });
        let mut rng = Pcg64::new(4);
        let t = 64;
        let x = Mat::randn(t, d, 1.0, &mut rng);
        let k = Mat::randn(t, d, 1.0, &mut rng);
        let v = Mat::randn(t, d, 1.0, &mut rng);
        for layer in 0..2 {
            c.ingest_prefill(layer, &x, &k, &v);
        }
        let full_bytes = 2 * 2 * t * d * 4;
        let got = c.kv_bytes();
        // compressed ≈ 2 layers × 2 caches × t×r×4 + window overhead
        let expect = 2 * 2 * t * r * 4 + 2 * 2 * 4 * d * 4;
        assert_eq!(got, expect);
        assert!(got * 3 < full_bytes, "should be ≳3× smaller: {got} vs {full_bytes}");
    }

    #[test]
    fn int4_groups_seal_and_reduce_memory() {
        let d = 16;
        let f = identity_factors(d, 1);
        let mut c = CskvCache::new(f.clone(), d, CskvConfig { window: 2, quant: QuantMode::Int4 });
        let mut rng = Pcg64::new(5);
        let t = GROUP * 2 + 7; // 2 sealed groups + residual
        let x = Mat::randn(t, d, 1.0, &mut rng);
        let k = Mat::randn(t, d, 1.0, &mut rng);
        let v = Mat::randn(t, d, 1.0, &mut rng);
        c.ingest_prefill(0, &x, &k, &v);
        assert_eq!(c.layers[0].ck.groups.len(), 2);
        assert_eq!(c.layers[0].ck.resid.rows(), 7);
        assert_eq!(c.layers[0].ck.len(), t);
        // fp32 equivalent store
        let mut cf = CskvCache::new(f, d, CskvConfig { window: 2, quant: QuantMode::None });
        cf.ingest_prefill(0, &x, &k, &v);
        assert!(c.kv_bytes() * 3 < cf.kv_bytes(), "{} vs {}", c.kv_bytes(), cf.kv_bytes());
        // Materialized history approximates the fp32 one.
        let vq = c.materialize(0);
        let vf = cf.materialize(0);
        assert_eq!(vq.len(), vf.len());
        let err = vq.k.max_abs_diff(&vf.k);
        assert!(err < 0.5, "int4 error too large: {err}");
    }

    #[test]
    fn append_then_materialize_reconstructs_lowrank() {
        let d = 12;
        let r = 4;
        let f = lowrank_factors(d, r, 1, 6);
        let mut c = CskvCache::new(f.clone(), d, CskvConfig { window: 2, quant: QuantMode::None });
        let mut rng = Pcg64::new(7);
        let x = Mat::randn(6, d, 1.0, &mut rng);
        let k = Mat::randn(6, d, 1.0, &mut rng);
        let v = Mat::randn(6, d, 1.0, &mut rng);
        c.ingest_prefill(0, &x, &k, &v);
        let view = c.materialize(0);
        // historical rows = X·A_k·B_k
        let expect = f.layers[0].k.reconstruct(&f.layers[0].k.compress(&x));
        for i in 0..4 {
            assert!(view
                .k
                .row(i)
                .iter()
                .zip(expect.row(i))
                .all(|(a, b)| (a - b).abs() < 1e-4));
        }
    }

    #[test]
    fn sync_view_incremental_matches_fresh_across_seals() {
        let d = 16;
        for quant in [QuantMode::None, QuantMode::Int4] {
            let f = lowrank_factors(d, 4, 1, 9);
            let mut c = CskvCache::new(f, d, CskvConfig { window: 3, quant });
            let mut rng = Pcg64::new(10);
            let t = GROUP + 5;
            let x = Mat::randn(t, d, 1.0, &mut rng);
            let k = Mat::randn(t, d, 1.0, &mut rng);
            let v = Mat::randn(t, d, 1.0, &mut rng);
            c.ingest_prefill(0, &x, &k, &v);
            let mut live = DecodeView::new(d, 2, 10000.0);
            c.sync_view(0, &mut live);
            // Drive across a seal boundary, syncing the live view every
            // step like the engine does.
            for _ in 0..(GROUP + 9) {
                let row: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                c.append(0, &row, &row, &row);
                c.sync_view(0, &mut live);
                live.validate();
            }
            // A fresh view rebuilt from scratch must match bit-for-bit —
            // including the quantized segment (same_contents compares the
            // sealed blocks too).
            let mut fresh = DecodeView::new(d, 2, 10000.0);
            c.sync_view(0, &mut fresh);
            assert!(live.same_contents(&fresh), "quant={quant:?}");
            assert_eq!(live.len(), c.len(0));
            match quant {
                QuantMode::None => assert_eq!(live.quant_rows(), 0),
                QuantMode::Int4 => {
                    // n = 2·GROUP + 14, window 3 ⇒ hist ≥ 2·GROUP sealed
                    // rows, all covered by the view's quantized segment.
                    assert_eq!(live.quant_rows(), 2 * GROUP, "sealed spans must quantize");
                }
            }
        }
    }

    #[test]
    fn snapshot_roundtrip_bit_exact_across_quant_modes() {
        let d = 16;
        for quant in [QuantMode::None, QuantMode::Int4] {
            let f = lowrank_factors(d, 4, 2, 11);
            let mut c = CskvCache::new(Arc::clone(&f), d, CskvConfig { window: 3, quant });
            let mut rng = Pcg64::new(12);
            // GROUP + 7 tokens: one sealed group + mid-group residual, and
            // the window is mid-migration (rolling every append).
            let t = GROUP + 7;
            let x = Mat::randn(t, d, 1.0, &mut rng);
            let k = Mat::randn(t, d, 1.0, &mut rng);
            let v = Mat::randn(t, d, 1.0, &mut rng);
            for layer in 0..2 {
                c.ingest_prefill(layer, &x, &k, &v);
            }
            for _ in 0..5 {
                let row: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                for layer in 0..2 {
                    c.append(layer, &row, &row, &row);
                }
            }
            let snap = c.snapshot();
            // Compressed snapshot: ≈ kv_bytes, far below the full cache.
            assert!(snap.size_bytes() < c.kv_bytes() * 2);
            let mut fresh = CskvCache::new(Arc::clone(&f), d, CskvConfig { window: 3, quant });
            fresh.restore(&snap).unwrap();
            for layer in 0..2 {
                assert_eq!(fresh.len(layer), c.len(layer));
                let (a, b) = (c.materialize(layer), fresh.materialize(layer));
                assert_eq!(a.k.data, b.k.data, "quant={quant:?}");
                assert_eq!(a.v.data, b.v.data);
                assert_eq!(a.rope_pos, b.rope_pos);
                // Synced views rebuild bit-identically from the restored
                // state (the engine's restore path).
                let mut va = DecodeView::new(d, 2, 10000.0);
                let mut vb = DecodeView::new(d, 2, 10000.0);
                c.sync_view(layer, &mut va);
                fresh.sync_view(layer, &mut vb);
                assert!(va.same_contents(&vb));
            }
            assert_eq!(fresh.kv_bytes(), c.kv_bytes());
            // Mismatched target config errors.
            let mut wrong = CskvCache::new(Arc::clone(&f), d, CskvConfig { window: 4, quant });
            assert!(wrong.restore(&snap).is_err());
        }
    }

    #[test]
    fn window_zero_behaves_like_pure_compression() {
        let d = 8;
        let f = identity_factors(d, 1);
        let mut c = CskvCache::new(f, d, CskvConfig { window: 0, quant: QuantMode::None });
        let mut rng = Pcg64::new(8);
        let x = Mat::randn(4, d, 1.0, &mut rng);
        let k = Mat::randn(4, d, 1.0, &mut rng);
        let v = Mat::randn(4, d, 1.0, &mut rng);
        c.ingest_prefill(0, &x, &k, &v);
        let view = c.materialize(0);
        assert_eq!(view.len(), 4);
        assert_eq!(c.layers[0].win_pos.len(), 0);
    }
}
