//! Analytic KV-cache memory model — reproduces the paper's intro claim
//! (LLaMA-2-7B @ 200K tokens ⇒ ~100GB KV cache) and feeds `bench_memory`.
//!
//! Two accountings are provided: the *analytic* model for arbitrary
//! (LLM-scale) configurations, and the *measured* accounting that
//! [`super::KvCachePolicy::kv_bytes`] reports for our runnable models —
//! the bench cross-checks one against the other.

/// Architecture description for analytic accounting (covers models we
/// cannot run, like LLaMA-2-7B, for the intro-claim reproduction).
#[derive(Clone, Debug)]
pub struct ArchSpec {
    pub name: String,
    pub n_layers: usize,
    /// KV hidden width per layer (n_kv_heads × d_head).
    pub kv_dim: usize,
    /// Bytes per stored element (2 = fp16, 4 = fp32).
    pub elem_bytes: usize,
    /// Total parameter count (for the weights-vs-cache comparison).
    pub n_params: usize,
}

impl ArchSpec {
    /// LLaMA-2-7B in fp16 — the paper's intro example.
    pub fn llama2_7b() -> Self {
        ArchSpec {
            name: "LLaMA-2-7B".into(),
            n_layers: 32,
            kv_dim: 4096,
            elem_bytes: 2,
            n_params: 6_738_000_000,
        }
    }

    /// Our runnable TinyLM (fp32 cache).
    pub fn tiny(cfg: &crate::model::ModelConfig) -> Self {
        ArchSpec {
            name: "TinyLM".into(),
            n_layers: cfg.n_layers,
            kv_dim: cfg.d_model,
            elem_bytes: 4,
            n_params: cfg.n_params(),
        }
    }

    /// Full-precision KV bytes for `tokens` cached tokens.
    pub fn kv_bytes_full(&self, tokens: usize) -> usize {
        2 * self.n_layers * self.kv_dim * self.elem_bytes * tokens
    }

    /// Weight bytes.
    pub fn weight_bytes(&self) -> usize {
        self.n_params * self.elem_bytes
    }

    /// CSKV bytes: compressed channels (`keep` fraction) for all tokens +
    /// a full-precision window of `window` tokens, optionally int4 on the
    /// compressed branch.
    pub fn kv_bytes_cskv(&self, tokens: usize, keep: f64, window: usize, int4: bool) -> usize {
        let comp_dim = (self.kv_dim as f64 * keep).round() as usize;
        let comp_elem = if int4 {
            // 4 bits + amortized affine params (~6% at group 32) — use 0.5B
            // + 1/16 overhead to stay honest.
            0.53125
        } else {
            self.elem_bytes as f64
        };
        let hist = 2 * self.n_layers * tokens * (comp_dim as f64 * comp_elem) as usize;
        let win = self.kv_bytes_full(window.min(tokens));
        hist + win
    }

    /// Token-pruning bytes (StreamingLLM / H2O keep `keep` of the tokens).
    pub fn kv_bytes_pruned(&self, tokens: usize, keep: f64) -> usize {
        self.kv_bytes_full((tokens as f64 * keep).round() as usize)
    }
}

pub const GB: f64 = 1024.0 * 1024.0 * 1024.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_intro_claim_reproduced() {
        // "processing a sequence with 200K tokens using LLaMA-2-7B results
        // in a KV cache occupying around 100GB, compared to 14GB for
        // weights".
        let a = ArchSpec::llama2_7b();
        let kv_gb = a.kv_bytes_full(200_000) as f64 / GB;
        assert!((kv_gb - 97.65).abs() < 1.0, "kv={kv_gb}GB");
        let w_gb = a.weight_bytes() as f64 / GB;
        assert!((12.0..15.0).contains(&w_gb), "weights={w_gb}GB");
    }

    #[test]
    fn cskv_80_gets_roughly_5x() {
        let a = ArchSpec::llama2_7b();
        let full = a.kv_bytes_full(200_000);
        let cskv = a.kv_bytes_cskv(200_000, 0.2, 32, false);
        let ratio = full as f64 / cskv as f64;
        assert!((4.5..5.5).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn cskv_int4_hits_95_percent_class() {
        let a = ArchSpec::llama2_7b();
        let full = a.kv_bytes_full(200_000) as f64;
        let c = a.kv_bytes_cskv(200_000, 0.2, 32, true) as f64;
        let saved = 1.0 - c / full;
        assert!(saved > 0.93, "saved={saved}");
    }

    #[test]
    fn pruned_matches_token_fraction() {
        let a = ArchSpec::llama2_7b();
        let half = a.kv_bytes_pruned(1000, 0.5);
        assert_eq!(half, a.kv_bytes_full(500));
    }
}
