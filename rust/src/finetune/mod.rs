//! Training-efficient fine-tuning (§2.2, Figure 2).
//!
//! The paper's key cost saving: instead of retraining the LLM end-to-end,
//! minimize the **layer-wise reconstruction loss**
//! `L = MSE(X·W, X·A·B)` for each layer's key and value projections
//! independently, starting from an (A)SVD initialization.
//!
//! * [`adam`] — the AdamW optimizer state (manual gradients; the loss is a
//!   bilinear least-squares form so autodiff is unnecessary).
//! * [`recon`] — the layer-wise trainer, loss-curve capture (Figure 4),
//!   QAT (fake-quant in the loss path, Table 5) and the end-to-end
//!   `build_factors` pipeline (calibrate → init → fine-tune).

pub mod adam;
pub mod recon;

pub use recon::{build_factors, FinetuneConfig, FinetuneReport};
