//! Layer-wise reconstruction fine-tuning (§2.2, Figures 2 & 4).
//!
//! For each layer and each of {K, V}: minimize
//! `L = MSE(X·W, fq(X·A)·B)` over (A, B) with AdamW, where `fq` is the
//! identity (plain CSKV) or the int4 fake-quantizer (QAT, Table 5).
//! Gradients are closed-form (the loss is bilinear):
//!
//! ```text
//! E  = Ĉ·B − X·W               (Ĉ = fq(X·A); straight-through through fq)
//! ∂B = Ĉᵀ·E · 2/(n·d)
//! ∂A = Xᵀ·(E·Bᵀ) · 2/(n·d)
//! ```
//!
//! The total model loss (Eq. 2) is the sum over layers of `L_K + L_V`;
//! because layers are independent this trains layer-by-layer exactly as
//! the paper describes, at a tiny fraction of end-to-end cost.

use crate::compress::quant::{fake_quant, QuantAxis};
use crate::compress::ratio::KvCompressionPlan;
use crate::compress::svd_init::{init_factors, InitMethod};
use crate::compress::{LayerFactors, LowRankFactors, ModelFactors};
use crate::model::ModelWeights;
use crate::tensor::Mat;
use crate::util::prng::Pcg64;

use super::adam::{AdamConfig, AdamState};

/// Quantization-aware-training mode for the compressed features.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QatMode {
    /// No quantization in the loss (paper's main configuration).
    Off,
    /// Fake-quant `C` in the loss path: per-channel for K, per-token for V.
    Int4,
}

/// Fine-tuning configuration.
#[derive(Clone, Debug)]
pub struct FinetuneConfig {
    pub init: InitMethod,
    pub steps: usize,
    /// Rows per minibatch (32 = the int4 group size, so QAT sees true groups).
    pub batch_rows: usize,
    pub adam: AdamConfig,
    pub qat: QatMode,
    pub seed: u64,
}

impl Default for FinetuneConfig {
    fn default() -> Self {
        FinetuneConfig {
            init: InitMethod::asvd_default(),
            steps: 200,
            batch_rows: 32,
            adam: AdamConfig {
                lr: 2e-3,
                weight_decay: 0.0,
                ..Default::default()
            },
            qat: QatMode::Off,
            seed: 0,
        }
    }
}

/// Per-projection training trace (Figure 4's series).
#[derive(Clone, Debug)]
pub struct LossCurve {
    pub label: String,
    pub losses: Vec<f32>,
}

/// Everything produced by a fine-tuning run.
#[derive(Clone, Debug)]
pub struct FinetuneReport {
    pub factors: ModelFactors,
    /// One curve per (layer, K/V) pair, in layer order (K then V).
    pub curves: Vec<LossCurve>,
    /// Eq. 2: Σ_layers (L_K + L_V) at the end of training.
    pub final_total_loss: f32,
}

/// Train one factor pair on `(x, w)`. Returns the per-step loss curve.
pub fn train_lowrank(
    x: &Mat,
    w: &Mat,
    factors: &mut LowRankFactors,
    cfg: &FinetuneConfig,
    quant_axis: Option<QuantAxis>,
) -> Vec<f32> {
    let n = x.rows;
    let d = w.cols;
    let target = x.matmul(w); // exact K (or V)
    let mut rng = Pcg64::new(cfg.seed ^ 0x5eed);
    let mut adam_a = AdamState::for_param(&factors.a);
    let mut adam_b = AdamState::for_param(&factors.b);
    let mut losses = Vec::with_capacity(cfg.steps);
    let bs = cfg.batch_rows.min(n).max(1);

    for _step in 0..cfg.steps {
        // Sample a row minibatch.
        let idx = rng.sample_indices(n, bs);
        let mut xb = Mat::zeros(bs, x.cols);
        let mut tb = Mat::zeros(bs, d);
        for (oi, &src) in idx.iter().enumerate() {
            xb.row_mut(oi).copy_from_slice(x.row(src));
            tb.row_mut(oi).copy_from_slice(target.row(src));
        }

        // Forward (with optional straight-through fake quant).
        let c = xb.matmul(&factors.a);
        let c_used = match quant_axis {
            Some(axis) => fake_quant(&c, axis),
            None => c.clone(),
        };
        let khat = c_used.matmul(&factors.b);
        let err = khat.sub(&tb);
        let loss = err.data.iter().map(|e| e * e).sum::<f32>() / (bs * d) as f32;
        losses.push(loss);

        // Backward (straight-through: d c_used / d c = I).
        let scale = 2.0 / (bs * d) as f32;
        let grad_b = c_used.matmul_tn(&err).scale(scale);
        let err_bt = err.matmul_nt(&factors.b);
        let grad_a = xb.matmul_tn(&err_bt).scale(scale);
        adam_a.step(&mut factors.a, &grad_a, &cfg.adam);
        adam_b.step(&mut factors.b, &grad_b, &cfg.adam);
    }
    losses
}

/// Current full-data reconstruction loss of a factor pair.
pub fn recon_loss(x: &Mat, w: &Mat, f: &LowRankFactors, quant_axis: Option<QuantAxis>) -> f32 {
    let target = x.matmul(w);
    let c = f.compress(x);
    let c_used = match quant_axis {
        Some(axis) => fake_quant(&c, axis),
        None => c,
    };
    c_used.matmul(&f.b).mse(&target)
}

/// End-to-end factor construction: init (per `cfg.init`) and, if
/// `cfg.steps > 0`, layer-wise reconstruction fine-tuning.
///
/// `calib` is one activation matrix per layer (from
/// [`crate::model::Engine::collect_calibration`]).
pub fn build_factors(
    weights: &ModelWeights,
    calib: &[Mat],
    plan: KvCompressionPlan,
    cfg: &FinetuneConfig,
) -> FinetuneReport {
    let mcfg = &weights.cfg;
    assert_eq!(calib.len(), mcfg.n_layers, "need calibration per layer");
    let d = mcfg.d_model;
    let (rk, rv) = (plan.rank_k(d), plan.rank_v(d));
    let (qk, qv) = match cfg.qat {
        QatMode::Off => (None, None),
        QatMode::Int4 => (Some(QuantAxis::PerChannel), Some(QuantAxis::PerToken)),
    };

    let mut layers = Vec::with_capacity(mcfg.n_layers);
    let mut curves = Vec::new();
    let mut total = 0.0f32;
    for (li, lw) in weights.layers.iter().enumerate() {
        let x = &calib[li];
        let seed = cfg.seed.wrapping_add(li as u64 * 1000);
        let mut fk = init_factors(&lw.wk, rk, cfg.init, Some(x), seed);
        let mut fv = init_factors(&lw.wv, rv, cfg.init, Some(x), seed + 1);
        if cfg.steps > 0 {
            let ck = train_lowrank(x, &lw.wk, &mut fk, cfg, qk);
            curves.push(LossCurve {
                label: format!("layer{li}.K"),
                losses: ck,
            });
            let cv = train_lowrank(x, &lw.wv, &mut fv, cfg, qv);
            curves.push(LossCurve {
                label: format!("layer{li}.V"),
                losses: cv,
            });
        }
        total += recon_loss(x, &lw.wk, &fk, qk) + recon_loss(x, &lw.wv, &fv, qv);
        layers.push(LayerFactors { k: fk, v: fv });
    }

    let provenance = format!(
        "init={} steps={} rk={rk} rv={rv} qat={:?}",
        cfg.init.name(),
        cfg.steps,
        cfg.qat
    );
    FinetuneReport {
        factors: ModelFactors { layers, provenance },
        curves,
        final_total_loss: total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn calib_like(w: &ModelWeights, rows: usize, seed: u64) -> Vec<Mat> {
        let mut rng = Pcg64::new(seed);
        (0..w.cfg.n_layers)
            .map(|_| Mat::randn(rows, w.cfg.d_model, 1.0, &mut rng))
            .collect()
    }

    #[test]
    fn training_reduces_loss_from_svd_init() {
        let w = ModelWeights::init(&ModelConfig::test_small(), 1);
        let calib = calib_like(&w, 128, 2);
        let rank = 4; // deep compression of d=32
        let mut f = init_factors(&w.layers[0].wk, rank, InitMethod::Svd, None, 0);
        let before = recon_loss(&calib[0], &w.layers[0].wk, &f, None);
        let cfg = FinetuneConfig {
            steps: 150,
            ..Default::default()
        };
        let curve = train_lowrank(&calib[0], &w.layers[0].wk, &mut f, &cfg, None);
        let after = recon_loss(&calib[0], &w.layers[0].wk, &f, None);
        assert!(after < before, "train must improve: {before} -> {after}");
        assert!(curve.len() == 150);
    }

    #[test]
    fn random_init_converges_far_slower_than_svd() {
        // The Figure 4 phenomenon at miniature scale: after the same budget
        // the random-init loss is much worse than the (A)SVD-init loss.
        let w = ModelWeights::init(&ModelConfig::test_small(), 3);
        let calib = calib_like(&w, 128, 4);
        let run = |init: InitMethod| {
            let mut f = init_factors(&w.layers[0].wk, 4, init, Some(&calib[0]), 7);
            let cfg = FinetuneConfig {
                steps: 60,
                ..Default::default()
            };
            train_lowrank(&calib[0], &w.layers[0].wk, &mut f, &cfg, None);
            recon_loss(&calib[0], &w.layers[0].wk, &f, None)
        };
        let (l_rand, l_svd) = (run(InitMethod::Random), run(InitMethod::Svd));
        assert!(
            l_rand > 3.0 * l_svd,
            "random {l_rand} should trail svd {l_svd}"
        );
    }

    #[test]
    fn build_factors_shapes_and_provenance() {
        let w = ModelWeights::init(&ModelConfig::test_small(), 5);
        let calib = calib_like(&w, 96, 6);
        let plan = KvCompressionPlan::uniform(0.5);
        let cfg = FinetuneConfig {
            steps: 20,
            ..Default::default()
        };
        let rep = build_factors(&w, &calib, plan, &cfg);
        assert_eq!(rep.factors.layers.len(), w.cfg.n_layers);
        assert_eq!(rep.factors.rank_k(), 16);
        assert_eq!(rep.curves.len(), 2 * w.cfg.n_layers);
        assert!(rep.final_total_loss.is_finite());
        assert!(rep.factors.provenance.contains("asvd"));
    }

    #[test]
    fn qat_trains_against_quantized_path() {
        let w = ModelWeights::init(&ModelConfig::test_small(), 8);
        let calib = calib_like(&w, 128, 9);
        let plan = KvCompressionPlan::uniform(0.5);
        // PTQ: train without quant, evaluate with quant.
        let base = build_factors(
            &w,
            &calib,
            plan,
            &FinetuneConfig {
                steps: 120,
                ..Default::default()
            },
        );
        let ptq_loss: f32 = w
            .layers
            .iter()
            .enumerate()
            .map(|(li, lw)| {
                recon_loss(&calib[li], &lw.wk, &base.factors.layers[li].k, Some(QuantAxis::PerChannel))
            })
            .sum();
        // QAT: quant inside the loss.
        let qat = build_factors(
            &w,
            &calib,
            plan,
            &FinetuneConfig {
                steps: 120,
                qat: QatMode::Int4,
                ..Default::default()
            },
        );
        let qat_loss: f32 = w
            .layers
            .iter()
            .enumerate()
            .map(|(li, lw)| {
                recon_loss(&calib[li], &lw.wk, &qat.factors.layers[li].k, Some(QuantAxis::PerChannel))
            })
            .sum();
        assert!(
            qat_loss <= ptq_loss * 1.05,
            "QAT {qat_loss} should not lose to PTQ {ptq_loss}"
        );
    }

    #[test]
    fn no_steps_means_pure_init() {
        let w = ModelWeights::init(&ModelConfig::test_small(), 10);
        let calib = calib_like(&w, 64, 11);
        let rep = build_factors(
            &w,
            &calib,
            KvCompressionPlan::uniform(0.5),
            &FinetuneConfig {
                steps: 0,
                ..Default::default()
            },
        );
        assert!(rep.curves.is_empty());
        assert_eq!(rep.factors.layers.len(), w.cfg.n_layers);
    }
}
