//! AdamW optimizer over [`Mat`] parameters.
//!
//! Matches the paper's setup (§B): AdamW, initial lr 5e-5 scaled to our
//! problem size, β = (0.9, 0.999), decoupled weight decay.

use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
        }
    }
}

/// First/second-moment state for one parameter tensor.
#[derive(Clone, Debug)]
pub struct AdamState {
    m: Mat,
    v: Mat,
    t: u64,
}

impl AdamState {
    pub fn new(rows: usize, cols: usize) -> Self {
        AdamState {
            m: Mat::zeros(rows, cols),
            v: Mat::zeros(rows, cols),
            t: 0,
        }
    }

    pub fn for_param(p: &Mat) -> Self {
        Self::new(p.rows, p.cols)
    }

    /// One AdamW step: updates `param` in place from `grad`.
    pub fn step(&mut self, param: &mut Mat, grad: &Mat, cfg: &AdamConfig) {
        assert_eq!((param.rows, param.cols), (grad.rows, grad.cols));
        self.t += 1;
        let b1t = 1.0 - cfg.beta1.powi(self.t as i32);
        let b2t = 1.0 - cfg.beta2.powi(self.t as i32);
        for i in 0..param.data.len() {
            let g = grad.data[i];
            self.m.data[i] = cfg.beta1 * self.m.data[i] + (1.0 - cfg.beta1) * g;
            self.v.data[i] = cfg.beta2 * self.v.data[i] + (1.0 - cfg.beta2) * g * g;
            let mhat = self.m.data[i] / b1t;
            let vhat = self.v.data[i] / b2t;
            // Decoupled weight decay (AdamW).
            param.data[i] -= cfg.lr * (mhat / (vhat.sqrt() + cfg.eps) + cfg.weight_decay * param.data[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    /// Adam on a convex quadratic must converge to the minimum.
    #[test]
    fn converges_on_quadratic() {
        let mut rng = Pcg64::new(1);
        let target = Mat::randn(4, 3, 1.0, &mut rng);
        let mut p = Mat::zeros(4, 3);
        let mut st = AdamState::for_param(&p);
        let cfg = AdamConfig {
            lr: 0.05,
            ..Default::default()
        };
        for _ in 0..500 {
            let grad = p.sub(&target); // ∇ of 0.5‖p−target‖²
            st.step(&mut p, &grad, &cfg);
        }
        assert!(p.allclose(&target, 1e-2), "diff={}", p.max_abs_diff(&target));
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut p = Mat::from_vec(1, 2, vec![10.0, -10.0]);
        let mut st = AdamState::for_param(&p);
        let cfg = AdamConfig {
            lr: 0.1,
            weight_decay: 0.5,
            ..Default::default()
        };
        let zero_grad = Mat::zeros(1, 2);
        for _ in 0..100 {
            st.step(&mut p, &zero_grad, &cfg);
        }
        assert!(p.abs_max() < 1.0, "decay should shrink params: {p:?}");
    }

    #[test]
    fn bias_correction_first_step() {
        // After one step with gradient g, update ≈ lr * sign(g).
        let mut p = Mat::zeros(1, 1);
        let mut st = AdamState::for_param(&p);
        let cfg = AdamConfig {
            lr: 0.01,
            ..Default::default()
        };
        let g = Mat::from_vec(1, 1, vec![3.7]);
        st.step(&mut p, &g, &cfg);
        assert!((p.data[0] + 0.01).abs() < 1e-4, "got {}", p.data[0]);
    }
}
