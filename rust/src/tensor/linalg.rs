//! Householder QR and least-squares solves.
//!
//! Used by the "oracle" closed-form initializer (an extension beyond the
//! paper — see DESIGN.md §6): the rank-r minimizer of ‖X·W − X·A·B‖_F is
//! obtained from QR of X followed by an SVD of R·W in the X-metric.

use super::svd::svd;
use super::Mat;

/// Thin QR decomposition `A = Q·R` with `Q: m×n` (orthonormal columns),
/// `R: n×n` upper triangular. Requires `m ≥ n`.
pub fn qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "qr requires m >= n, got {m}x{n}");
    // Householder vectors stored in-place in `r`, accumulated into q later.
    let mut r = a.clone();
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n);
    for k in 0..n {
        // Build the Householder vector for column k below the diagonal.
        let mut norm = 0.0f64;
        for i in k..m {
            let x = r.at(i, k) as f64;
            norm += x * x;
        }
        let norm = norm.sqrt() as f32;
        let mut v = vec![0.0f32; m - k];
        if norm <= 1e-20 {
            vs.push(v);
            continue;
        }
        let alpha = if r.at(k, k) >= 0.0 { -norm } else { norm };
        for i in k..m {
            v[i - k] = r.at(i, k);
        }
        v[0] -= alpha;
        let vnorm2: f32 = v.iter().map(|x| x * x).sum();
        if vnorm2 <= 1e-30 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        // Apply H = I - 2 v vᵀ / (vᵀv) to the trailing submatrix.
        for j in k..n {
            let mut dotv = 0.0f32;
            for i in k..m {
                dotv += v[i - k] * r.at(i, j);
            }
            let f = 2.0 * dotv / vnorm2;
            for i in k..m {
                *r.at_mut(i, j) -= f * v[i - k];
            }
        }
        vs.push(v);
    }
    // Extract R (upper n×n block).
    let mut rr = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            *rr.at_mut(i, j) = r.at(i, j);
        }
    }
    // Accumulate Q = H_0 H_1 ... H_{n-1} applied to the first n columns of I.
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        *q.at_mut(j, j) = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f32 = v.iter().map(|x| x * x).sum();
        if vnorm2 <= 1e-30 {
            continue;
        }
        for j in 0..n {
            let mut dotv = 0.0f32;
            for i in k..m {
                dotv += v[i - k] * q.at(i, j);
            }
            let f = 2.0 * dotv / vnorm2;
            for i in k..m {
                *q.at_mut(i, j) -= f * v[i - k];
            }
        }
    }
    (q, rr)
}

/// Solve the upper-triangular system `R·x = b` (single RHS in-place).
pub fn solve_upper(r: &Mat, b: &[f32]) -> Vec<f32> {
    let n = r.rows;
    assert_eq!(r.cols, n);
    assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        let mut s = x[i];
        for j in (i + 1)..n {
            s -= r.at(i, j) * x[j];
        }
        let d = r.at(i, i);
        x[i] = if d.abs() > 1e-20 { s / d } else { 0.0 };
    }
    x
}

/// Least squares `argmin_W ‖A·W − B‖_F` for matrix RHS via QR.
pub fn lstsq(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows);
    let (q, r) = qr(a);
    let qtb = q.matmul_tn(b); // n×k
    let mut w = Mat::zeros(a.cols, b.cols);
    for j in 0..b.cols {
        let col: Vec<f32> = (0..a.cols).map(|i| qtb.at(i, j)).collect();
        let x = solve_upper(&r, &col);
        for i in 0..a.cols {
            *w.at_mut(i, j) = x[i];
        }
    }
    w
}

/// Closed-form rank-r minimizer of ‖X·W − X·A·B‖_F (the "oracle" init).
///
/// With X = Q·R, the problem becomes the best rank-r approximation of
/// R·W in Frobenius norm: SVD(R·W) = U Σ Vᵀ, then
/// `A = R⁻¹·U_r·Σ_r`, `B = V_rᵀ`.
pub fn oracle_lowrank(x: &Mat, w: &Mat, r: usize) -> (Mat, Mat) {
    assert_eq!(x.cols, w.rows);
    let (_, rr) = qr(x);
    let rw = rr.matmul(w);
    let d = svd(&rw);
    let rank = r.min(d.s.len());
    // U_r Σ_r
    let mut us = d.u.cols_slice(0, rank);
    for (j, &sv) in d.s[..rank].iter().enumerate() {
        us.scale_col(j, sv);
    }
    // A = R⁻¹ (U_r Σ_r): solve R·A = U_r Σ_r column by column.
    let mut a = Mat::zeros(w.rows, rank);
    for j in 0..rank {
        let col: Vec<f32> = (0..w.rows).map(|i| us.at(i, j)).collect();
        let s = solve_upper(&rr, &col);
        for i in 0..w.rows {
            *a.at_mut(i, j) = s[i];
        }
    }
    let b = d.v.cols_slice(0, rank).t();
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg64::new(1);
        for (m, n) in [(5, 5), (12, 4), (30, 17)] {
            let a = Mat::randn(m, n, 1.0, &mut rng);
            let (q, r) = qr(&a);
            assert!(q.matmul(&r).allclose(&a, 1e-3), "({m},{n})");
            // Q orthonormal
            let g = q.matmul_tn(&q);
            assert!(g.allclose(&Mat::eye(n), 1e-3));
            // R upper triangular
            for i in 0..n {
                for j in 0..i {
                    assert!(r.at(i, j).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn solve_upper_exact() {
        let r = Mat::from_vec(3, 3, vec![2.0, 1.0, 0.0, 0.0, 3.0, -1.0, 0.0, 0.0, 4.0]);
        let x_true = [1.0f32, -2.0, 0.5];
        let b: Vec<f32> = (0..3)
            .map(|i| (0..3).map(|j| r.at(i, j) * x_true[j]).sum())
            .collect();
        let x = solve_upper(&r, &b);
        for (a, b) in x.iter().zip(x_true.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn lstsq_recovers_planted_solution() {
        let mut rng = Pcg64::new(2);
        let a = Mat::randn(40, 6, 1.0, &mut rng);
        let w_true = Mat::randn(6, 3, 1.0, &mut rng);
        let b = a.matmul(&w_true);
        let w = lstsq(&a, &b);
        assert!(w.allclose(&w_true, 1e-3));
    }

    #[test]
    fn oracle_beats_plain_svd_under_x_metric() {
        // When X has strongly anisotropic columns, the oracle init must give
        // lower ‖XW − XAB‖ than truncated SVD of W itself.
        let mut rng = Pcg64::new(3);
        let n = 12;
        let mut x = Mat::randn(80, n, 1.0, &mut rng);
        for j in 0..n {
            let s = if j < 2 { 10.0 } else { 0.1 };
            x.scale_col(j, s);
        }
        let w = Mat::randn(n, n, 1.0, &mut rng);
        let r = 3;
        let (a_o, b_o) = oracle_lowrank(&x, &w, r);
        let d = svd(&w);
        let (a_s, b_s) = d.factors(r);
        let err = |a: &Mat, b: &Mat| x.matmul(&a.matmul(b)).sub(&x.matmul(&w)).frob_norm();
        let (eo, es) = (err(&a_o, &b_o), err(&a_s, &b_s));
        assert!(eo <= es * 1.001, "oracle {eo} vs svd {es}");
    }

    #[test]
    fn oracle_full_rank_is_exact() {
        let mut rng = Pcg64::new(4);
        let x = Mat::randn(30, 8, 1.0, &mut rng);
        let w = Mat::randn(8, 8, 1.0, &mut rng);
        let (a, b) = oracle_lowrank(&x, &w, 8);
        let approx = a.matmul(&b);
        assert!(approx.allclose(&w, 2e-2), "diff={}", approx.max_abs_diff(&w));
    }
}
