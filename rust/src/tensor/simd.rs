//! Explicit-width SIMD kernels (8-lane f32) for the two primitive
//! reductions every hot path funnels through: AXPY (`c += s·b`) and dot.
//!
//! This module is **always compiled** — the `simd` cargo feature only
//! gates whether `tensor/matmul.rs` *dispatches* to it — so the property
//! tests in `rust/tests/property_invariants.rs` can compare the SIMD and
//! scalar kernels directly under either feature configuration.
//!
//! ## Numerics contract
//!
//! * [`axpy`] is **bit-identical** to the scalar
//!   [`axpy_row_scalar`](super::matmul::axpy_row_scalar): AXPY is
//!   elementwise (`c[i] += s * b[i]` independently per lane), and the
//!   vector body uses a separate multiply then add — never an FMA — so
//!   each lane performs exactly the scalar operation with the same
//!   rounding. Every kernel built from AXPY (the i-k-j GEMM, the
//!   transposed GEMVs, the batched decode projection, decode attention's
//!   value accumulation) therefore stays bitwise unchanged when SIMD is
//!   enabled.
//! * [`dot`] reassociates: it keeps an 8-lane accumulator (then a fixed
//!   pairwise horizontal sum) where the scalar kernel keeps 4 running
//!   sums. Both are valid orderings of the same sum; they differ by a few
//!   ULPs at the scale of `Σ|xᵢyᵢ|`. Kernels built on dot
//!   (`matmul_nt`, `matvec_into`, attention scores) carry a documented
//!   ULP tolerance against their scalar oracles instead of bit-identity.
//!
//! ## Dispatch
//!
//! [`available`] performs runtime feature detection (AVX on x86_64 —
//! cached by `is_x86_feature_detected!` — NEON is baseline on aarch64).
//! On other architectures it returns `false` and the unsafe kernels are
//! unreachable; callers must guard on [`available`].

/// Whether the SIMD kernels can run on this CPU. Cheap after the first
/// call (the std detection macro caches its cpuid probe).
#[inline]
pub fn available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx")
    }
    #[cfg(target_arch = "aarch64")]
    {
        true // NEON is baseline on aarch64.
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        false
    }
}

/// `crow += s * brow`, 8 lanes at a time. Bit-identical to the scalar
/// kernel (separate mul + add per lane, no FMA, scalar remainder tail).
///
/// # Safety
/// Requires [`available`] to have returned `true` on this CPU.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
pub unsafe fn axpy(crow: &mut [f32], s: f32, brow: &[f32]) {
    use core::arch::x86_64::*;
    debug_assert_eq!(crow.len(), brow.len());
    let n = crow.len();
    let chunks = n / 8;
    let vs = _mm256_set1_ps(s);
    let cp = crow.as_mut_ptr();
    let bp = brow.as_ptr();
    for c in 0..chunks {
        let o = c * 8;
        let b = _mm256_loadu_ps(bp.add(o));
        let cv = _mm256_loadu_ps(cp.add(o));
        // mul then add (NOT fmadd): one rounding per op, exactly like the
        // scalar `c += s * b` — this is what makes the lane bit-identical.
        let prod = _mm256_mul_ps(vs, b);
        _mm256_storeu_ps(cp.add(o), _mm256_add_ps(cv, prod));
    }
    for o in chunks * 8..n {
        crow[o] += s * brow[o];
    }
}

/// Dot product with an 8-lane accumulator and a fixed pairwise horizontal
/// sum. Reassociated relative to the scalar kernel — callers compare
/// against the scalar oracle with a ULP tolerance, not bit-identity.
///
/// # Safety
/// Requires [`available`] to have returned `true` on this CPU.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
    use core::arch::x86_64::*;
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    let xp = x.as_ptr();
    let yp = y.as_ptr();
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let o = c * 8;
        let xv = _mm256_loadu_ps(xp.add(o));
        let yv = _mm256_loadu_ps(yp.add(o));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, yv));
    }
    // Fixed horizontal reduction: (lo128 + hi128), then pairwise within
    // the 128-bit half. Deterministic order ⇒ reproducible bits.
    let lo = _mm256_castps256_ps128(acc);
    let hi = _mm256_extractf128_ps(acc, 1);
    let s4 = _mm_add_ps(lo, hi);
    let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
    let s1 = _mm_add_ss(s2, _mm_shuffle_ps(s2, s2, 0x55));
    let mut s = _mm_cvtss_f32(s1);
    for o in chunks * 8..n {
        s += x[o] * y[o];
    }
    s
}

/// `crow += s * brow`, two 4-lane NEON vectors per iteration (8 logical
/// lanes, matching the x86 path). Bit-identical to the scalar kernel
/// (vmul + vadd, no fused multiply-add).
///
/// # Safety
/// Requires [`available`] to have returned `true` on this CPU (always on
/// aarch64 — NEON is baseline).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
pub unsafe fn axpy(crow: &mut [f32], s: f32, brow: &[f32]) {
    use core::arch::aarch64::*;
    debug_assert_eq!(crow.len(), brow.len());
    let n = crow.len();
    let chunks = n / 8;
    let vs = vdupq_n_f32(s);
    let cp = crow.as_mut_ptr();
    let bp = brow.as_ptr();
    for c in 0..chunks {
        let o = c * 8;
        let b0 = vld1q_f32(bp.add(o));
        let b1 = vld1q_f32(bp.add(o + 4));
        let c0 = vld1q_f32(cp.add(o));
        let c1 = vld1q_f32(cp.add(o + 4));
        // vmulq + vaddq (NOT vfmaq): same two roundings as scalar.
        vst1q_f32(cp.add(o), vaddq_f32(c0, vmulq_f32(vs, b0)));
        vst1q_f32(cp.add(o + 4), vaddq_f32(c1, vmulq_f32(vs, b1)));
    }
    for o in chunks * 8..n {
        crow[o] += s * brow[o];
    }
}

/// Dot product with two 4-lane NEON accumulators (8 logical lanes) and a
/// fixed pairwise horizontal sum. ULP-tolerance contract, like the x86
/// path.
///
/// # Safety
/// Requires [`available`] to have returned `true` on this CPU.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
    use core::arch::aarch64::*;
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    let xp = x.as_ptr();
    let yp = y.as_ptr();
    let mut a0 = vdupq_n_f32(0.0);
    let mut a1 = vdupq_n_f32(0.0);
    for c in 0..chunks {
        let o = c * 8;
        a0 = vaddq_f32(a0, vmulq_f32(vld1q_f32(xp.add(o)), vld1q_f32(yp.add(o))));
        a1 = vaddq_f32(a1, vmulq_f32(vld1q_f32(xp.add(o + 4)), vld1q_f32(yp.add(o + 4))));
    }
    let s4 = vaddq_f32(a0, a1);
    let s2 = vadd_f32(vget_low_f32(s4), vget_high_f32(s4));
    let mut s = vget_lane_f32::<0>(s2) + vget_lane_f32::<1>(s2);
    for o in chunks * 8..n {
        s += x[o] * y[o];
    }
    s
}

/// Unsupported architecture: [`available`] returns `false`, so these are
/// never reached — they exist only to keep call sites compiling.
///
/// # Safety
/// Never safe to call (and never called): guarded by [`available`].
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub unsafe fn axpy(_crow: &mut [f32], _s: f32, _brow: &[f32]) {
    unreachable!("simd::axpy on unsupported arch; guard on simd::available()")
}

/// See [`axpy`] (unsupported-arch stub).
///
/// # Safety
/// Never safe to call (and never called): guarded by [`available`].
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub unsafe fn dot(_x: &[f32], _y: &[f32]) -> f32 {
    unreachable!("simd::dot on unsupported arch; guard on simd::available()")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn axpy_bit_identical_to_scalar_all_tails() {
        if !available() {
            return;
        }
        let mut rng = Pcg64::new(40);
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 33, 100, 257, 511] {
            let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut want: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut got = want.clone();
            let s = rng.normal();
            crate::tensor::matmul::axpy_row_scalar(&mut want, s, &b);
            unsafe { axpy(&mut got, s, &b) };
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "n={n}");
        }
    }

    #[test]
    fn dot_close_to_scalar() {
        if !available() {
            return;
        }
        let mut rng = Pcg64::new(41);
        for n in [0usize, 1, 7, 8, 9, 33, 100, 511] {
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let want = crate::tensor::matmul::dot_scalar(&x, &y);
            let got = unsafe { dot(&x, &y) };
            let scale: f32 = x
                .iter()
                .zip(&y)
                .map(|(a, b)| (a * b).abs())
                .sum::<f32>()
                .max(f32::MIN_POSITIVE);
            assert!(
                (got - want).abs() <= 8.0 * f32::EPSILON * scale,
                "n={n} got={got} want={want}"
            );
        }
    }
}
