//! NN primitives shared by the Rust reference engine and the trainers.
//!
//! These mirror the jnp ops in `python/compile/kernels/ref.py` exactly —
//! the cross-validation test (`rust/tests/integration_runtime.rs`) asserts
//! the Rust engine and the AOT HLO agree, which only holds if both sides
//! use the same formulations (RMSNorm without bias, rotate-half RoPE,
//! softmax with max-subtraction).

use crate::util::threadpool::parallel_rows;

use super::Mat;

/// In-place numerically-stable softmax over each row, restricted to the
/// first `valid` columns (the rest are treated as masked and set to 0).
pub fn softmax_rows_masked(m: &mut Mat, valid: usize) {
    let valid = valid.min(m.cols);
    for i in 0..m.rows {
        let row = m.row_mut(i);
        let mut mx = f32::NEG_INFINITY;
        for &v in &row[..valid] {
            mx = mx.max(v);
        }
        let mut sum = 0.0f32;
        for v in &mut row[..valid] {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in &mut row[..valid] {
            *v *= inv;
        }
        for v in &mut row[valid..] {
            *v = 0.0;
        }
    }
}

/// Full-row softmax.
pub fn softmax_rows(m: &mut Mat) {
    let c = m.cols;
    softmax_rows_masked(m, c);
}

/// Causal softmax: row `i` may attend to columns `0..=i + offset`.
/// `offset` is the number of cached tokens preceding this block
/// (prefill uses offset 0; decode of token n uses a 1-row score with
/// offset n).
pub fn softmax_causal(m: &mut Mat, offset: usize) {
    for i in 0..m.rows {
        let valid = (i + offset + 1).min(m.cols);
        let row = m.row_mut(i);
        let mut mx = f32::NEG_INFINITY;
        for &v in &row[..valid] {
            mx = mx.max(v);
        }
        let mut sum = 0.0f32;
        for v in &mut row[..valid] {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in &mut row[..valid] {
            *v *= inv;
        }
        for v in &mut row[valid..] {
            *v = 0.0;
        }
    }
}

/// RMSNorm: `x * g / sqrt(mean(x^2) + eps)` per row.
pub fn rmsnorm(x: &[f32], gain: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), gain.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, &xv), &g) in out.iter_mut().zip(x).zip(gain) {
        *o = xv * inv * g;
    }
}

/// Row-wise RMSNorm over a matrix.
pub fn rmsnorm_rows(m: &Mat, gain: &[f32], eps: f32) -> Mat {
    let mut out = Mat::zeros(m.rows, m.cols);
    rmsnorm_rows_into(m, gain, eps, &mut out, 1);
    out
}

/// Row-wise RMSNorm into a preallocated output, rows split across up to
/// `threads` workers. Rows are independent, so the result is bit-identical
/// to the serial loop at every thread count.
pub fn rmsnorm_rows_into(m: &Mat, gain: &[f32], eps: f32, out: &mut Mat, threads: usize) {
    assert_eq!((m.rows, m.cols), (out.rows, out.cols));
    let rows = m.rows;
    let cols = m.cols;
    parallel_rows(&mut out.data, rows, cols, threads, |i, dst| {
        rmsnorm(&m.data[i * cols..(i + 1) * cols], gain, eps, dst);
    });
}

/// Row-wise RMSNorm with a thread knob (allocating variant of
/// [`rmsnorm_rows_into`]).
pub fn rmsnorm_rows_par(m: &Mat, gain: &[f32], eps: f32, threads: usize) -> Mat {
    let mut out = Mat::zeros(m.rows, m.cols);
    rmsnorm_rows_into(m, gain, eps, &mut out, threads);
    out
}

/// SiLU (swish) activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

pub fn silu_inplace(m: &mut Mat) {
    for v in m.data.iter_mut() {
        *v = silu(*v);
    }
}

/// SiLU applied row-parallel (the prefill MLP's `[T, d_ff]` activation is
/// ~260k `exp` calls at ctx 509 — worth spreading). Element-wise, so
/// bit-identical to [`silu_inplace`] at every thread count.
pub fn silu_rows(m: &mut Mat, threads: usize) {
    let (rows, cols) = (m.rows, m.cols);
    parallel_rows(&mut m.data, rows, cols, threads, |_, row| {
        for v in row.iter_mut() {
            *v = silu(*v);
        }
    });
}

/// Rotate-half RoPE applied in place to one token's d-dim head vector.
///
/// Matches the L2 model: for pair `(x[i], x[i + d/2])`,
/// `theta_i = base^(-2i/d)`, angle `= pos * theta_i`.
pub fn rope_rotate(x: &mut [f32], pos: usize, base: f32) {
    let d = x.len();
    let half = d / 2;
    for i in 0..half {
        let theta = base.powf(-2.0 * i as f32 / d as f32);
        let angle = pos as f32 * theta;
        let (sin, cos) = angle.sin_cos();
        let (a, b) = (x[i], x[i + half]);
        x[i] = a * cos - b * sin;
        x[i + half] = a * sin + b * cos;
    }
}

/// Apply RoPE per-head to a `[tokens, n_heads*d_head]` matrix where token
/// `t` has absolute position `pos0 + t`.
pub fn rope_rows(m: &mut Mat, n_heads: usize, pos0: usize, base: f32) {
    let d_head = m.cols / n_heads;
    for t in 0..m.rows {
        let row = m.row_mut(t);
        for h in 0..n_heads {
            rope_rotate(&mut row[h * d_head..(h + 1) * d_head], pos0 + t, base);
        }
    }
}

/// Precomputed rotate-half RoPE sin/cos table for positions
/// `0..positions` and one head width.
///
/// [`rope_rotate`] recomputes `powf` + `sin_cos` per (pair, position,
/// head, layer); during prefill the same `(pair, position)` angle is
/// needed `n_heads × n_layers × 2` times (Q and K), so the table turns
/// ~0.5M libm calls per layer at ctx 509 into one build of
/// `positions × d_head/2` entries per generation. Entries are computed
/// with expressions identical to [`rope_rotate`], so applying the table
/// is **bit-identical** to the direct path.
#[derive(Clone, Debug, Default)]
pub struct RopeTable {
    d_head: usize,
    base: f32,
    positions: usize,
    /// `[positions, d_head/2]`, row-major.
    sin: Vec<f32>,
    cos: Vec<f32>,
}

impl RopeTable {
    pub fn new(d_head: usize, base: f32, positions: usize) -> Self {
        let half = d_head / 2;
        let mut sin = vec![0.0f32; positions * half];
        let mut cos = vec![0.0f32; positions * half];
        for pos in 0..positions {
            for i in 0..half {
                // Must match `rope_rotate` exactly (bit-identity).
                let theta = base.powf(-2.0 * i as f32 / d_head as f32);
                let angle = pos as f32 * theta;
                let (s, c) = angle.sin_cos();
                sin[pos * half + i] = s;
                cos[pos * half + i] = c;
            }
        }
        RopeTable {
            d_head,
            base,
            positions,
            sin,
            cos,
        }
    }

    /// True if this table covers `(d_head, base)` for positions `0..t`.
    pub fn covers(&self, d_head: usize, base: f32, t: usize) -> bool {
        self.d_head == d_head && self.base == base && self.positions >= t
    }

    /// Rotate one head vector at `pos` — bit-identical to
    /// [`rope_rotate`]`(x, pos, base)`.
    #[inline]
    pub fn rotate(&self, x: &mut [f32], pos: usize) {
        debug_assert_eq!(x.len(), self.d_head);
        debug_assert!(pos < self.positions);
        let half = self.d_head / 2;
        let srow = &self.sin[pos * half..(pos + 1) * half];
        let crow = &self.cos[pos * half..(pos + 1) * half];
        for i in 0..half {
            let (a, b) = (x[i], x[i + half]);
            x[i] = a * crow[i] - b * srow[i];
            x[i + half] = a * srow[i] + b * crow[i];
        }
    }
}

/// [`rope_rows`] through a [`RopeTable`], rows split across up to
/// `threads` workers. Rows are independent and the table is read-only, so
/// this is bit-identical to the serial direct path at every thread count.
pub fn rope_rows_cached(m: &mut Mat, n_heads: usize, pos0: usize, table: &RopeTable, threads: usize) {
    let d_head = m.cols / n_heads;
    assert_eq!(table.d_head, d_head, "RoPE table head width mismatch");
    assert!(table.positions >= pos0 + m.rows, "RoPE table too short");
    let (rows, cols) = (m.rows, m.cols);
    parallel_rows(&mut m.data, rows, cols, threads, |t, row| {
        for h in 0..n_heads {
            table.rotate(&mut row[h * d_head..(h + 1) * d_head], pos0 + t);
        }
    });
}

/// Argmax over a slice.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// log-softmax cross-entropy of one row of logits against a target id.
pub fn cross_entropy(logits: &[f32], target: usize) -> f32 {
    let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse = logits.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx;
    lse - logits[target]
}

/// Mean cross-entropy over `[tokens, vocab]` logits vs target ids.
pub fn cross_entropy_rows(logits: &Mat, targets: &[usize]) -> f32 {
    assert_eq!(logits.rows, targets.len());
    let mut s = 0.0;
    for (i, &t) in targets.iter().enumerate() {
        s += cross_entropy(logits.row(i), t);
    }
    s / targets.len().max(1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Pcg64::new(1);
        let mut m = Mat::randn(4, 9, 3.0, &mut rng);
        softmax_rows(&mut m);
        for i in 0..4 {
            let s: f32 = m.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(m.row(i).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_masked_zeroes_tail() {
        let mut m = Mat::from_vec(1, 4, vec![1.0, 2.0, 100.0, 200.0]);
        softmax_rows_masked(&mut m, 2);
        assert_eq!(m.at(0, 2), 0.0);
        assert_eq!(m.at(0, 3), 0.0);
        let s: f32 = m.row(0).iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let mut a = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let mut b = Mat::from_vec(1, 3, vec![1001.0, 1002.0, 1003.0]);
        softmax_rows(&mut a);
        softmax_rows(&mut b);
        assert!(a.allclose(&b, 1e-5));
    }

    #[test]
    fn causal_mask_pattern() {
        let mut m = Mat::from_vec(3, 3, vec![0.0; 9]);
        softmax_causal(&mut m, 0);
        // row 0 attends only to col 0
        assert_eq!(m.row(0), &[1.0, 0.0, 0.0]);
        // row 1 splits between 0 and 1
        assert!((m.at(1, 0) - 0.5).abs() < 1e-6);
        assert_eq!(m.at(1, 2), 0.0);
        // row 2 uniform over all three
        assert!((m.at(2, 2) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0f32, -4.0];
        let g = vec![1.0f32, 1.0];
        let mut out = vec![0.0f32; 2];
        rmsnorm(&x, &g, 0.0, &mut out);
        // mean square = 12.5, rms = 3.5355
        assert!((out[0] - 3.0 / 12.5f32.sqrt()).abs() < 1e-5);
        let ms = out.iter().map(|v| v * v).sum::<f32>() / 2.0;
        assert!((ms - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rope_preserves_norm_and_position_zero_identity() {
        let mut rng = Pcg64::new(2);
        let mut x: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let orig = x.clone();
        let n0: f32 = x.iter().map(|v| v * v).sum();
        rope_rotate(&mut x, 0, 10000.0);
        assert_eq!(x, orig, "pos 0 must be identity");
        rope_rotate(&mut x, 17, 10000.0);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-4, "rotation must preserve norm");
    }

    #[test]
    fn rope_relative_property() {
        // <RoPE(q,m), RoPE(k,n)> depends only on m-n: shift both by +s.
        let mut rng = Pcg64::new(3);
        let q: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
        let k: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
        let dotp = |a: &[f32], b: &[f32]| a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>();
        let rot = |v: &[f32], p: usize| {
            let mut w = v.to_vec();
            rope_rotate(&mut w, p, 10000.0);
            w
        };
        let d1 = dotp(&rot(&q, 5), &rot(&k, 2));
        let d2 = dotp(&rot(&q, 15), &rot(&k, 12));
        assert!((d1 - d2).abs() < 1e-3, "{d1} vs {d2}");
    }

    #[test]
    fn rope_table_bit_identical_to_direct() {
        let mut rng = Pcg64::new(7);
        let (nh, dh, t) = (3usize, 8usize, 19usize);
        let base = 10000.0f32;
        let table = RopeTable::new(dh, base, t + 2);
        assert!(table.covers(dh, base, t));
        assert!(!table.covers(dh + 2, base, t));
        let direct = Mat::randn(t, nh * dh, 1.0, &mut rng);
        for threads in [1usize, 2, 8] {
            let mut cached = direct.clone();
            let mut want = direct.clone();
            rope_rows(&mut want, nh, 2, base);
            rope_rows_cached(&mut cached, nh, 2, &table, threads);
            assert_eq!(cached.data, want.data, "threads={threads}");
        }
        // Single-vector path: table.rotate ≡ rope_rotate at the same pos.
        let mut x: Vec<f32> = (0..dh).map(|_| rng.normal()).collect();
        let mut y = x.clone();
        rope_rotate(&mut x, 5, base);
        table.rotate(&mut y, 5);
        assert_eq!(x, y);
    }

    #[test]
    fn rmsnorm_and_silu_parallel_match_serial() {
        let mut rng = Pcg64::new(8);
        let m = Mat::randn(9, 12, 2.0, &mut rng);
        let gain: Vec<f32> = (0..12).map(|_| rng.normal()).collect();
        let want = rmsnorm_rows(&m, &gain, 1e-5);
        for threads in [2usize, 8] {
            assert_eq!(rmsnorm_rows_par(&m, &gain, 1e-5, threads).data, want.data);
            let mut out = Mat::from_vec(9, 12, vec![3.0; 9 * 12]); // dirty
            rmsnorm_rows_into(&m, &gain, 1e-5, &mut out, threads);
            assert_eq!(out.data, want.data);
        }
        let mut a = Mat::randn(7, 33, 1.5, &mut rng);
        let mut b = a.clone();
        silu_inplace(&mut a);
        silu_rows(&mut b, 8);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn cross_entropy_basics() {
        // uniform logits -> ln(V)
        let l = vec![0.0f32; 8];
        assert!((cross_entropy(&l, 3) - (8.0f32).ln()).abs() < 1e-5);
        // confident correct answer -> ~0
        let mut l2 = vec![-20.0f32; 8];
        l2[2] = 20.0;
        assert!(cross_entropy(&l2, 2) < 1e-3);
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 5.0, -2.0, 5.0]), 1);
    }
}
