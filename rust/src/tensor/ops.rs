//! NN primitives shared by the Rust reference engine and the trainers.
//!
//! These mirror the jnp ops in `python/compile/kernels/ref.py` exactly —
//! the cross-validation test (`rust/tests/integration_runtime.rs`) asserts
//! the Rust engine and the AOT HLO agree, which only holds if both sides
//! use the same formulations (RMSNorm without bias, rotate-half RoPE,
//! softmax with max-subtraction).

use super::Mat;

/// In-place numerically-stable softmax over each row, restricted to the
/// first `valid` columns (the rest are treated as masked and set to 0).
pub fn softmax_rows_masked(m: &mut Mat, valid: usize) {
    let valid = valid.min(m.cols);
    for i in 0..m.rows {
        let row = m.row_mut(i);
        let mut mx = f32::NEG_INFINITY;
        for &v in &row[..valid] {
            mx = mx.max(v);
        }
        let mut sum = 0.0f32;
        for v in &mut row[..valid] {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in &mut row[..valid] {
            *v *= inv;
        }
        for v in &mut row[valid..] {
            *v = 0.0;
        }
    }
}

/// Full-row softmax.
pub fn softmax_rows(m: &mut Mat) {
    let c = m.cols;
    softmax_rows_masked(m, c);
}

/// Causal softmax: row `i` may attend to columns `0..=i + offset`.
/// `offset` is the number of cached tokens preceding this block
/// (prefill uses offset 0; decode of token n uses a 1-row score with
/// offset n).
pub fn softmax_causal(m: &mut Mat, offset: usize) {
    for i in 0..m.rows {
        let valid = (i + offset + 1).min(m.cols);
        let row = m.row_mut(i);
        let mut mx = f32::NEG_INFINITY;
        for &v in &row[..valid] {
            mx = mx.max(v);
        }
        let mut sum = 0.0f32;
        for v in &mut row[..valid] {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in &mut row[..valid] {
            *v *= inv;
        }
        for v in &mut row[valid..] {
            *v = 0.0;
        }
    }
}

/// RMSNorm: `x * g / sqrt(mean(x^2) + eps)` per row.
pub fn rmsnorm(x: &[f32], gain: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), gain.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, &xv), &g) in out.iter_mut().zip(x).zip(gain) {
        *o = xv * inv * g;
    }
}

/// Row-wise RMSNorm over a matrix.
pub fn rmsnorm_rows(m: &Mat, gain: &[f32], eps: f32) -> Mat {
    let mut out = Mat::zeros(m.rows, m.cols);
    for i in 0..m.rows {
        let (src, dst) = (m.row(i), &mut out.data[i * m.cols..(i + 1) * m.cols]);
        rmsnorm(src, gain, eps, dst);
    }
    out
}

/// SiLU (swish) activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

pub fn silu_inplace(m: &mut Mat) {
    for v in m.data.iter_mut() {
        *v = silu(*v);
    }
}

/// Rotate-half RoPE applied in place to one token's d-dim head vector.
///
/// Matches the L2 model: for pair `(x[i], x[i + d/2])`,
/// `theta_i = base^(-2i/d)`, angle `= pos * theta_i`.
pub fn rope_rotate(x: &mut [f32], pos: usize, base: f32) {
    let d = x.len();
    let half = d / 2;
    for i in 0..half {
        let theta = base.powf(-2.0 * i as f32 / d as f32);
        let angle = pos as f32 * theta;
        let (sin, cos) = angle.sin_cos();
        let (a, b) = (x[i], x[i + half]);
        x[i] = a * cos - b * sin;
        x[i + half] = a * sin + b * cos;
    }
}

/// Apply RoPE per-head to a `[tokens, n_heads*d_head]` matrix where token
/// `t` has absolute position `pos0 + t`.
pub fn rope_rows(m: &mut Mat, n_heads: usize, pos0: usize, base: f32) {
    let d_head = m.cols / n_heads;
    for t in 0..m.rows {
        let row = m.row_mut(t);
        for h in 0..n_heads {
            rope_rotate(&mut row[h * d_head..(h + 1) * d_head], pos0 + t, base);
        }
    }
}

/// Argmax over a slice.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// log-softmax cross-entropy of one row of logits against a target id.
pub fn cross_entropy(logits: &[f32], target: usize) -> f32 {
    let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse = logits.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx;
    lse - logits[target]
}

/// Mean cross-entropy over `[tokens, vocab]` logits vs target ids.
pub fn cross_entropy_rows(logits: &Mat, targets: &[usize]) -> f32 {
    assert_eq!(logits.rows, targets.len());
    let mut s = 0.0;
    for (i, &t) in targets.iter().enumerate() {
        s += cross_entropy(logits.row(i), t);
    }
    s / targets.len().max(1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Pcg64::new(1);
        let mut m = Mat::randn(4, 9, 3.0, &mut rng);
        softmax_rows(&mut m);
        for i in 0..4 {
            let s: f32 = m.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(m.row(i).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_masked_zeroes_tail() {
        let mut m = Mat::from_vec(1, 4, vec![1.0, 2.0, 100.0, 200.0]);
        softmax_rows_masked(&mut m, 2);
        assert_eq!(m.at(0, 2), 0.0);
        assert_eq!(m.at(0, 3), 0.0);
        let s: f32 = m.row(0).iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let mut a = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let mut b = Mat::from_vec(1, 3, vec![1001.0, 1002.0, 1003.0]);
        softmax_rows(&mut a);
        softmax_rows(&mut b);
        assert!(a.allclose(&b, 1e-5));
    }

    #[test]
    fn causal_mask_pattern() {
        let mut m = Mat::from_vec(3, 3, vec![0.0; 9]);
        softmax_causal(&mut m, 0);
        // row 0 attends only to col 0
        assert_eq!(m.row(0), &[1.0, 0.0, 0.0]);
        // row 1 splits between 0 and 1
        assert!((m.at(1, 0) - 0.5).abs() < 1e-6);
        assert_eq!(m.at(1, 2), 0.0);
        // row 2 uniform over all three
        assert!((m.at(2, 2) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0f32, -4.0];
        let g = vec![1.0f32, 1.0];
        let mut out = vec![0.0f32; 2];
        rmsnorm(&x, &g, 0.0, &mut out);
        // mean square = 12.5, rms = 3.5355
        assert!((out[0] - 3.0 / 12.5f32.sqrt()).abs() < 1e-5);
        let ms = out.iter().map(|v| v * v).sum::<f32>() / 2.0;
        assert!((ms - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rope_preserves_norm_and_position_zero_identity() {
        let mut rng = Pcg64::new(2);
        let mut x: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let orig = x.clone();
        let n0: f32 = x.iter().map(|v| v * v).sum();
        rope_rotate(&mut x, 0, 10000.0);
        assert_eq!(x, orig, "pos 0 must be identity");
        rope_rotate(&mut x, 17, 10000.0);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-4, "rotation must preserve norm");
    }

    #[test]
    fn rope_relative_property() {
        // <RoPE(q,m), RoPE(k,n)> depends only on m-n: shift both by +s.
        let mut rng = Pcg64::new(3);
        let q: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
        let k: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
        let dotp = |a: &[f32], b: &[f32]| a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>();
        let rot = |v: &[f32], p: usize| {
            let mut w = v.to_vec();
            rope_rotate(&mut w, p, 10000.0);
            w
        };
        let d1 = dotp(&rot(&q, 5), &rot(&k, 2));
        let d2 = dotp(&rot(&q, 15), &rot(&k, 12));
        assert!((d1 - d2).abs() < 1e-3, "{d1} vs {d2}");
    }

    #[test]
    fn cross_entropy_basics() {
        // uniform logits -> ln(V)
        let l = vec![0.0f32; 8];
        assert!((cross_entropy(&l, 3) - (8.0f32).ln()).abs() < 1e-5);
        // confident correct answer -> ~0
        let mut l2 = vec![-20.0f32; 8];
        l2[2] = 20.0;
        assert!(cross_entropy(&l2, 2) < 1e-3);
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 5.0, -2.0, 5.0]), 1);
    }
}
