//! Dense f32 matrix type + numerical kernels.
//!
//! Everything quality-critical in the Rust layer (reference engine,
//! SVD/ASVD initialization, reconstruction fine-tuning) runs on [`Mat`],
//! a row-major `f32` matrix. Submodules:
//!
//! * [`matmul`] — cache-blocked GEMM (the L3 hot path; see §Perf).
//! * [`simd`] — explicit-width 8-lane AXPY/dot kernels (runtime-detected;
//!   `matmul` dispatches to them behind the `simd` cargo feature).
//! * [`ops`] — NN primitives: softmax, RMSNorm, SiLU, RoPE, cross-entropy.
//! * [`linalg`] — Householder QR, triangular solves, least squares.
//! * [`svd`] — one-sided Jacobi SVD (used by SVD/ASVD init and Figure 3).

pub mod linalg;
pub mod matmul;
pub mod ops;
pub mod simd;
pub mod svd;

/// Row-major dense matrix of `f32`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl std::fmt::Debug for Mat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mat[{}x{}]", self.rows, self.cols)?;
        if self.rows * self.cols <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Mat {
    // ----- construction --------------------------------------------------

    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f32) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    pub fn eye(n: usize) -> Mat {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Gaussian random matrix (used by weight init and tests).
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut crate::util::prng::Pcg64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    // ----- element access -------------------------------------------------

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    // ----- shape ops -------------------------------------------------------

    /// Transpose (materialized).
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Copy of rows `lo..hi`.
    pub fn rows_slice(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows);
        Mat::from_vec(
            hi - lo,
            self.cols,
            self.data[lo * self.cols..hi * self.cols].to_vec(),
        )
    }

    /// Copy of columns `lo..hi`.
    pub fn cols_slice(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.cols);
        let mut out = Mat::zeros(self.rows, hi - lo);
        for i in 0..self.rows {
            out.row_mut(i)
                .copy_from_slice(&self.row(i)[lo..hi]);
        }
        out
    }

    /// Vertical concatenation.
    pub fn vcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut data = Vec::with_capacity((self.rows + other.rows) * self.cols);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Mat::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Horizontal concatenation.
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    // ----- arithmetic -------------------------------------------------------

    /// `self @ other` via the blocked GEMM in [`matmul`].
    pub fn matmul(&self, other: &Mat) -> Mat {
        matmul::matmul(self, other)
    }

    /// `self @ other.T` without materializing the transpose.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        matmul::matmul_nt(self, other)
    }

    /// `self.T @ other` without materializing the transpose.
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        matmul::matmul_tn(self, other)
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        out
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
        out
    }

    pub fn scale(&self, s: f32) -> Mat {
        let mut out = self.clone();
        for a in out.data.iter_mut() {
            *a *= s;
        }
        out
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// AXPY: `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scale column `j` by `s` (used by ASVD's activation scaling).
    pub fn scale_col(&mut self, j: usize, s: f32) {
        for i in 0..self.rows {
            self.data[i * self.cols + j] *= s;
        }
    }

    /// Scale row `i` by `s`.
    pub fn scale_row(&mut self, i: usize, s: f32) {
        for v in self.row_mut(i) {
            *v *= s;
        }
    }

    // ----- reductions -------------------------------------------------------

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Mean of squared entries — the paper's reconstruction MSE.
    pub fn mse(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = a - b;
                d * d
            })
            .sum::<f32>()
            / n as f32
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Column-wise mean of |x| (ASVD "Absolute Mean Value" scaling).
    pub fn col_abs_mean(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            for (j, v) in self.row(i).iter().enumerate() {
                out[j] += v.abs();
            }
        }
        let n = self.rows.max(1) as f32;
        for v in &mut out {
            *v /= n;
        }
        out
    }

    /// Max |a-b| — used by allclose-style assertions in tests.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    pub fn allclose(&self, other: &Mat, atol: f32) -> bool {
        self.rows == other.rows && self.cols == other.cols && self.max_abs_diff(other) <= atol
    }

    // ----- serialization (little-endian f32 blob) ----------------------------

    pub fn write_to(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.rows as u64).to_le_bytes());
        out.extend_from_slice(&(self.cols as u64).to_le_bytes());
        for x in &self.data {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn read_from(buf: &[u8], pos: &mut usize) -> anyhow::Result<Mat> {
        let take = |pos: &mut usize, n: usize| -> anyhow::Result<&[u8]> {
            if *pos + n > buf.len() {
                anyhow::bail!("truncated Mat blob at offset {}", *pos);
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let rows = u64::from_le_bytes(take(pos, 8)?.try_into().unwrap()) as usize;
        let cols = u64::from_le_bytes(take(pos, 8)?.try_into().unwrap()) as usize;
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(f32::from_le_bytes(take(pos, 4)?.try_into().unwrap()));
        }
        Ok(Mat::from_vec(rows, cols, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn construct_and_index() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(m.col(2), vec![2.0, 5.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(1);
        let m = Mat::randn(5, 7, 1.0, &mut rng);
        assert_eq!(m.t().t(), m);
    }

    #[test]
    fn slicing_and_concat_roundtrip() {
        let mut rng = Pcg64::new(2);
        let m = Mat::randn(6, 4, 1.0, &mut rng);
        let top = m.rows_slice(0, 2);
        let bot = m.rows_slice(2, 6);
        assert_eq!(top.vcat(&bot), m);
        let left = m.cols_slice(0, 1);
        let right = m.cols_slice(1, 4);
        assert_eq!(left.hcat(&right), m);
    }

    #[test]
    fn arithmetic() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::eye(2);
        assert_eq!(a.add(&b).at(0, 0), 2.0);
        assert_eq!(a.sub(&b).at(1, 1), 3.0);
        assert_eq!(a.scale(2.0).at(0, 1), 4.0);
        let mut c = a.clone();
        c.axpy(0.5, &b);
        assert_eq!(c.at(0, 0), 1.5);
    }

    #[test]
    fn mse_and_norms() {
        let a = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::zeros(1, 4);
        assert!((a.mse(&b) - 7.5).abs() < 1e-6);
        assert!((a.frob_norm() - 30.0f32.sqrt()).abs() < 1e-6);
        assert_eq!(a.abs_max(), 4.0);
    }

    #[test]
    fn col_abs_mean() {
        let a = Mat::from_vec(2, 2, vec![1.0, -2.0, 3.0, -4.0]);
        assert_eq!(a.col_abs_mean(), vec![2.0, 3.0]);
    }

    #[test]
    fn serialization_roundtrip() {
        let mut rng = Pcg64::new(3);
        let m = Mat::randn(3, 5, 2.0, &mut rng);
        let mut buf = Vec::new();
        m.write_to(&mut buf);
        let mut pos = 0;
        let n = Mat::read_from(&buf, &mut pos).unwrap();
        assert_eq!(m, n);
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn serialization_rejects_truncated() {
        let m = Mat::eye(4);
        let mut buf = Vec::new();
        m.write_to(&mut buf);
        buf.truncate(buf.len() - 3);
        let mut pos = 0;
        assert!(Mat::read_from(&buf, &mut pos).is_err());
    }
}
