//! Singular value decomposition via one-sided Jacobi rotations.
//!
//! Used by:
//! * SVD / ASVD initialization of the low-rank factors `A, B` (§2.2 of the
//!   paper) — `rust/src/compress/svd_init.rs`;
//! * the Figure 3 analysis (singular value distribution of the key cache).
//!
//! One-sided Jacobi orthogonalizes the columns of `A·V` by plane rotations;
//! it is simple, numerically robust, and plenty fast at our sizes
//! (n ≤ 256). Singular values come out as column norms.

use super::Mat;

/// Result of `A = U · diag(s) · Vᵀ` with `U: m×k`, `s: k`, `V: n×k`,
/// `k = min(m, n)`. Singular values are sorted descending.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f32>,
    pub v: Mat,
}

impl Svd {
    /// Reconstruct `U[:, :r] · diag(s[:r]) · V[:, :r]ᵀ`.
    pub fn reconstruct(&self, r: usize) -> Mat {
        let r = r.min(self.s.len());
        let mut us = self.u.cols_slice(0, r);
        for (j, &sv) in self.s[..r].iter().enumerate() {
            us.scale_col(j, sv);
        }
        us.matmul_nt(&self.v.cols_slice(0, r))
    }

    /// Rank-r factor split `A = U·diag(s), B = Vᵀ` (so `A·B ≈` input).
    /// The √s split used by the paper's init lives in `compress::svd_init`.
    pub fn factors(&self, r: usize) -> (Mat, Mat) {
        let r = r.min(self.s.len());
        let mut a = self.u.cols_slice(0, r);
        for (j, &sv) in self.s[..r].iter().enumerate() {
            a.scale_col(j, sv);
        }
        let b = self.v.cols_slice(0, r).t();
        (a, b)
    }
}

/// Compute the thin SVD of `a` by one-sided Jacobi.
///
/// Handles `m < n` by transposing internally.
pub fn svd(a: &Mat) -> Svd {
    if a.rows < a.cols {
        // A = U S Vt  <=>  At = V S Ut
        let t = svd(&a.t());
        return Svd {
            u: t.v,
            s: t.s,
            v: t.u,
        };
    }
    let m = a.rows;
    let n = a.cols;
    // Work on columns of G (copy of A); accumulate V.
    let mut g = a.clone();
    let mut v = Mat::eye(n);

    let eps = 1e-10f64;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries over columns p and q.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let gp = g.data[i * n + p] as f64;
                    let gq = g.data[i * n + q] as f64;
                    app += gp * gp;
                    aqq += gq * gq;
                    apq += gp * gq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() + 1e-30 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for i in 0..m {
                    let gp = g.data[i * n + p];
                    let gq = g.data[i * n + q];
                    g.data[i * n + p] = cf * gp - sf * gq;
                    g.data[i * n + q] = sf * gp + cf * gq;
                }
                for i in 0..n {
                    let vp = v.data[i * n + p];
                    let vq = v.data[i * n + q];
                    v.data[i * n + p] = cf * vp - sf * vq;
                    v.data[i * n + q] = sf * vp + cf * vq;
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }

    // Column norms = singular values; normalize to get U.
    let mut svals: Vec<(f32, usize)> = (0..n)
        .map(|j| {
            let norm = (0..m)
                .map(|i| {
                    let x = g.data[i * n + j] as f64;
                    x * x
                })
                .sum::<f64>()
                .sqrt() as f32;
            (norm, j)
        })
        .collect();
    svals.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut u = Mat::zeros(m, n);
    let mut vv = Mat::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (out_j, &(norm, j)) in svals.iter().enumerate() {
        s.push(norm);
        let inv = if norm > 1e-20 { 1.0 / norm } else { 0.0 };
        for i in 0..m {
            u.data[i * n + out_j] = g.data[i * n + j] * inv;
        }
        for i in 0..n {
            vv.data[i * n + out_j] = v.data[i * n + j];
        }
    }
    Svd { u, s, v: vv }
}

/// Singular values only (cheaper to call for Figure 3 dumps).
pub fn singular_values(a: &Mat) -> Vec<f32> {
    svd(a).s
}

/// Best rank-r approximation error `‖A - A_r‖_F` (Eckart–Young; equals the
/// l2 norm of the dropped singular-value tail).
pub fn lowrank_error(s: &[f32], r: usize) -> f32 {
    s[r.min(s.len())..].iter().map(|x| x * x).sum::<f32>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn assert_orthonormal_cols(m: &Mat, tol: f32) {
        let g = m.matmul_tn(m);
        for i in 0..g.rows {
            for j in 0..g.cols {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (g.at(i, j) - want).abs() < tol,
                    "gram[{i},{j}]={}",
                    g.at(i, j)
                );
            }
        }
    }

    #[test]
    fn reconstructs_random_matrix() {
        let mut rng = Pcg64::new(1);
        for (m, n) in [(8, 8), (20, 7), (7, 20), (33, 15)] {
            let a = Mat::randn(m, n, 1.0, &mut rng);
            let d = svd(&a);
            let full = d.reconstruct(n.min(m));
            assert!(
                full.allclose(&a, 1e-3),
                "({m},{n}) diff={}",
                full.max_abs_diff(&a)
            );
            assert_orthonormal_cols(&d.u, 1e-3);
            assert_orthonormal_cols(&d.v, 1e-3);
        }
    }

    #[test]
    fn singular_values_sorted_nonnegative() {
        let mut rng = Pcg64::new(2);
        let a = Mat::randn(16, 10, 1.0, &mut rng);
        let s = singular_values(&a);
        assert_eq!(s.len(), 10);
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn exact_on_known_diagonal() {
        let a = Mat::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, -5.0, 0.0, 0.0, 0.0, 1.0]);
        let s = singular_values(&a);
        assert!((s[0] - 5.0).abs() < 1e-4);
        assert!((s[1] - 3.0).abs() < 1e-4);
        assert!((s[2] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn lowrank_truncation_is_optimal() {
        // Build a matrix with known rank-2 structure + small noise;
        // rank-2 reconstruction must capture almost everything.
        let mut rng = Pcg64::new(3);
        let u = Mat::randn(24, 2, 1.0, &mut rng);
        let v = Mat::randn(2, 18, 1.0, &mut rng);
        let noise = Mat::randn(24, 18, 0.01, &mut rng);
        let a = u.matmul(&v).add(&noise);
        let d = svd(&a);
        let a2 = d.reconstruct(2);
        let rel = a2.sub(&a).frob_norm() / a.frob_norm();
        assert!(rel < 0.02, "rel={rel}");
        // Eckart–Young consistency
        let tail = lowrank_error(&d.s, 2);
        assert!((a2.sub(&a).frob_norm() - tail).abs() / tail.max(1e-6) < 0.05);
    }

    #[test]
    fn factors_multiply_back() {
        let mut rng = Pcg64::new(4);
        let a = Mat::randn(12, 9, 1.0, &mut rng);
        let d = svd(&a);
        let (fa, fb) = d.factors(9);
        assert!(fa.matmul(&fb).allclose(&a, 1e-3));
    }

    #[test]
    fn frobenius_preserved_by_svals() {
        let mut rng = Pcg64::new(5);
        let a = Mat::randn(15, 11, 1.0, &mut rng);
        let s = singular_values(&a);
        let sn = s.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((sn - a.frob_norm()).abs() < 1e-2);
    }
}
