//! Cache-blocked GEMM — the L3 hot path.
//!
//! Three entry points mirror the BLAS layouts the engine needs without ever
//! materializing transposes:
//!
//! * [`matmul`]   — `C = A·B`
//! * [`matmul_nt`] — `C = A·Bᵀ` (attention scores `Q·Kᵀ`)
//! * [`matmul_tn`] — `C = Aᵀ·B` (gradients `Xᵀ·E` in the recon trainer)
//!
//! Every kernel is built from two primitive reductions, each with a
//! scalar and a SIMD implementation:
//!
//! * **AXPY** (`crow += s·brow`) — [`axpy_row`] dispatches to the 8-lane
//!   [`simd::axpy`](super::simd::axpy) when the `simd` feature is on and
//!   the CPU supports it, else to [`axpy_row_scalar`]. AXPY is
//!   elementwise, and the SIMD body uses separate mul + add (no FMA), so
//!   **the two paths are bit-identical** — every AXPY-shaped kernel
//!   ([`matmul_into`], [`matvec_t_into`], [`matvec_t_batch_into`],
//!   `matmul_tn`, decode attention's value accumulation) produces the
//!   same bits under either feature configuration.
//! * **dot** — [`dot`] dispatches to the 8-lane accumulator
//!   [`simd::dot`](super::simd::dot) or to the 4-accumulator
//!   [`dot_scalar`]. The lane accumulators reassociate the sum, so
//!   dot-shaped kernels ([`matmul_nt_into`], [`matvec_into`], attention
//!   scores) agree with their scalar oracles only to a few ULPs at the
//!   scale of `Σ|xᵢyᵢ|` — the property tests pin this at ≤ 4 ULPs per
//!   depth block (`rust/tests/property_invariants.rs`).
//!
//! The scalar kernels are permanently kept as oracles behind `_scalar`
//! suffixes ([`matmul_into_scalar`], [`matmul_nt_into_scalar`],
//! [`matvec_t_into_scalar`], [`matvec_t_batch_into_scalar`]); the
//! composite kernels share one generic body per shape, so oracle and
//! dispatch variants differ *only* in the primitive they inline.
//!
//! ## Blocking
//!
//! The `A·B` kernel is an i-k-j loop order over `MC×KC` blocks. The
//! `A·Bᵀ` kernel blocks its dots over the same [`KC`] depth window —
//! long-context score panels (`k` = hundreds of channels, `n` = thousands
//! of keys) re-stream the B panel once per depth block from L2 instead of
//! blowing L1 with full-length dots. Per output element the reduction is
//! ascending depth blocks, each block reduced by [`dot`], accumulated in
//! ascending block order.
//!
//! ## Parallel row-block variants
//!
//! [`par_matmul_into`] / [`par_matmul_nt_into`] split the *output rows*
//! across scoped workers via [`parallel_chunks`] (GEMM rows cost the
//! same, so a static partition balances). Each output row is produced by
//! exactly one worker running the identical per-row reduction (ascending
//! `KC` depth blocks, ascending `p` within a block), so the result is
//! **bit-identical to the serial kernels at every thread count** — the
//! prefill bit-identity property test in `rust/tests/
//! property_invariants.rs` rests on this. (This holds under SIMD too:
//! the parallel split is by output row, and each row runs the same
//! dispatched primitive.)
//!
//! The historical `aip == 0.0` skip in the `matmul_into` inner loop was
//! removed: on the dense activations the engine feeds it, the branch
//! cost a compare per element and never fired. `matmul_tn` keeps its
//! skip — recon-trainer gradients are the one genuinely sparse-ish
//! operand left. `bench_perf_prefill` records the dense before/after
//! numbers plus the scalar-vs-SIMD and nt-blocking A/B rows.
//!
//! ## Batched decode projections
//!
//! [`matvec_t_batch_into`] is the serving coordinator's GEMM-batched
//! decode kernel: one (input-dim, batch) pass that streams each weight
//! row once across all in-flight sequences while keeping every output
//! row's reduction semantics identical to [`matvec_t_into`] — so fused
//! decode rounds are bit-identical to per-sequence GEMVs (and, being
//! AXPY-shaped, bit-identical across feature configurations too).

use crate::util::threadpool::{parallel_chunks, SendPtr};

#[cfg(feature = "simd")]
use super::simd;

use super::Mat;

/// Row-block size (fits a block of A in L1 alongside the B panel); also
/// the unit of work handed to one parallel task.
const MC: usize = 64;
/// Depth-block size, shared by the i-k-j GEMM and the `A·Bᵀ` dot kernel
/// (public so benches can align their A/B shapes with the blocking).
pub const KC: usize = 256;

// ---------------------------------------------------------------------------
// Primitive reductions: dispatching entry points + scalar oracles.
// ---------------------------------------------------------------------------

/// `crow += s * brow` — the shared AXPY kernel behind the GEMM inner
/// loop, `matvec_t_into`, and the decode attention's per-head weighted
/// value sum. Dispatches to the 8-lane SIMD kernel when available;
/// **bit-identical** to [`axpy_row_scalar`] either way (elementwise op,
/// no FMA).
#[inline]
pub fn axpy_row(crow: &mut [f32], s: f32, brow: &[f32]) {
    #[cfg(feature = "simd")]
    if simd::available() {
        // Safety: guarded by simd::available().
        unsafe { simd::axpy(crow, s, brow) };
        return;
    }
    axpy_row_scalar(crow, s, brow);
}

/// Scalar AXPY oracle: 8-way unrolled `crow[o] += s * brow[o]`.
#[inline]
pub fn axpy_row_scalar(crow: &mut [f32], s: f32, brow: &[f32]) {
    let n = crow.len();
    let chunks = n / 8;
    // Unrolled body — the compiler autovectorizes this reliably.
    for c in 0..chunks {
        let o = c * 8;
        crow[o] += s * brow[o];
        crow[o + 1] += s * brow[o + 1];
        crow[o + 2] += s * brow[o + 2];
        crow[o + 3] += s * brow[o + 3];
        crow[o + 4] += s * brow[o + 4];
        crow[o + 5] += s * brow[o + 5];
        crow[o + 6] += s * brow[o + 6];
        crow[o + 7] += s * brow[o + 7];
    }
    for o in chunks * 8..n {
        crow[o] += s * brow[o];
    }
}

/// Dot product. Dispatches to the 8-lane SIMD kernel when available;
/// agrees with [`dot_scalar`] to a few ULPs (lane accumulators
/// reassociate), **not** bit-identically — see the module docs.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    #[cfg(feature = "simd")]
    if simd::available() {
        // Safety: guarded by simd::available().
        return unsafe { simd::dot(x, y) };
    }
    dot_scalar(x, y)
}

/// Scalar dot oracle: 4 running accumulators (breaks the FP dependency
/// chain), summed `s0+s1+s2+s3`, then a sequential remainder tail.
#[inline]
pub fn dot_scalar(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let o = c * 4;
        s0 += x[o] * y[o];
        s1 += x[o + 1] * y[o + 1];
        s2 += x[o + 2] * y[o + 2];
        s3 += x[o + 3] * y[o + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for o in chunks * 4..n {
        s += x[o] * y[o];
    }
    s
}

// ---------------------------------------------------------------------------
// C = A·B
// ---------------------------------------------------------------------------

/// `C = A·B`.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols, b.rows,
        "matmul shape mismatch: {}x{} @ {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// `C = A·B` into a preallocated output (zero-alloc decode loop).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let m = a.rows;
    let n = b.cols;
    // Blocked i-k-j: for each (row-block, depth-block), stream B rows.
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + MC).min(m);
        matmul_row_block(a, b, &mut c.data[i0 * n..i1 * n], i0, i1);
        i0 = i1;
    }
}

/// Scalar oracle for [`matmul_into`]: identical blocking and loop order,
/// AXPY pinned to [`axpy_row_scalar`]. Bit-identical to the dispatching
/// kernel on every input (AXPY contract).
pub fn matmul_into_scalar(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let m = a.rows;
    let n = b.cols;
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + MC).min(m);
        matmul_row_block_with(a, b, &mut c.data[i0 * n..i1 * n], i0, i1, &axpy_row_scalar);
        i0 = i1;
    }
}

/// Compute output rows `[i0, i1)` of `C = A·B` into `c_rows` (a buffer
/// whose first element is `C[i0][0]`). The per-row reduction order —
/// ascending `KC` depth blocks, ascending `p` within a block — is the
/// single definition shared by the serial, parallel and scalar-oracle
/// entry points, so all produce identical bits for every row.
fn matmul_row_block(a: &Mat, b: &Mat, c_rows: &mut [f32], i0: usize, i1: usize) {
    matmul_row_block_with(a, b, c_rows, i0, i1, &axpy_row);
}

/// Shared `A·B` row-block body, generic over the AXPY primitive so the
/// dispatching kernel and the scalar oracle are the same code.
#[inline(always)]
fn matmul_row_block_with<F: Fn(&mut [f32], f32, &[f32])>(
    a: &Mat,
    b: &Mat,
    c_rows: &mut [f32],
    i0: usize,
    i1: usize,
    axpy: &F,
) {
    let (k, n) = (a.cols, b.cols);
    debug_assert_eq!(c_rows.len(), (i1 - i0) * n);
    c_rows.fill(0.0);
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KC).min(k);
        for i in i0..i1 {
            let arow = &a.data[i * k..(i + 1) * k];
            let crow = &mut c_rows[(i - i0) * n..(i - i0 + 1) * n];
            for p in k0..k1 {
                // Dense inner loop — no `aip == 0.0` skip: on dense
                // activations the branch never fires and costs a compare
                // per element (A/B'd in bench_perf_prefill).
                let brow = &b.data[p * n..(p + 1) * n];
                axpy(crow, arow[p], brow);
            }
        }
        k0 = k1;
    }
}

/// `C = A·B` with output rows split across up to `threads` scoped workers
/// via [`parallel_chunks`], each worker running the serial `MC`-blocked
/// kernel over its contiguous row range. Bit-identical to [`matmul_into`]
/// at every thread count (each row's reduction runs the same
/// [`matmul_row_block`] code on exactly one worker).
pub fn par_matmul_into(a: &Mat, b: &Mat, c: &mut Mat, threads: usize) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let threads = threads.max(1);
    let m = a.rows;
    if threads == 1 || m <= MC {
        matmul_into(a, b, c);
        return;
    }
    let n = b.cols;
    let ptr = SendPtr(c.data.as_mut_ptr());
    parallel_chunks(m, threads, |lo, hi| {
        let mut i0 = lo;
        while i0 < hi {
            let i1 = (i0 + MC).min(hi);
            // Safety: chunks are disjoint row ranges of `c.data`, each
            // handed to exactly one worker, and `c` outlives the scoped
            // workers.
            let c_rows = unsafe { ptr.slice_mut(i0 * n, (i1 - i0) * n) };
            matmul_row_block(a, b, c_rows, i0, i1);
            i0 = i1;
        }
    });
}

// ---------------------------------------------------------------------------
// C = A·Bᵀ
// ---------------------------------------------------------------------------

/// `C = A·Bᵀ` — both operands are traversed row-wise, so attention scores
/// against a row-major K cache need no transpose copy.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols, b.cols,
        "matmul_nt shape mismatch: {}x{} @ ({}x{})ᵀ",
        a.rows, a.cols, b.rows, b.cols
    );
    let mut c = Mat::zeros(a.rows, b.rows);
    matmul_nt_into(a, b, &mut c);
    c
}

/// `C = A·Bᵀ` into a preallocated output.
pub fn matmul_nt_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.rows));
    let n = b.rows;
    matmul_nt_row_block(a, b, &mut c.data[..a.rows * n], 0, a.rows);
}

/// Scalar oracle for [`matmul_nt_into`]: identical `KC` depth blocking,
/// dot pinned to [`dot_scalar`]. Agrees with the dispatching kernel to
/// the documented per-depth-block ULP tolerance.
pub fn matmul_nt_into_scalar(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.rows));
    let n = b.rows;
    matmul_nt_row_block_with(a, b, &mut c.data[..a.rows * n], 0, a.rows, &dot_scalar);
}

/// Output rows `[i0, i1)` of `C = A·Bᵀ` into `c_rows` (first element is
/// `C[i0][0]`). Shared by the serial and parallel entry points.
fn matmul_nt_row_block(a: &Mat, b: &Mat, c_rows: &mut [f32], i0: usize, i1: usize) {
    matmul_nt_row_block_with(a, b, c_rows, i0, i1, &dot);
}

/// Shared `A·Bᵀ` row-block body: `KC`-blocked dots so a long-`k` score
/// panel streams the B panel once per depth block instead of running
/// full-length dots per output element. Per element the reduction is
/// ascending depth blocks (`crow[j] += dot(block)`), each block reduced
/// by the supplied primitive — one definition for the serial, parallel
/// and scalar-oracle entry points.
#[inline(always)]
fn matmul_nt_row_block_with<F: Fn(&[f32], &[f32]) -> f32>(
    a: &Mat,
    b: &Mat,
    c_rows: &mut [f32],
    i0: usize,
    i1: usize,
    dotf: &F,
) {
    let k = a.cols;
    let n = b.rows;
    debug_assert_eq!(c_rows.len(), (i1 - i0) * n);
    c_rows.fill(0.0);
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KC).min(k);
        for i in i0..i1 {
            let arow = &a.data[i * k + k0..i * k + k1];
            let crow = &mut c_rows[(i - i0) * n..(i - i0 + 1) * n];
            for (j, cj) in crow.iter_mut().enumerate() {
                *cj += dotf(arow, &b.data[j * k + k0..j * k + k1]);
            }
        }
        k0 = k1;
    }
}

/// `C = A·Bᵀ` with output rows split across up to `threads` scoped
/// workers via [`parallel_chunks`]. Bit-identical to [`matmul_nt_into`]
/// at every thread count.
pub fn par_matmul_nt_into(a: &Mat, b: &Mat, c: &mut Mat, threads: usize) {
    assert_eq!(a.cols, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.rows));
    let threads = threads.max(1);
    let m = a.rows;
    if threads == 1 || m <= MC {
        matmul_nt_into(a, b, c);
        return;
    }
    let n = b.rows;
    let ptr = SendPtr(c.data.as_mut_ptr());
    parallel_chunks(m, threads, |lo, hi| {
        // Safety: disjoint row ranges, one worker each; `c` outlives the
        // scoped workers.
        let c_rows = unsafe { ptr.slice_mut(lo * n, (hi - lo) * n) };
        matmul_nt_row_block(a, b, c_rows, lo, hi);
    });
}

// ---------------------------------------------------------------------------
// C = Aᵀ·B and GEMVs
// ---------------------------------------------------------------------------

/// `C = Aᵀ·B` (A is m×k ⇒ C is k×n). Streamed as rank-1 updates so A is
/// still read row-major.
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.rows, b.rows,
        "matmul_tn shape mismatch: ({}x{})ᵀ @ {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let (k, n) = (a.cols, b.cols);
    let mut c = Mat::zeros(k, n);
    for i in 0..a.rows {
        let arow = a.row(i);
        let brow = &b.data[i * n..(i + 1) * n];
        for (p, &ap) in arow.iter().enumerate() {
            if ap == 0.0 {
                continue;
            }
            axpy_row(&mut c.data[p * n..(p + 1) * n], ap, brow);
        }
    }
    c
}

/// `y = A·x` for a vector `x` (decode-time projections).
pub fn matvec(a: &Mat, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0f32; a.rows];
    matvec_into(a, x, &mut y);
    y
}

/// `y = A·x` into a preallocated output (zero-alloc decode loop).
pub fn matvec_into(a: &Mat, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    for (i, yi) in y.iter_mut().enumerate() {
        *yi = dot(a.row(i), x);
    }
}

/// `y = Aᵀ·x` (single-token projection against a row-major weight).
pub fn matvec_t(a: &Mat, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0f32; a.cols];
    matvec_t_into(a, x, &mut y);
    y
}

/// `y = Aᵀ·x` into a preallocated output (zero-alloc decode loop).
pub fn matvec_t_into(a: &Mat, x: &[f32], y: &mut [f32]) {
    matvec_t_into_with(a, x, y, &axpy_row);
}

/// Scalar oracle for [`matvec_t_into`] (AXPY-shaped ⇒ bit-identical to
/// the dispatching kernel).
pub fn matvec_t_into_scalar(a: &Mat, x: &[f32], y: &mut [f32]) {
    matvec_t_into_with(a, x, y, &axpy_row_scalar);
}

/// Shared `Aᵀ·x` body: ascending input dim, `xi == 0.0` contributions
/// skipped (the skip is part of the reduction semantics the batched
/// kernel replicates).
#[inline(always)]
fn matvec_t_into_with<F: Fn(&mut [f32], f32, &[f32])>(a: &Mat, x: &[f32], y: &mut [f32], axpy: &F) {
    assert_eq!(a.rows, x.len());
    assert_eq!(a.cols, y.len());
    y.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        axpy(y, xi, a.row(i));
    }
}

/// `Y[b] = Aᵀ·X[b]` for a stack of input rows — the GEMM-batched decode
/// projection. `A` is the `[d_in, d_out]` row-major weight, `xs` holds
/// one input row per in-flight sequence (`[B, d_in]`) and `ys` the
/// outputs (`[B, d_out]`).
///
/// The loop order is (input dim, batch): each weight row is loaded
/// **once** and applied to every sequence while it is hot, so a decode
/// round streams the weight set once instead of once per sequence — the
/// whole point of batching GEMV-bound decode. Per output row the
/// reduction is ascending input dim with `xi == 0.0` contributions
/// skipped, i.e. *exactly* [`matvec_t_into`]'s semantics, so a batched
/// round is bit-identical to `B` independent GEMV calls at any batch
/// size (`rust/tests/batched_serving.rs` holds the oracle).
pub fn matvec_t_batch_into(a: &Mat, xs: &Mat, ys: &mut Mat) {
    matvec_t_batch_into_with(a, xs, ys, &axpy_row);
}

/// Scalar oracle for [`matvec_t_batch_into`] (AXPY-shaped ⇒
/// bit-identical to the dispatching kernel; `bench_perf_decode` A/Bs the
/// two on the batched decode projection shape).
pub fn matvec_t_batch_into_scalar(a: &Mat, xs: &Mat, ys: &mut Mat) {
    matvec_t_batch_into_with(a, xs, ys, &axpy_row_scalar);
}

/// Shared batched-GEMV body, generic over the AXPY primitive.
#[inline(always)]
fn matvec_t_batch_into_with<F: Fn(&mut [f32], f32, &[f32])>(
    a: &Mat,
    xs: &Mat,
    ys: &mut Mat,
    axpy: &F,
) {
    assert_eq!(a.rows, xs.cols);
    assert_eq!(a.cols, ys.cols);
    assert_eq!(xs.rows, ys.rows);
    ys.data.fill(0.0);
    for i in 0..a.rows {
        let arow = a.row(i);
        for b in 0..xs.rows {
            let xi = xs.at(b, i);
            if xi == 0.0 {
                continue;
            }
            axpy(ys.row_mut(b), xi, arow);
        }
    }
}

/// Minimum output-column span worth handing to one worker: below this the
/// pool dispatch costs more than the AXPY slices it parallelizes.
const BATCH_GEMV_MIN_COLS: usize = 64;

/// [`matvec_t_batch_into`] with the **output columns** split into
/// contiguous blocks across up to `threads` pooled workers — the
/// decode-side threading for GEMM-batched serving rounds (large `B ×
/// d_ff` down-projections are where the column split pays).
///
/// Each worker runs the full (input-dim, batch) loop over its own column
/// block `[c0, c1)`: it streams its slice of every weight row exactly
/// once (total weight traffic unchanged) and writes a disjoint column
/// range of every output row. Per output element the reduction is the
/// same ascending-input-dim order with the same `xi == 0.0` skip as the
/// serial kernel, so the result is **bit-identical to
/// [`matvec_t_batch_into`] at every thread count** — AXPY is
/// elementwise, so the column split preserves bits even under SIMD. The
/// serial kernel stays as the oracle, and
/// `rust/tests/batched_serving.rs` exercises both widths end to end.
pub fn par_matvec_t_batch_into(a: &Mat, xs: &Mat, ys: &mut Mat, threads: usize) {
    assert_eq!(a.rows, xs.cols);
    assert_eq!(a.cols, ys.cols);
    assert_eq!(xs.rows, ys.rows);
    let threads = threads.max(1).min(a.cols / BATCH_GEMV_MIN_COLS);
    if threads <= 1 {
        matvec_t_batch_into(a, xs, ys);
        return;
    }
    let (n_in, n_out, nb) = (a.rows, a.cols, xs.rows);
    let ptr = SendPtr(ys.data.as_mut_ptr());
    parallel_chunks(n_out, threads, |c0, c1| {
        let w = c1 - c0;
        for b in 0..nb {
            // Safety: workers receive disjoint `[c0, c1)` column ranges,
            // so the row-b output slices never overlap, and `ys` outlives
            // the parallel region.
            let yrow = unsafe { ptr.slice_mut(b * n_out + c0, w) };
            yrow.fill(0.0);
        }
        for i in 0..n_in {
            let arow = &a.row(i)[c0..c1];
            for b in 0..nb {
                let xi = xs.at(b, i);
                if xi == 0.0 {
                    continue;
                }
                let yrow = unsafe { ptr.slice_mut(b * n_out + c0, w) };
                axpy_row(yrow, xi, arow);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for p in 0..a.cols {
                    s += a.at(i, p) * b.at(p, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn matches_naive_various_shapes() {
        let mut rng = Pcg64::new(10);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (70, 300, 65), (8, 8, 8)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let r = naive(&a, &b);
            assert!(c.allclose(&r, 1e-3), "({m},{k},{n}) diff={}", c.max_abs_diff(&r));
        }
    }

    #[test]
    fn nt_matches_transpose() {
        let mut rng = Pcg64::new(11);
        let a = Mat::randn(9, 33, 1.0, &mut rng);
        let b = Mat::randn(14, 33, 1.0, &mut rng);
        let c = matmul_nt(&a, &b);
        let r = matmul(&a, &b.t());
        assert!(c.allclose(&r, 1e-4));
    }

    /// The nt depth-blocking must cover depths below, at, straddling and
    /// well above `KC` (multiple blocks + remainder).
    #[test]
    fn nt_blocked_depths_match_transpose() {
        let mut rng = Pcg64::new(17);
        for k in [1usize, KC - 1, KC, KC + 1, 2 * KC + 37] {
            let a = Mat::randn(5, k, 0.5, &mut rng);
            let b = Mat::randn(7, k, 0.5, &mut rng);
            let c = matmul_nt(&a, &b);
            let r = matmul(&a, &b.t());
            assert!(c.allclose(&r, 1e-2), "k={k} diff={}", c.max_abs_diff(&r));
        }
    }

    #[test]
    fn tn_matches_transpose() {
        let mut rng = Pcg64::new(12);
        let a = Mat::randn(21, 6, 1.0, &mut rng);
        let b = Mat::randn(21, 10, 1.0, &mut rng);
        let c = matmul_tn(&a, &b);
        let r = matmul(&a.t(), &b);
        assert!(c.allclose(&r, 1e-4));
    }

    #[test]
    fn matvec_consistent() {
        let mut rng = Pcg64::new(13);
        let a = Mat::randn(7, 12, 1.0, &mut rng);
        let x: Vec<f32> = (0..12).map(|_| rng.normal()).collect();
        let y = matvec(&a, &x);
        let xm = Mat::from_vec(12, 1, x.clone());
        let r = matmul(&a, &xm);
        for i in 0..7 {
            assert!((y[i] - r.at(i, 0)).abs() < 1e-4);
        }
        // transpose form
        let z: Vec<f32> = (0..7).map(|_| rng.normal()).collect();
        let yt = matvec_t(&a, &z);
        let zm = Mat::from_vec(1, 7, z);
        let rt = matmul(&zm, &a);
        for j in 0..12 {
            assert!((yt[j] - rt.at(0, j)).abs() < 1e-4);
        }
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Pcg64::new(14);
        let a = Mat::randn(6, 6, 1.0, &mut rng);
        assert!(matmul(&a, &Mat::eye(6)).allclose(&a, 1e-6));
        assert!(matmul(&Mat::eye(6), &a).allclose(&a, 1e-6));
    }

    #[test]
    fn matvec_into_variants_match_allocating() {
        let mut rng = Pcg64::new(16);
        let a = Mat::randn(9, 13, 1.0, &mut rng);
        let x: Vec<f32> = (0..13).map(|_| rng.normal()).collect();
        let mut y = vec![7.0f32; 9]; // dirty buffer
        matvec_into(&a, &x, &mut y);
        assert_eq!(y, matvec(&a, &x));
        let z: Vec<f32> = (0..9).map(|_| rng.normal()).collect();
        let mut yt = vec![-3.0f32; 13]; // dirty buffer
        matvec_t_into(&a, &z, &mut yt);
        assert_eq!(yt, matvec_t(&a, &z));
    }

    #[test]
    fn into_variants_reuse_buffers() {
        let mut rng = Pcg64::new(15);
        let a = Mat::randn(5, 8, 1.0, &mut rng);
        let b = Mat::randn(8, 3, 1.0, &mut rng);
        let mut c = Mat::from_vec(5, 3, vec![9.0; 15]); // dirty buffer
        matmul_into(&a, &b, &mut c);
        assert!(c.allclose(&naive(&a, &b), 1e-4));
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(4, 2);
        let _ = matmul(&a, &b);
    }

    /// The contract the parallel prefill rests on: the row-block parallel
    /// GEMMs are bit-identical to the serial kernels at every thread
    /// count, including shapes that don't tile evenly and zero-heavy
    /// operands (exercising the removed `aip == 0` fast path).
    #[test]
    fn par_variants_bit_identical_to_serial() {
        let mut rng = Pcg64::new(20);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (65, 33, 9), (130, 300, 17), (200, 8, 3)] {
            let mut a = Mat::randn(m, k, 1.0, &mut rng);
            // Sprinkle exact zeros so the dense inner loop covers them.
            for v in a.data.iter_mut().step_by(7) {
                *v = 0.0;
            }
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let mut want = Mat::zeros(m, n);
            matmul_into(&a, &b, &mut want);
            let bt = Mat::randn(n, k, 1.0, &mut rng);
            let mut want_nt = Mat::zeros(m, n);
            matmul_nt_into(&a, &bt, &mut want_nt);
            for threads in [1usize, 2, 3, 8] {
                let mut got = Mat::from_vec(m, n, vec![5.0; m * n]); // dirty
                par_matmul_into(&a, &b, &mut got, threads);
                assert_eq!(got.data, want.data, "matmul ({m},{k},{n}) threads={threads}");
                let mut got_nt = Mat::from_vec(m, n, vec![-2.0; m * n]);
                par_matmul_nt_into(&a, &bt, &mut got_nt, threads);
                assert_eq!(got_nt.data, want_nt.data, "matmul_nt ({m},{k},{n}) threads={threads}");
            }
        }
    }

    /// The contract the GEMM-batched decode rests on: the batched
    /// projection kernel is bit-identical to independent `matvec_t_into`
    /// calls for every row, including exact-zero inputs (whose skip is
    /// part of the shared reduction semantics).
    #[test]
    fn batch_matvec_t_bit_identical_to_gemv() {
        let mut rng = Pcg64::new(21);
        for (d_in, d_out, batch) in [(1, 1, 1), (5, 3, 2), (33, 17, 8), (64, 96, 3)] {
            let a = Mat::randn(d_in, d_out, 1.0, &mut rng);
            let mut xs = Mat::randn(batch, d_in, 1.0, &mut rng);
            for v in xs.data.iter_mut().step_by(5) {
                *v = 0.0; // exercise the shared zero-skip
            }
            let mut ys = Mat::from_vec(batch, d_out, vec![3.0; batch * d_out]); // dirty
            matvec_t_batch_into(&a, &xs, &mut ys);
            for b in 0..batch {
                let want = matvec_t(&a, xs.row(b));
                assert_eq!(ys.row(b), &want[..], "({d_in},{d_out}) row {b}");
            }
        }
    }

    /// The decode-threading contract: the column-block parallel batched
    /// GEMV is bit-identical to the serial kernel at every thread count,
    /// at widths below (serial fallback), at and above the per-worker
    /// column minimum.
    #[test]
    fn par_batch_matvec_t_bit_identical_at_every_width() {
        let mut rng = Pcg64::new(22);
        for (d_in, d_out, batch) in [(7, 33, 2), (64, 64, 1), (48, 130, 8), (96, 260, 5)] {
            let a = Mat::randn(d_in, d_out, 1.0, &mut rng);
            let mut xs = Mat::randn(batch, d_in, 1.0, &mut rng);
            for v in xs.data.iter_mut().step_by(7) {
                *v = 0.0; // the zero-skip is part of the shared semantics
            }
            let mut want = Mat::zeros(batch, d_out);
            matvec_t_batch_into(&a, &xs, &mut want);
            for threads in [1usize, 2, 3, 8] {
                let mut got = Mat::from_vec(batch, d_out, vec![9.0; batch * d_out]); // dirty
                par_matvec_t_batch_into(&a, &xs, &mut got, threads);
                assert_eq!(
                    got.data, want.data,
                    "({d_in},{d_out},B={batch}) threads={threads}"
                );
            }
        }
    }

    #[test]
    fn dense_inner_loop_handles_all_zero_rows() {
        // A row of exact zeros must still produce a (numerically) zero
        // output row without the old skip branch.
        let a = Mat::zeros(3, 4);
        let b = Mat::from_fn(4, 2, |i, j| -((i + j) as f32) - 1.0);
        let c = matmul(&a, &b);
        assert!(c.data.iter().all(|&v| v == 0.0));
    }
}
