//! # CSKV — Channel Shrinking for the KV Cache
//!
//! Full-system reproduction of *"CSKV: Training-Efficient Channel Shrinking
//! for KV Cache in Long-Context Scenarios"* (Wang et al., 2024).
//!
//! The crate is the **Layer-3 Rust coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the channel-shrink
//!   projection and the fused bi-branch decode attention.
//! * **L2** — JAX model (`python/compile/model.py`): TinyLM forward/backward,
//!   lowered once to HLO text under `artifacts/` by `python/compile/aot.py`.
//! * **L3** — this crate: serving coordinator, bi-branch KV-cache manager,
//!   compression (SVD/ASVD init, int4 quant), layer-wise reconstruction
//!   fine-tuning, baselines (StreamingLLM, H2O, ASVD), synthetic long-context
//!   benchmarks, and a PJRT runtime that executes the AOT artifacts.
//!
//! Python never runs on the request path: after `make artifacts` the binary
//! is self-contained.
//!
//! ## Layout
//!
//! | module | contents |
//! |--------|----------|
//! | [`util`] | offline substrates: PRNG, JSON, CLI, threadpool, stats, bench harness, property testing |
//! | [`tensor`] | matrix type, blocked matmul, Jacobi SVD, QR, NN ops |
//! | [`data`] | synthetic corpus + long-context task generators, vocabulary |
//! | [`model`] | TinyLM config/weights + pure-Rust reference engine |
//! | [`kvcache`] | the paper's contribution: bi-branch cache + policy trait + memory accounting |
//! | [`compress`] | low-rank factors, SVD/ASVD initialization, KIVI-style int4 |
//! | [`baselines`] | StreamingLLM, H2O, ASVD-only cache policies |
//! | [`finetune`] | layer-wise reconstruction trainer (Adam, QAT) |
//! | [`eval`] | synthetic LongEval / LongBench / LVEval harnesses |
//! | [`runtime`] | PJRT client wrapper: load + execute `artifacts/*.hlo.txt` |
//! | [`coordinator`] | request router, continuous batcher, scheduler, metrics |

pub mod baselines;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod finetune;
pub mod kvcache;
pub mod model;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Returns the directory that holds AOT artifacts (`artifacts/` next to the
/// manifest), honouring the `CSKV_ARTIFACTS` override.
pub fn artifacts_dir() -> std::path::PathBuf {
    match std::env::var("CSKV_ARTIFACTS") {
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => std::path::PathBuf::from("artifacts"),
    }
}

/// Returns the directory for run outputs (trained weights, experiment CSVs).
pub fn runs_dir() -> std::path::PathBuf {
    let p = match std::env::var("CSKV_RUNS") {
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => std::path::PathBuf::from("runs"),
    };
    let _ = std::fs::create_dir_all(&p);
    p
}
