//! Model architecture configuration.

use crate::util::json::Json;

/// TinyLM hyperparameters. Must stay in sync with
/// `python/compile/model.py::ModelConfig` — the AOT manifest embeds the
/// config used at lowering time and [`ModelConfig::validate_against_json`]
/// checks it at artifact load.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_base: f32,
    pub eps: f32,
    /// Worker threads for the engine's parallel prefill kernels.
    /// `0` = use the process default
    /// ([`crate::util::threadpool::global_threads`]); results are
    /// bit-identical at every width. A runtime knob, **not** part of the
    /// architecture: excluded from equality, JSON output and the AOT
    /// manifest contract.
    pub threads: usize,
}

/// Architecture equality only — `threads` is a runtime performance knob
/// and deliberately ignored, so a serving config with 8 workers still
/// validates against an AOT manifest lowered with the same architecture.
impl PartialEq for ModelConfig {
    fn eq(&self, other: &Self) -> bool {
        self.vocab_size == other.vocab_size
            && self.d_model == other.d_model
            && self.n_layers == other.n_layers
            && self.n_heads == other.n_heads
            && self.d_ff == other.d_ff
            && self.max_seq == other.max_seq
            && self.rope_base == other.rope_base
            && self.eps == other.eps
    }
}

impl ModelConfig {
    /// The primary evaluation model (stands in for LongChat-7B-v1.5-32k).
    pub fn tiny() -> Self {
        ModelConfig {
            vocab_size: crate::data::vocab::VOCAB_SIZE,
            d_model: 128,
            n_layers: 2,
            n_heads: 4,
            d_ff: 512,
            max_seq: 512,
            rope_base: 10000.0,
            eps: 1e-5,
            threads: 0,
        }
    }

    /// The secondary, wider model (stands in for Mistral-7B-Instruct-v0.2).
    pub fn wide() -> Self {
        ModelConfig {
            d_model: 192,
            n_heads: 6,
            d_ff: 768,
            ..Self::tiny()
        }
    }

    /// A minimal config for fast unit tests.
    pub fn test_small() -> Self {
        ModelConfig {
            vocab_size: crate::data::vocab::VOCAB_SIZE,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            max_seq: 128,
            rope_base: 10000.0,
            eps: 1e-5,
            threads: 0,
        }
    }

    /// Builder-style override of the worker-thread knob.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        let per_layer = 4 * self.d_model * self.d_model   // wq wk wv wo
            + 2 * self.d_model * self.d_ff                 // w1 w2
            + 2 * self.d_model; // ln gains
        self.vocab_size * self.d_model                     // embed
            + self.n_layers * per_layer
            + self.d_model                                  // ln_f
            + self.d_model * self.vocab_size // lm_head
    }

    /// Exact full-precision KV-cache bytes for `tokens` cached tokens
    /// (2 tensors × d_model × f32 per layer) — the paper's intro-claim
    /// accounting, reproduced at scale by `bench_memory`.
    pub fn kv_bytes_full(&self, tokens: usize) -> usize {
        2 * self.n_layers * tokens * self.d_model * 4
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.d_model % self.n_heads == 0, "d_model % n_heads != 0");
        anyhow::ensure!(self.d_head() % 2 == 0, "RoPE needs even d_head");
        anyhow::ensure!(self.vocab_size > 0 && self.n_layers > 0, "degenerate config");
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("vocab_size", self.vocab_size.into()),
            ("d_model", self.d_model.into()),
            ("n_layers", self.n_layers.into()),
            ("n_heads", self.n_heads.into()),
            ("d_ff", self.d_ff.into()),
            ("max_seq", self.max_seq.into()),
            ("rope_base", (self.rope_base as f64).into()),
            ("eps", (self.eps as f64).into()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let need = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("config missing field {k:?}"))
        };
        let cfg = ModelConfig {
            vocab_size: need("vocab_size")? as usize,
            d_model: need("d_model")? as usize,
            n_layers: need("n_layers")? as usize,
            n_heads: need("n_heads")? as usize,
            d_ff: need("d_ff")? as usize,
            max_seq: need("max_seq")? as usize,
            rope_base: need("rope_base")? as f32,
            eps: need("eps")? as f32,
            // Runtime knob, not serialized: manifests and saved weights
            // describe architecture only. 0 = inherit the process default.
            threads: 0,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Check an AOT manifest's embedded config matches this one.
    pub fn validate_against_json(&self, j: &Json) -> anyhow::Result<()> {
        let other = Self::from_json(j)?;
        anyhow::ensure!(
            *self == other,
            "model config mismatch: rust={self:?} manifest={other:?}"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        ModelConfig::tiny().validate().unwrap();
        ModelConfig::wide().validate().unwrap();
        ModelConfig::test_small().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let c = ModelConfig::tiny();
        let j = c.to_json();
        let c2 = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
        c.validate_against_json(&j).unwrap();
    }

    #[test]
    fn mismatch_detected() {
        let c = ModelConfig::tiny();
        let mut j = c.to_json();
        j.set("d_model", 999usize.into());
        assert!(c.validate_against_json(&j).is_err());
    }

    #[test]
    fn threads_knob_is_runtime_only() {
        let c = ModelConfig::tiny();
        let c8 = c.clone().with_threads(8);
        assert_eq!(c8.threads, 8);
        // Equality and manifest validation ignore the knob...
        assert_eq!(c, c8);
        c8.validate_against_json(&c.to_json()).unwrap();
        // ...and it never round-trips through JSON (architecture only).
        let parsed = ModelConfig::from_json(&c8.to_json()).unwrap();
        assert_eq!(parsed.threads, 0);
    }

    #[test]
    fn param_count_sane() {
        let c = ModelConfig::tiny();
        let p = c.n_params();
        // ~460k params for the tiny preset
        assert!(p > 300_000 && p < 700_000, "params={p}");
    }

    #[test]
    fn kv_accounting() {
        let c = ModelConfig::tiny();
        // 2 layers × 2 tensors × 128 dims × 4 bytes = 2 KiB per token
        assert_eq!(c.kv_bytes_full(1), 2 * 2 * 128 * 4);
        assert_eq!(c.kv_bytes_full(512), 512 * 2 * 2 * 128 * 4);
    }
}
