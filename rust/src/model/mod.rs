//! TinyLM — the transformer whose KV cache CSKV compresses.
//!
//! * [`config`] — architecture hyperparameters (+ the two presets standing
//!   in for the paper's LongChat-7B and Mistral-7B).
//! * [`weights`] — weight container, initialization, binary save/load, and
//!   the flat tensor ordering shared with the AOT (JAX) side.
//! * [`engine`] — pure-Rust reference engine: exact prefill, policy-driven
//!   decode, calibration activation capture. The engine is the workhorse
//!   for the quality grid (Tables 1–5); the PJRT path (see
//!   [`crate::runtime`]) executes the same computation from AOT artifacts
//!   and is cross-validated against this engine.

pub mod config;
pub mod engine;
pub mod weights;

pub use config::ModelConfig;
pub use engine::Engine;
pub use weights::ModelWeights;
