//! Weight container, initialization, and binary serialization.
//!
//! The flat tensor ordering ([`ModelWeights::flat_order`]) is the contract
//! between this crate and the AOT (JAX) side: `python/compile/model.py`
//! flattens its parameter pytree in the same order, so PJRT executables can
//! take/return weights as positional arguments.

use crate::tensor::Mat;
use crate::util::prng::Pcg64;

use super::config::ModelConfig;

/// Per-layer weights. Projections are stored as `[in, out]` so activations
/// multiply on the left (`x · W`), matching the JAX model.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerWeights {
    /// RMSNorm gain before attention, `[1, d_model]`.
    pub ln1: Mat,
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    pub wo: Mat,
    /// RMSNorm gain before the MLP, `[1, d_model]`.
    pub ln2: Mat,
    pub w1: Mat,
    pub w2: Mat,
}

/// Full model weights.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelWeights {
    pub cfg: ModelConfig,
    /// Token embedding `[vocab, d_model]`.
    pub embed: Mat,
    pub layers: Vec<LayerWeights>,
    /// Final RMSNorm gain `[1, d_model]`.
    pub ln_f: Mat,
    /// Output head `[d_model, vocab]`.
    pub lm_head: Mat,
}

const MAGIC: &[u8; 8] = b"CSKVWTS1";

impl ModelWeights {
    /// GPT-style initialization: N(0, 0.02) embeddings/projections, output
    /// projections scaled down by depth, unit norm gains.
    pub fn init(cfg: &ModelConfig, seed: u64) -> Self {
        cfg.validate().expect("invalid config");
        let mut rng = Pcg64::new(seed);
        let d = cfg.d_model;
        let std = 0.02f32;
        let out_std = std / (2.0 * cfg.n_layers as f32).sqrt();
        let ones = Mat::from_vec(1, d, vec![1.0; d]);
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                ln1: ones.clone(),
                wq: Mat::randn(d, d, std, &mut rng),
                wk: Mat::randn(d, d, std, &mut rng),
                wv: Mat::randn(d, d, std, &mut rng),
                wo: Mat::randn(d, d, out_std, &mut rng),
                ln2: ones.clone(),
                w1: Mat::randn(d, cfg.d_ff, std, &mut rng),
                w2: Mat::randn(cfg.d_ff, d, out_std, &mut rng),
            })
            .collect();
        ModelWeights {
            cfg: cfg.clone(),
            embed: Mat::randn(cfg.vocab_size, d, std, &mut rng),
            layers,
            ln_f: ones.clone(),
            lm_head: Mat::randn(d, cfg.vocab_size, std, &mut rng),
        }
    }

    /// Names + references in the flat order shared with the JAX side.
    pub fn flat_order(&self) -> Vec<(String, &Mat)> {
        let mut out: Vec<(String, &Mat)> = vec![("embed".into(), &self.embed)];
        for (i, l) in self.layers.iter().enumerate() {
            out.push((format!("layers.{i}.ln1"), &l.ln1));
            out.push((format!("layers.{i}.wq"), &l.wq));
            out.push((format!("layers.{i}.wk"), &l.wk));
            out.push((format!("layers.{i}.wv"), &l.wv));
            out.push((format!("layers.{i}.wo"), &l.wo));
            out.push((format!("layers.{i}.ln2"), &l.ln2));
            out.push((format!("layers.{i}.w1"), &l.w1));
            out.push((format!("layers.{i}.w2"), &l.w2));
        }
        out.push(("ln_f".into(), &self.ln_f));
        out.push(("lm_head".into(), &self.lm_head));
        out
    }

    /// Mutable references in the same flat order (for the PJRT trainer to
    /// write updated parameters back).
    pub fn flat_order_mut(&mut self) -> Vec<&mut Mat> {
        let mut out: Vec<&mut Mat> = vec![&mut self.embed];
        for l in self.layers.iter_mut() {
            out.push(&mut l.ln1);
            out.push(&mut l.wq);
            out.push(&mut l.wk);
            out.push(&mut l.wv);
            out.push(&mut l.wo);
            out.push(&mut l.ln2);
            out.push(&mut l.w1);
            out.push(&mut l.w2);
        }
        out.push(&mut self.ln_f);
        out.push(&mut self.lm_head);
        out
    }

    pub fn n_tensors(&self) -> usize {
        3 + 8 * self.layers.len()
    }

    // ----- serialization ----------------------------------------------------

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        let cfg_json = self.cfg.to_json().to_string_compact();
        buf.extend_from_slice(&(cfg_json.len() as u64).to_le_bytes());
        buf.extend_from_slice(cfg_json.as_bytes());
        for (_, m) in self.flat_order() {
            m.write_to(&mut buf);
        }
        std::fs::write(path, buf)?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        let buf = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading weights {}: {e}", path.display()))?;
        anyhow::ensure!(buf.len() > 16 && &buf[..8] == MAGIC, "bad weights file magic");
        let mut pos = 8;
        let jlen = u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap()) as usize;
        pos += 8;
        let cfg_json = std::str::from_utf8(&buf[pos..pos + jlen])?;
        pos += jlen;
        let cfg = ModelConfig::from_json(
            &crate::util::json::Json::parse(cfg_json)
                .map_err(|e| anyhow::anyhow!("weights config: {e:?}"))?,
        )?;
        let mut w = ModelWeights::init(&cfg, 0);
        for m in w.flat_order_mut() {
            *m = Mat::read_from(&buf, &mut pos)?;
        }
        anyhow::ensure!(pos == buf.len(), "trailing bytes in weights file");
        w.validate_shapes()?;
        Ok(w)
    }

    pub fn validate_shapes(&self) -> anyhow::Result<()> {
        let c = &self.cfg;
        anyhow::ensure!(self.embed.rows == c.vocab_size && self.embed.cols == c.d_model);
        anyhow::ensure!(self.layers.len() == c.n_layers);
        for l in &self.layers {
            anyhow::ensure!(l.wq.rows == c.d_model && l.wq.cols == c.d_model);
            anyhow::ensure!(l.wk.rows == c.d_model && l.wk.cols == c.d_model);
            anyhow::ensure!(l.wv.rows == c.d_model && l.wv.cols == c.d_model);
            anyhow::ensure!(l.wo.rows == c.d_model && l.wo.cols == c.d_model);
            anyhow::ensure!(l.w1.rows == c.d_model && l.w1.cols == c.d_ff);
            anyhow::ensure!(l.w2.rows == c.d_ff && l.w2.cols == c.d_model);
            anyhow::ensure!(l.ln1.cols == c.d_model && l.ln2.cols == c.d_model);
        }
        anyhow::ensure!(self.lm_head.rows == c.d_model && self.lm_head.cols == c.vocab_size);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes_validate() {
        let w = ModelWeights::init(&ModelConfig::test_small(), 1);
        w.validate_shapes().unwrap();
        assert_eq!(w.n_tensors(), w.flat_order().len());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("cskv_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let w = ModelWeights::init(&ModelConfig::test_small(), 7);
        w.save(&path).unwrap();
        let w2 = ModelWeights::load(&path).unwrap();
        assert_eq!(w, w2);
    }

    #[test]
    fn load_rejects_corrupt() {
        let dir = std::env::temp_dir().join("cskv_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a weights file").unwrap();
        assert!(ModelWeights::load(&path).is_err());
    }

    #[test]
    fn flat_order_is_stable_contract() {
        // The AOT side relies on this exact ordering — changing it silently
        // breaks artifact interchange, so pin it.
        let w = ModelWeights::init(&ModelConfig::test_small(), 1);
        let names: Vec<String> = w.flat_order().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names[0], "embed");
        assert_eq!(names[1], "layers.0.ln1");
        assert_eq!(names[8], "layers.0.w2");
        assert_eq!(names[names.len() - 2], "ln_f");
        assert_eq!(names[names.len() - 1], "lm_head");
    }

    #[test]
    fn init_is_seed_deterministic() {
        let a = ModelWeights::init(&ModelConfig::test_small(), 3);
        let b = ModelWeights::init(&ModelConfig::test_small(), 3);
        assert_eq!(a, b);
        let c = ModelWeights::init(&ModelConfig::test_small(), 4);
        assert_ne!(a, c);
    }
}
