//! Pure-Rust reference engine.
//!
//! Runs TinyLM with **exact prefill** and **policy-driven decode**: every
//! KV-cache method (CSKV bi-branch, StreamingLLM, H2O, ASVD, full) plugs in
//! through [`KvCachePolicy`]. The engine is used by the quality grid
//! (Tables 1–5), calibration capture for ASVD/fine-tuning, and as the
//! numerical oracle for the PJRT artifacts (cross-validated in
//! `rust/tests/integration_runtime.rs`).
//!
//! Architecture (must mirror `python/compile/model.py` exactly):
//! pre-norm transformer, RMSNorm, rotate-half RoPE applied to Q/K per head,
//! causal MHA, SiLU MLP, untied LM head.
//!
//! ## Prefill cost model
//!
//! The pre-streaming prefill (kept verbatim as the test/bench oracle
//! [`Engine::prefill_reference`]) paid, per layer and per head at context
//! `T`:
//!
//! | stage            | work / allocation                                   |
//! |------------------|-----------------------------------------------------|
//! | scores `Q·Kᵀ`    | full `T×T·d_h` FLOPs + a fresh `T×T` matrix         |
//! | causal softmax   | exp over the lower triangle, zeroing the upper      |
//! | H2O mass         | sweep of **all** `T×T` entries (half exact zeros)   |
//! | output `P·V`     | `T×T·d_h` MACs behind a per-element `!= 0` branch   |
//! | per-head slices  | 3 × `T×d_h` `cols_slice` copies                     |
//! | K/V routing      | unconditional `k.clone()` + `v.clone()` per layer   |
//! | RoPE             | `powf` + `sin_cos` recomputed per (pair, pos, head) |
//!
//! The streaming path ([`Engine::prefill`] / [`Engine::prefill_with`])
//! removes every row of that table: query rows are processed in fixed
//! [`PREFILL_ROW_BLOCK`]-row tiles (one per parallel task), each row
//! computes only its causal prefix `j ≤ i` of scores into an `O(T)`
//! scratch row — the `T×T` matrix is never materialized and the masked
//! upper triangle is never touched — softmax + H2O mass + the weighted
//! `V` sum run in the same pass, per-head slices are read in place,
//! K/V are cloned only when the policy actually substitutes them, RoPE
//! angles come from a per-generation [`ops::RopeTable`], and all
//! projection / MLP / logit GEMMs go through the row-block-parallel
//! [`par_matmul_into`]. Every per-row reduction keeps the serial kernel's
//! operation order and the H2O mass is reduced per row-tile in ascending
//! tile order, so the result is **bit-identical at every thread count**
//! (`rust/tests/property_invariants.rs` holds the oracle; the only
//! difference vs the pre-streaming code is that mass folds per row-tile
//! instead of one global running sum — same values up to fp association,
//! and it only seeds the H2O eviction heuristic). Decode-side costs are
//! unchanged — see the decode cost model in [`crate::kvcache`].
//!
//! ## Batched serving cost model
//!
//! The serving coordinator runs **B** concurrent sequences. Driven one at
//! a time (the pre-batching scheduler), every projection weight is
//! re-streamed from memory once per sequence per stage:
//!
//! | stage           | weight traffic / round (sequential)             |
//! |-----------------|-------------------------------------------------|
//! | admission prefill | full weight set × B (one prefill per request) |
//! | decode round    | `≈ 12·d² + vocab·d` floats × B (GEMV per seq)   |
//!
//! The batched entry points amortize that traffic to ×1 per round while
//! leaving all per-sequence math untouched:
//!
//! * [`Engine::prefill_batch`] stacks the B prompts' rows into one
//!   residual stream and runs every projection / MLP / logit GEMM as a
//!   single [`par_matmul_into`] over `Σ Tᵢ` rows (each weight panel
//!   streamed once, and with better row-parallel utilization than any
//!   single prompt); causal attention and policy ingestion stay strictly
//!   per-sequence.
//! * [`Engine::decode_step_batch`] stacks the B current hidden states
//!   into a `[B, d]` matrix and fuses the QKV / output / MLP / LM-head
//!   projections into one weight-streamed pass each via
//!   [`crate::tensor::matmul::par_matvec_t_batch_into`] (output columns
//!   split across the persistent pool behind the `threads` knob; the
//!   serial kernel is the bit-identity oracle); attention still runs
//!   per-sequence against each policy's [`DecodeView`].
//!
//! Both paths keep every per-row reduction order identical to the
//! single-sequence kernels (the GEMM row reduction is independent of
//! which rows surround it, and the batched GEMV kernel replays
//! `matvec_t_into`'s exact semantics), so token streams are
//! **bit-identical to the per-sequence scheduler at any batch size and
//! thread count** — `rust/tests/batched_serving.rs` holds the oracle.

use std::sync::Arc;

use crate::compress::quant::GROUP;
use crate::kvcache::{DecodeView, KvCachePolicy};
use crate::tensor::matmul::{axpy_row, dot, matvec_t_into, par_matmul_into, par_matvec_t_batch_into};
use crate::tensor::ops;
use crate::tensor::Mat;
use crate::util::threadpool::{parallel_for, resolve_threads, SendPtr};

use super::config::ModelConfig;
use super::weights::ModelWeights;

/// Everything captured during a prefill pass.
pub struct PrefillRecord {
    /// Per layer: attention inputs (`rmsnorm(x)`), `[T, d_model]` — the
    /// `X` of the paper's reconstruction loss.
    pub xnorms: Vec<Mat>,
    /// Per layer: pre-RoPE keys `[T, d_model]`.
    pub ks: Vec<Mat>,
    /// Per layer: values `[T, d_model]`.
    pub vs: Vec<Mat>,
    /// Per layer: aggregated attention mass per key position (H2O seed).
    pub attn_mass: Vec<Vec<f32>>,
    /// Full logits `[T, vocab]`.
    pub logits: Mat,
}

/// An assembled shared-prefix seed for [`Engine::prefill_batch_seeded`]:
/// the per-layer prefill activations of a `len`-token, block-aligned
/// prompt prefix, owned by the caller. The serving coordinator's
/// [`crate::kvcache::PrefixCache`] assembles one per admission hit from
/// its radix trie; seeding replays these rows into the new sequence's
/// policy, so the warm prefill is bitwise identical to a cold run while
/// computing only the suffix (see the prefix module docs for why replay
/// beats policy-state snapshots).
pub struct PrefixSeed {
    /// Prefix length in tokens: a multiple of [`PREFILL_ROW_BLOCK`],
    /// strictly shorter than the prompt it seeds.
    pub len: usize,
    /// Per layer: attention inputs `rmsnorm(x)`, `[len, d_model]`.
    pub xnorm: Vec<Mat>,
    /// Per layer: pre-RoPE, pre-replacement keys `[len, d_model]`.
    pub k: Vec<Mat>,
    /// Per layer: values `[len, d_model]`.
    pub v: Vec<Mat>,
    /// Per layer: the cold fold of the prefix row-tiles' H2O mass
    /// partials over key positions `[0, len)`.
    pub mass: Vec<Vec<f32>>,
}

/// One sequence's result from [`Engine::prefill_batch_seeded`]: the
/// full-context record plus what [`crate::kvcache::PrefixCache::publish`]
/// needs to share this prompt's prefix with later admissions.
pub struct SeededPrefill {
    /// `xnorms` / `ks` / `vs` / `attn_mass` cover all `T` prompt rows
    /// (prefix rows bitwise the seed's), while `logits` covers only the
    /// computed suffix: `[T - start, vocab]`.
    pub record: PrefillRecord,
    /// The seed length this prefill resumed from (0 = cold).
    pub start: usize,
    /// Captured per-suffix-tile H2O mass partial slabs, indexed
    /// `[suffix_tile][layer]`. Slab `lt` belongs to absolute row tile
    /// `start/BLOCK + lt` and holds the first
    /// `start + (lt+1)·`[`PREFILL_ROW_BLOCK`] entries of that tile's
    /// partial (exactly zero beyond — omitted). Only complete tiles are
    /// captured; empty when capture was off.
    pub mass_tiles: Vec<Vec<Vec<f32>>>,
}

/// Timing + memory statistics for one generation.
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub prefill_s: f64,
    pub decode_s: f64,
    pub decode_steps: usize,
    pub kv_bytes_final: usize,
}

/// Preallocated per-generation work buffers for the decode hot loop.
///
/// Every intermediate `decode_step_with` needs lives here, so a steady-
/// state decode step performs no heap allocation (`rust/tests/
/// decode_alloc.rs` enforces this with a counting allocator). `scores`
/// (one probability row per head, `n_heads × n`) and `agg_probs` grow
/// with the cache; [`DecodeState::reserve`] sizes them up front.
pub struct DecodeScratch {
    n_heads: usize,
    x: Vec<f32>,
    xnorm: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    o: Vec<f32>,
    xn2: Vec<f32>,
    h1: Vec<f32>,
    mlp: Vec<f32>,
    xf: Vec<f32>,
    logits: Vec<f32>,
    scores: Vec<f32>,
    agg_probs: Vec<f32>,
}

impl DecodeScratch {
    fn new(cfg: &ModelConfig) -> Self {
        let d = cfg.d_model;
        DecodeScratch {
            n_heads: cfg.n_heads,
            x: vec![0.0; d],
            xnorm: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            attn: vec![0.0; d],
            o: vec![0.0; d],
            xn2: vec![0.0; d],
            h1: vec![0.0; cfg.d_ff],
            mlp: vec![0.0; d],
            xf: vec![0.0; d],
            logits: vec![0.0; cfg.vocab_size],
            scores: Vec::new(),
            agg_probs: Vec::new(),
        }
    }
}

/// Engine-owned decode state for one in-flight generation: the persistent
/// per-layer [`DecodeView`]s (incrementally synced by the cache policy)
/// plus the [`DecodeScratch`] buffers. Create one per generation and pass
/// it to every [`Engine::decode_step_with`] call; see the kvcache module
/// docs for the single-live-view contract.
pub struct DecodeState {
    views: Vec<DecodeView>,
    scratch: DecodeScratch,
}

impl DecodeState {
    pub fn new(cfg: &ModelConfig) -> Self {
        DecodeState {
            views: (0..cfg.n_layers)
                .map(|_| DecodeView::new(cfg.d_model, cfg.n_heads, cfg.rope_base))
                .collect(),
            scratch: DecodeScratch::new(cfg),
        }
    }

    /// Reserve capacity for `total_tokens` cached rows per layer so that
    /// steady-state decode steps allocate nothing.
    pub fn reserve(&mut self, total_tokens: usize) {
        for v in &mut self.views {
            v.reserve(total_tokens);
        }
        let s = &mut self.scratch;
        let score_len = s.n_heads * total_tokens;
        s.scores.reserve(score_len.saturating_sub(s.scores.len()));
        s.agg_probs.reserve(total_tokens.saturating_sub(s.agg_probs.len()));
    }

    /// The synced view for `layer` (tests/diagnostics).
    pub fn view(&self, layer: usize) -> &DecodeView {
        &self.views[layer]
    }
}

/// Fixed query-row tile width for the streaming causal prefill attention:
/// the unit of parallel work, the granularity of the deterministic H2O
/// mass reduction, and the sizing denominator of the prefill scratch.
pub const PREFILL_ROW_BLOCK: usize = 32;

/// Preallocated per-generation work buffers for the prefill pass
/// (mirroring [`DecodeScratch`] for the decode loop).
///
/// Everything transient a prefill needs lives here — Q / RoPE'd-K /
/// attention / MLP matrices plus the per-tile score and mass scratch — so
/// a generation allocates these once instead of once per layer, and
/// harness-style callers ([`crate::eval::harness::EvalSet`], calibration
/// capture) reuse one scratch across every same-length prompt. Buffers
/// that the [`PrefillRecord`] *returns* (`xnorm`, pre-RoPE K, V, mass,
/// logits) are still allocated per layer by necessity.
/// For a cold prefill the query span is the whole context (`Q = T_kv`);
/// a prefix-seeded prefill ([`Engine::prefill_batch_seeded`]) sizes only
/// the computed suffix (`Q = T_kv − start`) for the row buffers while the
/// key-side buffers still cover the full context.
pub struct PrefillScratch {
    /// Computed (query/suffix) rows `Q`.
    t: usize,
    /// Attended (key/value) rows `T_kv ≥ Q`.
    t_kv: usize,
    d: usize,
    d_ff: usize,
    /// Residual stream `[Q, d]`.
    x: Mat,
    /// RoPE'd queries `[Q, d]`.
    q: Mat,
    /// RoPE'd attention keys `[T_kv, d]` (copy of the policy-routed K).
    k_rope: Mat,
    /// Attention output `[Q, d]`.
    attn_out: Mat,
    /// Post-attention RMSNorm `[Q, d]`.
    xn2: Mat,
    /// MLP hidden `[Q, d_ff]`.
    h1: Mat,
    /// Shared projection output `[Q, d]` (attn·Wo, then MLP down-proj).
    proj: Mat,
    /// Per-query-tile score rows, `n_tiles × T_kv` (each tile holds one
    /// `O(T_kv)` row — the `T×T` score matrix is never materialized).
    score_rows: Vec<f32>,
    /// Per-query-tile H2O mass partials, `n_tiles × T_kv`.
    mass_part: Vec<f32>,
    /// Final RMSNorm `[Q, d]`.
    xf: Mat,
    /// Cached RoPE angles for positions `0..T_kv`.
    rope: ops::RopeTable,
}

impl Default for PrefillScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl PrefillScratch {
    /// An empty scratch; buffers are sized lazily by the first prefill.
    pub fn new() -> Self {
        PrefillScratch {
            t: 0,
            t_kv: 0,
            d: 0,
            d_ff: 0,
            x: Mat::zeros(0, 0),
            q: Mat::zeros(0, 0),
            k_rope: Mat::zeros(0, 0),
            attn_out: Mat::zeros(0, 0),
            xn2: Mat::zeros(0, 0),
            h1: Mat::zeros(0, 0),
            proj: Mat::zeros(0, 0),
            xf: Mat::zeros(0, 0),
            score_rows: Vec::new(),
            mass_part: Vec::new(),
            rope: ops::RopeTable::default(),
        }
    }

    /// Size every buffer for a `t`-token prompt under `cfg` (no-op when
    /// already sized — the reuse fast path for harness loops).
    fn ensure(&mut self, t: usize, cfg: &ModelConfig) {
        self.ensure_span(t, t, cfg);
    }

    /// Size for a seeded prefill computing `q_rows` suffix rows while
    /// attending over `kv_rows ≥ q_rows` total context rows (no-op when
    /// already sized). `ensure` is the cold `q_rows == kv_rows` case.
    fn ensure_span(&mut self, q_rows: usize, kv_rows: usize, cfg: &ModelConfig) {
        debug_assert!(kv_rows >= q_rows);
        let (d, d_ff) = (cfg.d_model, cfg.d_ff);
        if self.t != q_rows || self.t_kv != kv_rows || self.d != d || self.d_ff != d_ff {
            self.x = Mat::zeros(q_rows, d);
            self.q = Mat::zeros(q_rows, d);
            self.k_rope = Mat::zeros(kv_rows, d);
            self.attn_out = Mat::zeros(q_rows, d);
            self.xn2 = Mat::zeros(q_rows, d);
            self.h1 = Mat::zeros(q_rows, d_ff);
            self.proj = Mat::zeros(q_rows, d);
            self.xf = Mat::zeros(q_rows, d);
            let n_tiles = q_rows.div_ceil(PREFILL_ROW_BLOCK);
            self.score_rows = vec![0.0; n_tiles * kv_rows];
            self.mass_part = vec![0.0; n_tiles * kv_rows];
            self.t = q_rows;
            self.t_kv = kv_rows;
            self.d = d;
            self.d_ff = d_ff;
        }
        if !self.rope.covers(cfg.d_head(), cfg.rope_base, kv_rows) {
            self.rope = ops::RopeTable::new(cfg.d_head(), cfg.rope_base, kv_rows);
        }
    }
}

/// Output + scratch bundle for [`streaming_causal_attention`] /
/// [`streaming_causal_attention_resume`].
struct AttnBuffers<'a> {
    /// Attention output `[Q, d]`, overwritten (`Q` = query rows).
    out: &'a mut Mat,
    /// Per-query-tile score rows (`n_tiles × T_kv`).
    score_rows: &'a mut [f32],
    /// Per-query-tile mass partials (`n_tiles × T_kv`).
    mass_part: &'a mut [f32],
    /// Aggregated H2O mass per key position `[T_kv]`. The resume kernel
    /// **accumulates** onto it (the caller pre-seeds positions below the
    /// resume point); the cold wrapper zeroes it first.
    mass: &'a mut [f32],
}

/// The non-buffer parameters of the streaming attention kernels.
struct AttnSpan {
    /// Absolute position of query row 0 (0 = cold full-context prefill).
    /// Must be a multiple of [`PREFILL_ROW_BLOCK`] so warm query tiles
    /// coincide with the cold run's — the bit-identity alignment
    /// requirement.
    start: usize,
    n_heads: usize,
    scale: f32,
    threads: usize,
}

/// Streaming (flash-style) causal attention over RoPE'd `q`/`k` and `v`:
/// query rows are processed in [`PREFILL_ROW_BLOCK`]-row tiles, one
/// parallel task per tile. Each row computes only its causal prefix
/// `j ≤ i` of scores into the tile's `O(T)` scratch row (the masked upper
/// triangle is skipped entirely and no `T×T` matrix exists), then runs
/// softmax, the H2O mass accumulation and the weighted `V` sum in the
/// same pass.
///
/// Determinism: every output row is produced by exactly one task using
/// the serial kernels' per-row operation order, and the mass partials are
/// reduced in ascending tile order after the parallel region — so the
/// result is bit-identical at every thread count.
fn streaming_causal_attention(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    n_heads: usize,
    scale: f32,
    threads: usize,
    bufs: AttnBuffers<'_>,
) {
    debug_assert_eq!(k.rows, q.rows);
    bufs.mass.fill(0.0);
    let span = AttnSpan {
        start: 0,
        n_heads,
        scale,
        threads,
    };
    streaming_causal_attention_resume(q, k, v, &span, bufs);
}

/// The mid-context form of [`streaming_causal_attention`], used by
/// [`Engine::prefill_batch_seeded`]: `q` holds only the `Q` **suffix**
/// query rows (already RoPE'd at absolute positions `start..start+Q`)
/// while `k`/`v` hold the full `T_kv = start + Q` context rows. Causal
/// masking resumes mid-context (`valid = start + i + 1`) and the H2O mass
/// fold **accumulates onto** `bufs.mass`, which the caller pre-seeds with
/// the prefix tiles' fold — because `start` is tile-aligned, each suffix
/// tile is exactly the cold run's tile `start/BLOCK + lt`, its partial is
/// computed in the cold kernel's per-row order, and partials are folded
/// in the same ascending tile order, so output rows *and* mass are
/// bit-identical to the cold full-context call (the cold kernel is
/// literally this one at `start = 0` over a zeroed mass).
fn streaming_causal_attention_resume(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    span: &AttnSpan,
    bufs: AttnBuffers<'_>,
) {
    let (start, n_heads, scale, threads) = (span.start, span.n_heads, span.scale, span.threads);
    let qn = q.rows;
    let t = k.rows;
    let d = q.cols;
    let dh = d / n_heads;
    debug_assert_eq!(start % PREFILL_ROW_BLOCK, 0);
    debug_assert_eq!(t, start + qn);
    debug_assert_eq!(v.rows, t);
    debug_assert_eq!((bufs.out.rows, bufs.out.cols), (qn, d));
    debug_assert_eq!(bufs.mass.len(), t);
    let n_tiles = qn.div_ceil(PREFILL_ROW_BLOCK);
    assert!(bufs.score_rows.len() >= n_tiles * t);
    assert!(bufs.mass_part.len() >= n_tiles * t);

    let out_ptr = SendPtr(bufs.out.data.as_mut_ptr());
    let score_ptr = SendPtr(bufs.score_rows.as_mut_ptr());
    let mpart_ptr = SendPtr(bufs.mass_part.as_mut_ptr());
    parallel_for(n_tiles, threads, |tile| {
        let r0 = tile * PREFILL_ROW_BLOCK;
        let r1 = (r0 + PREFILL_ROW_BLOCK).min(qn);
        // Safety: this tile exclusively owns output rows [r0, r1) and
        // scratch slot `tile`; `parallel_for` hands out each tile exactly
        // once and the buffers outlive the scoped workers.
        let out_rows = unsafe { out_ptr.slice_mut(r0 * d, (r1 - r0) * d) };
        let srow = unsafe { score_ptr.slice_mut(tile * t, t) };
        let mpart = unsafe { mpart_ptr.slice_mut(tile * t, t) };
        out_rows.fill(0.0);
        mpart.fill(0.0);
        for i in r0..r1 {
            // Causal prefix at the row's absolute position — the tile
            // never looks past it.
            let valid = start + i + 1;
            let qrow = q.row(i);
            let orow = &mut out_rows[(i - r0) * d..(i - r0 + 1) * d];
            for h in 0..n_heads {
                let (lo, hi) = (h * dh, (h + 1) * dh);
                let qh = &qrow[lo..hi];
                let mut mx = f32::NEG_INFINITY;
                for j in 0..valid {
                    let s = dot(qh, &k.row(j)[lo..hi]) * scale;
                    srow[j] = s;
                    mx = mx.max(s);
                }
                let mut sum = 0.0f32;
                for e in srow[..valid].iter_mut() {
                    *e = (*e - mx).exp();
                    sum += *e;
                }
                let inv = 1.0 / sum;
                for j in 0..valid {
                    let p = srow[j] * inv;
                    mpart[j] += p;
                    axpy_row(&mut orow[lo..hi], p, &v.row(j)[lo..hi]);
                }
            }
        }
    });

    // Deterministic H2O mass reduction: ascending tile order on top of
    // the caller-seeded prefix fold (zeroed by the cold wrapper), so the
    // result is independent of the thread count that produced the
    // partials and bitwise equal to the cold fold (partials are sums of
    // probabilities, hence ≥ +0.0, and `x + 0.0 == x` bitwise for
    // `x ≥ 0` — the prefix tiles' zero suffix entries never perturb it).
    for tile in 0..n_tiles {
        let mpart = &bufs.mass_part[tile * t..(tile + 1) * t];
        for (mj, &pj) in bufs.mass.iter_mut().zip(mpart) {
            *mj += pj;
        }
    }
}

/// The pre-PR blocked GEMM **with** the `aip == 0.0` skip, kept solely
/// for [`Engine::prefill_reference`]'s `P·V` product: the causal softmax
/// zeroes the upper triangle of `P`, and the pre-streaming prefill's
/// cost profile depended on the branch skipping those ~`T²/2` AXPYs —
/// using today's branchless [`crate::tensor::matmul::matmul_into`] here
/// would make the bench baseline slower than the code this PR actually
/// replaced and inflate the reported speedups. Skipping exact zeros is
/// bit-preserving on these operands, so oracle bit-identity is
/// unaffected.
fn matmul_skip_zeros(a: &Mat, b: &Mat) -> Mat {
    const MC: usize = 64;
    const KC: usize = 256;
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + MC).min(m);
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KC).min(k);
            for i in i0..i1 {
                let arow = &a.data[i * k..(i + 1) * k];
                let crow = &mut c.data[i * n..(i + 1) * n];
                for p in k0..k1 {
                    let aip = arow[p];
                    if aip == 0.0 {
                        continue;
                    }
                    axpy_row(crow, aip, &b.data[p * n..(p + 1) * n]);
                }
            }
            k0 = k1;
        }
        i0 = i1;
    }
    c
}

/// The per-head geometry every attention kernel needs, bundled so the
/// decode helpers stay under clippy's argument budget.
#[derive(Clone, Copy)]
struct HeadSplit {
    n_heads: usize,
    d_head: usize,
    scale: f32,
}

impl HeadSplit {
    fn of(cfg: &ModelConfig) -> Self {
        let d_head = cfg.d_head();
        HeadSplit {
            n_heads: cfg.n_heads,
            d_head,
            scale: 1.0 / (d_head as f32).sqrt(),
        }
    }
}

/// Minimum per-head work (`history length × d_head` elements) before
/// [`decode_attention`] fans heads out across the persistent pool —
/// below this the pool dispatch costs more than the head loop it splits.
const HEAD_PAR_MIN_ELEMS: usize = 8 * 1024;

/// One decode step's per-sequence attention against a synced
/// [`DecodeView`]: per-head scores + softmax + weighted-V into `attn`,
/// aggregating per-position probabilities into `agg_probs` for the H2O
/// feedback. Extracted so [`Engine::decode_step_with`] and
/// [`Engine::decode_step_batch`] run the *same* code — the batched
/// scheduler's bit-identity holds for attention by construction.
///
/// Two perf structures live here:
///
/// * **Per-segment dispatch.** The view's sealed prefix (rows
///   `[0, quant_rows)`) is held as packed int4 groups; those rows are
///   scored with [`crate::compress::quant::QuantizedBlock::fused_dot_rows`]
///   and accumulated with `fused_axpy_rows` — dequantization fused into
///   the GEMV, no materialized f32 copy. The f32 tail (`[quant_rows, n)`)
///   runs the classic [`dot`]/[`axpy_row`] path. For f32-only views
///   `quant_rows == 0` and the math is bit-identical to the pre-split
///   single-segment loop.
/// * **Head parallelism.** `scores` holds one probability row *per head*
///   (`n_heads × n`), so each head's pass is independent: heads fan out
///   over the persistent pool when the per-head work clears
///   [`HEAD_PAR_MIN_ELEMS`] and the config is wide enough. `agg_probs`
///   is reduced *after* the head loop in ascending head order, making the
///   output bit-identical at every thread count (the same argument as
///   the streaming prefill's tile reduction). Narrow configs stay on the
///   serial path, which allocates nothing — the zero-alloc decode tests
///   cover both the full cache and the fused int4 path.
fn decode_attention(
    view: &DecodeView,
    q: &[f32],
    attn: &mut [f32],
    scores: &mut Vec<f32>,
    agg_probs: &mut Vec<f32>,
    heads: HeadSplit,
    threads: usize,
) {
    let HeadSplit { n_heads, d_head: dh, scale } = heads;
    let n = view.len();
    attn.fill(0.0);
    scores.clear();
    scores.resize(n_heads * n, 0.0);

    let par_threads = if n_heads >= 4 && n * dh >= HEAD_PAR_MIN_ELEMS {
        threads
    } else {
        1 // narrow config: inline serial path, no pool dispatch, no allocs
    };
    let attn_ptr = SendPtr(attn.as_mut_ptr());
    let score_ptr = SendPtr(scores.as_mut_ptr());
    parallel_for(n_heads, par_threads, |h| {
        let (lo, hi) = (h * dh, (h + 1) * dh);
        // Safety: head h exclusively owns attn[lo..hi] and score row h;
        // both buffers outlive the scoped workers.
        let ah = unsafe { attn_ptr.slice_mut(lo, dh) };
        let srow = unsafe { score_ptr.slice_mut(h * n, n) };
        decode_attention_head(view, &q[lo..hi], ah, srow, (lo, hi), scale);
    });

    // Deterministic H2O feedback: per-position probability mass summed in
    // ascending head order — the same additions in the same order as the
    // pre-split inline accumulation, at every thread count.
    agg_probs.clear();
    agg_probs.resize(n, 0.0);
    for h in 0..n_heads {
        let srow = &scores[h * n..(h + 1) * n];
        for (a, &p) in agg_probs.iter_mut().zip(srow) {
            *a += p;
        }
    }
}

/// One head's score/softmax/weighted-V pass for [`decode_attention`]:
/// fused-int4 over the view's sealed groups, f32 over the live tail.
/// Leaves the head's probability row in `srow` for the H2O reduction.
fn decode_attention_head(
    view: &DecodeView,
    qh: &[f32],
    ah: &mut [f32],
    srow: &mut [f32],
    (lo, hi): (usize, usize),
    scale: f32,
) {
    let n = view.len();
    let qrows = view.quant_rows();
    // Scores: packed int4 groups first (dequantize fused into the dot),
    // then the f32 segment at its shifted storage index.
    for (gi, g) in view.quant_key_groups().iter().enumerate() {
        g.fused_dot_rows(qh, lo, hi, scale, &mut srow[gi * GROUP..(gi + 1) * GROUP]);
    }
    for (i, s) in srow.iter_mut().enumerate().skip(qrows) {
        *s = dot(qh, &view.key_row(i)[lo..hi]) * scale;
    }
    let mut mx = f32::NEG_INFINITY;
    for &s in srow.iter() {
        mx = mx.max(s);
    }
    // softmax
    let mut sum = 0.0;
    for s in srow.iter_mut() {
        *s = (*s - mx).exp();
        sum += *s;
    }
    let inv = 1.0 / sum;
    for s in srow.iter_mut() {
        *s *= inv;
    }
    // Weighted V: fused dequantize-AXPY over sealed groups, then the f32
    // tail — ascending rows throughout, the scalar reduction order.
    for (gi, g) in view.quant_value_groups().iter().enumerate() {
        g.fused_axpy_rows(&srow[gi * GROUP..(gi + 1) * GROUP], lo, hi, ah);
    }
    for i in qrows..n {
        axpy_row(ah, srow[i], &view.value_row(i)[lo..hi]);
    }
}

/// Resize a stacked work buffer to `rows × cols` in place. Logical
/// dimensions are updated but the backing `Vec` only reallocates when it
/// has never been this large (grow-only capacity) — so the width
/// fluctuations of continuous batching (a retirement or admission nearly
/// every round) reallocate nothing in steady state. Newly exposed
/// elements are zeroed; callers fully overwrite every live row anyway.
fn resize_stacked(m: &mut Mat, rows: usize, cols: usize) {
    m.rows = rows;
    m.cols = cols;
    m.data.resize(rows * cols, 0.0);
}

/// Stacked `[B, ·]` work buffers for one fused decode round
/// ([`Engine::decode_step_batch`]) — the batch-level mirror of
/// [`DecodeScratch`]. Owned by the scheduler and reused across rounds;
/// backing storage is grow-only ([`resize_stacked`]), so batch-width
/// changes between rounds don't reallocate.
pub struct BatchDecodeScratch {
    x: Mat,
    xnorm: Mat,
    q: Mat,
    k: Mat,
    v: Mat,
    attn: Mat,
    o: Mat,
    xn2: Mat,
    h1: Mat,
    mlp: Mat,
    xf: Mat,
    logits: Mat,
}

impl Default for BatchDecodeScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl BatchDecodeScratch {
    /// An empty scratch; buffers are sized lazily by the first round.
    pub fn new() -> Self {
        BatchDecodeScratch {
            x: Mat::zeros(0, 0),
            xnorm: Mat::zeros(0, 0),
            q: Mat::zeros(0, 0),
            k: Mat::zeros(0, 0),
            v: Mat::zeros(0, 0),
            attn: Mat::zeros(0, 0),
            o: Mat::zeros(0, 0),
            xn2: Mat::zeros(0, 0),
            h1: Mat::zeros(0, 0),
            mlp: Mat::zeros(0, 0),
            xf: Mat::zeros(0, 0),
            logits: Mat::zeros(0, 0),
        }
    }

    fn ensure(&mut self, b: usize, cfg: &ModelConfig) {
        let d = cfg.d_model;
        resize_stacked(&mut self.x, b, d);
        resize_stacked(&mut self.xnorm, b, d);
        resize_stacked(&mut self.q, b, d);
        resize_stacked(&mut self.k, b, d);
        resize_stacked(&mut self.v, b, d);
        resize_stacked(&mut self.attn, b, d);
        resize_stacked(&mut self.o, b, d);
        resize_stacked(&mut self.xn2, b, d);
        resize_stacked(&mut self.h1, b, cfg.d_ff);
        resize_stacked(&mut self.mlp, b, d);
        resize_stacked(&mut self.xf, b, d);
        resize_stacked(&mut self.logits, b, cfg.vocab_size);
    }

    /// Logits row for batch slot `b` after a [`Engine::decode_step_batch`]
    /// round.
    pub fn logits_row(&self, b: usize) -> &[f32] {
        self.logits.row(b)
    }
}

/// One sequence's slot in a fused decode round: its cache policy, the
/// token decoded last round, the token's absolute position, and the
/// persistent per-sequence [`DecodeState`] (views + attention scratch).
pub struct BatchDecodeEntry<'a> {
    pub policy: &'a mut dyn KvCachePolicy,
    pub token: usize,
    pub abs_pos: usize,
    pub state: &'a mut DecodeState,
}

/// Stacked buffers + per-sequence attention scratch for
/// [`Engine::prefill_batch`]. The stacked matrices hold all sequences'
/// rows (`Σ Tᵢ × ·`) so every GEMM streams its weight panel once across
/// the whole admission round; the per-sequence [`PrefillScratch`]es feed
/// the unchanged per-sequence attention/RoPE path. Stacked storage is
/// grow-only ([`resize_stacked`]): admission rounds of varying size
/// reuse the high-water allocation.
pub struct BatchPrefillScratch {
    x: Mat,
    xnorm: Mat,
    q: Mat,
    k: Mat,
    v: Mat,
    attn: Mat,
    xn2: Mat,
    h1: Mat,
    proj: Mat,
    xf: Mat,
    seqs: Vec<PrefillScratch>,
}

impl Default for BatchPrefillScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl BatchPrefillScratch {
    /// An empty scratch; buffers are sized lazily per admission round.
    pub fn new() -> Self {
        BatchPrefillScratch {
            x: Mat::zeros(0, 0),
            xnorm: Mat::zeros(0, 0),
            q: Mat::zeros(0, 0),
            k: Mat::zeros(0, 0),
            v: Mat::zeros(0, 0),
            attn: Mat::zeros(0, 0),
            xn2: Mat::zeros(0, 0),
            h1: Mat::zeros(0, 0),
            proj: Mat::zeros(0, 0),
            xf: Mat::zeros(0, 0),
            seqs: Vec::new(),
        }
    }

    /// Stacked buffers sized for each sequence's **computed** rows
    /// (`q_lens`, what the GEMMs stream) and per-sequence scratches
    /// spanning each sequence's full attended context (`kv_lens`). A cold
    /// batch has `q_lens == kv_lens`.
    fn ensure_spans(&mut self, q_lens: &[usize], kv_lens: &[usize], cfg: &ModelConfig) {
        let total: usize = q_lens.iter().sum();
        let d = cfg.d_model;
        resize_stacked(&mut self.x, total, d);
        resize_stacked(&mut self.xnorm, total, d);
        resize_stacked(&mut self.q, total, d);
        resize_stacked(&mut self.k, total, d);
        resize_stacked(&mut self.v, total, d);
        resize_stacked(&mut self.attn, total, d);
        resize_stacked(&mut self.xn2, total, d);
        resize_stacked(&mut self.h1, total, cfg.d_ff);
        resize_stacked(&mut self.proj, total, d);
        resize_stacked(&mut self.xf, total, d);
        while self.seqs.len() < q_lens.len() {
            self.seqs.push(PrefillScratch::new());
        }
        for ((ss, &qt), &kt) in self.seqs.iter_mut().zip(q_lens).zip(kv_lens) {
            ss.ensure_span(qt, kt, cfg);
        }
    }
}

/// The reference engine. Cheap to clone (weights are shared).
#[derive(Clone)]
pub struct Engine {
    pub w: Arc<ModelWeights>,
}

impl Engine {
    pub fn new(w: Arc<ModelWeights>) -> Self {
        Engine { w }
    }

    /// Exact prefill over `tokens`, feeding `policy` (if any) per layer.
    /// Policies may substitute lossy K/V for the attention itself (ASVD).
    ///
    /// Convenience wrapper around [`Engine::prefill_with`] with a
    /// throwaway [`PrefillScratch`] (one allocation set per generation).
    /// Callers that prefill in a loop (eval harness, calibration capture)
    /// should hold a scratch and call `prefill_with` directly.
    pub fn prefill(&self, tokens: &[usize], policy: Option<&mut dyn KvCachePolicy>) -> PrefillRecord {
        let mut scratch = PrefillScratch::new();
        self.prefill_with(tokens, policy, &mut scratch)
    }

    /// Exact prefill through the streaming tiled attention path, using
    /// (and lazily sizing) the caller's [`PrefillScratch`].
    ///
    /// Worker count comes from `ModelConfig::threads` (0 = the process
    /// default, see [`crate::util::threadpool::set_global_threads`]); the
    /// result is bit-identical at every width, and to the serial
    /// [`Engine::prefill_reference`] oracle.
    pub fn prefill_with(
        &self,
        tokens: &[usize],
        mut policy: Option<&mut dyn KvCachePolicy>,
        scratch: &mut PrefillScratch,
    ) -> PrefillRecord {
        let cfg = &self.w.cfg;
        let t = tokens.len();
        assert!(t > 0, "empty prompt");
        let (d, nh, dh) = (cfg.d_model, cfg.n_heads, cfg.d_head());
        let scale = 1.0 / (dh as f32).sqrt();
        let threads = resolve_threads(cfg.threads);
        scratch.ensure(t, cfg);
        let PrefillScratch {
            x,
            q,
            k_rope,
            attn_out,
            xn2,
            h1,
            proj,
            xf,
            score_rows,
            mass_part,
            rope,
            ..
        } = scratch;

        // Embedding lookup.
        for (i, &tok) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.w.embed.row(tok));
        }

        let mut xnorms = Vec::with_capacity(cfg.n_layers);
        let mut ks = Vec::with_capacity(cfg.n_layers);
        let mut vs = Vec::with_capacity(cfg.n_layers);
        let mut masses = Vec::with_capacity(cfg.n_layers);

        for (li, lw) in self.w.layers.iter().enumerate() {
            let xnorm = ops::rmsnorm_rows_par(&*x, lw.ln1.row(0), cfg.eps, threads);
            par_matmul_into(&xnorm, &lw.wq, q, threads);
            let mut k = Mat::zeros(t, d); // pre-RoPE, returned in the record
            par_matmul_into(&xnorm, &lw.wk, &mut k, threads);
            let mut v = Mat::zeros(t, d); // returned in the record
            par_matmul_into(&xnorm, &lw.wv, &mut v, threads);

            // Hand the exact streams to the policy; it may substitute.
            let replacement = policy
                .as_deref_mut()
                .and_then(|p| p.ingest_prefill(li, &xnorm, &k, &v));
            // Allocation-lean routing: when the policy substitutes
            // nothing, attention reads `v` in place and `k` through the
            // reusable RoPE buffer — no per-layer clones.
            let (k_att, v_att): (&Mat, &Mat) = match &replacement {
                Some((rk, rv)) => (rk, rv),
                None => (&k, &v),
            };

            // RoPE at absolute positions 0..t via the cached angle table.
            k_rope.data.copy_from_slice(&k_att.data);
            ops::rope_rows_cached(q, nh, 0, rope, threads);
            ops::rope_rows_cached(k_rope, nh, 0, rope, threads);

            // Streaming tiled causal MHA + H2O mass, one pass.
            let mut mass = vec![0.0f32; t];
            streaming_causal_attention(
                &*q,
                &*k_rope,
                v_att,
                nh,
                scale,
                threads,
                AttnBuffers {
                    out: &mut *attn_out,
                    score_rows: &mut score_rows[..],
                    mass_part: &mut mass_part[..],
                    mass: &mut mass,
                },
            );
            if let Some(p) = policy.as_deref_mut() {
                p.observe_prefill_attn(li, &mass);
            }
            masses.push(mass);
            par_matmul_into(&*attn_out, &lw.wo, proj, threads);
            x.add_assign(&*proj);

            // MLP block.
            ops::rmsnorm_rows_into(&*x, lw.ln2.row(0), cfg.eps, xn2, threads);
            par_matmul_into(&*xn2, &lw.w1, h1, threads);
            ops::silu_rows(h1, threads);
            par_matmul_into(&*h1, &lw.w2, proj, threads);
            x.add_assign(&*proj);

            xnorms.push(xnorm);
            ks.push(k);
            vs.push(v);
        }

        ops::rmsnorm_rows_into(&*x, self.w.ln_f.row(0), cfg.eps, xf, threads);
        let mut logits = Mat::zeros(t, cfg.vocab_size);
        par_matmul_into(&*xf, &self.w.lm_head, &mut logits, threads);
        PrefillRecord {
            xnorms,
            ks,
            vs,
            attn_mass: masses,
            logits,
        }
    }

    /// Fused multi-sequence prefill: one exact prefill pass over several
    /// prompts at once, streaming each layer's weights **once** across
    /// the stacked sequences instead of once per prompt.
    ///
    /// All rows of the B prompts are stacked into one `Σ Tᵢ × d` residual
    /// stream; RMSNorm, the QKV / output / MLP / logit GEMMs run as
    /// single [`par_matmul_into`] passes over the stack, while causal
    /// attention, RoPE and policy ingestion run strictly per sequence
    /// (each with its own [`PrefillScratch`] inside `scratch`). Every
    /// per-row reduction keeps the single-sequence kernels' operation
    /// order, so each returned [`PrefillRecord`] — and each policy's
    /// post-prefill state — is **bit-identical** to a standalone
    /// [`Engine::prefill_with`] call for that prompt, at any batch size
    /// and thread count (`rust/tests/batched_serving.rs`).
    pub fn prefill_batch(
        &self,
        prompts: &[&[usize]],
        policies: &mut [Option<&mut dyn KvCachePolicy>],
        scratch: &mut BatchPrefillScratch,
    ) -> Vec<PrefillRecord> {
        let seeds: Vec<Option<&PrefixSeed>> = vec![None; prompts.len()];
        self.prefill_batch_seeded(prompts, &seeds, policies, false, scratch)
            .into_iter()
            .map(|sp| sp.record)
            .collect()
    }

    /// [`Engine::prefill_batch`] generalized with shared-prefix seeding:
    /// the cold batch is literally this with no seeds and capture off.
    ///
    /// For a sequence with a [`PrefixSeed`] of `start` tokens, only the
    /// `T − start` suffix rows enter the stacked residual stream — the
    /// embedding, RMSNorm, QKV / output / MLP / logit GEMMs and the
    /// attention *query* side all skip the prefix (the warm-TTFT win) —
    /// while each layer assembles the full-context `xnorm`/K/V by
    /// prepending the seed's rows to the computed suffix rows. The policy
    /// ingests those full streams and observes the full H2O mass (prefix
    /// positions pre-seeded from the seed's fold, suffix tiles folded on
    /// top by [`streaming_causal_attention_resume`]), so its inputs — and
    /// therefore its state, for **every** policy — are bitwise the cold
    /// run's (`rust/tests/prefix_reuse.rs` holds the oracle; see
    /// [`crate::kvcache::prefix`] for why replaying ingestion is the only
    /// sound seeding). Per-row GEMM reductions are position-independent
    /// and the suffix queries RoPE at their absolute positions via the
    /// same cached table, so every computed row is bitwise the cold row.
    ///
    /// With `capture` on, each sequence's complete suffix row-tiles'
    /// mass-partial slabs are saved into the returned
    /// [`SeededPrefill::mass_tiles`] so the coordinator can publish the
    /// prompt's prefix into its [`crate::kvcache::PrefixCache`].
    pub fn prefill_batch_seeded(
        &self,
        prompts: &[&[usize]],
        seeds: &[Option<&PrefixSeed>],
        policies: &mut [Option<&mut dyn KvCachePolicy>],
        capture: bool,
        scratch: &mut BatchPrefillScratch,
    ) -> Vec<SeededPrefill> {
        assert_eq!(prompts.len(), policies.len());
        assert_eq!(prompts.len(), seeds.len());
        let nb = prompts.len();
        if nb == 0 {
            return Vec::new();
        }
        let cfg = &self.w.cfg;
        let (d, nh, dh) = (cfg.d_model, cfg.n_heads, cfg.d_head());
        let scale = 1.0 / (dh as f32).sqrt();
        let threads = resolve_threads(cfg.threads);
        let lens: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
        assert!(lens.iter().all(|&t| t > 0), "empty prompt");
        let starts: Vec<usize> = seeds.iter().map(|s| s.map_or(0, |s| s.len)).collect();
        for (si, seed) in seeds.iter().enumerate() {
            let Some(s) = seed else { continue };
            assert!(
                s.len % PREFILL_ROW_BLOCK == 0,
                "prefix seed must be tile-aligned"
            );
            assert!(s.len < lens[si], "prefix seed must leave a suffix row");
            debug_assert_eq!(s.xnorm.len(), cfg.n_layers);
            debug_assert!(s.xnorm.iter().all(|m| (m.rows, m.cols) == (s.len, d)));
            debug_assert!(s.mass.iter().all(|m| m.len() == s.len));
        }
        // Suffix (computed) row counts and their stacked offsets.
        let q_lens: Vec<usize> = lens.iter().zip(&starts).map(|(&t, &s)| t - s).collect();
        let mut offs = Vec::with_capacity(nb);
        let mut total = 0usize;
        for &qt in &q_lens {
            offs.push(total);
            total += qt;
        }
        scratch.ensure_spans(&q_lens, &lens, cfg);

        // Embedding lookup, suffix rows of all sequences stacked.
        for (si, prompt) in prompts.iter().enumerate() {
            for (i, &tok) in prompt[starts[si]..].iter().enumerate() {
                scratch.x.row_mut(offs[si] + i).copy_from_slice(self.w.embed.row(tok));
            }
        }

        let mut xnorms_all: Vec<Vec<Mat>> =
            (0..nb).map(|_| Vec::with_capacity(cfg.n_layers)).collect();
        let mut ks_all: Vec<Vec<Mat>> =
            (0..nb).map(|_| Vec::with_capacity(cfg.n_layers)).collect();
        let mut vs_all: Vec<Vec<Mat>> =
            (0..nb).map(|_| Vec::with_capacity(cfg.n_layers)).collect();
        let mut masses_all: Vec<Vec<Vec<f32>>> =
            (0..nb).map(|_| Vec::with_capacity(cfg.n_layers)).collect();
        // Captured slabs, indexed [seq][suffix_tile][layer]. Only
        // complete tiles are publishable (a partial tile's partial is not
        // the cold tile's — later prompt rows would still add to it).
        let mut tiles_all: Vec<Vec<Vec<Vec<f32>>>> = q_lens
            .iter()
            .map(|&qt| {
                let n = if capture { qt / PREFILL_ROW_BLOCK } else { 0 };
                (0..n).map(|_| Vec::with_capacity(cfg.n_layers)).collect()
            })
            .collect();

        for (li, lw) in self.w.layers.iter().enumerate() {
            // Stacked RMSNorm + one weight-streamed GEMM per projection
            // for the whole round. The GEMM row reduction is independent
            // of which rows share the stack, so every row matches the
            // single-sequence path bitwise.
            ops::rmsnorm_rows_into(&scratch.x, lw.ln1.row(0), cfg.eps, &mut scratch.xnorm, threads);
            par_matmul_into(&scratch.xnorm, &lw.wq, &mut scratch.q, threads);
            par_matmul_into(&scratch.xnorm, &lw.wk, &mut scratch.k, threads);
            par_matmul_into(&scratch.xnorm, &lw.wv, &mut scratch.v, threads);

            // Per-sequence attention + policy ingestion, unchanged from
            // the single-sequence path.
            for si in 0..nb {
                let (t, start, off) = (lens[si], starts[si], offs[si]);
                let qt = q_lens[si];
                // Full-context streams: seed prefix rows (bitwise the
                // donor run's) ++ this pass's suffix rows.
                let mut xnorm = Mat::zeros(t, d);
                let mut k = Mat::zeros(t, d);
                let mut v = Mat::zeros(t, d);
                if let Some(s) = seeds[si] {
                    xnorm.data[..start * d].copy_from_slice(&s.xnorm[li].data);
                    k.data[..start * d].copy_from_slice(&s.k[li].data);
                    v.data[..start * d].copy_from_slice(&s.v[li].data);
                }
                xnorm.data[start * d..].copy_from_slice(&scratch.xnorm.data[off * d..(off + qt) * d]);
                k.data[start * d..].copy_from_slice(&scratch.k.data[off * d..(off + qt) * d]);
                v.data[start * d..].copy_from_slice(&scratch.v.data[off * d..(off + qt) * d]);
                let replacement = policies[si]
                    .as_deref_mut()
                    .and_then(|p| p.ingest_prefill(li, &xnorm, &k, &v));
                let (k_att, v_att): (&Mat, &Mat) = match &replacement {
                    Some((rk, rv)) => (rk, rv),
                    None => (&k, &v),
                };
                let ss = &mut scratch.seqs[si];
                // Suffix queries RoPE'd at their absolute positions;
                // full-context keys RoPE'd from 0 — one shared table.
                ss.q.data.copy_from_slice(&scratch.q.data[off * d..(off + qt) * d]);
                ss.k_rope.data.copy_from_slice(&k_att.data);
                ops::rope_rows_cached(&mut ss.q, nh, start, &ss.rope, threads);
                ops::rope_rows_cached(&mut ss.k_rope, nh, 0, &ss.rope, threads);
                let mut mass = vec![0.0f32; t];
                if let Some(s) = seeds[si] {
                    mass[..start].copy_from_slice(&s.mass[li]);
                }
                let span = AttnSpan {
                    start,
                    n_heads: nh,
                    scale,
                    threads,
                };
                streaming_causal_attention_resume(
                    &ss.q,
                    &ss.k_rope,
                    v_att,
                    &span,
                    AttnBuffers {
                        out: &mut ss.attn_out,
                        score_rows: &mut ss.score_rows[..],
                        mass_part: &mut ss.mass_part[..],
                        mass: &mut mass,
                    },
                );
                for (lt, slabs) in tiles_all[si].iter_mut().enumerate() {
                    let abs_end = start + (lt + 1) * PREFILL_ROW_BLOCK;
                    slabs.push(ss.mass_part[lt * t..lt * t + abs_end].to_vec());
                }
                if let Some(p) = policies[si].as_deref_mut() {
                    p.observe_prefill_attn(li, &mass);
                }
                scratch.attn.data[off * d..(off + qt) * d].copy_from_slice(&ss.attn_out.data);
                masses_all[si].push(mass);
                xnorms_all[si].push(xnorm);
                ks_all[si].push(k);
                vs_all[si].push(v);
            }

            // Output projection + MLP, fused across the stack.
            par_matmul_into(&scratch.attn, &lw.wo, &mut scratch.proj, threads);
            scratch.x.add_assign(&scratch.proj);
            ops::rmsnorm_rows_into(&scratch.x, lw.ln2.row(0), cfg.eps, &mut scratch.xn2, threads);
            par_matmul_into(&scratch.xn2, &lw.w1, &mut scratch.h1, threads);
            ops::silu_rows(&mut scratch.h1, threads);
            par_matmul_into(&scratch.h1, &lw.w2, &mut scratch.proj, threads);
            scratch.x.add_assign(&scratch.proj);
        }

        ops::rmsnorm_rows_into(&scratch.x, self.w.ln_f.row(0), cfg.eps, &mut scratch.xf, threads);
        let mut logits = Mat::zeros(total, cfg.vocab_size);
        par_matmul_into(&scratch.xf, &self.w.lm_head, &mut logits, threads);

        (0..nb)
            .map(|si| SeededPrefill {
                record: PrefillRecord {
                    xnorms: std::mem::take(&mut xnorms_all[si]),
                    ks: std::mem::take(&mut ks_all[si]),
                    vs: std::mem::take(&mut vs_all[si]),
                    attn_mass: std::mem::take(&mut masses_all[si]),
                    logits: logits.rows_slice(offs[si], offs[si] + q_lens[si]),
                },
                start: starts[si],
                mass_tiles: std::mem::take(&mut tiles_all[si]),
            })
            .collect()
    }

    /// Single-sequence convenience over [`Engine::prefill_batch_seeded`]
    /// (tests, the coordinator's `--sequential` A/B path).
    pub fn prefill_seeded(
        &self,
        tokens: &[usize],
        seed: Option<&PrefixSeed>,
        policy: Option<&mut dyn KvCachePolicy>,
        capture: bool,
        scratch: &mut BatchPrefillScratch,
    ) -> SeededPrefill {
        let mut policies = [policy];
        self.prefill_batch_seeded(&[tokens], &[seed], &mut policies, capture, scratch)
            .pop()
            .expect("one sequence in, one out")
    }

    /// The pre-streaming serial prefill, kept verbatim as the correctness
    /// oracle and the bench baseline: per head it materializes the full
    /// `T×T` score matrix, runs [`ops::softmax_causal`] and the blocked
    /// output GEMM, and clones K/V unconditionally — exactly what
    /// [`Engine::prefill`] paid before the streaming rewrite (see the
    /// prefill cost model in the module docs).
    ///
    /// The one deliberate deviation: H2O mass folds per
    /// [`PREFILL_ROW_BLOCK`]-row tile (the parallel path's deterministic
    /// reduction order) instead of the old single running sum, so
    /// `rust/tests/property_invariants.rs` can assert **bit-identity**
    /// between this oracle and the streaming path at every thread count.
    pub fn prefill_reference(
        &self,
        tokens: &[usize],
        mut policy: Option<&mut dyn KvCachePolicy>,
    ) -> PrefillRecord {
        let cfg = &self.w.cfg;
        let t = tokens.len();
        assert!(t > 0, "empty prompt");
        let (d, nh, dh) = (cfg.d_model, cfg.n_heads, cfg.d_head());
        let scale = 1.0 / (dh as f32).sqrt();

        // Embedding lookup.
        let mut x = Mat::zeros(t, d);
        for (i, &tok) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.w.embed.row(tok));
        }

        let mut xnorms = Vec::with_capacity(cfg.n_layers);
        let mut ks = Vec::with_capacity(cfg.n_layers);
        let mut vs = Vec::with_capacity(cfg.n_layers);
        let mut masses = Vec::with_capacity(cfg.n_layers);

        for (li, lw) in self.w.layers.iter().enumerate() {
            let xnorm = ops::rmsnorm_rows(&x, lw.ln1.row(0), cfg.eps);
            let q = xnorm.matmul(&lw.wq);
            let k = xnorm.matmul(&lw.wk); // pre-RoPE
            let v = xnorm.matmul(&lw.wv);

            let replacement = policy
                .as_deref_mut()
                .and_then(|p| p.ingest_prefill(li, &xnorm, &k, &v));
            let (k_use, v_use) = match replacement {
                Some((rk, rv)) => (rk, rv),
                None => (k.clone(), v.clone()),
            };

            let mut q_r = q;
            let mut k_r = k_use;
            ops::rope_rows(&mut q_r, nh, 0, cfg.rope_base);
            ops::rope_rows(&mut k_r, nh, 0, cfg.rope_base);

            // Causal MHA with materialized per-head probability matrices.
            let mut attn_out = Mat::zeros(t, d);
            let mut probs = Vec::with_capacity(nh);
            for h in 0..nh {
                let (lo, hi) = (h * dh, (h + 1) * dh);
                let qh = q_r.cols_slice(lo, hi);
                let kh = k_r.cols_slice(lo, hi);
                let vh = v_use.cols_slice(lo, hi);
                let mut scores = qh.matmul_nt(&kh).scale(scale);
                ops::softmax_causal(&mut scores, 0);
                // Pre-PR kernel: the zero-skip branch is what made the
                // old path's P·V effectively triangle-only.
                let oh = matmul_skip_zeros(&scores, &vh);
                for i in 0..t {
                    attn_out.row_mut(i)[lo..hi].copy_from_slice(oh.row(i));
                }
                probs.push(scores);
            }
            // H2O mass over the causal lower triangle only, folded per
            // row tile in the canonical (tile, i, h, j) order.
            let mut mass = vec![0.0f32; t];
            let mut r0 = 0;
            while r0 < t {
                let r1 = (r0 + PREFILL_ROW_BLOCK).min(t);
                let mut part = vec![0.0f32; t];
                for i in r0..r1 {
                    for p in &probs {
                        for (j, pj) in part.iter_mut().enumerate().take(i + 1) {
                            *pj += p.at(i, j);
                        }
                    }
                }
                for (mj, &pj) in mass.iter_mut().zip(&part) {
                    *mj += pj;
                }
                r0 = r1;
            }
            if let Some(p) = policy.as_deref_mut() {
                p.observe_prefill_attn(li, &mass);
            }
            masses.push(mass);
            x.add_assign(&attn_out.matmul(&lw.wo));

            // MLP block.
            let xn2 = ops::rmsnorm_rows(&x, lw.ln2.row(0), cfg.eps);
            let mut h1 = xn2.matmul(&lw.w1);
            ops::silu_inplace(&mut h1);
            x.add_assign(&h1.matmul(&lw.w2));

            xnorms.push(xnorm);
            ks.push(k);
            vs.push(v);
        }

        let xf = ops::rmsnorm_rows(&x, self.w.ln_f.row(0), cfg.eps);
        let logits = xf.matmul(&self.w.lm_head);
        PrefillRecord {
            xnorms,
            ks,
            vs,
            attn_mass: masses,
            logits,
        }
    }

    /// One decode step for the token at absolute position `abs_pos`
    /// (0-based; the prompt occupied `0..abs_pos`), using the persistent
    /// per-generation `state`. Returns the logits row, borrowed from the
    /// state's scratch buffer.
    ///
    /// This is the zero-alloc hot path: all intermediates live in
    /// [`DecodeScratch`], cache keys are read from the incrementally
    /// synced [`DecodeView`]s (already reconstructed *and RoPE'd*; for
    /// int4 policies the sealed prefix stays packed and is scored through
    /// the fused dequantize-GEMV kernels), and the per-head score /
    /// weighted-sum loops run through the blocked [`dot`] / [`axpy_row`]
    /// kernels — see [`decode_attention`].
    pub fn decode_step_with<'s>(
        &self,
        policy: &mut dyn KvCachePolicy,
        token: usize,
        abs_pos: usize,
        state: &'s mut DecodeState,
    ) -> &'s [f32] {
        let cfg = &self.w.cfg;
        let (nh, dh) = (cfg.n_heads, cfg.d_head());
        let heads = HeadSplit::of(cfg);
        let threads = resolve_threads(cfg.threads);
        let DecodeState { views, scratch } = state;

        scratch.x.copy_from_slice(self.w.embed.row(token));
        for (li, lw) in self.w.layers.iter().enumerate() {
            ops::rmsnorm(&scratch.x, lw.ln1.row(0), cfg.eps, &mut scratch.xnorm);
            matvec_t_into(&lw.wq, &scratch.xnorm, &mut scratch.q);
            matvec_t_into(&lw.wk, &scratch.xnorm, &mut scratch.k); // pre-RoPE
            matvec_t_into(&lw.wv, &scratch.xnorm, &mut scratch.v);

            policy.append(li, &scratch.xnorm, &scratch.k, &scratch.v);
            let view = &mut views[li];
            policy.sync_view(li, view);
            let view = &views[li];
            debug_assert_eq!(view.len(), policy.len(li));

            // RoPE the query at the policy's coordinate system (cached
            // keys were RoPE'd once, when written into the view).
            let qpos = policy.query_rope_pos(li, abs_pos);
            for h in 0..nh {
                ops::rope_rotate(&mut scratch.q[h * dh..(h + 1) * dh], qpos, cfg.rope_base);
            }

            // Per-head attention; aggregate probs across heads for H2O.
            decode_attention(
                view,
                &scratch.q,
                &mut scratch.attn,
                &mut scratch.scores,
                &mut scratch.agg_probs,
                heads,
                threads,
            );
            policy.observe_decode_attn(li, view.abs_positions(), &scratch.agg_probs);

            // Output projection + residual.
            matvec_t_into(&lw.wo, &scratch.attn, &mut scratch.o);
            for (xi, oi) in scratch.x.iter_mut().zip(&scratch.o) {
                *xi += oi;
            }
            // MLP.
            ops::rmsnorm(&scratch.x, lw.ln2.row(0), cfg.eps, &mut scratch.xn2);
            matvec_t_into(&lw.w1, &scratch.xn2, &mut scratch.h1);
            for hv in scratch.h1.iter_mut() {
                *hv = ops::silu(*hv);
            }
            matvec_t_into(&lw.w2, &scratch.h1, &mut scratch.mlp);
            for (xi, mi) in scratch.x.iter_mut().zip(&scratch.mlp) {
                *xi += mi;
            }
        }
        ops::rmsnorm(&scratch.x, self.w.ln_f.row(0), cfg.eps, &mut scratch.xf);
        matvec_t_into(&self.w.lm_head, &scratch.xf, &mut scratch.logits);
        &scratch.logits
    }

    /// One GEMM-batched decode round: advance every entry's sequence by
    /// one token, fusing the QKV / output / MLP / LM-head projections
    /// into a single weight-streamed pass each over the stacked `[B, d]`
    /// hidden states ([`matvec_t_batch_into`]), while cache appends, view
    /// sync, RoPE and attention run per sequence exactly as
    /// [`Engine::decode_step_with`] does.
    ///
    /// After the call, `batch.logits_row(i)` holds entry `i`'s logits.
    /// The batched projection kernel replays `matvec_t_into`'s per-row
    /// reduction semantics, so every sequence's logits — and its policy /
    /// view state — are **bit-identical** to B independent
    /// `decode_step_with` calls, at any batch width
    /// (`rust/tests/batched_serving.rs`).
    pub fn decode_step_batch(
        &self,
        entries: &mut [BatchDecodeEntry<'_>],
        batch: &mut BatchDecodeScratch,
    ) {
        let nb = entries.len();
        if nb == 0 {
            return;
        }
        let cfg = &self.w.cfg;
        let (nh, dh) = (cfg.n_heads, cfg.d_head());
        let heads = HeadSplit::of(cfg);
        let threads = resolve_threads(cfg.threads);
        batch.ensure(nb, cfg);

        for (bi, e) in entries.iter().enumerate() {
            batch.x.row_mut(bi).copy_from_slice(self.w.embed.row(e.token));
        }
        for (li, lw) in self.w.layers.iter().enumerate() {
            for bi in 0..nb {
                ops::rmsnorm(batch.x.row(bi), lw.ln1.row(0), cfg.eps, batch.xnorm.row_mut(bi));
            }
            // Fused projections: each weight streamed once for the round,
            // output columns split across the pool (`threads` knob) — the
            // serial kernel remains the bit-identity oracle.
            par_matvec_t_batch_into(&lw.wq, &batch.xnorm, &mut batch.q, threads);
            par_matvec_t_batch_into(&lw.wk, &batch.xnorm, &mut batch.k, threads);
            par_matvec_t_batch_into(&lw.wv, &batch.xnorm, &mut batch.v, threads);

            // Per-sequence cache update, RoPE and attention — identical
            // to the single-sequence step.
            for (bi, e) in entries.iter_mut().enumerate() {
                let policy = &mut *e.policy;
                let DecodeState { views, scratch } = &mut *e.state;
                policy.append(li, batch.xnorm.row(bi), batch.k.row(bi), batch.v.row(bi));
                let view = &mut views[li];
                policy.sync_view(li, view);
                let view = &views[li];
                debug_assert_eq!(view.len(), policy.len(li));

                let qpos = policy.query_rope_pos(li, e.abs_pos);
                {
                    let qrow = batch.q.row_mut(bi);
                    for h in 0..nh {
                        ops::rope_rotate(&mut qrow[h * dh..(h + 1) * dh], qpos, cfg.rope_base);
                    }
                }
                decode_attention(
                    view,
                    batch.q.row(bi),
                    batch.attn.row_mut(bi),
                    &mut scratch.scores,
                    &mut scratch.agg_probs,
                    heads,
                    threads,
                );
                policy.observe_decode_attn(li, view.abs_positions(), &scratch.agg_probs);
            }

            // Output projection + residual, fused.
            par_matvec_t_batch_into(&lw.wo, &batch.attn, &mut batch.o, threads);
            batch.x.add_assign(&batch.o);
            // MLP, fused.
            for bi in 0..nb {
                ops::rmsnorm(batch.x.row(bi), lw.ln2.row(0), cfg.eps, batch.xn2.row_mut(bi));
            }
            par_matvec_t_batch_into(&lw.w1, &batch.xn2, &mut batch.h1, threads);
            for hv in batch.h1.data.iter_mut() {
                *hv = ops::silu(*hv);
            }
            par_matvec_t_batch_into(&lw.w2, &batch.h1, &mut batch.mlp, threads);
            batch.x.add_assign(&batch.mlp);
        }
        for bi in 0..nb {
            ops::rmsnorm(batch.x.row(bi), self.w.ln_f.row(0), cfg.eps, batch.xf.row_mut(bi));
        }
        par_matvec_t_batch_into(&self.w.lm_head, &batch.xf, &mut batch.logits, threads);
    }

    /// One decode step with a throwaway [`DecodeState`] (compatibility /
    /// cold path — the views are rebuilt from scratch every call). Prefer
    /// [`Engine::decode_step_with`] with a persistent state for decoding
    /// more than one token.
    pub fn decode_step(
        &self,
        policy: &mut dyn KvCachePolicy,
        token: usize,
        abs_pos: usize,
    ) -> Vec<f32> {
        let mut state = DecodeState::new(&self.w.cfg);
        self.decode_step_with(policy, token, abs_pos, &mut state)
            .to_vec()
    }

    /// Greedy generation: exact prefill + policy decode. Returns generated
    /// token ids (length `n_new`) and stats.
    pub fn generate(
        &self,
        prompt: &[usize],
        n_new: usize,
        policy: &mut dyn KvCachePolicy,
    ) -> (Vec<usize>, GenStats) {
        let t0 = std::time::Instant::now();
        let rec = self.prefill(prompt, Some(policy));
        let prefill_s = t0.elapsed().as_secs_f64();

        let mut out = Vec::with_capacity(n_new);
        let mut next = ops::argmax(rec.logits.row(prompt.len() - 1));
        let mut state = DecodeState::new(&self.w.cfg);
        state.reserve(prompt.len() + n_new);
        policy.reserve(n_new);
        let t1 = std::time::Instant::now();
        for i in 0..n_new {
            out.push(next);
            if i + 1 == n_new {
                break;
            }
            let logits = self.decode_step_with(policy, next, prompt.len() + i, &mut state);
            next = ops::argmax(logits);
        }
        let stats = GenStats {
            prefill_s,
            decode_s: t1.elapsed().as_secs_f64(),
            decode_steps: n_new.saturating_sub(1),
            kv_bytes_final: policy.kv_bytes(),
        };
        (out, stats)
    }

    /// Mean next-token cross-entropy over a token sequence (perplexity =
    /// exp of this), using exact attention.
    pub fn lm_loss(&self, tokens: &[usize]) -> f32 {
        assert!(tokens.len() >= 2);
        let rec = self.prefill(tokens, None);
        let targets: Vec<usize> = tokens[1..].to_vec();
        let logits = rec.logits.rows_slice(0, tokens.len() - 1);
        ops::cross_entropy_rows(&logits, &targets)
    }

    /// Capture calibration activations: per-layer `xnorm` matrices pooled
    /// over `docs`, row-subsampled to at most `max_rows` per layer.
    pub fn collect_calibration(
        &self,
        docs: &[Vec<usize>],
        max_rows: usize,
        seed: u64,
    ) -> Vec<Mat> {
        let cfg = &self.w.cfg;
        let mut pools: Vec<Mat> = (0..cfg.n_layers)
            .map(|_| Mat::zeros(0, cfg.d_model))
            .collect();
        // One scratch across the whole corpus: same-length docs reuse
        // every prefill buffer allocation-free.
        let mut scratch = PrefillScratch::new();
        for doc in docs {
            let rec = self.prefill_with(doc, None, &mut scratch);
            for (li, xn) in rec.xnorms.iter().enumerate() {
                pools[li] = pools[li].vcat(xn);
            }
        }
        let mut rng = crate::util::prng::Pcg64::new(seed);
        pools
            .into_iter()
            .map(|p| {
                if p.rows <= max_rows {
                    p
                } else {
                    let idx = rng.sample_indices(p.rows, max_rows);
                    let mut out = Mat::zeros(max_rows, p.cols);
                    for (oi, &src) in idx.iter().enumerate() {
                        out.row_mut(oi).copy_from_slice(p.row(src));
                    }
                    out
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::FullCache;
    use crate::model::config::ModelConfig;

    fn engine() -> Engine {
        Engine::new(Arc::new(ModelWeights::init(&ModelConfig::test_small(), 42)))
    }

    #[test]
    fn prefill_shapes() {
        let e = engine();
        let rec = e.prefill(&[1, 5, 9, 3], None);
        let cfg = &e.w.cfg;
        assert_eq!(rec.logits.rows, 4);
        assert_eq!(rec.logits.cols, cfg.vocab_size);
        assert_eq!(rec.xnorms.len(), cfg.n_layers);
        assert_eq!(rec.ks[0].rows, 4);
        assert_eq!(rec.ks[0].cols, cfg.d_model);
    }

    /// THE core equivalence: decoding token-by-token with a full cache must
    /// produce the same logits as one exact prefill over the whole
    /// sequence. This validates the entire decode path (RoPE positions,
    /// cache ordering, masking) against the prefill path.
    #[test]
    fn decode_with_full_cache_matches_prefill() {
        let e = engine();
        let cfg = &e.w.cfg;
        let tokens = [1usize, 17, 30, 8, 99, 64, 2, 41];
        let full = e.prefill(&tokens, None);

        // Prefill only the first 3 tokens, then decode the rest.
        let mut cache = FullCache::new(cfg.n_layers, cfg.d_model);
        let pre = e.prefill(&tokens[..3], Some(&mut cache));
        for r in 0..3 {
            for c in 0..cfg.vocab_size {
                assert!(
                    (pre.logits.at(r, c) - full.logits.at(r, c)).abs() < 1e-4,
                    "prefill prefix logits must match"
                );
            }
        }
        for (i, &tok) in tokens[3..].iter().enumerate() {
            let abs = 3 + i;
            let logits = e.decode_step(&mut cache, tok, abs);
            let want = full.logits.row(abs);
            let max_diff = logits
                .iter()
                .zip(want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff < 1e-3, "step {abs}: max diff {max_diff}");
        }
    }

    /// The persistent incremental DecodeState must produce the same
    /// logits as both the throwaway-state wrapper and the exact prefill —
    /// the engine-level guarantee that view memoization changes nothing.
    #[test]
    fn incremental_state_matches_throwaway_and_prefill() {
        let e = engine();
        let cfg = &e.w.cfg;
        let tokens = [2usize, 11, 45, 7, 120, 9, 33, 60, 5, 71];
        let full = e.prefill(&tokens, None);

        let mut inc_cache = FullCache::new(cfg.n_layers, cfg.d_model);
        let _ = e.prefill(&tokens[..4], Some(&mut inc_cache));
        let mut state = DecodeState::new(cfg);
        state.reserve(tokens.len());

        let mut fresh_cache = FullCache::new(cfg.n_layers, cfg.d_model);
        let _ = e.prefill(&tokens[..4], Some(&mut fresh_cache));

        for (i, &tok) in tokens[4..].iter().enumerate() {
            let abs = 4 + i;
            let via_wrapper = e.decode_step(&mut fresh_cache, tok, abs);
            let via_state = e.decode_step_with(&mut inc_cache, tok, abs, &mut state);
            assert_eq!(via_state, &via_wrapper[..], "step {abs}: paths must be bit-identical");
            let want = full.logits.row(abs);
            let max_diff = via_state
                .iter()
                .zip(want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff < 1e-3, "step {abs}: max diff {max_diff}");
            // The synced view is always exactly the cache contents.
            state.view(0).validate();
            assert_eq!(state.view(0).len(), abs + 1);
        }
    }

    #[test]
    fn generate_is_deterministic_and_reports_stats() {
        let e = engine();
        let prompt = [1usize, 5, 20, 31, 7];
        let cfg = &e.w.cfg;
        let mut c1 = FullCache::new(cfg.n_layers, cfg.d_model);
        let mut c2 = FullCache::new(cfg.n_layers, cfg.d_model);
        let (g1, s1) = e.generate(&prompt, 6, &mut c1);
        let (g2, _) = e.generate(&prompt, 6, &mut c2);
        assert_eq!(g1, g2);
        assert_eq!(g1.len(), 6);
        assert!(s1.kv_bytes_final > 0);
        // 5 prompt + 5 decoded appends (last token is returned, not decoded)
        assert_eq!(c1.len(0), prompt.len() + 5);
    }

    /// The tentpole guarantee at engine granularity: the streaming tiled
    /// prefill is bit-identical to the materializing serial oracle, at
    /// several thread counts, with and without a policy attached. (The
    /// cross-policy sweep lives in `rust/tests/property_invariants.rs`.)
    #[test]
    fn streaming_prefill_matches_reference_oracle() {
        let cfg = ModelConfig::test_small();
        // 70 rows > MC = 64, so the row-chunked parallel GEMMs run their
        // parallel path inside prefill (not the m <= MC serial fallback).
        let tokens: Vec<usize> = (0..70).map(|i| (i * 29 + 3) % 256).collect();
        for threads in [1usize, 2, 8] {
            let mut c = cfg.clone();
            c.threads = threads;
            let e = Engine::new(Arc::new(ModelWeights::init(&c, 42)));
            let want = e.prefill_reference(&tokens, None);
            let got = e.prefill(&tokens, None);
            assert_eq!(got.logits.data, want.logits.data, "logits, threads={threads}");
            for li in 0..c.n_layers {
                assert_eq!(got.xnorms[li].data, want.xnorms[li].data, "xnorm L{li}");
                assert_eq!(got.ks[li].data, want.ks[li].data, "k L{li}");
                assert_eq!(got.vs[li].data, want.vs[li].data, "v L{li}");
                assert_eq!(got.attn_mass[li], want.attn_mass[li], "mass L{li}");
            }
        }
    }

    /// Scratch reuse across different prompt lengths must resize cleanly
    /// and stay equal to fresh-scratch results.
    #[test]
    fn prefill_scratch_reuse_across_lengths() {
        let e = engine();
        let mut scratch = PrefillScratch::new();
        for t in [1usize, 5, 33, 64, 7] {
            let tokens: Vec<usize> = (0..t).map(|i| (i * 7 + 1) % 256).collect();
            let reused = e.prefill_with(&tokens, None, &mut scratch);
            let fresh = e.prefill(&tokens, None);
            assert_eq!(reused.logits.data, fresh.logits.data, "t={t}");
            assert_eq!(reused.attn_mass, fresh.attn_mass, "t={t}");
        }
    }

    /// The batched serving guarantee at engine granularity: fused
    /// multi-sequence prefill and GEMM-batched decode rounds are
    /// bit-identical to independent per-sequence calls — logits, records
    /// and policy state. (The cross-policy × batch-width × thread sweep
    /// lives in `rust/tests/batched_serving.rs`.)
    #[test]
    fn batched_prefill_and_decode_match_single_sequence() {
        let e = engine();
        let cfg = e.w.cfg.clone();
        let prompts: Vec<Vec<usize>> = vec![
            vec![1, 7, 9, 2],
            (0..37).map(|i| (i * 13 + 5) % 256).collect(),
            vec![4],
        ];
        let nb = prompts.len();

        // Sequential oracle: per-sequence prefill + decode.
        let mut seq_caches: Vec<FullCache> = (0..nb)
            .map(|_| FullCache::new(cfg.n_layers, cfg.d_model))
            .collect();
        let mut want_recs = Vec::new();
        for (p, c) in prompts.iter().zip(seq_caches.iter_mut()) {
            want_recs.push(e.prefill(p, Some(c)));
        }

        // Batched prefill.
        let mut batch_caches: Vec<FullCache> = (0..nb)
            .map(|_| FullCache::new(cfg.n_layers, cfg.d_model))
            .collect();
        let prompt_refs: Vec<&[usize]> = prompts.iter().map(|p| p.as_slice()).collect();
        let mut scratch = BatchPrefillScratch::new();
        let recs = {
            let mut policies: Vec<Option<&mut dyn KvCachePolicy>> = batch_caches
                .iter_mut()
                .map(|c| Some(c as &mut dyn KvCachePolicy))
                .collect();
            e.prefill_batch(&prompt_refs, &mut policies, &mut scratch)
        };
        assert_eq!(recs.len(), nb);
        for si in 0..nb {
            assert_eq!(recs[si].logits.data, want_recs[si].logits.data, "logits seq {si}");
            for li in 0..cfg.n_layers {
                assert_eq!(recs[si].xnorms[li].data, want_recs[si].xnorms[li].data);
                assert_eq!(recs[si].ks[li].data, want_recs[si].ks[li].data);
                assert_eq!(recs[si].vs[li].data, want_recs[si].vs[li].data);
                assert_eq!(recs[si].attn_mass[li], want_recs[si].attn_mass[li]);
                assert_eq!(
                    seq_caches[si].materialize(li).k.data,
                    batch_caches[si].materialize(li).k.data,
                    "cache state seq {si} L{li}"
                );
            }
        }

        // Decode rounds: batched vs per-sequence, 5 steps.
        let mut seq_states: Vec<DecodeState> = (0..nb).map(|_| DecodeState::new(&cfg)).collect();
        let mut batch_states: Vec<DecodeState> = (0..nb).map(|_| DecodeState::new(&cfg)).collect();
        let mut toks: Vec<usize> = (0..nb)
            .map(|si| crate::tensor::ops::argmax(recs[si].logits.row(prompts[si].len() - 1)))
            .collect();
        let mut pos: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
        let mut batch_scratch = BatchDecodeScratch::new();
        for step in 0..5 {
            let mut want_logits = Vec::with_capacity(nb);
            for si in 0..nb {
                let l = e.decode_step_with(
                    &mut seq_caches[si],
                    toks[si],
                    pos[si],
                    &mut seq_states[si],
                );
                want_logits.push(l.to_vec());
            }
            {
                let mut entries: Vec<BatchDecodeEntry> = batch_caches
                    .iter_mut()
                    .zip(batch_states.iter_mut())
                    .enumerate()
                    .map(|(si, (c, s))| BatchDecodeEntry {
                        policy: c as &mut dyn KvCachePolicy,
                        token: toks[si],
                        abs_pos: pos[si],
                        state: s,
                    })
                    .collect();
                e.decode_step_batch(&mut entries, &mut batch_scratch);
            }
            for si in 0..nb {
                assert_eq!(
                    batch_scratch.logits_row(si),
                    &want_logits[si][..],
                    "step {step} seq {si}: batched logits must be bit-identical"
                );
                toks[si] = crate::tensor::ops::argmax(&want_logits[si]);
                pos[si] += 1;
            }
        }
    }

    #[test]
    fn lm_loss_in_sane_range() {
        let e = engine();
        let tokens: Vec<usize> = (0..40).map(|i| (i * 13 + 5) % 256).collect();
        let loss = e.lm_loss(&tokens);
        // Untrained model ⇒ near-uniform ⇒ ln(256) ≈ 5.55
        assert!((4.5..6.5).contains(&loss), "loss={loss}");
    }

    #[test]
    fn calibration_capture_shapes_and_cap() {
        let e = engine();
        let docs = vec![vec![1, 2, 3, 4, 5, 6], vec![7, 8, 9, 10]];
        let pools = e.collect_calibration(&docs, 8, 1);
        assert_eq!(pools.len(), e.w.cfg.n_layers);
        for p in &pools {
            assert_eq!(p.rows, 8); // 10 rows available, capped at 8
            assert_eq!(p.cols, e.w.cfg.d_model);
        }
        let pools2 = e.collect_calibration(&docs, 100, 1);
        assert_eq!(pools2[0].rows, 10); // no cap
    }
}
