//! Pure-Rust reference engine.
//!
//! Runs TinyLM with **exact prefill** and **policy-driven decode**: every
//! KV-cache method (CSKV bi-branch, StreamingLLM, H2O, ASVD, full) plugs in
//! through [`KvCachePolicy`]. The engine is used by the quality grid
//! (Tables 1–5), calibration capture for ASVD/fine-tuning, and as the
//! numerical oracle for the PJRT artifacts (cross-validated in
//! `rust/tests/integration_runtime.rs`).
//!
//! Architecture (must mirror `python/compile/model.py` exactly):
//! pre-norm transformer, RMSNorm, rotate-half RoPE applied to Q/K per head,
//! causal MHA, SiLU MLP, untied LM head.

use std::sync::Arc;

use crate::kvcache::{DecodeView, KvCachePolicy};
use crate::tensor::matmul::{axpy_row, dot, matvec_t_into};
use crate::tensor::ops;
use crate::tensor::Mat;

use super::config::ModelConfig;
use super::weights::ModelWeights;

/// Everything captured during a prefill pass.
pub struct PrefillRecord {
    /// Per layer: attention inputs (`rmsnorm(x)`), `[T, d_model]` — the
    /// `X` of the paper's reconstruction loss.
    pub xnorms: Vec<Mat>,
    /// Per layer: pre-RoPE keys `[T, d_model]`.
    pub ks: Vec<Mat>,
    /// Per layer: values `[T, d_model]`.
    pub vs: Vec<Mat>,
    /// Per layer: aggregated attention mass per key position (H2O seed).
    pub attn_mass: Vec<Vec<f32>>,
    /// Full logits `[T, vocab]`.
    pub logits: Mat,
}

/// Timing + memory statistics for one generation.
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub prefill_s: f64,
    pub decode_s: f64,
    pub decode_steps: usize,
    pub kv_bytes_final: usize,
}

/// Preallocated per-generation work buffers for the decode hot loop.
///
/// Every intermediate `decode_step_with` needs lives here, so a steady-
/// state decode step performs no heap allocation (`rust/tests/
/// decode_alloc.rs` enforces this with a counting allocator). `scores`
/// and `agg_probs` grow with the cache; [`DecodeState::reserve`] sizes
/// them up front.
pub struct DecodeScratch {
    x: Vec<f32>,
    xnorm: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    o: Vec<f32>,
    xn2: Vec<f32>,
    h1: Vec<f32>,
    mlp: Vec<f32>,
    xf: Vec<f32>,
    logits: Vec<f32>,
    scores: Vec<f32>,
    agg_probs: Vec<f32>,
}

impl DecodeScratch {
    fn new(cfg: &ModelConfig) -> Self {
        let d = cfg.d_model;
        DecodeScratch {
            x: vec![0.0; d],
            xnorm: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            attn: vec![0.0; d],
            o: vec![0.0; d],
            xn2: vec![0.0; d],
            h1: vec![0.0; cfg.d_ff],
            mlp: vec![0.0; d],
            xf: vec![0.0; d],
            logits: vec![0.0; cfg.vocab_size],
            scores: Vec::new(),
            agg_probs: Vec::new(),
        }
    }
}

/// Engine-owned decode state for one in-flight generation: the persistent
/// per-layer [`DecodeView`]s (incrementally synced by the cache policy)
/// plus the [`DecodeScratch`] buffers. Create one per generation and pass
/// it to every [`Engine::decode_step_with`] call; see the kvcache module
/// docs for the single-live-view contract.
pub struct DecodeState {
    views: Vec<DecodeView>,
    scratch: DecodeScratch,
}

impl DecodeState {
    pub fn new(cfg: &ModelConfig) -> Self {
        DecodeState {
            views: (0..cfg.n_layers)
                .map(|_| DecodeView::new(cfg.d_model, cfg.n_heads, cfg.rope_base))
                .collect(),
            scratch: DecodeScratch::new(cfg),
        }
    }

    /// Reserve capacity for `total_tokens` cached rows per layer so that
    /// steady-state decode steps allocate nothing.
    pub fn reserve(&mut self, total_tokens: usize) {
        for v in &mut self.views {
            v.reserve(total_tokens);
        }
        let s = &mut self.scratch;
        s.scores.reserve(total_tokens.saturating_sub(s.scores.len()));
        s.agg_probs.reserve(total_tokens.saturating_sub(s.agg_probs.len()));
    }

    /// The synced view for `layer` (tests/diagnostics).
    pub fn view(&self, layer: usize) -> &DecodeView {
        &self.views[layer]
    }
}

/// The reference engine. Cheap to clone (weights are shared).
#[derive(Clone)]
pub struct Engine {
    pub w: Arc<ModelWeights>,
}

impl Engine {
    pub fn new(w: Arc<ModelWeights>) -> Self {
        Engine { w }
    }

    /// Exact prefill over `tokens`, feeding `policy` (if any) per layer.
    /// Policies may substitute lossy K/V for the attention itself (ASVD).
    pub fn prefill(&self, tokens: &[usize], mut policy: Option<&mut dyn KvCachePolicy>) -> PrefillRecord {
        let cfg = &self.w.cfg;
        let t = tokens.len();
        assert!(t > 0, "empty prompt");
        let (d, nh, dh) = (cfg.d_model, cfg.n_heads, cfg.d_head());
        let scale = 1.0 / (dh as f32).sqrt();

        // Embedding lookup.
        let mut x = Mat::zeros(t, d);
        for (i, &tok) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.w.embed.row(tok));
        }

        let mut xnorms = Vec::with_capacity(cfg.n_layers);
        let mut ks = Vec::with_capacity(cfg.n_layers);
        let mut vs = Vec::with_capacity(cfg.n_layers);
        let mut masses = Vec::with_capacity(cfg.n_layers);

        for (li, lw) in self.w.layers.iter().enumerate() {
            let xnorm = ops::rmsnorm_rows(&x, lw.ln1.row(0), cfg.eps);
            let q = xnorm.matmul(&lw.wq);
            let k = xnorm.matmul(&lw.wk); // pre-RoPE
            let v = xnorm.matmul(&lw.wv);

            // Hand the exact streams to the policy; it may substitute.
            let replacement = policy
                .as_deref_mut()
                .and_then(|p| p.ingest_prefill(li, &xnorm, &k, &v));
            let (k_use, v_use) = match replacement {
                Some((rk, rv)) => (rk, rv),
                None => (k.clone(), v.clone()),
            };

            // RoPE at absolute positions 0..t.
            let mut q_r = q;
            let mut k_r = k_use;
            ops::rope_rows(&mut q_r, nh, 0, cfg.rope_base);
            ops::rope_rows(&mut k_r, nh, 0, cfg.rope_base);

            // Causal MHA, accumulating attention mass for H2O.
            let mut attn_out = Mat::zeros(t, d);
            let mut mass = vec![0.0f32; t];
            for h in 0..nh {
                let (lo, hi) = (h * dh, (h + 1) * dh);
                let qh = q_r.cols_slice(lo, hi);
                let kh = k_r.cols_slice(lo, hi);
                let vh = v_use.cols_slice(lo, hi);
                let mut scores = qh.matmul_nt(&kh).scale(scale);
                ops::softmax_causal(&mut scores, 0);
                for i in 0..t {
                    for (j, &p) in scores.row(i).iter().enumerate() {
                        mass[j] += p;
                    }
                }
                let oh = scores.matmul(&vh);
                for i in 0..t {
                    attn_out.row_mut(i)[lo..hi].copy_from_slice(oh.row(i));
                }
            }
            if let Some(p) = policy.as_deref_mut() {
                p.observe_prefill_attn(li, &mass);
            }
            masses.push(mass);
            x.add_assign(&attn_out.matmul(&lw.wo));

            // MLP block.
            let xn2 = ops::rmsnorm_rows(&x, lw.ln2.row(0), cfg.eps);
            let mut h1 = xn2.matmul(&lw.w1);
            ops::silu_inplace(&mut h1);
            x.add_assign(&h1.matmul(&lw.w2));

            xnorms.push(xnorm);
            ks.push(k);
            vs.push(v);
        }

        let xf = ops::rmsnorm_rows(&x, self.w.ln_f.row(0), cfg.eps);
        let logits = xf.matmul(&self.w.lm_head);
        PrefillRecord {
            xnorms,
            ks,
            vs,
            attn_mass: masses,
            logits,
        }
    }

    /// One decode step for the token at absolute position `abs_pos`
    /// (0-based; the prompt occupied `0..abs_pos`), using the persistent
    /// per-generation `state`. Returns the logits row, borrowed from the
    /// state's scratch buffer.
    ///
    /// This is the zero-alloc hot path: all intermediates live in
    /// [`DecodeScratch`], cache keys are read from the incrementally
    /// synced [`DecodeView`]s (already reconstructed *and RoPE'd*), and
    /// the per-head score / weighted-sum loops run through the blocked
    /// [`dot`] / [`axpy_row`] kernels.
    pub fn decode_step_with<'s>(
        &self,
        policy: &mut dyn KvCachePolicy,
        token: usize,
        abs_pos: usize,
        state: &'s mut DecodeState,
    ) -> &'s [f32] {
        let cfg = &self.w.cfg;
        let (nh, dh) = (cfg.n_heads, cfg.d_head());
        let scale = 1.0 / (dh as f32).sqrt();
        let DecodeState { views, scratch } = state;

        scratch.x.copy_from_slice(self.w.embed.row(token));
        for (li, lw) in self.w.layers.iter().enumerate() {
            ops::rmsnorm(&scratch.x, lw.ln1.row(0), cfg.eps, &mut scratch.xnorm);
            matvec_t_into(&lw.wq, &scratch.xnorm, &mut scratch.q);
            matvec_t_into(&lw.wk, &scratch.xnorm, &mut scratch.k); // pre-RoPE
            matvec_t_into(&lw.wv, &scratch.xnorm, &mut scratch.v);

            policy.append(li, &scratch.xnorm, &scratch.k, &scratch.v);
            let view = &mut views[li];
            policy.sync_view(li, view);
            let view = &views[li];
            debug_assert_eq!(view.len(), policy.len(li));

            // RoPE the query at the policy's coordinate system (cached
            // keys were RoPE'd once, when written into the view).
            let qpos = policy.query_rope_pos(li, abs_pos);
            for h in 0..nh {
                ops::rope_rotate(&mut scratch.q[h * dh..(h + 1) * dh], qpos, cfg.rope_base);
            }

            // Per-head attention; aggregate probs across heads for H2O.
            let n = view.len();
            scratch.attn.fill(0.0);
            scratch.agg_probs.clear();
            scratch.agg_probs.resize(n, 0.0);
            for h in 0..nh {
                let (lo, hi) = (h * dh, (h + 1) * dh);
                let qh = &scratch.q[lo..hi];
                scratch.scores.clear();
                scratch.scores.resize(n, 0.0);
                let mut mx = f32::NEG_INFINITY;
                for (i, s) in scratch.scores.iter_mut().enumerate() {
                    *s = dot(qh, &view.key_row(i)[lo..hi]) * scale;
                    mx = mx.max(*s);
                }
                // softmax
                let mut sum = 0.0;
                for s in scratch.scores.iter_mut() {
                    *s = (*s - mx).exp();
                    sum += *s;
                }
                let inv = 1.0 / sum;
                for (i, s) in scratch.scores.iter_mut().enumerate() {
                    *s *= inv;
                    scratch.agg_probs[i] += *s;
                    axpy_row(&mut scratch.attn[lo..hi], *s, &view.value_row(i)[lo..hi]);
                }
            }
            policy.observe_decode_attn(li, view.abs_positions(), &scratch.agg_probs);

            // Output projection + residual.
            matvec_t_into(&lw.wo, &scratch.attn, &mut scratch.o);
            for (xi, oi) in scratch.x.iter_mut().zip(&scratch.o) {
                *xi += oi;
            }
            // MLP.
            ops::rmsnorm(&scratch.x, lw.ln2.row(0), cfg.eps, &mut scratch.xn2);
            matvec_t_into(&lw.w1, &scratch.xn2, &mut scratch.h1);
            for hv in scratch.h1.iter_mut() {
                *hv = ops::silu(*hv);
            }
            matvec_t_into(&lw.w2, &scratch.h1, &mut scratch.mlp);
            for (xi, mi) in scratch.x.iter_mut().zip(&scratch.mlp) {
                *xi += mi;
            }
        }
        ops::rmsnorm(&scratch.x, self.w.ln_f.row(0), cfg.eps, &mut scratch.xf);
        matvec_t_into(&self.w.lm_head, &scratch.xf, &mut scratch.logits);
        &scratch.logits
    }

    /// One decode step with a throwaway [`DecodeState`] (compatibility /
    /// cold path — the views are rebuilt from scratch every call). Prefer
    /// [`Engine::decode_step_with`] with a persistent state for decoding
    /// more than one token.
    pub fn decode_step(
        &self,
        policy: &mut dyn KvCachePolicy,
        token: usize,
        abs_pos: usize,
    ) -> Vec<f32> {
        let mut state = DecodeState::new(&self.w.cfg);
        self.decode_step_with(policy, token, abs_pos, &mut state)
            .to_vec()
    }

    /// Greedy generation: exact prefill + policy decode. Returns generated
    /// token ids (length `n_new`) and stats.
    pub fn generate(
        &self,
        prompt: &[usize],
        n_new: usize,
        policy: &mut dyn KvCachePolicy,
    ) -> (Vec<usize>, GenStats) {
        let t0 = std::time::Instant::now();
        let rec = self.prefill(prompt, Some(policy));
        let prefill_s = t0.elapsed().as_secs_f64();

        let mut out = Vec::with_capacity(n_new);
        let mut next = ops::argmax(rec.logits.row(prompt.len() - 1));
        let mut state = DecodeState::new(&self.w.cfg);
        state.reserve(prompt.len() + n_new);
        policy.reserve(n_new);
        let t1 = std::time::Instant::now();
        for i in 0..n_new {
            out.push(next);
            if i + 1 == n_new {
                break;
            }
            let logits = self.decode_step_with(policy, next, prompt.len() + i, &mut state);
            next = ops::argmax(logits);
        }
        let stats = GenStats {
            prefill_s,
            decode_s: t1.elapsed().as_secs_f64(),
            decode_steps: n_new.saturating_sub(1),
            kv_bytes_final: policy.kv_bytes(),
        };
        (out, stats)
    }

    /// Mean next-token cross-entropy over a token sequence (perplexity =
    /// exp of this), using exact attention.
    pub fn lm_loss(&self, tokens: &[usize]) -> f32 {
        assert!(tokens.len() >= 2);
        let rec = self.prefill(tokens, None);
        let targets: Vec<usize> = tokens[1..].to_vec();
        let logits = rec.logits.rows_slice(0, tokens.len() - 1);
        ops::cross_entropy_rows(&logits, &targets)
    }

    /// Capture calibration activations: per-layer `xnorm` matrices pooled
    /// over `docs`, row-subsampled to at most `max_rows` per layer.
    pub fn collect_calibration(
        &self,
        docs: &[Vec<usize>],
        max_rows: usize,
        seed: u64,
    ) -> Vec<Mat> {
        let cfg = &self.w.cfg;
        let mut pools: Vec<Mat> = (0..cfg.n_layers)
            .map(|_| Mat::zeros(0, cfg.d_model))
            .collect();
        for doc in docs {
            let rec = self.prefill(doc, None);
            for (li, xn) in rec.xnorms.iter().enumerate() {
                pools[li] = pools[li].vcat(xn);
            }
        }
        let mut rng = crate::util::prng::Pcg64::new(seed);
        pools
            .into_iter()
            .map(|p| {
                if p.rows <= max_rows {
                    p
                } else {
                    let idx = rng.sample_indices(p.rows, max_rows);
                    let mut out = Mat::zeros(max_rows, p.cols);
                    for (oi, &src) in idx.iter().enumerate() {
                        out.row_mut(oi).copy_from_slice(p.row(src));
                    }
                    out
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::FullCache;
    use crate::model::config::ModelConfig;

    fn engine() -> Engine {
        Engine::new(Arc::new(ModelWeights::init(&ModelConfig::test_small(), 42)))
    }

    #[test]
    fn prefill_shapes() {
        let e = engine();
        let rec = e.prefill(&[1, 5, 9, 3], None);
        let cfg = &e.w.cfg;
        assert_eq!(rec.logits.rows, 4);
        assert_eq!(rec.logits.cols, cfg.vocab_size);
        assert_eq!(rec.xnorms.len(), cfg.n_layers);
        assert_eq!(rec.ks[0].rows, 4);
        assert_eq!(rec.ks[0].cols, cfg.d_model);
    }

    /// THE core equivalence: decoding token-by-token with a full cache must
    /// produce the same logits as one exact prefill over the whole
    /// sequence. This validates the entire decode path (RoPE positions,
    /// cache ordering, masking) against the prefill path.
    #[test]
    fn decode_with_full_cache_matches_prefill() {
        let e = engine();
        let cfg = &e.w.cfg;
        let tokens = [1usize, 17, 30, 8, 99, 64, 2, 41];
        let full = e.prefill(&tokens, None);

        // Prefill only the first 3 tokens, then decode the rest.
        let mut cache = FullCache::new(cfg.n_layers, cfg.d_model);
        let pre = e.prefill(&tokens[..3], Some(&mut cache));
        for r in 0..3 {
            for c in 0..cfg.vocab_size {
                assert!(
                    (pre.logits.at(r, c) - full.logits.at(r, c)).abs() < 1e-4,
                    "prefill prefix logits must match"
                );
            }
        }
        for (i, &tok) in tokens[3..].iter().enumerate() {
            let abs = 3 + i;
            let logits = e.decode_step(&mut cache, tok, abs);
            let want = full.logits.row(abs);
            let max_diff = logits
                .iter()
                .zip(want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff < 1e-3, "step {abs}: max diff {max_diff}");
        }
    }

    /// The persistent incremental DecodeState must produce the same
    /// logits as both the throwaway-state wrapper and the exact prefill —
    /// the engine-level guarantee that view memoization changes nothing.
    #[test]
    fn incremental_state_matches_throwaway_and_prefill() {
        let e = engine();
        let cfg = &e.w.cfg;
        let tokens = [2usize, 11, 45, 7, 120, 9, 33, 60, 5, 71];
        let full = e.prefill(&tokens, None);

        let mut inc_cache = FullCache::new(cfg.n_layers, cfg.d_model);
        let _ = e.prefill(&tokens[..4], Some(&mut inc_cache));
        let mut state = DecodeState::new(cfg);
        state.reserve(tokens.len());

        let mut fresh_cache = FullCache::new(cfg.n_layers, cfg.d_model);
        let _ = e.prefill(&tokens[..4], Some(&mut fresh_cache));

        for (i, &tok) in tokens[4..].iter().enumerate() {
            let abs = 4 + i;
            let via_wrapper = e.decode_step(&mut fresh_cache, tok, abs);
            let via_state = e.decode_step_with(&mut inc_cache, tok, abs, &mut state);
            assert_eq!(via_state, &via_wrapper[..], "step {abs}: paths must be bit-identical");
            let want = full.logits.row(abs);
            let max_diff = via_state
                .iter()
                .zip(want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff < 1e-3, "step {abs}: max diff {max_diff}");
            // The synced view is always exactly the cache contents.
            state.view(0).validate();
            assert_eq!(state.view(0).len(), abs + 1);
        }
    }

    #[test]
    fn generate_is_deterministic_and_reports_stats() {
        let e = engine();
        let prompt = [1usize, 5, 20, 31, 7];
        let cfg = &e.w.cfg;
        let mut c1 = FullCache::new(cfg.n_layers, cfg.d_model);
        let mut c2 = FullCache::new(cfg.n_layers, cfg.d_model);
        let (g1, s1) = e.generate(&prompt, 6, &mut c1);
        let (g2, _) = e.generate(&prompt, 6, &mut c2);
        assert_eq!(g1, g2);
        assert_eq!(g1.len(), 6);
        assert!(s1.kv_bytes_final > 0);
        // 5 prompt + 5 decoded appends (last token is returned, not decoded)
        assert_eq!(c1.len(0), prompt.len() + 5);
    }

    #[test]
    fn lm_loss_in_sane_range() {
        let e = engine();
        let tokens: Vec<usize> = (0..40).map(|i| (i * 13 + 5) % 256).collect();
        let loss = e.lm_loss(&tokens);
        // Untrained model ⇒ near-uniform ⇒ ln(256) ≈ 5.55
        assert!((4.5..6.5).contains(&loss), "loss={loss}");
    }

    #[test]
    fn calibration_capture_shapes_and_cap() {
        let e = engine();
        let docs = vec![vec![1, 2, 3, 4, 5, 6], vec![7, 8, 9, 10]];
        let pools = e.collect_calibration(&docs, 8, 1);
        assert_eq!(pools.len(), e.w.cfg.n_layers);
        for p in &pools {
            assert_eq!(p.rows, 8); // 10 rows available, capped at 8
            assert_eq!(p.cols, e.w.cfg.d_model);
        }
        let pools2 = e.collect_calibration(&docs, 100, 1);
        assert_eq!(pools2[0].rows, 10); // no cap
    }
}
