//! Synthetic data: vocabulary, long-context task generators, and the
//! pretraining/calibration corpus.
//!
//! The paper evaluates pretrained 7B models on LongEval/LongBench/LVEval and
//! fine-tunes on a scaled-down Pile. None of those are available here
//! (offline, CPU-only), so per DESIGN.md §2 we *train our own* small model
//! (TinyLM) on a synthetic mixture whose evaluation tasks have the same
//! structure as the paper's:
//!
//! * [`vocab`] — fixed token-id layout (special tokens, line keys, digits,
//!   general vocabulary).
//! * [`tasks`] — LongEval-style line retrieval, LongBench-style multi-fact
//!   QA, LVEval-style confusing-fact retrieval.
//! * [`corpus`] — the pretraining mixture (retrieval documents + template
//!   language) and the calibration sampler for ASVD / reconstruction
//!   fine-tuning.

pub mod corpus;
pub mod tasks;
pub mod vocab;
