//! Long-context task generators — scaled analogues of the paper's three
//! evaluation suites (DESIGN.md §2 documents the mapping).
//!
//! * [`line_retrieval`] — LongEval: "line <key>: REGISTER_CONTENT is
//!   <digits>" documents followed by a retrieval query.
//! * [`multifact_qa`] — LongBench-E: facts embedded in filler text, query
//!   one fact; bucketed by context length.
//! * [`confusing_retrieval`] — LVEval: the hardest bucket — maximum
//!   distance to the queried fact plus near-miss distractor values that
//!   reuse the answer's digit prefix (reproducing the paper's observed
//!   "4244 vs 42440"-style failures).
//!
//! All generators emit token sequences directly in TinyLM's vocabulary.

use super::vocab as v;
use crate::util::prng::Pcg64;

/// One evaluation sample: the model must greedily continue `prompt` with
/// exactly `answer` (VALUE_LEN digit tokens).
#[derive(Clone, Debug)]
pub struct TaskSample {
    pub prompt: Vec<usize>,
    pub answer: Vec<usize>,
    /// Prompt length in tokens (the paper buckets by this).
    pub ctx_len: usize,
}

/// Tokens per retrieval line: LINE key REG IS d d d SEP.
pub const LINE_TOKENS: usize = 5 + v::VALUE_LEN;
/// Tokens of query suffix: QUERY key ANSWER.
pub const QUERY_TOKENS: usize = 3;

fn random_value(rng: &mut Pcg64) -> Vec<usize> {
    (0..v::VALUE_LEN).map(|_| v::digit_token(rng.below(10))).collect()
}

fn push_line(out: &mut Vec<usize>, key: usize, value: &[usize]) {
    out.push(v::LINE);
    out.push(v::key_token(key));
    out.push(v::REG);
    out.push(v::IS);
    out.extend_from_slice(value);
    out.push(v::SEP);
}

fn push_fact(out: &mut Vec<usize>, key: usize, value: &[usize]) {
    out.push(v::FACT);
    out.push(v::key_token(key));
    out.push(v::IS);
    out.extend_from_slice(value);
    out.push(v::SEP);
}

fn push_query(out: &mut Vec<usize>, key: usize) {
    out.push(v::QUERY);
    out.push(v::key_token(key));
    out.push(v::ANSWER);
}

/// Number of lines that fits a line-retrieval prompt of `ctx_len` tokens.
pub fn lines_for_ctx(ctx_len: usize) -> usize {
    ctx_len.saturating_sub(1 + QUERY_TOKENS) / LINE_TOKENS
}

/// LongEval-style line retrieval with `n_lines` lines; the queried line is
/// uniformly random, so expected retrieval distance grows with context.
pub fn line_retrieval(n_lines: usize, rng: &mut Pcg64) -> TaskSample {
    assert!(n_lines >= 1 && n_lines <= v::N_KEYS);
    let keys = rng.sample_indices(v::N_KEYS, n_lines);
    let mut prompt = vec![v::BOS];
    let mut values = Vec::with_capacity(n_lines);
    for &k in &keys {
        let val = random_value(rng);
        push_line(&mut prompt, k, &val);
        values.push(val);
    }
    let qi = rng.below(n_lines);
    push_query(&mut prompt, keys[qi]);
    let ctx_len = prompt.len();
    TaskSample {
        prompt,
        answer: values[qi].clone(),
        ctx_len,
    }
}

/// Line retrieval sized to approximately `ctx_len` prompt tokens.
pub fn line_retrieval_ctx(ctx_len: usize, rng: &mut Pcg64) -> TaskSample {
    line_retrieval(lines_for_ctx(ctx_len).max(1), rng)
}

/// LongBench-style multi-fact QA: `n_facts` facts at random positions in
/// filler text; total prompt ≈ `ctx_len` tokens.
pub fn multifact_qa(ctx_len: usize, n_facts: usize, rng: &mut Pcg64) -> TaskSample {
    assert!(n_facts >= 1 && n_facts <= v::N_KEYS);
    let fact_tokens = 4 + v::VALUE_LEN; // FACT key IS d.. SEP
    let budget = ctx_len.saturating_sub(1 + QUERY_TOKENS + n_facts * fact_tokens);
    let keys = rng.sample_indices(v::N_KEYS, n_facts);
    let values: Vec<Vec<usize>> = (0..n_facts).map(|_| random_value(rng)).collect();

    // Split the filler budget into n_facts+1 random chunks.
    let mut cuts: Vec<usize> = (0..n_facts).map(|_| rng.below(budget + 1)).collect();
    cuts.sort_unstable();
    let mut prompt = vec![v::BOS];
    let mut prev = 0;
    for i in 0..n_facts {
        push_filler(&mut prompt, cuts[i] - prev, rng);
        push_fact(&mut prompt, keys[i], &values[i]);
        prev = cuts[i];
    }
    push_filler(&mut prompt, budget - prev, rng);
    let qi = rng.below(n_facts);
    push_query(&mut prompt, keys[qi]);
    let ctx = prompt.len();
    TaskSample {
        prompt,
        answer: values[qi].clone(),
        ctx_len: ctx,
    }
}

/// LVEval-style: maximum retrieval distance (queried fact is the FIRST
/// fact) plus `n_confusers` near-miss facts whose values share the
/// answer's digit prefix but differ in the last digit.
pub fn confusing_retrieval(ctx_len: usize, n_confusers: usize, rng: &mut Pcg64) -> TaskSample {
    let fact_tokens = 4 + v::VALUE_LEN;
    let n_facts = (1 + n_confusers + 2).min(v::N_KEYS);
    let budget = ctx_len.saturating_sub(1 + QUERY_TOKENS + n_facts * fact_tokens);
    let keys = rng.sample_indices(v::N_KEYS, n_facts);
    let answer = random_value(rng);

    let mut prompt = vec![v::BOS];
    // Queried fact first — the longest possible retrieval distance.
    push_fact(&mut prompt, keys[0], &answer);
    for i in 1..n_facts {
        // Fill remaining budget between facts evenly-ish.
        let chunk = budget / (n_facts - 1);
        push_filler(&mut prompt, chunk, rng);
        let val = if i <= n_confusers {
            // Near-miss: same prefix, different final digit.
            let mut val = answer.clone();
            let last = val[v::VALUE_LEN - 1] - v::DIGIT_BASE;
            val[v::VALUE_LEN - 1] = v::digit_token((last + 1 + rng.below(9)) % 10);
            val
        } else {
            random_value(rng)
        };
        push_fact(&mut prompt, keys[i], &val);
    }
    push_query(&mut prompt, keys[0]);
    let ctx = prompt.len();
    TaskSample {
        prompt,
        answer,
        ctx_len: ctx,
    }
}

/// Append `n` filler tokens drawn from the bigram language model used by
/// the pretraining corpus (shared structure so filler is in-distribution).
pub fn push_filler(out: &mut Vec<usize>, n: usize, rng: &mut Pcg64) {
    let mut w = rng.below(v::N_WORDS);
    for i in 0..n {
        // End sentences occasionally with SEP for structure.
        if i > 0 && rng.chance(0.1) {
            out.push(v::SEP);
            w = rng.below(v::N_WORDS);
            continue;
        }
        out.push(v::word_token(w));
        w = next_word(w, rng);
    }
}

/// Deterministic-ish bigram transition: each word prefers a small set of
/// successors, giving the LM mixture learnable structure.
pub fn next_word(w: usize, rng: &mut Pcg64) -> usize {
    let base = (w * 7 + 3) % v::N_WORDS;
    (base + rng.below(4)) % v::N_WORDS
}

/// Exact-match scoring of generated digit tokens against the answer.
pub fn score_exact(generated: &[usize], answer: &[usize]) -> bool {
    generated.len() >= answer.len() && &generated[..answer.len()] == answer
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_retrieval_wellformed() {
        let mut rng = Pcg64::new(1);
        let s = line_retrieval(10, &mut rng);
        assert_eq!(s.prompt[0], v::BOS);
        assert_eq!(s.prompt.len(), 1 + 10 * LINE_TOKENS + QUERY_TOKENS);
        assert_eq!(s.answer.len(), v::VALUE_LEN);
        assert!(s.answer.iter().all(|&t| v::is_digit(t)));
        // Query key must appear in a line, and the answer must be that
        // line's value.
        let qkey = s.prompt[s.prompt.len() - 2];
        assert!(v::is_key(qkey));
        let pos = s.prompt.iter().position(|&t| t == qkey).unwrap();
        assert_eq!(&s.prompt[pos + 3..pos + 3 + v::VALUE_LEN], &s.answer[..]);
    }

    #[test]
    fn line_retrieval_ctx_sizing() {
        let mut rng = Pcg64::new(2);
        for ctx in [64, 128, 256, 448] {
            let s = line_retrieval_ctx(ctx, &mut rng);
            assert!(s.ctx_len <= ctx, "{} > {ctx}", s.ctx_len);
            assert!(s.ctx_len + LINE_TOKENS > ctx.saturating_sub(LINE_TOKENS));
        }
    }

    #[test]
    fn keys_are_unique_per_sample() {
        let mut rng = Pcg64::new(3);
        let s = line_retrieval(30, &mut rng);
        let mut keys: Vec<usize> = s
            .prompt
            .iter()
            .zip(s.prompt.iter().skip(1))
            .filter(|(&a, _)| a == v::LINE)
            .map(|(_, &b)| b)
            .collect();
        assert_eq!(keys.len(), 30);
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), 30, "line keys must be distinct");
    }

    #[test]
    fn multifact_qa_wellformed() {
        let mut rng = Pcg64::new(4);
        let s = multifact_qa(200, 5, &mut rng);
        assert!(s.ctx_len <= 205, "ctx={}", s.ctx_len);
        assert!(s.ctx_len >= 180, "ctx={}", s.ctx_len);
        let qkey = s.prompt[s.prompt.len() - 2];
        let pos = s.prompt.iter().position(|&t| t == qkey).unwrap();
        // FACT key IS d d d
        assert_eq!(s.prompt[pos - 1], v::FACT);
        assert_eq!(&s.prompt[pos + 2..pos + 2 + v::VALUE_LEN], &s.answer[..]);
    }

    #[test]
    fn confusing_retrieval_has_near_misses() {
        let mut rng = Pcg64::new(5);
        let s = confusing_retrieval(300, 2, &mut rng);
        // The queried fact is the first fact.
        assert_eq!(s.prompt[1], v::FACT);
        let qkey = s.prompt[s.prompt.len() - 2];
        assert_eq!(s.prompt[2], qkey);
        // Near-miss values share the first VALUE_LEN-1 digits.
        let prefix = &s.answer[..v::VALUE_LEN - 1];
        let mut near = 0;
        for i in 0..s.prompt.len() - v::VALUE_LEN {
            if s.prompt[i] == v::IS
                && s.prompt[i + 1..i + v::VALUE_LEN].iter().eq(prefix.iter())
                && s.prompt[i + v::VALUE_LEN] != s.answer[v::VALUE_LEN - 1]
                && v::is_digit(s.prompt[i + v::VALUE_LEN])
            {
                near += 1;
            }
        }
        assert!(near >= 2, "expected ≥2 near-miss facts, got {near}");
    }

    #[test]
    fn score_exact_behaviour() {
        assert!(score_exact(&[1, 2, 3, 9], &[1, 2, 3]));
        assert!(!score_exact(&[1, 2], &[1, 2, 3]));
        assert!(!score_exact(&[1, 2, 4], &[1, 2, 3]));
    }

    #[test]
    fn samples_are_seed_deterministic() {
        let a = line_retrieval(8, &mut Pcg64::new(42));
        let b = line_retrieval(8, &mut Pcg64::new(42));
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.answer, b.answer);
    }
}
