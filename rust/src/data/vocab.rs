//! Fixed token-id vocabulary layout for TinyLM.
//!
//! The synthetic tasks construct token sequences directly (no string
//! tokenizer is needed), but ids are organized into semantic ranges so
//! generators and scorers share one source of truth, and `detokenize`
//! renders sequences for debugging / failure-case inspection (the paper's
//! §3.2 discussion of failure modes is reproduced with these renderings).

/// Total vocabulary size (must match `python/compile/model.py`).
pub const VOCAB_SIZE: usize = 256;

// ----- special tokens -----------------------------------------------------
pub const PAD: usize = 0;
pub const BOS: usize = 1;
pub const EOS: usize = 2;
pub const SEP: usize = 3;
/// "What is the REGISTER_CONTENT in line …?"
pub const QUERY: usize = 4;
/// "line"
pub const LINE: usize = 5;
/// "REGISTER_CONTENT"
pub const REG: usize = 6;
/// "is"
pub const IS: usize = 7;
/// answer delimiter
pub const ANSWER: usize = 8;
/// fact marker for the QA tasks
pub const FACT: usize = 9;

// ----- ranges ---------------------------------------------------------------

/// Line/fact key ids (the "line 337" identifiers): 100 distinct keys.
pub const KEY_BASE: usize = 16;
pub const N_KEYS: usize = 100;

/// Digit tokens 0..9 — answers are [`VALUE_LEN`]-digit sequences, which
/// reproduces the paper's observed near-miss failures ("4244" vs "42440").
pub const DIGIT_BASE: usize = KEY_BASE + N_KEYS; // 116
pub const N_DIGITS: usize = 10;

/// General vocabulary for the language-modeling mixture.
pub const WORD_BASE: usize = DIGIT_BASE + N_DIGITS; // 126
pub const N_WORDS: usize = VOCAB_SIZE - WORD_BASE; // 130

/// Number of digit tokens per retrieval answer.
pub const VALUE_LEN: usize = 3;

pub fn key_token(k: usize) -> usize {
    assert!(k < N_KEYS);
    KEY_BASE + k
}

pub fn digit_token(d: usize) -> usize {
    assert!(d < N_DIGITS);
    DIGIT_BASE + d
}

pub fn word_token(w: usize) -> usize {
    assert!(w < N_WORDS);
    WORD_BASE + w
}

pub fn is_digit(tok: usize) -> bool {
    (DIGIT_BASE..DIGIT_BASE + N_DIGITS).contains(&tok)
}

pub fn is_key(tok: usize) -> bool {
    (KEY_BASE..KEY_BASE + N_KEYS).contains(&tok)
}

/// Render a token sequence for debugging and failure-case inspection.
pub fn detokenize(tokens: &[usize]) -> String {
    let mut out = String::new();
    for &t in tokens {
        let s = match t {
            PAD => "<pad>".to_string(),
            BOS => "<bos>".to_string(),
            EOS => "<eos>".to_string(),
            SEP => "·".to_string(),
            QUERY => "QUERY".to_string(),
            LINE => "line".to_string(),
            REG => "REGISTER_CONTENT".to_string(),
            IS => "is".to_string(),
            ANSWER => "=>".to_string(),
            FACT => "fact".to_string(),
            t if is_key(t) => format!("k{}", t - KEY_BASE),
            t if is_digit(t) => format!("{}", t - DIGIT_BASE),
            t if t >= WORD_BASE && t < VOCAB_SIZE => format!("w{}", t - WORD_BASE),
            t => format!("<{t}?>"),
        };
        out.push_str(&s);
        out.push(' ');
    }
    out.trim_end().to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_are_disjoint_and_fit() {
        assert!(KEY_BASE > FACT);
        assert_eq!(DIGIT_BASE, KEY_BASE + N_KEYS);
        assert_eq!(WORD_BASE, DIGIT_BASE + N_DIGITS);
        assert_eq!(WORD_BASE + N_WORDS, VOCAB_SIZE);
        assert!(N_WORDS > 64, "need a reasonable LM vocabulary");
    }

    #[test]
    fn classifiers_match_constructors() {
        assert!(is_key(key_token(0)));
        assert!(is_key(key_token(N_KEYS - 1)));
        assert!(!is_key(digit_token(0)));
        assert!(is_digit(digit_token(9)));
        assert!(!is_digit(word_token(0)));
    }

    #[test]
    fn detokenize_is_readable() {
        let seq = vec![BOS, LINE, key_token(42), REG, IS, digit_token(4), digit_token(2), SEP];
        let s = detokenize(&seq);
        assert_eq!(s, "<bos> line k42 REGISTER_CONTENT is 4 2 ·");
    }

    #[test]
    #[should_panic]
    fn key_token_bounds_checked() {
        let _ = key_token(N_KEYS);
    }
}
