//! Pretraining mixture + calibration sampler.
//!
//! Stands in for (a) the base models' pretraining data and (b) the paper's
//! scaled-down-Pile fine-tuning/calibration set. The mixture is:
//!
//! * 50% LongEval-style line-retrieval documents (teaches the long-range
//!   retrieval behaviour the paper's benchmarks probe),
//! * 20% multi-fact QA documents,
//! * 10% LVEval-style confusing-fact documents,
//! * 20% bigram template language (keeps perplexity meaningful and the
//!   activations diverse for calibration).
//!
//! Documents are generated to a fixed `seq_len`, padded with `PAD`; the
//! training loss masks positions whose *target* is `PAD`.

use super::tasks;
use super::vocab as v;
use crate::util::prng::Pcg64;

/// A training batch in next-token-prediction layout.
#[derive(Clone, Debug)]
pub struct Batch {
    /// `[batch, seq]` input token ids, flattened row-major.
    pub x: Vec<i32>,
    /// `[batch, seq]` target ids (inputs shifted left).
    pub y: Vec<i32>,
    /// `[batch, seq]` loss mask (1.0 where the target counts).
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

/// Configuration for the corpus generator.
#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub seq_len: usize,
    /// Mixture weights: [line_retrieval, multifact_qa, confusing, language].
    pub mix: [f32; 4],
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            seq_len: 512,
            mix: [0.5, 0.2, 0.1, 0.2],
        }
    }
}

/// Generate one document of exactly `seq_len + 1` tokens (so x/y shift fits).
///
/// Retrieval documents place the full task followed by the answer and EOS,
/// then pad; language documents fill the whole window.
pub fn gen_document(cfg: &CorpusConfig, rng: &mut Pcg64) -> Vec<usize> {
    let want = cfg.seq_len + 1;
    let kind = rng.categorical(&cfg.mix);
    let mut doc = match kind {
        0 => {
            // Random context length: vary retrieval distance during training
            // so evaluation lengths are in-distribution.
            let max_lines = tasks::lines_for_ctx(want - v::VALUE_LEN - 1);
            let n_lines = rng.range(2, max_lines.max(3));
            let s = tasks::line_retrieval(n_lines.min(v::N_KEYS), rng);
            let mut d = s.prompt;
            d.extend_from_slice(&s.answer);
            d.push(v::EOS);
            d
        }
        1 => {
            let ctx = rng.range(want / 4, want - v::VALUE_LEN - 1);
            let n_facts = rng.range(2, 9);
            let s = tasks::multifact_qa(ctx, n_facts, rng);
            let mut d = s.prompt;
            d.extend_from_slice(&s.answer);
            d.push(v::EOS);
            d
        }
        2 => {
            let ctx = rng.range(want / 2, want - v::VALUE_LEN - 1);
            let s = tasks::confusing_retrieval(ctx, 2, rng);
            let mut d = s.prompt;
            d.extend_from_slice(&s.answer);
            d.push(v::EOS);
            d
        }
        _ => {
            let mut d = vec![v::BOS];
            tasks::push_filler(&mut d, want - 2, rng);
            d.push(v::EOS);
            d
        }
    };
    doc.truncate(want);
    while doc.len() < want {
        doc.push(v::PAD);
    }
    doc
}

/// Loss weight for answer-digit targets. Retrieval answers are ~3 tokens
/// out of ~500, so without upweighting the retrieval gradient vanishes
/// into the filler LM signal and the model never learns to retrieve.
pub const ANSWER_WEIGHT: f32 = 16.0;

/// Pack documents back-to-back until the row is full: short retrieval
/// tasks would otherwise leave >90% of every row as PAD, starving the
/// model of retrieval examples (documents already start with BOS and end
/// with EOS, so boundaries are marked).
pub fn pack_row(cfg: &CorpusConfig, rng: &mut Pcg64) -> Vec<usize> {
    let want = cfg.seq_len + 1;
    let mut row = Vec::with_capacity(want + 64);
    while row.len() < want {
        let remaining = want - row.len();
        // Bias document sizes: mostly short (packable) tasks, sometimes a
        // long one that spans the remaining window (long-range retrieval
        // must stay in-distribution for the 4k-10k-style eval lengths).
        let doc_cfg = CorpusConfig {
            seq_len: if rng.chance(0.25) {
                remaining.max(32) - 1
            } else {
                rng.range(32, (remaining).clamp(33, 160)) // short task
            },
            mix: cfg.mix,
        };
        let mut doc = gen_document(&doc_cfg, rng);
        // Strip padding before packing.
        while doc.last() == Some(&v::PAD) {
            doc.pop();
        }
        row.extend_from_slice(&doc);
    }
    row.truncate(want);
    row
}

/// Generate a next-token training batch (packed rows).
pub fn gen_batch(cfg: &CorpusConfig, batch: usize, rng: &mut Pcg64) -> Batch {
    let seq = cfg.seq_len;
    let mut x = Vec::with_capacity(batch * seq);
    let mut y = Vec::with_capacity(batch * seq);
    let mut mask = Vec::with_capacity(batch * seq);
    for _ in 0..batch {
        let doc = pack_row(cfg, rng);
        // Positions whose *target* is an answer digit (the VALUE_LEN
        // tokens right after ANSWER) get boosted weight.
        let mut w = vec![1.0f32; seq];
        for a in 0..seq {
            if doc[a] == v::ANSWER {
                for t in a..(a + v::VALUE_LEN).min(seq) {
                    w[t] = ANSWER_WEIGHT;
                }
            }
        }
        for t in 0..seq {
            x.push(doc[t] as i32);
            y.push(doc[t + 1] as i32);
            mask.push(if doc[t + 1] == v::PAD { 0.0 } else { w[t] });
        }
    }
    Batch {
        x,
        y,
        mask,
        batch,
        seq,
    }
}

/// Calibration documents for ASVD scaling + reconstruction fine-tuning:
/// prompt-only prefixes (no answers needed — only activations are used).
pub fn calibration_docs(cfg: &CorpusConfig, n_docs: usize, seed: u64) -> Vec<Vec<usize>> {
    let mut rng = Pcg64::new(seed);
    (0..n_docs)
        .map(|_| {
            let mut d = gen_document(cfg, &mut rng);
            // Strip padding — calibration runs variable-length prefills.
            while d.last() == Some(&v::PAD) {
                d.pop();
            }
            d
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn documents_have_exact_length() {
        let cfg = CorpusConfig::default();
        let mut rng = Pcg64::new(1);
        for _ in 0..20 {
            let d = gen_document(&cfg, &mut rng);
            assert_eq!(d.len(), cfg.seq_len + 1);
            assert!(d.iter().all(|&t| t < v::VOCAB_SIZE));
        }
    }

    #[test]
    fn batch_shapes_and_shift() {
        let cfg = CorpusConfig {
            seq_len: 64,
            ..Default::default()
        };
        let mut rng = Pcg64::new(2);
        let b = gen_batch(&cfg, 3, &mut rng);
        assert_eq!(b.x.len(), 3 * 64);
        assert_eq!(b.y.len(), 3 * 64);
        assert_eq!(b.mask.len(), 3 * 64);
        // y is x shifted by one within each row (verify via regeneration:
        // x[t+1] == y[t] wherever both are in range and not padding joints).
        for row in 0..3 {
            for t in 0..63 {
                let xi = b.x[row * 64 + t + 1];
                let yi = b.y[row * 64 + t];
                assert_eq!(xi, yi);
            }
        }
    }

    #[test]
    fn mask_weights_answers_and_zeroes_pads() {
        let cfg = CorpusConfig {
            seq_len: 96,
            mix: [1.0, 0.0, 0.0, 0.0],
            ..Default::default()
        };
        let mut rng = Pcg64::new(3);
        let b = gen_batch(&cfg, 4, &mut rng);
        let mut saw_weighted = false;
        for i in 0..b.x.len() {
            if b.y[i] == v::PAD as i32 {
                assert_eq!(b.mask[i], 0.0);
            } else {
                assert!(b.mask[i] == 1.0 || b.mask[i] == ANSWER_WEIGHT);
            }
            if b.mask[i] == ANSWER_WEIGHT {
                saw_weighted = true;
                assert!(v::is_digit(b.y[i] as usize), "boosted target must be a digit");
            }
        }
        assert!(saw_weighted, "packed retrieval rows must contain answers");
    }

    #[test]
    fn packed_rows_are_dense_with_tasks() {
        let cfg = CorpusConfig::default();
        let mut rng = Pcg64::new(13);
        let row = pack_row(&cfg, &mut rng);
        assert_eq!(row.len(), cfg.seq_len + 1);
        // Packing should land several documents per row.
        let n_bos = row.iter().filter(|&&t| t == v::BOS).count();
        assert!(n_bos >= 2, "expected ≥2 packed docs, got {n_bos}");
        assert!(!row.contains(&v::PAD));
    }

    #[test]
    fn mixture_hits_all_kinds() {
        let cfg = CorpusConfig {
            seq_len: 128,
            mix: [0.25, 0.25, 0.25, 0.25],
        };
        let mut rng = Pcg64::new(4);
        let mut saw_query = false;
        let mut saw_fact = false;
        let mut saw_lang_only = false;
        for _ in 0..40 {
            let d = gen_document(&cfg, &mut rng);
            if d.contains(&v::QUERY) {
                saw_query = true;
            }
            if d.contains(&v::FACT) {
                saw_fact = true;
            }
            if !d.contains(&v::QUERY) && !d.contains(&v::FACT) {
                saw_lang_only = true;
            }
        }
        assert!(saw_query && saw_fact && saw_lang_only);
    }

    #[test]
    fn calibration_docs_strip_padding() {
        let cfg = CorpusConfig::default();
        let docs = calibration_docs(&cfg, 5, 7);
        assert_eq!(docs.len(), 5);
        for d in &docs {
            assert_ne!(*d.last().unwrap(), v::PAD);
            assert!(d.len() <= cfg.seq_len + 1);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = CorpusConfig::default();
        let a = gen_batch(&cfg, 2, &mut Pcg64::new(9));
        let b = gen_batch(&cfg, 2, &mut Pcg64::new(9));
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }
}
