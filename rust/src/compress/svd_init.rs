//! Factor initialization — §2.2 of the paper + the Table 2 ablation.
//!
//! * `Random`  — Kaiming-uniform A, B. The paper shows this fails to
//!   converge (loss ~1e9, accuracy 0.00); reproduced by `bench_table2_init`.
//! * `Svd`     — truncated SVD of `W`: `A = U_r √Σ_r`, `B = √Σ_r V_rᵀ`.
//! * `Asvd`    — activation-aware SVD [Yuan et al., 2024], the paper's
//!   default: scale input channels by `S = diag(mean|X_j|^α)` before the
//!   SVD so directions that carry large activations are preserved.
//!   `X·W = (X·S⁻¹)(S·W)`; with `SVD(S·W) = UΣVᵀ`,
//!   `A = S⁻¹·U_r·√Σ_r`, `B = √Σ_r·V_rᵀ`.
//! * `Oracle`  — closed-form rank-r minimizer of ‖XW − XAB‖_F via QR+SVD
//!   (our extension; upper-bounds what reconstruction training can reach).

use crate::tensor::linalg::oracle_lowrank;
use crate::tensor::svd::svd;
use crate::tensor::Mat;
use crate::util::prng::Pcg64;

use super::lowrank::LowRankFactors;

/// Initialization method for the low-rank factors.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InitMethod {
    Random,
    Svd,
    /// α is the activation-scaling exponent; the paper uses 0.5 with the
    /// Absolute Mean Value statistic.
    Asvd {
        alpha: f32,
    },
    Oracle,
}

impl InitMethod {
    pub fn asvd_default() -> Self {
        InitMethod::Asvd { alpha: 0.5 }
    }

    pub fn name(&self) -> String {
        match self {
            InitMethod::Random => "random".into(),
            InitMethod::Svd => "svd".into(),
            InitMethod::Asvd { alpha } => format!("asvd(a={alpha})"),
            InitMethod::Oracle => "oracle".into(),
        }
    }
}

/// Initialize factors for one projection `w: [d_in, d_out]` at `rank`.
///
/// `calib_x` (`[n, d_in]`, the layer's attention-input activations) is
/// required for `Asvd` and `Oracle`; ignored by the others.
pub fn init_factors(
    w: &Mat,
    rank: usize,
    method: InitMethod,
    calib_x: Option<&Mat>,
    seed: u64,
) -> LowRankFactors {
    let rank = rank.clamp(1, w.rows.min(w.cols));
    match method {
        InitMethod::Random => {
            let mut rng = Pcg64::new(seed);
            // Kaiming-uniform bound for each factor.
            let bound_a = (6.0 / w.rows as f32).sqrt();
            let bound_b = (6.0 / rank as f32).sqrt();
            let mut a = Mat::zeros(w.rows, rank);
            let mut b = Mat::zeros(rank, w.cols);
            rng.fill_uniform(&mut a.data, bound_a);
            rng.fill_uniform(&mut b.data, bound_b);
            LowRankFactors::new(a, b)
        }
        InitMethod::Svd => {
            let d = svd(w);
            split_sqrt(&d, rank)
        }
        InitMethod::Asvd { alpha } => {
            let x = calib_x.expect("ASVD init requires calibration activations");
            assert_eq!(x.cols, w.rows, "calibration/weight shape mismatch");
            // Absolute Mean Value scaling (paper's setting).
            let s: Vec<f32> = x
                .col_abs_mean()
                .iter()
                .map(|&m| m.max(1e-6).powf(alpha))
                .collect();
            // SW: scale rows of W by s.
            let mut sw = w.clone();
            for (i, &si) in s.iter().enumerate() {
                sw.scale_row(i, si);
            }
            let d = svd(&sw);
            let f = split_sqrt(&d, rank);
            // A = S^{-1} * (U_r sqrt(Σ))
            let mut a = f.a;
            for (i, &si) in s.iter().enumerate() {
                a.scale_row(i, 1.0 / si);
            }
            LowRankFactors::new(a, f.b)
        }
        InitMethod::Oracle => {
            let x = calib_x.expect("Oracle init requires calibration activations");
            let (a, b) = oracle_lowrank(x, w, rank);
            LowRankFactors::new(a, b)
        }
    }
}

/// Split `U Σ Vᵀ` symmetrically: `A = U_r √Σ_r`, `B = √Σ_r V_rᵀ`.
/// The symmetric split balances the factor norms, which conditions the
/// subsequent Adam fine-tuning better than `UΣ · Vᵀ`.
fn split_sqrt(d: &crate::tensor::svd::Svd, rank: usize) -> LowRankFactors {
    let rank = rank.min(d.s.len());
    let mut a = d.u.cols_slice(0, rank);
    let mut bt = d.v.cols_slice(0, rank); // [d_out, r]
    for j in 0..rank {
        let sq = d.s[j].max(0.0).sqrt();
        a.scale_col(j, sq);
        bt.scale_col(j, sq);
    }
    LowRankFactors::new(a, bt.t())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planted_problem(seed: u64, n: usize, d: usize) -> (Mat, Mat) {
        let mut rng = Pcg64::new(seed);
        let x = Mat::randn(n, d, 1.0, &mut rng);
        let w = Mat::randn(d, d, 0.1, &mut rng);
        (x, w)
    }

    #[test]
    fn svd_init_is_eckart_young() {
        let (_, w) = planted_problem(1, 64, 16);
        let f = init_factors(&w, 16, InitMethod::Svd, None, 0);
        // Full rank ⇒ exact.
        assert!(f.effective_weight().allclose(&w, 1e-3));
        let f4 = init_factors(&w, 4, InitMethod::Svd, None, 0);
        // Truncation error equals the singular tail.
        let s = crate::tensor::svd::singular_values(&w);
        let tail = crate::tensor::svd::lowrank_error(&s, 4);
        let err = f4.effective_weight().sub(&w).frob_norm();
        assert!((err - tail).abs() / tail.max(1e-9) < 0.05, "{err} vs {tail}");
    }

    #[test]
    fn asvd_beats_svd_with_skewed_activations() {
        // Anisotropic X: ASVD should give lower X-weighted error than SVD.
        let mut rng = Pcg64::new(2);
        let d = 24;
        let mut x = Mat::randn(200, d, 1.0, &mut rng);
        for j in 0..d {
            let s = if j < 3 { 8.0 } else { 0.05 };
            x.scale_col(j, s);
        }
        let w = Mat::randn(d, d, 0.1, &mut rng);
        let r = 6;
        let fa = init_factors(&w, r, InitMethod::asvd_default(), Some(&x), 0);
        let fs = init_factors(&w, r, InitMethod::Svd, None, 0);
        let (ea, es) = (fa.relative_error(&x, &w), fs.relative_error(&x, &w));
        assert!(ea < es, "asvd {ea} should beat svd {es}");
    }

    #[test]
    fn oracle_lower_bounds_others() {
        let mut rng = Pcg64::new(3);
        let d = 20;
        let mut x = Mat::randn(150, d, 1.0, &mut rng);
        for j in 0..d {
            x.scale_col(j, 1.0 + j as f32);
        }
        let w = Mat::randn(d, d, 0.1, &mut rng);
        let r = 5;
        let fo = init_factors(&w, r, InitMethod::Oracle, Some(&x), 0);
        for m in [InitMethod::Svd, InitMethod::asvd_default()] {
            let f = init_factors(&w, r, m, Some(&x), 0);
            assert!(
                fo.relative_error(&x, &w) <= f.relative_error(&x, &w) * 1.01,
                "oracle must not lose to {m:?}"
            );
        }
    }

    #[test]
    fn random_init_has_large_error() {
        let (x, w) = planted_problem(4, 100, 16);
        let fr = init_factors(&w, 8, InitMethod::Random, None, 9);
        let fs = init_factors(&w, 8, InitMethod::Svd, None, 0);
        assert!(fr.relative_error(&x, &w) > 5.0 * fs.relative_error(&x, &w));
    }

    #[test]
    fn rank_is_clamped() {
        let (_, w) = planted_problem(5, 10, 8);
        let f = init_factors(&w, 10_000, InitMethod::Svd, None, 0);
        assert_eq!(f.rank(), 8);
    }

    #[test]
    fn deterministic_for_seed() {
        let (_, w) = planted_problem(6, 10, 8);
        let a = init_factors(&w, 4, InitMethod::Random, None, 11);
        let b = init_factors(&w, 4, InitMethod::Random, None, 11);
        assert_eq!(a, b);
    }
}
