//! Channel-shrinking compression machinery (§2 of the paper).
//!
//! * [`lowrank`] — the `A·B` factor pair that replaces `W_K`/`W_V`; the
//!   intermediate feature `C = X·A` is what the compressed cache stores.
//! * [`ratio`] — compression-ratio bookkeeping, including the Table 4
//!   K/V allocation arithmetic (keep fractions, ranks, memory math).
//! * [`svd_init`] — Random / SVD / ASVD / Oracle initialization of the
//!   factors (§2.2 + the Table 2 ablation; Oracle is our extension).
//! * [`quant`] — KIVI-style int4 group quantization (per-channel keys,
//!   per-token values) for the Table 5 integration.

pub mod lowrank;
pub mod quant;
pub mod ratio;
pub mod svd_init;

pub use lowrank::{LayerFactors, LowRankFactors, ModelFactors};
pub use ratio::KvCompressionPlan;
pub use svd_init::InitMethod;
