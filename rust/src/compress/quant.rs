//! KIVI-style int4 group quantization of the compressed cache (Table 5).
//!
//! Following the paper's §C.4 setup: asymmetric 4-bit quantization applied
//! to the *compressed* features `C`, **per-channel** for keys (statistics
//! over the token axis within a group) and **per-token** for values.
//! Tokens are quantized in groups of [`GROUP`] once a group fills; the
//! residual (< GROUP newest tokens) stays fp32, exactly like KIVI's
//! residual window.

use crate::tensor::Mat;

/// Group length in tokens (the paper sets window = residual = 32).
pub const GROUP: usize = 32;

/// Quantization statistic axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantAxis {
    /// Scale/zero per column (channel) across the group's tokens — keys.
    PerChannel,
    /// Scale/zero per row (token) across channels — values.
    PerToken,
}

/// A quantized `[rows, cols]` block: packed int4 codes + affine params.
#[derive(Clone, Debug)]
pub struct QuantizedBlock {
    pub rows: usize,
    pub cols: usize,
    pub axis: QuantAxis,
    /// Two 4-bit codes per byte, row-major.
    packed: Vec<u8>,
    scale: Vec<f32>,
    zero: Vec<f32>,
}

fn params_len(rows: usize, cols: usize, axis: QuantAxis) -> usize {
    match axis {
        QuantAxis::PerChannel => cols,
        QuantAxis::PerToken => rows,
    }
}

/// Quantize a dense block to int4.
pub fn quantize_block(m: &Mat, axis: QuantAxis) -> QuantizedBlock {
    let (rows, cols) = (m.rows, m.cols);
    let np = params_len(rows, cols, axis);
    let mut mins = vec![f32::INFINITY; np];
    let mut maxs = vec![f32::NEG_INFINITY; np];
    for i in 0..rows {
        for j in 0..cols {
            let p = match axis {
                QuantAxis::PerChannel => j,
                QuantAxis::PerToken => i,
            };
            let v = m.at(i, j);
            mins[p] = mins[p].min(v);
            maxs[p] = maxs[p].max(v);
        }
    }
    let mut scale = vec![0.0f32; np];
    let mut zero = vec![0.0f32; np];
    for p in 0..np {
        let range = (maxs[p] - mins[p]).max(1e-8);
        scale[p] = range / 15.0;
        zero[p] = mins[p];
    }
    let n = rows * cols;
    let mut packed = vec![0u8; n.div_ceil(2)];
    for i in 0..rows {
        for j in 0..cols {
            let p = match axis {
                QuantAxis::PerChannel => j,
                QuantAxis::PerToken => i,
            };
            let q = (((m.at(i, j) - zero[p]) / scale[p]).round() as i32).clamp(0, 15) as u8;
            let idx = i * cols + j;
            if idx % 2 == 0 {
                packed[idx / 2] |= q;
            } else {
                packed[idx / 2] |= q << 4;
            }
        }
    }
    QuantizedBlock {
        rows,
        cols,
        axis,
        packed,
        scale,
        zero,
    }
}

impl QuantizedBlock {
    /// Packed int4 codes (two per byte, row-major) — snapshot serialization.
    pub fn packed(&self) -> &[u8] {
        &self.packed
    }

    /// Affine scales (one per channel or token, by [`QuantizedBlock::axis`]).
    pub fn scale(&self) -> &[f32] {
        &self.scale
    }

    /// Affine zero points.
    pub fn zero(&self) -> &[f32] {
        &self.zero
    }

    /// Reassemble a block from its serialized parts (snapshot restore).
    /// Validates every length so corrupt cold-tier data errors instead
    /// of panicking later; the codes and params are taken verbatim, so a
    /// round-trip through [`QuantizedBlock::packed`] etc. dequantizes
    /// bit-identically.
    pub fn from_raw(
        rows: usize,
        cols: usize,
        axis: QuantAxis,
        packed: Vec<u8>,
        scale: Vec<f32>,
        zero: Vec<f32>,
    ) -> anyhow::Result<Self> {
        let np = params_len(rows, cols, axis);
        anyhow::ensure!(
            packed.len() == (rows * cols).div_ceil(2),
            "quant block: packed {} != {} for {rows}x{cols}",
            packed.len(),
            (rows * cols).div_ceil(2)
        );
        anyhow::ensure!(
            scale.len() == np && zero.len() == np,
            "quant block: params {}/{} != {np}",
            scale.len(),
            zero.len()
        );
        Ok(QuantizedBlock {
            rows,
            cols,
            axis,
            packed,
            scale,
            zero,
        })
    }

    /// Dequantize back to f32.
    pub fn dequantize(&self) -> Mat {
        self.dequantize_rows(0, self.rows)
    }

    /// Dequantize a row range `[lo, hi)` only (tile-wise reconstruction).
    pub fn dequantize_rows(&self, lo: usize, hi: usize) -> Mat {
        let mut out = Mat::zeros(hi - lo, self.cols);
        self.dequantize_rows_into(lo, hi, &mut out.data);
        out
    }

    /// Dequantize rows `[lo, hi)` directly into a caller-provided slice of
    /// `(hi - lo) * cols` floats — the allocation-free path used when
    /// assembling multi-group reconstructions into one preallocated
    /// buffer.
    pub fn dequantize_rows_into(&self, lo: usize, hi: usize, out: &mut [f32]) {
        assert!(lo <= hi && hi <= self.rows);
        assert_eq!(out.len(), (hi - lo) * self.cols);
        for i in lo..hi {
            for j in 0..self.cols {
                let idx = i * self.cols + j;
                let byte = self.packed[idx / 2];
                let q = if idx % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                let p = match self.axis {
                    QuantAxis::PerChannel => j,
                    QuantAxis::PerToken => i,
                };
                out[(i - lo) * self.cols + j] = q as f32 * self.scale[p] + self.zero[p];
            }
        }
    }

    /// True storage footprint: packed codes + affine params.
    pub fn bytes(&self) -> usize {
        self.packed.len() + (self.scale.len() + self.zero.len()) * 4
    }
}

/// Quantize–dequantize (straight-through fake quant) — the QAT loss path
/// and the PTQ evaluation path share this.
pub fn fake_quant(m: &Mat, axis: QuantAxis) -> Mat {
    quantize_block(m, axis).dequantize()
}

/// Worst-case absolute quantization error for a block (half a step).
pub fn max_quant_step(m: &Mat, axis: QuantAxis) -> f32 {
    let q = quantize_block(m, axis);
    q.scale.iter().fold(0.0f32, |a, &s| a.max(s)) * 0.5
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Pcg64::new(1);
        for axis in [QuantAxis::PerChannel, QuantAxis::PerToken] {
            let m = Mat::randn(32, 16, 1.0, &mut rng);
            let q = quantize_block(&m, axis);
            let d = q.dequantize();
            // Error per element must be within half a quantization step.
            let step = max_quant_step(&m, axis);
            assert!(
                m.max_abs_diff(&d) <= step + 1e-5,
                "axis={axis:?} err={} step={step}",
                m.max_abs_diff(&d)
            );
        }
    }

    #[test]
    fn per_channel_robust_to_channel_scale_outliers() {
        // A channel with huge magnitude must not destroy other channels —
        // the reason KIVI uses per-channel for keys.
        let mut rng = Pcg64::new(2);
        let mut m = Mat::randn(32, 8, 1.0, &mut rng);
        m.scale_col(0, 100.0);
        let dc = fake_quant(&m, QuantAxis::PerChannel).sub(&m);
        let dt = fake_quant(&m, QuantAxis::PerToken).sub(&m);
        // Error on the non-outlier columns:
        let ec = dc.cols_slice(1, 8).frob_norm();
        let et = dt.cols_slice(1, 8).frob_norm();
        assert!(ec < et / 3.0, "per-channel {ec} should beat per-token {et}");
    }

    #[test]
    fn per_token_robust_to_token_outliers() {
        let mut rng = Pcg64::new(3);
        let mut m = Mat::randn(16, 8, 1.0, &mut rng);
        m.scale_row(0, 100.0);
        let dt = fake_quant(&m, QuantAxis::PerToken).sub(&m);
        let dc = fake_quant(&m, QuantAxis::PerChannel).sub(&m);
        let et = dt.rows_slice(1, 16).frob_norm();
        let ec = dc.rows_slice(1, 16).frob_norm();
        assert!(et < ec / 3.0, "per-token {et} should beat per-channel {ec}");
    }

    #[test]
    fn packing_is_4bit() {
        let mut rng = Pcg64::new(4);
        let m = Mat::randn(GROUP, 26, 1.0, &mut rng);
        let q = quantize_block(&m, QuantAxis::PerChannel);
        // 32*26 codes = 416 bytes packed, + 2*26 f32 params = 208 bytes
        assert_eq!(q.bytes(), (GROUP * 26) / 2 + 2 * 26 * 4);
        // 8× reduction on codes vs f32 (modulo params overhead)
        assert!(q.bytes() * 4 < GROUP * 26 * 4);
    }

    #[test]
    fn dequantize_rows_matches_full() {
        let mut rng = Pcg64::new(5);
        let m = Mat::randn(20, 6, 1.0, &mut rng);
        let q = quantize_block(&m, QuantAxis::PerChannel);
        let full = q.dequantize();
        let part = q.dequantize_rows(5, 13);
        assert!(part.allclose(&full.rows_slice(5, 13), 1e-6));
    }

    #[test]
    fn constant_block_exact() {
        let m = Mat::from_vec(4, 4, vec![3.5; 16]);
        let d = fake_quant(&m, QuantAxis::PerToken);
        assert!(d.allclose(&m, 1e-5));
    }
}
