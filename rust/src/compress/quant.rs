//! KIVI-style int4 group quantization of the compressed cache (Table 5).
//!
//! Following the paper's §C.4 setup: asymmetric 4-bit quantization applied
//! to the *compressed* features `C`, **per-channel** for keys (statistics
//! over the token axis within a group) and **per-token** for values.
//! Tokens are quantized in groups of [`GROUP`] once a group fills; the
//! residual (< GROUP newest tokens) stays fp32, exactly like KIVI's
//! residual window.
//!
//! ## Fused GEMV over packed codes
//!
//! [`QuantizedBlock::fused_dot_rows`] / [`QuantizedBlock::fused_axpy_rows`]
//! let decode attention consume a sealed block *directly* — packed codes
//! + affine params, dequantized inline inside the reduction — instead of
//! materializing the block into f32 rows first. Both replicate the
//! scalar kernels' reduction order exactly (`dot_scalar`'s 4-accumulator
//! sum, `axpy_row_scalar`'s elementwise update), so they are
//! **bit-identical** to dequantize-then-scalar-GEMV on any block,
//! including partial final groups and column sub-ranges (head slices) —
//! `rust/tests/property_invariants.rs` holds the oracle.

use crate::tensor::Mat;

/// Group length in tokens (the paper sets window = residual = 32).
pub const GROUP: usize = 32;

/// Quantization statistic axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantAxis {
    /// Scale/zero per column (channel) across the group's tokens — keys.
    PerChannel,
    /// Scale/zero per row (token) across channels — values.
    PerToken,
}

/// A quantized `[rows, cols]` block: packed int4 codes + affine params.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedBlock {
    pub rows: usize,
    pub cols: usize,
    pub axis: QuantAxis,
    /// Two 4-bit codes per byte, row-major.
    packed: Vec<u8>,
    scale: Vec<f32>,
    zero: Vec<f32>,
}

fn params_len(rows: usize, cols: usize, axis: QuantAxis) -> usize {
    match axis {
        QuantAxis::PerChannel => cols,
        QuantAxis::PerToken => rows,
    }
}

/// Quantize a dense block to int4.
pub fn quantize_block(m: &Mat, axis: QuantAxis) -> QuantizedBlock {
    let (rows, cols) = (m.rows, m.cols);
    let np = params_len(rows, cols, axis);
    let mut mins = vec![f32::INFINITY; np];
    let mut maxs = vec![f32::NEG_INFINITY; np];
    for i in 0..rows {
        for j in 0..cols {
            let p = match axis {
                QuantAxis::PerChannel => j,
                QuantAxis::PerToken => i,
            };
            let v = m.at(i, j);
            mins[p] = mins[p].min(v);
            maxs[p] = maxs[p].max(v);
        }
    }
    let mut scale = vec![0.0f32; np];
    let mut zero = vec![0.0f32; np];
    for p in 0..np {
        let range = (maxs[p] - mins[p]).max(1e-8);
        scale[p] = range / 15.0;
        zero[p] = mins[p];
    }
    let n = rows * cols;
    let mut packed = vec![0u8; n.div_ceil(2)];
    for i in 0..rows {
        for j in 0..cols {
            let p = match axis {
                QuantAxis::PerChannel => j,
                QuantAxis::PerToken => i,
            };
            let q = (((m.at(i, j) - zero[p]) / scale[p]).round() as i32).clamp(0, 15) as u8;
            let idx = i * cols + j;
            if idx % 2 == 0 {
                packed[idx / 2] |= q;
            } else {
                packed[idx / 2] |= q << 4;
            }
        }
    }
    QuantizedBlock {
        rows,
        cols,
        axis,
        packed,
        scale,
        zero,
    }
}

impl QuantizedBlock {
    /// Packed int4 codes (two per byte, row-major) — snapshot serialization.
    pub fn packed(&self) -> &[u8] {
        &self.packed
    }

    /// Affine scales (one per channel or token, by [`QuantizedBlock::axis`]).
    pub fn scale(&self) -> &[f32] {
        &self.scale
    }

    /// Affine zero points.
    pub fn zero(&self) -> &[f32] {
        &self.zero
    }

    /// Reassemble a block from its serialized parts (snapshot restore).
    /// Validates every length so corrupt cold-tier data errors instead
    /// of panicking later; the codes and params are taken verbatim, so a
    /// round-trip through [`QuantizedBlock::packed`] etc. dequantizes
    /// bit-identically.
    pub fn from_raw(
        rows: usize,
        cols: usize,
        axis: QuantAxis,
        packed: Vec<u8>,
        scale: Vec<f32>,
        zero: Vec<f32>,
    ) -> anyhow::Result<Self> {
        let np = params_len(rows, cols, axis);
        anyhow::ensure!(
            packed.len() == (rows * cols).div_ceil(2),
            "quant block: packed {} != {} for {rows}x{cols}",
            packed.len(),
            (rows * cols).div_ceil(2)
        );
        anyhow::ensure!(
            scale.len() == np && zero.len() == np,
            "quant block: params {}/{} != {np}",
            scale.len(),
            zero.len()
        );
        Ok(QuantizedBlock {
            rows,
            cols,
            axis,
            packed,
            scale,
            zero,
        })
    }

    /// Dequantize back to f32.
    pub fn dequantize(&self) -> Mat {
        self.dequantize_rows(0, self.rows)
    }

    /// Dequantize a row range `[lo, hi)` only (tile-wise reconstruction).
    pub fn dequantize_rows(&self, lo: usize, hi: usize) -> Mat {
        let mut out = Mat::zeros(hi - lo, self.cols);
        self.dequantize_rows_into(lo, hi, &mut out.data);
        out
    }

    /// Dequantize rows `[lo, hi)` directly into a caller-provided slice of
    /// `(hi - lo) * cols` floats — the allocation-free path used when
    /// assembling multi-group reconstructions into one preallocated
    /// buffer.
    pub fn dequantize_rows_into(&self, lo: usize, hi: usize, out: &mut [f32]) {
        assert!(lo <= hi && hi <= self.rows);
        assert_eq!(out.len(), (hi - lo) * self.cols);
        for i in lo..hi {
            for j in 0..self.cols {
                let idx = i * self.cols + j;
                let byte = self.packed[idx / 2];
                let q = if idx % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                let p = match self.axis {
                    QuantAxis::PerChannel => j,
                    QuantAxis::PerToken => i,
                };
                out[(i - lo) * self.cols + j] = q as f32 * self.scale[p] + self.zero[p];
            }
        }
    }

    /// Dequantize one element at row `r`, absolute column `j` — the
    /// inline primitive the fused GEMV kernels are built from. Exactly
    /// the arithmetic of [`QuantizedBlock::dequantize_rows_into`]
    /// (`q as f32 * scale[p] + zero[p]`), so fused and
    /// materialize-then-compute paths see identical f32 values.
    #[inline(always)]
    fn deq(&self, r: usize, j: usize) -> f32 {
        let idx = r * self.cols + j;
        let byte = self.packed[idx / 2];
        let q = if idx % 2 == 0 { byte & 0x0F } else { byte >> 4 };
        let p = match self.axis {
            QuantAxis::PerChannel => j,
            QuantAxis::PerToken => r,
        };
        q as f32 * self.scale[p] + self.zero[p]
    }

    /// Fused dequantize-dot: for every row `r` of the block,
    /// `out[r] = dot(x, deq(row r)[c0..c1]) * scale_mul`, with the packed
    /// codes dequantized inline — the block is never materialized to f32.
    ///
    /// The reduction replicates `dot_scalar` exactly (4 running
    /// accumulators over `x[o] * deq`, summed `s0+s1+s2+s3`, sequential
    /// remainder tail), so the result is **bit-identical** to
    /// dequantizing rows first and calling the scalar dot on each —
    /// decode attention's int4 key scores ride on this
    /// (`scale_mul` folds in the per-head `1/√d_head`).
    pub fn fused_dot_rows(&self, x: &[f32], c0: usize, c1: usize, scale_mul: f32, out: &mut [f32]) {
        assert!(c0 <= c1 && c1 <= self.cols, "column range out of bounds");
        assert_eq!(x.len(), c1 - c0);
        assert_eq!(out.len(), self.rows);
        let w = c1 - c0;
        let chunks = w / 4;
        for (r, or) in out.iter_mut().enumerate() {
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for c in 0..chunks {
                let o = c * 4;
                s0 += x[o] * self.deq(r, c0 + o);
                s1 += x[o + 1] * self.deq(r, c0 + o + 1);
                s2 += x[o + 2] * self.deq(r, c0 + o + 2);
                s3 += x[o + 3] * self.deq(r, c0 + o + 3);
            }
            let mut s = s0 + s1 + s2 + s3;
            for o in chunks * 4..w {
                s += x[o] * self.deq(r, c0 + o);
            }
            *or = s * scale_mul;
        }
    }

    /// Fused dequantize-AXPY: `acc[j] += weights[r] * deq(r, c0 + j)` for
    /// every row `r` ascending — the weighted value sum of decode
    /// attention, consuming packed codes directly.
    ///
    /// AXPY is elementwise (one mul + one add per element), so this is
    /// **bit-identical** to dequantizing each row and calling the scalar
    /// AXPY per row in the same ascending order.
    pub fn fused_axpy_rows(&self, weights: &[f32], c0: usize, c1: usize, acc: &mut [f32]) {
        assert!(c0 <= c1 && c1 <= self.cols, "column range out of bounds");
        assert_eq!(weights.len(), self.rows);
        assert_eq!(acc.len(), c1 - c0);
        for (r, &s) in weights.iter().enumerate() {
            for (o, a) in acc.iter_mut().enumerate() {
                *a += s * self.deq(r, c0 + o);
            }
        }
    }

    /// True storage footprint: packed codes + affine params.
    pub fn bytes(&self) -> usize {
        self.packed.len() + (self.scale.len() + self.zero.len()) * 4
    }
}

/// Quantize–dequantize (straight-through fake quant) — the QAT loss path
/// and the PTQ evaluation path share this.
pub fn fake_quant(m: &Mat, axis: QuantAxis) -> Mat {
    quantize_block(m, axis).dequantize()
}

/// Worst-case absolute quantization error for a block (half a step).
pub fn max_quant_step(m: &Mat, axis: QuantAxis) -> f32 {
    let q = quantize_block(m, axis);
    q.scale.iter().fold(0.0f32, |a, &s| a.max(s)) * 0.5
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Pcg64::new(1);
        for axis in [QuantAxis::PerChannel, QuantAxis::PerToken] {
            let m = Mat::randn(32, 16, 1.0, &mut rng);
            let q = quantize_block(&m, axis);
            let d = q.dequantize();
            // Error per element must be within half a quantization step.
            let step = max_quant_step(&m, axis);
            assert!(
                m.max_abs_diff(&d) <= step + 1e-5,
                "axis={axis:?} err={} step={step}",
                m.max_abs_diff(&d)
            );
        }
    }

    #[test]
    fn per_channel_robust_to_channel_scale_outliers() {
        // A channel with huge magnitude must not destroy other channels —
        // the reason KIVI uses per-channel for keys.
        let mut rng = Pcg64::new(2);
        let mut m = Mat::randn(32, 8, 1.0, &mut rng);
        m.scale_col(0, 100.0);
        let dc = fake_quant(&m, QuantAxis::PerChannel).sub(&m);
        let dt = fake_quant(&m, QuantAxis::PerToken).sub(&m);
        // Error on the non-outlier columns:
        let ec = dc.cols_slice(1, 8).frob_norm();
        let et = dt.cols_slice(1, 8).frob_norm();
        assert!(ec < et / 3.0, "per-channel {ec} should beat per-token {et}");
    }

    #[test]
    fn per_token_robust_to_token_outliers() {
        let mut rng = Pcg64::new(3);
        let mut m = Mat::randn(16, 8, 1.0, &mut rng);
        m.scale_row(0, 100.0);
        let dt = fake_quant(&m, QuantAxis::PerToken).sub(&m);
        let dc = fake_quant(&m, QuantAxis::PerChannel).sub(&m);
        let et = dt.rows_slice(1, 16).frob_norm();
        let ec = dc.rows_slice(1, 16).frob_norm();
        assert!(et < ec / 3.0, "per-token {et} should beat per-channel {ec}");
    }

    #[test]
    fn packing_is_4bit() {
        let mut rng = Pcg64::new(4);
        let m = Mat::randn(GROUP, 26, 1.0, &mut rng);
        let q = quantize_block(&m, QuantAxis::PerChannel);
        // 32*26 codes = 416 bytes packed, + 2*26 f32 params = 208 bytes
        assert_eq!(q.bytes(), (GROUP * 26) / 2 + 2 * 26 * 4);
        // 8× reduction on codes vs f32 (modulo params overhead)
        assert!(q.bytes() * 4 < GROUP * 26 * 4);
    }

    #[test]
    fn dequantize_rows_matches_full() {
        let mut rng = Pcg64::new(5);
        let m = Mat::randn(20, 6, 1.0, &mut rng);
        let q = quantize_block(&m, QuantAxis::PerChannel);
        let full = q.dequantize();
        let part = q.dequantize_rows(5, 13);
        assert!(part.allclose(&full.rows_slice(5, 13), 1e-6));
    }

    #[test]
    fn constant_block_exact() {
        let m = Mat::from_vec(4, 4, vec![3.5; 16]);
        let d = fake_quant(&m, QuantAxis::PerToken);
        assert!(d.allclose(&m, 1e-5));
    }

    /// Fused dot/axpy ≡ dequantize-then-scalar-GEMV, bitwise, including
    /// odd column sub-ranges and a partial (non-GROUP) final block.
    #[test]
    fn fused_gemv_bit_identical_to_materialized() {
        use crate::tensor::matmul::{axpy_row_scalar, dot_scalar};
        let mut rng = Pcg64::new(6);
        for axis in [QuantAxis::PerChannel, QuantAxis::PerToken] {
            for (rows, cols) in [(GROUP, 16), (7, 9), (1, 1), (GROUP, 8)] {
                let m = Mat::randn(rows, cols, 1.0, &mut rng);
                let q = quantize_block(&m, axis);
                let d = q.dequantize();
                for (c0, c1) in [(0, cols), (0, cols / 2), (cols / 3, cols)] {
                    if c0 >= c1 {
                        continue;
                    }
                    let w = c1 - c0;
                    let x: Vec<f32> = (0..w).map(|_| rng.normal()).collect();
                    let scale = 0.37f32;
                    let mut got = vec![0.0f32; rows];
                    q.fused_dot_rows(&x, c0, c1, scale, &mut got);
                    for r in 0..rows {
                        let want = dot_scalar(&x, &d.row(r)[c0..c1]) * scale;
                        assert_eq!(
                            got[r].to_bits(),
                            want.to_bits(),
                            "dot axis={axis:?} {rows}x{cols} [{c0},{c1}) r={r}"
                        );
                    }
                    let ws: Vec<f32> = (0..rows).map(|_| rng.normal().abs()).collect();
                    let mut acc = vec![0.5f32; w];
                    let mut want_acc = acc.clone();
                    q.fused_axpy_rows(&ws, c0, c1, &mut acc);
                    for r in 0..rows {
                        axpy_row_scalar(&mut want_acc, ws[r], &d.row(r)[c0..c1]);
                    }
                    let gb: Vec<u32> = acc.iter().map(|v| v.to_bits()).collect();
                    let wb: Vec<u32> = want_acc.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(gb, wb, "axpy axis={axis:?} {rows}x{cols} [{c0},{c1})");
                }
            }
        }
    }
}
