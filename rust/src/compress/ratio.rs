//! Compression-ratio arithmetic, including Table 4's K/V allocation.
//!
//! Terminology (matching the paper):
//! * **compression ratio** `ρ` — fraction of KV memory removed
//!   (80% ⇒ the compressed cache is 5× smaller).
//! * **keep fraction** — `1 − ρ` per cache, i.e. `h_comp / h_out`.
//!
//! Table 4 lists *keep fractions per cache*: "K(87.5%) V(12.5%)" at total
//! ratio 50% means `keep_k + keep_v = 2·(1 − ρ_total)`.

/// Per-model-layer compression plan for keys and values.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KvCompressionPlan {
    /// Kept channel fraction of the key cache (`h_comp_k / h_out`).
    pub keep_k: f64,
    /// Kept channel fraction of the value cache.
    pub keep_v: f64,
}

impl KvCompressionPlan {
    /// Uniform plan: both caches compressed at `ratio` (the paper's main
    /// Table 1 setting).
    pub fn uniform(ratio: f64) -> Self {
        assert!((0.0..1.0).contains(&ratio), "ratio must be in [0,1)");
        KvCompressionPlan {
            keep_k: 1.0 - ratio,
            keep_v: 1.0 - ratio,
        }
    }

    /// Table 4 allocation: fix the *total* ratio, give the key cache keep
    /// fraction `keep_k`; the value keep fraction is implied.
    pub fn with_allocation(total_ratio: f64, keep_k: f64) -> Self {
        let budget = 2.0 * (1.0 - total_ratio);
        let keep_v = budget - keep_k;
        assert!(
            keep_k > 0.0 && keep_v > 0.0 && keep_k <= 1.0 && keep_v <= 1.0,
            "infeasible allocation: total={total_ratio} keep_k={keep_k} -> keep_v={keep_v}"
        );
        KvCompressionPlan { keep_k, keep_v }
    }

    /// Total compression ratio across K and V.
    pub fn total_ratio(&self) -> f64 {
        1.0 - (self.keep_k + self.keep_v) / 2.0
    }

    /// Channel rank of the compressed key cache for hidden size `d`.
    pub fn rank_k(&self, d: usize) -> usize {
        rank_for_keep(d, self.keep_k)
    }

    pub fn rank_v(&self, d: usize) -> usize {
        rank_for_keep(d, self.keep_v)
    }

    /// Additional ratio multiplier from int4 quantization of the
    /// compressed cache (4 bits vs 32: ×8 smaller ⇒ Table 5's
    /// "50% origin → 87.5% total" plus int4's own overhead ignored, as in
    /// the paper's headline arithmetic).
    pub fn total_ratio_with_int4(&self) -> f64 {
        1.0 - (1.0 - self.total_ratio()) / 8.0
    }
}

/// Round a keep fraction to a channel rank (≥1 so the cache stays usable).
pub fn rank_for_keep(d: usize, keep: f64) -> usize {
    ((d as f64 * keep).round() as usize).clamp(1, d)
}

/// All Table 4 allocation rows for a given total ratio, as (keep_k, keep_v)
/// pairs in the paper's order (K-heavy → V-heavy).
pub fn table4_allocations(total_ratio: f64) -> Vec<KvCompressionPlan> {
    let budget = 2.0 * (1.0 - total_ratio);
    (1..8)
        .rev()
        .map(|i| {
            let keep_k = budget * i as f64 / 8.0;
            KvCompressionPlan::with_allocation(total_ratio, keep_k)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_matches_paper_settings() {
        let p = KvCompressionPlan::uniform(0.8);
        assert!((p.total_ratio() - 0.8).abs() < 1e-12);
        // d=128 at 80% ⇒ rank 26
        assert_eq!(p.rank_k(128), 26);
        assert_eq!(p.rank_v(128), 26);
    }

    #[test]
    fn table4_rows_at_50() {
        // K(87.5%) V(12.5%) from the paper.
        let p = KvCompressionPlan::with_allocation(0.5, 0.875);
        assert!((p.keep_v - 0.125).abs() < 1e-12);
        assert!((p.total_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn table4_rows_at_75() {
        // K(43.75%) V(6.25%) from the paper.
        let p = KvCompressionPlan::with_allocation(0.75, 0.4375);
        assert!((p.keep_v - 0.0625).abs() < 1e-9);
        assert!((p.total_ratio() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn table4_generator_matches_paper_rows() {
        let rows = table4_allocations(0.5);
        assert_eq!(rows.len(), 7);
        let expect_k = [0.875, 0.75, 0.625, 0.5, 0.375, 0.25, 0.125];
        for (r, e) in rows.iter().zip(expect_k) {
            assert!((r.keep_k - e).abs() < 1e-9, "{} vs {e}", r.keep_k);
            assert!((r.total_ratio() - 0.5).abs() < 1e-9);
        }
    }

    #[test]
    fn int4_total_matches_table5() {
        for (origin, total) in [(0.5, 0.9375), (0.6, 0.95), (0.8, 0.975)] {
            let p = KvCompressionPlan::uniform(origin);
            assert!((p.total_ratio_with_int4() - total).abs() < 1e-9);
        }
        // NOTE: the paper reports 50%→87.5% by counting int4 as 4× (vs
        // fp16 baseline); we store fp32, so int4 is 8×. EXPERIMENTS.md
        // reconciles the two conventions.
    }

    #[test]
    fn rank_clamps() {
        assert_eq!(rank_for_keep(128, 0.0), 1);
        assert_eq!(rank_for_keep(128, 1.0), 128);
        assert_eq!(rank_for_keep(128, 0.2), 26);
    }

    #[test]
    #[should_panic]
    fn infeasible_allocation_panics() {
        // total 50% ⇒ budget 1.0; keep_k=1.0 leaves nothing for V.
        let _ = KvCompressionPlan::with_allocation(0.5, 1.0);
    }
}
