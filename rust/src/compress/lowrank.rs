//! Low-rank factor pairs for the key/value projections.
//!
//! The paper approximates `W ∈ R^{h_in×h_out}` with `A ∈ R^{h_in×h_comp}`
//! and `B ∈ R^{h_comp×h_out}` and **stores `C = X·A` as the compressed
//! cache**; `K̂ = C·B` is reconstructed tile-wise during attention.

use crate::tensor::{matmul, Mat};

/// One `A, B` factor pair.
#[derive(Clone, Debug, PartialEq)]
pub struct LowRankFactors {
    /// Down-projection `[d_in, rank]` — producer of the compressed cache.
    pub a: Mat,
    /// Up-projection `[rank, d_out]` — reconstruction at attention time.
    pub b: Mat,
}

impl LowRankFactors {
    pub fn new(a: Mat, b: Mat) -> Self {
        assert_eq!(a.cols, b.rows, "rank mismatch between A and B");
        LowRankFactors { a, b }
    }

    pub fn rank(&self) -> usize {
        self.a.cols
    }

    pub fn d_in(&self) -> usize {
        self.a.rows
    }

    pub fn d_out(&self) -> usize {
        self.b.cols
    }

    /// Compress a batch of activations: `C = X·A` (`[n, rank]`).
    pub fn compress(&self, x: &Mat) -> Mat {
        x.matmul(&self.a)
    }

    /// Compress a single token's activation row.
    pub fn compress_row(&self, x: &[f32]) -> Vec<f32> {
        matmul::matvec_t(&self.a, x)
    }

    /// [`LowRankFactors::compress_row`] into a preallocated `rank`-length
    /// buffer (zero-alloc decode appends).
    pub fn compress_row_into(&self, x: &[f32], out: &mut [f32]) {
        matmul::matvec_t_into(&self.a, x, out)
    }

    /// Reconstruct `K̂ = C·B` (`[n, d_out]`).
    pub fn reconstruct(&self, c: &Mat) -> Mat {
        c.matmul(&self.b)
    }

    /// [`LowRankFactors::reconstruct`] into a preallocated `[n, d_out]`
    /// output (zero-alloc decode-time window migration).
    pub fn reconstruct_into(&self, c: &Mat, out: &mut Mat) {
        matmul::matmul_into(c, &self.b, out)
    }

    /// Effective weight `A·B` (for ASVD-style whole-weight replacement).
    pub fn effective_weight(&self) -> Mat {
        self.a.matmul(&self.b)
    }

    /// Reconstruction error `‖X·W − X·A·B‖ / ‖X·W‖` on given activations.
    pub fn relative_error(&self, x: &Mat, w: &Mat) -> f32 {
        let exact = x.matmul(w);
        let approx = self.reconstruct(&self.compress(x));
        approx.sub(&exact).frob_norm() / exact.frob_norm().max(1e-12)
    }
}

/// K + V factors for one transformer layer.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerFactors {
    pub k: LowRankFactors,
    pub v: LowRankFactors,
}

/// Factors for every layer + provenance metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelFactors {
    pub layers: Vec<LayerFactors>,
    /// Human-readable provenance ("asvd r=26/26 ft=400" etc.) recorded into
    /// experiment outputs.
    pub provenance: String,
}

const MAGIC: &[u8; 8] = b"CSKVFAC1";

impl ModelFactors {
    pub fn rank_k(&self) -> usize {
        self.layers[0].k.rank()
    }

    pub fn rank_v(&self) -> usize {
        self.layers[0].v.rank()
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        let prov = self.provenance.as_bytes();
        buf.extend_from_slice(&(prov.len() as u64).to_le_bytes());
        buf.extend_from_slice(prov);
        buf.extend_from_slice(&(self.layers.len() as u64).to_le_bytes());
        for l in &self.layers {
            l.k.a.write_to(&mut buf);
            l.k.b.write_to(&mut buf);
            l.v.a.write_to(&mut buf);
            l.v.b.write_to(&mut buf);
        }
        std::fs::write(path, buf)?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        let buf = std::fs::read(path)?;
        anyhow::ensure!(buf.len() > 24 && &buf[..8] == MAGIC, "bad factors file");
        let mut pos = 8;
        let plen = u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap()) as usize;
        pos += 8;
        let provenance = String::from_utf8(buf[pos..pos + plen].to_vec())?;
        pos += plen;
        let n = u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap()) as usize;
        pos += 8;
        let mut layers = Vec::with_capacity(n);
        for _ in 0..n {
            let ka = Mat::read_from(&buf, &mut pos)?;
            let kb = Mat::read_from(&buf, &mut pos)?;
            let va = Mat::read_from(&buf, &mut pos)?;
            let vb = Mat::read_from(&buf, &mut pos)?;
            layers.push(LayerFactors {
                k: LowRankFactors::new(ka, kb),
                v: LowRankFactors::new(va, vb),
            });
        }
        anyhow::ensure!(pos == buf.len(), "trailing bytes in factors file");
        Ok(ModelFactors { layers, provenance })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn compress_reconstruct_shapes() {
        let mut rng = Pcg64::new(1);
        let f = LowRankFactors::new(
            Mat::randn(16, 4, 1.0, &mut rng),
            Mat::randn(4, 16, 1.0, &mut rng),
        );
        let x = Mat::randn(10, 16, 1.0, &mut rng);
        let c = f.compress(&x);
        assert_eq!((c.rows, c.cols), (10, 4));
        let k = f.reconstruct(&c);
        assert_eq!((k.rows, k.cols), (10, 16));
        assert_eq!(f.rank(), 4);
    }

    #[test]
    fn compress_row_matches_batch() {
        let mut rng = Pcg64::new(2);
        let f = LowRankFactors::new(
            Mat::randn(8, 3, 1.0, &mut rng),
            Mat::randn(3, 8, 1.0, &mut rng),
        );
        let x = Mat::randn(5, 8, 1.0, &mut rng);
        let c = f.compress(&x);
        for i in 0..5 {
            let row = f.compress_row(x.row(i));
            for j in 0..3 {
                assert!((row[j] - c.at(i, j)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn exact_when_full_rank_factors_of_w() {
        let mut rng = Pcg64::new(3);
        let w = Mat::randn(12, 12, 1.0, &mut rng);
        let d = crate::tensor::svd::svd(&w);
        let (a, b) = d.factors(12);
        let f = LowRankFactors::new(a, b);
        let x = Mat::randn(30, 12, 1.0, &mut rng);
        assert!(f.relative_error(&x, &w) < 1e-3);
    }

    #[test]
    fn factors_roundtrip_disk() {
        let mut rng = Pcg64::new(4);
        let mut mk = move || {
            LowRankFactors::new(
                Mat::randn(8, 2, 1.0, &mut rng),
                Mat::randn(2, 8, 1.0, &mut rng),
            )
        };
        let mut rng2 = Pcg64::new(5);
        let _ = &mut rng2;
        let mf = ModelFactors {
            layers: vec![
                LayerFactors { k: mk(), v: mk() },
                LayerFactors { k: mk(), v: mk() },
            ],
            provenance: "test r=2".into(),
        };
        let dir = std::env::temp_dir().join("cskv_test_factors");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("f.bin");
        mf.save(&p).unwrap();
        let mf2 = ModelFactors::load(&p).unwrap();
        assert_eq!(mf, mf2);
        assert_eq!(mf2.rank_k(), 2);
    }

    #[test]
    #[should_panic]
    fn rank_mismatch_panics() {
        let a = Mat::zeros(8, 3);
        let b = Mat::zeros(4, 8);
        let _ = LowRankFactors::new(a, b);
    }
}
