//! StreamingLLM baseline: attention sinks + recent-window eviction.
//!
//! Keeps the first `n_sink` tokens (attention sinks) plus the most recent
//! tokens within a total token budget. Evicted tokens are gone forever —
//! the failure mode the paper highlights on retrieval tasks (the queried
//! line is usually outside the window).
//!
//! RoPE positions are **cache-relative** ("we use positions within the
//! cache rather than those in the original text" — StreamingLLM §3.2),
//! which is what lets the model run past its trained length.

use crate::tensor::Mat;

use crate::kvcache::snapshot::{self, tags, SnapReader, SnapWriter};
use crate::kvcache::{CacheView, DecodeView, GrowMat, KvCachePolicy, KvSnapshot};

pub struct StreamingLlmCache {
    n_sink: usize,
    budget: usize,
    layers: Vec<LayerState>,
}

struct LayerState {
    k: GrowMat,
    v: GrowMat,
    abs_pos: Vec<usize>,
    /// Total tokens seen (kept + evicted).
    n: usize,
    /// Cumulative eviction count — synced views record it as their epoch.
    /// Every eviction shifts all non-sink rows *and* their cache-relative
    /// RoPE positions, so any missed eviction dirties rows from
    /// `n_sink` on.
    evictions: usize,
}

impl StreamingLlmCache {
    /// `budget` = max kept tokens (sinks included); the paper's Table 1
    /// rows use `budget = (1 - ratio) × prompt_len`.
    pub fn new(n_layers: usize, d_model: usize, n_sink: usize, budget: usize) -> Self {
        assert!(budget > n_sink, "budget must exceed sink count");
        StreamingLlmCache {
            n_sink,
            budget,
            layers: (0..n_layers)
                .map(|_| LayerState {
                    k: GrowMat::new(d_model),
                    v: GrowMat::new(d_model),
                    abs_pos: Vec::new(),
                    n: 0,
                    evictions: 0,
                })
                .collect(),
        }
    }

    fn evict(&mut self, layer: usize) {
        let n_sink = self.n_sink;
        let budget = self.budget;
        let l = &mut self.layers[layer];
        while l.abs_pos.len() > budget {
            // Drop the oldest non-sink entry.
            l.k.remove_row(n_sink);
            l.v.remove_row(n_sink);
            l.abs_pos.remove(n_sink);
            l.evictions += 1;
        }
    }
}

impl KvCachePolicy for StreamingLlmCache {
    fn name(&self) -> String {
        format!("streamingllm(sink={},budget={})", self.n_sink, self.budget)
    }

    fn ingest_prefill(&mut self, layer: usize, _xnorm: &Mat, k: &Mat, v: &Mat) -> Option<(Mat, Mat)> {
        {
            let l = &mut self.layers[layer];
            l.k.push_mat(k);
            l.v.push_mat(v);
            l.abs_pos.extend(0..k.rows);
            l.n = k.rows;
        }
        self.evict(layer);
        None
    }

    fn append(&mut self, layer: usize, _xnorm: &[f32], k: &[f32], v: &[f32]) {
        {
            let l = &mut self.layers[layer];
            let pos = l.n;
            l.k.push_row(k);
            l.v.push_row(v);
            l.abs_pos.push(pos);
            l.n += 1;
        }
        self.evict(layer);
    }

    fn sync_view(&mut self, layer: usize, view: &mut DecodeView) {
        let n_sink = self.n_sink;
        let l = &self.layers[layer];
        let kept = l.abs_pos.len();
        // Sink rows never move: index, cache-relative RoPE position and
        // contents are all stable. Any eviction shifts every non-sink row
        // *and changes its RoPE position*, so a view that missed one
        // rebuilds everything from the first non-sink row.
        let start = if view.epoch == l.evictions {
            view.len().min(kept)
        } else {
            n_sink.min(view.len()).min(kept)
        };
        view.truncate(start);
        for i in start..kept {
            // Cache-relative RoPE positions: row i rotates at angle i.
            view.write_row(i, l.k.row(i), l.v.row(i), i, l.abs_pos[i]);
        }
        view.epoch = l.evictions;
    }

    fn materialize(&self, layer: usize) -> CacheView {
        let l = &self.layers[layer];
        let n = l.abs_pos.len();
        CacheView {
            k: l.k.to_mat(),
            v: l.v.to_mat(),
            // Cache-relative positions: 0..n in cache order.
            rope_pos: (0..n).collect(),
            abs_pos: l.abs_pos.clone(),
        }
    }

    fn reserve(&mut self, additional_tokens: usize) {
        let cap = self.budget + 1;
        for l in &mut self.layers {
            let extra = additional_tokens.min(cap);
            l.k.reserve_rows(extra);
            l.v.reserve_rows(extra);
        }
    }

    fn query_rope_pos(&self, layer: usize, _abs_pos: usize) -> usize {
        // The query sits one past the newest cache slot.
        self.layers[layer].abs_pos.len()
    }

    fn len(&self, layer: usize) -> usize {
        self.layers[layer].abs_pos.len()
    }

    fn kv_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.bytes() + l.v.bytes()).sum()
    }

    fn kv_bytes_projected(&self, tokens: usize) -> usize {
        // Sinks + recent window: storage never exceeds the budget.
        let kept = tokens.min(self.budget);
        self.layers
            .iter()
            .map(|l| 4 * kept * (l.k.cols + l.v.cols))
            .sum()
    }

    fn snapshot(&self) -> KvSnapshot {
        let mut w = SnapWriter::new();
        w.write_usize(self.n_sink);
        w.write_usize(self.budget);
        w.write_usize(self.layers.len());
        for l in &self.layers {
            snapshot::write_growmat(&mut w, &l.k);
            snapshot::write_growmat(&mut w, &l.v);
            w.usizes(&l.abs_pos);
            w.write_usize(l.n);
            w.write_usize(l.evictions);
        }
        KvSnapshot::new(tags::STREAMING, w.finish())
    }

    fn restore(&mut self, snap: &KvSnapshot) -> anyhow::Result<()> {
        snap.expect_tag(tags::STREAMING, "streamingllm cache")?;
        let mut r = SnapReader::new(snap.payload());
        let n_sink = r.read_usize()?;
        let budget = r.read_usize()?;
        anyhow::ensure!(
            n_sink == self.n_sink && budget == self.budget,
            "streamingllm cache: snapshot sink/budget {n_sink}/{budget} != target {}/{}",
            self.n_sink,
            self.budget
        );
        let n_layers = r.read_usize()?;
        anyhow::ensure!(
            n_layers == self.layers.len(),
            "streamingllm cache: snapshot has {n_layers} layers, target {}",
            self.layers.len()
        );
        for l in &mut self.layers {
            let k = snapshot::read_growmat(&mut r)?;
            let v = snapshot::read_growmat(&mut r)?;
            let abs_pos = r.usizes()?;
            let n = r.read_usize()?;
            let evictions = r.read_usize()?;
            anyhow::ensure!(
                k.cols == l.k.cols
                    && v.cols == l.v.cols
                    && k.rows() == abs_pos.len()
                    && v.rows() == abs_pos.len()
                    && abs_pos.len() <= n
                    && abs_pos.len() <= self.budget,
                "streamingllm cache: inconsistent layer snapshot (kept={}, n={n})",
                abs_pos.len()
            );
            l.k = k;
            l.v = v;
            l.abs_pos = abs_pos;
            l.n = n;
            l.evictions = evictions;
        }
        r.expect_end()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn ingest(c: &mut StreamingLlmCache, t: usize, d: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Pcg64::new(seed);
        let x = Mat::randn(t, d, 1.0, &mut rng);
        let k = Mat::randn(t, d, 1.0, &mut rng);
        let v = Mat::randn(t, d, 1.0, &mut rng);
        c.ingest_prefill(0, &x, &k, &v);
        (k, v)
    }

    #[test]
    fn keeps_sinks_and_recent() {
        let mut c = StreamingLlmCache::new(1, 4, 2, 6);
        let (k, _) = ingest(&mut c, 20, 4, 1);
        let view = c.materialize(0);
        view.validate();
        assert_eq!(view.len(), 6);
        // sinks 0,1 + recent 16..20
        assert_eq!(view.abs_pos, vec![0, 1, 16, 17, 18, 19]);
        assert_eq!(view.k.row(0), k.row(0));
        assert_eq!(view.k.row(5), k.row(19));
        // cache-relative rope positions
        assert_eq!(view.rope_pos, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(c.query_rope_pos(0, 20), 6);
    }

    #[test]
    fn decode_eviction_maintains_budget() {
        let mut c = StreamingLlmCache::new(2, 4, 1, 5);
        ingest(&mut c, 8, 4, 2);
        let mut rng = Pcg64::new(3);
        for step in 0..10 {
            let row: Vec<f32> = (0..4).map(|_| rng.normal()).collect();
            c.append(0, &row, &row, &row);
            assert_eq!(c.len(0), 5);
            let view = c.materialize(0);
            // newest token always present
            assert_eq!(*view.abs_pos.last().unwrap(), 8 + step);
            // sink always present
            assert_eq!(view.abs_pos[0], 0);
        }
    }

    #[test]
    fn sync_view_incremental_matches_fresh_while_rolling() {
        let mut c = StreamingLlmCache::new(1, 4, 2, 6);
        ingest(&mut c, 4, 4, 8); // below budget: append-only phase first
        let mut live = DecodeView::new(4, 2, 10000.0);
        c.sync_view(0, &mut live);
        let mut rng = Pcg64::new(9);
        for _ in 0..10 {
            let row: Vec<f32> = (0..4).map(|_| rng.normal()).collect();
            c.append(0, &row, &row, &row);
            c.sync_view(0, &mut live);
            live.validate();
        }
        let mut fresh = DecodeView::new(4, 2, 10000.0);
        c.sync_view(0, &mut fresh);
        assert!(live.same_contents(&fresh));
        assert_eq!(live.len(), c.len(0));
        // Cache-relative positions are contiguous in the view.
        assert_eq!(live.rope_positions().to_vec(), (0..live.len()).collect::<Vec<_>>());
    }

    #[test]
    fn memory_is_budget_bound() {
        let mut c = StreamingLlmCache::new(1, 8, 2, 10);
        ingest(&mut c, 100, 8, 4);
        assert_eq!(c.kv_bytes(), 10 * 2 * 8 * 4);
    }

    #[test]
    fn short_prompts_not_evicted() {
        let mut c = StreamingLlmCache::new(1, 4, 2, 16);
        ingest(&mut c, 5, 4, 5);
        assert_eq!(c.len(0), 5);
        assert_eq!(c.materialize(0).abs_pos, vec![0, 1, 2, 3, 4]);
    }
}
