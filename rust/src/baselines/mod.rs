//! Baseline KV-cache compression methods the paper compares against
//! (Table 1):
//!
//! * [`streaming`] — StreamingLLM [Xiao et al., 2024]: attention sinks +
//!   recent window, cache-relative RoPE positions.
//! * [`h2o`] — H2O [Zhang et al., 2023]: heavy-hitter tokens selected by
//!   cumulative attention mass + a recent window.
//! * [`asvd`] — ASVD [Yuan et al., 2024] applied to `W_K`/`W_V` only
//!   (the paper's footnote 2): whole-projection low-rank replacement, no
//!   bi-branch window, no fine-tuning — also used for CSKV's init.
//!
//! All are [`crate::kvcache::KvCachePolicy`] implementations and are
//! evaluated through exactly the same engine/harness as CSKV.

pub mod asvd;
pub mod h2o;
pub mod streaming;

pub use asvd::AsvdCache;
pub use h2o::H2oCache;
pub use streaming::StreamingLlmCache;
