//! ASVD baseline: whole-projection low-rank replacement.
//!
//! Per the paper's footnote 2, the comparison decomposes only `W_K`/`W_V`
//! per layer (activation-aware SVD, no fine-tuning, no bi-branch window).
//! Consequently *prefill attention is lossy too* — this policy returns
//! replacement K/V from `ingest_prefill`, which is exactly why its 80%
//! rows collapse in Table 1 while CSKV's exact-prefill + window survive.

use std::sync::Arc;

use crate::compress::ModelFactors;
use crate::tensor::Mat;

use crate::kvcache::snapshot::{self, tags, SnapReader, SnapWriter};
use crate::kvcache::{CacheView, DecodeView, GrowMat, KvCachePolicy, KvSnapshot};

pub struct AsvdCache {
    factors: Arc<ModelFactors>,
    layers: Vec<LayerState>,
}

struct LayerState {
    ck: GrowMat,
    cv: GrowMat,
    n: usize,
}

impl AsvdCache {
    pub fn new(factors: Arc<ModelFactors>) -> Self {
        let layers = factors
            .layers
            .iter()
            .map(|lf| LayerState {
                ck: GrowMat::new(lf.k.rank()),
                cv: GrowMat::new(lf.v.rank()),
                n: 0,
            })
            .collect();
        AsvdCache { factors, layers }
    }
}

impl KvCachePolicy for AsvdCache {
    fn name(&self) -> String {
        format!(
            "asvd(r_k={},r_v={})",
            self.factors.rank_k(),
            self.factors.rank_v()
        )
    }

    fn ingest_prefill(&mut self, layer: usize, xnorm: &Mat, _k: &Mat, _v: &Mat) -> Option<(Mat, Mat)> {
        let lf = &self.factors.layers[layer];
        let ck = lf.k.compress(xnorm);
        let cv = lf.v.compress(xnorm);
        let khat = lf.k.reconstruct(&ck);
        let vhat = lf.v.reconstruct(&cv);
        let l = &mut self.layers[layer];
        l.ck.push_mat(&ck);
        l.cv.push_mat(&cv);
        l.n = xnorm.rows;
        // Lossy prefill: attention uses the reconstructed K/V.
        Some((khat, vhat))
    }

    fn append(&mut self, layer: usize, xnorm: &[f32], _k: &[f32], _v: &[f32]) {
        let lf = &self.factors.layers[layer];
        let l = &mut self.layers[layer];
        l.ck.push_row(&lf.k.compress_row(xnorm));
        l.cv.push_row(&lf.v.compress_row(xnorm));
        l.n += 1;
    }

    fn sync_view(&mut self, layer: usize, view: &mut DecodeView) {
        let lf = &self.factors.layers[layer];
        let l = &self.layers[layer];
        let n = l.n;
        view.truncate(n);
        // Compressed features are append-only and immutable: each row is
        // reconstructed (C·B) and RoPE'd exactly once.
        let start = view.len();
        if n > start {
            let kh = lf.k.reconstruct(&l.ck.slice(start, n));
            let vh = lf.v.reconstruct(&l.cv.slice(start, n));
            for (j, i) in (start..n).enumerate() {
                view.write_row(i, kh.row(j), vh.row(j), i, i);
            }
        }
        view.stable_rows = n;
        view.hist_rows = n;
    }

    fn materialize(&self, layer: usize) -> CacheView {
        let lf = &self.factors.layers[layer];
        let l = &self.layers[layer];
        let k = lf.k.reconstruct(&l.ck.to_mat());
        let v = lf.v.reconstruct(&l.cv.to_mat());
        let pos: Vec<usize> = (0..l.n).collect();
        CacheView {
            k,
            v,
            rope_pos: pos.clone(),
            abs_pos: pos,
        }
    }

    fn reserve(&mut self, additional_tokens: usize) {
        for l in &mut self.layers {
            l.ck.reserve_rows(additional_tokens);
            l.cv.reserve_rows(additional_tokens);
        }
    }

    fn lossy_prefill(&self) -> bool {
        true
    }

    fn len(&self, layer: usize) -> usize {
        self.layers[layer].n
    }

    fn kv_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.ck.bytes() + l.cv.bytes()).sum()
    }

    fn kv_bytes_projected(&self, tokens: usize) -> usize {
        // Every token stores rank-r K and V features only.
        self.layers
            .iter()
            .map(|l| 4 * tokens * (l.ck.cols + l.cv.cols))
            .sum()
    }

    fn snapshot(&self) -> KvSnapshot {
        let mut w = SnapWriter::new();
        w.write_usize(self.layers.len());
        for l in &self.layers {
            snapshot::write_growmat(&mut w, &l.ck);
            snapshot::write_growmat(&mut w, &l.cv);
            w.write_usize(l.n);
        }
        KvSnapshot::new(tags::ASVD, w.finish())
    }

    fn restore(&mut self, snap: &KvSnapshot) -> anyhow::Result<()> {
        snap.expect_tag(tags::ASVD, "asvd cache")?;
        let mut r = SnapReader::new(snap.payload());
        let n_layers = r.read_usize()?;
        anyhow::ensure!(
            n_layers == self.layers.len(),
            "asvd cache: snapshot has {n_layers} layers, target {}",
            self.layers.len()
        );
        for l in &mut self.layers {
            let ck = snapshot::read_growmat(&mut r)?;
            let cv = snapshot::read_growmat(&mut r)?;
            let n = r.read_usize()?;
            anyhow::ensure!(
                ck.cols == l.ck.cols
                    && cv.cols == l.cv.cols
                    && ck.rows() == n
                    && cv.rows() == n,
                "asvd cache: inconsistent layer snapshot (n={n}, rows={})",
                ck.rows()
            );
            l.ck = ck;
            l.cv = cv;
            l.n = n;
        }
        r.expect_end()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{LayerFactors, LowRankFactors};
    use crate::util::prng::Pcg64;

    fn factors(d: usize, r: usize, layers: usize, seed: u64) -> Arc<ModelFactors> {
        let mut rng = Pcg64::new(seed);
        let mut mk = || {
            LowRankFactors::new(
                Mat::randn(d, r, 0.3, &mut rng),
                Mat::randn(r, d, 0.3, &mut rng),
            )
        };
        Arc::new(ModelFactors {
            layers: (0..layers)
                .map(|_| LayerFactors { k: mk(), v: mk() })
                .collect(),
            provenance: "test".into(),
        })
    }

    #[test]
    fn prefill_is_lossy_and_consistent_with_materialize() {
        let d = 8;
        let f = factors(d, 3, 1, 1);
        let mut c = AsvdCache::new(f.clone());
        let mut rng = Pcg64::new(2);
        let x = Mat::randn(6, d, 1.0, &mut rng);
        let k = Mat::randn(6, d, 1.0, &mut rng);
        let v = Mat::randn(6, d, 1.0, &mut rng);
        let rep = c.ingest_prefill(0, &x, &k, &v);
        let (khat, vhat) = rep.expect("asvd must replace prefill K/V");
        // Replacement equals the reconstruction of the stored cache.
        let view = c.materialize(0);
        view.validate();
        assert!(view.k.allclose(&khat, 1e-5));
        assert!(view.v.allclose(&vhat, 1e-5));
        // And differs from the exact K (rank 3 < 8).
        assert!(view.k.max_abs_diff(&k) > 1e-3);
    }

    #[test]
    fn memory_is_rank_proportional() {
        let d = 16;
        let f = factors(d, 4, 2, 3);
        let mut c = AsvdCache::new(f);
        let mut rng = Pcg64::new(4);
        let x = Mat::randn(10, d, 1.0, &mut rng);
        let k = Mat::randn(10, d, 1.0, &mut rng);
        let v = Mat::randn(10, d, 1.0, &mut rng);
        for layer in 0..2 {
            c.ingest_prefill(layer, &x, &k, &v);
        }
        assert_eq!(c.kv_bytes(), 2 * 2 * 10 * 4 * 4);
    }

    #[test]
    fn append_grows_cache() {
        let d = 8;
        let f = factors(d, 2, 1, 5);
        let mut c = AsvdCache::new(f);
        let mut rng = Pcg64::new(6);
        let row: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        c.append(0, &row, &row, &row);
        c.append(0, &row, &row, &row);
        assert_eq!(c.len(0), 2);
        let view = c.materialize(0);
        assert_eq!(view.len(), 2);
        // Identical inputs reconstruct identically.
        assert_eq!(view.k.row(0), view.k.row(1));
    }
}
